//! Native gpt-nano: train the tiny causal-transformer LM on the bit-exact
//! quantised simulator across the paper's precision modes — attention,
//! layernorm and a tied softmax head, no PJRT artifacts needed.
//!
//! ```bash
//! cargo run --release --offline --example gpt_nano -- \
//!     [--steps 300] [--seed 0] [--intra-threads 1]
//! ```
//!
//! Expected shape (paper): sr16/kahan16 track fp32; standard16 is worse —
//! nearest rounding cancels the small late-training updates.  Results are
//! bit-identical at every `--intra-threads` setting.

use anyhow::Result;

use bf16_train::qsim::gpt::{GptConfig, GptTrainer};
use bf16_train::qsim::Mode;
use bf16_train::util::cli::Args;

fn main() -> Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let steps = args.opt_u64("steps", 300)? as usize;
    let seed = args.opt_u64("seed", 0)?;
    let intra_threads = args.opt_u64("intra-threads", 1)? as usize;
    args.finish()?;

    println!("gpt-nano: {steps} steps/mode on the native quantised simulator\n");
    println!("{:<12} {:>10} {:>10} {:>9} {:>9}", "mode", "eval loss", "ppl", "cancel%", "steps/s");
    let warm = (steps / 20).max(1);
    for mode in [Mode::Fp32, Mode::Sr16, Mode::Kahan16, Mode::Standard16] {
        let cfg = GptConfig { seed, intra_threads, ..Default::default() };
        let mut tr = GptTrainer::new(cfg, mode);
        let mut cancel = bf16_train::qsim::UpdateStats::default();
        let t0 = std::time::Instant::now();
        for step in 0..steps {
            let lr = if step < warm { 0.2 * (step + 1) as f32 / warm as f32 } else { 0.2 };
            cancel.merge(tr.step(lr).total());
        }
        let dt = t0.elapsed().as_secs_f64();
        let el = tr.eval(8).loss;
        println!(
            "{:<12} {:>10.4} {:>10.2} {:>9.1} {:>9.1}",
            mode.name(),
            el,
            (el as f64).exp(),
            cancel.frac() * 100.0,
            steps as f64 / dt
        );
    }
    println!("\nPerplexity floor is the Markov chain's conditional entropy; uniform = vocab size.");
    Ok(())
}
