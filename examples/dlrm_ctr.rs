//! Recommendation scenario: DLRM on synthetic click-through logs across
//! every precision mode and format — the application where the paper's
//! effect is most visible (embedding tables → tiny, cancellable updates).
//!
//! The whole (policy × seed) grid runs through the threaded `Sweep`, so the
//! table fills in parallel across cores with deterministic per-cell seeds.
//!
//! ```bash
//! cargo run --release --offline --example dlrm_ctr -- [--steps 800] [--seeds 2]
//! ```

use anyhow::Result;

use bf16_train::metrics::mean_std;
use bf16_train::util::cli::Args;
use bf16_train::util::table::{pm, Table};
use bf16_train::{Policy, RunSpec, Runner, Sweep};

fn main() -> Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let steps = args.opt_u64("steps", 800)?;
    let seeds = args.opt_u64("seeds", 2)?;
    args.finish()?;

    let runner = Runner::open("artifacts")?;
    let policies: Vec<Policy> = [
        "fp32",
        "mixed16",
        "standard16",
        "sr16",
        "kahan16",
        "srkahan16",
        "standard16-fp16",
        "sr16-fp16",
        "kahan16-e8m5",
    ]
    .iter()
    .map(|s| Policy::parse(s))
    .collect::<Result<_, _>>()?;

    let base = RunSpec::new("dlrm-small").steps(steps).eval_every(steps);
    let results = Sweep::new(base)
        .policies(policies.iter().copied())
        .seeds(seeds)
        .run(&runner)?;

    let mut table = Table::new(
        "DLRM-CTR: validation AUC% by precision policy",
        &["mode", "fmt", "val AUC %", "cancelled %"],
    );
    for p in &policies {
        let rs = results.for_policy(p);
        // diverged runs are recorded as NaN — filter them like the
        // experiment harness does instead of averaging NaN into the cell
        let aucs: Vec<f64> =
            rs.iter().map(|r| r.val_metric).filter(|v| v.is_finite()).collect();
        let cancel: Vec<f64> = rs
            .iter()
            .map(|r| r.mean_cancel_frac * 100.0)
            .filter(|v| v.is_finite())
            .collect();
        let auc_cell = if aucs.is_empty() {
            "diverged".to_string()
        } else {
            let (m, sd) = mean_std(&aucs);
            pm(m, sd, 2)
        };
        let cancel_cell = if cancel.is_empty() {
            "-".to_string()
        } else {
            format!("{:.1}", mean_std(&cancel).0)
        };
        table.row(vec![
            p.mode.name().to_string(),
            p.fmt.name.to_string(),
            auc_cell,
            cancel_cell,
        ]);
    }
    println!("{}", table.render());
    println!("Shape to expect: fp32 ≈ sr16 ≈ kahan16 > standard16; fp16 lags bf16.");
    Ok(())
}
