//! Recommendation scenario: DLRM on synthetic click-through logs across
//! every precision mode and format — the application where the paper's
//! effect is most visible (embedding tables → tiny, cancellable updates).
//!
//! ```bash
//! cargo run --release --offline --example dlrm_ctr -- [--steps 800] [--seeds 2]
//! ```

use anyhow::Result;

use bf16_train::config::RunConfig;
use bf16_train::coordinator::Trainer;
use bf16_train::metrics::mean_std;
use bf16_train::runtime::{Engine, Manifest};
use bf16_train::util::cli::Args;
use bf16_train::util::table::{pm, Table};

fn main() -> Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let steps = args.opt_u64("steps", 800)?;
    let seeds = args.opt_u64("seeds", 2)?;
    args.finish()?;

    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;
    let mut table = Table::new(
        "DLRM-CTR: validation AUC% by precision policy",
        &["mode", "fmt", "val AUC %", "cancelled %"],
    );
    let sweep: &[(&str, &str)] = &[
        ("fp32", "bf16"),
        ("mixed16", "bf16"),
        ("standard16", "bf16"),
        ("sr16", "bf16"),
        ("kahan16", "bf16"),
        ("srkahan16", "bf16"),
        ("standard16", "fp16"),
        ("sr16", "fp16"),
        ("kahan16", "e8m5"),
    ];
    for (mode, fmt) in sweep {
        let mut aucs = Vec::new();
        let mut cancel = Vec::new();
        for seed in 0..seeds {
            let mut cfg = RunConfig::defaults_for("dlrm-small");
            cfg.mode = mode.to_string();
            cfg.fmt = fmt.to_string();
            cfg.steps = steps;
            cfg.eval_every = steps;
            cfg.seed = seed;
            let mut tr = Trainer::new(&engine, &manifest, cfg)?;
            let s = tr.run()?;
            aucs.push(s.val_metric);
            cancel.push(s.mean_cancel_frac * 100.0);
        }
        let (m, sd) = mean_std(&aucs);
        let (cm, _) = mean_std(&cancel);
        table.row(vec![
            mode.to_string(),
            fmt.to_string(),
            pm(m, sd, 2),
            format!("{cm:.1}"),
        ]);
        eprintln!("  {mode}-{fmt}: AUC {m:.2}");
    }
    println!("{}", table.render());
    println!("Shape to expect: fp32 ≈ sr16 ≈ kahan16 > standard16; fp16 lags bf16.");
    Ok(())
}
