//! Theory walkthrough (paper §3.1): reproduce Figure 2 and probe Theorem 1
//! interactively — no artifacts needed, pure rust-native simulation (this
//! path deliberately bypasses the PJRT `Runner`/`RunSpec` API; the typed
//! `precision::Policy` modes map onto `Placement` rounding sites here).
//!
//! ```bash
//! cargo run --release --offline --example lsq_theory [-- steps]
//! ```

use bf16_train::qsim::lsq::{self, LsqConfig, LsqData, Placement};

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(20_000);
    let cfg = LsqConfig { steps, ..LsqConfig::default() };
    let data = LsqData::generate(&cfg);
    println!(
        "10-dim least squares, w* ~ U[0,100), lr {}, batch 1, bf16 — {} steps",
        cfg.lr, cfg.steps
    );
    println!(
        "Theorem 1 halting radius: {:.4e}\n",
        lsq::halting_radius(&cfg, &data)
    );
    println!(
        "{:<22} {:>12} {:>14} {:>10}",
        "rounding placement", "final loss", "final ||w-w*||", "halted %"
    );
    for p in Placement::ALL {
        let run = lsq::run(&cfg, &data, p);
        println!(
            "{:<22} {:>12.4e} {:>14.4e} {:>9.1}%",
            p.name(),
            run.losses.last().copied().unwrap_or(f32::NAN),
            run.final_dist,
            run.halt_frac * 100.0
        );
    }
    println!(
        "\nReading: 'weight-update' halts orders of magnitude above 'exact';\n\
         'fwd-bwd' barely matters; SR and Kahan rescue convergence (paper Fig. 2)."
    );
}
