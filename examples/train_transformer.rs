//! End-to-end driver: train a transformer LM through the full stack —
//! Pallas-semantics kernels inside an AOT-lowered JAX graph, executed by the
//! rust coordinator over the synthetic token pipeline — and log the loss
//! curve (recorded in EXPERIMENTS.md §End-to-end).
//!
//! ```bash
//! cargo run --release --offline --example train_transformer -- \
//!     [--app gpt-tiny|gpt-small|gpt-100m] [--steps 300] [--mode kahan16]
//! ```
//!
//! `--mode` takes any typed policy name (`kahan16`, `sr16-e8m5`, …).
//! `gpt-tiny` (~0.9M params) is lowered by default; `gpt-small`/`gpt-100m`
//! need `python -m compile.aot --filter gpt-small` (or gpt-100m) first.
//!
//! For a transformer LM on the *bit-exact native simulator* (no artifacts,
//! exact per-operator rounding, deterministic across `--intra-threads`),
//! see the `gpt_nano` example / `repro exp gpt` instead.

use anyhow::Result;

use bf16_train::util::cli::Args;
use bf16_train::{Policy, RunSpec, Runner};

fn main() -> Result<()> {
    let mut args = Args::parse(std::env::args().skip(1))?;
    let app = args.opt("app", "gpt-tiny");
    let policy: Policy = args.opt("mode", "kahan16").parse()?;
    let steps = args.opt_u64("steps", 300)?;
    args.finish()?;

    let runner = Runner::open("artifacts")?;
    let spec = RunSpec::new(&app)
        .policy(policy)
        .steps(steps)
        .eval_every(steps)
        .log_every((steps / 50).max(1));
    let cfg = spec.build();
    println!(
        "end-to-end: {} [{}] — {} steps of causal-LM training on synthetic Markov corpus",
        app, policy, steps
    );
    let artifact = runner.manifest().get(&cfg.artifact_name())?;
    println!(
        "model: {} params across {} tensors (vocab={}, dim={}, layers={})",
        artifact.param_elements,
        artifact.num_params,
        artifact.hparam("vocab"),
        artifact.hparam("dim"),
        artifact.hparam("layers"),
    );

    let mut tr = runner.trainer(&spec)?;
    let t0 = std::time::Instant::now();
    let summary = tr.run()?;
    println!("\nloss curve (step → train loss / ppl):");
    for p in summary
        .history
        .points
        .iter()
        .step_by((summary.history.points.len() / 12).max(1))
    {
        println!(
            "  step {:>5}: loss {:.4}  ppl {:.2}  lr {:.2e}",
            p.step,
            p.loss,
            (p.loss as f64).exp(),
            p.lr
        );
    }
    println!(
        "\nfinal: val ppl {:.2} | {:.1} steps/s | {:.1}s total",
        summary.val_metric,
        steps as f64 / t0.elapsed().as_secs_f64(),
        t0.elapsed().as_secs_f64()
    );
    std::fs::create_dir_all("results")?;
    let path = format!("results/e2e__{app}__{policy}.csv");
    std::fs::write(&path, summary.history.to_csv(None))?;
    println!("history written to {path}");
    Ok(())
}
