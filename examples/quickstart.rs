//! Quickstart: train one model in three precision modes and compare.
//!
//! ```bash
//! make artifacts && cargo build --release --offline
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Loads the AOT-compiled DLRM artifact (bf16), trains it with the failing
//! standard nearest-rounding update, the paper's stochastic-rounding fix,
//! and the fp32 baseline — printing the validation AUC of each.

use anyhow::Result;

use bf16_train::config::RunConfig;
use bf16_train::coordinator::Trainer;
use bf16_train::runtime::{Engine, Manifest};

fn main() -> Result<()> {
    let engine = Engine::cpu()?;
    let manifest = Manifest::load("artifacts")?;
    println!("PJRT platform: {}", engine.platform());

    for mode in ["fp32", "standard16", "sr16"] {
        let mut cfg = RunConfig::defaults_for("dlrm-small");
        cfg.mode = mode.to_string();
        cfg.steps = 600;
        cfg.eval_every = 600;
        let mut tr = Trainer::new(&engine, &manifest, cfg)?;
        let s = tr.run()?;
        println!(
            "{mode:<12} val AUC = {:>6.2}%   (train loss {:.4}, {:.0}% of updates cancelled)",
            s.val_metric,
            s.final_train_loss,
            s.mean_cancel_frac * 100.0
        );
    }
    println!("\nExpected: sr16 ≈ fp32, standard16 below both (the paper's headline).");
    Ok(())
}
