//! Quickstart: train one model in three precision modes and compare.
//!
//! ```bash
//! make artifacts && cargo build --release --offline
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Opens the runtime through the library `Runner` facade, then trains the
//! AOT-compiled DLRM artifact under three typed policies — the failing
//! standard nearest-rounding update, the paper's stochastic-rounding fix,
//! and the fp32 baseline — printing the validation AUC of each.

use anyhow::Result;

use bf16_train::{Mode, Policy, RunSpec, Runner};

fn main() -> Result<()> {
    let runner = Runner::open("artifacts")?;
    println!("PJRT platform: {}", runner.engine().platform());

    for mode in [Mode::Fp32, Mode::Standard16, Mode::Sr16] {
        let spec = RunSpec::new("dlrm-small")
            .policy(Policy::bf16(mode))
            .steps(600)
            .eval_every(600);
        let s = runner.run(&spec)?;
        println!(
            "{:<12} val AUC = {:>6.2}%   (train loss {:.4}, {:.0}% of updates cancelled)",
            mode.name(),
            s.val_metric,
            s.final_train_loss,
            s.mean_cancel_frac * 100.0
        );
    }
    println!("\nExpected: sr16 ≈ fp32, standard16 below both (the paper's headline).");
    Ok(())
}
