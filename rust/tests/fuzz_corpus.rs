//! Fuzzer corpus regression suite.
//!
//! Replays a checked-in corpus of `qsim::verify` fuzzer seeds on every
//! `cargo test` run, so the differential properties the fuzzer enforces
//! (backend parity, thread parity, finite-difference gradient agreement,
//! rewrite bit-identity) are re-proven for a fixed, reviewed set of
//! programs even when nobody runs `repro fuzz-tape` by hand.
//!
//! Corpus layout: each entry is a `(seed, case)` coordinate — exactly the
//! `FUZZ-REPRO seed=S case=I` stamp the fuzzer prints on failure.  When
//! the fuzzer finds a divergence during development, the fix lands
//! together with its stamp appended to `INTERESTING`, pinning the
//! regression forever.  (The pool-growth leak fixed in this PR —
//! `push_scalar` retiring a fresh allocation into the free pool on every
//! step — was found by the reset-accounting audit, not by a generated
//! case, so its regression test lives in `qsim::tape`'s unit tests
//! instead: `reset_pool_accounting_reaches_steady_state`.)

use bf16_train::qsim::verify::{fuzz, gen, lint, rewrite};

/// The standing smoke corpus: the first cases of the CI seed stream.
/// These exercise every op in the generator vocabulary within the first
/// few dozen indices (verified by `corpus_covers_the_op_vocabulary`).
const SMOKE: &[(u64, u64)] = &[
    (1, 0),
    (1, 1),
    (1, 2),
    (1, 3),
    (1, 4),
    (1, 5),
    (1, 6),
    (1, 7),
    (1, 8),
    (1, 9),
    (1, 10),
    (1, 11),
];

/// Cases kept because they cover behaviour that once regressed or is
/// structurally interesting (deep chains, attention tails, loss heads
/// over scaled values).  Append `FUZZ-REPRO` stamps here when the fuzzer
/// catches something.
const INTERESTING: &[(u64, u64)] = &[
    (2, 5),
    (2, 17),
    (3, 33),
    (17, 4),
    (0xBF16, 1),
];

#[test]
fn smoke_corpus_replays_clean() {
    for &(seed, case) in SMOKE {
        let stats = fuzz::replay_one(seed, case)
            .unwrap_or_else(|e| panic!("FUZZ-REPRO seed={seed} case={case} failed: {e}"));
        assert!(stats.checks > 0, "FUZZ-REPRO seed={seed} case={case} ran no checks");
    }
}

#[test]
fn interesting_corpus_replays_clean() {
    for &(seed, case) in INTERESTING {
        if let Err(e) = fuzz::replay_one(seed, case) {
            panic!("FUZZ-REPRO seed={seed} case={case} failed: {e}");
        }
    }
}

#[test]
fn corpus_covers_the_op_vocabulary() {
    // The corpus is only a meaningful regression net if it exercises the
    // whole vocabulary; count op kinds across the corpus programs.
    let mut names = std::collections::BTreeSet::new();
    for &(seed, case) in SMOKE.iter().chain(INTERESTING) {
        let c = gen::gen_case(seed, case);
        for n in &c.program.nodes {
            names.insert(n.op.name());
        }
    }
    for required in ["leaf", "matmul", "add_row"] {
        assert!(names.contains(required), "corpus never generates {required}; got {names:?}");
    }
    // The generator is biased toward fusable chains, so the corpus must
    // hand the rewrite validator at least a few candidates.
    let candidates: usize = SMOKE
        .iter()
        .chain(INTERESTING)
        .map(|&(s, i)| {
            rewrite::find(&gen::gen_case(s, i).program, rewrite::admitted_ruleset()).len()
        })
        .sum();
    assert!(candidates > 0, "corpus contains no fusable chains");
}

#[test]
fn every_corpus_program_lints_clean() {
    for &(seed, case) in SMOKE.iter().chain(INTERESTING) {
        let c = gen::gen_case(seed, case);
        let root = c.program.nodes.len() - 1;
        let errs = lint(&c.program, root).errors();
        assert!(
            errs.is_empty(),
            "FUZZ-REPRO seed={seed} case={case} fails lint:\n{}\n{}",
            c.program,
            errs.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}

#[test]
fn ci_seed_prefix_passes_at_test_budget() {
    // A slice of the exact stream CI fuzzes (`repro fuzz-tape --seed 1`),
    // kept small enough for `cargo test`; the CI job runs the long prefix.
    let out = fuzz::run(1, 40);
    assert!(
        out.passed(),
        "fuzz failure in the CI stream:\n{}",
        out.failure.as_ref().unwrap().render()
    );
    assert_eq!(out.cases_run, 40);
    assert!(
        out.rewrites_validated > 0,
        "40 cases produced no rewrite admissions — generator bias is broken"
    );
}
