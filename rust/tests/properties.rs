//! Property-based tests (hand-rolled: proptest is unavailable offline).
//! Randomized invariants over the precision substrate, the quantised
//! simulator, and the coordinator's pure components, with explicit seeds so
//! failures reproduce.

use bf16_train::config::Schedule;
use bf16_train::precision::{
    kahan_add, round_nearest, round_nearest_slice, round_stochastic, round_stochastic_slice,
    round_stochastic_slice_keyed, Format, Mode, Policy, ALL, BF16,
};
use bf16_train::qsim::{Backend, QPolicy, Tape, Tensor};
use bf16_train::util::rng::{DitherKey, Rng};

fn random_f32(rng: &mut Rng) -> f32 {
    // wide dynamic range incl. negatives, zeros, tiny and huge magnitudes
    let mag = 10f32.powi(rng.below(60) as i32 - 30);
    let v = rng.normal() * mag;
    if rng.below(50) == 0 {
        0.0
    } else {
        v
    }
}

#[test]
fn prop_round_nearest_is_monotone() {
    // x <= y  =>  Q(x) <= Q(y)  for every format
    let mut rng = Rng::new(0xA1, 0);
    for fmt in ALL {
        for _ in 0..20_000 {
            let a = random_f32(&mut rng);
            let b = random_f32(&mut rng);
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            let ql = round_nearest(lo, fmt);
            let qh = round_nearest(hi, fmt);
            assert!(ql <= qh, "{} monotone violated: {lo} {hi} -> {ql} {qh}", fmt.name);
        }
    }
}

#[test]
fn prop_round_nearest_sign_symmetric() {
    // Q(-x) == -Q(x) (RNE is sign-symmetric)
    let mut rng = Rng::new(0xA2, 0);
    for fmt in ALL {
        for _ in 0..20_000 {
            let x = random_f32(&mut rng);
            let a = round_nearest(-x, fmt);
            let b = -round_nearest(x, fmt);
            assert_eq!(a.to_bits(), b.to_bits(), "{}: x={x}", fmt.name);
        }
    }
}

#[test]
fn prop_stochastic_brackets_value() {
    // SR(x) is one of the two neighbours: |SR(x) - x| < ulp(x)
    let mut rng = Rng::new(0xA3, 0);
    for _ in 0..50_000 {
        let x = rng.normal() * 10f32.powi(rng.below(16) as i32 - 8);
        let q = round_stochastic(x, BF16, rng.next_u32());
        let ulp = 2f32.powi(-7) * x.abs().max(f32::MIN_POSITIVE);
        assert!((q - x).abs() <= ulp, "x={x} q={q}");
    }
}

#[test]
fn prop_stochastic_mean_near_exact() {
    // empirical mean over dithers approaches x (unbiasedness)
    let mut rng = Rng::new(0xA4, 0);
    for _ in 0..20 {
        let x = rng.uniform_in(0.5, 2.0);
        let n = 20_000;
        let mut acc = 0f64;
        for _ in 0..n {
            acc += round_stochastic(x, BF16, rng.next_u32()) as f64;
        }
        let mean = acc / n as f64;
        let ulp = 2f64.powi(-8) * x as f64;
        assert!((mean - x as f64).abs() < ulp * 0.15, "x={x} mean={mean}");
    }
}

#[test]
fn prop_kahan_beats_naive_accumulation() {
    // random small-increment streams: compensated error <= naive error
    let mut rng = Rng::new(0xA5, 0);
    for trial in 0..50 {
        let start = rng.uniform_in(0.5, 4.0);
        let inc = 2f32.powi(-(rng.below(6) as i32) - 9);
        let steps = 500 + rng.below(1500);
        let mut naive = start;
        let mut s = start;
        let mut c = 0.0;
        for _ in 0..steps {
            naive = round_nearest(naive + inc, BF16);
            let (ns, nc) = kahan_add(s, c, inc, BF16);
            s = ns;
            c = nc;
        }
        let exact = start as f64 + inc as f64 * steps as f64;
        let e_naive = (naive as f64 - exact).abs();
        let e_kahan = (s as f64 - exact).abs();
        assert!(
            e_kahan <= e_naive + 2f64.powi(-8) * exact.abs(),
            "trial {trial}: kahan {e_kahan} vs naive {e_naive}"
        );
    }
}

#[test]
fn prop_quantised_forward_error_bounded_per_op() {
    // |quantised_fwd - exact_fwd| on a 2-layer MLP stays within a small
    // multiple of eps times the value scale (no error explosion).
    let mut rng = Rng::new(0xA6, 0);
    for _ in 0..25 {
        let x = Tensor::randn(4, 8, 1.0, &mut rng);
        let w1 = Tensor::randn(8, 16, 0.35, &mut rng);
        let w2 = Tensor::randn(16, 1, 0.25, &mut rng);
        let run = |fmt: Option<Format>| -> f32 {
            let mut t = match fmt {
                None => Tape::new(QPolicy::exact()),
                Some(f) => Tape::new(QPolicy::new(f)),
            };
            let xv = t.input(x.clone());
            let w1v = t.param(w1.clone());
            let w2v = t.param(w2.clone());
            let h = t.matmul(xv, w1v);
            let h = t.relu(h);
            let o = t.matmul(h, w2v);
            let m = t.mean_all(o);
            t.value(m).item()
        };
        let exact = run(None);
        let q = run(Some(BF16));
        // ~4 rounding boundaries; allow a 32x eps budget on the magnitude
        let tol = 32.0 * 2f32.powi(-8) * (exact.abs() + 1.0);
        assert!((q - exact).abs() <= tol, "exact={exact} q={q}");
    }
}

#[test]
fn prop_slice_rounding_kernels_match_scalar_all_formats() {
    // the batched kernels must be bit-identical to the scalar reference for
    // every format, at odd/unaligned lengths straddling the chunk size
    let mut rng = Rng::new(0xB1, 0);
    for fmt in ALL {
        for len in [1usize, 5, 127, 255, 256, 257, 511, 777] {
            let xs: Vec<f32> = (0..len).map(|_| random_f32(&mut rng)).collect();
            // nearest
            let mut fast = xs.clone();
            round_nearest_slice(&mut fast, fmt);
            for (i, (&f, &x)) in fast.iter().zip(&xs).enumerate() {
                assert_eq!(
                    f.to_bits(),
                    round_nearest(x, fmt).to_bits(),
                    "nearest {} len={len} i={i}",
                    fmt.name
                );
            }
            // stochastic: values and RNG stream position must both match
            let mut fast = xs.clone();
            let mut ra = Rng::new(0xB2, len as u64);
            let mut rb = ra.clone();
            round_stochastic_slice(&mut fast, fmt, &mut ra);
            for (i, (&f, &x)) in fast.iter().zip(&xs).enumerate() {
                assert_eq!(
                    f.to_bits(),
                    round_stochastic(x, fmt, rb.next_u32()).to_bits(),
                    "stochastic {} len={len} i={i}",
                    fmt.name
                );
            }
            assert_eq!(ra.next_u64(), rb.next_u64(), "rng stream {} len={len}", fmt.name);
        }
    }
}

#[test]
fn prop_dither_words_are_uniform() {
    // the counter RNG behind SR dither: mean near 2^31, every output bit
    // near half ones, over several keys
    let keys = [(0u64, 0u64, 0u64, 0u64), (42, 0x907, 3, 7), (9, 1, 1000, 2)];
    for (seed, stream, step, tid) in keys {
        let key = DitherKey::new(seed, stream, step, tid);
        let n = 1u64 << 16;
        let mut acc = 0f64;
        let mut bit_ones = [0u32; 32];
        for i in 0..n {
            let w = key.word(i);
            acc += w as f64;
            for (b, ones) in bit_ones.iter_mut().enumerate() {
                *ones += (w >> b) & 1;
            }
        }
        let mean = acc / n as f64;
        let expect = (u32::MAX as f64) / 2.0;
        assert!(
            (mean - expect).abs() < expect * 0.01,
            "key {key:?}: mean {mean:.0} vs {expect:.0}"
        );
        for (b, &ones) in bit_ones.iter().enumerate() {
            let frac = ones as f64 / n as f64;
            assert!(
                (frac - 0.5).abs() < 0.02,
                "key {key:?} bit {b}: ones fraction {frac}"
            );
        }
    }
}

#[test]
fn prop_dither_keys_independent_across_tensor_and_step() {
    // streams of keys differing in one coordinate (tensor_id or step) must
    // look unrelated: word collisions at chance level and cross-stream bit
    // agreement near 50%
    let n = 4096u64;
    let base = DitherKey::new(5, 0x907, 10, 3);
    let neighbours = [
        DitherKey::new(5, 0x907, 10, 4), // tensor_id + 1
        DitherKey::new(5, 0x907, 11, 3), // step + 1
        DitherKey::new(5, 0x907, 11, 4), // both
        DitherKey::new(6, 0x907, 10, 3), // seed + 1
    ];
    for other in neighbours {
        let mut equal_words = 0u64;
        let mut agreeing_bits = 0u64;
        for i in 0..n {
            let a = base.word(i);
            let b = other.word(i);
            if a == b {
                equal_words += 1;
            }
            agreeing_bits += (!(a ^ b)).count_ones() as u64;
        }
        // P(word collision) = 2^-32; over 4096 draws even 2 would be wild
        assert!(equal_words <= 1, "{other:?}: {equal_words} word collisions");
        let agree_frac = agreeing_bits as f64 / (n * 32) as f64;
        assert!(
            (agree_frac - 0.5).abs() < 0.02,
            "{other:?}: cross-stream bit agreement {agree_frac}"
        );
    }
}

#[test]
fn prop_keyed_rounding_chunking_invariant_ragged_lengths() {
    // chunked/parallel rounding of a slice must equal whole-slice rounding
    // bit-for-bit for every format, ragged length and chunk size
    let mut rng = Rng::new(0xB5, 0);
    for fmt in ALL {
        for len in [1usize, 2, 7, 63, 64, 65, 255, 257, 777] {
            let key = DitherKey::new(0xD17, 0x51, len as u64, 1);
            let xs: Vec<f32> = (0..len).map(|_| random_f32(&mut rng)).collect();
            let mut whole = xs.clone();
            round_stochastic_slice_keyed(&mut whole, fmt, key, 0);
            // scalar oracle
            for (i, (&w, &x)) in whole.iter().zip(&xs).enumerate() {
                assert_eq!(
                    w.to_bits(),
                    round_stochastic(x, fmt, key.word(i as u64)).to_bits(),
                    "{} len={len} i={i} oracle",
                    fmt.name
                );
            }
            for chunk in [1usize, 2, 5, 16, 97, 256] {
                let mut pieces = xs.clone();
                let mut off = 0;
                while off < len {
                    let end = (off + chunk).min(len);
                    round_stochastic_slice_keyed(&mut pieces[off..end], fmt, key, off as u64);
                    off = end;
                }
                for (i, (a, b)) in pieces.iter().zip(&whole).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{} len={len} chunk={chunk} i={i}",
                        fmt.name
                    );
                }
            }
        }
    }
}

#[test]
fn prop_fill_u32_is_the_next_u32_stream() {
    for (seed, len) in [(1u64, 1usize), (2, 4), (3, 63), (4, 64), (5, 1000)] {
        let mut a = Rng::new(seed, 9);
        let mut b = Rng::new(seed, 9);
        let mut buf = vec![0u32; len];
        a.fill_u32(&mut buf);
        for (i, &v) in buf.iter().enumerate() {
            assert_eq!(v, b.next_u32(), "seed={seed} i={i}");
        }
        assert_eq!(a.next_u64(), b.next_u64(), "stream position seed={seed}");
    }
}

#[test]
fn prop_tiled_matmul_matches_scalar_reference() {
    let mut rng = Rng::new(0xB3, 0);
    for trial in 0..40 {
        let m = 1 + rng.below(9);
        let k = 1 + rng.below(300);
        let n = 1 + rng.below(300);
        let mut a = Tensor::randn(m, k, 1.0, &mut rng);
        let b = Tensor::randn(k, n, 1.0, &mut rng);
        // zeros exercise the skip path; identical in both kernels
        for i in 0..a.data.len() {
            if i % 5 == 0 {
                a.data[i] = 0.0;
            }
        }
        let fast = a.matmul(&b);
        let reference = a.matmul_reference(&b);
        for (i, (x, y)) in fast.data.iter().zip(&reference.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "trial {trial} ({m}x{k}x{n}) elem {i}");
        }
    }
}

#[test]
fn prop_tape_backends_bit_identical_over_formats() {
    // one fwd+bwd MLP step per format: fast (arena + tiled + fused rounding)
    // vs reference (scalar) must agree bitwise on loss and weight grads
    let mut rng = Rng::new(0xB4, 0);
    for fmt in ALL {
        for _ in 0..5 {
            let x = Tensor::randn(3, 70, 1.0, &mut rng);
            let w = Tensor::randn(70, 5, 0.3, &mut rng);
            let run = |backend: Backend| {
                let mut t = Tape::new(QPolicy::with_backend(fmt, backend));
                let xv = t.input_from(&x);
                let wv = t.param_from(&w);
                let h = t.matmul(xv, wv);
                let r = t.relu(h);
                let m = t.mean_all(r);
                t.backward(m);
                (t.value(m).item(), t.grad(wv).unwrap().clone())
            };
            let (lf, gf) = run(Backend::Fast);
            let (lr, gr) = run(Backend::Reference);
            assert_eq!(lf.to_bits(), lr.to_bits(), "{} loss", fmt.name);
            for (i, (a, b)) in gf.data.iter().zip(&gr.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} grad elem {i}", fmt.name);
            }
        }
    }
}

#[test]
fn prop_schedule_factor_in_unit_interval() {
    let mut rng = Rng::new(0xA7, 0);
    for _ in 0..2000 {
        let total = 1 + rng.below(100_000) as u64;
        let step = rng.below(total as usize + 1) as u64;
        for sched in [
            Schedule::Constant,
            Schedule::StepDecay { boundaries: vec![0.3, 0.6, 0.9], factor: 0.1 },
            Schedule::WarmupLinear { warmup_frac: 0.08 },
        ] {
            let f = sched.factor(step, total);
            assert!((0.0..=1.0 + 1e-9).contains(&f), "{sched:?} {step}/{total} -> {f}");
        }
    }
}

#[test]
fn prop_data_generators_deterministic_across_instances() {
    use bf16_train::data::{Ctr, Dataset, Images, Regression, SeqFrames, TokenCls, TokenLm};
    for seed in [0u64, 7, 42] {
        let pairs: Vec<(Box<dyn Dataset>, Box<dyn Dataset>)> = vec![
            (
                Box::new(Regression::new(10, 4, seed, 0)),
                Box::new(Regression::new(10, 4, seed, 0)),
            ),
            (
                Box::new(Images::new(16, 10, 4, seed, 0)),
                Box::new(Images::new(16, 10, 4, seed, 0)),
            ),
            (
                Box::new(Ctr::new(8, 4, 50, 16, seed, 0)),
                Box::new(Ctr::new(8, 4, 50, 16, seed, 0)),
            ),
            (
                Box::new(TokenCls::new(64, 8, 3, 8, seed, 0)),
                Box::new(TokenCls::new(64, 8, 3, 8, seed, 0)),
            ),
            (
                Box::new(TokenLm::new(64, 8, 4, seed, 0)),
                Box::new(TokenLm::new(64, 8, 4, seed, 0)),
            ),
            (
                Box::new(SeqFrames::new(8, 6, 4, 4, seed, 0)),
                Box::new(SeqFrames::new(8, 6, 4, 4, seed, 0)),
            ),
        ];
        for (mut a, mut b) in pairs {
            for _ in 0..3 {
                assert_eq!(a.next_batch(), b.next_batch(), "{}", a.name());
            }
        }
    }
}

#[test]
fn prop_policy_parse_display_round_trips_exhaustively() {
    // every mode × format combination must survive Display → parse, and the
    // artifact-name rule (bare bf16 suffix elision) must invert exactly
    for mode in Mode::ALL {
        for fmt in ALL {
            let p = Policy::new(mode, fmt);
            let name = p.to_string();
            assert_eq!(name.parse::<Policy>().unwrap(), p, "policy name {name:?}");
            if fmt == BF16 {
                assert_eq!(name, mode.name(), "bf16 suffix must be elided");
            } else {
                assert_eq!(name, format!("{}-{}", mode.name(), fmt.name));
            }
            for app in ["lsq", "dlrm-small", "gpt-tiny"] {
                let artifact = p.artifact_name(app);
                let (got_app, got_p) = Policy::parse_artifact_name(&artifact).unwrap();
                assert_eq!((got_app.as_str(), got_p), (app, p), "artifact {artifact:?}");
            }
        }
    }
}

#[test]
fn prop_policy_rejects_malformed_strings() {
    for bad in [
        "",
        "bogus",
        "SR16",
        "fp32 ",
        " fp32",
        "sr16-",
        "-bf16",
        "sr16-nope",
        "sr16-e8m5-x",
        "sr16_e8m5",
    ] {
        assert!(bad.parse::<Policy>().is_err(), "{bad:?} should not parse");
    }
    assert!(Policy::parse_artifact_name("dlrm__bogus").is_err());
    assert!(Policy::from_parts("sr16", "nope").is_err());
    assert!(Policy::from_parts("nope", "bf16").is_err());
}

#[test]
fn prop_dataset_skip_equals_consuming_batches() {
    use bf16_train::data::{Ctr, Dataset, Images, Regression, SeqFrames, TokenCls, TokenLm};
    // skip(n) must land the generator exactly where n next_batch calls do,
    // for every generator and several skip lengths
    for n in [1u64, 2, 5] {
        let pairs: Vec<(Box<dyn Dataset>, Box<dyn Dataset>)> = vec![
            (
                Box::new(Regression::new(10, 4, 1, 0x7E)),
                Box::new(Regression::new(10, 4, 1, 0x7E)),
            ),
            (
                Box::new(Images::new(16, 10, 4, 2, 0x7E)),
                Box::new(Images::new(16, 10, 4, 2, 0x7E)),
            ),
            (
                Box::new(Ctr::new(8, 4, 50, 16, 3, 0x7E)),
                Box::new(Ctr::new(8, 4, 50, 16, 3, 0x7E)),
            ),
            (
                Box::new(TokenCls::new(64, 8, 3, 8, 4, 0x7E)),
                Box::new(TokenCls::new(64, 8, 3, 8, 4, 0x7E)),
            ),
            (
                Box::new(TokenLm::new(64, 8, 4, 5, 0x7E)),
                Box::new(TokenLm::new(64, 8, 4, 5, 0x7E)),
            ),
            (
                Box::new(SeqFrames::new(8, 6, 4, 4, 6, 0x7E)),
                Box::new(SeqFrames::new(8, 6, 4, 4, 6, 0x7E)),
            ),
        ];
        for (mut a, mut b) in pairs {
            a.skip(n);
            for _ in 0..n {
                b.next_batch();
            }
            assert_eq!(a.next_batch(), b.next_batch(), "{} skip({n})", a.name());
        }
    }
}

#[test]
fn prop_auc_invariant_to_monotone_transform() {
    let mut rng = Rng::new(0xA8, 0);
    for _ in 0..50 {
        let scored: Vec<(f32, bool)> = (0..200)
            .map(|_| (rng.normal(), rng.uniform() < 0.4))
            .collect();
        let transformed: Vec<(f32, bool)> =
            scored.iter().map(|&(s, y)| (s * 3.0 + 1.0, y)).collect();
        let a = bf16_train::metrics::auc(&scored);
        let b = bf16_train::metrics::auc(&transformed);
        assert!((a - b).abs() < 1e-6);
    }
}
