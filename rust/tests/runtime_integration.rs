//! End-to-end integration over the PJRT runtime: load real artifacts, train,
//! checkpoint, and verify the paper's qualitative behaviour on the lsq app.
//!
//! These tests need `make artifacts` to have produced at least the lsq
//! artifact set; they skip with a notice otherwise.  Runs go through the
//! library `Runner` facade with `RunSpec`-built configs.

use bf16_train::{Policy, RunSpec, Runner};

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");

fn runtime() -> Option<Runner> {
    match Runner::open(ARTIFACTS) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP: runtime unavailable ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn lsq_spec(mode: &str, steps: u64, seed: u64) -> RunSpec {
    RunSpec::new("lsq")
        .policy(Policy::parse(mode).unwrap())
        .steps(steps)
        .seed(seed)
        .eval_every(steps)
        .artifacts_dir(ARTIFACTS)
}

#[test]
fn fp32_training_descends_and_is_deterministic() {
    let Some(runner) = runtime() else { return };
    let run = |seed| {
        let mut tr = runner.trainer(&lsq_spec("fp32", 400, seed)).unwrap();
        tr.run().unwrap()
    };
    let a = run(1);
    let b = run(1);
    let c = run(2);
    assert!(a.final_train_loss < a.history.points[0].loss as f64);
    assert_eq!(a.final_train_loss, b.final_train_loss, "same seed must repeat exactly");
    assert_ne!(a.final_train_loss, c.final_train_loss, "different seed must differ");
}

#[test]
fn standard16_halts_above_fp32_and_fixes_recover() {
    let Some(runner) = runtime() else { return };
    let final_loss = |mode: &str| {
        let mut tr = runner.trainer(&lsq_spec(mode, 4000, 0)).unwrap();
        let s = tr.run().unwrap();
        (s.final_train_loss, s.mean_cancel_frac)
    };
    let (fp32, _) = final_loss("fp32");
    let (std16, cancel) = final_loss("standard16");
    let (kahan, _) = final_loss("kahan16");
    let (mixed, _) = final_loss("mixed16");
    // Theorem 1's halting: standard16 plateaus well above fp32
    assert!(std16 > 3.0 * fp32.max(1e-4), "std16={std16} fp32={fp32}");
    assert!(cancel > 0.3, "cancellation should dominate late training: {cancel}");
    // the two fixes + the ablation all land near fp32
    assert!(kahan < std16 / 2.0, "kahan={kahan} std16={std16}");
    assert!(mixed < std16 / 2.0, "mixed={mixed} std16={std16}");
}

#[test]
fn checkpoint_round_trip_resumes_identically() {
    let Some(runner) = runtime() else { return };
    let dir = std::env::temp_dir().join("bf16_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lsq.ckpt");

    // train 200 steps, checkpoint, train 200 more
    let mut tr = runner.trainer(&lsq_spec("sr16", 400, 3)).unwrap();
    tr.run_steps(200).unwrap();
    tr.save_checkpoint(&path).unwrap();
    tr.run_steps(200).unwrap();
    let (loss_a, _) = tr.evaluate(4).unwrap();

    // restore and redo the same 200 steps
    let mut tr2 = runner.trainer(&lsq_spec("sr16", 400, 3)).unwrap();
    tr2.load_checkpoint(&path).unwrap();
    tr2.run_steps(200).unwrap();
    let (loss_b, _) = tr2.evaluate(4).unwrap();
    assert_eq!(loss_a, loss_b, "resumed run must replay exactly");
}

#[test]
fn checkpoint_rejects_mismatched_artifact() {
    let Some(runner) = runtime() else { return };
    let dir = std::env::temp_dir().join("bf16_ckpt_mismatch_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lsq_sr16.ckpt");

    let mut tr = runner.trainer(&lsq_spec("sr16", 100, 0)).unwrap();
    tr.run_steps(10).unwrap();
    tr.save_checkpoint(&path).unwrap();

    // same app, same state shapes — but a different policy must be refused
    let mut other = runner.trainer(&lsq_spec("kahan16", 100, 0)).unwrap();
    let err = other.load_checkpoint(&path).unwrap_err().to_string();
    assert!(
        err.contains("lsq__sr16") && err.contains("lsq__kahan16"),
        "error should name both artifacts: {err}"
    );
}

#[test]
fn weights_remain_bf16_representable_in_16bit_modes() {
    let Some(runner) = runtime() else { return };
    let mut tr = runner.trainer(&lsq_spec("standard16", 50, 0)).unwrap();
    tr.run_steps(50).unwrap();
    // reach into the session: params are the first num_params state tensors
    let summary_session = tr; // Trainer owns the session privately; use checkpoint
    let dir = std::env::temp_dir().join("bf16_fmt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("w.ckpt");
    summary_session.save_checkpoint(&path).unwrap();
    let buf = std::fs::read(&path).unwrap();
    // parse the v2 layout: magic, name_len + name, steps, tensor count,
    // then the first tensor's length + f32 data
    assert_eq!(&buf[..8], b"BF16CKP2");
    let name_len = u64::from_le_bytes(buf[8..16].try_into().unwrap()) as usize;
    assert_eq!(&buf[16..16 + name_len], b"lsq__standard16");
    let mut off = 16 + name_len + 8; // skip the step counter
    let n_tensors = u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()) as usize;
    assert!(n_tensors >= 2);
    off += 8;
    let len = u64::from_le_bytes(buf[off..off + 8].try_into().unwrap()) as usize;
    off += 8;
    for k in 0..len {
        let v = f32::from_le_bytes(buf[off + 4 * k..off + 4 * k + 4].try_into().unwrap());
        let q = bf16_train::precision::round_nearest(v, bf16_train::precision::BF16);
        assert_eq!(v.to_bits(), q.to_bits(), "weight {k} not bf16-representable: {v}");
    }
}

#[test]
fn eval_preds_match_batch_size() {
    let Some(runner) = runtime() else { return };
    let Ok(_a) = runner.manifest().get("dlrm-small__fp32") else {
        eprintln!("SKIP: dlrm-small artifacts not built");
        return;
    };
    let spec = RunSpec::new("dlrm-small").steps(5).eval_every(5).artifacts_dir(ARTIFACTS);
    let mut tr = runner.trainer(&spec).unwrap();
    tr.run_steps(5).unwrap();
    let (loss, auc) = tr.evaluate(2).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=100.0).contains(&auc));
}
