//! End-to-end integration over the PJRT runtime: load real artifacts, train,
//! checkpoint, and verify the paper's qualitative behaviour on the lsq app.
//!
//! These tests need `make artifacts` to have produced at least the lsq
//! artifact set; they skip with a notice otherwise.  They share one PJRT
//! client (creating several in one process is wasteful but safe).

use bf16_train::config::RunConfig;
use bf16_train::coordinator::Trainer;
use bf16_train::runtime::{Engine, Manifest};

fn runtime() -> Option<(Engine, Manifest)> {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(_) => {
            eprintln!("SKIP: no artifacts (run `make artifacts`)");
            return None;
        }
    };
    let engine = Engine::cpu().expect("PJRT CPU client");
    Some((engine, manifest))
}

fn lsq_cfg(mode: &str, steps: u64, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::defaults_for("lsq");
    cfg.mode = mode.to_string();
    cfg.steps = steps;
    cfg.seed = seed;
    cfg.eval_every = steps;
    cfg.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    cfg
}

#[test]
fn fp32_training_descends_and_is_deterministic() {
    let Some((engine, manifest)) = runtime() else { return };
    let run = |seed| {
        let mut tr = Trainer::new(&engine, &manifest, lsq_cfg("fp32", 400, seed)).unwrap();
        tr.run().unwrap()
    };
    let a = run(1);
    let b = run(1);
    let c = run(2);
    assert!(a.final_train_loss < a.history.points[0].loss as f64);
    assert_eq!(a.final_train_loss, b.final_train_loss, "same seed must repeat exactly");
    assert_ne!(a.final_train_loss, c.final_train_loss, "different seed must differ");
}

#[test]
fn standard16_halts_above_fp32_and_fixes_recover() {
    let Some((engine, manifest)) = runtime() else { return };
    let final_loss = |mode: &str| {
        let mut tr = Trainer::new(&engine, &manifest, lsq_cfg(mode, 4000, 0)).unwrap();
        let s = tr.run().unwrap();
        (s.final_train_loss, s.mean_cancel_frac)
    };
    let (fp32, _) = final_loss("fp32");
    let (std16, cancel) = final_loss("standard16");
    let (kahan, _) = final_loss("kahan16");
    let (mixed, _) = final_loss("mixed16");
    // Theorem 1's halting: standard16 plateaus well above fp32
    assert!(std16 > 3.0 * fp32.max(1e-4), "std16={std16} fp32={fp32}");
    assert!(cancel > 0.3, "cancellation should dominate late training: {cancel}");
    // the two fixes + the ablation all land near fp32
    assert!(kahan < std16 / 2.0, "kahan={kahan} std16={std16}");
    assert!(mixed < std16 / 2.0, "mixed={mixed} std16={std16}");
}

#[test]
fn checkpoint_round_trip_resumes_identically() {
    let Some((engine, manifest)) = runtime() else { return };
    let dir = std::env::temp_dir().join("bf16_ckpt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("lsq.ckpt");

    // train 200 steps, checkpoint, train 200 more
    let mut tr = Trainer::new(&engine, &manifest, lsq_cfg("sr16", 400, 3)).unwrap();
    tr.run_steps(200).unwrap();
    tr.save_checkpoint(&path).unwrap();
    tr.run_steps(200).unwrap();
    let (loss_a, _) = tr.evaluate(4).unwrap();

    // restore and redo the same 200 steps
    let mut tr2 = Trainer::new(&engine, &manifest, lsq_cfg("sr16", 400, 3)).unwrap();
    tr2.load_checkpoint(&path).unwrap();
    tr2.run_steps(200).unwrap();
    let (loss_b, _) = tr2.evaluate(4).unwrap();
    assert_eq!(loss_a, loss_b, "resumed run must replay exactly");
}

#[test]
fn weights_remain_bf16_representable_in_16bit_modes() {
    let Some((engine, manifest)) = runtime() else { return };
    let mut tr = Trainer::new(&engine, &manifest, lsq_cfg("standard16", 50, 0)).unwrap();
    tr.run_steps(50).unwrap();
    // reach into the session: params are the first num_params state tensors
    let summary_session = tr; // Trainer owns the session privately; use checkpoint
    let dir = std::env::temp_dir().join("bf16_fmt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("w.ckpt");
    summary_session.save_checkpoint(&path).unwrap();
    let buf = std::fs::read(&path).unwrap();
    // parse: skip magic+step+count, then first tensor
    let n_tensors = u64::from_le_bytes(buf[16..24].try_into().unwrap()) as usize;
    assert!(n_tensors >= 2);
    let len = u64::from_le_bytes(buf[24..32].try_into().unwrap()) as usize;
    for k in 0..len {
        let v = f32::from_le_bytes(buf[32 + 4 * k..36 + 4 * k].try_into().unwrap());
        let q = bf16_train::precision::round_nearest(v, bf16_train::precision::BF16);
        assert_eq!(v.to_bits(), q.to_bits(), "weight {k} not bf16-representable: {v}");
    }
}

#[test]
fn eval_preds_match_batch_size() {
    let Some((engine, manifest)) = runtime() else { return };
    let Ok(_a) = manifest.get("dlrm-small__fp32") else {
        eprintln!("SKIP: dlrm-small artifacts not built");
        return;
    };
    let mut cfg = RunConfig::defaults_for("dlrm-small");
    cfg.steps = 5;
    cfg.eval_every = 5;
    cfg.artifacts_dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts").to_string();
    let mut tr = Trainer::new(&engine, &manifest, cfg).unwrap();
    tr.run_steps(5).unwrap();
    let (loss, auc) = tr.evaluate(2).unwrap();
    assert!(loss.is_finite());
    assert!((0.0..=100.0).contains(&auc));
}
