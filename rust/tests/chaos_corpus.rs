//! Chaos-schedule corpus: checked-in fault schedules replayed against the
//! sharded trainer on every `cargo test` run.
//!
//! Each spec in [`CORPUS`] drives a 4-shard run of the spiral-MLP task and
//! must reproduce the clean 1-shard run bit for bit — per-step loss bits,
//! the final parameter digest and the eval loss.  The corpus pins the
//! schedules that have historically been the nastiest shapes (every shard
//! crashing in the same step, every update broadcast dropped at once, the
//! CI acceptance combo), so a recovery-path regression fails here with the
//! exact offending spec string in the assert message.

use std::sync::Arc;
use std::time::Duration;

use bf16_train::precision::Mode;
use bf16_train::qsim::mlp::MlpConfig;
use bf16_train::qsim::{ChaosConfig, ChaosPlan, ShardOptions, ShardedTrainer};

const STEPS: usize = 10;
const LR: f32 = 0.1;
const SEED: u64 = 21;

/// Pinned schedules: every recovery path, alone and combined.  These must
/// inject at least one event within [`STEPS`] steps on 4 shards.
const CORPUS: &[&str] = &[
    // the CI acceptance schedule: crash + straggler + corrupt message,
    // plus a dropped gradient and a dropped update broadcast
    "crash@2.1,stall@4.3:80,corrupt@6.0,drop@8.2,drop-update@5.1",
    // every shard crashes while computing the same step
    "crash@1.0,crash@1.1,crash@1.2,crash@1.3",
    // every update broadcast for one step is dropped: all four replicas
    // drift silently and must be healed by digest-triggered resync
    "drop-update@3.0,drop-update@3.1,drop-update@3.2,drop-update@3.3",
    // repeated faults on one shard across consecutive steps
    "crash@1.2,drop@2.2,corrupt@3.2,drop-update@4.2,stall@5.2:60",
    // corruption storm: every shard's gradient frame flipped in one step
    "corrupt@2.0,corrupt@2.1,corrupt@2.2,corrupt@2.3",
    // crash immediately at step 0, before any update was ever applied
    "crash@0.0,drop@0.3",
];

/// Probabilistic schedules (deterministic per seed via the keyed counter
/// RNG, so these are replays, not flakes).  Event counts are not asserted:
/// a quiet draw is a valid schedule.
const RATE_CORPUS: &[&str] = &[
    "heavy",
    "heavy,seed=7",
    "seed=11,crash=0.08,stall=0.04,drop=0.08,corrupt=0.08,drop-update=0.08",
    "seed=23,crash=0.08,stall=0.04,drop=0.08,corrupt=0.08,drop-update=0.08",
    "seed=47,crash=0.15,drop-update=0.15",
];

fn opts(shards: usize, chaos: Option<Arc<ChaosPlan>>) -> ShardOptions {
    ShardOptions {
        shards,
        microbatches: 4,
        chaos,
        // short windows keep crash recovery fast in tests; spurious
        // timeouts only exercise the (idempotent) retransmit path harder
        timeout: Duration::from_millis(120),
        ..Default::default()
    }
}

/// Per-step loss bits, final parameter digest, eval-loss bits.
fn run(shards: usize, chaos: Option<Arc<ChaosPlan>>) -> (Vec<u32>, u64, u32) {
    let task = MlpConfig { seed: SEED, ..Default::default() };
    let mut tr = ShardedTrainer::new(task, Mode::Sr16, opts(shards, chaos))
        .expect("shard geometry is valid");
    let mut losses = Vec::with_capacity(STEPS);
    for _ in 0..STEPS {
        losses.push(tr.step(LR).loss.to_bits());
    }
    let digest = tr.param_digest();
    let eval = tr.eval(4).loss.to_bits();
    (losses, digest, eval)
}

fn plan(spec: &str) -> Arc<ChaosPlan> {
    Arc::new(ChaosPlan::new(
        ChaosConfig::parse(spec).unwrap_or_else(|e| panic!("corpus spec {spec:?}: {e}")),
    ))
}

#[test]
fn pinned_corpus_replays_bit_identically() {
    let clean = run(1, None);
    for spec in CORPUS {
        let chaos = plan(spec);
        let task = MlpConfig { seed: SEED, ..Default::default() };
        let mut tr = ShardedTrainer::new(task, Mode::Sr16, opts(4, Some(chaos)))
            .expect("shard geometry is valid");
        let mut losses = Vec::with_capacity(STEPS);
        for _ in 0..STEPS {
            losses.push(tr.step(LR).loss.to_bits());
        }
        assert_eq!(losses, clean.0, "loss trajectory diverged under chaos {spec:?}");
        assert_eq!(tr.param_digest(), clean.1, "param digest diverged under chaos {spec:?}");
        assert_eq!(tr.eval(4).loss.to_bits(), clean.2, "eval diverged under chaos {spec:?}");
        let st = tr.stats();
        assert!(st.total_events() >= 1, "pinned schedule {spec:?} never fired: {st:?}");
    }
}

#[test]
fn rate_corpus_replays_bit_identically() {
    let clean = run(1, None);
    for spec in RATE_CORPUS {
        let got = run(4, Some(plan(spec)));
        assert_eq!(got, clean, "run diverged under chaos {spec:?}");
    }
}

/// Property sweep: the invariant holds across data seeds × chaos seeds,
/// not just the corpus's fixed pairing.
#[test]
fn seed_cross_chaos_property() {
    for task_seed in [3u64, 91] {
        let clean = {
            let task = MlpConfig { seed: task_seed, ..Default::default() };
            let mut tr = ShardedTrainer::new(task, Mode::Sr16, opts(1, None)).unwrap();
            for _ in 0..6 {
                tr.step(LR);
            }
            tr.param_digest()
        };
        for chaos_seed in [5u64, 17] {
            let spec = format!(
                "seed={chaos_seed},crash=0.08,stall=0.05,drop=0.08,corrupt=0.08,drop-update=0.08"
            );
            let task = MlpConfig { seed: task_seed, ..Default::default() };
            let mut tr =
                ShardedTrainer::new(task, Mode::Sr16, opts(4, Some(plan(&spec)))).unwrap();
            for _ in 0..6 {
                tr.step(LR);
            }
            assert_eq!(
                tr.param_digest(),
                clean,
                "seed {task_seed} diverged under chaos seed {chaos_seed}"
            );
        }
    }
}
