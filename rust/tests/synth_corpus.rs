//! Tier-1 guard on the checked-in synthesized ruleset
//! (`tests/data/synth_rules.txt`): every pinned rule's bit-identity
//! admission proof re-runs on every `cargo test` — at the pinned
//! admission seed *and* at a fresh one the synthesizer never saw — and a
//! bounded depth-2 synthesis run must rediscover both hand-written PR-6
//! fusion rules from the raw op vocabulary.

use bf16_train::qsim::verify::rewrite::{self, Pattern};
use bf16_train::qsim::verify::OpIr;
use bf16_train::qsim::verify::synth::{self, SynthConfig};

#[test]
fn corpus_parses_with_canonical_and_new_rules() {
    let doc = rewrite::corpus_doc().expect("synth_rules.txt must parse");
    for rule in &doc.rules {
        rule.check().unwrap_or_else(|e| panic!("malformed corpus rule: {e}"));
    }
    let names: Vec<&str> = doc.rules.iter().map(|r| r.name.as_str()).collect();
    assert!(names.contains(&"fuse-affine"), "corpus lost fuse-affine: {names:?}");
    assert!(
        names.contains(&"fuse-affine-relu"),
        "corpus lost fuse-affine-relu: {names:?}"
    );
    let new = names
        .iter()
        .filter(|n| !matches!(**n, "fuse-affine" | "fuse-affine-relu"))
        .count();
    assert!(
        new >= 2,
        "corpus must carry at least two synthesized rules beyond the \
         hand-written fusions, found {new}: {names:?}"
    );
}

#[test]
fn every_corpus_rule_reproves_at_the_pinned_admission_seed() {
    let doc = rewrite::corpus_doc().expect("synth_rules.txt must parse");
    let seed = synth::admission_seed(doc.seed);
    for rule in &doc.rules {
        let cells = rewrite::validate_rule(rule, seed, 2).unwrap_or_else(|e| {
            panic!("pinned admission proof broke for {}: {e}", rule.name)
        });
        assert!(cells > 0, "rule {} proved zero cells", rule.name);
    }
}

#[test]
fn every_corpus_rule_reproves_at_a_fresh_seed() {
    // Data the synthesizer never clustered or admitted on: a pinned rule
    // must be an identity of the ops, not of its witness valuations.
    let doc = rewrite::corpus_doc().expect("synth_rules.txt must parse");
    for rule in &doc.rules {
        rewrite::validate_rule(rule, 0xC0FFEE, 2).unwrap_or_else(|e| {
            panic!("fresh-seed proof broke for {}: {e}", rule.name)
        });
    }
}

#[test]
fn bounded_depth2_synthesis_rediscovers_the_fusion_rules() {
    // Reduced valuation counts keep this inside a test budget; the relu
    // chain (size 3) is reachable at depth 2 via chain-bias seeding.
    let cfg = SynthConfig { cvec_valuations: 2, admit_valuations: 1, ..SynthConfig::at(2, 7) };
    let report = synth::synthesize(&cfg);
    let affine = (
        Pattern::parse("(add_row (matmul ?a ?b) ?c)").unwrap(),
        Pattern::parse("(affine ?a ?b ?c)").unwrap(),
    );
    let affine_relu = (
        Pattern::parse("(relu (add_row (matmul ?a ?b) ?c))").unwrap(),
        Pattern::parse("(affine_relu ?a ?b ?c)").unwrap(),
    );
    for (tag, (lhs, rhs)) in [("fuse-affine", affine), ("fuse-affine-relu", affine_relu)] {
        assert!(
            report.admitted.iter().any(|r| r.lhs == lhs && r.rhs == rhs),
            "depth-2 synthesis failed to rediscover {tag}; admitted: {:?}",
            report.admitted.iter().map(|r| r.render()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn ruleset_collapses_the_classic_chain_and_validates() {
    // The PR-6 motivating program — relu(add_row(matmul x w, b)) — must
    // fully fuse under the pinned ruleset and pass the admission sweep.
    let doc = rewrite::corpus_doc().expect("synth_rules.txt must parse");
    let far = doc
        .rules
        .iter()
        .find(|r| r.name == "fuse-affine-relu")
        .expect("fuse-affine-relu pinned");
    let prog = rewrite::pattern_program(&far.lhs, &far.shapes).unwrap();
    let (rw, applied) = rewrite::rewrite_fixpoint(&prog, rewrite::admitted_ruleset());
    assert!(!applied.is_empty(), "ruleset did not fire on the classic chain");
    assert_eq!(
        rw.nodes.len(),
        far.shapes.len() + 1,
        "chain must collapse to leaves + one fused op, got:\n{rw}"
    );
    assert!(
        matches!(rw.nodes.last().unwrap().op, OpIr::Affine { relu: true, .. }),
        "fused root must be affine_relu, got:\n{rw}"
    );
    let leaves = rewrite::valuation_leaves(&far.shapes, 0xBEEF, 0);
    let cells = rewrite::validate(&prog, &rw, &leaves)
        .unwrap_or_else(|e| panic!("fused chain diverged: {e}"));
    assert!(cells > 0);
}
