//! Golden serve-vs-train parity: replies from `repro serve`'s async
//! batched executor must be bit-identical to per-request tape evals of
//! the same checkpoint — across Fast/Simd backends and across batch
//! windows.  Dynamic micro-batching (and the padding it implies) is a
//! latency knob only; it must never change a scored bit.

use bf16_train::qsim::dlrm::{CtrBatch, CtrGen, DlrmConfig};
use bf16_train::qsim::gpt::{GptConfig, LmBatch, MarkovGen};
use bf16_train::qsim::infer::{run_load, spawn_server, tape_oracle_replies};
use bf16_train::qsim::train::Trainer;
use bf16_train::qsim::{Backend, Mode, ServeApp, ServeConfig};

fn ctr_request(batch: &CtrBatch, r: usize, dd: usize) -> String {
    let dense: Vec<String> =
        batch.dense.data[r * dd..(r + 1) * dd].iter().map(|v| v.to_string()).collect();
    let cat: Vec<String> = batch.cat.iter().map(|col| col[r].to_string()).collect();
    format!("dlrm {} | {}", dense.join(" "), cat.join(" "))
}

fn lm_request(batch: &LmBatch, s: usize, len: usize, t_len: usize) -> String {
    let toks: Vec<String> =
        batch.tokens[s * t_len..s * t_len + len].iter().map(|t| t.to_string()).collect();
    format!("gpt {}", toks.join(" "))
}

#[test]
fn dlrm_serve_is_bit_identical_to_tape_eval_across_backends_and_windows() {
    let base = DlrmConfig { seed: 21, ..Default::default() };
    let ckpt = {
        let mut tr = Trainer::new(base.clone(), Mode::Sr16);
        for _ in 0..5 {
            tr.step(0.05);
        }
        tr.checkpoint_bytes()
    };
    let batch = CtrGen::new(&base).next_batch();
    let corpus: Vec<String> = (0..10).map(|r| ctr_request(&batch, r, base.dense_dim)).collect();

    let mut digests = Vec::new();
    let mut eval_losses = Vec::new();
    for backend in [Backend::Fast, Backend::Simd] {
        let cfg = DlrmConfig { backend, ..base.clone() };
        let mut tr = Trainer::new(cfg.clone(), Mode::Sr16);
        tr.load_checkpoint_bytes(&ckpt).unwrap();
        // Trainer::eval routes through the compiled inference plan; its
        // metrics must stay bit-identical across backends.
        let m = tr.eval(4);
        eval_losses.push((m.loss.to_bits(), m.metric.to_bits()));
        let policy = tr.policy();
        let oracle = tape_oracle_replies(&ServeApp::Dlrm(Box::new(tr.model)), policy, &corpus);
        for window in [0u64, 1000] {
            let scfg = ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                batch_window_us: window,
                max_batch: 4,
                backend,
            };
            let mut fresh = Trainer::new(cfg.clone(), Mode::Sr16);
            fresh.load_checkpoint_bytes(&ckpt).unwrap();
            let app = ServeApp::Dlrm(Box::new(fresh.model));
            let handle = spawn_server(app, policy, &scfg).unwrap();
            let report = run_load(&handle.addr().to_string(), &corpus, 3).unwrap();
            handle.shutdown().unwrap();
            assert_eq!(report.replies, oracle, "{backend:?} w{window} diverged from the oracle");
            digests.push(report.digest());
        }
    }
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "reply digests must match across Fast/Simd x batch windows: {digests:016x?}"
    );
    assert!(
        eval_losses.windows(2).all(|w| w[0] == w[1]),
        "plan-routed eval metrics must match across backends: {eval_losses:?}"
    );
}

#[test]
fn gpt_serve_is_bit_identical_to_tape_eval_across_backends_and_windows() {
    let base = GptConfig { seed: 8, ..Default::default() };
    let ckpt = {
        let mut tr = Trainer::new(base.clone(), Mode::Sr16);
        for _ in 0..3 {
            tr.step(0.1);
        }
        tr.checkpoint_bytes()
    };
    let batch = MarkovGen::new(&base).next_batch();
    let t_len = base.seq_len;
    // variable-length prompts so batching has to pad
    let corpus: Vec<String> =
        (0..6).map(|s| lm_request(&batch, s % 4, 1 + (s * 5) % t_len, t_len)).collect();

    let mut digests = Vec::new();
    for backend in [Backend::Fast, Backend::Simd] {
        let cfg = GptConfig { backend, ..base.clone() };
        let mut tr = Trainer::new(cfg.clone(), Mode::Sr16);
        tr.load_checkpoint_bytes(&ckpt).unwrap();
        let policy = tr.policy();
        let oracle = tape_oracle_replies(&ServeApp::Gpt(Box::new(tr.model)), policy, &corpus);
        for window in [0u64, 800] {
            let scfg = ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                batch_window_us: window,
                max_batch: 3,
                backend,
            };
            let mut fresh = Trainer::new(cfg.clone(), Mode::Sr16);
            fresh.load_checkpoint_bytes(&ckpt).unwrap();
            let app = ServeApp::Gpt(Box::new(fresh.model));
            let handle = spawn_server(app, policy, &scfg).unwrap();
            let report = run_load(&handle.addr().to_string(), &corpus, 2).unwrap();
            handle.shutdown().unwrap();
            assert_eq!(report.replies, oracle, "{backend:?} w{window} diverged from the oracle");
            digests.push(report.digest());
        }
    }
    assert!(
        digests.windows(2).all(|w| w[0] == w[1]),
        "reply digests must match across Fast/Simd x batch windows: {digests:016x?}"
    );
}
