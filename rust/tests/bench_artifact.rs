//! Guards the committed `BENCH_qsim.json` artifact: the bench trajectory
//! is only useful if the checked-in numbers are real measurements, not
//! placeholder zeros, and the memory-footprint keys must stay equal to
//! what the trainers actually allocate.
//!
//! Hot-path rows (`matmul 128x256x64 *` and every `* step *` row) must
//! carry `samples >= 1` and a positive median; `speedup_matmul_128x256x64`
//! (reference / simd) must exceed 1.0; and the
//! `bytes_weights_{fp32,bf16,kahan16}` keys are re-derived from live
//! `Trainer::measured_weight_bytes()` walks so a storage regression (e.g.
//! weights silently widening back to fp32) fails here even if nobody
//! re-runs the bench.

use bf16_train::qsim::dlrm::DlrmConfig;
use bf16_train::qsim::train::Trainer;
use bf16_train::qsim::Mode;
use bf16_train::util::json::Json;

fn artifact() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_qsim.json");
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e:?}"))
}

fn derived(doc: &Json, key: &str) -> f64 {
    doc.get("derived")
        .and_then(|d| d.get(key))
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("derived.{key} missing from BENCH_qsim.json"))
}

#[test]
fn hot_path_rows_are_measured_not_placeholders() {
    let doc = artifact();
    let rows = doc
        .get("benches")
        .and_then(Json::as_arr)
        .expect("benches array missing from BENCH_qsim.json");
    assert!(!rows.is_empty(), "artifact has no bench rows");
    let mut guarded = 0usize;
    for row in rows {
        let name = row.get_str("name").expect("bench row without a name");
        if !(name.contains("matmul 128x256x64") || name.contains(" step ")) {
            continue;
        }
        let samples = row.get_usize("samples").unwrap_or(0);
        let median = row.get("median_ns").and_then(Json::as_f64).unwrap_or(0.0);
        assert!(samples >= 1, "row {name:?} has samples == 0 (placeholder artifact)");
        assert!(median > 0.0, "row {name:?} has median_ns == 0 (placeholder artifact)");
        guarded += 1;
    }
    assert!(
        guarded >= 10,
        "only {guarded} matmul/step rows found; artifact looks truncated"
    );
}

#[test]
fn simd_matmul_beats_the_scalar_reference() {
    let doc = artifact();
    let speedup = derived(&doc, "speedup_matmul_128x256x64");
    assert!(
        speedup > 1.0,
        "simd matmul must beat the scalar reference kernel, got {speedup}x"
    );
}

#[test]
fn shard_scaling_rows_and_keys_are_present() {
    let doc = artifact();
    let rows = doc
        .get("benches")
        .and_then(Json::as_arr)
        .expect("benches array missing from BENCH_qsim.json");
    for shards in [1usize, 2, 4] {
        let name = format!("dlrm-shard step sr16 s{shards}");
        let row = rows
            .iter()
            .find(|r| r.get_str("name") == Some(name.as_str()))
            .unwrap_or_else(|| panic!("bench row {name:?} missing from BENCH_qsim.json"));
        let median = row.get("median_ns").and_then(Json::as_f64).unwrap_or(0.0);
        assert!(median > 0.0, "row {name:?} has median_ns == 0 (placeholder artifact)");
    }
    let s2 = derived(&doc, "scaling_shards_sr16_s2");
    let s4 = derived(&doc, "scaling_shards_sr16_s4");
    assert!(
        s2 > 1.0 && s4 > s2,
        "shard fan-out must pay off monotonically (s2 {s2}x, s4 {s4}x)"
    );
}

#[test]
fn committed_weight_bytes_match_live_measurement() {
    let doc = artifact();
    for (mode, key) in [
        (Mode::Fp32, "bytes_weights_fp32"),
        (Mode::Sr16, "bytes_weights_bf16"),
        (Mode::Kahan16, "bytes_weights_kahan16"),
    ] {
        let tr = Trainer::new(DlrmConfig { seed: 3, ..Default::default() }, mode);
        let live = tr.measured_weight_bytes() as f64;
        let committed = derived(&doc, key);
        assert_eq!(
            committed,
            live,
            "derived.{key} ({committed}) != live measured bytes ({live}) for {}",
            mode.name()
        );
    }
    // the paper's thesis, as stored: native 16-bit weights are half of
    // fp32, and a 16-bit Kahan buffer brings kahan16 back to fp32's total
    let fp32 = derived(&doc, "bytes_weights_fp32");
    let bf16 = derived(&doc, "bytes_weights_bf16");
    let kahan = derived(&doc, "bytes_weights_kahan16");
    assert_eq!(bf16 * 2.0, fp32, "bf16 weight bytes must be half of fp32");
    assert_eq!(kahan, fp32, "kahan16 = bf16 weights + bf16 compensation = fp32 total");
}

// ---- BENCH_serve.json (the `repro serve-bench` artifact) ----

fn serve_artifact() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../BENCH_serve.json");
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {path}: {e}"));
    Json::parse(&text).unwrap_or_else(|e| panic!("parsing {path}: {e:?}"))
}

fn serve_derived(doc: &Json, key: &str) -> f64 {
    doc.get("derived")
        .and_then(|d| d.get(key))
        .and_then(Json::as_f64)
        .unwrap_or_else(|| panic!("derived.{key} missing from BENCH_serve.json"))
}

#[test]
fn serve_rows_are_measured_not_placeholders() {
    let doc = serve_artifact();
    let rows = doc
        .get("benches")
        .and_then(Json::as_arr)
        .expect("benches array missing from BENCH_serve.json");
    assert!(!rows.is_empty(), "artifact has no bench rows");
    let mut guarded = 0usize;
    for row in rows {
        let name = row.get_str("name").expect("bench row without a name");
        if !(name.contains("infer-plan") || name.contains("tape-eval") || name.starts_with("serve "))
        {
            continue;
        }
        let samples = row.get_usize("samples").unwrap_or(0);
        let median = row.get("median_ns").and_then(Json::as_f64).unwrap_or(0.0);
        assert!(samples >= 1, "row {name:?} has samples == 0 (placeholder artifact)");
        assert!(median > 0.0, "row {name:?} has median_ns == 0 (placeholder artifact)");
        guarded += 1;
    }
    assert!(
        guarded >= 14,
        "only {guarded} infer-plan/tape-eval/serve rows found; artifact looks truncated"
    );
}

#[test]
fn compiled_plan_beats_the_tape_eval_path() {
    let doc = serve_artifact();
    let dlrm = serve_derived(&doc, "speedup_infer_vs_tape_dlrm");
    assert!(
        dlrm >= 1.3,
        "the tape-free plan must beat per-request tape eval on dlrm by >= 1.3x, got {dlrm}x"
    );
    let gpt = serve_derived(&doc, "speedup_infer_vs_tape_gpt");
    assert!(gpt > 1.0, "the tape-free plan must beat tape eval on gpt-nano, got {gpt}x");
}

#[test]
fn serve_latency_percentiles_are_consistent() {
    let doc = serve_artifact();
    for app in ["dlrm", "gpt-nano"] {
        for backend in ["fast", "simd"] {
            for window in [0u64, 200] {
                let tag = format!("{app}_{backend}_w{window}");
                let p50 = serve_derived(&doc, &format!("p50_serve_{tag}_ns"));
                let p99 = serve_derived(&doc, &format!("p99_serve_{tag}_ns"));
                let qps = serve_derived(&doc, &format!("qps_serve_{tag}"));
                assert!(p50 > 0.0, "{tag}: p50 must be positive, got {p50}");
                assert!(p99 >= p50, "{tag}: p99 ({p99}) must be >= p50 ({p50})");
                assert!(qps > 0.0, "{tag}: qps must be positive, got {qps}");
            }
        }
    }
}
