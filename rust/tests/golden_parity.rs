//! Bit-exact parity between the rust `precision` substrate and the python
//! `formats` library, over the shared golden vectors emitted by `aot.py`.
//!
//! Skips (with a notice) when `artifacts/golden_formats.json` is absent —
//! run `make artifacts` first.

use bf16_train::precision::{round_nearest, round_stochastic, Format};
use bf16_train::util::json::Json;

fn load() -> Option<Json> {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/golden_formats.json");
    let text = std::fs::read_to_string(path).ok()?;
    Some(Json::parse(&text).expect("golden_formats.json must parse"))
}

fn u32s(j: &Json) -> Vec<u32> {
    j.as_arr()
        .expect("array")
        .iter()
        .map(|v| v.as_i64().expect("int") as u32)
        .collect()
}

#[test]
fn rust_rounding_matches_python_bit_for_bit() {
    let Some(doc) = load() else {
        eprintln!("SKIP: artifacts/golden_formats.json missing (run `make artifacts`)");
        return;
    };
    let inputs: Vec<f32> = u32s(doc.get("inputs_bits").unwrap())
        .into_iter()
        .map(f32::from_bits)
        .collect();
    let formats = doc.get("formats").unwrap().as_obj().unwrap();
    assert!(formats.len() >= 5, "expected all non-fp32 formats");
    for (name, entry) in formats {
        let fmt = Format::by_name(name).unwrap_or_else(|| panic!("unknown format {name}"));
        let rbits = u32s(entry.get("rbits").unwrap());
        let nearest: Vec<u32> = u32s(entry.get("nearest_bits").unwrap());
        let stochastic: Vec<u32> = u32s(entry.get("stochastic_bits").unwrap());
        for (i, &x) in inputs.iter().enumerate() {
            let rn = round_nearest(x, fmt);
            assert_eq!(
                rn.to_bits(),
                nearest[i],
                "{name} nearest mismatch at {i}: x={x:e} ours={rn:e} theirs={:e}",
                f32::from_bits(nearest[i])
            );
            let rs = round_stochastic(x, fmt, rbits[i]);
            assert_eq!(
                rs.to_bits(),
                stochastic[i],
                "{name} stochastic mismatch at {i}: x={x:e}",
            );
        }
    }
}
