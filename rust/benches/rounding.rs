//! L3 micro-benches: the precision substrate's hot loops (rounding,
//! Kahan accumulation, RNG).  These bound the rust-native simulator's
//! optimizer throughput (EXPERIMENTS.md §Perf).

use bf16_train::precision::{
    kahan_add, round_nearest, round_nearest_slice, round_stochastic, round_stochastic_slice,
    round_stochastic_slice_keyed, RoundMode, Rounder, BF16, E8M3, FP16,
};
use bf16_train::util::bench::{bench, black_box, throughput};
use bf16_train::util::rng::{DitherKey, Rng};

fn main() {
    let mut rng = Rng::new(7, 0);
    let xs: Vec<f32> = (0..65_536).map(|_| rng.normal()).collect();
    let bits: Vec<u32> = (0..65_536).map(|_| rng.next_u32()).collect();
    let n = xs.len();

    let r = bench("round_nearest/bf16 64k", || {
        let mut acc = 0f32;
        for &x in &xs {
            acc += round_nearest(black_box(x), BF16);
        }
        black_box(acc);
    });
    throughput(&r, n);

    for (name, fmt) in [("fp16", FP16), ("e8m3", E8M3)] {
        let r = bench(&format!("round_nearest/{name} 64k"), || {
            let mut acc = 0f32;
            for &x in &xs {
                acc += round_nearest(black_box(x), fmt);
            }
            black_box(acc);
        });
        throughput(&r, n);
    }

    let r = bench("round_stochastic/bf16 64k", || {
        let mut acc = 0f32;
        for (&x, &b) in xs.iter().zip(&bits) {
            acc += round_stochastic(black_box(x), BF16, b);
        }
        black_box(acc);
    });
    throughput(&r, n);

    let r = bench("rounder_slice/bf16-stochastic 64k", || {
        let mut r = Rounder::new(BF16, RoundMode::Stochastic, 1);
        let mut v = xs.clone();
        r.round_slice(&mut v);
        black_box(v);
    });
    throughput(&r, n);

    // batched slice kernels vs the scalar loops above
    let r = bench("round_nearest_slice/bf16 64k", || {
        let mut v = xs.clone();
        round_nearest_slice(&mut v, BF16);
        black_box(v);
    });
    throughput(&r, n);

    let r = bench("round_stochastic_slice/bf16 64k", || {
        let mut g = Rng::new(1, 0);
        let mut v = xs.clone();
        round_stochastic_slice(&mut v, BF16, &mut g);
        black_box(v);
    });
    throughput(&r, n);

    // counter-keyed SR (the dither schedule the qsim trainers consume):
    // slice kernel vs the scalar per-word draws it must match bit-for-bit
    let key = DitherKey::new(7, 0x5352, 0, 0);
    let r = bench("round_stochastic_slice_keyed/bf16 64k", || {
        let mut v = xs.clone();
        round_stochastic_slice_keyed(&mut v, BF16, key, 0);
        black_box(v);
    });
    throughput(&r, n);

    let r = bench("dither_key/word 64k", || {
        let mut acc = 0u32;
        for i in 0..n {
            acc = acc.wrapping_add(key.word(i as u64));
        }
        black_box(acc);
    });
    throughput(&r, n);

    let r = bench("rng/fill_u32 64k", || {
        let mut g = Rng::new(3, 0);
        let mut buf = vec![0u32; n];
        g.fill_u32(&mut buf);
        black_box(buf);
    });
    throughput(&r, n);

    let r = bench("kahan_add/bf16 64k", || {
        let mut s = 0f32;
        let mut c = 0f32;
        for &x in &xs {
            let (ns, nc) = kahan_add(s, c, black_box(x) * 1e-4, BF16);
            s = ns;
            c = nc;
        }
        black_box((s, c));
    });
    throughput(&r, n);

    let r = bench("rng/xoshiro u32 64k", || {
        let mut g = Rng::new(3, 0);
        let mut acc = 0u32;
        for _ in 0..n {
            acc = acc.wrapping_add(g.next_u32());
        }
        black_box(acc);
    });
    throughput(&r, n);
}
