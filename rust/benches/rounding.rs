//! L3 micro-benches: the precision substrate's hot loops (rounding,
//! Kahan accumulation, RNG), scalar kernels against their 8-lane SIMD
//! counterparts.  These bound the rust-native simulator's optimizer
//! throughput (EXPERIMENTS.md §Perf).
//!
//! Merges its rows into `BENCH_qsim.json` (override with `QSIM_BENCH_OUT`)
//! alongside the `qsim_step` rows instead of discarding the timings.
//! `QSIM_BENCH_SMOKE=1` (or `--smoke`) switches to a fixed tiny budget.

use bf16_train::precision::{
    kahan_add, round_nearest, round_nearest_slice, round_nearest_slice_simd,
    round_stochastic, round_stochastic_slice, round_stochastic_slice_keyed,
    round_stochastic_slice_keyed_simd, RoundMode, Rounder, BF16, E8M3, FP16,
};
use bf16_train::util::bench::{bench, bench_n, black_box, merge_bench_json, throughput};
use bf16_train::util::rng::{DitherKey, Rng};

fn main() {
    let smoke = std::env::var("QSIM_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke");
    let out_path =
        std::env::var("QSIM_BENCH_OUT").unwrap_or_else(|_| "BENCH_qsim.json".into());
    let mut results = Vec::new();
    let mut run = |name: &str, n: usize, f: &mut dyn FnMut()| {
        let r = if smoke { bench_n(name, 3, f) } else { bench(name, f) };
        throughput(&r, n);
        results.push(r);
    };

    let mut rng = Rng::new(7, 0);
    let xs: Vec<f32> = (0..65_536).map(|_| rng.normal()).collect();
    let bits: Vec<u32> = (0..65_536).map(|_| rng.next_u32()).collect();
    let n = xs.len();

    run("round_nearest/bf16 64k", n, &mut || {
        let mut acc = 0f32;
        for &x in &xs {
            acc += round_nearest(black_box(x), BF16);
        }
        black_box(acc);
    });

    for (name, fmt) in [("fp16", FP16), ("e8m3", E8M3)] {
        run(&format!("round_nearest/{name} 64k"), n, &mut || {
            let mut acc = 0f32;
            for &x in &xs {
                acc += round_nearest(black_box(x), fmt);
            }
            black_box(acc);
        });
    }

    run("round_stochastic/bf16 64k", n, &mut || {
        let mut acc = 0f32;
        for (&x, &b) in xs.iter().zip(&bits) {
            acc += round_stochastic(black_box(x), BF16, b);
        }
        black_box(acc);
    });

    run("rounder_slice/bf16-stochastic 64k", n, &mut || {
        let mut r = Rounder::new(BF16, RoundMode::Stochastic, 1);
        let mut v = xs.clone();
        r.round_slice(&mut v);
        black_box(v);
    });

    // batched slice kernels vs the scalar loops above
    run("round_nearest_slice/bf16 64k", n, &mut || {
        let mut v = xs.clone();
        round_nearest_slice(&mut v, BF16);
        black_box(v);
    });

    run("round_stochastic_slice/bf16 64k", n, &mut || {
        let mut g = Rng::new(1, 0);
        let mut v = xs.clone();
        round_stochastic_slice(&mut v, BF16, &mut g);
        black_box(v);
    });

    // counter-keyed SR (the dither schedule the qsim trainers consume):
    // slice kernel vs the scalar per-word draws it must match bit-for-bit
    let key = DitherKey::new(7, 0x5352, 0, 0);
    run("round_stochastic_slice_keyed/bf16 64k", n, &mut || {
        let mut v = xs.clone();
        round_stochastic_slice_keyed(&mut v, BF16, key, 0);
        black_box(v);
    });

    // 8-lane SIMD kernels (the `Backend::Simd` hot path); bit-identical to
    // the scalar slice kernels above, so the deltas are pure speedup
    run("round_nearest_slice_simd/bf16 64k", n, &mut || {
        let mut v = xs.clone();
        round_nearest_slice_simd(&mut v, BF16);
        black_box(v);
    });

    run("round_stochastic_slice_keyed_simd/bf16 64k", n, &mut || {
        let mut v = xs.clone();
        round_stochastic_slice_keyed_simd(&mut v, BF16, key, 0);
        black_box(v);
    });

    run("dither_key/word 64k", n, &mut || {
        let mut acc = 0u32;
        for i in 0..n {
            acc = acc.wrapping_add(key.word(i as u64));
        }
        black_box(acc);
    });

    run("rng/fill_u32 64k", n, &mut || {
        let mut g = Rng::new(3, 0);
        let mut buf = vec![0u32; n];
        g.fill_u32(&mut buf);
        black_box(buf);
    });

    run("kahan_add/bf16 64k", n, &mut || {
        let mut s = 0f32;
        let mut c = 0f32;
        for &x in &xs {
            let (ns, nc) = kahan_add(s, c, black_box(x) * 1e-4, BF16);
            s = ns;
            c = nc;
        }
        black_box((s, c));
    });

    run("rng/xoshiro u32 64k", n, &mut || {
        let mut g = Rng::new(3, 0);
        let mut acc = 0u32;
        for _ in 0..n {
            acc = acc.wrapping_add(g.next_u32());
        }
        black_box(acc);
    });

    merge_bench_json(&out_path, &results, &[]).expect("writing bench json");
    println!("merged {} rounding rows into {out_path}", results.len());
}
