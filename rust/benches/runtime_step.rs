//! PJRT runtime benches: raw train-step latency per application and
//! precision mode — the end-to-end hot path the coordinator drives.
//!
//! Needs `make artifacts`; skips apps whose artifacts are missing.

use bf16_train::util::bench::bench;
use bf16_train::{Policy, RunSpec, Runner};

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let runner = match Runner::open(dir) {
        Ok(r) => r,
        Err(_) => {
            println!("SKIP runtime_step: no artifacts (run `make artifacts`)");
            return;
        }
    };

    for (app, mode) in [
        ("lsq", "fp32"),
        ("lsq", "sr16"),
        ("lsq", "kahan16"),
        ("dlrm-small", "fp32"),
        ("dlrm-small", "sr16"),
        ("cifar-cnn", "sr16"),
        ("bert-cls", "sr16"),
        ("lstm-seq", "sr16"),
        ("gpt-tiny", "kahan16"),
    ] {
        let spec = RunSpec::new(app)
            .policy(Policy::parse(mode).unwrap())
            .steps(u64::MAX) // schedule factor stays ~1
            .artifacts_dir(dir);
        let Ok(mut tr) = runner.trainer(&spec) else {
            println!("SKIP {app}__{mode}: artifact missing");
            continue;
        };
        tr.run_steps(3).unwrap(); // warmup
        bench(&format!("pjrt step {app}__{mode}"), || {
            tr.run_steps(1).unwrap();
        });
    }
}
