//! PJRT runtime benches: raw train-step latency per application and
//! precision mode — the end-to-end hot path the coordinator drives.
//!
//! Needs `make artifacts`; skips apps whose artifacts are missing.

use bf16_train::config::RunConfig;
use bf16_train::coordinator::Trainer;
use bf16_train::runtime::{Engine, Manifest};
use bf16_train::util::bench::bench;

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(_) => {
            println!("SKIP runtime_step: no artifacts (run `make artifacts`)");
            return;
        }
    };
    let engine = Engine::cpu().expect("pjrt cpu");

    for (app, mode) in [
        ("lsq", "fp32"),
        ("lsq", "sr16"),
        ("lsq", "kahan16"),
        ("dlrm-small", "fp32"),
        ("dlrm-small", "sr16"),
        ("cifar-cnn", "sr16"),
        ("bert-cls", "sr16"),
        ("lstm-seq", "sr16"),
        ("gpt-tiny", "kahan16"),
    ] {
        let mut cfg = RunConfig::defaults_for(app);
        cfg.mode = mode.to_string();
        cfg.artifacts_dir = dir.to_string();
        cfg.steps = u64::MAX; // schedule factor stays ~1
        let Ok(mut tr) = Trainer::new(&engine, &manifest, cfg) else {
            println!("SKIP {app}__{mode}: artifact missing");
            continue;
        };
        tr.run_steps(3).unwrap(); // warmup
        bench(&format!("pjrt step {app}__{mode}"), || {
            tr.run_steps(1).unwrap();
        });
    }
}
