//! Native-step bench baseline: times lsq + dlrm train steps per precision
//! mode on the vectorized `Fast` backend against the scalar `Reference`
//! backend (the pre-optimization code path), with no PJRT artifacts needed.
//!
//! Emits `BENCH_qsim.json` (override the path with `QSIM_BENCH_OUT`) so
//! future PRs have a throughput trajectory to compare against.  Set
//! `QSIM_BENCH_SMOKE=1` (or pass `--smoke`) for a tiny CI-sized iteration
//! budget that only verifies the target still runs end to end.

use bf16_train::qsim::dlrm::{DlrmConfig, DlrmTrainer};
use bf16_train::qsim::lsq::{self, LsqConfig, LsqData, Placement};
use bf16_train::qsim::{Backend, Mode, Tensor};
use bf16_train::util::bench::{bench, bench_n, black_box, write_bench_json, BenchResult};
use bf16_train::util::rng::Rng;

fn timed(smoke: bool, name: &str, f: impl FnMut()) -> BenchResult {
    if smoke {
        bench_n(name, 3, f)
    } else {
        bench(name, f)
    }
}

fn dlrm_trainer(mode: Mode, backend: Backend) -> DlrmTrainer {
    let cfg = DlrmConfig { seed: 3, backend, ..Default::default() };
    let mut tr = DlrmTrainer::new(cfg, mode);
    // warm the tape arena / allocator so we time steady state
    for _ in 0..3 {
        tr.step(0.05);
    }
    tr
}

fn main() {
    let smoke = std::env::var("QSIM_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke");
    let out_path =
        std::env::var("QSIM_BENCH_OUT").unwrap_or_else(|_| "BENCH_qsim.json".into());
    let mut results: Vec<BenchResult> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();

    // -- kernel micro-bench: tiled vs reference matmul ----------------------
    let mut rng = Rng::new(1, 0);
    let a = Tensor::randn(128, 256, 1.0, &mut rng);
    let b = Tensor::randn(256, 64, 1.0, &mut rng);
    let fast_mm = timed(smoke, "matmul 128x256x64 tiled", || {
        black_box(a.matmul(&b));
    });
    let ref_mm = timed(smoke, "matmul 128x256x64 reference", || {
        black_box(a.matmul_reference(&b));
    });
    derived.push(("speedup_matmul_128x256x64".into(), ref_mm.median_ns / fast_mm.median_ns));
    results.extend([fast_mm, ref_mm]);

    // -- dlrm-small train step, per mode and backend ------------------------
    for mode in [Mode::Fp32, Mode::Standard16, Mode::Sr16, Mode::Kahan16, Mode::SrKahan16] {
        let mut pair = Vec::new();
        for backend in [Backend::Fast, Backend::Reference] {
            let mut tr = dlrm_trainer(mode, backend);
            let r = timed(
                smoke,
                &format!("dlrm-small step {} {}", mode.name(), backend.name()),
                || {
                    black_box(tr.step(0.05));
                },
            );
            pair.push(r.median_ns);
            results.push(r);
        }
        let speedup = pair[1] / pair[0];
        println!("  ↳ dlrm-small {} speedup fast/reference: {speedup:.2}x", mode.name());
        derived.push((format!("speedup_dlrm_{}", mode.name()), speedup));
    }

    // -- lsq theory loop, per rounding placement ----------------------------
    let steps = if smoke { 50 } else { 1000 };
    let cfg = LsqConfig { steps, n_samples: 256, ..LsqConfig::default() };
    let data = LsqData::generate(&cfg);
    for placement in
        [Placement::WeightUpdate, Placement::WeightUpdateSr, Placement::WeightUpdateKahan]
    {
        let r = timed(smoke, &format!("lsq {steps} steps {}", placement.name()), || {
            black_box(lsq::run(&cfg, &data, placement));
        });
        results.push(r);
    }

    // -- bit-identity spot check (the test suite asserts this too) ----------
    let parity_steps = if smoke { 10 } else { 100 };
    let mut fast = {
        let cfg = DlrmConfig { seed: 11, backend: Backend::Fast, ..Default::default() };
        DlrmTrainer::new(cfg, Mode::Sr16)
    };
    let mut reference = {
        let cfg = DlrmConfig { seed: 11, backend: Backend::Reference, ..Default::default() };
        DlrmTrainer::new(cfg, Mode::Sr16)
    };
    for s in 0..parity_steps {
        let a = fast.step(0.05);
        let b = reference.step(0.05);
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "fast/reference loss diverged at step {s}"
        );
    }
    println!("parity: {parity_steps} sr16 steps bit-identical across backends");
    derived.push(("parity_sr16_steps".into(), parity_steps as f64));

    write_bench_json(&out_path, &results, &derived).expect("writing bench json");
    println!("wrote {out_path}");
}
