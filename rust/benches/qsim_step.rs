//! Native-step bench baseline: times lsq + dlrm + gpt-nano + mlp train
//! steps per precision mode across all three backend tiers — `Simd`
//! (vector-wide kernels), `Fast` (tiled scalar), and `Reference` (the
//! scalar oracle, i.e. the pre-optimization code path) — with no PJRT
//! artifacts needed, plus `intra_threads ∈ {1, 2, hw}` scaling sweeps of
//! the parallel execution layer (`derived.scaling_dlrm_sr16_tN` /
//! `scaling_gpt_sr16_tN` / `scaling_mlp_sr16_tN` = t1 median / tN median;
//! > 1.0 means the worker pool pays off at N threads).
//!
//! Every app runs through the generic `qsim::train` engine, so the
//! per-app sections are one helper call each (`bench_app_modes` /
//! `bench_app_scaling`) instead of copied loops.  Per-mode derived keys:
//! `speedup_<tag>_<mode>` (reference/fast) and `speedup_simd_<tag>_<mode>`
//! (reference/simd).  The 2x-memory thesis is *measured*, not planned:
//! `bytes_weights_{fp32,bf16,kahan16}` come from
//! `Trainer::measured_weight_bytes()` over the native 16-bit storage.
//!
//! Merges into `BENCH_qsim.json` (override the path with `QSIM_BENCH_OUT`)
//! so future PRs have a throughput trajectory to compare against and the
//! `rounding` bench target can contribute rows to the same artifact.  Set
//! `QSIM_BENCH_SMOKE=1` (or pass `--smoke`) for a tiny CI-sized iteration
//! budget that only verifies the target still runs end to end (smoke
//! scaling ratios are noise — `derived.smoke = 1` marks such runs).

use bf16_train::qsim::dlrm::DlrmConfig;
use bf16_train::qsim::gpt::GptConfig;
use bf16_train::qsim::lsq::{self, LsqConfig, LsqData, Placement};
use bf16_train::qsim::mlp::MlpConfig;
use bf16_train::qsim::train::{Task, Trainer};
use bf16_train::qsim::{Backend, Mode, ShardOptions, ShardedTrainer, Tensor};
use bf16_train::util::bench::{bench, bench_n, black_box, merge_bench_json, BenchResult};
use bf16_train::util::rng::Rng;

fn timed(smoke: bool, name: &str, f: impl FnMut()) -> BenchResult {
    if smoke {
        bench_n(name, 3, f)
    } else {
        bench(name, f)
    }
}

/// Per-(mode, backend) step timings + `derived.speedup_<tag>_<mode>`
/// (reference median / fast median) and `speedup_simd_<tag>_<mode>`
/// (reference median / simd median) for one app.
#[allow(clippy::too_many_arguments)]
fn bench_app_modes<T: Task>(
    smoke: bool,
    label: &str,
    tag: &str,
    lr: f32,
    modes: &[Mode],
    mk: impl Fn(Backend) -> T,
    results: &mut Vec<BenchResult>,
    derived: &mut Vec<(String, f64)>,
) {
    for &mode in modes {
        let mut med = Vec::new();
        for backend in [Backend::Fast, Backend::Reference, Backend::Simd] {
            let mut tr = Trainer::new(mk(backend), mode);
            // warm the tape arena / allocator so we time steady state
            for _ in 0..3 {
                tr.step(lr);
            }
            let r = timed(
                smoke,
                &format!("{label} step {} {}", mode.name(), backend.name()),
                || {
                    black_box(tr.step(lr));
                },
            );
            med.push(r.median_ns);
            results.push(r);
        }
        let speedup = med[1] / med[0];
        let speedup_simd = med[1] / med[2];
        println!(
            "  ↳ {label} {} speedup reference/fast {speedup:.2}x, \
             reference/simd {speedup_simd:.2}x",
            mode.name()
        );
        derived.push((format!("speedup_{tag}_{}", mode.name()), speedup));
        derived.push((format!("speedup_simd_{tag}_{}", mode.name()), speedup_simd));
    }
}

/// `intra_threads` scaling sweep (`derived.scaling_<tag>_sr16_tN` = t1
/// median / tN median) plus a t1-vs-t2 bit-identity spot check for one app
/// (the test suite asserts the full contract; this guards the bench
/// configs themselves).
#[allow(clippy::too_many_arguments)]
fn bench_app_scaling<T: Task>(
    smoke: bool,
    label: &str,
    tag: &str,
    lr: f32,
    thread_counts: &[usize],
    mk: impl Fn(usize) -> T,
    results: &mut Vec<BenchResult>,
    derived: &mut Vec<(String, f64)>,
) {
    let mut t1_median = None;
    for &threads in thread_counts {
        let mut tr = Trainer::new(mk(threads), Mode::Sr16);
        // warm the tape arena and the worker pool
        for _ in 0..2 {
            tr.step(lr);
        }
        let r = timed(smoke, &format!("{label} step sr16 t{threads}"), || {
            black_box(tr.step(lr));
        });
        match t1_median {
            None => t1_median = Some(r.median_ns),
            Some(t1) => {
                let scaling = t1 / r.median_ns;
                println!("  ↳ {label} sr16 scaling t{threads} vs t1: {scaling:.2}x");
                derived.push((format!("scaling_{tag}_sr16_t{threads}"), scaling));
            }
        }
        results.push(r);
    }
    let mut a = Trainer::new(mk(1), Mode::Sr16);
    let mut b = Trainer::new(mk(2), Mode::Sr16);
    for s in 0..3 {
        let la = a.step(lr).loss;
        let lb = b.step(lr).loss;
        assert_eq!(la.to_bits(), lb.to_bits(), "{label} t1/t2 loss diverged at step {s}");
    }
    println!("parity: {label} sr16 bit-identical at 1 vs 2 intra-threads");
}

fn main() {
    let smoke = std::env::var("QSIM_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke");
    let out_path =
        std::env::var("QSIM_BENCH_OUT").unwrap_or_else(|_| "BENCH_qsim.json".into());
    let mut results: Vec<BenchResult> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();

    // -- kernel micro-bench: simd vs tiled vs reference matmul --------------
    let mut rng = Rng::new(1, 0);
    let a = Tensor::randn(128, 256, 1.0, &mut rng);
    let b = Tensor::randn(256, 64, 1.0, &mut rng);
    let fast_mm = timed(smoke, "matmul 128x256x64 tiled", || {
        black_box(a.matmul(&b));
    });
    let ref_mm = timed(smoke, "matmul 128x256x64 reference", || {
        black_box(a.matmul_reference(&b));
    });
    let mut out = Tensor::zeros(128, 64);
    let simd_mm = timed(smoke, "matmul 128x256x64 simd", || {
        a.matmul_into_simd(&b, &mut out, None);
        black_box(&out);
    });
    derived.push(("speedup_matmul_128x256x64".into(), ref_mm.median_ns / simd_mm.median_ns));
    derived.push((
        "speedup_matmul_128x256x64_tiled".into(),
        ref_mm.median_ns / fast_mm.median_ns,
    ));
    results.extend([fast_mm, ref_mm, simd_mm]);

    // -- measured weight bytes: the paper's 2x-memory claim, as stored ------
    // (dlrm-small; standard16/sr16 hold weights natively in 16 bits, kahan
    // adds a 16-bit compensation buffer alongside — back to fp32's total)
    for (mode, key) in [
        (Mode::Fp32, "bytes_weights_fp32"),
        (Mode::Sr16, "bytes_weights_bf16"),
        (Mode::Kahan16, "bytes_weights_kahan16"),
    ] {
        let tr = Trainer::new(DlrmConfig { seed: 3, ..Default::default() }, mode);
        let bytes = tr.measured_weight_bytes();
        println!("{key}: {bytes} (dlrm-small, {})", mode.name());
        derived.push((key.into(), bytes as f64));
    }

    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut thread_counts = vec![1usize, 2];
    if hw > 2 {
        thread_counts.push(hw);
    }

    // -- dlrm-small train step, per mode and backend ------------------------
    bench_app_modes(
        smoke,
        "dlrm-small",
        "dlrm",
        0.05,
        &[Mode::Fp32, Mode::Standard16, Mode::Sr16, Mode::Kahan16, Mode::SrKahan16],
        |backend| DlrmConfig { seed: 3, backend, ..Default::default() },
        &mut results,
        &mut derived,
    );

    // -- dlrm intra-step scaling: a DLRM big enough for the pool to matter --
    // (dlrm-small's default shapes are too tiny to amortize any dispatch;
    // this config matches a mid-size production-ish embedding + MLP stack)
    bench_app_scaling(
        smoke,
        "dlrm-par",
        "dlrm",
        0.05,
        &thread_counts,
        |threads| DlrmConfig {
            seed: 3,
            table_size: 2000,
            embed_dim: 32,
            dense_dim: 32,
            hidden: 256,
            batch: if smoke { 64 } else { 256 },
            intra_threads: threads,
            ..Default::default()
        },
        &mut results,
        &mut derived,
    );

    // -- gpt-nano train step, per mode and backend --------------------------
    bench_app_modes(
        smoke,
        "gpt-nano",
        "gpt",
        0.1,
        &[Mode::Fp32, Mode::Sr16],
        |backend| GptConfig { seed: 3, backend, ..Default::default() },
        &mut results,
        &mut derived,
    );

    // -- gpt intra-step scaling: a transformer big enough for the pool ------
    // (attention fans out per sequence, the matmuls per row panel)
    bench_app_scaling(
        smoke,
        "gpt-par",
        "gpt",
        0.1,
        &thread_counts,
        |threads| GptConfig {
            seed: 3,
            vocab: 256,
            seq_len: 32,
            dim: 64,
            hidden: 256,
            batch: if smoke { 8 } else { 16 },
            intra_threads: threads,
            ..Default::default()
        },
        &mut results,
        &mut derived,
    );

    // -- mlp (spiral classifier) train step, per mode and backend -----------
    bench_app_modes(
        smoke,
        "mlp",
        "mlp",
        0.1,
        &[Mode::Fp32, Mode::Sr16],
        |backend| MlpConfig { seed: 3, backend, ..Default::default() },
        &mut results,
        &mut derived,
    );

    // -- mlp intra-step scaling: widths where the matmul fan-out matters ----
    bench_app_scaling(
        smoke,
        "mlp-par",
        "mlp",
        0.1,
        &thread_counts,
        |threads| MlpConfig {
            seed: 3,
            hidden: 512,
            batch: if smoke { 64 } else { 256 },
            intra_threads: threads,
            ..Default::default()
        },
        &mut results,
        &mut derived,
    );

    // -- shard-count sweep: the data-parallel engine over one full step -----
    // (every shard count runs the identical fixed M=4 microbatch grid, so
    // `derived.scaling_shards_sr16_sN` = s1 median / sN median isolates the
    // worker fan-out win at bit-identical arithmetic; s1 pays the same
    // framing + channel cost, which keeps the ratio honest about transport
    // overhead rather than comparing against the in-process trainer)
    {
        let mk = || DlrmConfig {
            seed: 3,
            table_size: 2000,
            embed_dim: 32,
            dense_dim: 32,
            hidden: 256,
            batch: if smoke { 32 } else { 128 },
            ..Default::default()
        };
        let sharded = |shards| {
            ShardedTrainer::new(
                mk(),
                Mode::Sr16,
                ShardOptions { shards, microbatches: 4, ..Default::default() },
            )
            .expect("bench shard geometry is valid")
        };
        let mut s1_median = None;
        for shards in [1usize, 2, 4] {
            let mut tr = sharded(shards);
            // warm the workers' tape arenas and the channel path
            for _ in 0..2 {
                tr.step(0.05);
            }
            let r = timed(smoke, &format!("dlrm-shard step sr16 s{shards}"), || {
                black_box(tr.step(0.05));
            });
            match s1_median {
                None => s1_median = Some(r.median_ns),
                Some(s1) => {
                    let scaling = s1 / r.median_ns;
                    println!("  ↳ dlrm-shard sr16 scaling s{shards} vs s1: {scaling:.2}x");
                    derived.push((format!("scaling_shards_sr16_s{shards}"), scaling));
                }
            }
            results.push(r);
        }
        // s1-vs-s4 bit-identity spot check over fresh trainers (the test
        // suite asserts the full contract; this guards the bench configs)
        let mut a = sharded(1);
        let mut b = sharded(4);
        for s in 0..3 {
            let la = a.step(0.05).loss;
            let lb = b.step(0.05).loss;
            assert_eq!(la.to_bits(), lb.to_bits(), "dlrm-shard s1/s4 loss diverged at step {s}");
        }
        assert_eq!(a.param_digest(), b.param_digest(), "dlrm-shard s1/s4 params diverged");
        println!("parity: dlrm-shard sr16 bit-identical at 1 vs 4 shards");
    }

    // -- lsq theory loop, per rounding placement ----------------------------
    let steps = if smoke { 50 } else { 1000 };
    let cfg = LsqConfig { steps, n_samples: 256, ..LsqConfig::default() };
    let data = LsqData::generate(&cfg);
    for placement in
        [Placement::WeightUpdate, Placement::WeightUpdateSr, Placement::WeightUpdateKahan]
    {
        let r = timed(smoke, &format!("lsq {steps} steps {}", placement.name()), || {
            black_box(lsq::run(&cfg, &data, placement));
        });
        results.push(r);
    }

    // -- bit-identity spot check (the test suite asserts this too) ----------
    let parity_steps = if smoke { 10 } else { 100 };
    let mut fast = Trainer::new(
        DlrmConfig { seed: 11, backend: Backend::Fast, ..Default::default() },
        Mode::Sr16,
    );
    let mut reference = Trainer::new(
        DlrmConfig { seed: 11, backend: Backend::Reference, ..Default::default() },
        Mode::Sr16,
    );
    let mut simd = Trainer::new(
        DlrmConfig { seed: 11, backend: Backend::Simd, ..Default::default() },
        Mode::Sr16,
    );
    for s in 0..parity_steps {
        let a = fast.step(0.05);
        let b = reference.step(0.05);
        let c = simd.step(0.05);
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "fast/reference loss diverged at step {s}"
        );
        assert_eq!(
            c.loss.to_bits(),
            b.loss.to_bits(),
            "simd/reference loss diverged at step {s}"
        );
    }
    println!("parity: {parity_steps} sr16 steps bit-identical across all three backends");
    derived.push(("parity_sr16_steps".into(), parity_steps as f64));
    derived.push(("smoke".into(), if smoke { 1.0 } else { 0.0 }));

    merge_bench_json(&out_path, &results, &derived).expect("writing bench json");
    println!("wrote {out_path}");
}
