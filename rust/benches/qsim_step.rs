//! Native-step bench baseline: times lsq + dlrm train steps per precision
//! mode on the vectorized `Fast` backend against the scalar `Reference`
//! backend (the pre-optimization code path), with no PJRT artifacts needed,
//! plus an `intra_threads ∈ {1, 2, hw}` scaling sweep of the parallel
//! execution layer (`derived.scaling_dlrm_sr16_tN` = t1 median / tN median;
//! > 1.0 means the worker pool pays off at N threads).
//!
//! Emits `BENCH_qsim.json` (override the path with `QSIM_BENCH_OUT`) so
//! future PRs have a throughput trajectory to compare against.  Set
//! `QSIM_BENCH_SMOKE=1` (or pass `--smoke`) for a tiny CI-sized iteration
//! budget that only verifies the target still runs end to end (smoke
//! scaling ratios are noise — `derived.smoke = 1` marks such runs).

use bf16_train::qsim::dlrm::{DlrmConfig, DlrmTrainer};
use bf16_train::qsim::gpt::{GptConfig, GptTrainer};
use bf16_train::qsim::lsq::{self, LsqConfig, LsqData, Placement};
use bf16_train::qsim::{Backend, Mode, Tensor};
use bf16_train::util::bench::{bench, bench_n, black_box, write_bench_json, BenchResult};
use bf16_train::util::rng::Rng;

fn timed(smoke: bool, name: &str, f: impl FnMut()) -> BenchResult {
    if smoke {
        bench_n(name, 3, f)
    } else {
        bench(name, f)
    }
}

fn dlrm_trainer(mode: Mode, backend: Backend) -> DlrmTrainer {
    let cfg = DlrmConfig { seed: 3, backend, ..Default::default() };
    let mut tr = DlrmTrainer::new(cfg, mode);
    // warm the tape arena / allocator so we time steady state
    for _ in 0..3 {
        tr.step(0.05);
    }
    tr
}

fn main() {
    let smoke = std::env::var("QSIM_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--smoke");
    let out_path =
        std::env::var("QSIM_BENCH_OUT").unwrap_or_else(|_| "BENCH_qsim.json".into());
    let mut results: Vec<BenchResult> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();

    // -- kernel micro-bench: tiled vs reference matmul ----------------------
    let mut rng = Rng::new(1, 0);
    let a = Tensor::randn(128, 256, 1.0, &mut rng);
    let b = Tensor::randn(256, 64, 1.0, &mut rng);
    let fast_mm = timed(smoke, "matmul 128x256x64 tiled", || {
        black_box(a.matmul(&b));
    });
    let ref_mm = timed(smoke, "matmul 128x256x64 reference", || {
        black_box(a.matmul_reference(&b));
    });
    derived.push(("speedup_matmul_128x256x64".into(), ref_mm.median_ns / fast_mm.median_ns));
    results.extend([fast_mm, ref_mm]);

    // -- dlrm-small train step, per mode and backend ------------------------
    for mode in [Mode::Fp32, Mode::Standard16, Mode::Sr16, Mode::Kahan16, Mode::SrKahan16] {
        let mut pair = Vec::new();
        for backend in [Backend::Fast, Backend::Reference] {
            let mut tr = dlrm_trainer(mode, backend);
            let r = timed(
                smoke,
                &format!("dlrm-small step {} {}", mode.name(), backend.name()),
                || {
                    black_box(tr.step(0.05));
                },
            );
            pair.push(r.median_ns);
            results.push(r);
        }
        let speedup = pair[1] / pair[0];
        println!("  ↳ dlrm-small {} speedup fast/reference: {speedup:.2}x", mode.name());
        derived.push((format!("speedup_dlrm_{}", mode.name()), speedup));
    }

    // -- intra-step scaling: a DLRM big enough for the pool to matter -------
    // (dlrm-small's default shapes are too tiny to amortize any dispatch;
    // this config matches a mid-size production-ish embedding + MLP stack)
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let mut thread_counts = vec![1usize, 2];
    if hw > 2 {
        thread_counts.push(hw);
    }
    let par_cfg = |threads: usize| DlrmConfig {
        seed: 3,
        table_size: 2000,
        embed_dim: 32,
        dense_dim: 32,
        hidden: 256,
        batch: if smoke { 64 } else { 256 },
        intra_threads: threads,
        ..Default::default()
    };
    let mut t1_median = None;
    for &threads in &thread_counts {
        let mut tr = DlrmTrainer::new(par_cfg(threads), Mode::Sr16);
        for _ in 0..2 {
            tr.step(0.05); // warm the tape arena and the worker pool
        }
        let r = timed(smoke, &format!("dlrm-par step sr16 t{threads}"), || {
            black_box(tr.step(0.05));
        });
        match t1_median {
            None => t1_median = Some(r.median_ns),
            Some(t1) => {
                let scaling = t1 / r.median_ns;
                println!("  ↳ dlrm-par sr16 scaling t{threads} vs t1: {scaling:.2}x");
                derived.push((format!("scaling_dlrm_sr16_t{threads}"), scaling));
            }
        }
        results.push(r);
    }
    // thread-count bit-identity spot check on the scaling config
    {
        let mut a = DlrmTrainer::new(par_cfg(1), Mode::Sr16);
        let mut b = DlrmTrainer::new(par_cfg(2), Mode::Sr16);
        for s in 0..3 {
            let ta = a.step(0.05);
            let tb = b.step(0.05);
            assert_eq!(
                ta.loss.to_bits(),
                tb.loss.to_bits(),
                "t1/t2 loss diverged at step {s}"
            );
        }
        println!("parity: dlrm-par sr16 bit-identical at 1 vs 2 intra-threads");
    }

    // -- gpt-nano train step, per mode and backend --------------------------
    let gpt_trainer = |mode: Mode, backend: Backend| {
        let cfg = GptConfig { seed: 3, backend, ..Default::default() };
        let mut tr = GptTrainer::new(cfg, mode);
        for _ in 0..3 {
            tr.step(0.1); // warm the tape arena
        }
        tr
    };
    for mode in [Mode::Fp32, Mode::Sr16] {
        let mut pair = Vec::new();
        for backend in [Backend::Fast, Backend::Reference] {
            let mut tr = gpt_trainer(mode, backend);
            let r = timed(
                smoke,
                &format!("gpt-nano step {} {}", mode.name(), backend.name()),
                || {
                    black_box(tr.step(0.1));
                },
            );
            pair.push(r.median_ns);
            results.push(r);
        }
        let speedup = pair[1] / pair[0];
        println!("  ↳ gpt-nano {} speedup fast/reference: {speedup:.2}x", mode.name());
        derived.push((format!("speedup_gpt_{}", mode.name()), speedup));
    }

    // -- gpt intra-step scaling: a transformer big enough for the pool ------
    // (attention fans out per sequence, the matmuls per row panel)
    let gpt_par_cfg = |threads: usize| GptConfig {
        seed: 3,
        vocab: 256,
        seq_len: 32,
        dim: 64,
        hidden: 256,
        batch: if smoke { 8 } else { 16 },
        intra_threads: threads,
        ..Default::default()
    };
    let mut gpt_t1_median = None;
    for &threads in &thread_counts {
        let mut tr = GptTrainer::new(gpt_par_cfg(threads), Mode::Sr16);
        for _ in 0..2 {
            tr.step(0.1); // warm the tape arena and the worker pool
        }
        let r = timed(smoke, &format!("gpt-par step sr16 t{threads}"), || {
            black_box(tr.step(0.1));
        });
        match gpt_t1_median {
            None => gpt_t1_median = Some(r.median_ns),
            Some(t1) => {
                let scaling = t1 / r.median_ns;
                println!("  ↳ gpt-par sr16 scaling t{threads} vs t1: {scaling:.2}x");
                derived.push((format!("scaling_gpt_sr16_t{threads}"), scaling));
            }
        }
        results.push(r);
    }
    // thread-count bit-identity spot check on the gpt scaling config
    {
        let mut a = GptTrainer::new(gpt_par_cfg(1), Mode::Sr16);
        let mut b = GptTrainer::new(gpt_par_cfg(2), Mode::Sr16);
        for s in 0..3 {
            let (la, _) = a.step(0.1);
            let (lb, _) = b.step(0.1);
            assert_eq!(
                la.to_bits(),
                lb.to_bits(),
                "gpt t1/t2 loss diverged at step {s}"
            );
        }
        println!("parity: gpt-par sr16 bit-identical at 1 vs 2 intra-threads");
    }

    // -- lsq theory loop, per rounding placement ----------------------------
    let steps = if smoke { 50 } else { 1000 };
    let cfg = LsqConfig { steps, n_samples: 256, ..LsqConfig::default() };
    let data = LsqData::generate(&cfg);
    for placement in
        [Placement::WeightUpdate, Placement::WeightUpdateSr, Placement::WeightUpdateKahan]
    {
        let r = timed(smoke, &format!("lsq {steps} steps {}", placement.name()), || {
            black_box(lsq::run(&cfg, &data, placement));
        });
        results.push(r);
    }

    // -- bit-identity spot check (the test suite asserts this too) ----------
    let parity_steps = if smoke { 10 } else { 100 };
    let mut fast = {
        let cfg = DlrmConfig { seed: 11, backend: Backend::Fast, ..Default::default() };
        DlrmTrainer::new(cfg, Mode::Sr16)
    };
    let mut reference = {
        let cfg = DlrmConfig { seed: 11, backend: Backend::Reference, ..Default::default() };
        DlrmTrainer::new(cfg, Mode::Sr16)
    };
    for s in 0..parity_steps {
        let a = fast.step(0.05);
        let b = reference.step(0.05);
        assert_eq!(
            a.loss.to_bits(),
            b.loss.to_bits(),
            "fast/reference loss diverged at step {s}"
        );
    }
    println!("parity: {parity_steps} sr16 steps bit-identical across backends");
    derived.push(("parity_sr16_steps".into(), parity_steps as f64));
    derived.push(("smoke".into(), if smoke { 1.0 } else { 0.0 }));

    write_bench_json(&out_path, &results, &derived).expect("writing bench json");
    println!("wrote {out_path}");
}
