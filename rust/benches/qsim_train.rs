//! Native simulator benches: quantised matmul + full DLRM train steps per
//! precision mode.  These are the L3 hot path for the theory/telemetry
//! experiments (Figures 2, 5, 9, 10).

use bf16_train::qsim::dlrm::{DlrmConfig, DlrmTrainer};
use bf16_train::qsim::{Mode, QPolicy, Tape, Tensor};
use bf16_train::util::bench::{bench, black_box, throughput};
use bf16_train::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(1, 0);
    let a = Tensor::randn(128, 256, 1.0, &mut rng);
    let b = Tensor::randn(256, 64, 1.0, &mut rng);

    let r = bench("qsim matmul 128x256x64 fp32 (tiled)", || {
        black_box(a.matmul(&b));
    });
    throughput(&r, 128 * 256 * 64);

    let r = bench("qsim matmul 128x256x64 fp32 (reference)", || {
        black_box(a.matmul_reference(&b));
    });
    throughput(&r, 128 * 256 * 64);

    let r = bench("qsim fwd+bwd matmul-mse bf16", || {
        let mut t = Tape::new(QPolicy::new(bf16_train::precision::BF16));
        let av = t.input(a.clone());
        let bv = t.param(b.clone());
        let y = t.matmul(av, bv);
        let tgt = t.input(Tensor::zeros(128, 64));
        let l = t.mse_loss(y, tgt);
        t.backward(l);
        black_box(t.grad(bv).is_some());
    });
    throughput(&r, 2 * 128 * 256 * 64);

    for mode in [Mode::Fp32, Mode::Standard16, Mode::Sr16, Mode::Kahan16] {
        let cfg = DlrmConfig::default();
        let mut tr = DlrmTrainer::new(cfg, mode);
        tr.step(0.05); // warm the allocator
        bench(&format!("dlrm train step {}", mode.name()), || {
            black_box(tr.step(0.05));
        });
    }

    // LSQ theory experiment throughput (Figure 2's inner loop)
    use bf16_train::qsim::lsq::{self, LsqConfig, LsqData, Placement};
    let cfg = LsqConfig { steps: 1000, n_samples: 256, ..LsqConfig::default() };
    let data = LsqData::generate(&cfg);
    bench("lsq 1000 sgd steps (weight-update rounding)", || {
        black_box(lsq::run(&cfg, &data, Placement::WeightUpdate));
    });
}
