//! Miniature end-to-end versions of every paper table/figure — one bench
//! entry per experiment, so `cargo bench` demonstrates each regeneration
//! path compiles and runs.  Full-scale runs: `repro exp all`.

use bf16_train::coordinator::{run_experiment, ExpOptions};
use bf16_train::util::bench::bench;
use bf16_train::Runner;

fn main() {
    let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
    let runner = match Runner::open(dir) {
        Ok(r) => Some(r),
        Err(e) => {
            println!("note: PJRT runtime unavailable ({e:#})");
            None
        }
    };

    let tmp = std::env::temp_dir().join("bf16_bench_results");
    let opts = ExpOptions {
        steps: Some(60),
        seeds: 1,
        out_dir: tmp.to_string_lossy().into_owned(),
        artifacts_dir: dir.to_string(),
        ..ExpOptions::default()
    };

    // native-only experiments
    for id in ["table1", "table2", "fig2", "thm1", "fig5", "fig9"] {
        bench(&format!("exp {id} (mini)"), || {
            run_experiment(id, None, &opts, None).unwrap();
        });
    }
    // PJRT-backed experiments (skip when artifacts missing)
    if runner.is_some() {
        for id in ["fig1", "table3", "fig10", "fig11", "fig12"] {
            bench(&format!("exp {id} (mini)"), || {
                run_experiment(id, runner.as_ref(), &opts, None).unwrap();
            });
        }
        bench("exp table4 (mini, dlrm-small only)", || {
            run_experiment("table4", runner.as_ref(), &opts, Some("dlrm-small")).unwrap();
        });
    } else {
        println!("SKIP PJRT experiments: no artifacts (run `make artifacts`)");
    }
}
