//! `repro` — launcher CLI for the bf16-train framework.
//!
//! Subcommands:
//!   list                      — show available artifacts
//!   train                     — run one training job (flags or --config TOML)
//!   exp <id> [--steps N] …    — regenerate one paper table/figure (or `all`)
//!   bench-step <artifact>     — measure raw train-step latency
//!   qsim-parity               — deterministic digest of a native qsim run
//!                               (CI diffs it across --intra-threads values)
//!
//! Precision policies are typed end-to-end: `--mode sr16 --fmt e8m5` (and
//! artifact names like `dlrm-small__sr16-e8m5`) parse through
//! `precision::Policy`, so an invalid policy fails at the command line, not
//! deep inside a run.  Runs are assembled with the `RunSpec` builder and
//! executed through the library `Runner`; `exp` fans its policy × seed
//! grids out across threads (cap with `--threads`).
//!
//! Python never runs here; artifacts must exist (`make artifacts`).

use anyhow::{bail, Context, Result};

use bf16_train::config::{RunConfig, RunSpec};
use bf16_train::coordinator::{run_experiment, ExpOptions, ALL_EXPERIMENTS};
use bf16_train::precision::{Format, Mode, Policy};
use bf16_train::runtime::Manifest;
use bf16_train::util::cli::Args;
use bf16_train::Runner;

fn main() -> Result<()> {
    let mut args = Args::from_env()?;
    let cmd = args.pos(0).unwrap_or("help").to_string();
    match cmd.as_str() {
        "list" => cmd_list(&mut args),
        "train" => cmd_train(&mut args),
        "exp" => cmd_exp(&mut args),
        "bench-step" => cmd_bench_step(&mut args),
        "qsim-parity" => cmd_qsim_parity(&mut args),
        "lint-tape" => cmd_lint_tape(&mut args),
        "fuzz-tape" => cmd_fuzz_tape(&mut args),
        "synth-rules" => cmd_synth_rules(&mut args),
        "serve" => cmd_serve(&mut args),
        "serve-bench" => cmd_serve_bench(&mut args),
        "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

const USAGE: &str = "usage: repro <command>
  list [--artifacts DIR]
  train --app APP [--mode MODE] [--fmt FMT] [--steps N] [--seed S]
        [--lr LR] [--intra-threads T] [--backend fast|reference|simd]
        [--config FILE.toml] [--checkpoint PATH] [--resume PATH] [--native]
        [--shards N] [--grad-accum M] [--chaos SPEC]
  exp <table1|table2|table3|table4|fig1|fig2|fig5|fig9|fig10|fig11|fig12|thm1|gpt|mlp|all>
        [--steps N] [--seeds K] [--app APP] [--threads T]
        [--intra-threads T] [--no-smooth]
  bench-step <artifact-name> [--iters N] [--intra-threads T]
  qsim-parity [--steps N] [--seed S] [--intra-threads T]
        [--app all|dlrm|gpt|mlp|lsq] [--backend fast|reference|simd]
        [--shards N] [--grad-accum M] [--chaos SPEC]
  lint-tape [--app all|dlrm|gpt|mlp|lsq] [--seed S]
  fuzz-tape [--budget N] [--seed S] [--case I]
  synth-rules [--depth D] [--seed S] [--check] [--write]
  serve --ckpt FILE [--addr HOST:PORT] [--batch-window US] [--max-batch N]
        [--backend fast|reference|simd] [--mode MODE] [--fmt FMT] [--seed S]
        [--config FILE.toml]
  serve-bench [--iters N] [--requests N] [--out FILE]
  serve-bench --connect ADDR --app dlrm|gpt-nano --corpus FILE
        [--clients C] [--shutdown]
  serve-bench --oracle --ckpt FILE --corpus FILE [--mode MODE] [--fmt FMT]
        [--seed S]

modes: fp32 standard16 mixed16 sr16 kahan16 srkahan16
fmts:  bf16 (default) fp16 e8m5 e8m3 e8m1

`exp gpt` / `exp mlp` train the native apps (gpt-nano transformer LM;
spiral-MLP classifier) across fp32/sr16/kahan16/standard16 on the
bit-exact simulator — no PJRT artifacts needed.

`train --native` runs one app (dlrm, gpt-nano, mlp) on the generic
`qsim::train` engine instead of the PJRT runtime; --checkpoint / --resume
save and restore native BF16CKP2 checkpoints, and a resumed run is
bit-identical to an uninterrupted one.

`lint-tape` records one real training step per app, exports the tape
graph as a program IR and runs the `qsim::verify` structural linter over
it (shapes, grad flow, dead nodes, replayability, chains fusable by the
admitted ruleset), checks the app's stochastic-rounding dither
coordinates for collisions, then resets the tape and audits free-pool
accounting.  `fuzz-tape` runs the enumerative differential fuzzer:
seeded random tape programs checked for bitwise parity across backends,
thread counts and every policy format, against finite-difference
gradients, and through the admitted rewrite ruleset applied to fixpoint;
a failure prints a minimized repro replayable with --case.

`synth-rules` runs Ruler-style rewrite synthesis over the tape IR:
enumerate small op patterns, cluster them by bitwise cvec fingerprints
(shared seeded inputs, both backends, fp32/bf16/fp16/e8m5), and admit
candidate rules only when loss, forward and every leaf gradient are
bit-identical across formats x {fast,reference,simd} x {1,4} threads.
--depth/--seed default to the checked-in corpus coordinates
(rust/tests/data/synth_rules.txt).  The corpus is the pinned, reviewed
subset of what synthesis admits; --check re-proves every checked-in rule,
fails if any stops proving or stops being synthesized, and lists newly
admitted rules for review; --write rewrites the corpus from a fresh run
(review before committing).

--threads fans runs out across sweep workers; --intra-threads parallelizes
within one train step (bit-identical results at every setting).  Today the
intra-step pool drives the qsim-native kernels (fig5/fig9, qsim-parity, the
native benches); the PJRT session path records the setting but still runs
its lowered executables as compiled.

`serve` loads a BF16CKP2 checkpoint (app auto-detected from the header)
into a frozen model and scores it through the tape-free compiled
inference plan: one line per request over TCP (`dlrm <dense..> | <idx..>`
or `gpt <tok..>`), replies carry the logit bit pattern, and concurrent
requests are coalesced for up to --batch-window microseconds (up to
--max-batch rows) and scored as one padded batch — batching and padding
never change a scored bit, so replies are bit-identical to a per-request
tape eval.  --mode/--fmt/--seed must match the training run (the
checkpoint validates them); checkpoints from custom-sized configs load
via the same --config used to train.  Send the line `shutdown` to stop
the server.  `serve-bench` with no flags runs the in-process suite and
writes BENCH_serve.json (p50/p99/QPS per backend x batch window, plus
infer-plan vs tape-eval speedups); --connect drives a corpus file
against a running server and prints a reply digest that must equal the
digest `--oracle` computes from the checkpoint via per-request tape
evals.

--shards N (with --native, or on qsim-parity) runs the data-parallel
`qsim::shard` engine: each optimizer step splits --grad-accum M
microbatches (power of two, default 4) across N worker shards (power of
two <= M) and reduces their gradients over a fixed pairwise tree, so the
trajectory is bit-identical at every shard count — including N=1 — and
checkpoints resume across shard counts.  --chaos injects a deterministic
fault schedule (crashes, stalls, dropped/corrupted messages; presets
`light`/`heavy`, rates like `crash=0.05`, pinned events like
`crash@3.1,stall@5.0:80`); recovery is bit-exact, so qsim-parity digests
stay byte-identical under any schedule.  Recovery counters go to stderr.";

fn cmd_list(args: &mut Args) -> Result<()> {
    let dir = args.opt("artifacts", "artifacts");
    args.finish()?;
    let manifest = Manifest::load(&dir)?;
    println!("{:<36} {:<12} {:<6} {:<12} params", "artifact", "mode", "fmt", "family");
    for a in &manifest.artifacts {
        println!(
            "{:<36} {:<12} {:<6} {:<12} {}",
            a.name, a.mode, a.fmt, a.family, a.param_elements
        );
    }
    println!("{} artifacts in {dir}", manifest.artifacts.len());
    Ok(())
}

fn cmd_train(args: &mut Args) -> Result<()> {
    let cfg = match args.opt_maybe("config") {
        Some(path) => RunConfig::from_toml_file(&path)?,
        None => {
            let app = args
                .opt_maybe("app")
                .context("train needs --app or --config")?;
            RunConfig::defaults_for(&app)
        }
    };
    let mut policy = cfg.policy;
    if let Some(m) = args.opt_maybe("mode") {
        policy = Policy::new(m.parse::<Mode>()?, policy.fmt);
    }
    if let Some(f) = args.opt_maybe("fmt") {
        let fmt = Format::by_name(&f).with_context(|| format!("--fmt {f:?} is not a known format"))?;
        policy = Policy::new(policy.mode, fmt);
    }
    let steps = args.opt_u64("steps", cfg.steps)?;
    let seed = args.opt_u64("seed", cfg.seed)?;
    let lr = args.opt_f64("lr", cfg.base_lr)?;
    let intra_threads = args.opt_u64("intra-threads", cfg.intra_threads as u64)? as usize;
    let backend = match args.opt_maybe("backend") {
        Some(b) => bf16_train::qsim::Backend::by_name(&b)
            .with_context(|| format!("--backend {b:?} (expected fast, reference or simd)"))?,
        None => cfg.backend,
    };
    let artifacts_dir = args.opt("artifacts", &cfg.artifacts_dir.clone());
    let checkpoint = args.opt_maybe("checkpoint");
    let resume = args.opt_maybe("resume");
    let shards = args.opt_u64("shards", cfg.shards as u64)? as usize;
    let grad_accum = args.opt_u64("grad-accum", cfg.grad_accum.max(1) as u64)? as usize;
    let chaos = args.opt_maybe("chaos").or_else(|| cfg.chaos.clone());
    let native = args.flag("native");
    args.finish()?;

    if !native && (shards > 0 || chaos.is_some() || grad_accum > 1) {
        bail!("--shards / --grad-accum / --chaos drive the qsim-native engine; add --native");
    }
    if chaos.is_some() && shards == 0 {
        bail!("--chaos injects faults into shard workers; add --shards N");
    }

    if native {
        return cmd_train_native(
            &cfg.app,
            NativeRun {
                mode: policy.mode,
                fmt: policy.fmt,
                steps,
                seed,
                lr,
                intra_threads,
                backend,
                eval_batches: cfg.eval_batches,
                checkpoint,
                resume,
                shards,
                grad_accum,
                chaos,
            },
        );
    }

    let spec = RunSpec::from_config(cfg)
        .policy(policy)
        .steps(steps)
        .seed(seed)
        .lr(lr)
        .intra_threads(intra_threads)
        .backend(backend)
        .artifacts_dir(&artifacts_dir);
    let cfg = spec.build();
    let runner = Runner::open(&artifacts_dir)?;
    println!(
        "train {} | steps={} lr={} seed={} [{} on {}]",
        cfg.artifact_name(),
        cfg.steps,
        cfg.base_lr,
        cfg.seed,
        cfg.policy.mode,
        runner.engine().platform()
    );
    let out_dir = cfg.out_dir.clone();
    let mut tr = runner.trainer_for(cfg)?;
    if let Some(path) = resume {
        tr.load_checkpoint(&path)?;
        println!("resumed from {path}");
    }
    let summary = tr.run()?;
    println!(
        "done: val {}={:.3}  train-loss={:.4}  cancel={:.1}%  ({:.1}s, {:.1} steps/s)",
        summary.metric_name,
        summary.val_metric,
        summary.final_train_loss,
        summary.mean_cancel_frac * 100.0,
        summary.wallclock_s,
        summary.steps_per_s
    );
    std::fs::create_dir_all(&out_dir)?;
    let csv_path = format!(
        "{out_dir}/train__{}__{}__seed{}.csv",
        summary.app, summary.policy, summary.seed
    );
    std::fs::write(&csv_path, summary.history.to_csv(None))?;
    println!("history: {csv_path}");
    if let Some(path) = checkpoint {
        tr.save_checkpoint(&path)?;
        println!("checkpoint: {path}");
    }
    Ok(())
}

/// Everything `train --native` needs beyond the app name (bundled so the
/// sharded variant doesn't push the parameter list into the teens).
struct NativeRun {
    mode: Mode,
    fmt: Format,
    steps: u64,
    seed: u64,
    lr: f64,
    intra_threads: usize,
    backend: bf16_train::qsim::Backend,
    eval_batches: u64,
    checkpoint: Option<String>,
    resume: Option<String>,
    /// 0 = single-process loop; N >= 1 = the `qsim::shard` engine.
    shards: usize,
    grad_accum: usize,
    chaos: Option<String>,
}

/// Build the chaos plan from a `--chaos` spec (None when the schedule can
/// never fire, so clean runs skip the injection hooks entirely).
fn chaos_plan(spec: Option<&str>) -> Result<Option<std::sync::Arc<bf16_train::qsim::ChaosPlan>>> {
    use bf16_train::qsim::{ChaosConfig, ChaosPlan};
    match spec {
        None => Ok(None),
        Some(s) => {
            let cfg = ChaosConfig::parse(s).with_context(|| format!("--chaos {s:?}"))?;
            Ok(if cfg.is_quiet() { None } else { Some(std::sync::Arc::new(ChaosPlan::new(cfg))) })
        }
    }
}

/// `train --native`: run one app on the generic `qsim::train` engine (no
/// PJRT artifacts), with native BF16CKP2 checkpoint/resume.  Constant lr —
/// the native engine leaves scheduling to the experiment harness.
fn cmd_train_native(app: &str, run: NativeRun) -> Result<()> {
    use bf16_train::qsim::dlrm::DlrmConfig;
    use bf16_train::qsim::gpt::GptConfig;
    use bf16_train::qsim::mlp::MlpConfig;

    println!(
        "train {app} (native qsim) | steps={} lr={} seed={} [{} on {}, {} backend]",
        run.steps,
        run.lr,
        run.seed,
        run.mode,
        run.fmt.name,
        run.backend.name()
    );
    let (seed, fmt, intra_threads, backend) = (run.seed, run.fmt, run.intra_threads, run.backend);
    match app {
        "dlrm" => run_native_train(
            DlrmConfig { seed, fmt, intra_threads, backend, ..Default::default() },
            run,
        ),
        "gpt" | "gpt-nano" => run_native_train(
            GptConfig { seed, fmt, intra_threads, backend, ..Default::default() },
            run,
        ),
        "mlp" => run_native_train(
            MlpConfig { seed, fmt, intra_threads, backend, ..Default::default() },
            run,
        ),
        other => bail!("--native supports apps dlrm, gpt-nano and mlp, got {other:?}"),
    }
}

/// The app-generic body of `train --native` — one function for every
/// [`Task`](bf16_train::qsim::Task), which is the point of the engine.
fn run_native_train<T>(task: T, run: NativeRun) -> Result<()>
where
    T: bf16_train::qsim::Task + Clone + Send + 'static,
{
    if run.shards > 0 {
        return run_native_train_sharded(task, run);
    }
    let mut tr = bf16_train::qsim::train::Trainer::new(task, run.mode)
        .with_grad_accum(run.grad_accum.max(1));
    if let Some(path) = &run.resume {
        tr.load_checkpoint(path)?;
        println!("resumed from {path} at step {}", tr.steps_done());
    }
    let remaining = run.steps.saturating_sub(tr.steps_done());
    let t0 = std::time::Instant::now();
    let mut last_loss = f32::NAN;
    for _ in 0..remaining {
        last_loss = tr.step(run.lr as f32).loss;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = tr.eval(run.eval_batches as usize);
    println!(
        "done: eval loss={:.4} {}={:.4}  train-loss={:.4}  ({} steps, {:.1} steps/s)",
        m.loss,
        m.metric_name,
        m.metric,
        last_loss,
        remaining,
        if dt > 0.0 { remaining as f64 / dt } else { 0.0 }
    );
    if let Some(path) = &run.checkpoint {
        tr.save_checkpoint(path)?;
        println!("checkpoint: {path} (step {})", tr.steps_done());
    }
    Ok(())
}

/// `train --native --shards N`: the same run on the data-parallel
/// `qsim::shard` engine — bit-identical results at every power-of-two
/// shard count and under any `--chaos` schedule; recovery counters are
/// reported on stderr.
fn run_native_train_sharded<T>(task: T, run: NativeRun) -> Result<()>
where
    T: bf16_train::qsim::Task + Clone + Send + 'static,
{
    use bf16_train::qsim::{ShardOptions, ShardedTrainer};

    let opts = ShardOptions {
        shards: run.shards,
        microbatches: run.grad_accum,
        chaos: chaos_plan(run.chaos.as_deref())?,
        ..Default::default()
    };
    let mut tr = ShardedTrainer::new(task, run.mode, opts)?;
    if let Some(path) = &run.resume {
        tr.load_checkpoint(path)?;
        println!("resumed from {path} at step {}", tr.steps_done());
    }
    let remaining = run.steps.saturating_sub(tr.steps_done());
    let t0 = std::time::Instant::now();
    let mut last_loss = f32::NAN;
    for _ in 0..remaining {
        last_loss = tr.step(run.lr as f32).loss;
    }
    let dt = t0.elapsed().as_secs_f64();
    let m = tr.eval(run.eval_batches as usize);
    println!(
        "done: eval loss={:.4} {}={:.4}  train-loss={:.4}  ({} steps x {} microbatches, {:.1} steps/s)",
        m.loss,
        m.metric_name,
        m.metric,
        last_loss,
        remaining,
        tr.microbatches(),
        if dt > 0.0 { remaining as f64 / dt } else { 0.0 }
    );
    let st = tr.stats();
    eprintln!(
        "shards {}: retries {} respawns {} crc-rejects {} stale {} nacks {} \
         drift-resyncs {} updates-dropped {} stragglers {}",
        tr.shards(),
        st.retries,
        st.respawns,
        st.crc_rejects,
        st.stale_frames,
        st.nacks,
        st.drift_resyncs,
        st.updates_dropped,
        st.stragglers
    );
    if let Some(path) = &run.checkpoint {
        tr.save_checkpoint(path)?;
        println!("checkpoint: {path} (step {})", tr.steps_done());
    }
    Ok(())
}

fn cmd_exp(args: &mut Args) -> Result<()> {
    let id = args.pos(1).unwrap_or("all").to_string();
    let mut opts = ExpOptions {
        steps: args.opt_maybe("steps").map(|s| s.parse()).transpose()?,
        seeds: args.opt_u64("seeds", 3)?,
        out_dir: args.opt("out", "results"),
        artifacts_dir: args.opt("artifacts", "artifacts"),
        smooth: 0.15,
        threads: args
            .opt_maybe("threads")
            .map(|s| s.parse::<usize>().with_context(|| format!("--threads expects an integer, got {s:?}")))
            .transpose()?,
        intra_threads: args
            .opt_maybe("intra-threads")
            .map(|s| {
                s.parse::<usize>()
                    .with_context(|| format!("--intra-threads expects an integer, got {s:?}"))
            })
            .transpose()?,
    };
    if args.flag("no-smooth") {
        opts.smooth = 1.0; // Figure 6: unsmoothed curves
    }
    let only_app = args.opt_maybe("app");
    args.finish()?;

    // PJRT runtime is only created when an experiment needs it.  Surface
    // the reason it is unavailable (missing artifacts vs a build without
    // the `pjrt` feature) instead of swallowing it.
    let runner = match Runner::open(&opts.artifacts_dir) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("note: PJRT runtime unavailable ({e:#}); native experiments only");
            None
        }
    };

    let ids: Vec<&str> = if id == "all" {
        ALL_EXPERIMENTS.to_vec()
    } else {
        vec![id.as_str()]
    };
    for id in ids {
        eprintln!("=== experiment {id} ===");
        let rendered = run_experiment(id, runner.as_ref(), &opts, only_app.as_deref())?;
        println!("{rendered}");
    }
    println!("results written to {}/", opts.out_dir);
    Ok(())
}

fn cmd_bench_step(args: &mut Args) -> Result<()> {
    let name = args.pos(1).context("bench-step needs an artifact name")?.to_string();
    let iters = args.opt_u64("iters", 200)?;
    let intra_threads = args.opt_u64("intra-threads", 1)? as usize;
    let dir = args.opt("artifacts", "artifacts");
    args.finish()?;
    let (app, policy) = Policy::parse_artifact_name(&name)?;
    // Budget warmup + timed iters so the timed region runs mid-schedule
    // (WarmupLinear decays to 0 once steps_done exceeds cfg.steps).
    let warmup = iters.min(20);
    let spec = RunSpec::new(&app)
        .policy(policy)
        .steps(warmup + iters)
        .intra_threads(intra_threads)
        .artifacts_dir(&dir);
    let runner = Runner::open(&dir)?;
    let mut tr = runner.trainer(&spec)?;
    tr.run_steps(warmup)?;
    let t0 = std::time::Instant::now();
    tr.run_steps(iters)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "{name}: {iters} steps in {dt:.3}s  =>  {:.2} ms/step, {:.1} steps/s",
        dt * 1000.0 / iters as f64,
        iters as f64 / dt
    );
    Ok(())
}

/// Deterministic digest of native qsim training runs (DLRM, the gpt-nano
/// transformer LM, the spiral-MLP classifier — all through the generic
/// `qsim::train` engine — plus the scalar least-squares probe): per-step
/// loss bit patterns and cancellation counters, plus a final eval.
/// Contains no timings, so the output must be byte-identical across
/// `--intra-threads` settings *and* across
/// `--backend fast|reference|simd` — the CI determinism and simd jobs
/// diff all of them.
fn cmd_qsim_parity(args: &mut Args) -> Result<()> {
    use bf16_train::qsim::dlrm::{DlrmConfig, DlrmTrainer};
    use bf16_train::qsim::gpt::{GptConfig, GptTrainer};
    use bf16_train::qsim::mlp::{MlpConfig, MlpTrainer};
    use bf16_train::qsim::Backend;

    let steps = args.opt_u64("steps", 40)?;
    let seed = args.opt_u64("seed", 17)?;
    let intra_threads = args.opt_u64("intra-threads", 1)? as usize;
    let app = args.opt("app", "all");
    if !matches!(app.as_str(), "all" | "dlrm" | "gpt" | "gpt-nano" | "mlp" | "lsq") {
        bail!("--app must be all, dlrm, gpt, mlp or lsq, got {app:?}");
    }
    let backend = match args.opt("backend", "fast").as_str() {
        "fast" => Backend::Fast,
        "reference" => Backend::Reference,
        "simd" => Backend::Simd,
        other => bail!("--backend must be fast, reference or simd, got {other:?}"),
    };
    let shards = args.opt_u64("shards", 0)? as usize;
    let grad_accum = args.opt_u64("grad-accum", 4)? as usize;
    let chaos = args.opt_maybe("chaos");
    args.finish()?;
    if shards > 0 {
        if app == "lsq" {
            bail!("the sharded engine drives the Task apps; --app lsq has no shard path");
        }
        return qsim_parity_sharded(&app, steps, seed, shards, grad_accum, chaos.as_deref());
    }
    if chaos.is_some() {
        bail!("--chaos injects faults into shard workers; add --shards N");
    }
    eprintln!(
        "qsim-parity: {steps} steps, seed {seed}, {intra_threads} intra-threads, {} backend",
        backend.name()
    );
    if app == "all" || app == "dlrm" {
        for mode in [Mode::Sr16, Mode::SrKahan16] {
            let cfg = DlrmConfig {
                seed,
                // large enough that the parallel kernels actually engage
                table_size: 600,
                embed_dim: 16,
                hidden: 64,
                batch: 48,
                backend,
                intra_threads,
                ..Default::default()
            };
            let mut tr = DlrmTrainer::new(cfg, mode);
            for step in 0..steps {
                let tel = tr.step(0.05);
                println!(
                    "dlrm {} step {step}: loss {:08x} embed {}/{} mlp {}/{}",
                    mode.name(),
                    tel.loss.to_bits(),
                    tel.embed.cancelled,
                    tel.embed.nonzero,
                    tel.mlp.cancelled,
                    tel.mlp.nonzero
                );
            }
            let m = tr.eval(4);
            println!(
                "dlrm {} final: eval-loss {:08x} auc {:08x}",
                mode.name(),
                m.loss.to_bits(),
                m.metric.to_bits()
            );
        }
    }
    if app == "all" || app == "gpt" || app == "gpt-nano" {
        for mode in [Mode::Fp32, Mode::Standard16, Mode::Sr16, Mode::Kahan16] {
            let cfg = GptConfig {
                seed,
                // large enough that the attention/matmul fan-outs engage
                vocab: 64,
                seq_len: 16,
                dim: 32,
                hidden: 64,
                batch: 8,
                backend,
                intra_threads,
                ..Default::default()
            };
            let mut tr = GptTrainer::new(cfg, mode);
            for step in 0..steps {
                let tel = tr.step(0.1);
                let stats = tel.total();
                println!(
                    "gpt-nano {} step {step}: loss {:08x} upd {}/{}",
                    mode.name(),
                    tel.loss.to_bits(),
                    stats.cancelled,
                    stats.nonzero
                );
            }
            let eval_loss = tr.eval(4).loss;
            println!("gpt-nano {} final: eval-loss {:08x}", mode.name(), eval_loss.to_bits());
        }
    }
    if app == "all" || app == "mlp" {
        for mode in [Mode::Fp32, Mode::Standard16, Mode::Sr16, Mode::Kahan16] {
            let cfg = MlpConfig {
                seed,
                // large enough that the matmul fan-outs engage
                hidden: 96,
                batch: 64,
                backend,
                intra_threads,
                ..Default::default()
            };
            let mut tr = MlpTrainer::new(cfg, mode);
            for step in 0..steps {
                let tel = tr.step(0.1);
                let stats = tel.total();
                println!(
                    "mlp {} step {step}: loss {:08x} upd {}/{}",
                    mode.name(),
                    tel.loss.to_bits(),
                    stats.cancelled,
                    stats.nonzero
                );
            }
            let m = tr.eval(4);
            println!(
                "mlp {} final: eval-loss {:08x} acc {:08x}",
                mode.name(),
                m.loss.to_bits(),
                m.metric.to_bits()
            );
        }
    }
    if app == "all" || app == "lsq" {
        use bf16_train::qsim::lsq::{self, LsqConfig, LsqData, Placement};
        // lsq trains outside the tape (hand-rolled scalar SGD), so its
        // digest must be backend- and thread-invariant by construction —
        // diffing it pins the shared dataset and placement sweep too.
        let cfg = LsqConfig { seed, steps: 2_000, ..Default::default() };
        let data = LsqData::generate(&cfg);
        for placement in [
            Placement::Exact,
            Placement::WeightUpdate,
            Placement::WeightUpdateSr,
            Placement::ForwardBackward,
            Placement::Everywhere,
        ] {
            let run = lsq::run(&cfg, &data, placement);
            // FNV-1a over the sampled loss bit patterns
            let mut h = 0xcbf29ce484222325u64;
            for l in &run.losses {
                h = (h ^ l.to_bits() as u64).wrapping_mul(0x100000001b3);
            }
            println!(
                "lsq {} final: dist {:08x} halt {:08x} losses {:016x}",
                placement.name(),
                run.final_dist.to_bits(),
                run.halt_frac.to_bits(),
                h
            );
        }
    }
    Ok(())
}

/// The sharded branch of `qsim-parity`: the same digest discipline (per
/// step loss bit patterns + cancellation counters + a final eval, no
/// timings) over the `qsim::shard` engine.  Crucially the output contains
/// neither the shard count nor the chaos schedule, because the whole
/// contract is that they cannot change a bit of it: CI diffs this digest
/// across `--shards 1|2|4` and with `--chaos heavy` injected.  Recovery
/// counters go to stderr.
fn qsim_parity_sharded(
    app: &str,
    steps: u64,
    seed: u64,
    shards: usize,
    grad_accum: usize,
    chaos: Option<&str>,
) -> Result<()> {
    use bf16_train::qsim::dlrm::DlrmConfig;
    use bf16_train::qsim::gpt::GptConfig;
    use bf16_train::qsim::mlp::MlpConfig;

    eprintln!(
        "qsim-parity (sharded): {steps} steps x {grad_accum} microbatches, seed {seed}, \
         {shards} shards, chaos {}",
        chaos.unwrap_or("none")
    );
    if app == "all" || app == "dlrm" {
        for mode in [Mode::Sr16, Mode::SrKahan16] {
            let cfg = DlrmConfig {
                seed,
                table_size: 600,
                embed_dim: 16,
                hidden: 64,
                batch: 48,
                ..Default::default()
            };
            sharded_parity_run("dlrm", cfg, mode, steps, 0.05, shards, grad_accum, chaos)?;
        }
    }
    if app == "all" || app == "gpt" || app == "gpt-nano" {
        let cfg = GptConfig {
            seed,
            vocab: 64,
            seq_len: 16,
            dim: 32,
            hidden: 64,
            batch: 8,
            ..Default::default()
        };
        sharded_parity_run("gpt-nano", cfg, Mode::Sr16, steps, 0.1, shards, grad_accum, chaos)?;
    }
    if app == "all" || app == "mlp" {
        for mode in [Mode::Sr16, Mode::Kahan16] {
            let cfg = MlpConfig { seed, hidden: 96, batch: 64, ..Default::default() };
            sharded_parity_run("mlp", cfg, mode, steps, 0.1, shards, grad_accum, chaos)?;
        }
    }
    Ok(())
}

/// One (app, mode) sharded parity run.  A fresh [`ChaosPlan`] per run so
/// the schedule a cell hosts is a pure function of the spec, never of
/// which apps ran before it.
#[allow(clippy::too_many_arguments)]
fn sharded_parity_run<T>(
    label: &str,
    task: T,
    mode: Mode,
    steps: u64,
    lr: f32,
    shards: usize,
    grad_accum: usize,
    chaos: Option<&str>,
) -> Result<()>
where
    T: bf16_train::qsim::Task + Clone + Send + 'static,
{
    use bf16_train::qsim::{ShardOptions, ShardedTrainer};

    let opts = ShardOptions {
        shards,
        microbatches: grad_accum,
        chaos: chaos_plan(chaos)?,
        ..Default::default()
    };
    let mut tr = ShardedTrainer::new(task, mode, opts)?;
    for step in 0..steps {
        let tel = tr.step(lr);
        println!(
            "{label} {} step {step}: loss {:08x} embed {}/{} mlp {}/{}",
            mode.name(),
            tel.loss.to_bits(),
            tel.embed.cancelled,
            tel.embed.nonzero,
            tel.mlp.cancelled,
            tel.mlp.nonzero
        );
    }
    let m = tr.eval(4);
    println!(
        "{label} {} final: eval-loss {:08x} {} {:08x}",
        mode.name(),
        m.loss.to_bits(),
        m.metric_name,
        m.metric.to_bits()
    );
    let st = tr.stats();
    eprintln!(
        "{label} {}: retries {} respawns {} crc-rejects {} stale {} nacks {} \
         drift-resyncs {} updates-dropped {} stragglers {}",
        mode.name(),
        st.retries,
        st.respawns,
        st.crc_rejects,
        st.stale_frames,
        st.nacks,
        st.drift_resyncs,
        st.updates_dropped,
        st.stragglers
    );
    Ok(())
}

/// Export one recorded training-step graph as a `qsim::verify` program,
/// lint it, and audit free-pool accounting across `reset()`.  Returns
/// `true` when the app's tape is unhealthy (lint errors or leaked
/// buffers) so the caller can fail the process.
fn report_tape_lint(
    name: &str,
    t: &mut bf16_train::qsim::Tape,
    loss: bf16_train::qsim::Var,
    n_params: usize,
) -> bool {
    use bf16_train::qsim::verify;

    let prog = t.export_program();
    let report = verify::lint(&prog, loss.0);
    let (errors, warnings, infos) = report.counts();
    println!(
        "{name}: {} tape nodes, {n_params} param tensors — {errors} errors, \
         {warnings} warnings, {infos} infos",
        prog.nodes.len()
    );
    if !report.is_clean() {
        print!("{report}");
    }
    t.reset();
    let (pool_bufs, outstanding) = t.pool_stats();
    println!("{name}: free-pool after reset: {pool_bufs} buffers pooled, {outstanding} outstanding");
    if outstanding != 0 {
        println!(
            "{name}: FREE-POOL ACCOUNTING VIOLATION: {outstanding} buffer(s) \
             taken from the pool were never returned by reset()"
        );
    }
    errors > 0 || outstanding != 0
}

/// Run the static dither-key collision lint over one app's coordinates.
fn report_dither_lint(name: &str, coords: &[bf16_train::qsim::verify::DitherCoord]) -> bool {
    use bf16_train::qsim::verify;

    let rep = verify::lint_dither_coords(coords);
    let errors = rep.errors().len();
    println!("{name}: {} dither coordinates, {errors} collisions", coords.len());
    if !rep.is_clean() {
        print!("{rep}");
    }
    errors > 0
}

/// Build + backward one real training step for a [`Task`] app and lint it,
/// plus the app's real optimizer-bank dither coordinates.
fn lint_task_graph<T: bf16_train::qsim::Task>(task: T) -> bool {
    use bf16_train::precision::Mode as PMode;
    use bf16_train::qsim::train::Trainer;
    use bf16_train::qsim::verify::DitherCoord;
    use bf16_train::qsim::{QPolicy, Tape};

    // The coordinates come from the real optimizer bank the trainer
    // builds (one SGD per tensor), not a re-derivation of its layout.
    let tr = Trainer::new(task, PMode::Sr16);
    let coords: Vec<DitherCoord> = tr
        .dither_coords()
        .into_iter()
        .enumerate()
        .map(|(i, (stream, tid))| DitherCoord::new(format!("sgd:w{i}"), stream, tid))
        .collect();
    let task = tr.task;

    let policy = QPolicy::with_backend(task.fmt(), task.backend());
    let model = task.init_model();
    let mut gen = task.make_gen();
    let batch = T::next_batch(&mut gen);
    let mut t = Tape::new(policy);
    let (loss, params) = T::forward_into(&model, &mut t, &batch);
    t.backward(loss);
    report_tape_lint(T::NAME, &mut t, loss, params.len()) | report_dither_lint(T::NAME, &coords)
}

/// `lsq` trains outside the tape (hand-rolled SGD over `w`), so lint the
/// equivalent recorded graph: `x @ w` against targets under the fused MSE
/// loss — same shapes, same ops the tape would record for it.
fn lint_lsq_graph(seed: u64) -> bool {
    use bf16_train::qsim::lsq::{LsqConfig, LsqData};
    use bf16_train::qsim::{QPolicy, Tape, Tensor};

    let cfg = LsqConfig { seed, ..Default::default() };
    let data = LsqData::generate(&cfg);
    let batch = cfg.n_samples.min(64);
    let mut t = Tape::new(QPolicy::exact());
    let x = t.input(Tensor::from_vec(batch, cfg.dim, data.xs[..batch * cfg.dim].to_vec()));
    let y = t.input(Tensor::from_vec(batch, 1, data.ys[..batch].to_vec()));
    let w = t.param(Tensor::zeros(cfg.dim, 1));
    let pred = t.matmul(x, w);
    let loss = t.mse_loss(pred, y);
    t.backward(loss);
    let (stream, tid) = bf16_train::qsim::lsq::dither_coord();
    let coords = vec![bf16_train::qsim::verify::DitherCoord::new("lsq:w", stream, tid)];
    report_tape_lint("lsq", &mut t, loss, 1) | report_dither_lint("lsq", &coords)
}

/// `repro lint-tape` — static analysis of each app's real training graph.
fn cmd_lint_tape(args: &mut Args) -> Result<()> {
    use bf16_train::qsim::dlrm::DlrmConfig;
    use bf16_train::qsim::gpt::GptConfig;
    use bf16_train::qsim::mlp::MlpConfig;

    let app = args.opt("app", "all");
    let seed = args.opt_u64("seed", 17)?;
    args.finish()?;
    if !matches!(app.as_str(), "all" | "dlrm" | "gpt" | "gpt-nano" | "mlp" | "lsq") {
        bail!("--app must be all, dlrm, gpt, mlp or lsq, got {app:?}");
    }
    let mut unhealthy = false;
    if app == "all" || app == "dlrm" {
        unhealthy |= lint_task_graph(DlrmConfig { seed, ..Default::default() });
    }
    if app == "all" || app == "gpt" || app == "gpt-nano" {
        unhealthy |= lint_task_graph(GptConfig { seed, ..Default::default() });
    }
    if app == "all" || app == "mlp" {
        unhealthy |= lint_task_graph(MlpConfig { seed, ..Default::default() });
    }
    if app == "all" || app == "lsq" {
        unhealthy |= lint_lsq_graph(seed);
    }
    if unhealthy {
        bail!("lint-tape found structural errors (see diagnostics above)");
    }
    println!("lint-tape: all checked graphs structurally clean");
    Ok(())
}

/// `repro fuzz-tape` — enumerative differential fuzzing of the tape.
fn cmd_fuzz_tape(args: &mut Args) -> Result<()> {
    use bf16_train::qsim::verify::{fuzz, gen};

    let budget = args.opt_u64("budget", 200)?;
    let seed = args.opt_u64("seed", 1)?;
    let case = args
        .opt_maybe("case")
        .map(|s| {
            s.parse::<u64>()
                .with_context(|| format!("--case expects an integer, got {s:?}"))
        })
        .transpose()?;
    args.finish()?;

    if let Some(i) = case {
        // Replay one case verbosely (the FUZZ-REPRO workflow).
        let c = gen::gen_case(seed, i);
        println!("FUZZ-REPRO seed={seed} case={i} — program:");
        print!("{}", c.program);
        return match fuzz::check_case(&c) {
            Ok(stats) => {
                println!(
                    "PASS: {} parity/gradient/rewrite checks, {} rewrites validated",
                    stats.checks, stats.rewrites
                );
                Ok(())
            }
            Err(e) => bail!("FAIL: {e}"),
        };
    }

    let fmt_names: Vec<&str> = fuzz::sweep_formats().iter().map(|f| f.name).collect();
    println!(
        "fuzz-tape: seed={seed} budget={budget} formats=[{}] backends=[fast, reference, simd] threads=[1, 4]",
        fmt_names.join(", ")
    );
    let out = fuzz::run(seed, budget);
    match &out.failure {
        None => {
            println!(
                "PASS: {} cases, {} checks ({} rewrite admissions proven bit-identical)",
                out.cases_run, out.checks_run, out.rewrites_validated
            );
            Ok(())
        }
        Some(f) => {
            println!("FAIL after {} clean cases:\n{}", out.cases_run, f.render());
            bail!("fuzz-tape found a divergence; replay with: repro fuzz-tape --seed {} --case {}",
                f.seed, f.case)
        }
    }
}

/// `repro synth-rules` — Ruler-style rewrite-rule synthesis over the tape
/// IR, plus corpus regeneration (`--write`) and drift-checking (`--check`).
fn cmd_synth_rules(args: &mut Args) -> Result<()> {
    use std::collections::BTreeSet;

    use bf16_train::qsim::verify::rewrite;
    use bf16_train::qsim::verify::synth::{self, SynthConfig};

    let check = args.flag("check");
    let write = args.flag("write");
    let corpus = rewrite::corpus_doc()
        .map_err(|e| anyhow::anyhow!("checked-in synth_rules.txt is invalid: {e}"))?;
    let depth = args.opt_u64("depth", corpus.depth as u64)? as usize;
    let seed = args.opt_u64("seed", corpus.seed)?;
    args.finish()?;
    if check && (depth != corpus.depth || seed != corpus.seed) {
        bail!(
            "--check re-synthesizes at the corpus coordinates (depth={} seed={}); \
             drop --depth/--seed or regenerate with --write first",
            corpus.depth,
            corpus.seed
        );
    }
    let cfg = SynthConfig::at(depth, seed);
    println!(
        "synth-rules: depth={depth} seed={seed} vars={} cvec-valuations={} \
         admission={{fp32,bf16,fp16,e8m5}} x {{fast,reference,simd}} x {{1,4}} threads \
         x {} fresh valuations",
        synth::VAR_SHAPES.len(),
        cfg.cvec_valuations,
        cfg.admit_valuations
    );
    let report = synth::synthesize(&cfg);
    println!(
        "enumerated {} terms ({} dropped by the per-level cap, {} failed evaluation) \
         -> {} non-trivial clusters -> {} candidate rules ({} over per-cluster/ruleset caps)",
        report.enumerated,
        report.dropped,
        report.eval_failed,
        report.clusters,
        report.candidates,
        report.capped
    );
    for (rule, why) in &report.rejected {
        println!("rejected: {rule}\n          {why}");
    }
    for rule in &report.derived {
        println!("derived (instance of smaller admitted rules, skipped): {rule}");
    }
    println!(
        "admitted {} rules ({} bit-identity cells proven):",
        report.admitted.len(),
        report.admission_cells
    );
    for r in &report.admitted {
        println!("  {}", r.render());
    }

    if write {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/synth_rules.txt");
        std::fs::write(path, report.corpus().render())
            .with_context(|| format!("writing {path}"))?;
        println!("wrote {} rules to {path}", report.admitted.len());
        return Ok(());
    }

    if check {
        // 1. Every checked-in rule must still prove at the admission seed.
        for r in &corpus.rules {
            rewrite::validate_rule(r, synth::admission_seed(corpus.seed), cfg.admit_valuations)
                .map_err(|e| anyhow::anyhow!("corpus rule `{}` no longer proves: {e}", r.name))?;
        }
        println!("corpus: all {} checked-in rules re-proven", corpus.rules.len());
        // 2. Containment drift gate: the corpus is the *pinned, reviewed*
        //    subset of what synthesis admits, so every pinned rule must
        //    still come out of a fresh run.  Extra fresh rules are not
        //    drift — they are surfaced for review and land via --write.
        let fresh: BTreeSet<String> = report.admitted.iter().map(|r| r.render()).collect();
        let pinned: BTreeSet<String> = corpus.rules.iter().map(|r| r.render()).collect();
        let lost: Vec<&String> = pinned.difference(&fresh).collect();
        if !lost.is_empty() {
            for r in lost {
                println!("drift: checked-in rule no longer synthesized: {r}");
            }
            bail!("synth-rules --check: ruleset drift (regenerate with --write and review)");
        }
        for r in fresh.difference(&pinned) {
            println!("unpinned (admitted fresh, not in corpus; vet and --write to pin): {r}");
        }
        // 3. Regression gate: the hand-written PR-6 rules must be
        //    rediscovered, alongside at least two genuinely new ones.
        for name in ["fuse-affine", "fuse-affine-relu"] {
            if !report.admitted.iter().any(|r| r.name == name) {
                bail!("synth-rules --check: canonical rule `{name}` was not rediscovered");
            }
        }
        if report.admitted.len() < 4 {
            bail!(
                "synth-rules --check: only {} admitted rules (need the 2 canonical + >=2 new)",
                report.admitted.len()
            );
        }
        println!(
            "synth-rules --check: corpus re-proven, every pinned rule re-synthesized, no drift"
        );
    }
    Ok(())
}

/// `repro serve` — load a checkpoint into a frozen model and serve it
/// through the tape-free compiled inference plan with async dynamic
/// micro-batching (`qsim::infer`).
fn cmd_serve(args: &mut Args) -> Result<()> {
    let ckpt_path = args.opt_maybe("ckpt");
    let file_cfg = args
        .opt_maybe("config")
        .map(|p| RunConfig::from_toml_file(&p))
        .transpose()?;
    let mut serve = file_cfg.as_ref().map(|c| c.serve.clone()).unwrap_or_default();
    let mut policy = file_cfg.as_ref().map(|c| c.policy).unwrap_or_default();
    if let Some(m) = args.opt_maybe("mode") {
        policy = Policy::new(m.parse::<Mode>()?, policy.fmt);
    }
    if let Some(f) = args.opt_maybe("fmt") {
        let fmt = Format::by_name(&f).with_context(|| format!("--fmt {f:?} is not a known format"))?;
        policy = Policy::new(policy.mode, fmt);
    }
    let seed = args.opt_u64("seed", file_cfg.as_ref().map(|c| c.seed).unwrap_or(0))?;
    if let Some(a) = args.opt_maybe("addr") {
        if !a.contains(':') {
            bail!("--addr {a:?} must be host:port");
        }
        serve.addr = a;
    }
    serve.batch_window_us = args.opt_u64("batch-window", serve.batch_window_us)?;
    let max_batch = args.opt_u64("max-batch", serve.max_batch as u64)?;
    if max_batch < 1 {
        bail!("--max-batch must be >= 1, got {max_batch}");
    }
    serve.max_batch = max_batch as usize;
    if let Some(b) = args.opt_maybe("backend") {
        serve.backend = bf16_train::qsim::Backend::by_name(&b)
            .with_context(|| format!("--backend {b:?} (expected fast, reference or simd)"))?;
    }
    args.finish()?;
    let ckpt_path = ckpt_path.context("serve needs --ckpt FILE (a BF16CKP2 checkpoint)")?;

    let bytes = std::fs::read(&ckpt_path)
        .with_context(|| format!("reading checkpoint {ckpt_path:?}"))?;
    let app_name = bf16_train::util::ckpt::peek_app_name(&bytes)
        .with_context(|| format!("checkpoint {ckpt_path:?}"))?;
    let (app, qpolicy) =
        load_serve_app(&app_name, &bytes, policy.mode, policy.fmt, seed, serve.backend)
            .with_context(|| format!("checkpoint {ckpt_path:?}"))?;
    println!(
        "serve {app_name} | window {}us max-batch {} [{} {} on {} backend]",
        serve.batch_window_us,
        serve.max_batch,
        policy.mode,
        policy.fmt.name,
        serve.backend.name()
    );
    let handle = bf16_train::qsim::infer::spawn_server(app, qpolicy, &serve)?;
    println!("serving {app_name} at {} (send `shutdown` to stop)", handle.addr());
    handle.join();
    println!("server stopped");
    Ok(())
}

/// Rebuild the trainer a checkpoint came from and freeze its model for
/// serving.  Configs are constructed exactly as `train --native` builds
/// them, so a checkpoint saved there passes the fingerprint check here;
/// custom-sized runs load through the same `--config` they trained with.
fn load_serve_app(
    app: &str,
    ckpt: &[u8],
    mode: Mode,
    fmt: Format,
    seed: u64,
    backend: bf16_train::qsim::Backend,
) -> Result<(bf16_train::qsim::ServeApp, bf16_train::qsim::QPolicy)> {
    use bf16_train::qsim::dlrm::DlrmConfig;
    use bf16_train::qsim::gpt::GptConfig;
    use bf16_train::qsim::train::Trainer;
    use bf16_train::qsim::ServeApp;

    let intra_threads = 1usize;
    match app {
        "dlrm" => {
            let cfg = DlrmConfig { seed, fmt, intra_threads, backend, ..Default::default() };
            let mut tr = Trainer::new(cfg, mode);
            tr.load_checkpoint_bytes(ckpt)?;
            let policy = tr.policy();
            Ok((ServeApp::Dlrm(Box::new(tr.model)), policy))
        }
        "gpt-nano" => {
            let cfg = GptConfig { seed, fmt, intra_threads, backend, ..Default::default() };
            let mut tr = Trainer::new(cfg, mode);
            tr.load_checkpoint_bytes(ckpt)?;
            let policy = tr.policy();
            Ok((ServeApp::Gpt(Box::new(tr.model)), policy))
        }
        other => bail!("serve supports dlrm and gpt-nano checkpoints, got {other:?}"),
    }
}

fn read_corpus(path: &str) -> Result<Vec<String>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading corpus {path:?}"))?;
    let lines: Vec<String> = text
        .lines()
        .filter(|l| !l.trim().is_empty() && !l.trim_start().starts_with('#'))
        .map(|l| l.to_string())
        .collect();
    if lines.is_empty() {
        bail!("corpus {path:?} has no request lines");
    }
    Ok(lines)
}

/// `repro serve-bench` — three modes: the default in-process suite
/// (writes `BENCH_serve.json`), `--connect` (drive a corpus against a
/// running server, print its reply digest), and `--oracle` (compute the
/// same digest from the checkpoint via per-request tape evals; CI diffs
/// the two).
fn cmd_serve_bench(args: &mut Args) -> Result<()> {
    use bf16_train::qsim::infer;

    if let Some(addr) = args.opt_maybe("connect") {
        let app = args.opt("app", "dlrm");
        let corpus_path = args.opt_maybe("corpus").context("--connect needs --corpus FILE")?;
        let clients = (args.opt_u64("clients", 4)? as usize).max(1);
        let shutdown = args.flag("shutdown");
        args.finish()?;
        let corpus = read_corpus(&corpus_path)?;
        let report = infer::run_load(&addr, &corpus, clients)?;
        println!(
            "serve-load {app}: {} requests x {clients} clients  p50 {:.3} ms  p99 {:.3} ms  \
             {:.1} qps",
            corpus.len(),
            report.percentile_ns(0.50) as f64 / 1e6,
            report.percentile_ns(0.99) as f64 / 1e6,
            report.qps()
        );
        println!("digest {app} {:016x}", report.digest());
        if shutdown {
            use std::io::{BufRead, BufReader, Write};
            let mut s = infer::connect_retry(&addr)?;
            s.write_all(b"shutdown\n")?;
            let mut reply = String::new();
            BufReader::new(&mut s).read_line(&mut reply)?;
            println!("shutdown: {}", reply.trim_end());
        }
        return Ok(());
    }

    if args.flag("oracle") {
        let ckpt_path = args.opt_maybe("ckpt").context("--oracle needs --ckpt FILE")?;
        let corpus_path = args.opt_maybe("corpus").context("--oracle needs --corpus FILE")?;
        let mut policy = Policy::default();
        if let Some(m) = args.opt_maybe("mode") {
            policy = Policy::new(m.parse::<Mode>()?, policy.fmt);
        }
        if let Some(f) = args.opt_maybe("fmt") {
            let fmt =
                Format::by_name(&f).with_context(|| format!("--fmt {f:?} is not a known format"))?;
            policy = Policy::new(policy.mode, fmt);
        }
        let seed = args.opt_u64("seed", 0)?;
        args.finish()?;
        let bytes = std::fs::read(&ckpt_path)
            .with_context(|| format!("reading checkpoint {ckpt_path:?}"))?;
        let app_name = bf16_train::util::ckpt::peek_app_name(&bytes)?;
        let (app, qpolicy) = load_serve_app(
            &app_name,
            &bytes,
            policy.mode,
            policy.fmt,
            seed,
            bf16_train::qsim::Backend::Fast,
        )?;
        let corpus = read_corpus(&corpus_path)?;
        let replies = infer::tape_oracle_replies(&app, qpolicy, &corpus);
        println!("digest {app_name} {:016x}", infer::reply_digest(&replies));
        return Ok(());
    }

    let smoke = std::env::var("QSIM_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let iters = args.opt_u64("iters", if smoke { 20 } else { 150 })? as usize;
    let requests = (args.opt_u64("requests", if smoke { 32 } else { 96 })? as usize).max(1);
    let out = args.opt("out", "BENCH_serve.json");
    args.finish()?;
    serve_bench_suite(iters.max(1), requests, &out)
}

/// The in-process serve-bench suite: compiled-plan vs tape-eval latency
/// per backend, then end-to-end serve p50/p99/QPS per backend x batch
/// window over a loopback server.
fn serve_bench_suite(iters: usize, requests: usize, out: &str) -> Result<()> {
    use bf16_train::qsim::dlrm::{CtrBatch, CtrGen, DlrmConfig, DlrmModel};
    use bf16_train::qsim::gpt::{GptConfig, GptModel, LmBatch, MarkovGen};
    use bf16_train::qsim::infer::{self, DlrmPlan, GptPlan, ServeApp, ServeConfig};
    use bf16_train::qsim::{Backend, QPolicy};
    use bf16_train::util::bench::{bench_n, black_box, write_bench_json, BenchResult};

    fn ctr_corpus(batch: &CtrBatch, n: usize, dd: usize) -> Vec<String> {
        (0..n)
            .map(|i| {
                let r = i % batch.dense.rows;
                let dense: Vec<String> =
                    batch.dense.data[r * dd..(r + 1) * dd].iter().map(|v| v.to_string()).collect();
                let cat: Vec<String> = batch.cat.iter().map(|c| c[r].to_string()).collect();
                format!("dlrm {} | {}", dense.join(" "), cat.join(" "))
            })
            .collect()
    }
    fn lm_corpus(batch: &LmBatch, n: usize, t_len: usize) -> Vec<String> {
        let seqs = batch.tokens.len() / t_len.max(1);
        (0..n)
            .map(|i| {
                let s = i % seqs.max(1);
                let len = 1 + (i * 7) % t_len;
                let toks: Vec<String> = batch.tokens[s * t_len..s * t_len + len]
                    .iter()
                    .map(|t| t.to_string())
                    .collect();
                format!("gpt {}", toks.join(" "))
            })
            .collect()
    }
    fn serve_rows(
        results: &mut Vec<BenchResult>,
        derived: &mut Vec<(String, f64)>,
        app: &str,
        backend: Backend,
        window: u64,
        report: &infer::LoadReport,
    ) {
        let n = report.latencies_ns.len().max(1);
        let p50 = report.percentile_ns(0.50) as f64;
        let row = BenchResult {
            name: format!("serve {app} {} w{window}", backend.name()),
            median_ns: p50,
            mean_ns: report.latencies_ns.iter().sum::<u64>() as f64 / n as f64,
            min_ns: report.latencies_ns.iter().copied().min().unwrap_or(0) as f64,
            samples: report.latencies_ns.len(),
        };
        println!("{}", row.report());
        let tag = format!("{app}_{}_w{window}", backend.name());
        derived.push((format!("p50_serve_{tag}_ns"), p50));
        derived.push((format!("p99_serve_{tag}_ns"), report.percentile_ns(0.99) as f64));
        derived.push((format!("qps_serve_{tag}"), report.qps()));
        results.push(row);
    }

    let mut results: Vec<BenchResult> = Vec::new();
    let mut derived: Vec<(String, f64)> = Vec::new();

    // compiled plan vs per-call tape eval (same batch, same policy)
    let dcfg = DlrmConfig { seed: 11, ..Default::default() };
    let dmodel = DlrmModel::init(&dcfg);
    let dbatch = CtrGen::new(&dcfg).next_batch();
    for backend in [Backend::Fast, Backend::Simd] {
        let policy = QPolicy::with_backend(dcfg.fmt, backend);
        let tape = bench_n(&format!("dlrm tape-eval {}", backend.name()), iters, || {
            black_box(dmodel.eval_scores(&dbatch, policy));
        });
        let mut plan = DlrmPlan::compile(&dmodel, &dbatch, policy);
        let fast = bench_n(&format!("dlrm infer-plan {}", backend.name()), iters, || {
            black_box(plan.score(&dbatch));
        });
        let key = match backend {
            Backend::Fast => "speedup_infer_vs_tape_dlrm".to_string(),
            _ => format!("speedup_infer_vs_tape_dlrm_{}", backend.name()),
        };
        derived.push((key, tape.median_ns / fast.median_ns.max(1.0)));
        results.push(tape);
        results.push(fast);
    }

    let gcfg = GptConfig { seed: 11, ..Default::default() };
    let gmodel = GptModel::init(&gcfg);
    let gbatch = MarkovGen::new(&gcfg).next_batch();
    for backend in [Backend::Fast, Backend::Simd] {
        let policy = QPolicy::with_backend(gcfg.fmt, backend);
        let tape = bench_n(&format!("gpt-nano tape-eval {}", backend.name()), iters, || {
            black_box(gmodel.eval_loss(&gbatch, policy));
        });
        let mut plan = GptPlan::compile(&gmodel, &gbatch, policy);
        let fast = bench_n(&format!("gpt-nano infer-plan {}", backend.name()), iters, || {
            black_box(plan.score(&gbatch));
        });
        let key = match backend {
            Backend::Fast => "speedup_infer_vs_tape_gpt".to_string(),
            _ => format!("speedup_infer_vs_tape_gpt_{}", backend.name()),
        };
        derived.push((key, tape.median_ns / fast.median_ns.max(1.0)));
        results.push(tape);
        results.push(fast);
    }

    // end-to-end serve latency over a loopback server
    let d_corpus = ctr_corpus(&dbatch, requests, dcfg.dense_dim);
    let g_corpus = lm_corpus(&gbatch, requests, gcfg.seq_len);
    for backend in [Backend::Fast, Backend::Simd] {
        for window in [0u64, 200] {
            let cfg = ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                batch_window_us: window,
                max_batch: 16,
                backend,
            };
            let policy = QPolicy::with_backend(dcfg.fmt, backend);
            let app = ServeApp::Dlrm(Box::new(DlrmModel::init(&dcfg)));
            let handle = infer::spawn_server(app, policy, &cfg)?;
            let report = infer::run_load(&handle.addr().to_string(), &d_corpus, 4)?;
            handle.shutdown()?;
            serve_rows(&mut results, &mut derived, "dlrm", backend, window, &report);

            let policy = QPolicy::with_backend(gcfg.fmt, backend);
            let app = ServeApp::Gpt(Box::new(GptModel::init(&gcfg)));
            let handle = infer::spawn_server(app, policy, &cfg)?;
            let report = infer::run_load(&handle.addr().to_string(), &g_corpus, 4)?;
            handle.shutdown()?;
            serve_rows(&mut results, &mut derived, "gpt-nano", backend, window, &report);
        }
    }

    write_bench_json(out, &results, &derived).with_context(|| format!("writing {out}"))?;
    println!("wrote {} bench rows + {} derived keys to {out}", results.len(), derived.len());
    Ok(())
}
