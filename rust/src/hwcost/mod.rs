//! Hardware cost model: FMAC unit costs (Table 1) and training-memory
//! footprints per precision mode (Table 2, Figure 5's x-axis, and the
//! Appendix-B.2 33% / 43% memory-saving claims).
//!
//! The FMAC numbers are normalized against a 32-bit FMAC using the
//! energy/area scaling of Horowitz (ISSCC'14) and Galal et al. (ARITH'13),
//! the sources the paper cites for its 3× power / 1.5× latency / 1.5× area
//! headline: multiplier cost scales ~quadratically with mantissa width,
//! adder/accumulator cost ~linearly.

use crate::precision::{Format, Mode};

/// Relative cost of one fused multiply-accumulate unit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FmacCost {
    /// multiply energy relative to fp32 multiply
    pub mul_energy: f64,
    /// accumulate energy relative to fp32 multiply
    pub acc_energy: f64,
    /// chip area relative to the fp32 FMAC
    pub area: f64,
    /// latency relative to the fp32 FMAC
    pub latency: f64,
}

/// Cost of an FMAC with `mul_fmt` multiply precision and a 32-bit
/// accumulator (the standard unit of Table 1).
pub fn fmac_cost(mul_fmt: Format) -> FmacCost {
    // mantissa multiplier dominates: cost ∝ (mant+1)^2; exponent/align adds
    // a linear term.  Normalised so fp32 == 1.0.
    let mant = (mul_fmt.mant_bits + 1) as f64;
    let fp32_mant = 24.0;
    let mul = (mant * mant) / (fp32_mant * fp32_mant);
    let align = mant / fp32_mant;
    let mul_energy = 0.85 * mul + 0.15 * align;
    // 32-bit accumulate is shared and cheap relative to a 32-bit multiply
    let acc_energy = 0.12;
    // area follows energy closely for multiplier arrays; the fixed
    // accumulator/control floor keeps 16-bit units at ~2/3 of fp32
    let area = (0.70 * mul_energy + 0.30_f64).min(1.0);
    // latency: shorter partial-product tree; paper cites 1.5× lower
    let latency = (0.55 + 0.45 * mul).min(1.0);
    FmacCost { mul_energy, acc_energy, area, latency }
}

/// Table 1 rendering: 16-bit vs 32-bit FMAC.
pub fn table1() -> Vec<(String, FmacCost)> {
    vec![
        ("32-bit FMAC".into(), fmac_cost(crate::precision::FP32)),
        ("16-bit FMAC (bf16)".into(), fmac_cost(crate::precision::BF16)),
    ]
}

/// Storage per weight (bytes) for one precision mode (Table 2 + App. B.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MemoryPlan {
    /// bytes per weight for the weights themselves
    pub weight_bytes: u32,
    /// additional master-copy bytes (mixed precision keeps both)
    pub master_bytes: u32,
    /// bytes per weight per optimizer-state tensor
    pub opt_state_bytes: u32,
    /// bytes per weight for the Kahan compensation buffer
    pub kahan_bytes: u32,
    /// whether a 32-bit FPU is required anywhere in training
    pub needs_fp32_fpu: bool,
}

/// Memory plan for a precision mode (exhaustive over the typed `Mode`).
pub fn memory_plan(mode: Mode) -> MemoryPlan {
    match mode {
        Mode::Fp32 => MemoryPlan {
            weight_bytes: 4,
            master_bytes: 0,
            opt_state_bytes: 4,
            kahan_bytes: 0,
            needs_fp32_fpu: true,
        },
        // mixed precision: 16-bit working weights + 32-bit master + 32-bit
        // optimizer states (Micikevicius et al.)
        Mode::Mixed16 => MemoryPlan {
            weight_bytes: 2,
            master_bytes: 4,
            opt_state_bytes: 4,
            kahan_bytes: 0,
            needs_fp32_fpu: true,
        },
        Mode::Standard16 | Mode::Sr16 => MemoryPlan {
            weight_bytes: 2,
            master_bytes: 0,
            opt_state_bytes: 2,
            kahan_bytes: 0,
            needs_fp32_fpu: false,
        },
        Mode::Kahan16 | Mode::SrKahan16 => MemoryPlan {
            weight_bytes: 2,
            master_bytes: 0,
            opt_state_bytes: 2,
            kahan_bytes: 2,
            needs_fp32_fpu: false,
        },
    }
}

/// Total training-state bytes for `n` weights under `mode` with `n_states`
/// optimizer-state tensors (SGD-momentum: 1, Adam: 2).
pub fn training_bytes(mode: Mode, n: u64, n_states: u32) -> u64 {
    let p = memory_plan(mode);
    n * (p.weight_bytes + p.master_bytes + p.opt_state_bytes * n_states + p.kahan_bytes)
        as u64
}

/// Weight-storage bytes for one parameter tensor of `elems` elements
/// trained under `mode`: the in-format weights plus any Kahan compensation
/// buffer (the quantities that scale with the *weight* count; optimizer
/// state is accounted separately via [`training_bytes`]).  This is the
/// per-tensor unit the generic `qsim::train::Trainer::weight_bytes` walk
/// sums — the accounting used to be hand-rolled inside the DLRM trainer
/// only; now every app's memory plan comes from the same `Module` param
/// walk.
pub fn tensor_weight_bytes(elems: u64, mode: Mode) -> u64 {
    let p = memory_plan(mode);
    elems * (p.weight_bytes + p.kahan_bytes) as u64
}

/// Figure 5's x-axis: bytes per weight when a fraction `kahan_frac` of the
/// model's weights use Kahan (rest stochastic rounding), Adam-free DLRM
/// (SGD, no momentum ⇒ no optimizer state).
pub fn mixed_kahan_bytes(n: u64, kahan_frac: f64) -> u64 {
    let kahan_n = (n as f64 * kahan_frac).round() as u64;
    let sr_n = n - kahan_n;
    sr_n * 2 + kahan_n * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::{BF16, FP32};

    #[test]
    fn table1_shape_matches_paper_headline() {
        let c32 = fmac_cost(FP32);
        let c16 = fmac_cost(BF16);
        assert!((c32.mul_energy - 1.0).abs() < 1e-9);
        // ≈3× power efficiency for the multiply
        let power_ratio = c32.mul_energy / c16.mul_energy;
        assert!(power_ratio > 2.5 && power_ratio < 12.0, "{power_ratio}");
        // ≈1.5× area and latency advantages
        assert!(c32.area / c16.area > 1.3, "{}", c32.area / c16.area);
        assert!(c32.latency / c16.latency > 1.2);
        // accumulate is cheap in both
        assert!(c16.acc_energy < 0.2 && c32.acc_energy < 0.2);
    }

    #[test]
    fn table2_fpu_requirements() {
        assert!(memory_plan(Mode::Fp32).needs_fp32_fpu);
        assert!(memory_plan(Mode::Mixed16).needs_fp32_fpu);
        assert!(!memory_plan(Mode::Standard16).needs_fp32_fpu);
        assert!(!memory_plan(Mode::Sr16).needs_fp32_fpu);
        assert!(!memory_plan(Mode::Kahan16).needs_fp32_fpu);
    }

    #[test]
    fn appendix_b2_adam_memory_savings() {
        // Adam: 2 optimizer states.  Paper: 16-bit+Kahan saves 33% vs
        // 32-bit and 43% vs mixed precision.
        let n = 1_000_000u64;
        let kahan = training_bytes(Mode::Kahan16, n, 2);
        let fp32 = training_bytes(Mode::Fp32, n, 2);
        let mixed = training_bytes(Mode::Mixed16, n, 2);
        let vs32 = 1.0 - kahan as f64 / fp32 as f64;
        let vsmixed = 1.0 - kahan as f64 / mixed as f64;
        assert!((vs32 - 0.333).abs() < 0.01, "{vs32}");
        assert!((vsmixed - 0.428).abs() < 0.01, "{vsmixed}");
    }

    #[test]
    fn tensor_weight_bytes_counts_weights_plus_kahan() {
        assert_eq!(tensor_weight_bytes(100, Mode::Sr16), 200);
        assert_eq!(tensor_weight_bytes(100, Mode::Standard16), 200);
        assert_eq!(tensor_weight_bytes(100, Mode::Kahan16), 400);
        assert_eq!(tensor_weight_bytes(100, Mode::SrKahan16), 400);
        assert_eq!(tensor_weight_bytes(100, Mode::Fp32), 400);
    }

    #[test]
    fn weight_memory_doubles_with_full_kahan() {
        let n = 1000;
        assert_eq!(mixed_kahan_bytes(n, 0.0), 2000);
        assert_eq!(mixed_kahan_bytes(n, 1.0), 4000);
        assert_eq!(mixed_kahan_bytes(n, 0.5), 3000);
    }
}
