//! The training coordinator: binds an artifact, a data pipeline and a
//! schedule into a run; logs history; evaluates; checkpoints.
//!
//! Python never runs here — the train step is a compiled PJRT executable
//! and batches come from the rust synthetic data pipeline.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::data::{self, Split};
use crate::metrics::{auc, History, HistoryPoint};
use crate::precision::Policy;
use crate::runtime::{BatchData, Engine, Manifest, TrainSession};
use crate::util::ckpt;

/// Final summary of one run.
#[derive(Debug, Clone)]
pub struct RunSummary {
    pub app: String,
    pub policy: Policy,
    pub seed: u64,
    pub steps: u64,
    /// paper-convention validation metric (Acc% / AUC% / PPL / WER)
    pub val_metric: f64,
    pub metric_name: String,
    pub final_train_loss: f64,
    pub mean_cancel_frac: f64,
    pub history: History,
    pub wallclock_s: f64,
    /// Training throughput over the steps this run actually executed (the
    /// qsim/runtime hot-path regression signal; 0.0 when nothing ran).
    pub steps_per_s: f64,
    /// Intra-step worker threads the run was configured with.  Metrics
    /// (losses, accuracies, `mean_cancel_frac`, checkpoints) are
    /// bit-identical across settings — only `steps_per_s`/`wallclock_s`
    /// may differ; the CI determinism job asserts exactly that over the
    /// qsim-native trainer.  The PJRT session path records the setting but
    /// does not yet re-thread its lowered executables.
    pub intra_threads: usize,
}

/// A live run: owns the session + generators.
pub struct Trainer<'e> {
    engine: &'e Engine,
    pub cfg: RunConfig,
    session: TrainSession,
    train_data: Box<dyn data::Dataset>,
    valid_data: Box<dyn data::Dataset>,
    pub history: History,
    cancel_acc: f64,
    /// Steps executed by *this* trainer (not counting resumed-from steps) —
    /// the denominator for the mean cancellation fraction.
    steps_run: u64,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, manifest: &Manifest, cfg: RunConfig) -> Result<Self> {
        let mut session = TrainSession::open(engine, manifest, &cfg.app, cfg.policy)?;
        session.init(engine, cfg.seed as i32)?;
        let artifact = session.artifact.clone();
        let train_data = data::for_artifact(&artifact, cfg.seed, Split::Train)?;
        let valid_data = data::for_artifact(&artifact, cfg.seed, Split::Valid)?;
        Ok(Self {
            engine,
            cfg,
            session,
            train_data,
            valid_data,
            history: History::default(),
            cancel_acc: 0.0,
            steps_run: 0,
        })
    }

    pub fn artifact_metric_name(&self) -> &str {
        &self.session.artifact.metric_name
    }

    /// Run `n` steps (continuing from the current step counter).
    pub fn run_steps(&mut self, n: u64) -> Result<()> {
        let total = self.cfg.steps;
        for _ in 0..n {
            let step = self.session.steps_done;
            let lr = (self.cfg.base_lr * self.cfg.schedule.factor(step, total)) as f32;
            let (x, y) = self.train_data.next_batch();
            // per-step RNG seed: decorrelates SR dither across steps/seeds
            let seed = (self.cfg.seed as i32)
                .wrapping_mul(1_000_003)
                .wrapping_add(step as i32);
            let stats = self.session.step(self.engine, &x, &y, seed, lr)?;
            if !stats.loss.is_finite() {
                bail!(
                    "loss diverged to {} at step {step} ({})",
                    stats.loss,
                    self.cfg.artifact_name()
                );
            }
            self.cancel_acc += stats.cancel_frac as f64;
            self.steps_run += 1;
            if step % self.cfg.log_every == 0 {
                self.history.push(HistoryPoint {
                    step,
                    loss: stats.loss,
                    metric: stats.metric,
                    cancel_frac: stats.cancel_frac,
                    lr,
                });
            }
        }
        Ok(())
    }

    /// Evaluate on `n` validation batches; returns (loss, paper metric).
    ///
    /// Metric conventions follow the paper's Table 4: Acc% for classifiers,
    /// AUC% for DLRM, PPL = exp(loss) for LMs, WER ≈ 100·(1-acc) for speech.
    pub fn evaluate(&mut self, n: u64) -> Result<(f64, f64)> {
        let mut loss_acc = 0f64;
        let mut metric_acc = 0f64;
        let mut scored: Vec<(f32, bool)> = Vec::new();
        for _ in 0..n {
            let (x, y) = self.valid_data.next_batch();
            let ev = self.session.eval(self.engine, &x, &y)?;
            loss_acc += ev.loss as f64;
            metric_acc += ev.metric as f64;
            if self.session.artifact.metric_name == "auc" {
                if let BatchData::F32(labels) = &y {
                    for (p, &l) in ev.preds.iter().zip(labels) {
                        scored.push((*p, l > 0.5));
                    }
                }
            }
        }
        let mean_loss = loss_acc / n as f64;
        let mean_metric = metric_acc / n as f64;
        let paper_metric = match self.session.artifact.metric_name.as_str() {
            "auc" => auc(&scored) as f64 * 100.0,
            "ppl" => mean_loss.exp(),
            "wer" => 100.0 * (1.0 - mean_metric),
            _ => mean_metric * 100.0, // accuracy-like
        };
        Ok((mean_loss, paper_metric))
    }

    /// Full run: train until the configured step budget, then evaluate.
    ///
    /// Counts steps already done (e.g. a resumed checkpoint) against the
    /// budget, so a resumed run finishes at `cfg.steps` like an
    /// uninterrupted one instead of training `cfg.steps` extra steps.
    pub fn run(&mut self) -> Result<RunSummary> {
        let t0 = std::time::Instant::now();
        let mut remaining = self.cfg.steps.saturating_sub(self.session.steps_done);
        while remaining > 0 {
            let chunk = remaining.min(self.cfg.eval_every);
            self.run_steps(chunk)?;
            remaining -= chunk;
        }
        let train_s = t0.elapsed().as_secs_f64();
        let (_, val_metric) = self.evaluate(self.cfg.eval_batches)?;
        Ok(RunSummary {
            app: self.cfg.app.clone(),
            policy: self.cfg.policy,
            seed: self.cfg.seed,
            steps: self.cfg.steps,
            val_metric,
            metric_name: self.session.artifact.metric_name.clone(),
            final_train_loss: self.history.tail_loss(5) as f64,
            // mean over the steps actually executed, so partial runs and
            // run_steps-driven benches report a correct fraction
            mean_cancel_frac: self.cancel_acc / self.steps_run.max(1) as f64,
            history: std::mem::take(&mut self.history),
            wallclock_s: t0.elapsed().as_secs_f64(),
            steps_per_s: if train_s > 0.0 { self.steps_run as f64 / train_s } else { 0.0 },
            intra_threads: self.cfg.intra_threads,
        })
    }

    // -- checkpointing -------------------------------------------------------

    /// Save all state tensors to a binary checkpoint.
    ///
    /// Format (`BF16CKP2`, shared framing in [`crate::util::ckpt`]): magic,
    /// artifact-name length + bytes, step counter, tensor count, then per
    /// tensor `len:u64, f32-LE data`, then the shared CRC-32 footer.
    /// Layout order is the manifest state order.  Footer-less checkpoints
    /// from older writers stay loadable.  The write goes through a sibling
    /// temp file + rename so a crash mid-write can never leave a truncated
    /// file at the checkpoint path.
    pub fn save_checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = ckpt::Writer::new();
        w.str(&self.cfg.artifact_name());
        w.u64(self.session.steps_done);
        let n = self.session.state_len();
        w.u64(n as u64);
        for i in 0..n {
            w.f32s(&self.session.state_host(i)?);
        }
        ckpt::write_atomic(path.as_ref(), &w.into_bytes())
            .with_context(|| format!("writing checkpoint {:?}", path.as_ref()))?;
        Ok(())
    }

    /// Restore state tensors from a checkpoint written by `save_checkpoint`.
    pub fn load_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let buf = std::fs::read(path.as_ref())
            .with_context(|| format!("reading checkpoint {:?}", path.as_ref()))?;
        let mut r = ckpt::Reader::new(&buf)
            .with_context(|| format!("checkpoint {:?}", path.as_ref()))?;
        let name = r.str()?;
        let expected = self.cfg.artifact_name();
        if name != expected {
            bail!(
                "checkpoint was saved from artifact {name:?} but this run uses {expected:?}; \
                 refusing to load mismatched state"
            );
        }
        let steps = r.u64()?;
        let n = r.u64()? as usize;
        if n != self.session.state_len() {
            bail!("checkpoint has {n} tensors, artifact needs {}", self.session.state_len());
        }
        for i in 0..n {
            let vals = r.f32s()?;
            self.session.set_state(i, &vals)?;
        }
        r.expect_end()
            .with_context(|| format!("checkpoint {:?}", path.as_ref()))?;
        self.session.steps_done = steps;
        // Reposition the training stream: generators are sequential, so a
        // resumed run must consume the same prefix the original run did to
        // replay the remaining batches exactly.  `skip` fast-forwards the
        // generator RNG without materializing the batches.
        self.train_data.skip(steps);
        Ok(())
    }
}
