//! Experiment harness: regenerates every table and figure of the paper
//! (DESIGN.md §5 maps each to its modules).  Each experiment renders an
//! aligned text table to stdout and writes text + CSV under `results/`.
//!
//! Every scenario is an [`Experiment`] implementation registered in
//! [`EXPERIMENTS`]; [`run_experiment`] dispatches uniformly by id or alias,
//! so new scenarios register in one place.  PJRT-backed experiments fan
//! their (policy × seed) grids out through the threaded
//! [`Sweep`](super::Sweep), which makes multi-seed regeneration scale with
//! the core count while keeping results bit-identical to sequential runs.
//!
//! Heavy experiments accept `--steps` / `--seeds` overrides so CI-scale
//! smoke runs and full paper-scale runs share one code path.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::RunSpec;
use crate::hwcost;
use crate::metrics::mean_std;
use crate::precision::{Mode, Policy, BF16, E8M1, E8M3, E8M5, FP16};
use crate::qsim::dlrm::{DlrmConfig, DlrmTrainer};
use crate::qsim::gpt::GptConfig;
use crate::qsim::lsq::{self, LsqConfig, LsqData, Placement};
use crate::qsim::mlp::MlpConfig;
use crate::qsim::train::{Task, Trainer as NativeTrainer};
use crate::qsim::UpdateStats;
use crate::util::table::{pm, Table};
use crate::Runner;

use super::sweep::{Sweep, SweepResults};
use super::trainer::RunSummary;

/// Shared options for experiment runs.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub steps: Option<u64>,
    pub seeds: u64,
    pub out_dir: String,
    pub artifacts_dir: String,
    /// EMA alpha for exported curves (1.0 = unsmoothed, Figure 6)
    pub smooth: f64,
    /// Worker threads for sweep fan-out (None: available parallelism)
    pub threads: Option<usize>,
    /// Intra-step worker threads per run (None: the config default of 1;
    /// `0` = auto, which a multi-worker sweep clamps back to sequential).
    /// Drives the qsim-native experiments (fig5/fig9) directly; sweep-based
    /// experiments thread it into each cell's `RunConfig`.  Bit-identical
    /// results at every setting.
    pub intra_threads: Option<usize>,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            steps: None,
            seeds: 3,
            out_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
            smooth: 0.15,
            threads: None,
            intra_threads: None,
        }
    }
}

impl ExpOptions {
    fn write(&self, name: &str, content: &str) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = Path::new(&self.out_dir).join(name);
        std::fs::write(&path, content).with_context(|| format!("writing {path:?}"))?;
        Ok(())
    }
}

/// Everything an experiment may need: options, the optional PJRT runner
/// (absent when no artifacts are built), and an app filter.
pub struct ExpContext<'a> {
    pub runner: Option<&'a Runner>,
    pub opts: &'a ExpOptions,
    pub only_app: Option<&'a str>,
}

impl<'a> ExpContext<'a> {
    /// The PJRT runner, or a clear error for runtime-backed experiments.
    pub fn runner(&self, id: &str) -> Result<&'a Runner> {
        self.runner
            .with_context(|| format!("experiment {id} needs PJRT artifacts (run `make artifacts`)"))
    }

    /// Run one app's (policy × seed) grid through the threaded sweep.
    fn sweep(&self, app: &str, policies: &[Policy], id: &str) -> Result<SweepResults> {
        let opts = self.opts;
        let mut base = RunSpec::new(app).artifacts_dir(&opts.artifacts_dir);
        if let Some(s) = opts.steps {
            base = base.steps(s).eval_every((s / 4).max(1)).log_every((s / 100).max(1));
        }
        if let Some(t) = opts.intra_threads {
            base = base.intra_threads(t);
        }
        let mut sweep = Sweep::new(base).policies(policies.iter().copied()).seeds(opts.seeds);
        if let Some(t) = opts.threads {
            sweep = sweep.threads(t);
        }
        sweep.run(self.runner(id)?)
    }
}

/// One registered scenario (a paper table or figure).
pub trait Experiment: Sync {
    /// Primary id (`table4`, `fig9`, …).
    fn id(&self) -> &'static str;
    /// Alternate ids that render the same output (e.g. fig3 ⇒ table3).
    fn aliases(&self) -> &'static [&'static str] {
        &[]
    }
    /// Whether the experiment needs the PJRT runtime + artifacts.
    fn needs_runtime(&self) -> bool {
        false
    }
    /// Render the experiment, writing outputs under `ctx.opts.out_dir`.
    fn run(&self, ctx: &ExpContext<'_>) -> Result<String>;
}

fn metric_cell(rs: &[&RunSummary]) -> String {
    let vals: Vec<f64> =
        rs.iter().map(|r| r.val_metric).filter(|v| v.is_finite()).collect();
    if vals.is_empty() {
        return "diverged".into();
    }
    let (m, s) = mean_std(&vals);
    pm(m, s, 2)
}

/// Mean training throughput over a set of runs (`-` when nothing ran) —
/// surfaces `steps_per_s` in the experiment tables, not just the train CLI.
fn throughput_cell<'a>(rs: impl IntoIterator<Item = &'a RunSummary>) -> String {
    let vals: Vec<f64> = rs
        .into_iter()
        .map(|r| r.steps_per_s)
        .filter(|v| v.is_finite() && *v > 0.0)
        .collect();
    if vals.is_empty() {
        return "-".into();
    }
    let (m, _) = mean_std(&vals);
    format!("{m:.1}")
}

/// One cell of a native (qsim) mode × seed grid.
struct NativeCell {
    mode: Mode,
    /// Per-seed eval losses / metrics / cancellation fractions.
    eval_loss: Vec<f64>,
    eval_metric: Vec<f64>,
    cancel_fracs: Vec<f64>,
    /// Merged update stats over every seed's run.
    cancel: UpdateStats,
    sps: Vec<f64>,
    /// Weight-memory footprint under the cell's mode (generic param-walk
    /// accounting — every native app reports its memory plan).
    weight_kb: f64,
}

/// Run a Table-4-style mode × seed grid through the generic native trainer
/// — the single loop behind every qsim-app experiment (gpt, mlp, future
/// tasks).  Per-app code shrinks to a config constructor and a table
/// renderer.
fn run_native_grid<T: Task>(
    modes: &[Mode],
    seeds: u64,
    steps: usize,
    lr: impl Fn(usize) -> f32,
    eval_batches: usize,
    mk_task: impl Fn(u64) -> T,
) -> Vec<NativeCell> {
    let mut cells = Vec::new();
    for &mode in modes {
        let mut cell = NativeCell {
            mode,
            eval_loss: Vec::new(),
            eval_metric: Vec::new(),
            cancel_fracs: Vec::new(),
            cancel: UpdateStats::default(),
            sps: Vec::new(),
            weight_kb: 0.0,
        };
        for seed in 0..seeds {
            let mut tr = NativeTrainer::new(mk_task(seed), mode);
            cell.weight_kb = tr.weight_bytes() as f64 / 1024.0;
            let mut seed_cancel = UpdateStats::default();
            let t0 = std::time::Instant::now();
            for step in 0..steps {
                let tel = tr.step(lr(step));
                seed_cancel.merge(tel.total());
            }
            let dt = t0.elapsed().as_secs_f64();
            if dt > 0.0 {
                cell.sps.push(steps as f64 / dt);
            }
            let m = tr.eval(eval_batches);
            cell.eval_loss.push(m.loss as f64);
            cell.eval_metric.push(m.metric as f64);
            cell.cancel_fracs.push(seed_cancel.frac());
            cell.cancel.merge(seed_cancel);
        }
        cells.push(cell);
    }
    cells
}

/// Export per-seed curves as CSV (step, loss, metric, cancel, lr).
fn export_curves(opts: &ExpOptions, tag: &str, rs: &[&RunSummary]) -> Result<()> {
    for r in rs {
        let alpha = if opts.smooth >= 1.0 { None } else { Some(opts.smooth) };
        opts.write(
            &format!("{tag}__{}__{}__seed{}.csv", r.app, r.policy, r.seed),
            &r.history.to_csv(alpha),
        )?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 & 2 — hardware cost model.
// ---------------------------------------------------------------------------

struct Table1;

impl Experiment for Table1 {
    fn id(&self) -> &'static str {
        "table1"
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Table 1 — FMAC hardware cost (relative to 32-bit FMAC)",
            &["compute unit", "multiply", "mul energy", "accum", "acc energy", "area", "latency"],
        );
        for (name, c) in hwcost::table1() {
            let mul_prec = if name.contains("16") { "16-bit" } else { "32-bit" };
            t.row(vec![
                name,
                mul_prec.into(),
                format!("{:.2}", c.mul_energy),
                "32-bit".into(),
                format!("{:.2}", c.acc_energy),
                format!("{:.2}", c.area),
                format!("{:.2}", c.latency),
            ]);
        }
        let s = t.render();
        opts.write("table1.txt", &s)?;
        opts.write("table1.csv", &t.to_csv())?;
        Ok(s)
    }
}

struct Table2;

impl Experiment for Table2 {
    fn id(&self) -> &'static str {
        "table2"
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Table 2 — training precision modes (per-weight bytes; Adam states)",
            &["mode", "weight", "master", "opt state", "kahan", "needs fp32 FPU", "total (Adam)"],
        );
        for mode in [Mode::Fp32, Mode::Mixed16, Mode::Standard16, Mode::Sr16, Mode::Kahan16] {
            let p = hwcost::memory_plan(mode);
            t.row(vec![
                mode.name().into(),
                p.weight_bytes.to_string(),
                p.master_bytes.to_string(),
                format!("{}×2", p.opt_state_bytes),
                p.kahan_bytes.to_string(),
                if p.needs_fp32_fpu { "yes" } else { "NO" }.into(),
                hwcost::training_bytes(mode, 1, 2).to_string(),
            ]);
        }
        let n = 1_000_000u64;
        let kahan = hwcost::training_bytes(Mode::Kahan16, n, 2) as f64;
        let fp32 = hwcost::training_bytes(Mode::Fp32, n, 2) as f64;
        let mixed = hwcost::training_bytes(Mode::Mixed16, n, 2) as f64;
        let extra = format!(
            "\nAppendix B.2 check (Adam, 1M weights): kahan16 saves {:.1}% vs fp32 (paper: 33%), {:.1}% vs mixed (paper: 43%)\n",
            (1.0 - kahan / fp32) * 100.0,
            (1.0 - kahan / mixed) * 100.0
        );
        let s = t.render() + &extra;
        opts.write("table2.txt", &s)?;
        opts.write("table2.csv", &t.to_csv())?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Figure 2 + Theorem 1 — native least-squares theory validation.
// ---------------------------------------------------------------------------

struct Fig2;

impl Experiment for Fig2 {
    fn id(&self) -> &'static str {
        "fig2"
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let cfg = LsqConfig {
            steps: opts.steps.unwrap_or(20_000) as usize,
            ..LsqConfig::default()
        };
        let data = LsqData::generate(&cfg);
        let mut t = Table::new(
            "Figure 2 — LSQ with selective nearest rounding (bf16, lr 0.01)",
            &["rounding placement", "final loss", "final ||w-w*||", "halted steps %"],
        );
        let mut csv = String::from("placement,step,loss\n");
        for placement in [
            Placement::Exact,
            Placement::ForwardBackward,
            Placement::WeightUpdate,
            Placement::Everywhere,
            Placement::WeightUpdateSr,
            Placement::WeightUpdateKahan,
        ] {
            let run = lsq::run(&cfg, &data, placement);
            t.row(vec![
                placement.name().into(),
                format!("{:.3e}", run.losses.last().copied().unwrap_or(f32::NAN)),
                format!("{:.3e}", run.final_dist),
                format!("{:.1}", run.halt_frac * 100.0),
            ]);
            for (i, l) in run.losses.iter().enumerate() {
                csv.push_str(&format!(
                    "{},{},{:.6e}\n",
                    placement.name(),
                    i * run.sample_every,
                    l
                ));
            }
        }
        let s = t.render();
        opts.write("fig2.txt", &s)?;
        opts.write("fig2.csv", &csv)?;
        Ok(s)
    }
}

struct Thm1;

impl Experiment for Thm1 {
    fn id(&self) -> &'static str {
        "thm1"
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Theorem 1 — halting radius vs observed final distance (bf16)",
            &["lr", "predicted radius", "observed ||w-w*||", "observed/predicted"],
        );
        for lr in [0.001f32, 0.01, 0.1] {
            let cfg = LsqConfig {
                lr,
                steps: opts.steps.unwrap_or(30_000) as usize,
                noise_std: 0.0, // interpolation regime: A1 holds exactly
                ..LsqConfig::default()
            };
            let data = LsqData::generate(&cfg);
            let run = lsq::run(&cfg, &data, Placement::WeightUpdate);
            let radius = lsq::halting_radius(&cfg, &data);
            t.row(vec![
                format!("{lr}"),
                format!("{radius:.3e}"),
                format!("{:.3e}", run.final_dist),
                format!("{:.2}", run.final_dist / radius),
            ]);
        }
        let s = t.render()
            + "\nTheorem 1: smaller lr ⇒ LARGER halting radius (opposite of exact SGD).\n";
        opts.write("thm1.txt", &s)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Figure 1 / 6 — BERT-stand-in standard16 vs fp32 curves.
// ---------------------------------------------------------------------------

struct Fig1;

impl Experiment for Fig1 {
    fn id(&self) -> &'static str {
        "fig1"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fig6"]
    }

    fn needs_runtime(&self) -> bool {
        true
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Figure 1 — transformer-cls: standard 16-bit-FPU vs 32-bit",
            &["algorithm", "final train acc %", "val acc %", "steps/s"],
        );
        let policies = [Policy::bf16(Mode::Fp32), Policy::bf16(Mode::Standard16)];
        let res = ctx.sweep("bert-cls", &policies, self.id())?;
        for p in &policies {
            let rs = res.for_policy(p);
            export_curves(opts, "fig1", &rs)?;
            let train_acc: Vec<f64> = rs
                .iter()
                .map(|r| r.history.tail_metric(5) as f64 * 100.0)
                .collect();
            let (m, _) = mean_std(&train_acc);
            t.row(vec![
                p.to_string(),
                format!("{m:.2}"),
                metric_cell(&rs),
                throughput_cell(rs.iter().copied()),
            ]);
        }
        let s = t.render();
        opts.write("fig1.txt", &s)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Table 3 / Figures 3 & 7 — the accuracy-bottleneck ablation.
// ---------------------------------------------------------------------------

struct Table3;

impl Experiment for Table3 {
    fn id(&self) -> &'static str {
        "table3"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fig3", "fig7"]
    }

    fn needs_runtime(&self) -> bool {
        true
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Table 3 — accuracy bottleneck ablation (metric: paper convention)",
            &["model", "metric", "32-bit", "standard 16-bit-FPU", "standard 16-bit + 32-bit weights"],
        );
        let policies =
            [Policy::bf16(Mode::Fp32), Policy::bf16(Mode::Standard16), Policy::bf16(Mode::Mixed16)];
        for app in ["cifar-cnn", "dlrm-small", "bert-cls"] {
            let res = ctx.sweep(app, &policies, self.id())?;
            let mut cells = Vec::new();
            let mut metric_name = String::new();
            for p in &policies {
                let rs = res.for_policy(p);
                export_curves(opts, "fig3", &rs)?;
                if let Some(r) = rs.first() {
                    metric_name = r.metric_name.clone();
                }
                cells.push(metric_cell(&rs));
            }
            t.row(vec![
                app.into(),
                metric_name,
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
            ]);
        }
        let s = t.render()
            + "\nExpected shape (paper): column 3 < columns 2 & 4; ablating weight-update\nrounding (col 4) recovers 32-bit accuracy.\n";
        opts.write("table3.txt", &s)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Table 4 / Figures 4 & 8 — the main result across seven applications.
// ---------------------------------------------------------------------------

pub const TABLE4_APPS: [&str; 7] = [
    "cifar-cnn",
    "imagenet-cnn",
    "dlrm-small",
    "dlrm-large",
    "bert-cls",
    "bert-lm",
    "lstm-seq",
];

struct Table4;

impl Experiment for Table4 {
    fn id(&self) -> &'static str {
        "table4"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["fig4", "fig8"]
    }

    fn needs_runtime(&self) -> bool {
        true
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Table 4 — 16-bit-FPU training vs 32-bit across applications",
            &[
                "model",
                "metric",
                "32-bit",
                "16-bit stochastic",
                "16-bit kahan",
                "16-bit standard",
                "sr16 steps/s",
            ],
        );
        let apps: Vec<&str> = match ctx.only_app {
            Some(a) => vec![a],
            None => TABLE4_APPS.to_vec(),
        };
        let policies = [
            Policy::bf16(Mode::Fp32),
            Policy::bf16(Mode::Sr16),
            Policy::bf16(Mode::Kahan16),
            Policy::bf16(Mode::Standard16),
        ];
        let mut csv = String::from("app,mode,seed,metric_name,val_metric\n");
        for app in apps {
            let res = ctx.sweep(app, &policies, self.id())?;
            let mut cells = Vec::new();
            let mut metric_name = String::new();
            for p in &policies {
                let rs = res.for_policy(p);
                export_curves(opts, "fig4", &rs)?;
                if let Some(r) = rs.first() {
                    metric_name = r.metric_name.clone();
                }
                for r in &rs {
                    csv.push_str(&format!(
                        "{app},{p},{},{},{:.4}\n",
                        r.seed, r.metric_name, r.val_metric
                    ));
                }
                cells.push(metric_cell(&rs));
            }
            t.row(vec![
                app.into(),
                metric_name,
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
                cells[3].clone(),
                // one policy's throughput, not a cross-policy mean: sr16 is
                // the paper's headline mode and the hot-path signal
                throughput_cell(
                    res.for_policy(&Policy::bf16(Mode::Sr16)).into_iter(),
                ),
            ]);
        }
        let s = t.render()
            + "\nExpected shape (paper): sr16/kahan16 within noise of 32-bit; standard16 clearly worse.\n";
        opts.write("table4.txt", &s)?;
        opts.write("table4.csv", &csv)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Figure 5 — SR↔Kahan memory/accuracy trade-off (native DLRM).
// ---------------------------------------------------------------------------

struct Fig5;

impl Experiment for Fig5 {
    fn id(&self) -> &'static str {
        "fig5"
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let steps = opts.steps.unwrap_or(1200) as usize;
        let mut t = Table::new(
            "Figure 5 — DLRM: replacing SR with Kahan tensor-by-tensor",
            &["kahan tensors", "weight MB (rel.)", "val AUC %", "steps/s"],
        );
        let base_cfg = DlrmConfig {
            intra_threads: opts.intra_threads.unwrap_or(1),
            ..DlrmConfig::default()
        };
        let n_tensors = base_cfg.num_tables + 6;
        // the all-SR byte count is loop-invariant: compute the denominator
        // once (sequential probe — no point spawning a pool for a byte sum)
        let all_sr =
            DlrmTrainer::new(DlrmConfig { intra_threads: 1, ..base_cfg.clone() }, Mode::Sr16)
                .weight_bytes();
        // sweep: 0 tensors (all SR) … all tensors Kahan, embeddings first
        // (they dominate memory, exactly the paper's sweep axis).
        for kahan_k in [0usize, 2, 4, n_tensors] {
            let mut aucs = Vec::new();
            let mut sps = Vec::new();
            let mut bytes = 0u64;
            for seed in 0..opts.seeds {
                let cfg = DlrmConfig { seed, ..base_cfg.clone() };
                let modes: Vec<Mode> = (0..n_tensors)
                    .map(|i| if i < kahan_k { Mode::Kahan16 } else { Mode::Sr16 })
                    .collect();
                let mut tr = DlrmTrainer::new_mixed(cfg, modes.clone());
                bytes = tr.weight_bytes();
                let t0 = std::time::Instant::now();
                for _ in 0..steps {
                    tr.step(0.05);
                }
                let dt = t0.elapsed().as_secs_f64();
                if dt > 0.0 {
                    sps.push(steps as f64 / dt);
                }
                let auc = tr.eval(16).metric;
                aucs.push(auc as f64 * 100.0);
            }
            let (m, s) = mean_std(&aucs);
            let (sps_m, _) = mean_std(&sps);
            t.row(vec![
                format!("{kahan_k}/{n_tensors}"),
                format!("{:.2}x", bytes as f64 / all_sr as f64),
                pm(m, s, 2),
                format!("{sps_m:.1}"),
            ]);
        }
        let s = t.render();
        opts.write("fig5.txt", &s)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Figure 9 — % of cancelled updates over training (native DLRM).
// ---------------------------------------------------------------------------

struct Fig9;

impl Experiment for Fig9 {
    fn id(&self) -> &'static str {
        "fig9"
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let steps = opts.steps.unwrap_or(3000) as usize;
        let mut t = Table::new(
            "Figure 9 — % non-zero updates cancelled by nearest rounding",
            &["dataset proxy / lr", "phase", "embedding layer", "MLP layers", "steps/s"],
        );
        let mut csv = String::from("setting,step,embed_cancel_pct,mlp_cancel_pct,loss\n");
        // Kaggle proxy: constant lr (cancellation grows as gradients shrink);
        // Terabyte proxy: decaying lr (compound effect, paper App. D.3).
        for (label, decay) in [("kaggle-constant-lr", false), ("terabyte-decaying-lr", true)] {
            let cfg = DlrmConfig {
                intra_threads: opts.intra_threads.unwrap_or(1),
                ..DlrmConfig::default()
            };
            let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
            let t0 = std::time::Instant::now();
            let window = (steps / 40).max(1);
            let mut emb_acc = crate::qsim::UpdateStats::default();
            let mut mlp_acc = crate::qsim::UpdateStats::default();
            let mut loss_acc = 0f64;
            let mut early = (0f64, 0f64);
            let mut late = (0f64, 0f64);
            for step in 0..steps {
                let lr = if decay {
                    let t = step as f32 / steps as f32;
                    if t < 0.5 {
                        0.03
                    } else {
                        0.03 * (1.0 - (t - 0.5) / 0.5).max(0.01)
                    }
                } else {
                    0.03
                };
                let tel = tr.step(lr);
                emb_acc.merge(tel.embed);
                mlp_acc.merge(tel.mlp);
                loss_acc += tel.loss as f64;
                if (step + 1) % window == 0 {
                    let row = (emb_acc.frac() * 100.0, mlp_acc.frac() * 100.0);
                    csv.push_str(&format!(
                        "{label},{},{:.2},{:.2},{:.4}\n",
                        step + 1,
                        row.0,
                        row.1,
                        loss_acc / window as f64
                    ));
                    if step < steps / 4 {
                        early = row;
                    }
                    late = row;
                    emb_acc = Default::default();
                    mlp_acc = Default::default();
                    loss_acc = 0.0;
                }
            }
            let dt = t0.elapsed().as_secs_f64();
            let sps = if dt > 0.0 { format!("{:.1}", steps as f64 / dt) } else { "-".into() };
            t.row(vec![
                label.into(),
                "early (first quarter)".into(),
                format!("{:.1}%", early.0),
                format!("{:.1}%", early.1),
                sps.clone(),
            ]);
            t.row(vec![
                label.into(),
                "late (final window)".into(),
                format!("{:.1}%", late.0),
                format!("{:.1}%", late.1),
                sps,
            ]);
        }
        let s = t.render()
            + "\nExpected shape (paper): cancellation grows into the mid-to-late stage,\nreaching >50-80% for both layer types; lr decay compounds the effect.\n";
        opts.write("fig9.txt", &s)?;
        opts.write("fig9.csv", &csv)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// gpt-nano — the native transformer-LM scenario of the Table-4 comparison.
// ---------------------------------------------------------------------------

/// The Table-4-style nearest/SR/Kahan comparison on the *bit-exact*
/// simulator's third application family: a tiny causal-transformer LM over
/// a seeded Markov corpus (the first two being DLRM and least-squares).
/// Runs fully native — no PJRT artifacts needed — and is bit-identical
/// across backends and `--intra-threads` settings.
struct GptNano;

impl Experiment for GptNano {
    fn id(&self) -> &'static str {
        "gpt"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["gpt-nano"]
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let steps = opts.steps.unwrap_or(300) as usize;
        let warm = (steps / 20).max(1);
        let mut t = Table::new(
            "gpt-nano (native) — 16-bit-FPU training vs 32-bit, transformer LM",
            &["mode", "eval loss", "eval ppl", "weight KB", "cancel %", "steps/s"],
        );
        let mut csv = String::from("mode,seed,eval_loss,eval_ppl,cancel_frac\n");
        let intra = opts.intra_threads.unwrap_or(1);
        let cells = run_native_grid(
            &[Mode::Fp32, Mode::Sr16, Mode::Kahan16, Mode::Standard16],
            opts.seeds,
            steps,
            // constant lr with a short linear warmup
            |step| if step < warm { 0.2 * (step + 1) as f32 / warm as f32 } else { 0.2 },
            8,
            |seed| GptConfig { seed, intra_threads: intra, ..GptConfig::default() },
        );
        for cell in &cells {
            for (seed, (el, cf)) in
                cell.eval_loss.iter().zip(&cell.cancel_fracs).enumerate()
            {
                csv.push_str(&format!(
                    "{},{seed},{el:.4},{:.3},{cf:.4}\n",
                    cell.mode.name(),
                    el.exp()
                ));
            }
            let (m, s) = mean_std(&cell.eval_loss);
            let (sm, _) = mean_std(&cell.sps);
            t.row(vec![
                cell.mode.name().into(),
                pm(m, s, 3),
                format!("{:.2}", m.exp()),
                format!("{:.1}", cell.weight_kb),
                format!("{:.1}", cell.cancel.frac() * 100.0),
                if cell.sps.is_empty() { "-".into() } else { format!("{sm:.1}") },
            ]);
        }
        let s = t.render()
            + "\nExpected shape (paper): sr16/kahan16 within noise of 32-bit; standard16\nworse — nearest rounding cancels late-training updates (see cancel %).\n";
        opts.write("gpt.txt", &s)?;
        opts.write("gpt.csv", &csv)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// mlp — the generic-engine proof app (spiral classifier).
// ---------------------------------------------------------------------------

/// The Table-4-style nearest/SR/Kahan comparison on the spiral-MLP
/// classifier — the app added *through* the generic `qsim::train` engine
/// (a `Task` impl, no hand-rolled trainer), demonstrating that new native
/// scenarios cost a config + forward pass rather than a copied loop.
/// Runs fully native and is bit-identical across backends and
/// `--intra-threads` settings.
struct MlpExp;

impl Experiment for MlpExp {
    fn id(&self) -> &'static str {
        "mlp"
    }

    fn aliases(&self) -> &'static [&'static str] {
        &["spiral"]
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let steps = opts.steps.unwrap_or(600) as usize;
        let warm = (steps / 20).max(1);
        let mut t = Table::new(
            "mlp (native) — 16-bit-FPU training vs 32-bit, spiral classifier",
            &["mode", "eval loss", "eval acc %", "weight KB", "cancel %", "steps/s"],
        );
        let mut csv = String::from("mode,seed,eval_loss,eval_acc,cancel_frac\n");
        let intra = opts.intra_threads.unwrap_or(1);
        let cells = run_native_grid(
            &[Mode::Fp32, Mode::Sr16, Mode::Kahan16, Mode::Standard16],
            opts.seeds,
            steps,
            |step| if step < warm { 0.3 * (step + 1) as f32 / warm as f32 } else { 0.3 },
            8,
            |seed| MlpConfig { seed, intra_threads: intra, ..MlpConfig::default() },
        );
        for cell in &cells {
            for (seed, ((el, acc), cf)) in cell
                .eval_loss
                .iter()
                .zip(&cell.eval_metric)
                .zip(&cell.cancel_fracs)
                .enumerate()
            {
                csv.push_str(&format!(
                    "{},{seed},{el:.4},{acc:.4},{cf:.4}\n",
                    cell.mode.name()
                ));
            }
            let (ml, sl) = mean_std(&cell.eval_loss);
            let accs: Vec<f64> = cell.eval_metric.iter().map(|a| a * 100.0).collect();
            let (ma, sa) = mean_std(&accs);
            let (sm, _) = mean_std(&cell.sps);
            t.row(vec![
                cell.mode.name().into(),
                pm(ml, sl, 3),
                pm(ma, sa, 1),
                format!("{:.1}", cell.weight_kb),
                format!("{:.1}", cell.cancel.frac() * 100.0),
                if cell.sps.is_empty() { "-".into() } else { format!("{sm:.1}") },
            ]);
        }
        let s = t.render()
            + "\nExpected shape (paper): sr16/kahan16 within noise of 32-bit; standard16\nworse — nearest rounding cancels late-training updates (see cancel %).\n";
        opts.write("mlp.txt", &s)?;
        opts.write("mlp.csv", &csv)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Figure 10 / 12 — sub-16-bit and fp16 format sweeps (PJRT, DLRM).
// ---------------------------------------------------------------------------

struct Fig10;

impl Experiment for Fig10 {
    fn id(&self) -> &'static str {
        "fig10"
    }

    fn needs_runtime(&self) -> bool {
        true
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Figure 10 — below 16-bit (DLRM; e8mN = 8 exp bits, N mantissa bits)",
            &["format (bits)", "standard", "stochastic", "kahan", "32-bit ref"],
        );
        let fmts = [BF16, E8M5, E8M3, E8M1];
        let modes = [Mode::Standard16, Mode::Sr16, Mode::Kahan16];
        // one grid: the fp32 reference plus every (mode, fmt) combination
        let mut policies = vec![Policy::bf16(Mode::Fp32)];
        for f in fmts {
            policies.extend(modes.iter().map(|&m| Policy::new(m, f)));
        }
        let res = ctx.sweep("dlrm-small", &policies, self.id())?;
        let fp32_cell = metric_cell(&res.for_policy(&Policy::bf16(Mode::Fp32)));
        for f in fmts {
            let cells: Vec<String> = modes
                .iter()
                .map(|&m| metric_cell(&res.for_policy(&Policy::new(m, f))))
                .collect();
            t.row(vec![
                format!("{} ({}-bit)", f.name, f.total_bits()),
                cells[0].clone(),
                cells[1].clone(),
                cells[2].clone(),
                fp32_cell.clone(),
            ]);
        }
        let s = t.render()
            + "\nExpected shape (paper): only 14-bit (e8m5) Kahan stays near 16/32-bit;\nlower precision degrades in all modes.\n";
        opts.write("fig10.txt", &s)?;
        Ok(s)
    }
}

struct Fig12;

impl Experiment for Fig12 {
    fn id(&self) -> &'static str {
        "fig12"
    }

    fn needs_runtime(&self) -> bool {
        true
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Figure 12 — Float16 (e5m10, no loss scaling) vs BFloat16 (DLRM)",
            &["format", "standard", "stochastic", "kahan"],
        );
        let fmts = [BF16, FP16];
        let modes = [Mode::Standard16, Mode::Sr16, Mode::Kahan16];
        let mut policies = Vec::new();
        for f in fmts {
            policies.extend(modes.iter().map(|&m| Policy::new(m, f)));
        }
        let res = ctx.sweep("dlrm-small", &policies, self.id())?;
        for f in fmts {
            let cells: Vec<String> = modes
                .iter()
                .map(|&m| metric_cell(&res.for_policy(&Policy::new(m, f))))
                .collect();
            t.row(vec![f.name.into(), cells[0].clone(), cells[1].clone(), cells[2].clone()]);
        }
        let s = t.render()
            + "\nExpected shape (paper): fp16 lags bf16 even with SR/Kahan — dynamic range,\nnot mantissa, is the binding constraint.\n";
        opts.write("fig12.txt", &s)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Figure 11 — combining SR + Kahan.
// ---------------------------------------------------------------------------

struct Fig11;

impl Experiment for Fig11 {
    fn id(&self) -> &'static str {
        "fig11"
    }

    fn needs_runtime(&self) -> bool {
        true
    }

    fn run(&self, ctx: &ExpContext<'_>) -> Result<String> {
        let opts = ctx.opts;
        let mut t = Table::new(
            "Figure 11 — stochastic rounding + Kahan simultaneously",
            &["model", "32-bit", "sr+kahan combined"],
        );
        let fp32 = Policy::bf16(Mode::Fp32);
        let combo = Policy::bf16(Mode::SrKahan16);
        for app in ["cifar-cnn", "dlrm-small", "bert-cls"] {
            let res = ctx.sweep(app, &[fp32, combo], self.id())?;
            let combo_rs = res.for_policy(&combo);
            export_curves(opts, "fig11", &combo_rs)?;
            t.row(vec![
                app.into(),
                metric_cell(&res.for_policy(&fp32)),
                metric_cell(&combo_rs),
            ]);
        }
        let s = t.render();
        opts.write("fig11.txt", &s)?;
        Ok(s)
    }
}

// ---------------------------------------------------------------------------
// Registry + dispatch.
// ---------------------------------------------------------------------------

/// Every registered experiment, dependency-light → heavy.
pub static EXPERIMENTS: &[&dyn Experiment] = &[
    &Table1, &Table2, &Fig2, &Thm1, &Fig5, &Fig9, &GptNano, &MlpExp, &Fig1, &Table3, &Fig10,
    &Fig11, &Fig12, &Table4,
];

/// All primary experiment ids, in registry order (for `exp all`).
pub const ALL_EXPERIMENTS: [&str; 14] = [
    "table1", "table2", "fig2", "thm1", "fig5", "fig9", "gpt", "mlp", "fig1", "table3",
    "fig10", "fig11", "fig12", "table4",
];

/// Find an experiment by primary id or alias.
pub fn find_experiment(id: &str) -> Option<&'static dyn Experiment> {
    EXPERIMENTS
        .iter()
        .copied()
        .find(|e| e.id() == id || e.aliases().contains(&id))
}

/// Dispatch an experiment by id.  `runner` is created lazily by the caller
/// and may be `None` when no artifacts are built (native experiments still
/// run).
pub fn run_experiment(
    id: &str,
    runner: Option<&Runner>,
    opts: &ExpOptions,
    only_app: Option<&str>,
) -> Result<String> {
    let Some(exp) = find_experiment(id) else {
        bail!(
            "unknown experiment {id:?}; available: {} all",
            ALL_EXPERIMENTS.join(" ")
        );
    };
    let ctx = ExpContext { runner, opts, only_app };
    if exp.needs_runtime() {
        ctx.runner(id)?; // fail early with a clear message
    }
    exp.run(&ctx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_match_all_experiments() {
        let ids: Vec<&str> = EXPERIMENTS.iter().map(|e| e.id()).collect();
        assert_eq!(ids, ALL_EXPERIMENTS.to_vec());
    }

    #[test]
    fn aliases_resolve_to_their_experiment() {
        assert_eq!(find_experiment("fig6").unwrap().id(), "fig1");
        assert_eq!(find_experiment("fig3").unwrap().id(), "table3");
        assert_eq!(find_experiment("fig4").unwrap().id(), "table4");
        assert_eq!(find_experiment("gpt-nano").unwrap().id(), "gpt");
        assert_eq!(find_experiment("spiral").unwrap().id(), "mlp");
        assert!(find_experiment("fig99").is_none());
    }

    #[test]
    fn native_experiments_run_without_runtime() {
        assert!(!find_experiment("gpt").unwrap().needs_runtime());
        assert!(!find_experiment("mlp").unwrap().needs_runtime());
    }

    /// Acceptance gate: `repro exp mlp` produces a Table-4-style results
    /// table through the generic native trainer (tiny budget here).
    #[test]
    fn mlp_experiment_renders_a_table4_style_table() {
        let dir = std::env::temp_dir().join("bf16_mlp_exp_test");
        let opts = ExpOptions {
            steps: Some(12),
            seeds: 1,
            out_dir: dir.to_string_lossy().into_owned(),
            ..ExpOptions::default()
        };
        let out = run_experiment("mlp", None, &opts, None).unwrap();
        for needle in ["fp32", "sr16", "kahan16", "standard16", "eval acc %", "weight KB"] {
            assert!(out.contains(needle), "missing {needle:?} in:\n{out}");
        }
        assert!(dir.join("mlp.csv").exists());
    }

    #[test]
    fn unknown_experiment_is_a_clear_error() {
        let err = run_experiment("nope", None, &ExpOptions::default(), None).unwrap_err();
        assert!(err.to_string().contains("unknown experiment"));
    }

    #[test]
    fn runtime_experiments_fail_without_runner() {
        let err = run_experiment("table4", None, &ExpOptions::default(), None).unwrap_err();
        assert!(err.to_string().contains("needs PJRT artifacts"));
    }
}
