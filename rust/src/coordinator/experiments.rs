//! Experiment harness: regenerates every table and figure of the paper
//! (DESIGN.md §5 maps each to its modules).  Each experiment renders an
//! aligned text table to stdout and writes text + CSV under `results/`.
//!
//! Heavy experiments accept `--steps` / `--seeds` overrides so CI-scale
//! smoke runs and full paper-scale runs share one code path.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::hwcost;
use crate::metrics::mean_std;
use crate::precision::Format;
use crate::qsim::dlrm::{DlrmConfig, DlrmTrainer};
use crate::qsim::lsq::{self, LsqConfig, LsqData, Placement};
use crate::qsim::Mode;
use crate::runtime::{Engine, Manifest};
use crate::util::table::{pm, Table};

use super::trainer::{RunSummary, Trainer};

/// Shared options for experiment runs.
#[derive(Debug, Clone)]
pub struct ExpOptions {
    pub steps: Option<u64>,
    pub seeds: u64,
    pub out_dir: String,
    pub artifacts_dir: String,
    /// EMA alpha for exported curves (1.0 = unsmoothed, Figure 6)
    pub smooth: f64,
}

impl Default for ExpOptions {
    fn default() -> Self {
        Self {
            steps: None,
            seeds: 3,
            out_dir: "results".into(),
            artifacts_dir: "artifacts".into(),
            smooth: 0.15,
        }
    }
}

impl ExpOptions {
    fn write(&self, name: &str, content: &str) -> Result<()> {
        std::fs::create_dir_all(&self.out_dir)?;
        let path = Path::new(&self.out_dir).join(name);
        std::fs::write(&path, content).with_context(|| format!("writing {path:?}"))?;
        Ok(())
    }
}

/// Run one (app, mode, fmt) × seeds sweep through the PJRT coordinator.
fn run_app(
    engine: &Engine,
    manifest: &Manifest,
    app: &str,
    mode: &str,
    fmt: &str,
    opts: &ExpOptions,
) -> Result<Vec<RunSummary>> {
    let mut out = Vec::new();
    for seed in 0..opts.seeds {
        let mut cfg = RunConfig::defaults_for(app);
        cfg.mode = mode.into();
        cfg.fmt = fmt.into();
        cfg.seed = seed;
        cfg.artifacts_dir = opts.artifacts_dir.clone();
        if let Some(s) = opts.steps {
            cfg.steps = s;
            cfg.eval_every = (s / 4).max(1);
            cfg.log_every = (s / 100).max(1);
        }
        let label = cfg.artifact_name();
        eprintln!("  [{label} seed={seed}] {} steps…", cfg.steps);
        let mut tr = Trainer::new(engine, manifest, cfg)?;
        // A diverged run is a *result* (the standard16/fp16 modes are
        // expected to fail on some workloads) — record NaN and continue.
        let summary = match tr.run() {
            Ok(s) => s,
            Err(e) => {
                eprintln!("  [{label} seed={seed}] FAILED: {e}");
                out.push(super::trainer::RunSummary {
                    app: app.to_string(),
                    mode: mode.to_string(),
                    fmt: fmt.to_string(),
                    seed,
                    steps: 0,
                    val_metric: f64::NAN,
                    metric_name: "failed".into(),
                    final_train_loss: f64::NAN,
                    mean_cancel_frac: f64::NAN,
                    history: Default::default(),
                    wallclock_s: 0.0,
                });
                continue;
            }
        };
        eprintln!(
            "  [{label} seed={seed}] {}={:.3} loss={:.4} cancel={:.1}% ({:.1}s)",
            summary.metric_name,
            summary.val_metric,
            summary.final_train_loss,
            summary.mean_cancel_frac * 100.0,
            summary.wallclock_s
        );
        out.push(summary);
    }
    Ok(out)
}

fn metric_cell(rs: &[RunSummary]) -> String {
    let vals: Vec<f64> =
        rs.iter().map(|r| r.val_metric).filter(|v| v.is_finite()).collect();
    if vals.is_empty() {
        return "diverged".into();
    }
    let (m, s) = mean_std(&vals);
    pm(m, s, 2)
}

/// Export per-seed curves as CSV (step, loss, metric, cancel, lr).
fn export_curves(opts: &ExpOptions, tag: &str, rs: &[RunSummary]) -> Result<()> {
    for r in rs {
        let alpha = if opts.smooth >= 1.0 { None } else { Some(opts.smooth) };
        opts.write(
            &format!("{tag}__{}__{}__seed{}.csv", r.app, r.mode, r.seed),
            &r.history.to_csv(alpha),
        )?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Table 1 & 2 — hardware cost model.
// ---------------------------------------------------------------------------

pub fn table1(opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Table 1 — FMAC hardware cost (relative to 32-bit FMAC)",
        &["compute unit", "multiply", "mul energy", "accum", "acc energy", "area", "latency"],
    );
    for (name, c) in hwcost::table1() {
        let mul_prec = if name.contains("16") { "16-bit" } else { "32-bit" };
        t.row(vec![
            name,
            mul_prec.into(),
            format!("{:.2}", c.mul_energy),
            "32-bit".into(),
            format!("{:.2}", c.acc_energy),
            format!("{:.2}", c.area),
            format!("{:.2}", c.latency),
        ]);
    }
    let s = t.render();
    opts.write("table1.txt", &s)?;
    opts.write("table1.csv", &t.to_csv())?;
    Ok(s)
}

pub fn table2(opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Table 2 — training precision modes (per-weight bytes; Adam states)",
        &["mode", "weight", "master", "opt state", "kahan", "needs fp32 FPU", "total (Adam)"],
    );
    for mode in ["fp32", "mixed16", "standard16", "sr16", "kahan16"] {
        let p = hwcost::memory_plan(mode);
        t.row(vec![
            mode.into(),
            p.weight_bytes.to_string(),
            p.master_bytes.to_string(),
            format!("{}×2", p.opt_state_bytes),
            p.kahan_bytes.to_string(),
            if p.needs_fp32_fpu { "yes" } else { "NO" }.into(),
            hwcost::training_bytes(mode, 1, 2).to_string(),
        ]);
    }
    let n = 1_000_000u64;
    let kahan = hwcost::training_bytes("kahan16", n, 2) as f64;
    let fp32 = hwcost::training_bytes("fp32", n, 2) as f64;
    let mixed = hwcost::training_bytes("mixed16", n, 2) as f64;
    let extra = format!(
        "\nAppendix B.2 check (Adam, 1M weights): kahan16 saves {:.1}% vs fp32 (paper: 33%), {:.1}% vs mixed (paper: 43%)\n",
        (1.0 - kahan / fp32) * 100.0,
        (1.0 - kahan / mixed) * 100.0
    );
    let s = t.render() + &extra;
    opts.write("table2.txt", &s)?;
    opts.write("table2.csv", &t.to_csv())?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Figure 2 + Theorem 1 — native least-squares theory validation.
// ---------------------------------------------------------------------------

pub fn fig2(opts: &ExpOptions) -> Result<String> {
    let cfg = LsqConfig {
        steps: opts.steps.unwrap_or(20_000) as usize,
        ..LsqConfig::default()
    };
    let data = LsqData::generate(&cfg);
    let mut t = Table::new(
        "Figure 2 — LSQ with selective nearest rounding (bf16, lr 0.01)",
        &["rounding placement", "final loss", "final ||w-w*||", "halted steps %"],
    );
    let mut csv = String::from("placement,step,loss\n");
    for placement in [
        Placement::Exact,
        Placement::ForwardBackward,
        Placement::WeightUpdate,
        Placement::Everywhere,
        Placement::WeightUpdateSr,
        Placement::WeightUpdateKahan,
    ] {
        let run = lsq::run(&cfg, &data, placement);
        t.row(vec![
            placement.name().into(),
            format!("{:.3e}", run.losses.last().copied().unwrap_or(f32::NAN)),
            format!("{:.3e}", run.final_dist),
            format!("{:.1}", run.halt_frac * 100.0),
        ]);
        for (i, l) in run.losses.iter().enumerate() {
            csv.push_str(&format!(
                "{},{},{:.6e}\n",
                placement.name(),
                i * run.sample_every,
                l
            ));
        }
    }
    let s = t.render();
    opts.write("fig2.txt", &s)?;
    opts.write("fig2.csv", &csv)?;
    Ok(s)
}

pub fn thm1(opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Theorem 1 — halting radius vs observed final distance (bf16)",
        &["lr", "predicted radius", "observed ||w-w*||", "observed/predicted"],
    );
    for lr in [0.001f32, 0.01, 0.1] {
        let cfg = LsqConfig {
            lr,
            steps: opts.steps.unwrap_or(30_000) as usize,
            noise_std: 0.0, // interpolation regime: A1 holds exactly
            ..LsqConfig::default()
        };
        let data = LsqData::generate(&cfg);
        let run = lsq::run(&cfg, &data, Placement::WeightUpdate);
        let radius = lsq::halting_radius(&cfg, &data);
        t.row(vec![
            format!("{lr}"),
            format!("{radius:.3e}"),
            format!("{:.3e}", run.final_dist),
            format!("{:.2}", run.final_dist / radius),
        ]);
    }
    let s = t.render()
        + "\nTheorem 1: smaller lr ⇒ LARGER halting radius (opposite of exact SGD).\n";
    opts.write("thm1.txt", &s)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Figure 1 / 6 — BERT-stand-in standard16 vs fp32 curves.
// ---------------------------------------------------------------------------

pub fn fig1(engine: &Engine, manifest: &Manifest, opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Figure 1 — transformer-cls: standard 16-bit-FPU vs 32-bit",
        &["algorithm", "final train acc %", "val acc %"],
    );
    for mode in ["fp32", "standard16"] {
        let rs = run_app(engine, manifest, "bert-cls", mode, "bf16", opts)?;
        export_curves(opts, "fig1", &rs)?;
        let train_acc: Vec<f64> = rs
            .iter()
            .map(|r| r.history.tail_metric(5) as f64 * 100.0)
            .collect();
        let (m, _) = mean_std(&train_acc);
        t.row(vec![mode.into(), format!("{m:.2}"), metric_cell(&rs)]);
    }
    let s = t.render();
    opts.write("fig1.txt", &s)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Table 3 / Figures 3 & 7 — the accuracy-bottleneck ablation.
// ---------------------------------------------------------------------------

pub fn table3(engine: &Engine, manifest: &Manifest, opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Table 3 — accuracy bottleneck ablation (metric: paper convention)",
        &["model", "metric", "32-bit", "standard 16-bit-FPU", "standard 16-bit + 32-bit weights"],
    );
    for app in ["cifar-cnn", "dlrm-small", "bert-cls"] {
        let mut cells = Vec::new();
        let mut metric_name = String::new();
        for mode in ["fp32", "standard16", "mixed16"] {
            let rs = run_app(engine, manifest, app, mode, "bf16", opts)?;
            export_curves(opts, "fig3", &rs)?;
            metric_name = rs[0].metric_name.clone();
            cells.push(metric_cell(&rs));
        }
        t.row(vec![
            app.into(),
            metric_name,
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
        ]);
    }
    let s = t.render()
        + "\nExpected shape (paper): column 3 < columns 2 & 4; ablating weight-update\nrounding (col 4) recovers 32-bit accuracy.\n";
    opts.write("table3.txt", &s)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Table 4 / Figures 4 & 8 — the main result across seven applications.
// ---------------------------------------------------------------------------

pub const TABLE4_APPS: [&str; 7] = [
    "cifar-cnn",
    "imagenet-cnn",
    "dlrm-small",
    "dlrm-large",
    "bert-cls",
    "bert-lm",
    "lstm-seq",
];

pub fn table4(
    engine: &Engine,
    manifest: &Manifest,
    opts: &ExpOptions,
    only_app: Option<&str>,
) -> Result<String> {
    let mut t = Table::new(
        "Table 4 — 16-bit-FPU training vs 32-bit across applications",
        &["model", "metric", "32-bit", "16-bit stochastic", "16-bit kahan", "16-bit standard"],
    );
    let apps: Vec<&str> = match only_app {
        Some(a) => vec![a],
        None => TABLE4_APPS.to_vec(),
    };
    let mut csv = String::from("app,mode,seed,metric_name,val_metric\n");
    for app in apps {
        let mut cells = BTreeMap::new();
        let mut metric_name = String::new();
        for mode in ["fp32", "sr16", "kahan16", "standard16"] {
            let rs = run_app(engine, manifest, app, mode, "bf16", opts)?;
            export_curves(opts, "fig4", &rs)?;
            metric_name = rs[0].metric_name.clone();
            for r in &rs {
                csv.push_str(&format!(
                    "{app},{mode},{},{},{:.4}\n",
                    r.seed, r.metric_name, r.val_metric
                ));
            }
            cells.insert(mode, metric_cell(&rs));
        }
        t.row(vec![
            app.into(),
            metric_name,
            cells["fp32"].clone(),
            cells["sr16"].clone(),
            cells["kahan16"].clone(),
            cells["standard16"].clone(),
        ]);
    }
    let s = t.render()
        + "\nExpected shape (paper): sr16/kahan16 within noise of 32-bit; standard16 clearly worse.\n";
    opts.write("table4.txt", &s)?;
    opts.write("table4.csv", &csv)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Figure 5 — SR↔Kahan memory/accuracy trade-off (native DLRM).
// ---------------------------------------------------------------------------

pub fn fig5(opts: &ExpOptions) -> Result<String> {
    let steps = opts.steps.unwrap_or(1200) as usize;
    let mut t = Table::new(
        "Figure 5 — DLRM: replacing SR with Kahan tensor-by-tensor",
        &["kahan tensors", "weight MB (rel.)", "val AUC %"],
    );
    let base_cfg = DlrmConfig::default();
    let n_tensors = base_cfg.num_tables + 6;
    // sweep: 0 tensors (all SR) … all tensors Kahan, embeddings first
    // (they dominate memory, exactly the paper's sweep axis).
    for kahan_k in [0usize, 2, 4, n_tensors] {
        let mut aucs = Vec::new();
        let mut bytes = 0u64;
        for seed in 0..opts.seeds {
            let cfg = DlrmConfig { seed, ..base_cfg.clone() };
            let modes: Vec<Mode> = (0..n_tensors)
                .map(|i| if i < kahan_k { Mode::Kahan16 } else { Mode::Sr16 })
                .collect();
            let mut tr = DlrmTrainer::new_mixed(cfg, modes.clone());
            bytes = tr.weight_bytes(&modes);
            for _ in 0..steps {
                tr.step(0.05);
            }
            let (_, auc) = tr.eval(16);
            aucs.push(auc as f64 * 100.0);
        }
        let (m, s) = mean_std(&aucs);
        let all_sr = DlrmTrainer::new(base_cfg.clone(), Mode::Sr16)
            .weight_bytes(&vec![Mode::Sr16; n_tensors]);
        t.row(vec![
            format!("{kahan_k}/{n_tensors}"),
            format!("{:.2}x", bytes as f64 / all_sr as f64),
            pm(m, s, 2),
        ]);
    }
    let s = t.render();
    opts.write("fig5.txt", &s)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Figure 9 — % of cancelled updates over training (native DLRM).
// ---------------------------------------------------------------------------

pub fn fig9(opts: &ExpOptions) -> Result<String> {
    let steps = opts.steps.unwrap_or(3000) as usize;
    let mut t = Table::new(
        "Figure 9 — % non-zero updates cancelled by nearest rounding",
        &["dataset proxy / lr", "phase", "embedding layer", "MLP layers"],
    );
    let mut csv = String::from("setting,step,embed_cancel_pct,mlp_cancel_pct,loss\n");
    // Kaggle proxy: constant lr (cancellation grows as gradients shrink);
    // Terabyte proxy: decaying lr (compound effect, paper App. D.3).
    for (label, decay) in [("kaggle-constant-lr", false), ("terabyte-decaying-lr", true)] {
        let cfg = DlrmConfig::default();
        let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
        let window = (steps / 40).max(1);
        let mut emb_acc = crate::qsim::UpdateStats::default();
        let mut mlp_acc = crate::qsim::UpdateStats::default();
        let mut loss_acc = 0f64;
        let mut early = (0f64, 0f64);
        let mut late = (0f64, 0f64);
        for step in 0..steps {
            let lr = if decay {
                let t = step as f32 / steps as f32;
                if t < 0.5 {
                    0.03
                } else {
                    0.03 * (1.0 - (t - 0.5) / 0.5).max(0.01)
                }
            } else {
                0.03
            };
            let tel = tr.step(lr);
            emb_acc.merge(tel.embed);
            mlp_acc.merge(tel.mlp);
            loss_acc += tel.loss as f64;
            if (step + 1) % window == 0 {
                let row = (emb_acc.frac() * 100.0, mlp_acc.frac() * 100.0);
                csv.push_str(&format!(
                    "{label},{},{:.2},{:.2},{:.4}\n",
                    step + 1,
                    row.0,
                    row.1,
                    loss_acc / window as f64
                ));
                if step < steps / 4 {
                    early = row;
                }
                late = row;
                emb_acc = Default::default();
                mlp_acc = Default::default();
                loss_acc = 0.0;
            }
        }
        t.row(vec![
            label.into(),
            "early (first quarter)".into(),
            format!("{:.1}%", early.0),
            format!("{:.1}%", early.1),
        ]);
        t.row(vec![
            label.into(),
            "late (final window)".into(),
            format!("{:.1}%", late.0),
            format!("{:.1}%", late.1),
        ]);
    }
    let s = t.render()
        + "\nExpected shape (paper): cancellation grows into the mid-to-late stage,\nreaching >50-80% for both layer types; lr decay compounds the effect.\n";
    opts.write("fig9.txt", &s)?;
    opts.write("fig9.csv", &csv)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Figure 10 / 12 — sub-16-bit and fp16 format sweeps (PJRT, DLRM).
// ---------------------------------------------------------------------------

pub fn fig10(engine: &Engine, manifest: &Manifest, opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Figure 10 — below 16-bit (DLRM; e8mN = 8 exp bits, N mantissa bits)",
        &["format (bits)", "standard", "stochastic", "kahan", "32-bit ref"],
    );
    let fp32 = run_app(engine, manifest, "dlrm-small", "fp32", "bf16", opts)?;
    let fp32_cell = metric_cell(&fp32);
    for fmt in ["bf16", "e8m5", "e8m3", "e8m1"] {
        let bits = Format::by_name(fmt).map(|f| f.total_bits()).unwrap_or(0);
        let mut cells = Vec::new();
        for mode in ["standard16", "sr16", "kahan16"] {
            let rs = run_app(engine, manifest, "dlrm-small", mode, fmt, opts)?;
            cells.push(metric_cell(&rs));
        }
        t.row(vec![
            format!("{fmt} ({bits}-bit)"),
            cells[0].clone(),
            cells[1].clone(),
            cells[2].clone(),
            fp32_cell.clone(),
        ]);
    }
    let s = t.render()
        + "\nExpected shape (paper): only 14-bit (e8m5) Kahan stays near 16/32-bit;\nlower precision degrades in all modes.\n";
    opts.write("fig10.txt", &s)?;
    Ok(s)
}

pub fn fig12(engine: &Engine, manifest: &Manifest, opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Figure 12 — Float16 (e5m10, no loss scaling) vs BFloat16 (DLRM)",
        &["format", "standard", "stochastic", "kahan"],
    );
    for fmt in ["bf16", "fp16"] {
        let mut cells = Vec::new();
        for mode in ["standard16", "sr16", "kahan16"] {
            let rs = run_app(engine, manifest, "dlrm-small", mode, fmt, opts)?;
            cells.push(metric_cell(&rs));
        }
        t.row(vec![fmt.into(), cells[0].clone(), cells[1].clone(), cells[2].clone()]);
    }
    let s = t.render()
        + "\nExpected shape (paper): fp16 lags bf16 even with SR/Kahan — dynamic range,\nnot mantissa, is the binding constraint.\n";
    opts.write("fig12.txt", &s)?;
    Ok(s)
}

// ---------------------------------------------------------------------------
// Figure 11 — combining SR + Kahan.
// ---------------------------------------------------------------------------

pub fn fig11(engine: &Engine, manifest: &Manifest, opts: &ExpOptions) -> Result<String> {
    let mut t = Table::new(
        "Figure 11 — stochastic rounding + Kahan simultaneously",
        &["model", "32-bit", "sr+kahan combined"],
    );
    for app in ["cifar-cnn", "dlrm-small", "bert-cls"] {
        let fp32 = run_app(engine, manifest, app, "fp32", "bf16", opts)?;
        let combo = run_app(engine, manifest, app, "srkahan16", "bf16", opts)?;
        export_curves(opts, "fig11", &combo)?;
        t.row(vec![app.into(), metric_cell(&fp32), metric_cell(&combo)]);
    }
    let s = t.render();
    opts.write("fig11.txt", &s)?;
    Ok(s)
}

/// Dispatch an experiment by id.  `engine`/`manifest` are created lazily by
/// the caller for PJRT-backed experiments.
pub fn run_experiment(
    id: &str,
    engine: Option<(&Engine, &Manifest)>,
    opts: &ExpOptions,
    only_app: Option<&str>,
) -> Result<String> {
    let need = |id: &str| -> Result<(&Engine, &Manifest)> {
        engine.with_context(|| format!("experiment {id} needs PJRT artifacts (run `make artifacts`)"))
    };
    Ok(match id {
        "table1" => table1(opts)?,
        "table2" => table2(opts)?,
        "fig2" => fig2(opts)?,
        "thm1" => thm1(opts)?,
        "fig5" => fig5(opts)?,
        "fig9" => fig9(opts)?,
        "fig1" | "fig6" => {
            let (e, m) = need(id)?;
            fig1(e, m, opts)?
        }
        "table3" | "fig3" | "fig7" => {
            let (e, m) = need(id)?;
            table3(e, m, opts)?
        }
        "table4" | "fig4" | "fig8" => {
            let (e, m) = need(id)?;
            table4(e, m, opts, only_app)?
        }
        "fig10" => {
            let (e, m) = need(id)?;
            fig10(e, m, opts)?
        }
        "fig11" => {
            let (e, m) = need(id)?;
            fig11(e, m, opts)?
        }
        "fig12" => {
            let (e, m) = need(id)?;
            fig12(e, m, opts)?
        }
        other => bail!(
            "unknown experiment {other:?}; available: table1 table2 table3 table4 \
             fig1 fig2 fig5 fig9 fig10 fig11 fig12 thm1 all"
        ),
    })
}

/// All experiment ids in dependency-light → heavy order.
pub const ALL_EXPERIMENTS: [&str; 12] = [
    "table1", "table2", "fig2", "thm1", "fig5", "fig9", "fig1", "table3", "fig10", "fig11",
    "fig12", "table4",
];
