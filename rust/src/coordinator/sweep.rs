//! Policy × seed sweep grids with threaded fan-out.
//!
//! A [`Sweep`] expands a base [`RunSpec`] into one cell per (policy, seed)
//! pair and runs the cells across worker threads.  Seeds are assigned
//! deterministically when the grid is built (`base_seed + seed_index`), and
//! results come back in grid order (policy-major, seed-minor) regardless of
//! scheduling, so a threaded sweep is bit-identical to a sequential one.
//!
//! Each worker owns its own PJRT [`Engine`] (clients are cheap on CPU and
//! the `xla` handle types are not `Send`); the parsed [`Manifest`] is shared
//! by reference.  A run that diverges is recorded as a NaN summary — that is
//! a *result* in this paper (standard16/fp16 are expected to fail on some
//! workloads) — while a run that cannot even start (missing artifact) fails
//! the whole sweep.
//!
//! ## Thread budget
//!
//! Sweep `--threads` fans *runs* out across workers; the per-run
//! `--intra-threads` knob parallelizes *within* a step.  The defaults
//! compose safely: cells inherit `intra_threads = 1`, and a cell asking for
//! *auto* sizing (`intra_threads == 0`) is clamped back to sequential when
//! the sweep runs multi-worker — every worker auto-sizing to all cores
//! would oversubscribe the machine `workers×`.  An explicit per-run thread
//! count always passes through.  Worker count never exceeds the number of
//! non-empty work chunks — the ceil-division chunk plan is recomputed so no
//! idle workers are spawned.

use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::config::{RunConfig, RunSpec};
use crate::metrics::History;
use crate::precision::Policy;
use crate::runtime::{Engine, Manifest};
use crate::Runner;

use super::trainer::{RunSummary, Trainer};

/// A policy × seed grid over one application.
#[derive(Debug, Clone)]
pub struct Sweep {
    base: RunSpec,
    policies: Vec<Policy>,
    seeds: u64,
    base_seed: u64,
    threads: Option<usize>,
}

impl Sweep {
    /// Sweep over the given base spec (application, step budget, paths…).
    pub fn new(base: RunSpec) -> Sweep {
        Sweep { base, policies: Vec::new(), seeds: 1, base_seed: 0, threads: None }
    }

    /// Add one policy to the grid.
    pub fn policy(mut self, p: Policy) -> Self {
        self.policies.push(p);
        self
    }

    /// Add several policies to the grid.
    pub fn policies(mut self, ps: impl IntoIterator<Item = Policy>) -> Self {
        self.policies.extend(ps);
        self
    }

    /// Number of seeds per policy (seed values `base_seed..base_seed+n`).
    pub fn seeds(mut self, n: u64) -> Self {
        self.seeds = n;
        self
    }

    /// First seed of the per-policy seed range (default 0).
    pub fn base_seed(mut self, s: u64) -> Self {
        self.base_seed = s;
        self
    }

    /// Cap the worker-thread count (default: available parallelism).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Expand the grid into per-cell configs, policy-major, seed-minor.
    pub fn cells(&self) -> Vec<RunConfig> {
        self.cells_for_workers(1)
    }

    /// Like [`Sweep::cells`], but with the multi-worker intra-thread rule
    /// applied: a cell that asks for *auto* intra-step sizing
    /// (`intra_threads == 0`) is clamped to sequential when runs fan out
    /// across `workers > 1` — every worker auto-sizing to all cores would
    /// oversubscribe the machine `workers×`.  An explicit thread count
    /// (builder or TOML `train.intra_threads`) is the caller's choice and
    /// always passes through.
    fn cells_for_workers(&self, workers: usize) -> Vec<RunConfig> {
        let mut cells = Vec::with_capacity(self.policies.len() * self.seeds as usize);
        for &p in &self.policies {
            for k in 0..self.seeds {
                let mut cfg = self.base.clone().policy(p).seed(self.base_seed + k).build();
                if workers > 1 && cfg.intra_threads == 0 {
                    cfg.intra_threads = 1;
                }
                cells.push(cfg);
            }
        }
        cells
    }

    /// Run every cell; results are in `cells()` order.
    pub fn run(&self, runner: &Runner) -> Result<SweepResults> {
        let n = self.policies.len() * self.seeds as usize;
        let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let threads = self.threads.unwrap_or(hw).min(n.max(1));
        let cells = self.cells_for_workers(threads);
        if threads <= 1 {
            // reuse the runner's engine (and its compiled-executable cache)
            let mut runs = Vec::with_capacity(n);
            for cfg in cells {
                runs.push(run_cell(runner.engine(), runner.manifest(), cfg)?);
            }
            return Ok(SweepResults { runs });
        }

        let manifest = runner.manifest();
        let slots: Vec<Mutex<Option<Result<RunSummary>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        // contiguous chunks: cells are policy-major, so one artifact's
        // cells stay on one worker and its executable cache amortizes the
        // XLA compilation instead of every worker recompiling every policy.
        // Ceil division can plan fewer non-empty chunks than `threads`
        // (e.g. 5 cells / 4 workers → 3 chunks of 2); recompute the worker
        // count from the chunk length so no idle worker is ever spawned.
        let chunk_len = n.div_ceil(threads);
        let threads = n.div_ceil(chunk_len);
        let mut work: Vec<Vec<(usize, RunConfig)>> = Vec::with_capacity(threads);
        let mut it = cells.into_iter().enumerate();
        for _ in 0..threads {
            work.push(it.by_ref().take(chunk_len).collect());
        }
        debug_assert!(work.iter().all(|c| !c.is_empty()), "idle sweep worker planned");
        std::thread::scope(|s| {
            for chunk in work {
                if chunk.is_empty() {
                    continue; // defensive: the recomputed plan has none
                }
                let slots = &slots;
                s.spawn(move || {
                    let engine = match Engine::cpu() {
                        Ok(e) => e,
                        Err(e) => {
                            let msg = format!("sweep worker engine: {e:#}");
                            for (i, _) in &chunk {
                                *slots[*i].lock().unwrap() = Some(Err(anyhow!("{msg}")));
                            }
                            return;
                        }
                    };
                    for (i, cfg) in chunk {
                        let r = run_cell(&engine, manifest, cfg);
                        *slots[i].lock().unwrap() = Some(r);
                    }
                });
            }
        });
        let mut runs = Vec::with_capacity(n);
        for slot in slots {
            let r = slot.into_inner().unwrap().context("sweep worker never reported")?;
            runs.push(r?);
        }
        Ok(SweepResults { runs })
    }
}

/// Sweep output, in grid order (policy-major, seed-minor).
#[derive(Debug, Clone)]
pub struct SweepResults {
    pub runs: Vec<RunSummary>,
}

impl SweepResults {
    /// All runs of one policy, seed-ascending.
    pub fn for_policy(&self, p: &Policy) -> Vec<&RunSummary> {
        self.runs.iter().filter(|r| r.policy == *p).collect()
    }
}

/// Run one grid cell.  Divergence becomes a NaN summary; failure to start
/// (e.g. missing artifact) is a hard error.
fn run_cell(engine: &Engine, manifest: &Manifest, cfg: RunConfig) -> Result<RunSummary> {
    let label = cfg.artifact_name();
    let seed = cfg.seed;
    let app = cfg.app.clone();
    let policy = cfg.policy;
    let intra_threads = cfg.intra_threads;
    eprintln!("  [{label} seed={seed}] {} steps…", cfg.steps);
    let mut tr = Trainer::new(engine, manifest, cfg)?;
    match tr.run() {
        Ok(summary) => {
            eprintln!(
                "  [{label} seed={seed}] {}={:.3} loss={:.4} cancel={:.1}% ({:.1}s, {:.1} steps/s)",
                summary.metric_name,
                summary.val_metric,
                summary.final_train_loss,
                summary.mean_cancel_frac * 100.0,
                summary.wallclock_s,
                summary.steps_per_s
            );
            Ok(summary)
        }
        Err(e) => {
            // A diverged run is a *result* (the standard16/fp16 modes are
            // expected to fail on some workloads) — record NaN and continue.
            eprintln!("  [{label} seed={seed}] FAILED: {e}");
            Ok(RunSummary {
                app,
                policy,
                seed,
                steps: 0,
                val_metric: f64::NAN,
                metric_name: "failed".into(),
                final_train_loss: f64::NAN,
                mean_cancel_frac: f64::NAN,
                history: History::default(),
                wallclock_s: 0.0,
                steps_per_s: 0.0,
                intra_threads,
            })
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Mode;

    #[test]
    fn grid_is_policy_major_with_deterministic_seeds() {
        let sweep = Sweep::new(RunSpec::new("lsq").steps(10))
            .policies([Policy::bf16(Mode::Fp32), Policy::bf16(Mode::Sr16)])
            .seeds(3)
            .base_seed(100);
        let cells = sweep.cells();
        assert_eq!(cells.len(), 6);
        assert_eq!(cells[0].policy, Policy::bf16(Mode::Fp32));
        assert_eq!(cells[0].seed, 100);
        assert_eq!(cells[2].seed, 102);
        assert_eq!(cells[3].policy, Policy::bf16(Mode::Sr16));
        assert_eq!(cells[3].seed, 100);
        for c in &cells {
            assert_eq!(c.steps, 10);
        }
    }

    #[test]
    fn multi_worker_sweep_clamps_auto_intra_threads() {
        // auto sizing (0) is fine single-worker but must not survive a
        // multi-worker fan-out (workers × cores oversubscription)
        let sweep = Sweep::new(RunSpec::new("lsq").steps(10).intra_threads(0))
            .policies([Policy::bf16(Mode::Fp32), Policy::bf16(Mode::Sr16)])
            .seeds(2);
        assert!(sweep.cells_for_workers(1).iter().all(|c| c.intra_threads == 0));
        assert!(sweep.cells_for_workers(4).iter().all(|c| c.intra_threads == 1));
        // the sequential default is untouched either way
        let sweep = Sweep::new(RunSpec::new("lsq").steps(10))
            .policy(Policy::bf16(Mode::Fp32))
            .seeds(2);
        assert!(sweep.cells_for_workers(4).iter().all(|c| c.intra_threads == 1));
        // an explicit per-run thread count always passes through
        let sweep = Sweep::new(RunSpec::new("lsq").steps(10).intra_threads(2))
            .policy(Policy::bf16(Mode::Fp32))
            .seeds(3);
        assert!(sweep.cells_for_workers(4).iter().all(|c| c.intra_threads == 2));
    }

    #[test]
    fn chunk_plan_never_leaves_idle_workers() {
        // the replanned worker count used by `run`: every worker gets a
        // non-empty contiguous chunk for any (cells, threads) combination
        for n in 1usize..40 {
            for req in 1usize..10 {
                let threads = req.min(n);
                let chunk_len = n.div_ceil(threads);
                let replanned = n.div_ceil(chunk_len);
                assert!(replanned <= threads, "n={n} req={req}");
                let last = n - chunk_len * (replanned - 1);
                assert!((1..=chunk_len).contains(&last), "n={n} req={req}");
            }
        }
    }
}
