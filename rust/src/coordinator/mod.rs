//! L3 coordinator: the training loop, run configs, checkpointing, and the
//! experiment harness that regenerates every paper table and figure.

pub mod experiments;
mod trainer;

pub use experiments::{run_experiment, ExpOptions, ALL_EXPERIMENTS, TABLE4_APPS};
pub use trainer::{RunSummary, Trainer};
