//! L3 coordinator: the training loop, run configs, checkpointing, the
//! threaded policy × seed sweep, and the experiment registry that
//! regenerates every paper table and figure.

pub mod experiments;
pub mod sweep;
mod trainer;

pub use experiments::{
    find_experiment, run_experiment, ExpContext, ExpOptions, Experiment, ALL_EXPERIMENTS,
    EXPERIMENTS, TABLE4_APPS,
};
pub use sweep::{Sweep, SweepResults};
pub use trainer::{RunSummary, Trainer};
