//! Run metrics: online statistics, smoothing, AUC, perplexity helpers and
//! the run-history recorder the coordinator logs into.

use std::fmt::Write as _;

/// ROC AUC from (score, is_positive) pairs (the paper's DLRM metric).
///
/// Rank-sum (Mann–Whitney U) formulation with average ranks for ties.
/// Total-order sort (`f32::total_cmp`), so non-finite scores — exactly what
/// a diverging `standard16` run produces — rank deterministically (NaNs
/// above +inf) instead of panicking mid-experiment.
pub fn auc(scored: &[(f32, bool)]) -> f32 {
    let pos = scored.iter().filter(|(_, y)| *y).count();
    let neg = scored.len() - pos;
    if pos == 0 || neg == 0 {
        return 0.5;
    }
    let mut sorted: Vec<&(f32, bool)> = scored.iter().collect();
    sorted.sort_by(|a, b| a.0.total_cmp(&b.0));
    // average ranks over tie groups
    let mut rank_sum_pos = 0f64;
    let mut i = 0;
    while i < sorted.len() {
        let mut j = i;
        while j + 1 < sorted.len() && sorted[j + 1].0 == sorted[i].0 {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0; // 1-based
        for item in &sorted[i..=j] {
            if item.1 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }
    let u = rank_sum_pos - (pos as f64 * (pos as f64 + 1.0)) / 2.0;
    (u / (pos as f64 * neg as f64)) as f32
}

/// Exponential moving average smoother (the paper's curves are smoothed;
/// Figure 6 shows the unsmoothed variant — `alpha = 1` disables smoothing).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    state: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Self { alpha, state: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let s = match self.state {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.state = Some(s);
        s
    }
}

/// Mean and sample standard deviation (the paper reports mean ± std over
/// 3 seeds).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    if xs.is_empty() {
        return (f64::NAN, f64::NAN);
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    if xs.len() < 2 {
        return (mean, 0.0);
    }
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1.0);
    (mean, var.sqrt())
}

/// One logged training point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HistoryPoint {
    pub step: u64,
    pub loss: f32,
    pub metric: f32,
    pub cancel_frac: f32,
    pub lr: f32,
}

/// Append-only run history with CSV export.
#[derive(Debug, Default, Clone)]
pub struct History {
    pub points: Vec<HistoryPoint>,
}

impl History {
    pub fn push(&mut self, p: HistoryPoint) {
        self.points.push(p);
    }

    pub fn last_metric(&self) -> Option<f32> {
        self.points.last().map(|p| p.metric)
    }

    /// Mean metric over the final `k` points (end-of-training estimate).
    pub fn tail_metric(&self, k: usize) -> f32 {
        let n = self.points.len();
        if n == 0 {
            return f32::NAN;
        }
        let s = n.saturating_sub(k);
        let tail = &self.points[s..];
        tail.iter().map(|p| p.metric).sum::<f32>() / tail.len() as f32
    }

    pub fn tail_loss(&self, k: usize) -> f32 {
        let n = self.points.len();
        if n == 0 {
            return f32::NAN;
        }
        let s = n.saturating_sub(k);
        let tail = &self.points[s..];
        tail.iter().map(|p| p.loss).sum::<f32>() / tail.len() as f32
    }

    /// CSV with optional EMA smoothing of loss/metric columns.
    pub fn to_csv(&self, smooth_alpha: Option<f64>) -> String {
        let mut out = String::from("step,loss,metric,cancel_frac,lr\n");
        let mut ema_l = smooth_alpha.map(Ema::new);
        let mut ema_m = smooth_alpha.map(Ema::new);
        for p in &self.points {
            let l = match &mut ema_l {
                Some(e) => e.update(p.loss as f64),
                None => p.loss as f64,
            };
            let m = match &mut ema_m {
                Some(e) => e.update(p.metric as f64),
                None => p.metric as f64,
            };
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{:.4},{:.6}",
                p.step, l, m, p.cancel_frac, p.lr
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_and_random() {
        let perfect: Vec<(f32, bool)> =
            (0..100).map(|i| (i as f32, i >= 50)).collect();
        assert!((auc(&perfect) - 1.0).abs() < 1e-6);
        let inverted: Vec<(f32, bool)> =
            (0..100).map(|i| (i as f32, i < 50)).collect();
        assert!(auc(&inverted).abs() < 1e-6);
        let all_pos: Vec<(f32, bool)> = (0..10).map(|i| (i as f32, true)).collect();
        assert_eq!(auc(&all_pos), 0.5);
    }

    #[test]
    fn auc_with_ties_is_half_credit() {
        let tied = vec![(0.5f32, true), (0.5, false), (0.5, true), (0.5, false)];
        assert!((auc(&tied) - 0.5).abs() < 1e-6);
    }

    /// Diverged runs hand AUC NaN/inf logits; it must stay total and finite
    /// (it used to panic in `partial_cmp(..).unwrap()`).
    #[test]
    fn auc_survives_non_finite_scores() {
        let scored = vec![
            (f32::NAN, true),
            (0.3, false),
            (f32::INFINITY, true),
            (f32::NEG_INFINITY, false),
            (0.7, true),
            (f32::NAN, false),
        ];
        let a = auc(&scored);
        assert!(a.is_finite());
        assert!((0.0..=1.0).contains(&a), "{a}");
        // all-NaN input is likewise defined
        let nans = vec![(f32::NAN, true), (f32::NAN, false)];
        assert!(auc(&nans).is_finite());
    }

    #[test]
    fn ema_smooths_towards_signal() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.update(10.0), 10.0);
        assert_eq!(e.update(0.0), 5.0);
        let mut id = Ema::new(1.0);
        id.update(3.0);
        assert_eq!(id.update(7.0), 7.0);
    }

    #[test]
    fn mean_std_matches_paper_convention() {
        let (m, s) = mean_std(&[1.0, 2.0, 3.0]);
        assert!((m - 2.0).abs() < 1e-12);
        assert!((s - 1.0).abs() < 1e-12);
        let (m1, s1) = mean_std(&[5.0]);
        assert_eq!((m1, s1), (5.0, 0.0));
    }

    #[test]
    fn history_csv_and_tail() {
        let mut h = History::default();
        for i in 0..10 {
            h.push(HistoryPoint {
                step: i,
                loss: 10.0 - i as f32,
                metric: i as f32 / 10.0,
                cancel_frac: 0.0,
                lr: 0.1,
            });
        }
        assert_eq!(h.last_metric(), Some(0.9));
        assert!((h.tail_metric(3) - 0.8).abs() < 1e-6);
        let csv = h.to_csv(None);
        assert_eq!(csv.lines().count(), 11);
        assert!(csv.starts_with("step,loss"));
    }
}
