//! Shared binary framing for the `BF16CKP2` checkpoint format.
//!
//! Two writers produce this format — the PJRT coordinator trainer
//! (`coordinator::Trainer`) and the native quantised-simulator engine
//! (`qsim::train::Trainer`) — so the length-prefixed primitives live here
//! instead of being re-derived (and drifting) in each.  The layout is
//! deliberately dumb: a magic, then a sequence of `u64`-length-prefixed
//! strings / f32 slices, every integer little-endian.  Readers validate
//! every length against the remaining buffer, so a truncated or corrupted
//! file fails with a clear error instead of a panic or a wrapped index.
//!
//! Integrity: framed (`Writer::new`) checkpoints end with an 8-byte footer
//! — the tag `CRCF` followed by the little-endian CRC-32 of everything
//! before it — which `Reader::new` verifies and strips, so a bit flip or a
//! mid-write truncation anywhere in the file fails loudly.  Footer-less
//! files from older builds are still accepted (their per-field bounds
//! checks remain the only guard).  `Reader::expect_end` additionally
//! rejects trailing garbage once a loader has consumed every field.
//!
//! The same primitives serve the `qsim::shard` wire layer through
//! `Writer::bare` / `Reader::bare`: no magic, no footer — message payloads
//! are integrity-checked by their enclosing frame instead.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::crc::crc32;

/// Version-2 magic: the header carries the artifact/app name so resuming
/// into a mismatched run fails loudly instead of silently loading
/// same-shaped tensors.
pub const MAGIC_V2: &[u8; 8] = b"BF16CKP2";
/// Legacy v1 magic — recognised only to produce a better error.
pub const MAGIC_V1: &[u8; 8] = b"BF16CKPT";
/// Tag introducing the trailing CRC-32 footer of a framed checkpoint.
pub const CRC_TAG: &[u8; 4] = b"CRCF";

/// Append-only builder for a v2 checkpoint body (magic written up front,
/// CRC-32 footer appended by `into_bytes`).
pub struct Writer {
    buf: Vec<u8>,
    framed: bool,
}

impl Writer {
    pub fn new() -> Writer {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC_V2);
        Writer { buf, framed: true }
    }

    /// Builder without magic or footer, for message payloads that are
    /// framed (and checksummed) by an outer layer.
    pub fn bare() -> Writer {
        Writer { buf: Vec::new(), framed: false }
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Single f32, bit pattern preserved exactly.
    pub fn f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed raw byte blob (e.g. a nested checkpoint image).
    pub fn blob(&mut self, bytes: &[u8]) {
        self.u64(bytes.len() as u64);
        self.buf.extend_from_slice(bytes);
    }

    /// Length-prefixed f32 slice (bit patterns preserved exactly).
    pub fn f32s(&mut self, vals: &[f32]) {
        self.u64(vals.len() as u64);
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Presence byte + length-prefixed slice (optional state tensors).
    pub fn opt_f32s(&mut self, vals: Option<&[f32]>) {
        match vals {
            Some(v) => {
                self.u8(1);
                self.f32s(v);
            }
            None => self.u8(0),
        }
    }

    pub fn into_bytes(mut self) -> Vec<u8> {
        if self.framed {
            let crc = crc32(&self.buf);
            self.buf.extend_from_slice(CRC_TAG);
            self.buf.extend_from_slice(&crc.to_le_bytes());
        }
        self.buf
    }
}

impl Default for Writer {
    fn default() -> Self {
        Self::new()
    }
}

/// Bounds-checked cursor over a v2 checkpoint buffer.
pub struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    /// Validate the magic (distinguishing the legacy v1 format), verify and
    /// strip the CRC-32 footer when present, and position the cursor after
    /// the magic.
    pub fn new(buf: &'a [u8]) -> Result<Reader<'a>> {
        if buf.len() >= 8 && &buf[..8] == MAGIC_V1 {
            bail!(
                "checkpoint is in the legacy v1 format, which lacks the artifact-name \
                 header and cannot be validated against this run; regenerate it by \
                 training and saving again with this version"
            );
        }
        if buf.len() < 8 || &buf[..8] != MAGIC_V2 {
            bail!("not a bf16-train checkpoint");
        }
        let body = if buf.len() >= 16 && &buf[buf.len() - 8..buf.len() - 4] == CRC_TAG {
            let stored = u32::from_le_bytes(buf[buf.len() - 4..].try_into().unwrap());
            let body = &buf[..buf.len() - 8];
            let actual = crc32(body);
            if stored != actual {
                bail!(
                    "checkpoint failed CRC-32 validation (stored {stored:08x}, computed \
                     {actual:08x}): the file was corrupted, truncated, or partially written"
                );
            }
            body
        } else {
            // footer-less file from an older build: per-field bounds checks
            // are the only integrity guard
            buf
        };
        Ok(Reader { buf: body, off: 8 })
    }

    /// Cursor over a bare (magic-less, footer-less) payload.
    pub fn bare(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, off: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.off)
    }

    /// Error unless every byte has been consumed — catches trailing
    /// garbage that the field-by-field loaders would silently ignore.
    pub fn expect_end(&self) -> Result<()> {
        if self.remaining() != 0 {
            bail!(
                "checkpoint has {} unread trailing bytes: corrupted, or written \
                 by a newer format",
                self.remaining()
            );
        }
        Ok(())
    }

    pub fn u64(&mut self) -> Result<u64> {
        if self.remaining() < 8 {
            bail!("truncated checkpoint");
        }
        let v = u64::from_le_bytes(self.buf[self.off..self.off + 8].try_into().unwrap());
        self.off += 8;
        Ok(v)
    }

    pub fn u8(&mut self) -> Result<u8> {
        if self.remaining() < 1 {
            bail!("truncated checkpoint");
        }
        let v = self.buf[self.off];
        self.off += 1;
        Ok(v)
    }

    pub fn f32(&mut self) -> Result<f32> {
        if self.remaining() < 4 {
            bail!("truncated checkpoint");
        }
        let v = f32::from_le_bytes(self.buf[self.off..self.off + 4].try_into().unwrap());
        self.off += 4;
        Ok(v)
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.u64()? as usize;
        // compare against the remainder (not `off + len`, which could wrap
        // for a huge length read from a corrupted file)
        if len > self.remaining() {
            bail!("truncated checkpoint");
        }
        let s = std::str::from_utf8(&self.buf[self.off..self.off + len])
            .context("checkpoint string is not utf-8")?
            .to_string();
        self.off += len;
        Ok(s)
    }

    pub fn blob(&mut self) -> Result<Vec<u8>> {
        let len = self.u64()? as usize;
        if len > self.remaining() {
            bail!("truncated checkpoint");
        }
        let b = self.buf[self.off..self.off + len].to_vec();
        self.off += len;
        Ok(b)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()? as usize;
        let byte_len = len
            .checked_mul(4)
            .with_context(|| format!("corrupt checkpoint: tensor length {len}"))?;
        if byte_len > self.remaining() {
            bail!("truncated checkpoint");
        }
        let mut vals = Vec::with_capacity(len);
        for k in 0..len {
            vals.push(f32::from_le_bytes(
                self.buf[self.off + k * 4..self.off + k * 4 + 4].try_into().unwrap(),
            ));
        }
        self.off += byte_len;
        Ok(vals)
    }

    pub fn opt_f32s(&mut self) -> Result<Option<Vec<f32>>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f32s()?)),
            other => bail!("corrupt checkpoint: bad option tag {other}"),
        }
    }
}

/// Read the app name out of a native-trainer checkpoint header without
/// loading it — `repro serve --app auto` dispatches on this.  Validates
/// magic + CRC (via [`Reader::new`]) and requires the `qsim/<app>` header
/// tag the native [`Trainer`](crate::qsim::train::Trainer) writes;
/// coordinator checkpoints (different tag) are rejected by name.
pub fn peek_app_name(bytes: &[u8]) -> Result<String> {
    let tag = Reader::new(bytes)?.str().context("reading checkpoint header tag")?;
    match tag.strip_prefix("qsim/") {
        Some(app) if !app.is_empty() => Ok(app.to_string()),
        _ => bail!("checkpoint header {tag:?} is not a native qsim/<app> checkpoint"),
    }
}

/// Write `bytes` to `path` atomically: stage into a sibling temp file, then
/// rename over the destination, so a crash mid-write can never leave a
/// truncated checkpoint under the real name.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let tmp = path.with_file_name(format!(
        "{}.tmp.{}",
        path.file_name().and_then(|n| n.to_str()).unwrap_or("ckpt"),
        std::process::id()
    ));
    std::fs::write(&tmp, bytes)
        .with_context(|| format!("writing checkpoint staging file {}", tmp.display()))?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e).with_context(|| {
            format!("renaming checkpoint {} -> {}", tmp.display(), path.display())
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peeks_app_name_from_header() {
        let mut w = Writer::new();
        w.str("qsim/gpt-nano");
        w.u64(7);
        let bytes = w.into_bytes();
        assert_eq!(peek_app_name(&bytes).unwrap(), "gpt-nano");

        let mut other = Writer::new();
        other.str("coord/dlrm");
        let err = peek_app_name(&other.into_bytes()).unwrap_err().to_string();
        assert!(err.contains("coord/dlrm"), "should name the bad tag: {err}");

        // corrupt CRC is rejected before any header parsing
        let mut bad = Writer::new();
        bad.str("qsim/dlrm");
        let mut img = bad.into_bytes();
        let n = img.len();
        img[n - 1] ^= 0xff;
        assert!(peek_app_name(&img).is_err());
    }

    #[test]
    fn round_trips_every_primitive() {
        let mut w = Writer::new();
        w.str("qsim/dlrm");
        w.u64(42);
        w.f32(0.25);
        w.blob(&[7, 8, 9]);
        w.f32s(&[1.5, -0.25, f32::from_bits(0x7fc0_0001)]); // incl. a NaN payload
        w.opt_f32s(None);
        w.opt_f32s(Some(&[2.0]));
        let bytes = w.into_bytes();
        assert_eq!(&bytes[..8], MAGIC_V2);
        assert_eq!(&bytes[bytes.len() - 8..bytes.len() - 4], CRC_TAG);

        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.str().unwrap(), "qsim/dlrm");
        assert_eq!(r.u64().unwrap(), 42);
        assert_eq!(r.f32().unwrap(), 0.25);
        assert_eq!(r.blob().unwrap(), vec![7, 8, 9]);
        let vals = r.f32s().unwrap();
        assert_eq!(vals.len(), 3);
        assert_eq!(vals[0], 1.5);
        assert_eq!(vals[2].to_bits(), 0x7fc0_0001, "bit patterns survive");
        assert!(r.opt_f32s().unwrap().is_none());
        assert_eq!(r.opt_f32s().unwrap().unwrap(), vec![2.0]);
        r.expect_end().unwrap();
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(Reader::new(b"nonsense").is_err());
        let v1_err = Reader::new(b"BF16CKPTxxxx").unwrap_err().to_string();
        assert!(v1_err.contains("legacy v1"), "{v1_err}");

        let mut w = Writer::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let mut bytes = w.into_bytes();
        // cut into the tensor data (past the 8-byte footer)
        bytes.truncate(bytes.len() - 10);
        let mut r = Reader::new(&bytes).unwrap();
        assert!(r.f32s().is_err(), "truncated slice must error");

        // a huge declared length must not wrap the offset
        let mut w = Writer::new();
        w.u64(u64::MAX);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes).unwrap();
        assert!(r.str().is_err());
    }

    #[test]
    fn crc_footer_catches_any_single_byte_corruption() {
        let mut w = Writer::new();
        w.str("qsim/mlp");
        w.u64(7);
        w.f32s(&[0.5, 1.5, -2.5, 3.25]);
        let bytes = w.into_bytes();
        // deterministic pseudo-random offsets over the whole file,
        // including magic and footer
        let mut x = 0x9E37_79B9u64;
        for _ in 0..64 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let off = (x >> 33) as usize % bytes.len();
            let bit = (x >> 29 & 7) as u8;
            let mut m = bytes.clone();
            m[off] ^= 1 << bit;
            let r = Reader::new(&m);
            let failed = match r {
                Err(_) => true,
                Ok(mut r) => {
                    // even if the flip lands in the footer tag (demoting the
                    // file to "legacy"), the trailing bytes must surface via
                    // expect_end after a full read
                    (|| -> Result<()> {
                        r.str()?;
                        r.u64()?;
                        r.f32s()?;
                        r.expect_end()
                    })()
                    .is_err()
                }
            };
            assert!(failed, "corruption at byte {off} bit {bit} went undetected");
        }
    }

    #[test]
    fn footerless_legacy_bytes_still_load() {
        let mut w = Writer::new();
        w.str("qsim/dlrm");
        w.u64(3);
        let mut bytes = w.into_bytes();
        bytes.truncate(bytes.len() - 8); // strip the footer: pre-CRC file
        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.str().unwrap(), "qsim/dlrm");
        assert_eq!(r.u64().unwrap(), 3);
        r.expect_end().unwrap();
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_staging_file() {
        let dir = std::env::temp_dir().join(format!("ckpt-atomic-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("model.ckpt");
        write_atomic(&path, b"first").unwrap();
        write_atomic(&path, b"second").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"second");
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().into_string().unwrap())
            .filter(|n| n != "model.ckpt")
            .collect();
        assert!(leftovers.is_empty(), "staging files left behind: {leftovers:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
