//! Shared binary framing for the `BF16CKP2` checkpoint format.
//!
//! Two writers produce this format — the PJRT coordinator trainer
//! (`coordinator::Trainer`) and the native quantised-simulator engine
//! (`qsim::train::Trainer`) — so the length-prefixed primitives live here
//! instead of being re-derived (and drifting) in each.  The layout is
//! deliberately dumb: a magic, then a sequence of `u64`-length-prefixed
//! strings / f32 slices, every integer little-endian.  Readers validate
//! every length against the remaining buffer, so a truncated or corrupted
//! file fails with a clear error instead of a panic or a wrapped index.

use anyhow::{bail, Context, Result};

/// Version-2 magic: the header carries the artifact/app name so resuming
/// into a mismatched run fails loudly instead of silently loading
/// same-shaped tensors.
pub const MAGIC_V2: &[u8; 8] = b"BF16CKP2";
/// Legacy v1 magic — recognised only to produce a better error.
pub const MAGIC_V1: &[u8; 8] = b"BF16CKPT";

/// Append-only builder for a v2 checkpoint body (magic written up front).
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Writer {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC_V2);
        Writer { buf }
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.buf.extend_from_slice(s.as_bytes());
    }

    /// Length-prefixed f32 slice (bit patterns preserved exactly).
    pub fn f32s(&mut self, vals: &[f32]) {
        self.u64(vals.len() as u64);
        for v in vals {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    /// Presence byte + length-prefixed slice (optional state tensors).
    pub fn opt_f32s(&mut self, vals: Option<&[f32]>) {
        match vals {
            Some(v) => {
                self.u8(1);
                self.f32s(v);
            }
            None => self.u8(0),
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

impl Default for Writer {
    fn default() -> Self {
        Self::new()
    }
}

/// Bounds-checked cursor over a v2 checkpoint buffer.
pub struct Reader<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Reader<'a> {
    /// Validate the magic (distinguishing the legacy v1 format) and
    /// position the cursor after it.
    pub fn new(buf: &'a [u8]) -> Result<Reader<'a>> {
        if buf.len() >= 8 && &buf[..8] == MAGIC_V1 {
            bail!(
                "checkpoint is in the legacy v1 format, which lacks the artifact-name \
                 header and cannot be validated against this run; regenerate it by \
                 training and saving again with this version"
            );
        }
        if buf.len() < 8 || &buf[..8] != MAGIC_V2 {
            bail!("not a bf16-train checkpoint");
        }
        Ok(Reader { buf, off: 8 })
    }

    fn remaining(&self) -> usize {
        self.buf.len().saturating_sub(self.off)
    }

    pub fn u64(&mut self) -> Result<u64> {
        if self.remaining() < 8 {
            bail!("truncated checkpoint");
        }
        let v = u64::from_le_bytes(self.buf[self.off..self.off + 8].try_into().unwrap());
        self.off += 8;
        Ok(v)
    }

    pub fn u8(&mut self) -> Result<u8> {
        if self.remaining() < 1 {
            bail!("truncated checkpoint");
        }
        let v = self.buf[self.off];
        self.off += 1;
        Ok(v)
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.u64()? as usize;
        // compare against the remainder (not `off + len`, which could wrap
        // for a huge length read from a corrupted file)
        if len > self.remaining() {
            bail!("truncated checkpoint");
        }
        let s = std::str::from_utf8(&self.buf[self.off..self.off + len])
            .context("checkpoint string is not utf-8")?
            .to_string();
        self.off += len;
        Ok(s)
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let len = self.u64()? as usize;
        let byte_len = len
            .checked_mul(4)
            .with_context(|| format!("corrupt checkpoint: tensor length {len}"))?;
        if byte_len > self.remaining() {
            bail!("truncated checkpoint");
        }
        let mut vals = Vec::with_capacity(len);
        for k in 0..len {
            vals.push(f32::from_le_bytes(
                self.buf[self.off + k * 4..self.off + k * 4 + 4].try_into().unwrap(),
            ));
        }
        self.off += byte_len;
        Ok(vals)
    }

    pub fn opt_f32s(&mut self) -> Result<Option<Vec<f32>>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f32s()?)),
            other => bail!("corrupt checkpoint: bad option tag {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_every_primitive() {
        let mut w = Writer::new();
        w.str("qsim/dlrm");
        w.u64(42);
        w.f32s(&[1.5, -0.25, f32::from_bits(0x7fc0_0001)]); // incl. a NaN payload
        w.opt_f32s(None);
        w.opt_f32s(Some(&[2.0]));
        let bytes = w.into_bytes();
        assert_eq!(&bytes[..8], MAGIC_V2);

        let mut r = Reader::new(&bytes).unwrap();
        assert_eq!(r.str().unwrap(), "qsim/dlrm");
        assert_eq!(r.u64().unwrap(), 42);
        let vals = r.f32s().unwrap();
        assert_eq!(vals.len(), 3);
        assert_eq!(vals[0], 1.5);
        assert_eq!(vals[2].to_bits(), 0x7fc0_0001, "bit patterns survive");
        assert!(r.opt_f32s().unwrap().is_none());
        assert_eq!(r.opt_f32s().unwrap().unwrap(), vec![2.0]);
    }

    #[test]
    fn rejects_bad_magic_and_truncation() {
        assert!(Reader::new(b"nonsense").is_err());
        let v1_err = Reader::new(b"BF16CKPTxxxx").unwrap_err().to_string();
        assert!(v1_err.contains("legacy v1"), "{v1_err}");

        let mut w = Writer::new();
        w.f32s(&[1.0, 2.0, 3.0]);
        let mut bytes = w.into_bytes();
        bytes.truncate(bytes.len() - 2);
        let mut r = Reader::new(&bytes).unwrap();
        assert!(r.f32s().is_err(), "truncated slice must error");

        // a huge declared length must not wrap the offset
        let mut w = Writer::new();
        w.u64(u64::MAX);
        let bytes = w.into_bytes();
        let mut r = Reader::new(&bytes).unwrap();
        assert!(r.str().is_err());
    }
}
