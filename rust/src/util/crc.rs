//! CRC-32 (IEEE 802.3, reflected polynomial 0xEDB88320), table-driven.
//!
//! Used as the integrity check on two wire-adjacent surfaces: the trailing
//! footer of `BF16CKP2` checkpoint files and the per-frame checksum of the
//! `qsim::shard` message layer.  The table is built at compile time so the
//! hot path is a single lookup per byte.

const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut bit = 0;
        while bit < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            bit += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const TABLE: [u32; 256] = build_table();

/// CRC-32 of `bytes` (IEEE: init all-ones, final complement).
pub fn crc32(bytes: &[u8]) -> u32 {
    !bytes
        .iter()
        .fold(!0u32, |c, &b| (c >> 8) ^ TABLE[((c ^ b as u32) & 0xFF) as usize])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical check value for the IEEE polynomial
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"a"), 0xE8B7_BE43);
    }

    #[test]
    fn single_bit_flip_changes_crc() {
        let base = b"the quick brown fox jumps over the lazy dog".to_vec();
        let c0 = crc32(&base);
        for byte in 0..base.len() {
            for bit in 0..8 {
                let mut m = base.clone();
                m[byte] ^= 1 << bit;
                assert_ne!(crc32(&m), c0, "flip at byte {byte} bit {bit} undetected");
            }
        }
    }
}
