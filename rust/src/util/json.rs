//! Minimal JSON parser/serializer substrate.
//!
//! The runtime's only structured interchange with the build-time python layer
//! is `artifacts/manifest.json` and `artifacts/golden_formats.json`; this
//! module parses them without external dependencies (the build environment
//! vendors only the `xla` crate closure).  It is a complete RFC 8259 reader
//! for the subset python's `json.dumps` emits: objects, arrays, strings with
//! escapes, numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for debuggability.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // -- typed accessors (None on type mismatch) ---------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| if f >= 0.0 { Some(f as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup (None if not an object or missing).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `get` chain helper: returns Json::Null reference semantics via Option.
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Json::as_usize)
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // BMP only (python json.dumps uses surrogate pairs
                            // beyond BMP; manifest content is ASCII).
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 sequence.
                    let s = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{"artifacts": [{"name": "lsq__sr16", "batch": 1,
            "shape": [10, 1], "dtype": "f32", "nested": {"a": true,
            "b": null, "c": -1.5e3}}], "stamp": "abc"}"#;
        let v = Json::parse(doc).unwrap();
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get_str("name"), Some("lsq__sr16"));
        assert_eq!(a.get_usize("batch"), Some(1));
        let shape: Vec<usize> =
            a.get("shape").unwrap().as_arr().unwrap().iter().map(|j| j.as_usize().unwrap()).collect();
        assert_eq!(shape, vec![10, 1]);
        assert_eq!(a.get("nested").unwrap().get("c").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(a.get("nested").unwrap().get("b"), Some(&Json::Null));
    }

    #[test]
    fn string_escapes_round_trip() {
        let v = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\nd\u{41}"));
        let s = Json::Str("a\"b\\c\nd".into()).to_string();
        assert_eq!(Json::parse(&s).unwrap().as_str(), Some("a\"b\\c\nd"));
    }

    #[test]
    fn numbers_and_big_ints() {
        // golden_formats.json stores u32 bit patterns as integers.
        let v = Json::parse("[0, 4294967295, 3.5, -2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[1].as_f64(), Some(4294967295.0));
        assert_eq!(a[1].as_i64(), Some(4294967295));
        assert_eq!(a[3].as_i64(), Some(-2));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn serializes_compact() {
        let v = Json::parse(r#"{"b": [1, 2], "a": "x"}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"a":"x","b":[1,2]}"#);
    }
}
