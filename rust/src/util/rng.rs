//! Deterministic PRNG substrate (splitmix64 / xoshiro256**).
//!
//! Used by the synthetic data pipeline, the rust-native quantised trainer's
//! stochastic rounding, and the property-test harness.  Deterministic,
//! seedable, dependency-free — data generation must be reproducible from a
//! (seed, stream) pair recorded in run metadata.

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a (seed, stream) pair; distinct streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xA3EC647659359ACD);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix cannot produce it
        // for all four outputs, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Bulk generation: fills `out` with the exact sequence that repeated
    /// [`Rng::next_u32`] calls would produce.  Hot loops (batched stochastic
    /// rounding) draw dither words through this so the generator state stays
    /// interchangeable with the scalar path.
    pub fn fill_u32(&mut self, out: &mut [u32]) {
        // Unrolled by four: the xoshiro state update has a serial dependency,
        // but splitting the output stores from the state recurrence lets the
        // compiler overlap them across iterations.
        let mut chunks = out.chunks_exact_mut(4);
        for c in &mut chunks {
            c[0] = self.next_u32();
            c[1] = self.next_u32();
            c[2] = self.next_u32();
            c[3] = self.next_u32();
        }
        for slot in chunks.into_remainder() {
            *slot = self.next_u32();
        }
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal (Box–Muller; one value per call, cheap enough).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f32::consts::TAU * u2).cos();
            }
        }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (categorical
    /// features in the synthetic CTR logs; token ids in the LM corpus).
    /// Rejection-inversion-free approximate sampler: inverse-CDF over the
    /// harmonic weights, precomputed by [`ZipfTable`] for hot use.
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        let u = self.uniform();
        table.sample(u)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Precomputed inverse-CDF table for Zipf sampling.
#[derive(Debug, Clone)]
pub struct ZipfTable {
    cdf: Vec<f32>,
}

impl ZipfTable {
    pub fn new(n: usize, exponent: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(exponent);
            cdf.push(acc as f32);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, u: f32) -> usize {
        // binary search for the first cdf entry >= u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 0);
        let mut c = Rng::new(42, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn fill_u32_matches_repeated_next_u32() {
        // every length class: empty, sub-unroll, exact multiple, ragged tail
        for len in [0usize, 1, 3, 4, 8, 17, 255, 256, 1000] {
            let mut a = Rng::new(0xF1, 7);
            let mut b = Rng::new(0xF1, 7);
            let mut buf = vec![0u32; len];
            a.fill_u32(&mut buf);
            let expect: Vec<u32> = (0..len).map(|_| b.next_u32()).collect();
            assert_eq!(buf, expect, "len={len}");
            // generator state must also land in the same place
            assert_eq!(a.next_u64(), b.next_u64(), "state diverged at len={len}");
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1, 0);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(7, 3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_head_heavy_and_in_range() {
        let table = ZipfTable::new(100, 1.2);
        let mut r = Rng::new(3, 0);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[r.zipf(&table)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9, 0);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
