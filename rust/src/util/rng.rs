//! Deterministic PRNG substrate (splitmix64 / xoshiro256**) plus the
//! counter-based keyed generator behind stochastic-rounding dither.
//!
//! Used by the synthetic data pipeline, the rust-native quantised trainer's
//! stochastic rounding, and the property-test harness.  Deterministic,
//! seedable, dependency-free — data generation must be reproducible from a
//! (seed, stream) pair recorded in run metadata.
//!
//! Two generator families live here:
//!
//! * [`Rng`] — a *sequential* stream (xoshiro256**): each draw advances
//!   hidden state, so consumers must draw in a fixed order.  Data
//!   generation and initialization use this.
//! * [`DitherKey`] — a *counter-based* keyed generator (splitmix64-style
//!   mix over `key + index·golden`): every output word is a pure function
//!   of `(seed, stream, step, tensor_id, element_index)`.  SR dither uses
//!   this, so any slice of any tensor can be rounded independently, in any
//!   order, on any thread, with bit-identical results (Gupta et al. 2015:
//!   SR's guarantees are order-independent — only stream plumbing isn't).

/// xoshiro256** with splitmix64 seeding.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a (seed, stream) pair; distinct streams are independent.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = seed ^ stream.wrapping_mul(0xA3EC647659359ACD);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        // All-zero state is invalid for xoshiro; splitmix cannot produce it
        // for all four outputs, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Self { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Bulk generation: fills `out` with the exact sequence that repeated
    /// [`Rng::next_u32`] calls would produce.  Hot loops (batched stochastic
    /// rounding) draw dither words through this so the generator state stays
    /// interchangeable with the scalar path.
    pub fn fill_u32(&mut self, out: &mut [u32]) {
        // Unrolled by four: the xoshiro state update has a serial dependency,
        // but splitting the output stores from the state recurrence lets the
        // compiler overlap them across iterations.
        let mut chunks = out.chunks_exact_mut(4);
        for c in &mut chunks {
            c[0] = self.next_u32();
            c[1] = self.next_u32();
            c[2] = self.next_u32();
            c[3] = self.next_u32();
        }
        for slot in chunks.into_remainder() {
            *slot = self.next_u32();
        }
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal (Box–Muller; one value per call, cheap enough).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-9 {
                let u2 = self.uniform();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (std::f32::consts::TAU * u2).cos();
            }
        }
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Zipf-distributed integer in [0, n) with exponent `s` (categorical
    /// features in the synthetic CTR logs; token ids in the LM corpus).
    /// Rejection-inversion-free approximate sampler: inverse-CDF over the
    /// harmonic weights, precomputed by [`ZipfTable`] for hot use.
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        let u = self.uniform();
        table.sample(u)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Finalizer of splitmix64 (Stafford's mix13 constants): a bijective
/// avalanche over u64, the mixing core of [`DitherKey`].
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// The golden-ratio increment of splitmix64 — the counter stride.
const GOLDEN: u64 = 0x9E3779B97F4A7C15;

/// Counter-based keyed RNG for stochastic-rounding dither.
///
/// A key is derived once per `(seed, stream, step, tensor_id)` quadruple;
/// dither word `i` is then `mix64(key + i·golden)` — exactly the splitmix64
/// sequence seeded at the key, addressed by position instead of generated by
/// mutation.  Because each word is a pure function of its coordinates:
///
/// * chunked / parallel rounding of a slice is bit-identical to whole-slice
///   rounding (element `i` always draws word `i`);
/// * the scalar `Reference` backend and the vectorized / multi-threaded
///   `Fast` backend consume the *same* dither schedule by construction;
/// * no stream position has to be maintained or replayed across skips.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct DitherKey(u64);

impl DitherKey {
    /// Derive the key for one `(seed, stream, step, tensor_id)` quadruple.
    ///
    /// Each coordinate is absorbed with its own odd multiplier and a full
    /// mix round, so keys differing in any single coordinate produce
    /// independent dither streams.
    pub fn new(seed: u64, stream: u64, step: u64, tensor_id: u64) -> Self {
        let mut k = seed ^ 0x243F_6A88_85A3_08D3; // pi: domain constant
        k = mix64(k.wrapping_add(stream.wrapping_mul(0xA3EC_6476_5935_9ACD)));
        k = mix64(k.wrapping_add(step.wrapping_mul(0xD6E8_FEB8_6659_FD93)));
        k = mix64(k.wrapping_add(tensor_id.wrapping_mul(0xCA5A_8263_9512_1157)));
        DitherKey(k)
    }

    /// Dither word for element `index` (the high 32 bits of the mixed
    /// counter, matching [`Rng::next_u32`]'s high-bits convention).
    #[inline]
    pub fn word(self, index: u64) -> u32 {
        (mix64(self.0.wrapping_add(index.wrapping_mul(GOLDEN))) >> 32) as u32
    }

    /// Bulk generation: `out[j] = self.word(base + j)`.
    pub fn fill(self, base: u64, out: &mut [u32]) {
        for (j, slot) in out.iter_mut().enumerate() {
            *slot = self.word(base.wrapping_add(j as u64));
        }
    }
}

/// Precomputed inverse-CDF table for Zipf sampling.
#[derive(Debug, Clone)]
pub struct ZipfTable {
    cdf: Vec<f32>,
}

impl ZipfTable {
    pub fn new(n: usize, exponent: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0f64;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(exponent);
            cdf.push(acc as f32);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, u: f32) -> usize {
        // binary search for the first cdf entry >= u
        match self.cdf.binary_search_by(|c| c.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let mut a = Rng::new(42, 0);
        let mut b = Rng::new(42, 0);
        let mut c = Rng::new(42, 1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn fill_u32_matches_repeated_next_u32() {
        // every length class: empty, sub-unroll, exact multiple, ragged tail
        for len in [0usize, 1, 3, 4, 8, 17, 255, 256, 1000] {
            let mut a = Rng::new(0xF1, 7);
            let mut b = Rng::new(0xF1, 7);
            let mut buf = vec![0u32; len];
            a.fill_u32(&mut buf);
            let expect: Vec<u32> = (0..len).map(|_| b.next_u32()).collect();
            assert_eq!(buf, expect, "len={len}");
            // generator state must also land in the same place
            assert_eq!(a.next_u64(), b.next_u64(), "state diverged at len={len}");
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(1, 0);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(7, 3);
        let n = 50_000;
        let xs: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn zipf_is_head_heavy_and_in_range() {
        let table = ZipfTable::new(100, 1.2);
        let mut r = Rng::new(3, 0);
        let mut counts = vec![0usize; 100];
        for _ in 0..20_000 {
            counts[r.zipf(&table)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50]);
    }

    #[test]
    fn dither_key_is_a_pure_function_of_coordinates() {
        let a = DitherKey::new(1, 2, 3, 4);
        let b = DitherKey::new(1, 2, 3, 4);
        assert_eq!(a, b);
        for i in [0u64, 1, 7, 1 << 40, u64::MAX] {
            assert_eq!(a.word(i), b.word(i));
        }
        // changing any single coordinate changes the stream
        for other in [
            DitherKey::new(9, 2, 3, 4),
            DitherKey::new(1, 9, 3, 4),
            DitherKey::new(1, 2, 9, 4),
            DitherKey::new(1, 2, 3, 9),
        ] {
            let same = (0..64).filter(|&i| other.word(i) == a.word(i)).count();
            assert!(same <= 1, "streams should not track each other ({same}/64 equal)");
        }
    }

    #[test]
    fn dither_key_fill_matches_word() {
        let key = DitherKey::new(0xF00, 0x51, 12, 3);
        for (base, len) in [(0u64, 17usize), (5, 256), (u64::MAX - 3, 8)] {
            let mut buf = vec![0u32; len];
            key.fill(base, &mut buf);
            for (j, &v) in buf.iter().enumerate() {
                assert_eq!(v, key.word(base.wrapping_add(j as u64)), "base={base} j={j}");
            }
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9, 0);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
