//! Dependency-free substrates: JSON, TOML-subset config parsing, PRNG,
//! CLI argument handling, table rendering, and the bench/property-test
//! harnesses.  The build environment vendors only the `xla` crate closure,
//! so everything else the framework needs is implemented here.

pub mod bench;
pub mod ckpt;
pub mod cli;
pub mod crc;
pub mod json;
pub mod rng;
pub mod table;
pub mod tomlmini;
