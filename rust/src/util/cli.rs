//! Minimal CLI argument parser (substrate; no external deps available).
//!
//! Grammar: positional arguments interleaved with `--flag`, `--key value`
//! and `--key=value` options.  Unknown flags are an error at `finish()`.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (no program name).
    pub fn parse(raw: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if rest.is_empty() {
                    bail!("bare -- is not supported");
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if let Some(v) = it.next_if(|n| !n.starts_with("--")) {
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional argument by index.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(String::as_str)
    }

    /// String option with default.
    pub fn opt(&mut self, key: &str, default: &str) -> String {
        self.consumed.push(key.to_string());
        self.options.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    /// Optional string option.
    pub fn opt_maybe(&mut self, key: &str) -> Option<String> {
        self.consumed.push(key.to_string());
        self.options.get(key).cloned()
    }

    /// Integer option with default.
    pub fn opt_u64(&mut self, key: &str, default: u64) -> Result<u64> {
        self.consumed.push(key.to_string());
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects an integer, got {v:?}")),
        }
    }

    /// Float option with default.
    pub fn opt_f64(&mut self, key: &str, default: f64) -> Result<f64> {
        self.consumed.push(key.to_string());
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} expects a number, got {v:?}")),
        }
    }

    /// Boolean flag presence.
    pub fn flag(&mut self, key: &str) -> bool {
        self.consumed.push(key.to_string());
        self.flags.iter().any(|f| f == key)
    }

    /// Error on any option/flag that was never consumed (typo guard).
    pub fn finish(&self) -> Result<()> {
        for k in self.options.keys() {
            if !self.consumed.contains(k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !self.consumed.contains(f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn positional_options_flags() {
        let mut a = parse("train lsq --steps 100 --lr=0.5 --verbose");
        assert_eq!(a.pos(0), Some("train"));
        assert_eq!(a.pos(1), Some("lsq"));
        assert_eq!(a.opt_u64("steps", 0).unwrap(), 100);
        assert_eq!(a.opt_f64("lr", 0.0).unwrap(), 0.5);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn unknown_option_rejected() {
        let mut a = parse("x --known 1 --typo 2");
        let _ = a.opt_u64("known", 0);
        assert!(a.finish().is_err());
    }

    #[test]
    fn negative_numbers_are_values() {
        let mut a = parse("--offset -5");
        assert_eq!(a.opt("offset", ""), "-5");
        a.finish().unwrap();
    }

    #[test]
    fn bad_int_is_error() {
        let mut a = parse("--steps abc");
        assert!(a.opt_u64("steps", 0).is_err());
    }
}
