//! Plain-text table rendering for the experiment harness (paper tables are
//! reproduced as aligned text + CSV files under `results/`).

/// A simple column-aligned table builder.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns and a title rule.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:<w$}"))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV export (for plotting).
    pub fn to_csv(&self) -> String {
        let mut out = self.header.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

/// `mean ± std` cell formatting, the paper's convention.
pub fn pm(mean: f64, std: f64, decimals: usize) -> String {
    format!("{mean:.decimals$} ± {std:.decimals$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["model", "acc"]);
        t.row(vec!["resnet".into(), "95.4".into()]);
        t.row(vec!["x".into(), "1".into()]);
        let s = t.render();
        assert!(s.contains("== Demo =="));
        let lines: Vec<&str> = s.lines().collect();
        // header and rows aligned on the same column start
        let col = lines[1].find("acc").unwrap();
        assert_eq!(lines[3].find("95.4"), Some(col));
    }

    #[test]
    fn csv_round_trip_arity() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        assert_eq!(t.to_csv(), "a,b\n1,2\n");
    }

    #[test]
    fn pm_formats() {
        assert_eq!(pm(95.448, 0.07, 2), "95.45 ± 0.07");
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
