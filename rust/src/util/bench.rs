//! Criterion-style micro-benchmark harness (substrate; criterion itself is
//! not available offline).  Median-of-samples timing with warmup, throughput
//! reporting, a `black_box` to defeat constant folding, and a JSON artifact
//! writer (`BENCH_*.json`) so bench trajectories survive across PRs.

use std::collections::BTreeMap;
use std::hint::black_box as bb;
use std::time::Instant;

use crate::util::json::Json;

/// Prevent the optimizer from eliding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub min_ns: f64,
    pub samples: usize,
}

impl BenchResult {
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(self.name.clone()));
        o.insert("median_ns".to_string(), Json::Num(self.median_ns));
        o.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        o.insert("min_ns".to_string(), Json::Num(self.min_ns));
        o.insert("samples".to_string(), Json::Num(self.samples as f64));
        Json::Obj(o)
    }

    pub fn report(&self) -> String {
        let (val, unit) = humanize(self.median_ns);
        format!(
            "{:<44} {:>9.3} {}/iter  (min {:.3} {}, {} samples)",
            self.name,
            val,
            unit,
            humanize(self.min_ns).0,
            humanize(self.min_ns).1,
            self.samples
        )
    }
}

fn humanize(ns: f64) -> (f64, &'static str) {
    if ns < 1_000.0 {
        (ns, "ns")
    } else if ns < 1_000_000.0 {
        (ns / 1e3, "µs")
    } else if ns < 1_000_000_000.0 {
        (ns / 1e6, "ms")
    } else {
        (ns / 1e9, "s")
    }
}

/// Time `f` adaptively: targets ~0.5 s of total measurement, ≥10 samples.
pub fn bench(name: &str, mut f: impl FnMut()) -> BenchResult {
    // warmup + calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let per_sample = ((50_000_000.0 / once).ceil() as usize).clamp(1, 1_000_000);
    // long-running benches (end-to-end experiment minis) get fewer samples
    let samples = if once > 5e9 {
        1
    } else if once > 5e8 {
        3
    } else {
        10
    };
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..per_sample {
            f();
        }
        times.push(t.elapsed().as_nanos() as f64 / per_sample as f64);
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let result = BenchResult {
        name: name.to_string(),
        median_ns: median,
        mean_ns: mean,
        min_ns: times[0],
        samples,
    };
    println!("{}", result.report());
    result
}

/// Fixed-budget variant for smoke/CI runs: one warmup call then exactly
/// `iters` timed iterations, reported as a single sample.  Keeps bench
/// targets runnable (and their wiring verified) inside a tiny CI budget.
pub fn bench_n(name: &str, iters: usize, mut f: impl FnMut()) -> BenchResult {
    f(); // warmup
    let iters = iters.max(1);
    let t = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t.elapsed().as_nanos() as f64 / iters as f64;
    let result = BenchResult {
        name: name.to_string(),
        median_ns: per,
        mean_ns: per,
        min_ns: per,
        samples: 1,
    };
    println!("{}", result.report());
    result
}

/// Write a `BENCH_*.json` artifact: every bench result plus derived scalar
/// metrics (speedup ratios, throughputs) under a `derived` object.
pub fn write_bench_json(
    path: impl AsRef<std::path::Path>,
    benches: &[BenchResult],
    derived: &[(String, f64)],
) -> std::io::Result<()> {
    let mut root = BTreeMap::new();
    root.insert(
        "benches".to_string(),
        Json::Arr(benches.iter().map(BenchResult::to_json).collect()),
    );
    let mut d = BTreeMap::new();
    for (k, v) in derived {
        d.insert(k.clone(), Json::Num(*v));
    }
    root.insert("derived".to_string(), Json::Obj(d));
    std::fs::write(path, Json::Obj(root).to_string())
}

/// Merge bench results into an existing `BENCH_*.json` artifact instead of
/// clobbering it: rows with the same `name` (and derived keys with the same
/// key) are replaced, everything else is preserved.  Lets independent bench
/// binaries (`qsim_step`, `rounding`) contribute to one artifact.  A
/// missing or unparseable file degrades to a plain write.
pub fn merge_bench_json(
    path: impl AsRef<std::path::Path>,
    benches: &[BenchResult],
    derived: &[(String, f64)],
) -> std::io::Result<()> {
    let existing = std::fs::read_to_string(path.as_ref())
        .ok()
        .and_then(|text| Json::parse(&text).ok());
    let Some(old) = existing else {
        return write_bench_json(path, benches, derived);
    };
    // keep old rows that the new run did not re-measure, in their order
    let mut rows: Vec<Json> = Vec::new();
    if let Some(Json::Arr(old_rows)) = old.get("benches") {
        for row in old_rows {
            let name = row.get_str("name").unwrap_or_default();
            if !benches.iter().any(|b| b.name == name) {
                rows.push(row.clone());
            }
        }
    }
    rows.extend(benches.iter().map(BenchResult::to_json));
    let mut d: BTreeMap<String, Json> = match old.get("derived") {
        Some(Json::Obj(o)) => o.clone(),
        _ => BTreeMap::new(),
    };
    for (k, v) in derived {
        d.insert(k.clone(), Json::Num(*v));
    }
    let mut root = BTreeMap::new();
    root.insert("benches".to_string(), Json::Arr(rows));
    root.insert("derived".to_string(), Json::Obj(d));
    std::fs::write(path, Json::Obj(root).to_string())
}

/// Throughput helper: elements processed per iteration → Melem/s line.
pub fn throughput(r: &BenchResult, elems_per_iter: usize) {
    let meps = elems_per_iter as f64 / r.median_ns * 1e3;
    println!(
        "{:<44} {:>9.1} Melem/s",
        format!("  ↳ {} throughput", r.name),
        meps
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_numbers() {
        let mut acc = 0u64;
        let r = bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.median_ns > 0.0);
        assert!(r.min_ns <= r.median_ns);
    }

    #[test]
    fn bench_n_fixed_budget_and_json_round_trip() {
        let mut acc = 0u64;
        let r = bench_n("smoke", 4, || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert_eq!(r.samples, 1);
        assert!(r.median_ns >= 0.0);
        let path = std::env::temp_dir().join("bf16_bench_json_test.json");
        write_bench_json(&path, &[r], &[("speedup_x".to_string(), 2.5)]).unwrap();
        let parsed =
            Json::parse(&std::fs::read_to_string(&path).unwrap()).expect("valid json");
        assert!(parsed.get("benches").is_some());
        assert_eq!(
            parsed.get("derived").and_then(|d| d.get("speedup_x")).and_then(Json::as_f64),
            Some(2.5)
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn merge_replaces_same_name_rows_and_keeps_the_rest() {
        let path = std::env::temp_dir().join("bf16_bench_merge_test.json");
        let _ = std::fs::remove_file(&path);
        let mk = |name: &str, ns: f64| BenchResult {
            name: name.to_string(),
            median_ns: ns,
            mean_ns: ns,
            min_ns: ns,
            samples: 1,
        };
        // first write degrades to a plain write (no existing file)
        merge_bench_json(&path, &[mk("a", 10.0), mk("b", 20.0)], &[("k1".into(), 1.0)])
            .unwrap();
        // second write re-measures `b`, adds `c`, and adds a derived key
        merge_bench_json(&path, &[mk("b", 25.0), mk("c", 30.0)], &[("k2".into(), 2.0)])
            .unwrap();
        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let rows = match parsed.get("benches") {
            Some(Json::Arr(rows)) => rows.clone(),
            other => panic!("benches must be an array, got {other:?}"),
        };
        let find = |n: &str| {
            rows.iter()
                .find(|r| r.get_str("name") == Some(n))
                .unwrap_or_else(|| panic!("row {n} missing"))
                .get("median_ns")
                .and_then(Json::as_f64)
                .unwrap()
        };
        assert_eq!(rows.len(), 3);
        assert_eq!(find("a"), 10.0, "unrelated row preserved");
        assert_eq!(find("b"), 25.0, "re-measured row replaced");
        assert_eq!(find("c"), 30.0, "new row appended");
        let d = parsed.get("derived").unwrap();
        assert_eq!(d.get("k1").and_then(Json::as_f64), Some(1.0));
        assert_eq!(d.get("k2").and_then(Json::as_f64), Some(2.0));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn humanize_units() {
        assert_eq!(humanize(10.0).1, "ns");
        assert_eq!(humanize(10_000.0).1, "µs");
        assert_eq!(humanize(10_000_000.0).1, "ms");
    }
}
