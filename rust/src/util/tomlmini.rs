//! TOML-subset parser for run configs (dependency-free substrate).
//!
//! Supports the subset the config system uses: `[section]` and
//! `[section.sub]` headers, `key = value` with string / integer / float /
//! boolean / flat-array values, `#` comments, blank lines.  Keys are stored
//! flat as `"section.sub.key"`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Parse error with line number.
#[derive(Debug, Clone, PartialEq)]
pub struct TomlError {
    pub line: usize,
    pub message: String,
}

impl fmt::Display for TomlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "toml error on line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for TomlError {}

/// Flat key → value map (`section.key` dotted paths).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut entries = BTreeMap::new();
        let mut section = String::new();
        for (ln, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    return Err(err(ln, "unterminated section header"));
                }
                section = line[1..line.len() - 1].trim().to_string();
                if section.is_empty() {
                    return Err(err(ln, "empty section name"));
                }
                continue;
            }
            let Some(eq) = line.find('=') else {
                return Err(err(ln, "expected key = value"));
            };
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err(ln, "empty key"));
            }
            let value = parse_value(line[eq + 1..].trim(), ln)?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            entries.insert(full, value);
        }
        Ok(TomlDoc { entries })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(TomlValue::as_str).unwrap_or(default)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(TomlValue::as_i64).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(TomlValue::as_bool).unwrap_or(default)
    }

    /// All keys under a `section.` prefix.
    pub fn section_keys(&self, section: &str) -> Vec<&str> {
        let prefix = format!("{section}.");
        self.entries
            .keys()
            .filter(|k| k.starts_with(&prefix))
            .map(|k| k.as_str())
            .collect()
    }
}

fn err(ln: usize, msg: &str) -> TomlError {
    TomlError { line: ln + 1, message: msg.to_string() }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, ln: usize) -> Result<TomlValue, TomlError> {
    if s.is_empty() {
        return Err(err(ln, "empty value"));
    }
    if let Some(stripped) = s.strip_prefix('"') {
        let Some(end) = stripped.find('"') else {
            return Err(err(ln, "unterminated string"));
        };
        if stripped[end + 1..].trim() != "" {
            return Err(err(ln, "trailing content after string"));
        }
        return Ok(TomlValue::Str(stripped[..end].to_string()));
    }
    if s.starts_with('[') {
        if !s.ends_with(']') {
            return Err(err(ln, "unterminated array"));
        }
        let inner = &s[1..s.len() - 1];
        let mut items = Vec::new();
        for part in inner.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, ln)?);
        }
        return Ok(TomlValue::Array(items));
    }
    match s {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(err(ln, &format!("cannot parse value {s:?}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
# run config
name = "dlrm-sweep"   # inline comment
[train]
steps = 2000
lr = 0.1
modes = ["fp32", "sr16"]
eval = true
[train.schedule]
kind = "step"
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", ""), "dlrm-sweep");
        assert_eq!(doc.i64_or("train.steps", 0), 2000);
        assert_eq!(doc.f64_or("train.lr", 0.0), 0.1);
        assert!(doc.bool_or("train.eval", false));
        assert_eq!(doc.str_or("train.schedule.kind", ""), "step");
        let modes = doc.get("train.modes").unwrap();
        if let TomlValue::Array(a) = modes {
            assert_eq!(a[1].as_str(), Some("sr16"));
        } else {
            panic!()
        }
    }

    #[test]
    fn integers_promote_to_float_lookup() {
        let doc = TomlDoc::parse("lr = 1").unwrap();
        assert_eq!(doc.f64_or("lr", 0.0), 1.0);
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = TomlDoc::parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        assert!(TomlDoc::parse("x = \"unterminated").is_err());
        assert!(TomlDoc::parse("[sec\nx = 1").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = TomlDoc::parse("s = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("s", ""), "a#b");
    }
}
