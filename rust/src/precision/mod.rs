//! Software numeric-format substrate — the rust mirror of
//! `python/compile/formats.py`.
//!
//! Bit-for-bit identical semantics (verified against shared golden vectors
//! emitted by `aot.py` in `rust/tests/golden_parity.rs`): every emulated
//! format is a value subset of f32; `round_nearest` is RNE on the mantissa
//! boundary; `round_stochastic` adds dither bits below the kept mantissa and
//! truncates (the hardware scheme of the paper's Appendix B.1); formats with
//! fewer than 8 exponent bits overflow to ±inf and flush subnormals to zero.
//!
//! This substrate powers the rust-native quantised trainer (`qsim`), the
//! theory-validation experiments (Figure 2, Theorem 1) and the property
//! tests; the PJRT path does its rounding *inside* the lowered HLO instead.
//!
//! [`Policy`] (mode × format, with the derived rounding scheme) is the typed
//! precision-policy core shared by config, qsim, runtime and coordinator —
//! the single place the `"sr16-e8m5"` naming convention is parsed/printed.

mod format;
mod kahan;
mod policy;
mod round;
mod simd;

pub use format::{Format, ALL, BF16, E8M1, E8M3, E8M5, FP16, FP32};
pub use kahan::{kahan_add, KahanAcc};
pub use policy::{Mode, Policy, PolicyParseError};
pub use round::{
    round_nearest, round_nearest_slice, round_stochastic, round_stochastic_slice,
    round_stochastic_slice_keyed, RoundMode, Rounder,
};
pub use simd::{
    round_nearest_slice_simd, round_stochastic_slice_keyed_simd, round_stochastic_slice_simd,
    SimdRound, LANES,
};
