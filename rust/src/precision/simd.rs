//! 8-lane chunked rounding kernels — the `Backend::Simd` leaf tier.
//!
//! Every kernel here is **bit-identical** to its scalar oracle in
//! [`super::round`]: the rounding algorithm is pure u32 bit arithmetic, the
//! counter-keyed dither word for element `i` is a pure function of position,
//! and lanes never interact — so processing eight elements per iteration
//! (in `[u32; 8]` arrays the compiler autovectorizes to 256-bit ops)
//! reproduces the scalar results exactly, including the clamp/FTZ path of
//! sub-8-exponent formats and the pass-through of non-finite inputs.
//!
//! The baseline is stable Rust: fixed-width array lanes with branchless
//! per-lane selects, which LLVM lowers to vector compares and blends on any
//! target.  An explicit AVX2 path for the hottest kernel (nearest-rounding,
//! fused into every matmul output row) is gated behind the
//! `simd-intrinsics` cargo feature plus a runtime
//! `is_x86_feature_detected!` check, and is restricted to formats that skip
//! the clamp (8 exponent bits) so the intrinsics stay a straight
//! add/mask/blend sequence.
//!
//! Non-finite handling: the scalar kernels `continue`, leaving the original
//! bits (NaN payloads included) untouched.  The lane kernels compute the
//! rounded candidate unconditionally and then select the *original* bits
//! wherever the exponent field is all-ones — the same observable result,
//! branch-free.  The clamp compares run in the bit domain: for the
//! non-negative magnitudes involved, IEEE ordering equals integer ordering
//! of the bit patterns, so `|y| > max` and `|y| < min_normal` become u32
//! compares on `y & 0x7fff_ffff`.

use super::format::Format;
use super::round::{
    round_nearest_slice, round_stochastic_slice_keyed, SR_CHUNK,
};
use crate::util::rng::{DitherKey, Rng};

/// Lane width of the chunked kernels (8 × f32 = one 256-bit vector).
pub const LANES: usize = 8;

/// Hoisted per-format rounding constants (bit-domain clamp bounds).
#[derive(Clone, Copy)]
struct Consts {
    drop: u32,
    half_m1: u32,
    noise_mask: u32,
    keep_mask: u32,
    clamp: bool,
    max_bits: u32,
    min_bits: u32,
}

impl Consts {
    fn new(fmt: Format) -> Self {
        let drop = fmt.drop_bits();
        Consts {
            drop,
            half_m1: (1u32 << (drop - 1)) - 1,
            noise_mask: (1u32 << drop) - 1,
            keep_mask: u32::MAX << drop,
            clamp: fmt.exp_bits < 8,
            max_bits: fmt.max_value().to_bits(),
            min_bits: fmt.min_normal().to_bits(),
        }
    }
}

/// Exponent-field mask: all-ones exponent ⇔ `!f32::is_finite()`.
const EXP_MASK: u32 = 0x7f80_0000;

/// Clamp `y` (bit pattern of a finite-or-inf, never-NaN value) to the
/// format's range in the bit domain; identity when `c.clamp` is false.
#[inline(always)]
fn clamp_bits(y: u32, c: &Consts) -> u32 {
    if !c.clamp {
        return y;
    }
    let ab = y & 0x7fff_ffff;
    let sign = y & 0x8000_0000;
    if ab > c.max_bits {
        EXP_MASK | sign // ±inf, sign preserved (copysign)
    } else if ab < c.min_bits {
        sign // FTZ preserves the sign (IEEE signed zero)
    } else {
        y
    }
}

/// One lane of round-to-nearest-even: bit algorithm of
/// [`super::round::round_nearest`], with the non-finite pass-through as a
/// final select instead of an early `continue`.
#[inline(always)]
fn rn_lane(u: u32, c: &Consts) -> u32 {
    let lsb = (u >> c.drop) & 1;
    let y = clamp_bits(u.wrapping_add(c.half_m1 + lsb) & c.keep_mask, c);
    if u & EXP_MASK == EXP_MASK {
        u
    } else {
        y
    }
}

/// One lane of stochastic rounding with pre-drawn dither word `rb`.
#[inline(always)]
fn sr_lane(u: u32, rb: u32, c: &Consts) -> u32 {
    let y = clamp_bits(u.wrapping_add(rb & c.noise_mask) & c.keep_mask, c);
    if u & EXP_MASK == EXP_MASK {
        u
    } else {
        y
    }
}

/// A bound 8-lane rounding helper for hot loops that interleave arithmetic
/// with rounding (the staged SGD passes): format constants hoisted once,
/// then [`SimdRound::nearest8`] / [`SimdRound::stochastic8`] round one lane
/// block at a time, bit-identically to mapping the scalar kernels over it.
/// For fp32 both calls are no-ops (exact passthrough), matching the scalar
/// kernels' early return.
#[derive(Clone, Copy)]
pub struct SimdRound {
    c: Consts,
    exact: bool,
}

impl SimdRound {
    pub fn new(fmt: Format) -> Self {
        Self {
            // the constants are never read when `exact` (fp32 has drop 0,
            // which would shift out of range), so substitute a harmless 1
            c: Consts::new(if fmt.is_fp32() {
                Format { name: "fp32-lane-dummy", exp_bits: 8, mant_bits: 22 }
            } else {
                fmt
            }),
            exact: fmt.is_fp32(),
        }
    }

    /// Round-to-nearest-even over one lane block, in place.
    #[inline]
    pub fn nearest8(&self, xs: &mut [f32; LANES]) {
        if self.exact {
            return;
        }
        let mut u = [0u32; LANES];
        for l in 0..LANES {
            u[l] = xs[l].to_bits();
        }
        for l in 0..LANES {
            xs[l] = f32::from_bits(rn_lane(u[l], &self.c));
        }
    }

    /// Stochastic rounding over one lane block with pre-drawn dither words.
    #[inline]
    pub fn stochastic8(&self, xs: &mut [f32; LANES], rb: &[u32; LANES]) {
        if self.exact {
            return;
        }
        let mut u = [0u32; LANES];
        for l in 0..LANES {
            u[l] = xs[l].to_bits();
        }
        for l in 0..LANES {
            xs[l] = f32::from_bits(sr_lane(u[l], rb[l], &self.c));
        }
    }
}

/// Round a slice to nearest-even in place, eight lanes per iteration.
///
/// Bit-identical to [`round_nearest_slice`] (hence to mapping
/// [`super::round::round_nearest`] over the slice); the ragged tail runs
/// through the scalar slice kernel.
pub fn round_nearest_slice_simd(xs: &mut [f32], fmt: Format) {
    if fmt.is_fp32() {
        return;
    }
    #[cfg(feature = "simd-intrinsics")]
    if fmt.exp_bits >= 8 && avx2::available() {
        // SAFETY: AVX2 support verified at runtime just above.
        unsafe { avx2::round_nearest_slice_avx2(xs, fmt) };
        return;
    }
    let c = Consts::new(fmt);
    let mut chunks = xs.chunks_exact_mut(LANES);
    for ch in &mut chunks {
        let mut u = [0u32; LANES];
        for l in 0..LANES {
            u[l] = ch[l].to_bits();
        }
        for l in 0..LANES {
            ch[l] = f32::from_bits(rn_lane(u[l], &c));
        }
    }
    round_nearest_slice(chunks.into_remainder(), fmt);
}

/// Stochastically round a slice in place, drawing dither from `rng`,
/// eight lanes per iteration.
///
/// Bit-identical to [`super::round::round_stochastic_slice`] — including
/// RNG consumption: dither words are drawn through the same
/// [`SR_CHUNK`]-batched [`Rng::fill_u32`] schedule (one word per element in
/// element order, even for fp32), so the generator stays interchangeable
/// with both scalar paths.
pub fn round_stochastic_slice_simd(xs: &mut [f32], fmt: Format, rng: &mut Rng) {
    let mut bits = [0u32; SR_CHUNK];
    if fmt.is_fp32() {
        // keep the dither stream position identical to the scalar path
        let mut left = xs.len();
        while left > 0 {
            let take = left.min(SR_CHUNK);
            rng.fill_u32(&mut bits[..take]);
            left -= take;
        }
        return;
    }
    let c = Consts::new(fmt);
    for chunk in xs.chunks_mut(SR_CHUNK) {
        let b = &mut bits[..chunk.len()];
        rng.fill_u32(b);
        let mut lane_pairs = chunk.chunks_exact_mut(LANES);
        let mut off = 0usize;
        for ch in &mut lane_pairs {
            let mut u = [0u32; LANES];
            for l in 0..LANES {
                u[l] = ch[l].to_bits();
            }
            for l in 0..LANES {
                ch[l] = f32::from_bits(sr_lane(u[l], b[off + l], &c));
            }
            off += LANES;
        }
        for (x, &rb) in lane_pairs.into_remainder().iter_mut().zip(&b[off..]) {
            let y = f32::from_bits(sr_lane(x.to_bits(), rb, &c));
            *x = y;
        }
    }
}

/// Stochastically round a slice in place with counter-keyed dither, eight
/// lanes per iteration.
///
/// Bit-identical to [`round_stochastic_slice_keyed`]: element `j` uses
/// dither word `key.word(base + j)`, generated eight counters at a time —
/// the splitmix mix over `key + index·golden` is lane-independent by
/// construction, so the `[u64; 8]` counter block autovectorizes without
/// changing a single dither bit.
pub fn round_stochastic_slice_keyed_simd(
    xs: &mut [f32],
    fmt: Format,
    key: DitherKey,
    base: u64,
) {
    if fmt.is_fp32() {
        // counter-based dither has no stream position to maintain
        return;
    }
    let c = Consts::new(fmt);
    let mut chunks = xs.chunks_exact_mut(LANES);
    let mut i = 0u64;
    for ch in &mut chunks {
        let mut rb = [0u32; LANES];
        for l in 0..LANES {
            rb[l] = key.word(base.wrapping_add(i + l as u64));
        }
        let mut u = [0u32; LANES];
        for l in 0..LANES {
            u[l] = ch[l].to_bits();
        }
        for l in 0..LANES {
            ch[l] = f32::from_bits(sr_lane(u[l], rb[l], &c));
        }
        i += LANES as u64;
    }
    round_stochastic_slice_keyed(
        chunks.into_remainder(),
        fmt,
        key,
        base.wrapping_add(i),
    );
}

/// Explicit AVX2 nearest-rounding path (the fused matmul output kernel),
/// compiled only under the `simd-intrinsics` feature on x86-64 and selected
/// only after a runtime CPU check.  Restricted to no-clamp formats
/// (`exp_bits >= 8`), where the algorithm is a pure
/// add/mask/non-finite-blend over the bit patterns.
#[cfg(all(feature = "simd-intrinsics", target_arch = "x86_64"))]
mod avx2 {
    use super::super::format::Format;
    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    pub fn available() -> bool {
        is_x86_feature_detected!("avx2")
    }

    /// # Safety
    /// Caller must verify AVX2 support (see [`available`]) and pass a
    /// format with `exp_bits >= 8` (no clamp/FTZ path).
    #[target_feature(enable = "avx2")]
    pub unsafe fn round_nearest_slice_avx2(xs: &mut [f32], fmt: Format) {
        debug_assert!(fmt.exp_bits >= 8 && !fmt.is_fp32());
        let drop = fmt.drop_bits();
        let half_m1 = _mm256_set1_epi32(((1u32 << (drop - 1)) - 1) as i32);
        let keep = _mm256_set1_epi32((u32::MAX << drop) as i32);
        let one = _mm256_set1_epi32(1);
        let expm = _mm256_set1_epi32(super::EXP_MASK as i32);
        // variable-count shift: count lives in the low 64 bits of a __m128i
        let dropv = _mm_cvtsi32_si128(drop as i32);
        let mut chunks = xs.chunks_exact_mut(8);
        for ch in &mut chunks {
            let u = _mm256_loadu_si256(ch.as_ptr() as *const __m256i);
            let lsb = _mm256_and_si256(_mm256_srl_epi32(u, dropv), one);
            let add = _mm256_add_epi32(half_m1, lsb);
            let y = _mm256_and_si256(_mm256_add_epi32(u, add), keep);
            // non-finite lanes (exponent all-ones) keep their original bits
            let nf = _mm256_cmpeq_epi32(_mm256_and_si256(u, expm), expm);
            let out = _mm256_blendv_epi8(y, u, nf);
            _mm256_storeu_si256(ch.as_mut_ptr() as *mut __m256i, out);
        }
        super::super::round::round_nearest_slice(chunks.into_remainder(), fmt);
    }
}

#[cfg(all(feature = "simd-intrinsics", not(target_arch = "x86_64")))]
mod avx2 {
    use super::super::format::Format;

    pub fn available() -> bool {
        false
    }

    /// # Safety
    /// Never called: [`available`] is always false off x86-64.
    pub unsafe fn round_nearest_slice_avx2(_xs: &mut [f32], _fmt: Format) {
        unreachable!("avx2 path is x86-64 only")
    }
}

#[cfg(test)]
mod tests {
    use super::super::format::{ALL, BF16};
    use super::super::round::{
        round_nearest, round_stochastic, round_stochastic_slice,
    };
    use super::*;

    /// Wide-dynamic-range value soup including zeros, subnormal-range
    /// magnitudes, huge magnitudes (overflow for e5 formats) and specials —
    /// the same adversarial distribution the scalar kernels are tested on.
    fn soup(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed, 0x50);
        (0..n)
            .map(|i| match i % 97 {
                0 => 0.0,
                1 => -0.0,
                2 => f32::INFINITY,
                3 => f32::NEG_INFINITY,
                4 => f32::NAN,
                _ => rng.normal() * 10f32.powi(rng.below(60) as i32 - 30),
            })
            .collect()
    }

    #[test]
    fn simd_nearest_matches_scalar_all_formats_odd_lengths() {
        for fmt in ALL {
            for len in [0usize, 1, 7, 8, 9, 255, 256, 257, 1023] {
                let xs = soup(len, 0x51AD ^ len as u64);
                let mut fast = xs.clone();
                round_nearest_slice_simd(&mut fast, fmt);
                for (i, (&f, &x)) in fast.iter().zip(&xs).enumerate() {
                    let want = round_nearest(x, fmt);
                    assert_eq!(
                        f.to_bits(),
                        want.to_bits(),
                        "{} len={len} i={i} x={x}",
                        fmt.name
                    );
                }
            }
        }
    }

    #[test]
    fn simd_stochastic_matches_scalar_and_rng_state_all_formats() {
        for fmt in ALL {
            for len in [0usize, 1, 7, 8, 9, 255, 256, 257, 1023] {
                let xs = soup(len, 0x51AE ^ len as u64);
                let mut fast = xs.clone();
                let mut rng_fast = Rng::new(4242, len as u64);
                let mut rng_ref = rng_fast.clone();
                round_stochastic_slice_simd(&mut fast, fmt, &mut rng_fast);
                let mut want = xs.clone();
                round_stochastic_slice(&mut want, fmt, &mut rng_ref);
                for (i, (&f, &w)) in fast.iter().zip(&want).enumerate() {
                    assert_eq!(f.to_bits(), w.to_bits(), "{} len={len} i={i}", fmt.name);
                }
                // generator must land exactly where the scalar kernel leaves it
                assert_eq!(rng_fast.next_u64(), rng_ref.next_u64(), "{} len={len}", fmt.name);
            }
        }
    }

    #[test]
    fn simd_keyed_matches_scalar_oracle_all_formats() {
        let key = DitherKey::new(7, 0x5352, 3, 1);
        for fmt in ALL {
            for len in [0usize, 1, 7, 8, 9, 255, 256, 257, 1023] {
                let xs = soup(len, 0x51AF ^ len as u64);
                let mut fast = xs.clone();
                round_stochastic_slice_keyed_simd(&mut fast, fmt, key, 11);
                for (i, (&f, &x)) in fast.iter().zip(&xs).enumerate() {
                    let want = round_stochastic(x, fmt, key.word(11 + i as u64));
                    assert_eq!(f.to_bits(), want.to_bits(), "{} len={len} i={i}", fmt.name);
                }
            }
        }
    }

    #[test]
    fn simd_round_lane_block_matches_scalar() {
        use super::super::format::FP32;
        let key = DitherKey::new(3, 0x5352, 1, 0);
        for fmt in ALL {
            let r = SimdRound::new(fmt);
            let xs = soup(LANES * 5, 0xB10C ^ fmt.mant_bits as u64);
            for (ci, chunk) in xs.chunks_exact(LANES).enumerate() {
                let mut near: [f32; LANES] = chunk.try_into().unwrap();
                r.nearest8(&mut near);
                let mut sto: [f32; LANES] = chunk.try_into().unwrap();
                let mut rb = [0u32; LANES];
                for (l, slot) in rb.iter_mut().enumerate() {
                    *slot = key.word((ci * LANES + l) as u64);
                }
                r.stochastic8(&mut sto, &rb);
                for l in 0..LANES {
                    assert_eq!(
                        near[l].to_bits(),
                        round_nearest(chunk[l], fmt).to_bits(),
                        "{} nearest lane {l}",
                        fmt.name
                    );
                    assert_eq!(
                        sto[l].to_bits(),
                        round_stochastic(chunk[l], fmt, rb[l]).to_bits(),
                        "{} stochastic lane {l}",
                        fmt.name
                    );
                }
            }
        }
        // fp32 is exact passthrough in both modes
        let r = SimdRound::new(FP32);
        let mut xs = [1.5f32, -0.1, 1e30, f32::INFINITY, 0.0, -0.0, 2.0, 3.0];
        let want = xs;
        r.nearest8(&mut xs);
        r.stochastic8(&mut xs, &[u32::MAX; LANES]);
        for l in 0..LANES {
            assert_eq!(xs[l].to_bits(), want[l].to_bits());
        }
    }

    #[test]
    fn simd_keyed_chunking_is_invariant() {
        let key = DitherKey::new(11, 0x5352, 9, 2);
        let xs = soup(1000, 0xC0FFEE);
        let mut whole = xs.clone();
        round_stochastic_slice_keyed_simd(&mut whole, BF16, key, 0);
        for chunk in [1usize, 3, 8, 64, 97, 256, 999] {
            let mut pieces = xs.clone();
            let mut off = 0usize;
            while off < pieces.len() {
                let end = (off + chunk).min(pieces.len());
                round_stochastic_slice_keyed_simd(&mut pieces[off..end], BF16, key, off as u64);
                off = end;
            }
            for (i, (a, b)) in pieces.iter().zip(&whole).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk={chunk} i={i}");
            }
        }
    }
}
