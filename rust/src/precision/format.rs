//! Floating-point format descriptors (mirrors `formats.Format` in python).

/// A binary floating-point format emulated inside f32 storage.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Format {
    pub name: &'static str,
    pub exp_bits: u32,
    pub mant_bits: u32,
}

/// IEEE single precision (the exact passthrough format).
pub const FP32: Format = Format { name: "fp32", exp_bits: 8, mant_bits: 23 };
/// BFloat16 (e8m7) — the paper's primary format.
pub const BF16: Format = Format { name: "bf16", exp_bits: 8, mant_bits: 7 };
/// IEEE half (e5m10) — Figure 12's dynamic-range failure case.
pub const FP16: Format = Format { name: "fp16", exp_bits: 5, mant_bits: 10 };
/// "14-bit" sub-format of Figure 10.
pub const E8M5: Format = Format { name: "e8m5", exp_bits: 8, mant_bits: 5 };
/// "12-bit" sub-format of Figure 10.
pub const E8M3: Format = Format { name: "e8m3", exp_bits: 8, mant_bits: 3 };
/// "10-bit" sub-format of Figure 10.
pub const E8M1: Format = Format { name: "e8m1", exp_bits: 8, mant_bits: 1 };

/// All emulated formats, for sweeps and parity tests.
pub const ALL: [Format; 6] = [FP32, BF16, FP16, E8M5, E8M3, E8M1];

impl Format {
    /// Look a format up by name (manifest `fmt` field).
    pub fn by_name(name: &str) -> Option<Format> {
        ALL.into_iter().find(|f| f.name == name)
    }

    pub fn is_fp32(&self) -> bool {
        self.exp_bits == 8 && self.mant_bits == 23
    }

    /// f32 mantissa bits dropped by this format.
    pub fn drop_bits(&self) -> u32 {
        23 - self.mant_bits
    }

    /// Maximum unbiased exponent of a finite value.
    pub fn max_exp(&self) -> i32 {
        (1 << (self.exp_bits - 1)) - 1
    }

    /// Minimum unbiased exponent of a normal value.
    pub fn min_exp(&self) -> i32 {
        -((1 << (self.exp_bits - 1)) - 2)
    }

    /// Paper's epsilon convention: |Q(u) - u| <= eps * |u|.
    pub fn machine_eps(&self) -> f64 {
        2f64.powi(-(self.mant_bits as i32) - 1)
    }

    /// Largest finite value.
    pub fn max_value(&self) -> f32 {
        ((2.0 - 2f64.powi(-(self.mant_bits as i32))) * 2f64.powi(self.max_exp())) as f32
    }

    /// Smallest positive normal value.
    pub fn min_normal(&self) -> f32 {
        2f64.powi(self.min_exp()) as f32
    }

    /// Storage bits (sign + exponent + mantissa).
    pub fn total_bits(&self) -> u32 {
        1 + self.exp_bits + self.mant_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_quantities() {
        assert_eq!(BF16.drop_bits(), 16);
        assert_eq!(BF16.machine_eps(), 2f64.powi(-8));
        assert_eq!(FP16.max_exp(), 15);
        assert_eq!(FP16.min_exp(), -14);
        assert_eq!(FP16.max_value(), 65504.0);
        assert_eq!(FP16.min_normal(), 6.103515625e-5);
        assert_eq!(E8M1.total_bits(), 10);
        assert_eq!(E8M3.total_bits(), 12);
        assert_eq!(E8M5.total_bits(), 14);
        assert!(FP32.is_fp32() && !BF16.is_fp32());
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(Format::by_name("bf16"), Some(BF16));
        assert_eq!(Format::by_name("nope"), None);
    }
}
