//! Rounding kernels: RNE and stochastic, bit-identical to the python side.

use super::format::Format;
use crate::util::rng::Rng;

/// How an operator output is rounded onto the target format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoundMode {
    /// Round-to-nearest-even (the standard FMAC output mode).
    Nearest,
    /// Stochastic rounding (paper Appendix B.1: dither + truncate).
    Stochastic,
    /// No rounding (fp32 passthrough).
    Exact,
}

#[inline]
fn clamp_range(y: f32, fmt: Format) -> f32 {
    if fmt.exp_bits >= 8 {
        return y;
    }
    let a = y.abs();
    if a > fmt.max_value() {
        f32::INFINITY.copysign(y)
    } else if a < fmt.min_normal() {
        0.0f32.copysign(y) // FTZ preserves the sign (IEEE signed zero)
    } else {
        y
    }
}

/// Round-to-nearest-even onto `fmt` (f32 storage).
///
/// Same bit algorithm as `formats.round_nearest`: add `half - 1 + lsb` to
/// the f32 pattern, clear the dropped mantissa bits; the carry propagates
/// into the exponent on mantissa rollover.  NaN/inf pass through.
#[inline]
pub fn round_nearest(x: f32, fmt: Format) -> f32 {
    if fmt.is_fp32() {
        return x;
    }
    if !x.is_finite() {
        return x;
    }
    let drop = fmt.drop_bits();
    let u = x.to_bits();
    let half = 1u32 << (drop - 1);
    let lsb = (u >> drop) & 1;
    let rounded = u.wrapping_add(half - 1 + lsb) & (u32::MAX << drop);
    clamp_range(f32::from_bits(rounded), fmt)
}

/// Stochastic rounding onto `fmt` with pre-drawn dither bits.
///
/// Only the low `drop_bits` bits of `rbits` are used; P(round up) equals
/// the fractional position of `x` between its neighbours.
#[inline]
pub fn round_stochastic(x: f32, fmt: Format, rbits: u32) -> f32 {
    if fmt.is_fp32() {
        return x;
    }
    if !x.is_finite() {
        return x;
    }
    let drop = fmt.drop_bits();
    let u = x.to_bits();
    let noise = rbits & ((1u32 << drop) - 1);
    let rounded = u.wrapping_add(noise) & (u32::MAX << drop);
    clamp_range(f32::from_bits(rounded), fmt)
}

/// A bound (format, mode, RNG) rounding policy for hot loops.
#[derive(Debug)]
pub struct Rounder {
    pub fmt: Format,
    pub mode: RoundMode,
    rng: Rng,
}

impl Rounder {
    pub fn new(fmt: Format, mode: RoundMode, seed: u64) -> Self {
        Self { fmt, mode, rng: Rng::new(seed, 0x5052) }
    }

    /// Round one value per the policy.
    #[inline]
    pub fn round(&mut self, x: f32) -> f32 {
        match self.mode {
            RoundMode::Exact => x,
            RoundMode::Nearest => round_nearest(x, self.fmt),
            RoundMode::Stochastic => {
                let bits = self.rng.next_u32();
                round_stochastic(x, self.fmt, bits)
            }
        }
    }

    /// Round a slice in place.
    pub fn round_slice(&mut self, xs: &mut [f32]) {
        match self.mode {
            RoundMode::Exact => {}
            RoundMode::Nearest => {
                for x in xs {
                    *x = round_nearest(*x, self.fmt);
                }
            }
            RoundMode::Stochastic => {
                for x in xs {
                    let bits = self.rng.next_u32();
                    *x = round_stochastic(*x, self.fmt, bits);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::format::{ALL, BF16, E8M1, FP16, FP32};
    use super::*;

    #[test]
    fn nearest_known_values() {
        // bf16 spacing at 1.0 is 2^-8
        assert_eq!(round_nearest(1.0, BF16), 1.0);
        assert_eq!(round_nearest(1.0 + 2f32.powi(-9), BF16), 1.0);
        assert_eq!(round_nearest(1.0 + 3.0 * 2f32.powi(-9), BF16), 1.0 + 2f32.powi(-7));
        // ties to even: 1 + 2^-8 is exactly half-way → rounds to even (1.0)
        assert_eq!(round_nearest(1.0 + 2f32.powi(-8), BF16), 1.0);
        // carry into exponent
        assert_eq!(round_nearest(1.9999999, BF16), 2.0);
        assert_eq!(round_nearest(0.999, E8M1), 1.0);
    }

    #[test]
    fn fp32_is_identity() {
        for x in [1.5f32, -0.1, 1e30, f32::INFINITY] {
            assert_eq!(round_nearest(x, FP32), x);
            assert_eq!(round_stochastic(x, FP32, 12345), x);
        }
    }

    #[test]
    fn fp16_overflow_and_ftz() {
        assert_eq!(round_nearest(1e6, FP16), f32::INFINITY);
        assert_eq!(round_nearest(-1e6, FP16), f32::NEG_INFINITY);
        assert_eq!(round_nearest(1e-8, FP16), 0.0);
        assert_eq!(round_nearest(65504.0, FP16), 65504.0);
    }

    #[test]
    fn projection_property_all_formats() {
        let mut rng = Rng::new(11, 0);
        for fmt in ALL {
            for _ in 0..2000 {
                let x = rng.normal() * 10f32.powi(rng.below(40) as i32 - 20);
                let once = round_nearest(x, fmt);
                assert_eq!(round_nearest(once, fmt).to_bits(), once.to_bits());
            }
        }
    }

    #[test]
    fn nearest_error_bound() {
        let mut rng = Rng::new(13, 0);
        for _ in 0..5000 {
            let x = rng.normal() * 10f32.powi(rng.below(20) as i32 - 10);
            let q = round_nearest(x, BF16);
            let eps = BF16.machine_eps() as f32;
            assert!((q - x).abs() <= eps * x.abs() + f32::MIN_POSITIVE);
        }
    }

    #[test]
    fn stochastic_rounds_to_neighbours_and_unbiased() {
        // mid-way value between bf16 neighbours 1.0 and 1.0078125 at 1/4
        let x = 1.0 + 1.0 / 512.0;
        let mut rng = Rng::new(17, 0);
        let mut ups = 0usize;
        let n = 40_000;
        for _ in 0..n {
            let q = round_stochastic(x, BF16, rng.next_u32());
            assert!(q == 1.0 || q == 1.0078125, "{q}");
            if q > 1.0 {
                ups += 1;
            }
        }
        let frac = ups as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.01, "{frac}");
    }

    #[test]
    fn rounder_policy_dispatch() {
        let mut r = Rounder::new(BF16, RoundMode::Nearest, 1);
        assert_eq!(r.round(1.0 + 2f32.powi(-12)), 1.0);
        let mut e = Rounder::new(BF16, RoundMode::Exact, 1);
        assert_eq!(e.round(1.0 + 2f32.powi(-12)), 1.0 + 2f32.powi(-12));
        let mut s = Rounder::new(BF16, RoundMode::Stochastic, 1);
        let mut vals = vec![1.0 + 2f32.powi(-12); 4096];
        s.round_slice(&mut vals);
        assert!(vals.iter().any(|&v| v > 1.0));
        assert!(vals.iter().any(|&v| v == 1.0));
    }
}
