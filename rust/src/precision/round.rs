//! Rounding kernels: RNE and stochastic, bit-identical to the python side.
//!
//! Stochastic rounding comes in two flavours: the legacy *sequential* slice
//! kernel ([`round_stochastic_slice`], dither drawn from an [`Rng`] stream,
//! element-order load-bearing) and the *counter-keyed* schedule, where the
//! dither for element `i` is a pure function of position via
//! [`DitherKey::word`], so any chunking or thread schedule reproduces it
//! bit-for-bit.  The qsim trainers consume the keyed schedule through
//! scalar `round_stochastic(x, fmt, key.word(i))` calls (their loops
//! interleave stats with the rounding); [`round_stochastic_slice_keyed`] is
//! the pure slice-level form of the same schedule for whole-buffer
//! consumers, and the chunk-invariance oracle the property tests pin down.

use super::format::Format;
use crate::util::rng::{DitherKey, Rng};

/// How an operator output is rounded onto the target format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RoundMode {
    /// Round-to-nearest-even (the standard FMAC output mode).
    Nearest,
    /// Stochastic rounding (paper Appendix B.1: dither + truncate).
    Stochastic,
    /// No rounding (fp32 passthrough).
    Exact,
}

#[inline]
fn clamp_range(y: f32, fmt: Format) -> f32 {
    if fmt.exp_bits >= 8 {
        return y;
    }
    let a = y.abs();
    if a > fmt.max_value() {
        f32::INFINITY.copysign(y)
    } else if a < fmt.min_normal() {
        0.0f32.copysign(y) // FTZ preserves the sign (IEEE signed zero)
    } else {
        y
    }
}

/// Round-to-nearest-even onto `fmt` (f32 storage).
///
/// Same bit algorithm as `formats.round_nearest`: add `half - 1 + lsb` to
/// the f32 pattern, clear the dropped mantissa bits; the carry propagates
/// into the exponent on mantissa rollover.  NaN/inf pass through.
#[inline]
pub fn round_nearest(x: f32, fmt: Format) -> f32 {
    if fmt.is_fp32() {
        return x;
    }
    if !x.is_finite() {
        return x;
    }
    let drop = fmt.drop_bits();
    let u = x.to_bits();
    let half = 1u32 << (drop - 1);
    let lsb = (u >> drop) & 1;
    let rounded = u.wrapping_add(half - 1 + lsb) & (u32::MAX << drop);
    clamp_range(f32::from_bits(rounded), fmt)
}

/// Stochastic rounding onto `fmt` with pre-drawn dither bits.
///
/// Only the low `drop_bits` bits of `rbits` are used; P(round up) equals
/// the fractional position of `x` between its neighbours.
#[inline]
pub fn round_stochastic(x: f32, fmt: Format, rbits: u32) -> f32 {
    if fmt.is_fp32() {
        return x;
    }
    if !x.is_finite() {
        return x;
    }
    let drop = fmt.drop_bits();
    let u = x.to_bits();
    let noise = rbits & ((1u32 << drop) - 1);
    let rounded = u.wrapping_add(noise) & (u32::MAX << drop);
    clamp_range(f32::from_bits(rounded), fmt)
}

/// Dither words drawn per chunk by [`round_stochastic_slice`]; sized so the
/// bit buffer lives in L1 while still amortizing the RNG call overhead.
pub(crate) const SR_CHUNK: usize = 256;

/// Round a slice to nearest-even in place.
///
/// Bit-identical to mapping [`round_nearest`] over the slice; the format
/// constants (`drop_bits`, masks, clamp bounds) are hoisted out of the loop
/// so the body is straight-line bit arithmetic the compiler can vectorize.
pub fn round_nearest_slice(xs: &mut [f32], fmt: Format) {
    if fmt.is_fp32() {
        return;
    }
    let drop = fmt.drop_bits();
    let half_m1 = (1u32 << (drop - 1)) - 1;
    let keep_mask = u32::MAX << drop;
    let clamp = fmt.exp_bits < 8;
    let max_v = fmt.max_value();
    let min_n = fmt.min_normal();
    for x in xs.iter_mut() {
        let v = *x;
        if !v.is_finite() {
            continue;
        }
        let u = v.to_bits();
        let lsb = (u >> drop) & 1;
        let mut y = f32::from_bits(u.wrapping_add(half_m1 + lsb) & keep_mask);
        if clamp {
            let a = y.abs();
            if a > max_v {
                y = f32::INFINITY.copysign(y);
            } else if a < min_n {
                y = 0.0f32.copysign(y);
            }
        }
        *x = y;
    }
}

/// Stochastically round a slice in place, drawing dither bits from `rng`.
///
/// Bit-identical to the scalar loop `for x { round_stochastic(x, fmt,
/// rng.next_u32()) }` — including RNG consumption: exactly one dither word is
/// drawn per element, in element order, even for fp32 (where the values pass
/// through unchanged), so the generator stays interchangeable with the
/// scalar path.  Dither words are drawn in [`SR_CHUNK`]-sized batches via
/// [`Rng::fill_u32`] and the format constants are hoisted out of the loop.
pub fn round_stochastic_slice(xs: &mut [f32], fmt: Format, rng: &mut Rng) {
    let mut bits = [0u32; SR_CHUNK];
    if fmt.is_fp32() {
        // keep the dither stream position identical to the scalar path
        let mut left = xs.len();
        while left > 0 {
            let take = left.min(SR_CHUNK);
            rng.fill_u32(&mut bits[..take]);
            left -= take;
        }
        return;
    }
    let drop = fmt.drop_bits();
    let noise_mask = (1u32 << drop) - 1;
    let keep_mask = u32::MAX << drop;
    let clamp = fmt.exp_bits < 8;
    let max_v = fmt.max_value();
    let min_n = fmt.min_normal();
    for chunk in xs.chunks_mut(SR_CHUNK) {
        let b = &mut bits[..chunk.len()];
        rng.fill_u32(b);
        for (x, &rb) in chunk.iter_mut().zip(b.iter()) {
            let v = *x;
            if !v.is_finite() {
                continue;
            }
            let u = v.to_bits();
            let mut y = f32::from_bits(u.wrapping_add(rb & noise_mask) & keep_mask);
            if clamp {
                let a = y.abs();
                if a > max_v {
                    y = f32::INFINITY.copysign(y);
                } else if a < min_n {
                    y = 0.0f32.copysign(y);
                }
            }
            *x = y;
        }
    }
}

/// Stochastically round a slice in place with counter-keyed dither.
///
/// Element `j` of `xs` uses dither word `key.word(base + j)`; the result is
/// therefore a pure function of `(key, base, xs)` — independent of how the
/// slice is chunked across calls or threads.  Rounding a whole tensor is
/// bit-identical to rounding any partition of it, provided each piece passes
/// its element offset as `base`.  Equivalent to the scalar loop
/// `for (j, x) { round_stochastic(x, fmt, key.word(base + j)) }`; dither is
/// generated in [`SR_CHUNK`]-sized batches via [`DitherKey::fill`] so the
/// counter mixing vectorizes independently of the rounding loop.
pub fn round_stochastic_slice_keyed(xs: &mut [f32], fmt: Format, key: DitherKey, base: u64) {
    if fmt.is_fp32() {
        // counter-based dither has no stream position to maintain: fp32
        // passthrough simply draws nothing
        return;
    }
    let drop = fmt.drop_bits();
    let noise_mask = (1u32 << drop) - 1;
    let keep_mask = u32::MAX << drop;
    let clamp = fmt.exp_bits < 8;
    let max_v = fmt.max_value();
    let min_n = fmt.min_normal();
    let mut bits = [0u32; SR_CHUNK];
    for (ci, chunk) in xs.chunks_mut(SR_CHUNK).enumerate() {
        let b = &mut bits[..chunk.len()];
        key.fill(base.wrapping_add((ci * SR_CHUNK) as u64), b);
        for (x, &rb) in chunk.iter_mut().zip(b.iter()) {
            let v = *x;
            if !v.is_finite() {
                continue;
            }
            let u = v.to_bits();
            let mut y = f32::from_bits(u.wrapping_add(rb & noise_mask) & keep_mask);
            if clamp {
                let a = y.abs();
                if a > max_v {
                    y = f32::INFINITY.copysign(y);
                } else if a < min_n {
                    y = 0.0f32.copysign(y);
                }
            }
            *x = y;
        }
    }
}

/// A bound (format, mode, RNG) rounding policy for hot loops.
#[derive(Debug)]
pub struct Rounder {
    pub fmt: Format,
    pub mode: RoundMode,
    rng: Rng,
}

impl Rounder {
    pub fn new(fmt: Format, mode: RoundMode, seed: u64) -> Self {
        Self { fmt, mode, rng: Rng::new(seed, 0x5052) }
    }

    /// Round one value per the policy.
    #[inline]
    pub fn round(&mut self, x: f32) -> f32 {
        match self.mode {
            RoundMode::Exact => x,
            RoundMode::Nearest => round_nearest(x, self.fmt),
            RoundMode::Stochastic => {
                let bits = self.rng.next_u32();
                round_stochastic(x, self.fmt, bits)
            }
        }
    }

    /// Round a slice in place via the batched kernels (bit-identical to
    /// mapping [`Rounder::round`] over the slice, including RNG draws).
    pub fn round_slice(&mut self, xs: &mut [f32]) {
        match self.mode {
            RoundMode::Exact => {}
            RoundMode::Nearest => round_nearest_slice(xs, self.fmt),
            RoundMode::Stochastic => round_stochastic_slice(xs, self.fmt, &mut self.rng),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::format::{ALL, BF16, E8M1, FP16, FP32};
    use super::*;

    #[test]
    fn nearest_known_values() {
        // bf16 spacing at 1.0 is 2^-8
        assert_eq!(round_nearest(1.0, BF16), 1.0);
        assert_eq!(round_nearest(1.0 + 2f32.powi(-9), BF16), 1.0);
        assert_eq!(round_nearest(1.0 + 3.0 * 2f32.powi(-9), BF16), 1.0 + 2f32.powi(-7));
        // ties to even: 1 + 2^-8 is exactly half-way → rounds to even (1.0)
        assert_eq!(round_nearest(1.0 + 2f32.powi(-8), BF16), 1.0);
        // carry into exponent
        assert_eq!(round_nearest(1.9999999, BF16), 2.0);
        assert_eq!(round_nearest(0.999, E8M1), 1.0);
    }

    #[test]
    fn fp32_is_identity() {
        for x in [1.5f32, -0.1, 1e30, f32::INFINITY] {
            assert_eq!(round_nearest(x, FP32), x);
            assert_eq!(round_stochastic(x, FP32, 12345), x);
        }
    }

    #[test]
    fn fp16_overflow_and_ftz() {
        assert_eq!(round_nearest(1e6, FP16), f32::INFINITY);
        assert_eq!(round_nearest(-1e6, FP16), f32::NEG_INFINITY);
        assert_eq!(round_nearest(1e-8, FP16), 0.0);
        assert_eq!(round_nearest(65504.0, FP16), 65504.0);
    }

    #[test]
    fn projection_property_all_formats() {
        let mut rng = Rng::new(11, 0);
        for fmt in ALL {
            for _ in 0..2000 {
                let x = rng.normal() * 10f32.powi(rng.below(40) as i32 - 20);
                let once = round_nearest(x, fmt);
                assert_eq!(round_nearest(once, fmt).to_bits(), once.to_bits());
            }
        }
    }

    #[test]
    fn nearest_error_bound() {
        let mut rng = Rng::new(13, 0);
        for _ in 0..5000 {
            let x = rng.normal() * 10f32.powi(rng.below(20) as i32 - 10);
            let q = round_nearest(x, BF16);
            let eps = BF16.machine_eps() as f32;
            assert!((q - x).abs() <= eps * x.abs() + f32::MIN_POSITIVE);
        }
    }

    #[test]
    fn stochastic_rounds_to_neighbours_and_unbiased() {
        // mid-way value between bf16 neighbours 1.0 and 1.0078125 at 1/4
        let x = 1.0 + 1.0 / 512.0;
        let mut rng = Rng::new(17, 0);
        let mut ups = 0usize;
        let n = 40_000;
        for _ in 0..n {
            let q = round_stochastic(x, BF16, rng.next_u32());
            assert!(q == 1.0 || q == 1.0078125, "{q}");
            if q > 1.0 {
                ups += 1;
            }
        }
        let frac = ups as f64 / n as f64;
        assert!((frac - 0.25).abs() < 0.01, "{frac}");
    }

    /// Wide-dynamic-range value soup including zeros, subnormal-range
    /// magnitudes, huge magnitudes (overflow for e5 formats) and specials.
    fn soup(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed, 0x50);
        (0..n)
            .map(|i| match i % 97 {
                0 => 0.0,
                1 => -0.0,
                2 => f32::INFINITY,
                3 => f32::NEG_INFINITY,
                4 => f32::NAN,
                _ => rng.normal() * 10f32.powi(rng.below(60) as i32 - 30),
            })
            .collect()
    }

    #[test]
    fn nearest_slice_matches_scalar_all_formats_odd_lengths() {
        for fmt in ALL {
            for len in [0usize, 1, 7, 255, 256, 257, 1023] {
                let xs = soup(len, 0xBEEF ^ len as u64);
                let mut fast = xs.clone();
                round_nearest_slice(&mut fast, fmt);
                for (i, (&f, &x)) in fast.iter().zip(&xs).enumerate() {
                    let want = round_nearest(x, fmt);
                    assert_eq!(
                        f.to_bits(),
                        want.to_bits(),
                        "{} len={len} i={i} x={x}",
                        fmt.name
                    );
                }
            }
        }
    }

    #[test]
    fn stochastic_slice_matches_scalar_all_formats_odd_lengths() {
        for fmt in ALL {
            for len in [0usize, 1, 7, 255, 256, 257, 1023] {
                let xs = soup(len, 0xFACE ^ len as u64);
                let mut fast = xs.clone();
                let mut rng_fast = Rng::new(99, len as u64);
                let mut rng_ref = rng_fast.clone();
                round_stochastic_slice(&mut fast, fmt, &mut rng_fast);
                for (i, (&f, &x)) in fast.iter().zip(&xs).enumerate() {
                    let want = round_stochastic(x, fmt, rng_ref.next_u32());
                    assert_eq!(
                        f.to_bits(),
                        want.to_bits(),
                        "{} len={len} i={i} x={x}",
                        fmt.name
                    );
                }
                // generator must land exactly where the scalar loop leaves it
                assert_eq!(rng_fast.next_u64(), rng_ref.next_u64(), "{} len={len}", fmt.name);
            }
        }
    }

    #[test]
    fn keyed_slice_matches_scalar_oracle_all_formats() {
        let key = DitherKey::new(7, 0x5352, 3, 1);
        for fmt in ALL {
            for len in [0usize, 1, 7, 255, 256, 257, 1023] {
                let xs = soup(len, 0xDE1 ^ len as u64);
                let mut fast = xs.clone();
                round_stochastic_slice_keyed(&mut fast, fmt, key, 0);
                for (i, (&f, &x)) in fast.iter().zip(&xs).enumerate() {
                    let want = round_stochastic(x, fmt, key.word(i as u64));
                    assert_eq!(f.to_bits(), want.to_bits(), "{} len={len} i={i}", fmt.name);
                }
            }
        }
    }

    #[test]
    fn keyed_slice_chunking_is_invariant() {
        let key = DitherKey::new(11, 0x5352, 9, 2);
        let xs = soup(1000, 0xC0FFEE);
        let mut whole = xs.clone();
        round_stochastic_slice_keyed(&mut whole, BF16, key, 0);
        for chunk in [1usize, 3, 64, 97, 256, 999] {
            let mut pieces = xs.clone();
            let mut off = 0usize;
            while off < pieces.len() {
                let end = (off + chunk).min(pieces.len());
                round_stochastic_slice_keyed(&mut pieces[off..end], BF16, key, off as u64);
                off = end;
            }
            for (i, (a, b)) in pieces.iter().zip(&whole).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk={chunk} i={i}");
            }
        }
    }

    #[test]
    fn rounder_slice_matches_per_element_round() {
        for mode in [RoundMode::Exact, RoundMode::Nearest, RoundMode::Stochastic] {
            let xs = soup(513, 0xD0);
            let mut a = Rounder::new(BF16, mode, 5);
            let mut b = Rounder::new(BF16, mode, 5);
            let mut fast = xs.clone();
            a.round_slice(&mut fast);
            let scalar: Vec<f32> = xs.iter().map(|&x| b.round(x)).collect();
            for (i, (f, s)) in fast.iter().zip(&scalar).enumerate() {
                assert_eq!(f.to_bits(), s.to_bits(), "{mode:?} i={i}");
            }
        }
    }

    #[test]
    fn rounder_policy_dispatch() {
        let mut r = Rounder::new(BF16, RoundMode::Nearest, 1);
        assert_eq!(r.round(1.0 + 2f32.powi(-12)), 1.0);
        let mut e = Rounder::new(BF16, RoundMode::Exact, 1);
        assert_eq!(e.round(1.0 + 2f32.powi(-12)), 1.0 + 2f32.powi(-12));
        let mut s = Rounder::new(BF16, RoundMode::Stochastic, 1);
        let mut vals = vec![1.0 + 2f32.powi(-12); 4096];
        s.round_slice(&mut vals);
        assert!(vals.iter().any(|&v| v > 1.0));
        assert!(vals.iter().any(|&v| v == 1.0));
    }
}
