//! The typed precision policy — the paper's central experiment axis.
//!
//! A [`Policy`] is the (training mode × storage format) pair that selects an
//! AOT artifact, an optimizer update rule and a rounding scheme.  It
//! round-trips the artifact naming convention used throughout the repo:
//! `"sr16"` (bare mode implies bf16) and `"sr16-e8m5"` (explicit format),
//! and `"app__sr16-e8m5"` for full artifact names.  Every call site that
//! used to re-split those strings by hand (config loading, the CLI,
//! `qsim::optim`, the manifest) now goes through this module.

use std::fmt;
use std::str::FromStr;

use super::format::{Format, BF16, FP32};
use super::round::RoundMode;

/// Weight-update policy for one training run (the paper's Algorithms 1-3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Exact 32-bit training (baseline).
    Fp32,
    /// Pure 16-bit FPU with nearest rounding everywhere (the failing mode).
    Standard16,
    /// 16-bit compute + 32-bit master weights (Micikevicius et al.).
    Mixed16,
    /// 16-bit with stochastic rounding on the weight update (Algorithm 2).
    Sr16,
    /// 16-bit with Kahan-compensated weight accumulation (Algorithm 3).
    Kahan16,
    /// Stochastic rounding and Kahan summation combined (Figure 11).
    SrKahan16,
}

impl Mode {
    pub const ALL: [Mode; 6] = [
        Mode::Fp32,
        Mode::Standard16,
        Mode::Mixed16,
        Mode::Sr16,
        Mode::Kahan16,
        Mode::SrKahan16,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Mode::Fp32 => "fp32",
            Mode::Standard16 => "standard16",
            Mode::Mixed16 => "mixed16",
            Mode::Sr16 => "sr16",
            Mode::Kahan16 => "kahan16",
            Mode::SrKahan16 => "srkahan16",
        }
    }

    pub fn by_name(name: &str) -> Option<Mode> {
        Mode::ALL.into_iter().find(|m| m.name() == name)
    }

    pub fn exact_update(&self) -> bool {
        matches!(self, Mode::Fp32 | Mode::Mixed16)
    }

    pub fn stochastic(&self) -> bool {
        matches!(self, Mode::Sr16 | Mode::SrKahan16)
    }

    pub fn kahan(&self) -> bool {
        matches!(self, Mode::Kahan16 | Mode::SrKahan16)
    }

    /// Rounding applied to the weight-accumulate output under this mode.
    pub fn round_mode(&self) -> RoundMode {
        if self.exact_update() {
            RoundMode::Exact
        } else if self.stochastic() {
            RoundMode::Stochastic
        } else {
            RoundMode::Nearest
        }
    }

    /// Format for forward/backward compute under this mode.
    pub fn compute_fmt(&self, fmt: Format) -> Format {
        match self {
            Mode::Fp32 => FP32,
            _ => fmt,
        }
    }
}

impl fmt::Display for Mode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Mode {
    type Err = PolicyParseError;

    fn from_str(s: &str) -> Result<Mode, PolicyParseError> {
        Mode::by_name(s).ok_or_else(|| PolicyParseError::unknown_mode(s))
    }
}

/// Error returned by the `Policy`/`Mode` parsers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PolicyParseError {
    msg: String,
}

impl PolicyParseError {
    fn unknown_mode(s: &str) -> Self {
        let known: Vec<&str> = Mode::ALL.iter().map(|m| m.name()).collect();
        Self { msg: format!("unknown precision mode {s:?} (known: {})", known.join(" ")) }
    }

    fn unknown_fmt(s: &str) -> Self {
        let known: Vec<&str> = super::format::ALL.iter().map(|f| f.name).collect();
        Self { msg: format!("unknown numeric format {s:?} (known: {})", known.join(" ")) }
    }
}

impl fmt::Display for PolicyParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for PolicyParseError {}

/// A complete precision policy: mode × storage format, with the derived
/// weight-update rounding mode and Kahan flag cached alongside.
///
/// `round` and `kahan` are derived from `mode` — construct policies through
/// [`Policy::new`] / [`Policy::parse`] so they stay consistent.  Equality
/// and hashing compare only the semantic `(mode, fmt)` key, so a struct
/// literal with stale derived fields can never break grid lookups.
#[derive(Debug, Clone, Copy, Eq)]
pub struct Policy {
    pub mode: Mode,
    pub fmt: Format,
    pub round: RoundMode,
    pub kahan: bool,
}

impl PartialEq for Policy {
    fn eq(&self, other: &Policy) -> bool {
        self.mode == other.mode && self.fmt == other.fmt
    }
}

impl std::hash::Hash for Policy {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.mode.hash(state);
        self.fmt.hash(state);
    }
}

impl Policy {
    /// Build a policy, deriving the rounding mode and Kahan flag.
    pub fn new(mode: Mode, fmt: Format) -> Policy {
        Policy { mode, fmt, round: mode.round_mode(), kahan: mode.kahan() }
    }

    /// The common case: a mode over bf16 storage.
    pub fn bf16(mode: Mode) -> Policy {
        Policy::new(mode, BF16)
    }

    /// Parse from one mode string and one format string (e.g. CLI
    /// `--mode sr16 --fmt e8m5`, or the manifest's metadata fields).
    pub fn from_parts(mode: &str, fmt: &str) -> Result<Policy, PolicyParseError> {
        let mode = mode.parse::<Mode>()?;
        let fmt = Format::by_name(fmt).ok_or_else(|| PolicyParseError::unknown_fmt(fmt))?;
        Ok(Policy::new(mode, fmt))
    }

    /// Parse a policy name: `"sr16"` (bare mode ⇒ bf16) or `"sr16-e8m5"`.
    pub fn parse(s: &str) -> Result<Policy, PolicyParseError> {
        match s.split_once('-') {
            None => Ok(Policy::bf16(s.parse::<Mode>()?)),
            Some((mode, fmt)) => Policy::from_parts(mode, fmt),
        }
    }

    /// Format for forward/backward compute under this policy.
    pub fn compute_fmt(&self) -> Format {
        self.mode.compute_fmt(self.fmt)
    }

    /// Artifact name in the manifest: `app__mode`, or `app__mode-fmt` for
    /// non-bf16 formats (the bare-bf16 suffix-elision rule).
    pub fn artifact_name(&self, app: &str) -> String {
        format!("{app}__{self}")
    }

    /// Inverse of [`Policy::artifact_name`]: split `"app__mode-fmt"` into
    /// the application and its policy.  A bare application name (no `"__"`)
    /// yields the default fp32/bf16 policy.
    pub fn parse_artifact_name(name: &str) -> Result<(String, Policy), PolicyParseError> {
        match name.split_once("__") {
            None => Ok((name.to_string(), Policy::default())),
            Some((app, policy)) => Ok((app.to_string(), Policy::parse(policy)?)),
        }
    }
}

impl Default for Policy {
    /// The 32-bit baseline over bf16 storage (matching `RunConfig` defaults).
    fn default() -> Policy {
        Policy::bf16(Mode::Fp32)
    }
}

impl fmt::Display for Policy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.fmt == BF16 {
            f.write_str(self.mode.name())
        } else {
            write!(f, "{}-{}", self.mode.name(), self.fmt.name)
        }
    }
}

impl FromStr for Policy {
    type Err = PolicyParseError;

    fn from_str(s: &str) -> Result<Policy, PolicyParseError> {
        Policy::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::super::format::{E8M5, FP16};
    use super::*;

    #[test]
    fn mode_round_trip_by_name() {
        for m in Mode::ALL {
            assert_eq!(Mode::by_name(m.name()), Some(m));
            assert_eq!(m.name().parse::<Mode>(), Ok(m));
        }
        assert_eq!(Mode::by_name("bogus"), None);
        assert!("bogus".parse::<Mode>().is_err());
    }

    #[test]
    fn derived_fields_follow_mode() {
        assert_eq!(Policy::bf16(Mode::Fp32).round, RoundMode::Exact);
        assert_eq!(Policy::bf16(Mode::Mixed16).round, RoundMode::Exact);
        assert_eq!(Policy::bf16(Mode::Standard16).round, RoundMode::Nearest);
        assert_eq!(Policy::bf16(Mode::Sr16).round, RoundMode::Stochastic);
        let combo = Policy::bf16(Mode::SrKahan16);
        assert_eq!(combo.round, RoundMode::Stochastic);
        assert!(combo.kahan);
        assert!(Policy::bf16(Mode::Kahan16).kahan);
        assert!(!Policy::bf16(Mode::Sr16).kahan);
    }

    #[test]
    fn display_elides_bf16() {
        assert_eq!(Policy::bf16(Mode::Sr16).to_string(), "sr16");
        assert_eq!(Policy::new(Mode::Sr16, E8M5).to_string(), "sr16-e8m5");
        assert_eq!(Policy::new(Mode::Kahan16, FP16).to_string(), "kahan16-fp16");
    }

    #[test]
    fn parse_accepts_explicit_bf16_and_normalizes() {
        let p = Policy::parse("sr16-bf16").unwrap();
        assert_eq!(p, Policy::bf16(Mode::Sr16));
        assert_eq!(p.to_string(), "sr16");
    }

    #[test]
    fn artifact_names_round_trip() {
        let p = Policy::new(Mode::Kahan16, E8M5);
        assert_eq!(p.artifact_name("dlrm-small"), "dlrm-small__kahan16-e8m5");
        let (app, q) = Policy::parse_artifact_name("dlrm-small__kahan16-e8m5").unwrap();
        assert_eq!(app, "dlrm-small");
        assert_eq!(q, p);
        // bare app name (no policy suffix) defaults to fp32/bf16
        let (app, q) = Policy::parse_artifact_name("lsq").unwrap();
        assert_eq!(app, "lsq");
        assert_eq!(q, Policy::default());
    }

    #[test]
    fn compute_fmt_only_fp32_escapes() {
        assert!(Policy::bf16(Mode::Fp32).compute_fmt().is_fp32());
        assert_eq!(Policy::new(Mode::Sr16, E8M5).compute_fmt(), E8M5);
    }
}
