//! Kahan compensated accumulation in a target format (paper Algorithm 1).
//!
//! All four operations of the compensation step are themselves rounded to
//! the format — only 16-bit FPUs are assumed, exactly as in the paper.

use super::format::Format;
use super::round::round_nearest;

/// One Kahan-compensated accumulation step in format `fmt`.
///
/// Returns `(sum', comp')` for `sum + u` where `comp` carries the running
/// rounding error.  With `fmt = FP32` this degenerates to classic Kahan
/// summation in single precision.
#[inline]
pub fn kahan_add(sum: f32, comp: f32, u: f32, fmt: Format) -> (f32, f32) {
    let r = |x: f32| round_nearest(x, fmt);
    let y = r(u - comp);
    let s = r(sum + y);
    let c = r(r(s - sum) - y);
    (s, c)
}

/// A Kahan accumulator bound to a format.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KahanAcc {
    pub sum: f32,
    pub comp: f32,
    pub fmt: Format,
}

impl KahanAcc {
    pub fn new(init: f32, fmt: Format) -> Self {
        Self { sum: round_nearest(init, fmt), comp: 0.0, fmt }
    }

    #[inline]
    pub fn add(&mut self, u: f32) {
        let (s, c) = kahan_add(self.sum, self.comp, u, self.fmt);
        self.sum = s;
        self.comp = c;
    }

    pub fn value(&self) -> f32 {
        self.sum
    }
}

#[cfg(test)]
mod tests {
    use super::super::format::{BF16, FP32};
    use super::*;

    #[test]
    fn recovers_tiny_increments_in_bf16() {
        // adding 2^-12 to 1.0 in bf16: plain rounding cancels every step,
        // Kahan lands one spacing (2^-8) every 16 steps.
        let mut acc = KahanAcc::new(1.0, BF16);
        for _ in 0..1600 {
            acc.add(2f32.powi(-12));
        }
        let exact = 1.0 + 1600.0 * 2f32.powi(-12);
        assert!((acc.value() - exact).abs() <= 2f32.powi(-8), "{}", acc.value());

        // the naive accumulator provably halts
        let mut naive = 1.0f32;
        for _ in 0..1600 {
            naive = super::round_nearest(naive + 2f32.powi(-12), BF16);
        }
        assert_eq!(naive, 1.0);
    }

    #[test]
    fn error_independent_of_stream_length() {
        // sum n copies of x: compensated error stays O(eps), naive is O(n eps)
        let x = 0.123f32;
        for n in [100usize, 10_000] {
            let mut acc = KahanAcc::new(0.0, FP32);
            for _ in 0..n {
                acc.add(x);
            }
            let exact = x as f64 * n as f64;
            let rel = ((acc.value() as f64 - exact) / exact).abs();
            assert!(rel < 1e-6, "n={n} rel={rel}");
        }
    }

    #[test]
    fn comp_records_cancelled_update() {
        let (s, c) = kahan_add(1.0, 0.0, 2f32.powi(-12), BF16);
        assert_eq!(s, 1.0);
        // comp = (s - sum) - y = -u, i.e. it remembers the lost mass
        assert_eq!(c, -(2f32.powi(-12)));
    }
}
