//! `qsim::nn` — reusable layer library over the quantised tape.
//!
//! The layer logic that used to be hand-rolled inside `qsim::dlrm` (embedding
//! gathers, Linear + bias, two-layer MLP blocks), extracted so every native
//! application (DLRM, gpt-nano, future scenarios) composes the same audited
//! building blocks instead of re-deriving them.
//!
//! ## Parameter registration contract
//!
//! Layers own their parameter tensors (kept in-format by the caller's
//! optimizer, exactly like the DLRM fields they replace).  Every training
//! `forward` registers its tensors on the tape via `param_from` and appends
//! the resulting [`Var`]s to the caller's list **in a fixed order** — the
//! same order [`Module::params`]/[`Module::params_mut`] walk.  That shared
//! order is what maps each tensor to its optimizer slot and counter-keyed
//! dither `tensor_id`, so it is part of the reproducibility contract:
//! reordering registrations changes SR trajectories.
//!
//! ## Training vs. inference split
//!
//! Every layer has two forward families with one graph shape:
//!
//! * **Training** — `forward`/`forward_relu` register parameters via
//!   `param_from` (gradients collected, optimizer slots assigned) and are
//!   the only entry points `Trainer::step` uses.
//! * **Inference** — `forward_frozen`/`forward_relu_frozen` build the
//!   *same* ops from no-grad `input` leaves: no gradient buffers, no
//!   optimizer registration, native-16 weights widened on tape entry.
//!   These are the graphs `Model::frozen_graph_into` assembles, which
//!   both the per-batch eval tapes and the `qsim::infer` compiled plans
//!   (eval routing, `repro serve`) replay.  Frozen and trainable forwards
//!   are bit-identical op for op, so eval losses, serve logits and
//!   training-forward values can be compared bit-for-bit.

use crate::precision::{round_nearest, Format};
use crate::util::rng::Rng;

use super::tape::{Tape, Var};
use super::tensor::Tensor;

/// Quantise a freshly-initialised parameter onto the storage format.
fn quant(mut t: Tensor, fmt: Format) -> Tensor {
    for x in &mut t.data {
        *x = round_nearest(*x, fmt);
    }
    t
}

/// Anything owning parameter tensors in a fixed registration order.
pub trait Module {
    /// Parameter tensors, in the same order the forward pass registers them.
    fn params(&self) -> Vec<&Tensor>;
    /// Mutable view in the same order (optimizer updates).
    fn params_mut(&mut self) -> Vec<&mut Tensor>;
    /// Number of parameter tensors this module registers.
    fn num_params(&self) -> usize {
        self.params().len()
    }
}

/// Fully-connected layer `x @ w (+ b)`; He-initialised, stored in-format.
#[derive(Debug, Clone)]
pub struct Linear {
    pub w: Tensor,
    pub b: Option<Tensor>,
}

impl Linear {
    /// He init: `w ~ N(0, 2/in_dim)`, quantised onto `fmt`; bias zeros.
    pub fn init(in_dim: usize, out_dim: usize, bias: bool, fmt: Format, rng: &mut Rng) -> Self {
        let w = quant(
            Tensor::randn(in_dim, out_dim, (2.0 / in_dim.max(1) as f32).sqrt(), rng),
            fmt,
        );
        Self { w, b: bias.then(|| Tensor::zeros(1, out_dim)) }
    }

    /// Register params and build `x @ w (+ b)`; pushes `[w, (b)]` onto
    /// `params` in that order.
    ///
    /// The biased case records the fused [`Tape::affine`] node — the
    /// `matmul + add_row` rewrite, admitted by `qsim::verify` as
    /// bit-identical to the unfused chain — so registration order and
    /// numerics are unchanged while the bias add happens in the matmul
    /// panel.
    pub fn forward(&self, t: &mut Tape, x: Var, params: &mut Vec<Var>) -> Var {
        let wv = t.param_from(&self.w);
        params.push(wv);
        match &self.b {
            Some(b) => {
                let bv = t.param_from(b);
                params.push(bv);
                t.affine(x, wv, bv, false)
            }
            None => t.matmul(x, wv),
        }
    }

    /// [`Linear::forward`] with a trailing relu, fused into the same
    /// affine node when a bias is present (`matmul + add_row + relu` →
    /// `affine(relu)`, the second admitted rewrite).
    pub fn forward_relu(&self, t: &mut Tape, x: Var, params: &mut Vec<Var>) -> Var {
        let wv = t.param_from(&self.w);
        params.push(wv);
        match &self.b {
            Some(b) => {
                let bv = t.param_from(b);
                params.push(bv);
                t.affine(x, wv, bv, true)
            }
            None => {
                let y = t.matmul(x, wv);
                t.relu(y)
            }
        }
    }

    /// Same graph from no-grad inputs (inference/eval paths).
    pub fn forward_frozen(&self, t: &mut Tape, x: Var) -> Var {
        let wv = t.input(self.w.clone());
        match &self.b {
            Some(b) => {
                let bv = t.input(b.clone());
                t.affine(x, wv, bv, false)
            }
            None => t.matmul(x, wv),
        }
    }

    /// [`Linear::forward_relu`] from no-grad inputs.
    pub fn forward_relu_frozen(&self, t: &mut Tape, x: Var) -> Var {
        let wv = t.input(self.w.clone());
        match &self.b {
            Some(b) => {
                let bv = t.input(b.clone());
                t.affine(x, wv, bv, true)
            }
            None => {
                let y = t.matmul(x, wv);
                t.relu(y)
            }
        }
    }
}

impl Module for Linear {
    fn params(&self) -> Vec<&Tensor> {
        let mut v = vec![&self.w];
        if let Some(b) = &self.b {
            v.push(b);
        }
        v
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v = vec![&mut self.w];
        if let Some(b) = &mut self.b {
            v.push(b);
        }
        v
    }
}

/// Embedding table: an `(n, dim)` tensor gathered by row index.
#[derive(Debug, Clone)]
pub struct Embedding {
    pub table: Tensor,
}

impl Embedding {
    /// Uniform init in `[-scale, scale)`, quantised onto `fmt`.
    pub fn init(n: usize, dim: usize, scale: f32, fmt: Format, rng: &mut Rng) -> Self {
        Self { table: quant(Tensor::rand_uniform(n, dim, -scale, scale, rng), fmt) }
    }

    /// Register the table and gather `idx` rows; pushes `[table]` onto
    /// `params`.
    pub fn forward(&self, t: &mut Tape, idx: Vec<usize>, params: &mut Vec<Var>) -> Var {
        let tv = self.bind(t, params);
        t.gather_rows(tv, idx)
    }

    /// Register the table *without* gathering — for weight tying, where the
    /// caller reuses the returned [`Var`] for both input gathers and the
    /// `matmul_nt` output projection (one shared parameter node, gradients
    /// from both paths accumulate into it).
    pub fn bind(&self, t: &mut Tape, params: &mut Vec<Var>) -> Var {
        let tv = t.param_from(&self.table);
        params.push(tv);
        tv
    }

    /// Gather from a no-grad copy of the table (inference/eval paths).
    pub fn forward_frozen(&self, t: &mut Tape, idx: Vec<usize>) -> Var {
        let tv = t.input(self.table.clone());
        t.gather_rows(tv, idx)
    }
}

impl Module for Embedding {
    fn params(&self) -> Vec<&Tensor> {
        vec![&self.table]
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        vec![&mut self.table]
    }
}

/// Two-layer MLP block: `relu(x @ w1 + b1) @ w2 + b2`.
#[derive(Debug, Clone)]
pub struct Mlp {
    pub fc1: Linear,
    pub fc2: Linear,
}

impl Mlp {
    pub fn init(in_dim: usize, hidden: usize, out_dim: usize, fmt: Format, rng: &mut Rng) -> Self {
        Self {
            fc1: Linear::init(in_dim, hidden, true, fmt, rng),
            fc2: Linear::init(hidden, out_dim, true, fmt, rng),
        }
    }

    /// Pushes `[fc1.w, fc1.b, fc2.w, fc2.b]` onto `params`.  The hidden
    /// layer runs as one fused affine-relu node (fc1 always carries a
    /// bias) — same numerics, same registration order, one kernel.
    pub fn forward(&self, t: &mut Tape, x: Var, params: &mut Vec<Var>) -> Var {
        let r = self.fc1.forward_relu(t, x, params);
        self.fc2.forward(t, r, params)
    }

    pub fn forward_frozen(&self, t: &mut Tape, x: Var) -> Var {
        let r = self.fc1.forward_relu_frozen(t, x);
        self.fc2.forward_frozen(t, r)
    }
}

impl Module for Mlp {
    fn params(&self) -> Vec<&Tensor> {
        let mut v = self.fc1.params();
        v.extend(self.fc2.params());
        v
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v = self.fc1.params_mut();
        v.extend(self.fc2.params_mut());
        v
    }
}

/// Non-affine row-wise layer normalisation.
///
/// No parameters: the paper's precision story lives in the *weight updates*,
/// and a learnable gain/shift would just be another pair of in-format
/// Linears — the plain normaliser keeps the op inventory minimal while
/// giving the transformer its conditioning.
#[derive(Debug, Clone, Copy)]
pub struct LayerNorm {
    pub eps: f32,
}

impl LayerNorm {
    pub fn new() -> Self {
        Self { eps: 1e-5 }
    }

    pub fn forward(&self, t: &mut Tape, x: Var) -> Var {
        t.layernorm(x, self.eps)
    }
}

impl Default for LayerNorm {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::super::tape::QPolicy;
    use super::*;
    use crate::precision::BF16;

    #[test]
    fn linear_registers_params_in_order_and_computes() {
        let mut rng = Rng::new(1, 0);
        let lin = Linear::init(3, 2, true, BF16, &mut rng);
        assert_eq!(lin.num_params(), 2);
        let mut t = Tape::new(QPolicy::exact());
        let x = t.input(Tensor::from_vec(1, 3, vec![1.0, 0.0, 0.0]));
        let mut params = Vec::new();
        let y = lin.forward(&mut t, x, &mut params);
        assert_eq!(params.len(), 2);
        // x = e0 ⇒ y = w row 0 (+ zero bias)
        let out = t.value(y);
        for (c, &o) in out.data.iter().enumerate() {
            assert_eq!(o, lin.w.at(0, c));
        }
        // params are in-format
        for &p in &lin.w.data {
            assert_eq!(p, crate::precision::round_nearest(p, BF16));
        }
    }

    #[test]
    fn linear_without_bias_registers_one_tensor() {
        let mut rng = Rng::new(2, 0);
        let lin = Linear::init(4, 4, false, BF16, &mut rng);
        assert_eq!(lin.num_params(), 1);
        let mut t = Tape::new(QPolicy::exact());
        let x = t.input(Tensor::from_vec(2, 4, vec![0.5; 8]));
        let mut params = Vec::new();
        let _ = lin.forward(&mut t, x, &mut params);
        assert_eq!(params.len(), 1);
    }

    #[test]
    fn embedding_gathers_rows_and_ties() {
        let mut rng = Rng::new(3, 0);
        let emb = Embedding::init(5, 3, 0.1, BF16, &mut rng);
        let mut t = Tape::new(QPolicy::exact());
        let mut params = Vec::new();
        let tv = emb.bind(&mut t, &mut params);
        let gathered = t.gather_rows(tv, vec![4, 0]);
        let gv = t.value(gathered);
        for c in 0..3 {
            assert_eq!(gv.at(0, c), emb.table.at(4, c));
            assert_eq!(gv.at(1, c), emb.table.at(0, c));
        }
        // tied use: the same var feeds an output projection; both paths'
        // gradients land on one tensor
        let logits = t.matmul_nt(gathered, tv);
        let loss = t.softmax_xent(logits, vec![1, 2]);
        t.backward(loss);
        assert!(t.grad(tv).is_some());
        assert_eq!(params.len(), 1);
    }

    #[test]
    fn mlp_frozen_matches_trainable_forward() {
        let mut rng = Rng::new(4, 0);
        let mlp = Mlp::init(4, 8, 2, BF16, &mut rng);
        assert_eq!(mlp.num_params(), 4);
        let x = Tensor::randn(3, 4, 1.0, &mut rng);
        let mut t1 = Tape::new(QPolicy::new(BF16));
        let mut params = Vec::new();
        let xv1 = t1.input_from(&x);
        let y1 = mlp.forward(&mut t1, xv1, &mut params);
        assert_eq!(params.len(), 4);
        let mut t2 = Tape::new(QPolicy::new(BF16));
        let xv2 = t2.input_from(&x);
        let y2 = mlp.forward_frozen(&mut t2, xv2);
        for (a, b) in t1.value(y1).data.iter().zip(&t2.value(y2).data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
