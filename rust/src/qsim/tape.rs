//! Reverse-mode autograd with per-operator output rounding.
//!
//! This is the rust-native equivalent of the paper's QPyTorch simulator
//! (and of our L2 `qops.py`): every forward operator accumulates in fp32
//! and rounds its output onto the compute format; every backward cotangent
//! is rounded at each operator boundary.  The quantisation *policy* is
//! per-graph, so the theory experiments can independently toggle rounding
//! for forward/backward compute versus weight updates (Figure 2).

use crate::precision::{round_nearest, Format, FP32};

use super::tensor::Tensor;

/// Rounding policy for forward/backward compute.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QPolicy {
    pub fmt: Format,
}

impl QPolicy {
    pub fn exact() -> Self {
        Self { fmt: FP32 }
    }

    pub fn new(fmt: Format) -> Self {
        Self { fmt }
    }

    #[inline]
    fn q(&self, t: Tensor) -> Tensor {
        if self.fmt.is_fp32() {
            return t;
        }
        let mut t = t;
        for x in &mut t.data {
            *x = round_nearest(*x, self.fmt);
        }
        t
    }
}

/// Index of a node in the tape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub usize);

enum Op {
    /// Leaf (input or parameter).
    Leaf,
    MatMul(Var, Var),
    Add(Var, Var),
    Sub(Var, Var),
    Mul(Var, Var),
    Relu(Var),
    Sigmoid(Var),
    Tanh(Var),
    /// Row gather: out[r] = table[idx[r]].
    Embed { table: Var, idx: Vec<usize> },
    /// Mean over all elements -> scalar.
    MeanAll(Var),
    /// 0.5 * mean(d^2) fused loss over a difference node -> scalar.
    MseLoss(Var),
    /// BCE-with-logits fused loss vs labels tensor -> scalar.
    BceLoss { logits: Var, labels: Tensor },
    /// Broadcast a (1, n) bias over rows of a (m, n) input.
    AddRow(Var, Var),
    /// Column-wise concatenation of same-row-count tensors (memory op).
    ConcatCols(Vec<Var>),
}

struct Node {
    op: Op,
    value: Tensor,
    grad: Option<Tensor>,
}

/// The autograd tape: build forward ops, then `backward` from a scalar.
pub struct Tape {
    nodes: Vec<Node>,
    pub policy: QPolicy,
}

impl Tape {
    pub fn new(policy: QPolicy) -> Self {
        Self { nodes: Vec::new(), policy }
    }

    fn push(&mut self, op: Op, value: Tensor) -> Var {
        self.nodes.push(Node { op, value, grad: None });
        Var(self.nodes.len() - 1)
    }

    /// Register an input (no gradient collected).
    pub fn input(&mut self, t: Tensor) -> Var {
        self.push(Op::Leaf, t)
    }

    /// Register a parameter (gradient collected).  The value is used as
    /// stored — callers keep parameters in-format themselves.
    pub fn param(&mut self, t: Tensor) -> Var {
        self.push(Op::Leaf, t)
    }

    pub fn value(&self, v: Var) -> &Tensor {
        &self.nodes[v.0].value
    }

    pub fn grad(&self, v: Var) -> Option<&Tensor> {
        self.nodes[v.0].grad.as_ref()
    }

    // -- forward ops (each rounds its output once) -------------------------

    pub fn matmul(&mut self, a: Var, b: Var) -> Var {
        let out = self.policy.q(self.nodes[a.0].value.matmul(&self.nodes[b.0].value));
        self.push(Op::MatMul(a, b), out)
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        let out = self
            .policy
            .q(self.nodes[a.0].value.zip(&self.nodes[b.0].value, |x, y| x + y));
        self.push(Op::Add(a, b), out)
    }

    /// Broadcast-add a (1, n) bias to an (m, n) activation.
    pub fn add_row(&mut self, a: Var, bias: Var) -> Var {
        let av = &self.nodes[a.0].value;
        let bv = &self.nodes[bias.0].value;
        assert_eq!(bv.rows, 1);
        assert_eq!(bv.cols, av.cols);
        let mut out = av.clone();
        for r in 0..out.rows {
            for c in 0..out.cols {
                *out.at_mut(r, c) += bv.at(0, c);
            }
        }
        let out = self.policy.q(out);
        self.push(Op::AddRow(a, bias), out)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        let out = self
            .policy
            .q(self.nodes[a.0].value.zip(&self.nodes[b.0].value, |x, y| x - y));
        self.push(Op::Sub(a, b), out)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        let out = self
            .policy
            .q(self.nodes[a.0].value.zip(&self.nodes[b.0].value, |x, y| x * y));
        self.push(Op::Mul(a, b), out)
    }

    pub fn relu(&mut self, a: Var) -> Var {
        let out = self.policy.q(self.nodes[a.0].value.map(|x| x.max(0.0)));
        self.push(Op::Relu(a), out)
    }

    pub fn sigmoid(&mut self, a: Var) -> Var {
        let out = self.policy.q(self.nodes[a.0].value.map(|x| 1.0 / (1.0 + (-x).exp())));
        self.push(Op::Sigmoid(a), out)
    }

    pub fn tanh(&mut self, a: Var) -> Var {
        let out = self.policy.q(self.nodes[a.0].value.map(f32::tanh));
        self.push(Op::Tanh(a), out)
    }

    /// Embedding lookup: rows of `table` selected by `idx`.
    pub fn embed(&mut self, table: Var, idx: Vec<usize>) -> Var {
        let tv = &self.nodes[table.0].value;
        let mut out = Tensor::zeros(idx.len(), tv.cols);
        for (r, &i) in idx.iter().enumerate() {
            let row = &tv.data[i * tv.cols..(i + 1) * tv.cols];
            out.data[r * tv.cols..(r + 1) * tv.cols].copy_from_slice(row);
        }
        // gather is a memory op: values already in-format, no rounding
        self.push(Op::Embed { table, idx }, out)
    }

    /// Column-wise concat (a memory op: values pass through unrounded).
    pub fn concat_cols(&mut self, parts: Vec<Var>) -> Var {
        assert!(!parts.is_empty());
        let rows = self.nodes[parts[0].0].value.rows;
        let total: usize = parts.iter().map(|v| self.nodes[v.0].value.cols).collect::<Vec<_>>().iter().sum();
        let mut out = Tensor::zeros(rows, total);
        let mut off = 0;
        for &p in &parts {
            let pv = &self.nodes[p.0].value;
            assert_eq!(pv.rows, rows, "concat row mismatch");
            for r in 0..rows {
                out.data[r * total + off..r * total + off + pv.cols]
                    .copy_from_slice(&pv.data[r * pv.cols..(r + 1) * pv.cols]);
            }
            off += pv.cols;
        }
        self.push(Op::ConcatCols(parts), out)
    }

    pub fn mean_all(&mut self, a: Var) -> Var {
        let v = &self.nodes[a.0].value;
        let m = v.data.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        let out = self.policy.q(Tensor::scalar(m as f32));
        self.push(Op::MeanAll(a), out)
    }

    /// Fused 0.5·mean((a-b)²) — one output rounding, like qops.mse_loss.
    pub fn mse_loss(&mut self, a: Var, b: Var) -> Var {
        let d = self.sub(a, b);
        let dv = &self.nodes[d.0].value;
        let m = dv.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
            / dv.len() as f64;
        let out = self.policy.q(Tensor::scalar(0.5 * m as f32));
        self.push(Op::MseLoss(d), out)
    }

    /// Fused BCE-with-logits against constant labels.
    pub fn bce_loss(&mut self, logits: Var, labels: Tensor) -> Var {
        let lv = &self.nodes[logits.0].value;
        assert_eq!(lv.len(), labels.len());
        let mut acc = 0f64;
        for (&z, &y) in lv.data.iter().zip(&labels.data) {
            // -(y log σ(z) + (1-y) log σ(-z)) = max(z,0) - zy + log(1+e^-|z|)
            let l = z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
            acc += l as f64;
        }
        let out = self.policy.q(Tensor::scalar((acc / lv.len() as f64) as f32));
        self.push(Op::BceLoss { logits, labels }, out)
    }

    // -- backward -----------------------------------------------------------

    fn accumulate(&mut self, v: Var, g: Tensor) {
        // Cotangents are rounded at every operator boundary (same rule as
        // qops._qcast_bwd); accumulation of fan-in happens in fp32 then is
        // rounded once.
        let g = self.policy.q(g);
        match &mut self.nodes[v.0].grad {
            Some(existing) => {
                let summed = existing.zip(&g, |a, b| a + b);
                *existing = self.policy.q(summed);
            }
            None => self.nodes[v.0].grad = Some(g),
        }
    }

    /// Run reverse-mode from scalar `root` (seed gradient 1.0).
    pub fn backward(&mut self, root: Var) {
        assert_eq!(self.nodes[root.0].value.len(), 1, "backward from non-scalar");
        self.nodes[root.0].grad = Some(Tensor::scalar(1.0));
        for i in (0..=root.0).rev() {
            let Some(g) = self.nodes[i].grad.clone() else { continue };
            // Split borrows: read values, then push grads.
            match &self.nodes[i].op {
                Op::Leaf => {}
                Op::MatMul(a, b) => {
                    let (a, b) = (*a, *b);
                    let av = self.nodes[a.0].value.clone();
                    let bv = self.nodes[b.0].value.clone();
                    let da = g.matmul(&bv.transpose());
                    let db = av.transpose().matmul(&g);
                    self.accumulate(a, da);
                    self.accumulate(b, db);
                }
                Op::Add(a, b) => {
                    let (a, b) = (*a, *b);
                    self.accumulate(a, g.clone());
                    self.accumulate(b, g);
                }
                Op::AddRow(a, bias) => {
                    let (a, bias) = (*a, *bias);
                    let mut db = Tensor::zeros(1, g.cols);
                    for r in 0..g.rows {
                        for c in 0..g.cols {
                            *db.at_mut(0, c) += g.at(r, c);
                        }
                    }
                    self.accumulate(a, g);
                    self.accumulate(bias, db);
                }
                Op::Sub(a, b) => {
                    let (a, b) = (*a, *b);
                    self.accumulate(a, g.clone());
                    self.accumulate(b, g.map(|x| -x));
                }
                Op::Mul(a, b) => {
                    let (a, b) = (*a, *b);
                    let av = self.nodes[a.0].value.clone();
                    let bv = self.nodes[b.0].value.clone();
                    self.accumulate(a, g.zip(&bv, |gg, y| gg * y));
                    self.accumulate(b, g.zip(&av, |gg, x| gg * x));
                }
                Op::Relu(a) => {
                    let a = *a;
                    let av = self.nodes[a.0].value.clone();
                    self.accumulate(a, g.zip(&av, |gg, x| if x > 0.0 { gg } else { 0.0 }));
                }
                Op::Sigmoid(a) => {
                    let a = *a;
                    let yv = self.nodes[i].value.clone();
                    self.accumulate(a, g.zip(&yv, |gg, y| gg * y * (1.0 - y)));
                }
                Op::Tanh(a) => {
                    let a = *a;
                    let yv = self.nodes[i].value.clone();
                    self.accumulate(a, g.zip(&yv, |gg, y| gg * (1.0 - y * y)));
                }
                Op::Embed { table, idx } => {
                    let table = *table;
                    let idx = idx.clone();
                    let tv = &self.nodes[table.0].value;
                    let mut dt = Tensor::zeros(tv.rows, tv.cols);
                    for (r, &row_i) in idx.iter().enumerate() {
                        for c in 0..g.cols {
                            *dt.at_mut(row_i, c) += g.at(r, c);
                        }
                    }
                    self.accumulate(table, dt);
                }
                Op::MeanAll(a) => {
                    let a = *a;
                    let n = self.nodes[a.0].value.len() as f32;
                    let seed = g.item() / n;
                    let av = &self.nodes[a.0].value;
                    let da = Tensor {
                        rows: av.rows,
                        cols: av.cols,
                        data: vec![seed; av.len()],
                    };
                    self.accumulate(a, da);
                }
                Op::MseLoss(d) => {
                    let d = *d;
                    let dv = self.nodes[d.0].value.clone();
                    let n = dv.len() as f32;
                    let seed = g.item();
                    self.accumulate(d, dv.map(|x| seed * x / n));
                }
                Op::ConcatCols(parts) => {
                    let parts = parts.clone();
                    let mut off = 0;
                    for p in parts {
                        let pv_cols = self.nodes[p.0].value.cols;
                        let pv_rows = self.nodes[p.0].value.rows;
                        let mut dp = Tensor::zeros(pv_rows, pv_cols);
                        for r in 0..pv_rows {
                            dp.data[r * pv_cols..(r + 1) * pv_cols].copy_from_slice(
                                &g.data[r * g.cols + off..r * g.cols + off + pv_cols],
                            );
                        }
                        self.accumulate(p, dp);
                        off += pv_cols;
                    }
                }
                Op::BceLoss { logits, labels } => {
                    let logits = *logits;
                    let labels = labels.clone();
                    let lv = self.nodes[logits.0].value.clone();
                    let n = lv.len() as f32;
                    let seed = g.item();
                    let dl = lv.zip(&labels, |z, y| {
                        let p = 1.0 / (1.0 + (-z).exp());
                        seed * (p - y) / n
                    });
                    self.accumulate(logits, dl);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::BF16;

    fn fd_check(f: impl Fn(&[f32]) -> f32, xs: &[f32], analytic: &[f32], tol: f32) {
        let h = 1e-3f32;
        for i in 0..xs.len() {
            let mut up = xs.to_vec();
            up[i] += h;
            let mut dn = xs.to_vec();
            dn[i] -= h;
            let fd = (f(&up) - f(&dn)) / (2.0 * h);
            assert!(
                (fd - analytic[i]).abs() <= tol * (1.0 + fd.abs()),
                "grad[{i}] analytic={} fd={fd}",
                analytic[i]
            );
        }
    }

    #[test]
    fn matmul_grad_matches_finite_difference() {
        let xs = vec![0.3f32, -0.7, 1.2, 0.5, -0.2, 0.9];
        let f = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let a = t.input(Tensor::from_vec(2, 3, vec![1.0, 2.0, -1.0, 0.5, 0.1, 0.3]));
            let wv = t.param(Tensor::from_vec(3, 2, w.to_vec()));
            let y = t.matmul(a, wv);
            let s = t.sigmoid(y);
            let target = t.input(Tensor::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]));
            let l = t.mse_loss(s, target);
            t.value(l).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let a = t.input(Tensor::from_vec(2, 3, vec![1.0, 2.0, -1.0, 0.5, 0.1, 0.3]));
        let wv = t.param(Tensor::from_vec(3, 2, xs.clone()));
        let y = t.matmul(a, wv);
        let s = t.sigmoid(y);
        let target = t.input(Tensor::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]));
        let l = t.mse_loss(s, target);
        t.backward(l);
        let g = t.grad(wv).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }

    #[test]
    fn bce_grad_matches_finite_difference() {
        let xs = vec![0.2f32, -0.4, 0.8];
        let labels = Tensor::vector(vec![1.0, 0.0, 1.0]);
        let f = |z: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let zv = t.param(Tensor::vector(z.to_vec()));
            let l = t.bce_loss(zv, Tensor::vector(vec![1.0, 0.0, 1.0]));
            t.value(l).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let zv = t.param(Tensor::vector(xs.clone()));
        let l = t.bce_loss(zv, labels);
        t.backward(l);
        let g = t.grad(zv).unwrap().data.clone();
        fd_check(f, &xs, &g, 1e-2);
    }

    #[test]
    fn embed_grad_scatters_rows() {
        let mut t = Tape::new(QPolicy::exact());
        let table = t.param(Tensor::from_vec(4, 2, (0..8).map(|i| i as f32).collect()));
        let e = t.embed(table, vec![1, 1, 3]);
        let m = t.mean_all(e);
        t.backward(m);
        let g = t.grad(table).unwrap();
        // 6 elements in `e`; each contributes 1/6
        assert_eq!(g.at(1, 0), 2.0 / 6.0);
        assert_eq!(g.at(3, 1), 1.0 / 6.0);
        assert_eq!(g.at(0, 0), 0.0);
    }

    #[test]
    fn quantised_forward_outputs_in_format() {
        let mut t = Tape::new(QPolicy::new(BF16));
        let a = t.input(Tensor::vector(vec![1.0001, 2.3456, -0.0001234]));
        let b = t.input(Tensor::vector(vec![1.0, 1.0, 1.0]));
        let s = t.add(a, b);
        for &x in &t.value(s).data {
            assert_eq!(x, crate::precision::round_nearest(x, BF16));
        }
    }

    #[test]
    fn relu_tanh_add_row_backward() {
        let xs = vec![0.5f32, -0.3];
        let f = |b: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let a = t.input(Tensor::from_vec(2, 2, vec![1.0, -1.0, 0.5, 2.0]));
            let bias = t.param(Tensor::vector(b.to_vec()));
            let h = t.add_row(a, bias);
            let r = t.relu(h);
            let th = t.tanh(r);
            let m = t.mean_all(th);
            t.value(m).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let a = t.input(Tensor::from_vec(2, 2, vec![1.0, -1.0, 0.5, 2.0]));
        let bias = t.param(Tensor::vector(xs.clone()));
        let h = t.add_row(a, bias);
        let r = t.relu(h);
        let th = t.tanh(r);
        let m = t.mean_all(th);
        t.backward(m);
        let g = t.grad(bias).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }
}
