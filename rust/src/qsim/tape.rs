//! Reverse-mode autograd with per-operator output rounding.
//!
//! This is the rust-native equivalent of the paper's QPyTorch simulator
//! (and of our L2 `qops.py`): every forward operator accumulates in fp32
//! and rounds its output onto the compute format; every backward cotangent
//! is rounded at each operator boundary.  The quantisation *policy* is
//! per-graph, so the theory experiments can independently toggle rounding
//! for forward/backward compute versus weight updates (Figure 2).
//!
//! ## Arena reuse
//!
//! Trainers rebuild the graph every step, so the tape retains its node and
//! gradient buffers across steps: [`Tape::reset`] clears the recorded graph
//! but moves every tensor allocation into a free pool that subsequent ops
//! draw from.  **`reset` invalidates all outstanding [`Var`]s** — after a
//! reset the graph must be rebuilt from scratch.  Steady-state training
//! therefore runs allocation-free once buffer capacities have converged
//! (usually within two steps).

use std::sync::Arc;

use crate::precision::{
    round_nearest, round_nearest_slice, round_nearest_slice_simd, Format, FP32,
};

use super::pool::Pool;
use super::tensor::{bf16_bits_to_f32, Storage, Tensor};
use super::Backend;

/// Minimum element count before an elementwise op fans out across the
/// worker pool (memory-bound loops amortize the dispatch handshake slowly).
const EW_PAR_MIN: usize = 8192;

/// Minimum multiply-accumulate count (`seqs · T² · d`) before the fused
/// attention kernel fans its sequences out across the worker pool.
const ATTN_PAR_MIN: usize = 16_384;

/// Rounding policy for forward/backward compute.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QPolicy {
    pub fmt: Format,
    pub backend: Backend,
}

impl QPolicy {
    pub fn exact() -> Self {
        Self { fmt: FP32, backend: Backend::Fast }
    }

    pub fn new(fmt: Format) -> Self {
        Self { fmt, backend: Backend::Fast }
    }

    pub fn with_backend(fmt: Format, backend: Backend) -> Self {
        Self { fmt, backend }
    }

    /// Round a slice in place per the policy (the per-operator output
    /// rounding).  Backends are bit-identical; `Reference` keeps the
    /// original scalar loop for baseline timing, `Simd` routes through the
    /// 8-wide lane kernel.
    #[inline]
    pub(crate) fn q_slice(&self, xs: &mut [f32]) {
        if self.fmt.is_fp32() {
            return;
        }
        match self.backend {
            Backend::Fast => round_nearest_slice(xs, self.fmt),
            Backend::Simd => round_nearest_slice_simd(xs, self.fmt),
            Backend::Reference => {
                for x in xs {
                    *x = round_nearest(*x, self.fmt);
                }
            }
        }
    }

    /// Format to fuse into producing kernels, `None` for fp32 passthrough.
    #[inline]
    pub(crate) fn fuse_fmt(&self) -> Option<Format> {
        if self.fmt.is_fp32() {
            None
        } else {
            Some(self.fmt)
        }
    }
}

/// Index of a node in the tape.  Invalidated by [`Tape::reset`].
///
/// The second field is the tape *epoch* the Var was minted in (bumped by
/// every `reset`); debug builds assert it on every use, so a stale Var —
/// one held across a `reset` — panics at the offending call site instead
/// of silently reading the next step's graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub usize, pub u32);

enum Op {
    /// Leaf (input or parameter).
    Leaf,
    MatMul(Var, Var),
    Add(Var, Var),
    Sub(Var, Var),
    Mul(Var, Var),
    Relu(Var),
    Sigmoid(Var),
    Tanh(Var),
    /// Row gather: out[r] = table[idx[r]].
    Embed { table: Var, idx: Vec<usize> },
    /// Mean over all elements -> scalar.
    MeanAll(Var),
    /// 0.5 * mean(d^2) fused loss over a difference node -> scalar.
    MseLoss(Var),
    /// BCE-with-logits fused loss vs labels tensor -> scalar.
    BceLoss { logits: Var, labels: Tensor },
    /// Broadcast a (1, n) bias over rows of a (m, n) input.
    AddRow(Var, Var),
    /// Fused `x @ w + b` panel with optional trailing relu — the validated
    /// `matmul + add_row (+ relu)` rewrite (see [`Tape::affine`] for the
    /// bit-identity argument).
    Affine { x: Var, w: Var, b: Var, relu: bool },
    /// Column-wise concatenation of same-row-count tensors (memory op).
    ConcatCols(Vec<Var>),
    /// Multiply by a compile-time-constant scalar (residual-branch scaling).
    Scale(Var, f32),
    /// `a @ bᵀ` without materializing the transpose (tied softmax head).
    MatMulNT(Var, Var),
    /// Row-wise layer normalisation, non-affine: `(x - μ) / √(σ² + eps)`.
    LayerNorm { x: Var, eps: f32 },
    /// Fused single-head causal self-attention over `seqs` packed
    /// sequences of `rows / seqs` tokens each; `probs` retains the
    /// (unrounded, internal-fp32) post-softmax weights for backward.
    CausalAttn { q: Var, k: Var, v: Var, seqs: usize, probs: Tensor },
    /// Fused softmax + cross-entropy against per-row target classes
    /// (mean over rows, natural log) -> scalar.
    SoftmaxXent { logits: Var, targets: Vec<usize> },
}

// -- free pool --------------------------------------------------------------

/// The tape's recycled-buffer pool, with leak accounting.
///
/// Every buffer handed out by [`FreeList::take`] (whether recycled or
/// freshly allocated on a pool miss) increments `outstanding`; every buffer
/// returned by [`FreeList::put`] decrements it.  Externally allocated
/// buffers entering tape storage (owned-tensor `input`/`param`, the
/// `Reference` backend's fresh backward temporaries) are announced through
/// [`FreeList::note_external`] so their eventual return balances.  After
/// [`Tape::reset`] has drained every node, gradient and op-held tensor,
/// `outstanding` must be exactly zero — a positive count means a pooled
/// buffer was dropped instead of returned (a steady-state allocation leak),
/// a `put` past zero means a buffer was double-pooled.  Debug builds assert
/// the invariant; [`Tape::pool_stats`] exposes the counters to the linter.
#[derive(Default)]
struct FreeList {
    bufs: Vec<Vec<f32>>,
    /// Buffers currently held by tape storage or in-flight computation.
    outstanding: u64,
}

impl FreeList {
    /// Hand out a cleared buffer (recycled when available).
    fn take(&mut self) -> Vec<f32> {
        self.outstanding += 1;
        let mut b = self.bufs.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Return a buffer previously handed out (or announced external).
    fn put(&mut self, b: Vec<f32>) {
        self.outstanding = self
            .outstanding
            .checked_sub(1)
            .expect("free-pool accounting: buffer returned that was never taken");
        self.bufs.push(b);
    }

    /// Announce a buffer that entered tape storage without coming from
    /// `take` — it will be `put` back by `reset` like any pooled buffer.
    fn note_external(&mut self) {
        self.outstanding += 1;
    }
}

// -- free-pool helpers (free functions so backward can hold disjoint field
//    borrows of the tape while allocating) ----------------------------------

/// Take an empty tensor whose storage comes from the pool (no zero fill —
/// callers extend/resize as they produce elements).
fn pool_tensor(free: &mut FreeList) -> Tensor {
    Tensor { rows: 0, cols: 0, data: free.take(), store: Storage::F32 }
}

fn pool_zeros(free: &mut FreeList, rows: usize, cols: usize) -> Tensor {
    let mut t = pool_tensor(free);
    t.rows = rows;
    t.cols = cols;
    t.data.resize(rows * cols, 0.0);
    t
}

fn pool_copy(free: &mut FreeList, src: &Tensor) -> Tensor {
    let mut t = pool_tensor(free);
    t.rows = src.rows;
    t.cols = src.cols;
    // the tape computes in f32: a native-16-bit source (a model-owned
    // parameter under 16-bit storage) widens on entry, bit-exactly
    match &src.store {
        Storage::F32 => t.data.extend_from_slice(&src.data),
        Storage::Bf16(h) => t.data.extend(h.iter().map(|&b| bf16_bits_to_f32(b))),
    }
    t
}

fn pool_map(free: &mut FreeList, src: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    let mut t = pool_tensor(free);
    t.rows = src.rows;
    t.cols = src.cols;
    t.data.extend(src.data.iter().map(|&x| f(x)));
    t
}

fn pool_zip(
    free: &mut FreeList,
    a: &Tensor,
    b: &Tensor,
    f: impl Fn(f32, f32) -> f32,
) -> Tensor {
    debug_assert_eq!(a.data.len(), b.data.len());
    let mut t = pool_tensor(free);
    t.rows = a.rows;
    t.cols = a.cols;
    t.data.extend(a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)));
    t
}

/// Partition `data` (a `rows × cols` buffer) into per-worker bands of whole
/// rows and run `f(row0, band)` on each — the shared fan-out shape of every
/// row-local kernel (layernorm, per-row losses).  The computation inside a
/// row never depends on which band it landed in, so results are
/// bit-identical at every thread count, including the sequential call.
fn run_row_bands(
    pool: &Pool,
    data: &mut [f32],
    rows: usize,
    cols: usize,
    f: impl Fn(usize, &mut [f32]) + Sync,
) {
    debug_assert_eq!(data.len(), rows * cols);
    let t = pool.threads().min(rows.max(1));
    if t <= 1 || cols == 0 {
        f(0, data);
        return;
    }
    let per = rows.div_ceil(t);
    let mut parts: Vec<(usize, &mut [f32])> = Vec::with_capacity(t);
    let mut rest = data;
    let mut row0 = 0usize;
    while row0 < rows {
        let take = per.min(rows - row0);
        let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * cols);
        parts.push((row0, band));
        rest = tail;
        row0 += take;
    }
    pool.run_parts(parts, |(row0, band)| f(*row0, &mut **band));
}

/// Row-wise layer normalisation of the `rows × cols` band `src` into `dst`:
/// `y = (x - μ) / √(σ² + eps)`, with μ/σ² accumulated in f64 and the output
/// rounded per the policy.  Entirely row-local.
pub(crate) fn layernorm_rows(
    src: &[f32],
    cols: usize,
    eps: f32,
    dst: &mut [f32],
    policy: QPolicy,
) {
    debug_assert_eq!(src.len(), dst.len());
    if cols == 0 {
        return;
    }
    for (srow, drow) in src.chunks_exact(cols).zip(dst.chunks_exact_mut(cols)) {
        let n = cols as f64;
        let mut mu = 0f64;
        for &x in srow {
            mu += x as f64;
        }
        mu /= n;
        let mut var = 0f64;
        for &x in srow {
            let d = x as f64 - mu;
            var += d * d;
        }
        var /= n;
        let inv = 1.0 / (var + eps as f64).sqrt();
        let (mu, inv) = (mu as f32, inv as f32);
        for (d, &x) in drow.iter_mut().zip(srow) {
            *d = (x - mu) * inv;
        }
        policy.q_slice(drow);
    }
}

/// Forward causal attention for a band of sequences starting at `seq0`.
///
/// `q`/`k`/`v` are the full packed `(seqs·T, d)` buffers; `out` and `p` are
/// this band's slices of the output and probability buffers (both
/// zero-initialised).  For each row i of each sequence: scaled scores
/// against keys j ≤ i, max-subtracted softmax (exp-sum in f64), then the
/// probability-weighted value sum; the output row is rounded per the
/// policy, the probabilities stay internal fp32 (retained for backward).
/// Everything is sequence-local, so any sequence partition — including the
/// pooled one — is bit-identical.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attn_forward_seqs(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t_len: usize,
    d: usize,
    alpha: f32,
    seq0: usize,
    out: &mut [f32],
    p: &mut [f32],
    policy: QPolicy,
) {
    if t_len == 0 || d == 0 {
        return;
    }
    let nseq = out.len() / (t_len * d);
    debug_assert_eq!(p.len(), nseq * t_len * t_len);
    for si in 0..nseq {
        let s = seq0 + si;
        let obase = si * t_len * d;
        let pbase = si * t_len * t_len;
        for i in 0..t_len {
            let qrow = &q[(s * t_len + i) * d..(s * t_len + i + 1) * d];
            let prow = &mut p[pbase + i * t_len..pbase + (i + 1) * t_len];
            // scaled masked scores into the prob row (reused as scratch)
            let mut m = f32::NEG_INFINITY;
            for j in 0..=i {
                let krow = &k[(s * t_len + j) * d..(s * t_len + j + 1) * d];
                let mut sc = 0f32;
                for (&a, &b) in qrow.iter().zip(krow) {
                    sc += a * b;
                }
                sc *= alpha;
                prow[j] = sc;
                if sc > m {
                    m = sc;
                }
            }
            let mut denom = 0f64;
            for pj in prow[..=i].iter_mut() {
                let e = ((*pj - m) as f64).exp();
                *pj = e as f32;
                denom += e;
            }
            let inv = (1.0 / denom) as f32;
            for pj in prow[..=i].iter_mut() {
                *pj *= inv;
            }
            // columns j > i stay zero (the causal mask)
            let orow = &mut out[obase + i * d..obase + (i + 1) * d];
            for j in 0..=i {
                let pij = prow[j];
                if pij == 0.0 {
                    continue;
                }
                let vrow = &v[(s * t_len + j) * d..(s * t_len + j + 1) * d];
                for (o, &b) in orow.iter_mut().zip(vrow) {
                    *o += pij * b;
                }
            }
            policy.q_slice(orow);
        }
    }
}

/// Per-row stable cross-entropy: `lse(z) - z[target]`, exp-sum in f64.
///
/// Degenerate rows (a ±inf max, i.e. a diverged run) report NaN — the loss
/// has no finite value and must *look* diverged downstream; masking it
/// with 0.0 would make a blown-up `standard16` run score as perfect.
pub(crate) fn xent_row(row: &[f32], target: usize) -> f32 {
    let mut m = f32::NEG_INFINITY;
    for &z in row {
        if z > m {
            m = z;
        }
    }
    if !m.is_finite() {
        return f32::NAN;
    }
    let mut sum = 0f64;
    for &z in row {
        sum += ((z - m) as f64).exp();
    }
    (m as f64 + sum.ln()) as f32 - row[target]
}

/// Accumulate cotangent `g` into node `v`'s gradient (rounding at the
/// operator boundary, fp32 fan-in accumulation rounded once — same rule as
/// qops._qcast_bwd).  No-grad leaves (tape inputs) skip all of it and
/// recycle the buffer.
fn accum(
    policy: QPolicy,
    requires_grad: &[bool],
    grads: &mut [Option<Tensor>],
    free: &mut FreeList,
    v: Var,
    mut g: Tensor,
) {
    if !requires_grad[v.0] {
        free.put(g.data);
        return;
    }
    policy.q_slice(&mut g.data);
    match &mut grads[v.0] {
        Some(existing) => {
            assert_eq!(existing.data.len(), g.data.len(), "cotangent shape mismatch");
            for (e, &x) in existing.data.iter_mut().zip(&g.data) {
                *e += x;
            }
            policy.q_slice(&mut existing.data);
            free.put(g.data);
        }
        None => grads[v.0] = Some(g),
    }
}

/// The autograd tape: build forward ops, then `backward` from a scalar.
///
/// Node storage is split into parallel vectors (ops / values / grads) so the
/// backward pass can read operand values while writing gradients without
/// cloning whole tensors per op.
pub struct Tape {
    ops: Vec<Op>,
    values: Vec<Tensor>,
    grads: Vec<Option<Tensor>>,
    requires_grad: Vec<bool>,
    pub policy: QPolicy,
    /// Retired buffers recycled across ops and (via [`Tape::reset`]) steps,
    /// with outstanding-buffer accounting (see [`FreeList`]).
    free: FreeList,
    /// Bumped by every [`Tape::reset`]; Vars carry the epoch they were
    /// minted in, and debug builds reject cross-epoch use.
    epoch: u32,
    /// Worker pool for the `Fast` backend's parallel kernels (matmul row
    /// panels, large elementwise ops).  Single-threaded by default; shared
    /// with the owning trainer via [`Tape::with_pool`].  Results are
    /// bit-identical at every pool size.
    pool: Arc<Pool>,
}

impl Tape {
    pub fn new(policy: QPolicy) -> Self {
        Self::with_pool(policy, Pool::single())
    }

    /// Build a tape whose `Fast`-backend kernels fan out over `pool`.
    pub fn with_pool(policy: QPolicy, pool: Arc<Pool>) -> Self {
        Self {
            ops: Vec::new(),
            values: Vec::new(),
            grads: Vec::new(),
            requires_grad: Vec::new(),
            policy,
            free: FreeList::default(),
            epoch: 0,
            pool,
        }
    }

    /// Clear the recorded graph while retaining all tensor storage for
    /// reuse.  Invalidates every outstanding [`Var`]; the next step's graph
    /// must be rebuilt from scratch, but its allocations are served from
    /// the pool instead of the allocator.
    pub fn reset(&mut self) {
        // recover op-held tensor storage too (attention probabilities, BCE
        // labels), so fused ops stay allocation-free in steady state
        for op in self.ops.drain(..) {
            match op {
                Op::BceLoss { labels, .. } => self.free.put(labels.data),
                Op::CausalAttn { probs, .. } => self.free.put(probs.data),
                _ => {}
            }
        }
        for t in self.values.drain(..) {
            self.free.put(t.data);
        }
        for g in self.grads.drain(..) {
            if let Some(t) = g {
                self.free.put(t.data);
            }
        }
        self.requires_grad.clear();
        self.epoch = self.epoch.wrapping_add(1);
        // Every buffer ever handed out (or adopted) must now be back in the
        // pool: a remainder is a recycling leak in some op's forward or
        // backward path.
        debug_assert_eq!(
            self.free.outstanding, 0,
            "free-pool accounting: {} buffer(s) taken from the pool were \
             dropped instead of returned before reset",
            self.free.outstanding
        );
    }

    /// Free-pool accounting counters: `(buffers parked in the pool,
    /// buffers outstanding in tape storage / in flight)`.  Right after a
    /// [`Tape::reset`] the second component must be zero; in steady state
    /// the first stops growing once capacities converge.
    pub fn pool_stats(&self) -> (usize, u64) {
        (self.free.bufs.len(), self.free.outstanding)
    }

    /// Number of nodes recorded since construction / the last reset.
    pub fn num_nodes(&self) -> usize {
        self.values.len()
    }

    fn push(&mut self, op: Op, value: Tensor, requires_grad: bool) -> Var {
        self.ops.push(op);
        self.values.push(value);
        self.grads.push(None);
        self.requires_grad.push(requires_grad);
        Var(self.values.len() - 1, self.epoch)
    }

    /// Debug-build staleness guard: reject a [`Var`] minted before the
    /// last [`Tape::reset`] at the call site that misuses it.
    #[inline]
    fn check(&self, v: Var) {
        debug_assert_eq!(
            v.1, self.epoch,
            "stale Var({}): minted in tape epoch {} but the tape is at epoch {} \
             (reset() invalidates all outstanding Vars)",
            v.0, v.1, self.epoch
        );
        debug_assert!(v.0 < self.values.len(), "Var({}) out of range", v.0);
    }

    fn take_buf(&mut self) -> Vec<f32> {
        self.free.take()
    }

    /// Register an input: no cotangent is accumulated into it during
    /// `backward` ([`Tape::grad`] stays `None`).  Native-16-bit tensors
    /// widen on entry — inside the tape everything is f32.
    pub fn input(&mut self, mut t: Tensor) -> Var {
        t.widen_to_f32();
        self.free.note_external();
        self.push(Op::Leaf, t, false)
    }

    /// Register a parameter (gradient collected).  The value is used as
    /// stored — callers keep parameters in-format themselves.  Native-16-bit
    /// tensors widen on entry (bit-exact: narrow storage holds grid values).
    pub fn param(&mut self, mut t: Tensor) -> Var {
        t.widen_to_f32();
        self.free.note_external();
        self.push(Op::Leaf, t, true)
    }

    /// [`Tape::input`] that copies into a pool buffer instead of taking an
    /// owned tensor (no per-step allocation in steady state).
    pub fn input_from(&mut self, t: &Tensor) -> Var {
        let c = pool_copy(&mut self.free, t);
        self.push(Op::Leaf, c, false)
    }

    /// [`Tape::param`] that copies into a pool buffer instead of taking an
    /// owned tensor (no per-step allocation in steady state).
    pub fn param_from(&mut self, t: &Tensor) -> Var {
        let c = pool_copy(&mut self.free, t);
        self.push(Op::Leaf, c, true)
    }

    pub fn value(&self, v: Var) -> &Tensor {
        self.check(v);
        &self.values[v.0]
    }

    pub fn grad(&self, v: Var) -> Option<&Tensor> {
        self.check(v);
        self.grads[v.0].as_ref()
    }

    // -- forward ops (each rounds its output once, fused with the producing
    //    loop so rounding never makes a second pass over cold memory) -------

    /// Elementwise ops compute + round per contiguous chunk; both steps are
    /// element-local, so the pooled path is bit-identical to the sequential
    /// one regardless of how chunks land on workers.
    fn unary(&mut self, a: Var, op: Op, f: impl Fn(f32) -> f32 + Sync) -> Var {
        self.check(a);
        let mut data = self.take_buf();
        let policy = self.policy;
        let (rows, cols);
        {
            let av = &self.values[a.0];
            rows = av.rows;
            cols = av.cols;
            if policy.backend.pooled()
                && self.pool.threads() > 1
                && av.data.len() >= EW_PAR_MIN
            {
                data.resize(av.data.len(), 0.0);
                let src = &av.data;
                self.pool.for_chunks_mut(&mut data, EW_PAR_MIN, |off, chunk| {
                    for (o, &x) in chunk.iter_mut().zip(&src[off..off + chunk.len()]) {
                        *o = f(x);
                    }
                    policy.q_slice(chunk);
                });
            } else {
                data.extend(av.data.iter().map(|&x| f(x)));
                policy.q_slice(&mut data);
            }
        }
        let out = Tensor { rows, cols, data, store: Storage::F32 };
        self.push(op, out, true)
    }

    fn binary(&mut self, a: Var, b: Var, op: Op, f: impl Fn(f32, f32) -> f32 + Sync) -> Var {
        self.check(a);
        self.check(b);
        let mut data = self.take_buf();
        let policy = self.policy;
        let (rows, cols);
        {
            let (av, bv) = (&self.values[a.0], &self.values[b.0]);
            assert_eq!(av.rows, bv.rows);
            assert_eq!(av.cols, bv.cols);
            rows = av.rows;
            cols = av.cols;
            if policy.backend.pooled()
                && self.pool.threads() > 1
                && av.data.len() >= EW_PAR_MIN
            {
                data.resize(av.data.len(), 0.0);
                let (sa, sb) = (&av.data, &bv.data);
                self.pool.for_chunks_mut(&mut data, EW_PAR_MIN, |off, chunk| {
                    let end = off + chunk.len();
                    for ((o, &x), &y) in
                        chunk.iter_mut().zip(&sa[off..end]).zip(&sb[off..end])
                    {
                        *o = f(x, y);
                    }
                    policy.q_slice(chunk);
                });
            } else {
                data.extend(av.data.iter().zip(&bv.data).map(|(&x, &y)| f(x, y)));
                policy.q_slice(&mut data);
            }
        }
        let out = Tensor { rows, cols, data, store: Storage::F32 };
        self.push(op, out, true)
    }

    /// Scalar node from a pooled buffer.  `Tensor::scalar` here would leak
    /// one fresh allocation into the free pool per step (every fused-loss
    /// scalar retires into the pool at `reset`), growing it without bound.
    fn push_scalar(&mut self, op: Op, v: f32) -> Var {
        let mut data = self.take_buf();
        data.push(v);
        let mut t = Tensor { rows: 1, cols: 1, data, store: Storage::F32 };
        self.policy.q_slice(&mut t.data);
        self.push(op, t, true)
    }

    pub fn matmul(&mut self, a: Var, b: Var) -> Var {
        self.check(a);
        self.check(b);
        match self.policy.backend {
            Backend::Fast | Backend::Simd => {
                let mut out = pool_tensor(&mut self.free);
                let fuse = self.policy.fuse_fmt();
                if self.policy.backend.simd() {
                    self.values[a.0].matmul_into_pooled_simd(
                        &self.values[b.0],
                        &mut out,
                        fuse,
                        &self.pool,
                    );
                } else {
                    self.values[a.0].matmul_into_pooled(
                        &self.values[b.0],
                        &mut out,
                        fuse,
                        &self.pool,
                    );
                }
                self.push(Op::MatMul(a, b), out, true)
            }
            Backend::Reference => {
                // reference kernels allocate fresh outputs (the pre-arena
                // code path); announce them so pool accounting balances
                let mut out = self.values[a.0].matmul_reference(&self.values[b.0]);
                self.free.note_external();
                self.policy.q_slice(&mut out.data);
                self.push(Op::MatMul(a, b), out, true)
            }
        }
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        self.binary(a, b, Op::Add(a, b), |x, y| x + y)
    }

    /// Broadcast-add a (1, n) bias to an (m, n) activation.
    pub fn add_row(&mut self, a: Var, bias: Var) -> Var {
        self.check(a);
        self.check(bias);
        let mut data = self.take_buf();
        {
            let (av, bv) = (&self.values[a.0], &self.values[bias.0]);
            assert_eq!(bv.rows, 1);
            assert_eq!(bv.cols, av.cols);
            data.reserve(av.data.len());
            if av.cols > 0 {
                for arow in av.data.chunks_exact(av.cols) {
                    data.extend(arow.iter().zip(&bv.data).map(|(&x, &b)| x + b));
                }
            }
        }
        let (rows, cols) = (self.values[a.0].rows, self.values[a.0].cols);
        let mut out = Tensor { rows, cols, data, store: Storage::F32 };
        self.policy.q_slice(&mut out.data);
        self.push(Op::AddRow(a, bias), out, true)
    }

    /// Fused affine panel: `x @ w + b` with an optional trailing relu —
    /// the `matmul → add_row (→ relu)` chain of [`nn::Linear`] collapsed
    /// into one node.
    ///
    /// **Bit-identity contract** (fuzzer-validated, see `qsim::verify`):
    /// the fused op reproduces the unfused chain exactly, on both backends
    /// and at every thread count.  Forward: the matmul output is rounded by
    /// the producing kernel, the bias row-add is rounded once, and the relu
    /// output is rounded once — the same three per-operator roundings the
    /// chain performs, over the same fp32 intermediates (rounding is
    /// elementwise, so the chain's chunked/pooled rounding of identical
    /// values lands on identical bits).  Backward: the relu mask is read
    /// off the *fused output* `y` — valid because the pre-relu value `a` is
    /// in-format, so `y = max(a, 0)` satisfies `y > 0 ⟺ a > 0` (NaN scores
    /// `false` on both sides: `f32::max(NaN, 0.0)` is `0.0`) — and the
    /// masked cotangent is rounded once before the bias column-sum and the
    /// two matmul cotangents, exactly where `accum` would round it between
    /// the unfused nodes (rounding is idempotent on in-format values, so
    /// the chain's extra pass-through roundings are no-ops).
    ///
    /// [`nn::Linear`]: super::nn::Linear
    pub fn affine(&mut self, x: Var, w: Var, b: Var, relu: bool) -> Var {
        self.check(x);
        self.check(w);
        self.check(b);
        let mut out = match self.policy.backend {
            Backend::Fast | Backend::Simd => {
                let mut out = pool_tensor(&mut self.free);
                let fuse = self.policy.fuse_fmt();
                if self.policy.backend.simd() {
                    self.values[x.0].matmul_into_pooled_simd(
                        &self.values[w.0],
                        &mut out,
                        fuse,
                        &self.pool,
                    );
                } else {
                    self.values[x.0].matmul_into_pooled(
                        &self.values[w.0],
                        &mut out,
                        fuse,
                        &self.pool,
                    );
                }
                out
            }
            Backend::Reference => {
                let mut out = self.values[x.0].matmul_reference(&self.values[w.0]);
                self.free.note_external();
                self.policy.q_slice(&mut out.data);
                out
            }
        };
        {
            let bv = &self.values[b.0];
            assert_eq!(bv.rows, 1);
            assert_eq!(bv.cols, out.cols);
            if out.cols > 0 {
                for orow in out.data.chunks_exact_mut(out.cols) {
                    for (o, &bx) in orow.iter_mut().zip(&bv.data) {
                        *o += bx;
                    }
                }
            }
            self.policy.q_slice(&mut out.data);
        }
        if relu {
            for o in &mut out.data {
                *o = o.max(0.0);
            }
            self.policy.q_slice(&mut out.data);
        }
        self.push(Op::Affine { x, w, b, relu }, out, true)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        self.binary(a, b, Op::Sub(a, b), |x, y| x - y)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        self.binary(a, b, Op::Mul(a, b), |x, y| x * y)
    }

    pub fn relu(&mut self, a: Var) -> Var {
        self.unary(a, Op::Relu(a), |x| x.max(0.0))
    }

    pub fn sigmoid(&mut self, a: Var) -> Var {
        self.unary(a, Op::Sigmoid(a), |x| 1.0 / (1.0 + (-x).exp()))
    }

    pub fn tanh(&mut self, a: Var) -> Var {
        self.unary(a, Op::Tanh(a), f32::tanh)
    }

    /// Embedding lookup: rows of `table` selected by `idx`.
    pub fn embed(&mut self, table: Var, idx: Vec<usize>) -> Var {
        self.check(table);
        let mut data = self.take_buf();
        let tv = &self.values[table.0];
        let cols = tv.cols;
        data.reserve(idx.len() * cols);
        for &i in &idx {
            data.extend_from_slice(&tv.data[i * cols..(i + 1) * cols]);
        }
        let out = Tensor { rows: idx.len(), cols, data, store: Storage::F32 };
        // gather is a memory op: values already in-format, no rounding
        self.push(Op::Embed { table, idx }, out, true)
    }

    /// Row gather from any tape node — the generalized form of
    /// [`Tape::embed`] (same op, same scatter-add backward): selects rows of
    /// an activation or table by index, e.g. token/position lookups or
    /// picking per-sequence rows out of a packed batch.
    pub fn gather_rows(&mut self, x: Var, idx: Vec<usize>) -> Var {
        self.embed(x, idx)
    }

    /// Multiply by a constant scalar (e.g. the GPT residual-branch scale
    /// 1/√(2·depth)).  Rounds its output like any elementwise op; the
    /// constant itself is exact.
    pub fn scale(&mut self, a: Var, c: f32) -> Var {
        self.unary(a, Op::Scale(a, c), move |x| c * x)
    }

    /// `a @ bᵀ` without materializing a transposed copy — the tied-softmax
    /// output projection (`logits = x @ embedᵀ`).  Backward accumulates
    /// into *both* operands, so tying the embedding table to the output
    /// head is a single shared parameter node.
    pub fn matmul_nt(&mut self, a: Var, b: Var) -> Var {
        self.check(a);
        self.check(b);
        let mut out = pool_tensor(&mut self.free);
        match self.policy.backend {
            // `Simd` shares the tiled NT kernel: an 8-wide NT microkernel
            // would need per-output partial sums in a different accumulation
            // order, breaking bit-identity with the reference loop
            Backend::Fast | Backend::Simd => {
                self.values[a.0].matmul_nt_into_pooled(&self.values[b.0], &mut out, &self.pool);
            }
            Backend::Reference => {
                self.values[a.0].matmul_nt_into(&self.values[b.0], &mut out);
            }
        }
        self.policy.q_slice(&mut out.data);
        self.push(Op::MatMulNT(a, b), out, true)
    }

    /// Row-wise layer normalisation (non-affine): `(x - μ) / √(σ² + eps)`
    /// per row, one output rounding.  Row-local, fanned out across the pool
    /// for large activations; bit-identical at every thread count.
    pub fn layernorm(&mut self, a: Var, eps: f32) -> Var {
        self.check(a);
        let mut data = self.take_buf();
        let policy = self.policy;
        let (rows, cols);
        {
            let av = &self.values[a.0];
            rows = av.rows;
            cols = av.cols;
            data.resize(av.data.len(), 0.0);
            let src = &av.data;
            if policy.backend.pooled()
                && self.pool.threads() > 1
                && av.data.len() >= EW_PAR_MIN
            {
                run_row_bands(&self.pool, &mut data, rows, cols, |row0, band| {
                    layernorm_rows(
                        &src[row0 * cols..row0 * cols + band.len()],
                        cols,
                        eps,
                        band,
                        policy,
                    );
                });
            } else {
                layernorm_rows(src, cols, eps, &mut data, policy);
            }
        }
        let out = Tensor { rows, cols, data, store: Storage::F32 };
        self.push(Op::LayerNorm { x: a, eps }, out, true)
    }

    /// Fused single-head causal self-attention over `seqs` packed
    /// sequences.
    ///
    /// `q`/`k`/`v` are `(seqs·T, d)` row-major with sequence `s` occupying
    /// rows `s·T .. (s+1)·T`.  Scores are scaled by 1/√d, masked to j ≤ i,
    /// softmax-normalised (internal fp32, max-subtracted) and applied to
    /// `v`; only the output is rounded — one rounding per operator, like
    /// the other fused ops.  The probability matrix is retained in the op
    /// for backward (storage drawn from the tape's buffer pool and
    /// recovered by [`Tape::reset`]).  Sequence-local, so the pooled
    /// fan-out is bit-identical at every thread count.
    pub fn causal_attention(&mut self, q: Var, k: Var, v: Var, seqs: usize) -> Var {
        self.check(q);
        self.check(k);
        self.check(v);
        let (rows, d) = {
            let (qv, kv, vv) = (&self.values[q.0], &self.values[k.0], &self.values[v.0]);
            assert_eq!(qv.rows, kv.rows, "attention q/k row mismatch");
            assert_eq!(qv.rows, vv.rows, "attention q/v row mismatch");
            assert_eq!(qv.cols, kv.cols, "attention q/k width mismatch");
            assert_eq!(qv.cols, vv.cols, "attention q/v width mismatch");
            (qv.rows, qv.cols)
        };
        assert!(seqs > 0 && rows % seqs == 0, "rows must pack whole sequences");
        let t_len = rows / seqs;
        let alpha = 1.0 / (d.max(1) as f32).sqrt();
        let policy = self.policy;
        let mut data = self.take_buf();
        data.resize(rows * d, 0.0);
        // prob storage comes from (and returns to, via reset) the pool —
        // take_buf clears, so the resize zero-fills every element
        let mut probs = Tensor { rows, cols: t_len, data: self.take_buf(), store: Storage::F32 };
        probs.data.resize(rows * t_len, 0.0);
        {
            let (qd, kd, vd) =
                (&self.values[q.0].data, &self.values[k.0].data, &self.values[v.0].data);
            let engage = policy.backend.pooled()
                && self.pool.threads() > 1
                && seqs >= 2
                && seqs * t_len * t_len * d >= ATTN_PAR_MIN;
            if engage {
                // matching per-sequence bands of the output and prob buffers
                struct Band<'a> {
                    seq0: usize,
                    out: &'a mut [f32],
                    p: &'a mut [f32],
                }
                let t = self.pool.threads().min(seqs);
                let per = seqs.div_ceil(t);
                let mut parts: Vec<Band> = Vec::with_capacity(t);
                let mut orest = data.as_mut_slice();
                let mut prest = probs.data.as_mut_slice();
                let mut s0 = 0usize;
                while s0 < seqs {
                    let take = per.min(seqs - s0);
                    let (ob, otail) =
                        std::mem::take(&mut orest).split_at_mut(take * t_len * d);
                    let (pb, ptail) =
                        std::mem::take(&mut prest).split_at_mut(take * t_len * t_len);
                    parts.push(Band { seq0: s0, out: ob, p: pb });
                    orest = otail;
                    prest = ptail;
                    s0 += take;
                }
                self.pool.run_parts(parts, |b| {
                    attn_forward_seqs(
                        qd,
                        kd,
                        vd,
                        t_len,
                        d,
                        alpha,
                        b.seq0,
                        &mut *b.out,
                        &mut *b.p,
                        policy,
                    );
                });
            } else {
                attn_forward_seqs(
                    qd, kd, vd, t_len, d, alpha, 0, &mut data, &mut probs.data, policy,
                );
            }
        }
        let out = Tensor { rows, cols: d, data, store: Storage::F32 };
        self.push(Op::CausalAttn { q, k, v, seqs, probs }, out, true)
    }

    /// Fused softmax + cross-entropy against per-row target class indices
    /// (mean over rows, natural log — perplexity is `exp(loss)`), stabilised
    /// by max-subtraction with the exp-sum in f64.  Per-row losses are
    /// row-local (pooled for large logit blocks); the cross-row mean is one
    /// sequential f64 reduction in row order, so the scalar output is
    /// bit-identical at every thread count.
    pub fn softmax_xent(&mut self, logits: Var, targets: Vec<usize>) -> Var {
        self.check(logits);
        let mut rowloss = self.take_buf();
        let mean = {
            let lv = &self.values[logits.0];
            assert_eq!(lv.rows, targets.len(), "one target per row");
            assert!(lv.cols > 0, "softmax_xent over empty rows");
            rowloss.resize(lv.rows, 0.0);
            let cols = lv.cols;
            let src = &lv.data;
            let tg = &targets;
            if self.policy.backend.pooled()
                && self.pool.threads() > 1
                && lv.data.len() >= EW_PAR_MIN
            {
                // one slot per row: slot r of `rowloss` is row r's loss
                run_row_bands(&self.pool, &mut rowloss, lv.rows, 1, |row0, band| {
                    for (ri, slot) in band.iter_mut().enumerate() {
                        let r = row0 + ri;
                        *slot = xent_row(&src[r * cols..(r + 1) * cols], tg[r]);
                    }
                });
            } else {
                for (r, slot) in rowloss.iter_mut().enumerate() {
                    *slot = xent_row(&src[r * cols..(r + 1) * cols], tg[r]);
                }
            }
            let mut acc = 0f64;
            for &l in rowloss.iter() {
                acc += l as f64;
            }
            (acc / lv.rows.max(1) as f64) as f32
        };
        self.free.put(std::mem::take(&mut rowloss));
        self.push_scalar(Op::SoftmaxXent { logits, targets }, mean)
    }

    /// Column-wise concat (a memory op: values pass through unrounded).
    pub fn concat_cols(&mut self, parts: Vec<Var>) -> Var {
        assert!(!parts.is_empty(), "concat_cols: need at least one part");
        for &p in &parts {
            self.check(p);
        }
        let mut data = self.take_buf();
        let rows = self.values[parts[0].0].rows;
        let total: usize = parts.iter().map(|v| self.values[v.0].cols).sum();
        data.resize(rows * total, 0.0);
        let mut off = 0;
        for &p in &parts {
            let pv = &self.values[p.0];
            assert_eq!(pv.rows, rows, "concat row mismatch");
            for r in 0..rows {
                data[r * total + off..r * total + off + pv.cols]
                    .copy_from_slice(&pv.data[r * pv.cols..(r + 1) * pv.cols]);
            }
            off += pv.cols;
        }
        let out = Tensor { rows, cols: total, data, store: Storage::F32 };
        self.push(Op::ConcatCols(parts), out, true)
    }

    pub fn mean_all(&mut self, a: Var) -> Var {
        self.check(a);
        let v = &self.values[a.0];
        let m = v.data.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        self.push_scalar(Op::MeanAll(a), m as f32)
    }

    /// Fused 0.5·mean((a-b)²) — one output rounding, like qops.mse_loss.
    pub fn mse_loss(&mut self, a: Var, b: Var) -> Var {
        let d = self.sub(a, b);
        self.mse_of(d)
    }

    /// The standalone form of [`Tape::mse_loss`]'s head: 0.5·mean(d²) over
    /// an already-recorded difference node.  Exported programs carry the
    /// fused head as `MseLoss { diff }`, so replaying them needs this
    /// entry point; it records exactly what `mse_loss` records.
    pub fn mse_of(&mut self, d: Var) -> Var {
        self.check(d);
        let dv = &self.values[d.0];
        let m =
            dv.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / dv.len() as f64;
        self.push_scalar(Op::MseLoss(d), 0.5 * m as f32)
    }

    /// Fused BCE-with-logits against constant labels (owned tensor — its
    /// storage is adopted into the free pool at `reset`).
    pub fn bce_loss(&mut self, logits: Var, labels: Tensor) -> Var {
        self.check(logits);
        self.free.note_external();
        self.bce_loss_inner(logits, labels)
    }

    /// [`Tape::bce_loss`] that copies the labels into a pool buffer instead
    /// of taking an owned tensor — callers passing a fresh clone every step
    /// would otherwise grow the free pool by one orphaned buffer per step.
    pub fn bce_loss_from(&mut self, logits: Var, labels: &Tensor) -> Var {
        self.check(logits);
        let c = pool_copy(&mut self.free, labels);
        self.bce_loss_inner(logits, c)
    }

    fn bce_loss_inner(&mut self, logits: Var, labels: Tensor) -> Var {
        let lv = &self.values[logits.0];
        assert_eq!(lv.len(), labels.len());
        let mut acc = 0f64;
        for (&z, &y) in lv.data.iter().zip(&labels.data) {
            // -(y log σ(z) + (1-y) log σ(-z)) = max(z,0) - zy + log(1+e^-|z|)
            let l = z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
            acc += l as f64;
        }
        let mean = (acc / lv.len() as f64) as f32;
        self.push_scalar(Op::BceLoss { logits, labels }, mean)
    }

    // -- static analysis ----------------------------------------------------

    /// Export the recorded graph as a [`verify`](super::verify) IR program
    /// for structural linting and fusion-opportunity scanning.  Purely
    /// observational — the tape is not modified.
    pub fn export_program(&self) -> super::verify::Program {
        use super::verify::{NodeIr, OpIr};
        let nodes = self
            .ops
            .iter()
            .zip(&self.values)
            .zip(&self.requires_grad)
            .map(|((op, val), &rg)| {
                let op = match op {
                    Op::Leaf => OpIr::Leaf,
                    Op::MatMul(a, b) => OpIr::MatMul(a.0, b.0),
                    Op::Add(a, b) => OpIr::Add(a.0, b.0),
                    Op::Sub(a, b) => OpIr::Sub(a.0, b.0),
                    Op::Mul(a, b) => OpIr::Mul(a.0, b.0),
                    Op::Relu(a) => OpIr::Relu(a.0),
                    Op::Sigmoid(a) => OpIr::Sigmoid(a.0),
                    Op::Tanh(a) => OpIr::Tanh(a.0),
                    Op::Embed { table, idx } => {
                        OpIr::GatherRows { x: table.0, idx: idx.clone() }
                    }
                    Op::MeanAll(a) => OpIr::MeanAll(a.0),
                    Op::MseLoss(d) => OpIr::MseLoss { diff: d.0 },
                    Op::BceLoss { logits, labels } => {
                        OpIr::BceLoss { logits: logits.0, labels: labels.data.clone() }
                    }
                    Op::AddRow(a, b) => OpIr::AddRow(a.0, b.0),
                    Op::Affine { x, w, b, relu } => {
                        OpIr::Affine { x: x.0, w: w.0, b: b.0, relu: *relu }
                    }
                    Op::ConcatCols(parts) => {
                        OpIr::ConcatCols(parts.iter().map(|p| p.0).collect())
                    }
                    Op::Scale(a, c) => OpIr::Scale(a.0, *c),
                    Op::MatMulNT(a, b) => OpIr::MatMulNT(a.0, b.0),
                    Op::LayerNorm { x, eps } => OpIr::LayerNorm { x: x.0, eps: *eps },
                    Op::CausalAttn { q, k, v, seqs, .. } => {
                        OpIr::CausalAttn { q: q.0, k: k.0, v: v.0, seqs: *seqs }
                    }
                    Op::SoftmaxXent { logits, targets } => {
                        OpIr::SoftmaxXent { logits: logits.0, targets: targets.clone() }
                    }
                };
                NodeIr { op, rows: val.rows, cols: val.cols, requires_grad: rg }
            })
            .collect();
        super::verify::Program { nodes }
    }

    /// Snapshot every node's value tensor, in node order — the companion to
    /// [`Tape::export_program`] for plan compilation (`qsim::infer`): leaf
    /// values seed the inference arena (weights widened exactly once, here),
    /// interior values pre-size its activation buffers.  Tape values are
    /// always f32 (native-16 tensors widen on `input`/`param` entry), so the
    /// snapshot is a plain clone.
    pub fn export_values(&self) -> Vec<Tensor> {
        self.values.clone()
    }

    /// Debug-build structural gate run by [`Tape::backward`]: export the
    /// graph and assert the linter finds no errors (shape inconsistencies,
    /// malformed operand references, a non-scalar root).
    #[cfg(debug_assertions)]
    fn debug_validate(&self, root: Var) {
        let prog = self.export_program();
        let errs = super::verify::lint(&prog, root.0).errors();
        debug_assert!(
            errs.is_empty(),
            "tape graph failed its structural lint before backward:\n{}",
            errs.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    // -- backward -----------------------------------------------------------

    /// Run reverse-mode from scalar `root` (seed gradient 1.0).
    ///
    /// Operand values are read through split field borrows — no per-op
    /// tensor cloning — and every intermediate cotangent draws its storage
    /// from (and returns it to) the tape's buffer pool.
    pub fn backward(&mut self, root: Var) {
        self.check(root);
        assert_eq!(self.values[root.0].len(), 1, "backward from non-scalar");
        #[cfg(debug_assertions)]
        self.debug_validate(root);
        // seed gradient from the pool — a fresh Tensor::scalar here retires
        // into the free pool every reset, leaking one allocation per step
        let mut seed = pool_tensor(&mut self.free);
        seed.rows = 1;
        seed.cols = 1;
        seed.data.push(1.0);
        self.grads[root.0] = Some(seed);
        let Tape { ops, values, grads, requires_grad, policy, free, pool, .. } = self;
        let policy = *policy;
        let pool: &Pool = pool;
        let rg: &[bool] = requires_grad;
        // pooled cotangent matmul with the backend's microkernel (no fused
        // rounding in backward: `accum` rounds at the operator boundary)
        let mm = |x: &Tensor, y: &Tensor, out: &mut Tensor| {
            if policy.backend.simd() {
                x.matmul_into_pooled_simd(y, out, None, pool);
            } else {
                x.matmul_into_pooled(y, out, None, pool);
            }
        };
        for i in (0..=root.0).rev() {
            let Some(g) = grads[i].take() else { continue };
            match &ops[i] {
                Op::Leaf => {}
                Op::MatMul(a, b) => {
                    let (a, b) = (*a, *b);
                    match policy.backend {
                        Backend::Fast | Backend::Simd => {
                            // da = g·bᵀ, db = aᵀ·g, transposes in pooled
                            // scratch; a no-grad operand (a tape input) skips
                            // its cotangent matmul entirely
                            if rg[a.0] {
                                let mut bt = pool_tensor(free);
                                values[b.0].transpose_into(&mut bt);
                                let mut da = pool_tensor(free);
                                mm(&g, &bt, &mut da);
                                free.put(bt.data);
                                accum(policy, rg, grads, free, a, da);
                            }
                            if rg[b.0] {
                                let mut at = pool_tensor(free);
                                values[a.0].transpose_into(&mut at);
                                let mut db = pool_tensor(free);
                                mm(&at, &g, &mut db);
                                free.put(at.data);
                                accum(policy, rg, grads, free, b, db);
                            }
                        }
                        Backend::Reference => {
                            let da = g.matmul_reference(&values[b.0].transpose());
                            let db = values[a.0].transpose().matmul_reference(&g);
                            free.note_external();
                            free.note_external();
                            accum(policy, rg, grads, free, a, da);
                            accum(policy, rg, grads, free, b, db);
                        }
                    }
                }
                Op::Add(a, b) => {
                    let (a, b) = (*a, *b);
                    let ga = pool_copy(free, &g);
                    let gb = pool_copy(free, &g);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, b, gb);
                }
                Op::AddRow(a, bias) => {
                    let (a, bias) = (*a, *bias);
                    let mut db = pool_zeros(free, 1, g.cols);
                    if g.cols > 0 {
                        for grow in g.data.chunks_exact(g.cols) {
                            for (d, &x) in db.data.iter_mut().zip(grow) {
                                *d += x;
                            }
                        }
                    }
                    let ga = pool_copy(free, &g);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, bias, db);
                }
                Op::Affine { x, w, b, relu } => {
                    // the unfused chain's backward verbatim: relu mask (read
                    // off the fused output — valid for in-format pre-relu
                    // values, see the forward's doc comment), one boundary
                    // rounding, then the add_row column-sum and the two
                    // matmul cotangents.  Contribution order (db, dx, dw)
                    // matches the unfused node order so fan-in rounding
                    // sequences agree even when operands alias.
                    let (x, w, b, relu) = (*x, *w, *b, *relu);
                    let mut g1 = if relu {
                        pool_zip(free, &g, &values[i], |gg, y| {
                            if y > 0.0 {
                                gg
                            } else {
                                0.0
                            }
                        })
                    } else {
                        pool_copy(free, &g)
                    };
                    policy.q_slice(&mut g1.data);
                    let mut db = pool_zeros(free, 1, g1.cols);
                    if g1.cols > 0 {
                        for grow in g1.data.chunks_exact(g1.cols) {
                            for (d, &gx) in db.data.iter_mut().zip(grow) {
                                *d += gx;
                            }
                        }
                    }
                    accum(policy, rg, grads, free, b, db);
                    match policy.backend {
                        Backend::Fast | Backend::Simd => {
                            if rg[x.0] {
                                let mut wt = pool_tensor(free);
                                values[w.0].transpose_into(&mut wt);
                                let mut dx = pool_tensor(free);
                                mm(&g1, &wt, &mut dx);
                                free.put(wt.data);
                                accum(policy, rg, grads, free, x, dx);
                            }
                            if rg[w.0] {
                                let mut xt = pool_tensor(free);
                                values[x.0].transpose_into(&mut xt);
                                let mut dw = pool_tensor(free);
                                mm(&xt, &g1, &mut dw);
                                free.put(xt.data);
                                accum(policy, rg, grads, free, w, dw);
                            }
                        }
                        Backend::Reference => {
                            let dx = g1.matmul_reference(&values[w.0].transpose());
                            let dw = values[x.0].transpose().matmul_reference(&g1);
                            free.note_external();
                            free.note_external();
                            accum(policy, rg, grads, free, x, dx);
                            accum(policy, rg, grads, free, w, dw);
                        }
                    }
                    free.put(g1.data);
                }
                Op::Sub(a, b) => {
                    let (a, b) = (*a, *b);
                    let ga = pool_copy(free, &g);
                    let gb = pool_map(free, &g, |x| -x);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, b, gb);
                }
                Op::Mul(a, b) => {
                    let (a, b) = (*a, *b);
                    let ga = pool_zip(free, &g, &values[b.0], |gg, y| gg * y);
                    let gb = pool_zip(free, &g, &values[a.0], |gg, x| gg * x);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, b, gb);
                }
                Op::Relu(a) => {
                    let a = *a;
                    let ga = pool_zip(free, &g, &values[a.0], |gg, x| {
                        if x > 0.0 {
                            gg
                        } else {
                            0.0
                        }
                    });
                    accum(policy, rg, grads, free, a, ga);
                }
                Op::Sigmoid(a) => {
                    let a = *a;
                    let ga = pool_zip(free, &g, &values[i], |gg, y| gg * y * (1.0 - y));
                    accum(policy, rg, grads, free, a, ga);
                }
                Op::Tanh(a) => {
                    let a = *a;
                    let ga = pool_zip(free, &g, &values[i], |gg, y| gg * (1.0 - y * y));
                    accum(policy, rg, grads, free, a, ga);
                }
                Op::Embed { table, idx } => {
                    let table = *table;
                    let (rows, cols) = (values[table.0].rows, values[table.0].cols);
                    let mut dt = pool_zeros(free, rows, cols);
                    for (r, &row_i) in idx.iter().enumerate() {
                        let dst = &mut dt.data[row_i * cols..(row_i + 1) * cols];
                        let src = &g.data[r * cols..(r + 1) * cols];
                        for (d, &x) in dst.iter_mut().zip(src) {
                            *d += x;
                        }
                    }
                    accum(policy, rg, grads, free, table, dt);
                }
                Op::MeanAll(a) => {
                    let a = *a;
                    let av = &values[a.0];
                    let seed = g.item() / av.len() as f32;
                    let mut da = pool_tensor(free);
                    da.rows = av.rows;
                    da.cols = av.cols;
                    da.data.resize(av.len(), seed);
                    accum(policy, rg, grads, free, a, da);
                }
                Op::MseLoss(d) => {
                    let d = *d;
                    let n = values[d.0].len() as f32;
                    let seed = g.item();
                    let da = pool_map(free, &values[d.0], |x| seed * x / n);
                    accum(policy, rg, grads, free, d, da);
                }
                Op::ConcatCols(parts) => {
                    let mut off = 0;
                    for &p in parts {
                        let (pr, pc) = (values[p.0].rows, values[p.0].cols);
                        let mut dp = pool_tensor(free);
                        dp.rows = pr;
                        dp.cols = pc;
                        dp.data.reserve(pr * pc);
                        for r in 0..pr {
                            dp.data.extend_from_slice(
                                &g.data[r * g.cols + off..r * g.cols + off + pc],
                            );
                        }
                        accum(policy, rg, grads, free, p, dp);
                        off += pc;
                    }
                }
                Op::BceLoss { logits, labels } => {
                    let logits = *logits;
                    let lv = &values[logits.0];
                    let n = lv.len() as f32;
                    let seed = g.item();
                    let dl = pool_zip(free, lv, labels, |z, y| {
                        let p = 1.0 / (1.0 + (-z).exp());
                        seed * (p - y) / n
                    });
                    accum(policy, rg, grads, free, logits, dl);
                }
                Op::Scale(a, c) => {
                    let (a, c) = (*a, *c);
                    let ga = pool_map(free, &g, |x| x * c);
                    accum(policy, rg, grads, free, a, ga);
                }
                Op::MatMulNT(a, b) => {
                    // out = a @ bᵀ  ⇒  da = g @ b,  db = gᵀ @ a
                    let (a, b) = (*a, *b);
                    match policy.backend {
                        Backend::Fast | Backend::Simd => {
                            if rg[a.0] {
                                let mut da = pool_tensor(free);
                                mm(&g, &values[b.0], &mut da);
                                accum(policy, rg, grads, free, a, da);
                            }
                            if rg[b.0] {
                                let mut gt = pool_tensor(free);
                                g.transpose_into(&mut gt);
                                let mut db = pool_tensor(free);
                                mm(&gt, &values[a.0], &mut db);
                                free.put(gt.data);
                                accum(policy, rg, grads, free, b, db);
                            }
                        }
                        Backend::Reference => {
                            let da = g.matmul_reference(&values[b.0]);
                            let db = g.transpose().matmul_reference(&values[a.0]);
                            free.note_external();
                            free.note_external();
                            accum(policy, rg, grads, free, a, da);
                            accum(policy, rg, grads, free, b, db);
                        }
                    }
                }
                Op::LayerNorm { x, eps } => {
                    // y = x̂ / √(σ²+eps); dx = inv·(g − mean(g) − x̂·mean(g⊙x̂))
                    // with μ/σ²/x̂ recomputed from the input (the stored
                    // output is rounded — internals stay fp32, like the
                    // other fused ops).  Row-local and cheap: sequential.
                    let (x, eps) = (*x, *eps);
                    let av = &values[x.0];
                    let cols = av.cols;
                    let mut dx = pool_zeros(free, av.rows, cols);
                    if cols > 0 {
                        for ((srow, grow), drow) in av
                            .data
                            .chunks_exact(cols)
                            .zip(g.data.chunks_exact(cols))
                            .zip(dx.data.chunks_exact_mut(cols))
                        {
                            let n = cols as f64;
                            let mut mu = 0f64;
                            for &v in srow {
                                mu += v as f64;
                            }
                            mu /= n;
                            let mut var = 0f64;
                            for &v in srow {
                                let dv = v as f64 - mu;
                                var += dv * dv;
                            }
                            var /= n;
                            let inv = 1.0 / (var + eps as f64).sqrt();
                            let mut gsum = 0f64;
                            let mut gxsum = 0f64;
                            for (&gg, &v) in grow.iter().zip(srow) {
                                let xh = (v as f64 - mu) * inv;
                                gsum += gg as f64;
                                gxsum += gg as f64 * xh;
                            }
                            let gmean = gsum / n;
                            let gxmean = gxsum / n;
                            for ((dxv, &gg), &v) in drow.iter_mut().zip(grow).zip(srow) {
                                let xh = (v as f64 - mu) * inv;
                                *dxv = (inv * (gg as f64 - gmean - xh * gxmean)) as f32;
                            }
                        }
                    }
                    accum(policy, rg, grads, free, x, dx);
                }
                Op::CausalAttn { q, k, v, seqs, probs } => {
                    // dV = Pᵀ dO;  dP = dO Vᵀ;  dS = P⊙(dP − rowdot(dP,P));
                    // dQ = α dS K;  dK = α dSᵀ Q — all per sequence, using
                    // the retained (internal-fp32) probabilities.
                    let (q, k, v, seqs) = (*q, *k, *v, *seqs);
                    let rows = values[q.0].rows;
                    let d = values[q.0].cols;
                    let t_len = if seqs == 0 { 0 } else { rows / seqs };
                    let alpha = 1.0 / (d.max(1) as f32).sqrt();
                    let mut dq = pool_zeros(free, rows, d);
                    let mut dk = pool_zeros(free, rows, d);
                    let mut dv = pool_zeros(free, rows, d);
                    let mut dprow = free.take();
                    dprow.resize(t_len, 0.0);
                    {
                        let qd = &values[q.0].data;
                        let kd = &values[k.0].data;
                        let vd = &values[v.0].data;
                        let pd = &probs.data;
                        let gd = &g.data;
                        for s in 0..seqs {
                            for i in 0..t_len {
                                let ri = s * t_len + i;
                                let grow = &gd[ri * d..(ri + 1) * d];
                                let prow = &pd[ri * t_len..(ri + 1) * t_len];
                                let mut row_dot = 0f64;
                                for j in 0..=i {
                                    let rj = s * t_len + j;
                                    let pij = prow[j];
                                    let vrow = &vd[rj * d..(rj + 1) * d];
                                    let dvrow = &mut dv.data[rj * d..(rj + 1) * d];
                                    let mut dp = 0f32;
                                    for ((&gg, &bv), dvx) in
                                        grow.iter().zip(vrow).zip(dvrow.iter_mut())
                                    {
                                        dp += gg * bv;
                                        *dvx += pij * gg;
                                    }
                                    dprow[j] = dp;
                                    row_dot += (dp * pij) as f64;
                                }
                                let rd = row_dot as f32;
                                let qrow = &qd[ri * d..(ri + 1) * d];
                                for j in 0..=i {
                                    let rj = s * t_len + j;
                                    let ds = prow[j] * (dprow[j] - rd) * alpha;
                                    if ds == 0.0 {
                                        continue;
                                    }
                                    let krow = &kd[rj * d..(rj + 1) * d];
                                    let dqrow = &mut dq.data[ri * d..(ri + 1) * d];
                                    for (dqx, &kx) in dqrow.iter_mut().zip(krow) {
                                        *dqx += ds * kx;
                                    }
                                    let dkrow = &mut dk.data[rj * d..(rj + 1) * d];
                                    for (dkx, &qx) in dkrow.iter_mut().zip(qrow) {
                                        *dkx += ds * qx;
                                    }
                                }
                            }
                        }
                    }
                    free.put(dprow);
                    accum(policy, rg, grads, free, q, dq);
                    accum(policy, rg, grads, free, k, dk);
                    accum(policy, rg, grads, free, v, dv);
                }
                Op::SoftmaxXent { logits, targets } => {
                    // dz = seed · (softmax(z) − onehot(target)) / rows, with
                    // the softmax recomputed from the (fp32) logits.
                    let logits = *logits;
                    let lv = &values[logits.0];
                    let (rows, cols) = (lv.rows, lv.cols);
                    let seed = g.item() / rows.max(1) as f32;
                    let mut dz = pool_zeros(free, rows, cols);
                    for r in 0..rows {
                        let zrow = &lv.data[r * cols..(r + 1) * cols];
                        let drow = &mut dz.data[r * cols..(r + 1) * cols];
                        let mut m = f32::NEG_INFINITY;
                        for &z in zrow {
                            if z > m {
                                m = z;
                            }
                        }
                        if !m.is_finite() {
                            // degenerate (±inf) row: its loss is already
                            // NaN — no usable gradient, contribute none
                            continue;
                        }
                        let mut sum = 0f64;
                        for &z in zrow {
                            sum += ((z - m) as f64).exp();
                        }
                        let inv = 1.0 / sum;
                        for (dx, &z) in drow.iter_mut().zip(zrow) {
                            *dx = seed * ((((z - m) as f64).exp() * inv) as f32);
                        }
                        drow[targets[r]] -= seed;
                    }
                    accum(policy, rg, grads, free, logits, dz);
                }
            }
            grads[i] = Some(g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::BF16;
    use crate::util::rng::Rng;

    fn fd_check(f: impl Fn(&[f32]) -> f32, xs: &[f32], analytic: &[f32], tol: f32) {
        let h = 1e-3f32;
        for i in 0..xs.len() {
            let mut up = xs.to_vec();
            up[i] += h;
            let mut dn = xs.to_vec();
            dn[i] -= h;
            let fd = (f(&up) - f(&dn)) / (2.0 * h);
            assert!(
                (fd - analytic[i]).abs() <= tol * (1.0 + fd.abs()),
                "grad[{i}] analytic={} fd={fd}",
                analytic[i]
            );
        }
    }

    #[test]
    fn matmul_grad_matches_finite_difference() {
        let xs = vec![0.3f32, -0.7, 1.2, 0.5, -0.2, 0.9];
        let f = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let a = t.input(Tensor::from_vec(2, 3, vec![1.0, 2.0, -1.0, 0.5, 0.1, 0.3]));
            let wv = t.param(Tensor::from_vec(3, 2, w.to_vec()));
            let y = t.matmul(a, wv);
            let s = t.sigmoid(y);
            let target = t.input(Tensor::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]));
            let l = t.mse_loss(s, target);
            t.value(l).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let a = t.input(Tensor::from_vec(2, 3, vec![1.0, 2.0, -1.0, 0.5, 0.1, 0.3]));
        let wv = t.param(Tensor::from_vec(3, 2, xs.clone()));
        let y = t.matmul(a, wv);
        let s = t.sigmoid(y);
        let target = t.input(Tensor::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]));
        let l = t.mse_loss(s, target);
        t.backward(l);
        let g = t.grad(wv).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }

    #[test]
    fn bce_grad_matches_finite_difference() {
        let xs = vec![0.2f32, -0.4, 0.8];
        let labels = Tensor::vector(vec![1.0, 0.0, 1.0]);
        let f = |z: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let zv = t.param(Tensor::vector(z.to_vec()));
            let l = t.bce_loss(zv, Tensor::vector(vec![1.0, 0.0, 1.0]));
            t.value(l).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let zv = t.param(Tensor::vector(xs.clone()));
        let l = t.bce_loss(zv, labels);
        t.backward(l);
        let g = t.grad(zv).unwrap().data.clone();
        fd_check(f, &xs, &g, 1e-2);
    }

    #[test]
    fn embed_grad_scatters_rows() {
        let mut t = Tape::new(QPolicy::exact());
        let table = t.param(Tensor::from_vec(4, 2, (0..8).map(|i| i as f32).collect()));
        let e = t.embed(table, vec![1, 1, 3]);
        let m = t.mean_all(e);
        t.backward(m);
        let g = t.grad(table).unwrap();
        // 6 elements in `e`; each contributes 1/6
        assert_eq!(g.at(1, 0), 2.0 / 6.0);
        assert_eq!(g.at(3, 1), 1.0 / 6.0);
        assert_eq!(g.at(0, 0), 0.0);
    }

    #[test]
    fn quantised_forward_outputs_in_format() {
        let mut t = Tape::new(QPolicy::new(BF16));
        let a = t.input(Tensor::vector(vec![1.0001, 2.3456, -0.0001234]));
        let b = t.input(Tensor::vector(vec![1.0, 1.0, 1.0]));
        let s = t.add(a, b);
        for &x in &t.value(s).data {
            assert_eq!(x, crate::precision::round_nearest(x, BF16));
        }
    }

    #[test]
    fn relu_tanh_add_row_backward() {
        let xs = vec![0.5f32, -0.3];
        let f = |b: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let a = t.input(Tensor::from_vec(2, 2, vec![1.0, -1.0, 0.5, 2.0]));
            let bias = t.param(Tensor::vector(b.to_vec()));
            let h = t.add_row(a, bias);
            let r = t.relu(h);
            let th = t.tanh(r);
            let m = t.mean_all(th);
            t.value(m).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let a = t.input(Tensor::from_vec(2, 2, vec![1.0, -1.0, 0.5, 2.0]));
        let bias = t.param(Tensor::vector(xs.clone()));
        let h = t.add_row(a, bias);
        let r = t.relu(h);
        let th = t.tanh(r);
        let m = t.mean_all(th);
        t.backward(m);
        let g = t.grad(bias).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }

    #[test]
    fn inputs_collect_no_gradient_params_do() {
        let mut t = Tape::new(QPolicy::exact());
        let x = t.input(Tensor::vector(vec![1.0, 2.0]));
        let w = t.param(Tensor::vector(vec![0.5, -0.5]));
        let p = t.mul(x, w);
        let m = t.mean_all(p);
        t.backward(m);
        assert!(t.grad(x).is_none(), "inputs must not accumulate cotangents");
        assert!(t.grad(w).is_some());
    }

    /// Build one MLP step's graph; returns (loss value, weight grad).
    fn mlp_graph(t: &mut Tape, x: &Tensor, w: &Tensor, bias: &Tensor) -> (f32, Tensor) {
        let xv = t.input_from(x);
        let wv = t.param_from(w);
        let bv = t.param_from(bias);
        let h = t.matmul(xv, wv);
        let hb = t.add_row(h, bv);
        let r = t.relu(hb);
        let s = t.sigmoid(r);
        let m = t.mean_all(s);
        t.backward(m);
        (t.value(m).item(), t.grad(wv).unwrap().clone())
    }

    #[test]
    fn reset_reuses_buffers_and_reproduces_fresh_tape() {
        let mut rng = Rng::new(0x7A, 0);
        let x = Tensor::randn(4, 6, 1.0, &mut rng);
        let w = Tensor::randn(6, 3, 0.5, &mut rng);
        let bias = Tensor::randn(1, 3, 0.1, &mut rng);
        let mut reused = Tape::new(QPolicy::new(BF16));
        let first = mlp_graph(&mut reused, &x, &w, &bias);
        for _ in 0..3 {
            reused.reset();
            let again = mlp_graph(&mut reused, &x, &w, &bias);
            let mut fresh = Tape::new(QPolicy::new(BF16));
            let clean = mlp_graph(&mut fresh, &x, &w, &bias);
            assert_eq!(again.0.to_bits(), clean.0.to_bits());
            assert_eq!(again.1, clean.1);
            assert_eq!(again.0.to_bits(), first.0.to_bits());
        }
    }

    #[test]
    fn pooled_tape_bit_identical_to_single_threaded() {
        let mut rng = Rng::new(0x7C, 0);
        // large enough to cross both the elementwise and matmul fan-out
        // thresholds, with ragged dimensions
        let x = Tensor::randn(64, 200, 1.0, &mut rng);
        let w = Tensor::randn(200, 161, 0.3, &mut rng);
        let bias = Tensor::randn(1, 161, 0.1, &mut rng);
        let run = |pool: Arc<Pool>| {
            let mut t = Tape::with_pool(QPolicy::new(BF16), pool);
            mlp_graph(&mut t, &x, &w, &bias)
        };
        let (l1, g1) = run(Pool::single());
        for threads in [2usize, 3, 4] {
            let (l, g) = run(Arc::new(Pool::new(threads)));
            assert_eq!(l.to_bits(), l1.to_bits(), "loss threads={threads}");
            assert_eq!(g.data.len(), g1.data.len());
            for (i, (a, b)) in g.data.iter().zip(&g1.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads} grad[{i}]");
            }
        }
    }

    #[test]
    fn fast_and_reference_backends_bit_identical() {
        let mut rng = Rng::new(0x7B, 0);
        for _ in 0..10 {
            let x = Tensor::randn(5, 65, 1.0, &mut rng);
            let w = Tensor::randn(65, 7, 0.3, &mut rng);
            let bias = Tensor::randn(1, 7, 0.1, &mut rng);
            let mut fast = Tape::new(QPolicy::with_backend(BF16, Backend::Fast));
            let mut reference = Tape::new(QPolicy::with_backend(BF16, Backend::Reference));
            let (lf, gf) = mlp_graph(&mut fast, &x, &w, &bias);
            let (lr, gr) = mlp_graph(&mut reference, &x, &w, &bias);
            assert_eq!(lf.to_bits(), lr.to_bits());
            assert_eq!(gf.rows, gr.rows);
            for (a, b) in gf.data.iter().zip(&gr.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    #[should_panic(expected = "concat_cols: need at least one part")]
    fn concat_cols_rejects_empty() {
        let mut t = Tape::new(QPolicy::exact());
        let _ = t.concat_cols(vec![]);
    }

    #[test]
    fn scale_grad_matches_finite_difference() {
        let xs = vec![0.4f32, -1.2, 0.7, 2.1];
        let f = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let wv = t.param(Tensor::vector(w.to_vec()));
            let y = t.scale(wv, 1.7);
            let s = t.tanh(y);
            let m = t.mean_all(s);
            t.value(m).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let wv = t.param(Tensor::vector(xs.clone()));
        let y = t.scale(wv, 1.7);
        let s = t.tanh(y);
        let m = t.mean_all(s);
        t.backward(m);
        let g = t.grad(wv).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }

    #[test]
    fn layernorm_grad_matches_finite_difference() {
        let xs = vec![0.5f32, -0.3, 1.2, 0.8, -1.1, 0.05];
        let f = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let wv = t.param(Tensor::from_vec(2, 3, w.to_vec()));
            let y = t.layernorm(wv, 1e-5);
            let s = t.sigmoid(y);
            let m = t.mean_all(s);
            t.value(m).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let wv = t.param(Tensor::from_vec(2, 3, xs.clone()));
        let y = t.layernorm(wv, 1e-5);
        let s = t.sigmoid(y);
        let m = t.mean_all(s);
        t.backward(m);
        let g = t.grad(wv).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }

    #[test]
    fn layernorm_rows_are_normalised() {
        let mut t = Tape::new(QPolicy::exact());
        let x = t.input(Tensor::from_vec(2, 4, vec![1.0, 2.0, 3.0, 4.0, -5.0, 0.0, 5.0, 10.0]));
        let y = t.layernorm(x, 1e-6);
        for row in t.value(y).data.chunks_exact(4) {
            let mu: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / 4.0;
            assert!(mu.abs() < 1e-5, "row mean {mu}");
            assert!((var - 1.0).abs() < 1e-3, "row var {var}");
        }
    }

    #[test]
    fn softmax_xent_grad_matches_finite_difference() {
        let xs = vec![0.3f32, -0.7, 1.2, 0.5, -0.2, 0.9];
        let targets = vec![2usize, 0];
        let f = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let wv = t.param(Tensor::from_vec(2, 3, w.to_vec()));
            let l = t.softmax_xent(wv, vec![2, 0]);
            t.value(l).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let wv = t.param(Tensor::from_vec(2, 3, xs.clone()));
        let l = t.softmax_xent(wv, targets);
        t.backward(l);
        let g = t.grad(wv).unwrap().data.clone();
        // each row of dz sums to ~0 (softmax minus onehot)
        for row in g.chunks_exact(3) {
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-5, "row grad sum {s}");
        }
        fd_check(f, &xs, &g, 1e-2);
    }

    #[test]
    fn softmax_xent_matches_log_likelihood() {
        // two rows with known softmax: loss = mean(-ln p[target])
        let mut t = Tape::new(QPolicy::exact());
        let z = t.input(Tensor::from_vec(2, 2, vec![0.0, 0.0, 2.0, 0.0]));
        let l = t.softmax_xent(z, vec![1, 0]);
        let want = (2f64.ln() + (1.0 + (-2f64).exp()).ln()) / 2.0;
        assert!((t.value(l).item() as f64 - want).abs() < 1e-5);
    }

    #[test]
    fn matmul_nt_grad_matches_finite_difference() {
        let a0 = vec![0.5f32, -0.2, 0.8, 0.1, 0.9, -0.4];
        let b0 = vec![0.3f32, 0.7, -0.5, 0.2, 0.6, -0.8];
        // grad wrt a (b as input), then wrt b (a as input)
        let fa = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let av = t.param(Tensor::from_vec(2, 3, w.to_vec()));
            let bv = t.input(Tensor::from_vec(2, 3, vec![0.3, 0.7, -0.5, 0.2, 0.6, -0.8]));
            let y = t.matmul_nt(av, bv);
            let s = t.sigmoid(y);
            let m = t.mean_all(s);
            t.value(m).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let av = t.param(Tensor::from_vec(2, 3, a0.clone()));
        let bv = t.param(Tensor::from_vec(2, 3, b0.clone()));
        let y = t.matmul_nt(av, bv);
        let s = t.sigmoid(y);
        let m = t.mean_all(s);
        t.backward(m);
        let ga = t.grad(av).unwrap().data.clone();
        let gb = t.grad(bv).unwrap().data.clone();
        fd_check(fa, &a0, &ga, 2e-2);
        let fb = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let av = t.input(Tensor::from_vec(2, 3, vec![0.5, -0.2, 0.8, 0.1, 0.9, -0.4]));
            let bv = t.param(Tensor::from_vec(2, 3, w.to_vec()));
            let y = t.matmul_nt(av, bv);
            let s = t.sigmoid(y);
            let m = t.mean_all(s);
            t.value(m).item()
        };
        fd_check(fb, &b0, &gb, 2e-2);
    }

    #[test]
    fn gather_rows_grad_scatters_like_embed() {
        let mut t = Tape::new(QPolicy::exact());
        let x = t.param(Tensor::from_vec(3, 2, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        let gsel = t.gather_rows(x, vec![2, 0, 2]);
        let m = t.mean_all(gsel);
        t.backward(m);
        let g = t.grad(x).unwrap();
        assert_eq!(g.at(2, 0), 2.0 / 6.0);
        assert_eq!(g.at(0, 1), 1.0 / 6.0);
        assert_eq!(g.at(1, 0), 0.0);
    }

    /// Attention graph builder for the FD checks: which of q/k/v is the
    /// parameter is selected by `which` (0/1/2); the other two are inputs.
    fn attn_loss(which: usize, w: &[f32], others: [&[f32]; 2]) -> (f32, Option<Vec<f32>>) {
        let mut t = Tape::new(QPolicy::exact());
        let shape = |data: &[f32]| Tensor::from_vec(6, 2, data.to_vec());
        let mut mk = |is_param: bool, data: &[f32]| {
            if is_param {
                t.param(shape(data))
            } else {
                t.input(shape(data))
            }
        };
        let slots: Vec<Var> = match which {
            0 => vec![mk(true, w), mk(false, others[0]), mk(false, others[1])],
            1 => vec![mk(false, others[0]), mk(true, w), mk(false, others[1])],
            _ => vec![mk(false, others[0]), mk(false, others[1]), mk(true, w)],
        };
        // two sequences of three tokens, head dim 2
        let a = t.causal_attention(slots[0], slots[1], slots[2], 2);
        let s = t.tanh(a);
        let m = t.mean_all(s);
        t.backward(m);
        let pv = slots[which];
        let grad = t.grad(pv).map(|g| g.data.clone());
        (t.value(m).item(), grad)
    }

    #[test]
    fn causal_attention_grad_matches_finite_difference() {
        let q0: Vec<f32> = vec![0.5, -0.2, 0.8, 0.1, -0.6, 0.9, 0.2, 0.4, -0.3, 0.7, 0.1, -0.5];
        let k0: Vec<f32> = vec![0.3, 0.6, -0.4, 0.8, 0.2, -0.7, 0.5, 0.1, 0.9, -0.2, -0.6, 0.3];
        let v0: Vec<f32> = vec![-0.5, 0.2, 0.7, -0.1, 0.4, 0.8, -0.9, 0.3, 0.6, 0.5, -0.2, 0.1];
        let sets: [(usize, &[f32], [&[f32]; 2]); 3] = [
            (0, &q0, [&k0, &v0]),
            (1, &k0, [&q0, &v0]),
            (2, &v0, [&q0, &k0]),
        ];
        for (which, w, others) in sets {
            let g = attn_loss(which, w, others).1.expect("param collects grad");
            let f = |x: &[f32]| attn_loss(which, x, others).0;
            fd_check(f, w, &g, 2e-2);
        }
    }

    #[test]
    fn causal_attention_is_causal() {
        // perturbing a later token's k/v must not change earlier outputs
        let mut rng = Rng::new(0xA77, 0);
        let q = Tensor::randn(4, 3, 1.0, &mut rng);
        let k = Tensor::randn(4, 3, 1.0, &mut rng);
        let v = Tensor::randn(4, 3, 1.0, &mut rng);
        let run = |k: &Tensor, v: &Tensor| {
            let mut t = Tape::new(QPolicy::exact());
            let qv = t.input(q.clone());
            let kv = t.input(k.clone());
            let vv = t.input(v.clone());
            let a = t.causal_attention(qv, kv, vv, 1);
            t.value(a).data.clone()
        };
        let base = run(&k, &v);
        let mut k2 = k.clone();
        let mut v2 = v.clone();
        for c in 0..3 {
            *k2.at_mut(3, c) += 5.0;
            *v2.at_mut(3, c) -= 3.0;
        }
        let poked = run(&k2, &v2);
        // rows 0..3 (tokens before the perturbed one) are bit-identical
        for i in 0..9 {
            assert_eq!(base[i].to_bits(), poked[i].to_bits(), "elem {i}");
        }
        // the final row must actually depend on its own k/v
        assert!(base[9..].iter().zip(&poked[9..]).any(|(a, b)| a != b));
    }

    #[test]
    fn causal_attention_rows_are_convex_weights() {
        // with v = identity-ish rows, each output row is a convex combination
        let mut t = Tape::new(QPolicy::exact());
        let q = t.input(Tensor::from_vec(3, 2, vec![0.1, 0.2, -0.3, 0.4, 0.5, -0.6]));
        let k = t.input(Tensor::from_vec(3, 2, vec![0.7, -0.1, 0.2, 0.3, -0.4, 0.5]));
        let v = t.input(Tensor::from_vec(3, 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0]));
        let a = t.causal_attention(q, k, v, 1);
        let out = t.value(a);
        // row 0 attends only to token 0
        assert!((out.at(0, 0) - 1.0).abs() < 1e-6);
        assert!(out.at(0, 1).abs() < 1e-6);
        // later rows: weights sum to 1, so col sums equal the row sum of v's
        for i in 1..3 {
            let s = out.at(i, 0) + out.at(i, 1);
            assert!(s > 0.99 && s < 2.01, "row {i} sum {s}");
        }
    }

    /// Extends every FD check above to `Backend::Reference`: under the
    /// exact (fp32) policy both backends must produce bit-identical values
    /// AND gradients for each new op, so the finite-difference validation
    /// of the Fast path carries over verbatim.
    #[test]
    fn new_op_grads_bit_identical_on_reference_backend() {
        let mut rng = Rng::new(0xFD2, 0);
        let x = Tensor::randn(6, 4, 1.0, &mut rng);
        let emb = Tensor::randn(9, 4, 0.5, &mut rng);
        let targets = vec![0usize, 3, 8, 1, 5, 2];
        let run = |backend| {
            let mut t = Tape::new(QPolicy::with_backend(FP32, backend));
            let xv = t.param(x.clone());
            let ln = t.layernorm(xv, 1e-5);
            let sc = t.scale(ln, 1.3);
            let gsel = t.gather_rows(sc, vec![1, 0, 3, 2, 5, 4]);
            let a = t.causal_attention(gsel, sc, ln, 2);
            let ev = t.param(emb.clone());
            let logits = t.matmul_nt(a, ev);
            let loss = t.softmax_xent(logits, targets.clone());
            t.backward(loss);
            (
                t.value(loss).item(),
                t.grad(xv).unwrap().clone(),
                t.grad(ev).unwrap().clone(),
            )
        };
        let (lf, gxf, gef) = run(Backend::Fast);
        let (lr, gxr, ger) = run(Backend::Reference);
        assert_eq!(lf.to_bits(), lr.to_bits());
        for (i, (a, b)) in gxf.data.iter().zip(&gxr.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "x grad[{i}]");
        }
        for (i, (a, b)) in gef.data.iter().zip(&ger.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "emb grad[{i}]");
        }
    }

    /// The new LM ops under a shared graph: pooled fan-out and the scalar
    /// reference backend must both reproduce the single-threaded fast path
    /// bit-for-bit (the PR-3 determinism contract extended to gpt-nano's
    /// kernels).
    #[test]
    fn lm_ops_bit_identical_across_pools_and_backends() {
        let mut rng = Rng::new(0x9A7, 0);
        // 8 sequences × 16 tokens × width 64: crosses the layernorm
        // (EW_PAR_MIN), attention (ATTN_PAR_MIN) and matmul-NT (MM-class)
        // fan-out thresholds with ragged worker splits
        let (seqs, t_len, d) = (8usize, 16usize, 64usize);
        let rows = seqs * t_len;
        let x = Tensor::randn(rows, d, 1.0, &mut rng);
        let wq = Tensor::randn(d, d, 0.2, &mut rng);
        let emb = Tensor::randn(37, d, 0.3, &mut rng); // "vocab" 37
        let targets: Vec<usize> = (0..rows).map(|i| (i * 7) % 37).collect();
        let build = |t: &mut Tape| -> (f32, Tensor) {
            let xv = t.input_from(&x);
            let ln = t.layernorm(xv, 1e-5);
            let wv = t.param_from(&wq);
            let q = t.matmul(ln, wv);
            let a = t.causal_attention(q, ln, ln, seqs);
            let sc = t.scale(a, 0.5);
            let r = t.add(ln, sc);
            let ev = t.param_from(&emb);
            let logits = t.matmul_nt(r, ev);
            let loss = t.softmax_xent(logits, targets.clone());
            t.backward(loss);
            (t.value(loss).item(), t.grad(ev).unwrap().clone())
        };
        let mut base_tape = Tape::with_pool(QPolicy::new(BF16), Pool::single());
        let (l1, g1) = build(&mut base_tape);
        for threads in [2usize, 3, 4] {
            let mut t = Tape::with_pool(QPolicy::new(BF16), Arc::new(Pool::new(threads)));
            let (l, g) = build(&mut t);
            assert_eq!(l.to_bits(), l1.to_bits(), "loss threads={threads}");
            for (i, (a, b)) in g.data.iter().zip(&g1.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads} grad[{i}]");
            }
        }
        let mut rt = Tape::new(QPolicy::with_backend(BF16, Backend::Reference));
        let (lr, gr) = build(&mut rt);
        assert_eq!(lr.to_bits(), l1.to_bits(), "reference backend loss");
        for (i, (a, b)) in gr.data.iter().zip(&g1.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "reference grad[{i}]");
        }
        for threads in [1usize, 4] {
            let pool = if threads == 1 { Pool::single() } else { Arc::new(Pool::new(threads)) };
            let mut st = Tape::with_pool(QPolicy::with_backend(BF16, Backend::Simd), pool);
            let (ls, gs) = build(&mut st);
            assert_eq!(ls.to_bits(), l1.to_bits(), "simd backend loss threads={threads}");
            for (i, (a, b)) in gs.data.iter().zip(&g1.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "simd threads={threads} grad[{i}]");
            }
        }
    }

    /// The fused affine panel must reproduce the unfused
    /// `matmul → add_row (→ relu)` chain bit-for-bit: loss and every
    /// parameter gradient, on both backends, at 1 and 4 intra-threads,
    /// under the exact and a rounding policy.  This is the hot-path
    /// admission test for the `FuseAffine`/`FuseAffineRelu` rewrites.
    #[test]
    fn affine_bit_identical_to_unfused_chain() {
        let mut rng = Rng::new(0xAF1, 0);
        // crosses the elementwise and matmul fan-out thresholds
        let x = Tensor::randn(48, 130, 1.0, &mut rng);
        let w = Tensor::randn(130, 70, 0.3, &mut rng);
        let bias = Tensor::randn(1, 70, 0.1, &mut rng);
        let run = |policy: QPolicy, pool: Arc<Pool>, fused: bool, relu: bool| {
            let mut t = Tape::with_pool(policy, pool);
            let xv = t.param_from(&x);
            let wv = t.param_from(&w);
            let bv = t.param_from(&bias);
            let out = if fused {
                t.affine(xv, wv, bv, relu)
            } else {
                let m = t.matmul(xv, wv);
                let a = t.add_row(m, bv);
                if relu {
                    t.relu(a)
                } else {
                    a
                }
            };
            let s = t.sigmoid(out);
            let l = t.mean_all(s);
            t.backward(l);
            (
                t.value(l).item(),
                t.grad(xv).unwrap().clone(),
                t.grad(wv).unwrap().clone(),
                t.grad(bv).unwrap().clone(),
            )
        };
        for fmt in [FP32, BF16] {
            for relu in [false, true] {
                let base = run(QPolicy::new(fmt), Pool::single(), false, relu);
                for (backend, threads) in [
                    (Backend::Fast, 1),
                    (Backend::Fast, 4),
                    (Backend::Reference, 1),
                    (Backend::Simd, 1),
                    (Backend::Simd, 4),
                ] {
                    let pool = if threads == 1 {
                        Pool::single()
                    } else {
                        Arc::new(Pool::new(threads))
                    };
                    let got = run(QPolicy::with_backend(fmt, backend), pool, true, relu);
                    let what = format!(
                        "fmt={} relu={relu} backend={} threads={threads}",
                        fmt.name,
                        backend.name()
                    );
                    assert_eq!(got.0.to_bits(), base.0.to_bits(), "loss {what}");
                    for (which, (gf, gu)) in
                        [(&got.1, &base.1), (&got.2, &base.2), (&got.3, &base.3)]
                            .iter()
                            .enumerate()
                    {
                        for (i, (a, b)) in gf.data.iter().zip(&gu.data).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "grad tensor {which} elem {i} {what}"
                            );
                        }
                    }
                }
            }
        }
    }

    /// Pool-accounting regression: after warmup, stepping a graph through
    /// `reset` must neither leave buffers outstanding nor keep growing the
    /// free pool.  Before the pooled-scalar fix, every step leaked two
    /// fresh allocations into the pool (the fused-loss scalar and the
    /// backward seed), so the pool grew without bound.
    #[test]
    fn reset_pool_accounting_reaches_steady_state() {
        let mut rng = Rng::new(0x9001, 0);
        let x = Tensor::randn(4, 6, 1.0, &mut rng);
        let w = Tensor::randn(6, 3, 0.5, &mut rng);
        let bias = Tensor::randn(1, 3, 0.1, &mut rng);
        let mut t = Tape::new(QPolicy::new(BF16));
        // warm the pool: two steps lets every buffer capacity converge
        for _ in 0..2 {
            let _ = mlp_graph(&mut t, &x, &w, &bias);
            t.reset();
        }
        let (settled, outstanding) = t.pool_stats();
        assert_eq!(outstanding, 0, "buffers left outstanding after reset");
        for step in 0..4 {
            let _ = mlp_graph(&mut t, &x, &w, &bias);
            t.reset();
            let (now, outstanding) = t.pool_stats();
            assert_eq!(outstanding, 0, "step {step}: outstanding after reset");
            assert_eq!(now, settled, "step {step}: free pool kept growing");
        }
    }

    /// A Var held across `reset` must be rejected (debug builds).
    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "stale Var")]
    fn stale_var_across_reset_panics_in_debug() {
        let mut t = Tape::new(QPolicy::exact());
        let v = t.input(Tensor::vector(vec![1.0, 2.0]));
        t.reset();
        let fresh = t.input(Tensor::vector(vec![3.0, 4.0]));
        let _ = t.add(v, fresh);
    }

    /// The exported IR mirrors the recorded graph and passes the linter.
    #[test]
    fn export_program_mirrors_graph_and_lints_clean() {
        let mut t = Tape::new(QPolicy::exact());
        let x = t.input(Tensor::from_vec(2, 3, vec![1.0, 2.0, -1.0, 0.5, 0.1, 0.3]));
        let w = t.param(Tensor::from_vec(3, 2, vec![0.3, -0.7, 1.2, 0.5, -0.2, 0.9]));
        let b = t.param(Tensor::from_vec(1, 2, vec![0.1, -0.1]));
        let y = t.affine(x, w, b, true);
        let l = t.softmax_xent(y, vec![1, 0]);
        let prog = t.export_program();
        assert_eq!(prog.nodes.len(), t.num_nodes());
        let report = super::super::verify::lint(&prog, l.0);
        assert!(report.errors().is_empty(), "{report}");
    }
}
