//! Reverse-mode autograd with per-operator output rounding.
//!
//! This is the rust-native equivalent of the paper's QPyTorch simulator
//! (and of our L2 `qops.py`): every forward operator accumulates in fp32
//! and rounds its output onto the compute format; every backward cotangent
//! is rounded at each operator boundary.  The quantisation *policy* is
//! per-graph, so the theory experiments can independently toggle rounding
//! for forward/backward compute versus weight updates (Figure 2).
//!
//! ## Arena reuse
//!
//! Trainers rebuild the graph every step, so the tape retains its node and
//! gradient buffers across steps: [`Tape::reset`] clears the recorded graph
//! but moves every tensor allocation into a free pool that subsequent ops
//! draw from.  **`reset` invalidates all outstanding [`Var`]s** — after a
//! reset the graph must be rebuilt from scratch.  Steady-state training
//! therefore runs allocation-free once buffer capacities have converged
//! (usually within two steps).

use std::sync::Arc;

use crate::precision::{round_nearest, round_nearest_slice, Format, FP32};

use super::pool::Pool;
use super::tensor::Tensor;
use super::Backend;

/// Minimum element count before an elementwise op fans out across the
/// worker pool (memory-bound loops amortize the dispatch handshake slowly).
const EW_PAR_MIN: usize = 8192;

/// Rounding policy for forward/backward compute.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QPolicy {
    pub fmt: Format,
    pub backend: Backend,
}

impl QPolicy {
    pub fn exact() -> Self {
        Self { fmt: FP32, backend: Backend::Fast }
    }

    pub fn new(fmt: Format) -> Self {
        Self { fmt, backend: Backend::Fast }
    }

    pub fn with_backend(fmt: Format, backend: Backend) -> Self {
        Self { fmt, backend }
    }

    /// Round a slice in place per the policy (the per-operator output
    /// rounding).  Backends are bit-identical; `Reference` keeps the
    /// original scalar loop for baseline timing.
    #[inline]
    fn q_slice(&self, xs: &mut [f32]) {
        if self.fmt.is_fp32() {
            return;
        }
        match self.backend {
            Backend::Fast => round_nearest_slice(xs, self.fmt),
            Backend::Reference => {
                for x in xs {
                    *x = round_nearest(*x, self.fmt);
                }
            }
        }
    }

    /// Format to fuse into producing kernels, `None` for fp32 passthrough.
    #[inline]
    fn fuse_fmt(&self) -> Option<Format> {
        if self.fmt.is_fp32() {
            None
        } else {
            Some(self.fmt)
        }
    }
}

/// Index of a node in the tape.  Invalidated by [`Tape::reset`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Var(pub usize);

enum Op {
    /// Leaf (input or parameter).
    Leaf,
    MatMul(Var, Var),
    Add(Var, Var),
    Sub(Var, Var),
    Mul(Var, Var),
    Relu(Var),
    Sigmoid(Var),
    Tanh(Var),
    /// Row gather: out[r] = table[idx[r]].
    Embed { table: Var, idx: Vec<usize> },
    /// Mean over all elements -> scalar.
    MeanAll(Var),
    /// 0.5 * mean(d^2) fused loss over a difference node -> scalar.
    MseLoss(Var),
    /// BCE-with-logits fused loss vs labels tensor -> scalar.
    BceLoss { logits: Var, labels: Tensor },
    /// Broadcast a (1, n) bias over rows of a (m, n) input.
    AddRow(Var, Var),
    /// Column-wise concatenation of same-row-count tensors (memory op).
    ConcatCols(Vec<Var>),
}

// -- free-pool helpers (free functions so backward can hold disjoint field
//    borrows of the tape while allocating) ----------------------------------

/// Take an empty tensor whose storage comes from the pool (no zero fill —
/// callers extend/resize as they produce elements).
fn pool_tensor(free: &mut Vec<Vec<f32>>) -> Tensor {
    let mut data = free.pop().unwrap_or_default();
    data.clear();
    Tensor { rows: 0, cols: 0, data }
}

fn pool_zeros(free: &mut Vec<Vec<f32>>, rows: usize, cols: usize) -> Tensor {
    let mut t = pool_tensor(free);
    t.rows = rows;
    t.cols = cols;
    t.data.resize(rows * cols, 0.0);
    t
}

fn pool_copy(free: &mut Vec<Vec<f32>>, src: &Tensor) -> Tensor {
    let mut t = pool_tensor(free);
    t.rows = src.rows;
    t.cols = src.cols;
    t.data.extend_from_slice(&src.data);
    t
}

fn pool_map(free: &mut Vec<Vec<f32>>, src: &Tensor, f: impl Fn(f32) -> f32) -> Tensor {
    let mut t = pool_tensor(free);
    t.rows = src.rows;
    t.cols = src.cols;
    t.data.extend(src.data.iter().map(|&x| f(x)));
    t
}

fn pool_zip(
    free: &mut Vec<Vec<f32>>,
    a: &Tensor,
    b: &Tensor,
    f: impl Fn(f32, f32) -> f32,
) -> Tensor {
    debug_assert_eq!(a.data.len(), b.data.len());
    let mut t = pool_tensor(free);
    t.rows = a.rows;
    t.cols = a.cols;
    t.data.extend(a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)));
    t
}

/// Accumulate cotangent `g` into node `v`'s gradient (rounding at the
/// operator boundary, fp32 fan-in accumulation rounded once — same rule as
/// qops._qcast_bwd).  No-grad leaves (tape inputs) skip all of it and
/// recycle the buffer.
fn accum(
    policy: QPolicy,
    requires_grad: &[bool],
    grads: &mut [Option<Tensor>],
    free: &mut Vec<Vec<f32>>,
    v: Var,
    mut g: Tensor,
) {
    if !requires_grad[v.0] {
        free.push(g.data);
        return;
    }
    policy.q_slice(&mut g.data);
    match &mut grads[v.0] {
        Some(existing) => {
            assert_eq!(existing.data.len(), g.data.len(), "cotangent shape mismatch");
            for (e, &x) in existing.data.iter_mut().zip(&g.data) {
                *e += x;
            }
            policy.q_slice(&mut existing.data);
            free.push(g.data);
        }
        None => grads[v.0] = Some(g),
    }
}

/// The autograd tape: build forward ops, then `backward` from a scalar.
///
/// Node storage is split into parallel vectors (ops / values / grads) so the
/// backward pass can read operand values while writing gradients without
/// cloning whole tensors per op.
pub struct Tape {
    ops: Vec<Op>,
    values: Vec<Tensor>,
    grads: Vec<Option<Tensor>>,
    requires_grad: Vec<bool>,
    pub policy: QPolicy,
    /// Retired buffers recycled across ops and (via [`Tape::reset`]) steps.
    free: Vec<Vec<f32>>,
    /// Worker pool for the `Fast` backend's parallel kernels (matmul row
    /// panels, large elementwise ops).  Single-threaded by default; shared
    /// with the owning trainer via [`Tape::with_pool`].  Results are
    /// bit-identical at every pool size.
    pool: Arc<Pool>,
}

impl Tape {
    pub fn new(policy: QPolicy) -> Self {
        Self::with_pool(policy, Pool::single())
    }

    /// Build a tape whose `Fast`-backend kernels fan out over `pool`.
    pub fn with_pool(policy: QPolicy, pool: Arc<Pool>) -> Self {
        Self {
            ops: Vec::new(),
            values: Vec::new(),
            grads: Vec::new(),
            requires_grad: Vec::new(),
            policy,
            free: Vec::new(),
            pool,
        }
    }

    /// Clear the recorded graph while retaining all tensor storage for
    /// reuse.  Invalidates every outstanding [`Var`]; the next step's graph
    /// must be rebuilt from scratch, but its allocations are served from
    /// the pool instead of the allocator.
    pub fn reset(&mut self) {
        self.ops.clear();
        for t in self.values.drain(..) {
            self.free.push(t.data);
        }
        for g in self.grads.drain(..) {
            if let Some(t) = g {
                self.free.push(t.data);
            }
        }
        self.requires_grad.clear();
    }

    /// Number of nodes recorded since construction / the last reset.
    pub fn num_nodes(&self) -> usize {
        self.values.len()
    }

    fn push(&mut self, op: Op, value: Tensor, requires_grad: bool) -> Var {
        self.ops.push(op);
        self.values.push(value);
        self.grads.push(None);
        self.requires_grad.push(requires_grad);
        Var(self.values.len() - 1)
    }

    fn take_buf(&mut self) -> Vec<f32> {
        let mut b = self.free.pop().unwrap_or_default();
        b.clear();
        b
    }

    /// Register an input: no cotangent is accumulated into it during
    /// `backward` ([`Tape::grad`] stays `None`).
    pub fn input(&mut self, t: Tensor) -> Var {
        self.push(Op::Leaf, t, false)
    }

    /// Register a parameter (gradient collected).  The value is used as
    /// stored — callers keep parameters in-format themselves.
    pub fn param(&mut self, t: Tensor) -> Var {
        self.push(Op::Leaf, t, true)
    }

    /// [`Tape::input`] that copies into a pool buffer instead of taking an
    /// owned tensor (no per-step allocation in steady state).
    pub fn input_from(&mut self, t: &Tensor) -> Var {
        let c = pool_copy(&mut self.free, t);
        self.push(Op::Leaf, c, false)
    }

    /// [`Tape::param`] that copies into a pool buffer instead of taking an
    /// owned tensor (no per-step allocation in steady state).
    pub fn param_from(&mut self, t: &Tensor) -> Var {
        let c = pool_copy(&mut self.free, t);
        self.push(Op::Leaf, c, true)
    }

    pub fn value(&self, v: Var) -> &Tensor {
        &self.values[v.0]
    }

    pub fn grad(&self, v: Var) -> Option<&Tensor> {
        self.grads[v.0].as_ref()
    }

    // -- forward ops (each rounds its output once, fused with the producing
    //    loop so rounding never makes a second pass over cold memory) -------

    /// Elementwise ops compute + round per contiguous chunk; both steps are
    /// element-local, so the pooled path is bit-identical to the sequential
    /// one regardless of how chunks land on workers.
    fn unary(&mut self, a: Var, op: Op, f: impl Fn(f32) -> f32 + Sync) -> Var {
        let mut data = self.take_buf();
        let policy = self.policy;
        let (rows, cols);
        {
            let av = &self.values[a.0];
            rows = av.rows;
            cols = av.cols;
            if policy.backend == Backend::Fast
                && self.pool.threads() > 1
                && av.data.len() >= EW_PAR_MIN
            {
                data.resize(av.data.len(), 0.0);
                let src = &av.data;
                self.pool.for_chunks_mut(&mut data, EW_PAR_MIN, |off, chunk| {
                    for (o, &x) in chunk.iter_mut().zip(&src[off..off + chunk.len()]) {
                        *o = f(x);
                    }
                    policy.q_slice(chunk);
                });
            } else {
                data.extend(av.data.iter().map(|&x| f(x)));
                policy.q_slice(&mut data);
            }
        }
        let out = Tensor { rows, cols, data };
        self.push(op, out, true)
    }

    fn binary(&mut self, a: Var, b: Var, op: Op, f: impl Fn(f32, f32) -> f32 + Sync) -> Var {
        let mut data = self.take_buf();
        let policy = self.policy;
        let (rows, cols);
        {
            let (av, bv) = (&self.values[a.0], &self.values[b.0]);
            assert_eq!(av.rows, bv.rows);
            assert_eq!(av.cols, bv.cols);
            rows = av.rows;
            cols = av.cols;
            if policy.backend == Backend::Fast
                && self.pool.threads() > 1
                && av.data.len() >= EW_PAR_MIN
            {
                data.resize(av.data.len(), 0.0);
                let (sa, sb) = (&av.data, &bv.data);
                self.pool.for_chunks_mut(&mut data, EW_PAR_MIN, |off, chunk| {
                    let end = off + chunk.len();
                    for ((o, &x), &y) in
                        chunk.iter_mut().zip(&sa[off..end]).zip(&sb[off..end])
                    {
                        *o = f(x, y);
                    }
                    policy.q_slice(chunk);
                });
            } else {
                data.extend(av.data.iter().zip(&bv.data).map(|(&x, &y)| f(x, y)));
                policy.q_slice(&mut data);
            }
        }
        let out = Tensor { rows, cols, data };
        self.push(op, out, true)
    }

    fn push_scalar(&mut self, op: Op, v: f32) -> Var {
        let mut t = Tensor::scalar(v);
        self.policy.q_slice(&mut t.data);
        self.push(op, t, true)
    }

    pub fn matmul(&mut self, a: Var, b: Var) -> Var {
        match self.policy.backend {
            Backend::Fast => {
                let mut out = Tensor { rows: 0, cols: 0, data: self.take_buf() };
                let fuse = self.policy.fuse_fmt();
                self.values[a.0].matmul_into_pooled(
                    &self.values[b.0],
                    &mut out,
                    fuse,
                    &self.pool,
                );
                self.push(Op::MatMul(a, b), out, true)
            }
            Backend::Reference => {
                let mut out = self.values[a.0].matmul_reference(&self.values[b.0]);
                self.policy.q_slice(&mut out.data);
                self.push(Op::MatMul(a, b), out, true)
            }
        }
    }

    pub fn add(&mut self, a: Var, b: Var) -> Var {
        self.binary(a, b, Op::Add(a, b), |x, y| x + y)
    }

    /// Broadcast-add a (1, n) bias to an (m, n) activation.
    pub fn add_row(&mut self, a: Var, bias: Var) -> Var {
        let mut data = self.take_buf();
        {
            let (av, bv) = (&self.values[a.0], &self.values[bias.0]);
            assert_eq!(bv.rows, 1);
            assert_eq!(bv.cols, av.cols);
            data.reserve(av.data.len());
            if av.cols > 0 {
                for arow in av.data.chunks_exact(av.cols) {
                    data.extend(arow.iter().zip(&bv.data).map(|(&x, &b)| x + b));
                }
            }
        }
        let (rows, cols) = (self.values[a.0].rows, self.values[a.0].cols);
        let mut out = Tensor { rows, cols, data };
        self.policy.q_slice(&mut out.data);
        self.push(Op::AddRow(a, bias), out, true)
    }

    pub fn sub(&mut self, a: Var, b: Var) -> Var {
        self.binary(a, b, Op::Sub(a, b), |x, y| x - y)
    }

    pub fn mul(&mut self, a: Var, b: Var) -> Var {
        self.binary(a, b, Op::Mul(a, b), |x, y| x * y)
    }

    pub fn relu(&mut self, a: Var) -> Var {
        self.unary(a, Op::Relu(a), |x| x.max(0.0))
    }

    pub fn sigmoid(&mut self, a: Var) -> Var {
        self.unary(a, Op::Sigmoid(a), |x| 1.0 / (1.0 + (-x).exp()))
    }

    pub fn tanh(&mut self, a: Var) -> Var {
        self.unary(a, Op::Tanh(a), f32::tanh)
    }

    /// Embedding lookup: rows of `table` selected by `idx`.
    pub fn embed(&mut self, table: Var, idx: Vec<usize>) -> Var {
        let mut data = self.take_buf();
        let tv = &self.values[table.0];
        let cols = tv.cols;
        data.reserve(idx.len() * cols);
        for &i in &idx {
            data.extend_from_slice(&tv.data[i * cols..(i + 1) * cols]);
        }
        let out = Tensor { rows: idx.len(), cols, data };
        // gather is a memory op: values already in-format, no rounding
        self.push(Op::Embed { table, idx }, out, true)
    }

    /// Column-wise concat (a memory op: values pass through unrounded).
    pub fn concat_cols(&mut self, parts: Vec<Var>) -> Var {
        assert!(!parts.is_empty(), "concat_cols: need at least one part");
        let mut data = self.take_buf();
        let rows = self.values[parts[0].0].rows;
        let total: usize = parts.iter().map(|v| self.values[v.0].cols).sum();
        data.resize(rows * total, 0.0);
        let mut off = 0;
        for &p in &parts {
            let pv = &self.values[p.0];
            assert_eq!(pv.rows, rows, "concat row mismatch");
            for r in 0..rows {
                data[r * total + off..r * total + off + pv.cols]
                    .copy_from_slice(&pv.data[r * pv.cols..(r + 1) * pv.cols]);
            }
            off += pv.cols;
        }
        let out = Tensor { rows, cols: total, data };
        self.push(Op::ConcatCols(parts), out, true)
    }

    pub fn mean_all(&mut self, a: Var) -> Var {
        let v = &self.values[a.0];
        let m = v.data.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        self.push_scalar(Op::MeanAll(a), m as f32)
    }

    /// Fused 0.5·mean((a-b)²) — one output rounding, like qops.mse_loss.
    pub fn mse_loss(&mut self, a: Var, b: Var) -> Var {
        let d = self.sub(a, b);
        let dv = &self.values[d.0];
        let m =
            dv.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>() / dv.len() as f64;
        self.push_scalar(Op::MseLoss(d), 0.5 * m as f32)
    }

    /// Fused BCE-with-logits against constant labels.
    pub fn bce_loss(&mut self, logits: Var, labels: Tensor) -> Var {
        let lv = &self.values[logits.0];
        assert_eq!(lv.len(), labels.len());
        let mut acc = 0f64;
        for (&z, &y) in lv.data.iter().zip(&labels.data) {
            // -(y log σ(z) + (1-y) log σ(-z)) = max(z,0) - zy + log(1+e^-|z|)
            let l = z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
            acc += l as f64;
        }
        let mean = (acc / lv.len() as f64) as f32;
        self.push_scalar(Op::BceLoss { logits, labels }, mean)
    }

    // -- backward -----------------------------------------------------------

    /// Run reverse-mode from scalar `root` (seed gradient 1.0).
    ///
    /// Operand values are read through split field borrows — no per-op
    /// tensor cloning — and every intermediate cotangent draws its storage
    /// from (and returns it to) the tape's buffer pool.
    pub fn backward(&mut self, root: Var) {
        assert_eq!(self.values[root.0].len(), 1, "backward from non-scalar");
        self.grads[root.0] = Some(Tensor::scalar(1.0));
        let Tape { ops, values, grads, requires_grad, policy, free, pool } = self;
        let policy = *policy;
        let pool: &Pool = pool;
        let rg: &[bool] = requires_grad;
        for i in (0..=root.0).rev() {
            let Some(g) = grads[i].take() else { continue };
            match &ops[i] {
                Op::Leaf => {}
                Op::MatMul(a, b) => {
                    let (a, b) = (*a, *b);
                    match policy.backend {
                        Backend::Fast => {
                            // da = g·bᵀ, db = aᵀ·g, transposes in pooled
                            // scratch; a no-grad operand (a tape input) skips
                            // its cotangent matmul entirely
                            if rg[a.0] {
                                let mut bt = pool_tensor(free);
                                values[b.0].transpose_into(&mut bt);
                                let mut da = pool_tensor(free);
                                g.matmul_into_pooled(&bt, &mut da, None, pool);
                                free.push(bt.data);
                                accum(policy, rg, grads, free, a, da);
                            }
                            if rg[b.0] {
                                let mut at = pool_tensor(free);
                                values[a.0].transpose_into(&mut at);
                                let mut db = pool_tensor(free);
                                at.matmul_into_pooled(&g, &mut db, None, pool);
                                free.push(at.data);
                                accum(policy, rg, grads, free, b, db);
                            }
                        }
                        Backend::Reference => {
                            let da = g.matmul_reference(&values[b.0].transpose());
                            let db = values[a.0].transpose().matmul_reference(&g);
                            accum(policy, rg, grads, free, a, da);
                            accum(policy, rg, grads, free, b, db);
                        }
                    }
                }
                Op::Add(a, b) => {
                    let (a, b) = (*a, *b);
                    let ga = pool_copy(free, &g);
                    let gb = pool_copy(free, &g);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, b, gb);
                }
                Op::AddRow(a, bias) => {
                    let (a, bias) = (*a, *bias);
                    let mut db = pool_zeros(free, 1, g.cols);
                    if g.cols > 0 {
                        for grow in g.data.chunks_exact(g.cols) {
                            for (d, &x) in db.data.iter_mut().zip(grow) {
                                *d += x;
                            }
                        }
                    }
                    let ga = pool_copy(free, &g);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, bias, db);
                }
                Op::Sub(a, b) => {
                    let (a, b) = (*a, *b);
                    let ga = pool_copy(free, &g);
                    let gb = pool_map(free, &g, |x| -x);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, b, gb);
                }
                Op::Mul(a, b) => {
                    let (a, b) = (*a, *b);
                    let ga = pool_zip(free, &g, &values[b.0], |gg, y| gg * y);
                    let gb = pool_zip(free, &g, &values[a.0], |gg, x| gg * x);
                    accum(policy, rg, grads, free, a, ga);
                    accum(policy, rg, grads, free, b, gb);
                }
                Op::Relu(a) => {
                    let a = *a;
                    let ga = pool_zip(free, &g, &values[a.0], |gg, x| {
                        if x > 0.0 {
                            gg
                        } else {
                            0.0
                        }
                    });
                    accum(policy, rg, grads, free, a, ga);
                }
                Op::Sigmoid(a) => {
                    let a = *a;
                    let ga = pool_zip(free, &g, &values[i], |gg, y| gg * y * (1.0 - y));
                    accum(policy, rg, grads, free, a, ga);
                }
                Op::Tanh(a) => {
                    let a = *a;
                    let ga = pool_zip(free, &g, &values[i], |gg, y| gg * (1.0 - y * y));
                    accum(policy, rg, grads, free, a, ga);
                }
                Op::Embed { table, idx } => {
                    let table = *table;
                    let (rows, cols) = (values[table.0].rows, values[table.0].cols);
                    let mut dt = pool_zeros(free, rows, cols);
                    for (r, &row_i) in idx.iter().enumerate() {
                        let dst = &mut dt.data[row_i * cols..(row_i + 1) * cols];
                        let src = &g.data[r * cols..(r + 1) * cols];
                        for (d, &x) in dst.iter_mut().zip(src) {
                            *d += x;
                        }
                    }
                    accum(policy, rg, grads, free, table, dt);
                }
                Op::MeanAll(a) => {
                    let a = *a;
                    let av = &values[a.0];
                    let seed = g.item() / av.len() as f32;
                    let mut da = pool_tensor(free);
                    da.rows = av.rows;
                    da.cols = av.cols;
                    da.data.resize(av.len(), seed);
                    accum(policy, rg, grads, free, a, da);
                }
                Op::MseLoss(d) => {
                    let d = *d;
                    let n = values[d.0].len() as f32;
                    let seed = g.item();
                    let da = pool_map(free, &values[d.0], |x| seed * x / n);
                    accum(policy, rg, grads, free, d, da);
                }
                Op::ConcatCols(parts) => {
                    let mut off = 0;
                    for &p in parts {
                        let (pr, pc) = (values[p.0].rows, values[p.0].cols);
                        let mut dp = pool_tensor(free);
                        dp.rows = pr;
                        dp.cols = pc;
                        dp.data.reserve(pr * pc);
                        for r in 0..pr {
                            dp.data.extend_from_slice(
                                &g.data[r * g.cols + off..r * g.cols + off + pc],
                            );
                        }
                        accum(policy, rg, grads, free, p, dp);
                        off += pc;
                    }
                }
                Op::BceLoss { logits, labels } => {
                    let logits = *logits;
                    let lv = &values[logits.0];
                    let n = lv.len() as f32;
                    let seed = g.item();
                    let dl = pool_zip(free, lv, labels, |z, y| {
                        let p = 1.0 / (1.0 + (-z).exp());
                        seed * (p - y) / n
                    });
                    accum(policy, rg, grads, free, logits, dl);
                }
            }
            grads[i] = Some(g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::BF16;
    use crate::util::rng::Rng;

    fn fd_check(f: impl Fn(&[f32]) -> f32, xs: &[f32], analytic: &[f32], tol: f32) {
        let h = 1e-3f32;
        for i in 0..xs.len() {
            let mut up = xs.to_vec();
            up[i] += h;
            let mut dn = xs.to_vec();
            dn[i] -= h;
            let fd = (f(&up) - f(&dn)) / (2.0 * h);
            assert!(
                (fd - analytic[i]).abs() <= tol * (1.0 + fd.abs()),
                "grad[{i}] analytic={} fd={fd}",
                analytic[i]
            );
        }
    }

    #[test]
    fn matmul_grad_matches_finite_difference() {
        let xs = vec![0.3f32, -0.7, 1.2, 0.5, -0.2, 0.9];
        let f = |w: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let a = t.input(Tensor::from_vec(2, 3, vec![1.0, 2.0, -1.0, 0.5, 0.1, 0.3]));
            let wv = t.param(Tensor::from_vec(3, 2, w.to_vec()));
            let y = t.matmul(a, wv);
            let s = t.sigmoid(y);
            let target = t.input(Tensor::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]));
            let l = t.mse_loss(s, target);
            t.value(l).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let a = t.input(Tensor::from_vec(2, 3, vec![1.0, 2.0, -1.0, 0.5, 0.1, 0.3]));
        let wv = t.param(Tensor::from_vec(3, 2, xs.clone()));
        let y = t.matmul(a, wv);
        let s = t.sigmoid(y);
        let target = t.input(Tensor::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]));
        let l = t.mse_loss(s, target);
        t.backward(l);
        let g = t.grad(wv).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }

    #[test]
    fn bce_grad_matches_finite_difference() {
        let xs = vec![0.2f32, -0.4, 0.8];
        let labels = Tensor::vector(vec![1.0, 0.0, 1.0]);
        let f = |z: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let zv = t.param(Tensor::vector(z.to_vec()));
            let l = t.bce_loss(zv, Tensor::vector(vec![1.0, 0.0, 1.0]));
            t.value(l).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let zv = t.param(Tensor::vector(xs.clone()));
        let l = t.bce_loss(zv, labels);
        t.backward(l);
        let g = t.grad(zv).unwrap().data.clone();
        fd_check(f, &xs, &g, 1e-2);
    }

    #[test]
    fn embed_grad_scatters_rows() {
        let mut t = Tape::new(QPolicy::exact());
        let table = t.param(Tensor::from_vec(4, 2, (0..8).map(|i| i as f32).collect()));
        let e = t.embed(table, vec![1, 1, 3]);
        let m = t.mean_all(e);
        t.backward(m);
        let g = t.grad(table).unwrap();
        // 6 elements in `e`; each contributes 1/6
        assert_eq!(g.at(1, 0), 2.0 / 6.0);
        assert_eq!(g.at(3, 1), 1.0 / 6.0);
        assert_eq!(g.at(0, 0), 0.0);
    }

    #[test]
    fn quantised_forward_outputs_in_format() {
        let mut t = Tape::new(QPolicy::new(BF16));
        let a = t.input(Tensor::vector(vec![1.0001, 2.3456, -0.0001234]));
        let b = t.input(Tensor::vector(vec![1.0, 1.0, 1.0]));
        let s = t.add(a, b);
        for &x in &t.value(s).data {
            assert_eq!(x, crate::precision::round_nearest(x, BF16));
        }
    }

    #[test]
    fn relu_tanh_add_row_backward() {
        let xs = vec![0.5f32, -0.3];
        let f = |b: &[f32]| {
            let mut t = Tape::new(QPolicy::exact());
            let a = t.input(Tensor::from_vec(2, 2, vec![1.0, -1.0, 0.5, 2.0]));
            let bias = t.param(Tensor::vector(b.to_vec()));
            let h = t.add_row(a, bias);
            let r = t.relu(h);
            let th = t.tanh(r);
            let m = t.mean_all(th);
            t.value(m).item()
        };
        let mut t = Tape::new(QPolicy::exact());
        let a = t.input(Tensor::from_vec(2, 2, vec![1.0, -1.0, 0.5, 2.0]));
        let bias = t.param(Tensor::vector(xs.clone()));
        let h = t.add_row(a, bias);
        let r = t.relu(h);
        let th = t.tanh(r);
        let m = t.mean_all(th);
        t.backward(m);
        let g = t.grad(bias).unwrap().data.clone();
        fd_check(f, &xs, &g, 2e-2);
    }

    #[test]
    fn inputs_collect_no_gradient_params_do() {
        let mut t = Tape::new(QPolicy::exact());
        let x = t.input(Tensor::vector(vec![1.0, 2.0]));
        let w = t.param(Tensor::vector(vec![0.5, -0.5]));
        let p = t.mul(x, w);
        let m = t.mean_all(p);
        t.backward(m);
        assert!(t.grad(x).is_none(), "inputs must not accumulate cotangents");
        assert!(t.grad(w).is_some());
    }

    /// Build one MLP step's graph; returns (loss value, weight grad).
    fn mlp_graph(t: &mut Tape, x: &Tensor, w: &Tensor, bias: &Tensor) -> (f32, Tensor) {
        let xv = t.input_from(x);
        let wv = t.param_from(w);
        let bv = t.param_from(bias);
        let h = t.matmul(xv, wv);
        let hb = t.add_row(h, bv);
        let r = t.relu(hb);
        let s = t.sigmoid(r);
        let m = t.mean_all(s);
        t.backward(m);
        (t.value(m).item(), t.grad(wv).unwrap().clone())
    }

    #[test]
    fn reset_reuses_buffers_and_reproduces_fresh_tape() {
        let mut rng = Rng::new(0x7A, 0);
        let x = Tensor::randn(4, 6, 1.0, &mut rng);
        let w = Tensor::randn(6, 3, 0.5, &mut rng);
        let bias = Tensor::randn(1, 3, 0.1, &mut rng);
        let mut reused = Tape::new(QPolicy::new(BF16));
        let first = mlp_graph(&mut reused, &x, &w, &bias);
        for _ in 0..3 {
            reused.reset();
            let again = mlp_graph(&mut reused, &x, &w, &bias);
            let mut fresh = Tape::new(QPolicy::new(BF16));
            let clean = mlp_graph(&mut fresh, &x, &w, &bias);
            assert_eq!(again.0.to_bits(), clean.0.to_bits());
            assert_eq!(again.1, clean.1);
            assert_eq!(again.0.to_bits(), first.0.to_bits());
        }
    }

    #[test]
    fn pooled_tape_bit_identical_to_single_threaded() {
        let mut rng = Rng::new(0x7C, 0);
        // large enough to cross both the elementwise and matmul fan-out
        // thresholds, with ragged dimensions
        let x = Tensor::randn(64, 200, 1.0, &mut rng);
        let w = Tensor::randn(200, 161, 0.3, &mut rng);
        let bias = Tensor::randn(1, 161, 0.1, &mut rng);
        let run = |pool: Arc<Pool>| {
            let mut t = Tape::with_pool(QPolicy::new(BF16), pool);
            mlp_graph(&mut t, &x, &w, &bias)
        };
        let (l1, g1) = run(Pool::single());
        for threads in [2usize, 3, 4] {
            let (l, g) = run(Arc::new(Pool::new(threads)));
            assert_eq!(l.to_bits(), l1.to_bits(), "loss threads={threads}");
            assert_eq!(g.data.len(), g1.data.len());
            for (i, (a, b)) in g.data.iter().zip(&g1.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads} grad[{i}]");
            }
        }
    }

    #[test]
    fn fast_and_reference_backends_bit_identical() {
        let mut rng = Rng::new(0x7B, 0);
        for _ in 0..10 {
            let x = Tensor::randn(5, 65, 1.0, &mut rng);
            let w = Tensor::randn(65, 7, 0.3, &mut rng);
            let bias = Tensor::randn(1, 7, 0.1, &mut rng);
            let mut fast = Tape::new(QPolicy::with_backend(BF16, Backend::Fast));
            let mut reference = Tape::new(QPolicy::with_backend(BF16, Backend::Reference));
            let (lf, gf) = mlp_graph(&mut fast, &x, &w, &bias);
            let (lr, gr) = mlp_graph(&mut reference, &x, &w, &bias);
            assert_eq!(lf.to_bits(), lr.to_bits());
            assert_eq!(gf.rows, gr.rows);
            for (a, b) in gf.data.iter().zip(&gr.data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    #[should_panic(expected = "concat_cols: need at least one part")]
    fn concat_cols_rejects_empty() {
        let mut t = Tape::new(QPolicy::exact());
        let _ = t.concat_cols(vec![]);
    }
}
