//! Quantised-training simulator — the rust-native analogue of QPyTorch.
//!
//! A small dense tensor library + reverse-mode autograd where **every
//! operator accumulates in fp32 and rounds its output** onto a configured
//! format, plus optimizers implementing the paper's weight-update policies.
//! Powers the theory experiments (Figure 2 / Theorem 1), the per-layer
//! cancellation telemetry (Figure 9), the sub-16-bit sweeps (Figure 10) and
//! the native criterion benches; the seven deep-learning applications run
//! through the PJRT runtime instead.

pub mod dlrm;
pub mod lsq;
pub mod optim;
pub mod tape;
pub mod tensor;

pub use crate::precision::Mode;
pub use optim::{Sgd, SgdState, UpdateStats};
pub use tape::{QPolicy, Tape, Var};
pub use tensor::Tensor;
