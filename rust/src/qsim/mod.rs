//! Quantised-training simulator — the rust-native analogue of QPyTorch.
//!
//! A small dense tensor library + reverse-mode autograd where **every
//! operator accumulates in fp32 and rounds its output** onto a configured
//! format, a reusable layer library ([`nn`]), optimizers implementing the
//! paper's weight-update policies, and the generic training engine
//! ([`train`]): one `Trainer<T: Task>` supplying the loop, per-tensor
//! optimizer bank, eval fork and native checkpoint/resume to every app.
//! Powers the theory experiments (Figure 2 / Theorem 1), the per-layer
//! cancellation telemetry (Figure 9), the sub-16-bit sweeps (Figure 10),
//! the native criterion benches and the bit-exact application scenarios —
//! DLRM ([`dlrm`]), least-squares ([`lsq`]), the tiny causal-transformer
//! LM ([`gpt`]) and the spiral MLP classifier ([`mlp`]); the paper's seven
//! full-scale applications run through the PJRT runtime instead.

pub mod dlrm;
pub mod gpt;
pub mod lsq;
pub mod mlp;
pub mod nn;
pub mod optim;
pub mod pool;
pub mod tape;
pub mod tensor;
pub mod train;
pub mod verify;

/// Which kernel implementations the simulator runs on.
///
/// Both backends are bit-identical by construction (verified by property
/// tests and the 100-step trainer parity test); `Reference` preserves the
/// original scalar loops and per-step allocation behaviour so the bench can
/// measure the vectorized path against the pre-optimization baseline.
/// `Fast` additionally fans its kernels out over a per-trainer worker
/// [`Pool`] when `intra_threads > 1`; because SR dither is counter-keyed
/// (a pure function of element position), results stay bit-identical at
/// every thread count — and to `Reference`, which always runs
/// scalar-sequential over the same dither schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Backend {
    /// Scalar kernels, fresh tape + per-element RNG each step (the
    /// pre-vectorization code path, kept as the exactness oracle).
    Reference,
    /// Tiled matmul, arena-reuse tape, batched rounding (default).
    #[default]
    Fast,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Reference => "reference",
            Backend::Fast => "fast",
        }
    }
}

pub use crate::precision::Mode;
pub use nn::Module;
pub use optim::{Sgd, SgdState, UpdateStats};
pub use pool::Pool;
pub use tape::{QPolicy, Tape, Var};
pub use tensor::Tensor;
pub use train::{EvalMetrics, StepTelemetry, Task, TensorClass};
