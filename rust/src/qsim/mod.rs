//! Quantised-training simulator — the rust-native analogue of QPyTorch.
//!
//! A small dense tensor library + reverse-mode autograd where **every
//! operator accumulates in fp32 and rounds its output** onto a configured
//! format, a reusable layer library ([`nn`]), optimizers implementing the
//! paper's weight-update policies, and the generic training engine
//! ([`train`]): one `Trainer<T: Task>` supplying the loop, per-tensor
//! optimizer bank, eval fork and native checkpoint/resume to every app.
//! Frozen graphs score through the tape-free compiled-plan executor
//! ([`infer`]) — the engine behind `repro serve` and every `Task::eval`.
//! Powers the theory experiments (Figure 2 / Theorem 1), the per-layer
//! cancellation telemetry (Figure 9), the sub-16-bit sweeps (Figure 10),
//! the native criterion benches and the bit-exact application scenarios —
//! DLRM ([`dlrm`]), least-squares ([`lsq`]), the tiny causal-transformer
//! LM ([`gpt`]) and the spiral MLP classifier ([`mlp`]); the paper's seven
//! full-scale applications run through the PJRT runtime instead.

pub mod dlrm;
pub mod fault;
pub mod gpt;
pub mod infer;
pub mod lsq;
pub mod mlp;
pub mod nn;
pub mod optim;
pub mod pool;
pub mod shard;
pub mod tape;
pub mod tensor;
pub mod train;
pub mod verify;

/// Which kernel implementations the simulator runs on.
///
/// All backends are bit-identical by construction (verified by property
/// tests, the differential fuzzer and the 100-step trainer parity test);
/// `Reference` preserves the original scalar loops and per-step allocation
/// behaviour so the bench can measure the optimized paths against the
/// pre-optimization baseline.  `Fast` and `Simd` additionally fan their
/// kernels out over a per-trainer worker [`Pool`] when `intra_threads > 1`;
/// because SR dither is counter-keyed (a pure function of element
/// position), results stay bit-identical at every thread count — and to
/// `Reference`, which always runs scalar-sequential over the same dither
/// schedule.  `Simd` swaps the leaf kernels (rounding slices, the matmul
/// microkernel, the staged SGD passes) for fixed-width 8-lane chunked
/// implementations; lane order is irrelevant to the result because every
/// per-element operation is position-keyed, so `Simd` stays on the same
/// digest as the other two tiers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Backend {
    /// Scalar kernels, fresh tape + per-element RNG each step (the
    /// pre-vectorization code path, kept as the exactness oracle).
    Reference,
    /// Tiled matmul, arena-reuse tape, batched rounding (default).
    #[default]
    Fast,
    /// `Fast` structure with 8-wide chunked-lane leaf kernels (rounding,
    /// matmul microkernel, SGD stage passes) the compiler autovectorizes;
    /// explicit AVX2 intrinsics behind the `simd-intrinsics` feature.
    Simd,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Reference => "reference",
            Backend::Fast => "fast",
            Backend::Simd => "simd",
        }
    }

    /// Whether this tier uses the pooled/arena execution structure
    /// (tape reuse, staged slice passes, worker-pool fan-out).  Only
    /// `Reference` keeps the scalar-sequential fresh-allocation layout.
    pub fn pooled(&self) -> bool {
        !matches!(self, Backend::Reference)
    }

    /// Whether this tier selects the 8-lane chunked leaf kernels.
    pub fn simd(&self) -> bool {
        matches!(self, Backend::Simd)
    }

    /// Parse a CLI/TOML backend name ([`Backend::name`] round-trips).
    pub fn by_name(name: &str) -> Option<Backend> {
        match name {
            "reference" => Some(Backend::Reference),
            "fast" => Some(Backend::Fast),
            "simd" => Some(Backend::Simd),
            _ => None,
        }
    }
}

pub use crate::precision::Mode;
pub use fault::{ChaosConfig, ChaosKind, ChaosPlan};
pub use infer::{DlrmPlan, GptPlan, InferPlan, MlpPlan, ServeApp, ServeConfig};
pub use nn::Module;
pub use optim::{Sgd, SgdState, UpdateStats};
pub use pool::Pool;
pub use shard::{ShardOptions, ShardStats, ShardedTrainer};
pub use tape::{QPolicy, Tape, Var};
pub use tensor::Tensor;
pub use train::{EvalMetrics, StepTelemetry, Task, TensorClass};
