//! Native tiny causal-transformer LM (`gpt-nano`) on the quantised tape —
//! the third exactly-simulated application family after DLRM and
//! least-squares.
//!
//! Kalamkar et al. (2019) show bf16 behaviour differs materially across
//! model families (embeddings vs attention vs MLP); this puts attention,
//! layernorm and a tied softmax head on the bit-exact simulator with the
//! same determinism contract as the DLRM path: counter-keyed SR dither,
//! `Fast`/`Reference` backends bit-identical, and bit-identical training at
//! every `--intra-threads` setting.
//!
//! Architecture (`gpt-nano`): token + position embeddings → N pre-LN blocks
//! of single-head causal attention and a two-layer MLP (residual branches
//! scaled by 1/√(2·N)) → final layernorm → softmax head **tied** to the
//! token embedding (`logits = x @ embedᵀ` via the tape's `matmul_nt`).
//! Data is a seeded synthetic first-order Markov corpus, so the optimal
//! loss is the chain's conditional entropy and the LM has real structure
//! (bigram statistics + positional regularities) to learn.

use std::sync::Arc;

use crate::precision::Format;
use crate::util::rng::Rng;

use super::nn::{Embedding, LayerNorm, Linear, Mlp, Module};
use super::tape::{QPolicy, Tape, Var};
use super::tensor::Tensor;
use super::train::{EvalMetrics, Task, TensorClass, Trainer};
use super::Backend;

/// Stream tag for the synthetic Markov corpus' training draws.
const LM_DATA_STREAM: u64 = 0x6D6B; // "mk"
/// Stream tag for the held-out eval draws (disjoint from training, so eval
/// cadence can never perturb the training trajectory).
const LM_EVAL_STREAM: u64 = 0xE7A2;
/// Stream tag for the ground-truth transition model.
const LM_TRUTH_STREAM: u64 = 0x7472; // "tr"
/// Stream tag for parameter initialisation.
const LM_INIT_STREAM: u64 = 0x6E; // "n"

/// Model + data configuration.
#[derive(Debug, Clone)]
pub struct GptConfig {
    pub vocab: usize,
    pub seq_len: usize,
    /// Residual / head width.
    pub dim: usize,
    /// MLP hidden width.
    pub hidden: usize,
    pub n_blocks: usize,
    /// Sequences per batch.
    pub batch: usize,
    pub fmt: Format,
    pub seed: u64,
    /// Kernel backend (see [`Backend`]); bit-identical results either way.
    pub backend: Backend,
    /// Intra-step worker threads (`Fast` backend only; `1` = sequential,
    /// `0` = auto).  Bit-identical results at every setting.
    pub intra_threads: usize,
}

impl Default for GptConfig {
    fn default() -> Self {
        Self {
            vocab: 32,
            seq_len: 16,
            dim: 16,
            hidden: 32,
            n_blocks: 2,
            batch: 8,
            fmt: crate::precision::BF16,
            seed: 0,
            backend: Backend::Fast,
            intra_threads: 1,
        }
    }
}

/// One batch of next-token prediction data: `batch` sequences of `seq_len`
/// tokens, flattened row-major (sequence s occupies rows s·T .. (s+1)·T).
pub struct LmBatch {
    pub tokens: Vec<usize>,
    pub targets: Vec<usize>,
}

/// Seeded synthetic Markov corpus: a row-stochastic transition matrix with
/// peaked successor distributions (softmax of N(0, 2) logits), sampled by
/// inverse CDF.  The transition model is shared between forks, so train and
/// eval streams draw from the *same* language through disjoint RNG streams.
pub struct MarkovGen {
    cfg: GptConfig,
    /// Per-token cumulative successor distribution (vocab × vocab).
    cdf: Arc<Vec<f32>>,
    rng: Rng,
}

impl MarkovGen {
    pub fn new(cfg: &GptConfig) -> Self {
        let mut truth = Rng::new(cfg.seed, LM_TRUTH_STREAM);
        let v = cfg.vocab;
        let mut cdf = vec![0f32; v * v];
        for r in 0..v {
            let row = &mut cdf[r * v..(r + 1) * v];
            let mut total = 0f64;
            for x in row.iter_mut() {
                *x = (truth.normal() * 2.0).exp();
                total += *x as f64;
            }
            let mut acc = 0f64;
            for x in row.iter_mut() {
                acc += *x as f64;
                *x = (acc / total) as f32;
            }
            // fp guard: the last bucket must cover every u in [0, 1)
            row[v - 1] = 1.0;
        }
        Self { cfg: cfg.clone(), cdf: Arc::new(cdf), rng: Rng::new(cfg.seed, LM_DATA_STREAM) }
    }

    /// Fork a generator sharing this one's transition model but drawing
    /// samples from an independent (seed, stream) pair.
    pub fn fork(&self, stream: u64) -> MarkovGen {
        MarkovGen {
            cfg: self.cfg.clone(),
            cdf: Arc::clone(&self.cdf),
            rng: Rng::new(self.cfg.seed, stream),
        }
    }

    fn next_token(&mut self, prev: usize) -> usize {
        let v = self.cfg.vocab;
        let u = self.rng.uniform();
        let row = &self.cdf[prev * v..(prev + 1) * v];
        row.partition_point(|&c| c < u).min(v - 1)
    }

    pub fn next_batch(&mut self) -> LmBatch {
        let (b, t_len, v) = (self.cfg.batch, self.cfg.seq_len, self.cfg.vocab);
        let mut tokens = Vec::with_capacity(b * t_len);
        let mut targets = Vec::with_capacity(b * t_len);
        for _ in 0..b {
            let mut prev = self.rng.below(v);
            for _ in 0..t_len {
                tokens.push(prev);
                let next = self.next_token(prev);
                targets.push(next);
                prev = next;
            }
        }
        LmBatch { tokens, targets }
    }
}

/// One pre-LN transformer block.
pub struct GptBlock {
    pub ln1: LayerNorm,
    pub wq: Linear,
    pub wk: Linear,
    pub wv: Linear,
    pub wo: Linear,
    pub ln2: LayerNorm,
    pub mlp: Mlp,
}

impl Module for GptBlock {
    fn params(&self) -> Vec<&Tensor> {
        let mut v = self.wq.params();
        v.extend(self.wk.params());
        v.extend(self.wv.params());
        v.extend(self.wo.params());
        v.extend(self.mlp.params());
        v
    }

    fn params_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v = self.wq.params_mut();
        v.extend(self.wk.params_mut());
        v.extend(self.wv.params_mut());
        v.extend(self.wo.params_mut());
        v.extend(self.mlp.params_mut());
        v
    }
}

/// Batch-payload and output node ids of one frozen gpt-nano graph — what
/// `qsim::infer` rebinds per request batch (token gather, xent targets)
/// and reads back (next-token logits, mean loss).
pub struct GptFrozenVars {
    pub tok_gather: Var,
    pub pos_gather: Var,
    pub logits: Var,
    pub loss: Var,
}

/// The model: embeddings + blocks + tied softmax head, built from `qsim::nn`
/// layers.
pub struct GptModel {
    pub cfg: GptConfig,
    pub tok: Embedding,
    pub pos: Embedding,
    pub blocks: Vec<GptBlock>,
    pub ln_f: LayerNorm,
    /// Residual-branch scale 1/√(2·n_blocks) (GPT-2-style depth scaling,
    /// applied through the tape's `scale` op).
    res_scale: f32,
}

impl GptModel {
    pub fn init(cfg: &GptConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, LM_INIT_STREAM);
        let d = cfg.dim;
        let tok = Embedding::init(cfg.vocab, d, 0.05, cfg.fmt, &mut rng);
        let pos = Embedding::init(cfg.seq_len, d, 0.05, cfg.fmt, &mut rng);
        let blocks = (0..cfg.n_blocks)
            .map(|_| GptBlock {
                ln1: LayerNorm::new(),
                wq: Linear::init(d, d, false, cfg.fmt, &mut rng),
                wk: Linear::init(d, d, false, cfg.fmt, &mut rng),
                wv: Linear::init(d, d, false, cfg.fmt, &mut rng),
                wo: Linear::init(d, d, false, cfg.fmt, &mut rng),
                ln2: LayerNorm::new(),
                mlp: Mlp::init(d, cfg.hidden, d, cfg.fmt, &mut rng),
            })
            .collect();
        Self {
            cfg: cfg.clone(),
            tok,
            pos,
            blocks,
            ln_f: LayerNorm::new(),
            res_scale: 1.0 / (2.0 * cfg.n_blocks.max(1) as f32).sqrt(),
        }
    }

    /// Number of parameter tensors: tok + pos + 8 per block (wq/wk/wv/wo +
    /// the MLP's two weight/bias pairs); the tied softmax head adds none.
    pub fn num_tensors(cfg: &GptConfig) -> usize {
        2 + 8 * cfg.n_blocks
    }

    /// Position ids 0..T repeated once per sequence.
    fn pos_ids(&self, seqs: usize) -> Vec<usize> {
        let t_len = self.cfg.seq_len;
        let mut ids = Vec::with_capacity(seqs * t_len);
        for _ in 0..seqs {
            ids.extend(0..t_len);
        }
        ids
    }

    /// Build the training graph into a caller-owned tape; returns
    /// (loss, params) with params ordered
    /// [tok, pos, (wq, wk, wv, wo, fc1_w, fc1_b, fc2_w, fc2_b) × block].
    pub fn forward_into(&self, t: &mut Tape, batch: &LmBatch) -> (Var, Vec<Var>) {
        let t_len = self.cfg.seq_len;
        assert_eq!(batch.tokens.len(), batch.targets.len());
        assert!(t_len > 0 && batch.tokens.len() % t_len == 0, "partial sequence in batch");
        let seqs = batch.tokens.len() / t_len;
        let mut params = Vec::new();
        let tokv = self.tok.bind(t, &mut params);
        let x_tok = t.gather_rows(tokv, batch.tokens.clone());
        let posv = self.pos.bind(t, &mut params);
        let x_pos = t.gather_rows(posv, self.pos_ids(seqs));
        let mut x = t.add(x_tok, x_pos);
        for blk in &self.blocks {
            let h = blk.ln1.forward(t, x);
            let q = blk.wq.forward(t, h, &mut params);
            let k = blk.wk.forward(t, h, &mut params);
            let v = blk.wv.forward(t, h, &mut params);
            let a = t.causal_attention(q, k, v, seqs);
            let o = blk.wo.forward(t, a, &mut params);
            let o = t.scale(o, self.res_scale);
            x = t.add(x, o);
            let h2 = blk.ln2.forward(t, x);
            let m = blk.mlp.forward(t, h2, &mut params);
            let m = t.scale(m, self.res_scale);
            x = t.add(x, m);
        }
        let xf = self.ln_f.forward(t, x);
        // tied softmax: the head reuses the token-embedding param node
        let logits = t.matmul_nt(xf, tokv);
        let loss = t.softmax_xent(logits, batch.targets.clone());
        (loss, params)
    }

    /// Build the frozen (no-grad) forward graph into a caller-owned tape
    /// — the single source of truth for the inference graph shape, shared
    /// by the per-batch eval path and `qsim::infer` plan compilation
    /// (which needs the batch-payload node ids to rebind per request).
    /// Op order matches the historical eval body exactly, so eval values
    /// are bit-identical across the refactor.
    pub fn frozen_graph_into(&self, t: &mut Tape, batch: &LmBatch) -> GptFrozenVars {
        let t_len = self.cfg.seq_len;
        let seqs = batch.tokens.len() / t_len;
        let tokv = t.input(self.tok.table.clone());
        let x_tok = t.gather_rows(tokv, batch.tokens.clone());
        let posv = t.input(self.pos.table.clone());
        let x_pos = t.gather_rows(posv, self.pos_ids(seqs));
        let mut x = t.add(x_tok, x_pos);
        for blk in &self.blocks {
            let h = blk.ln1.forward(t, x);
            let q = blk.wq.forward_frozen(t, h);
            let k = blk.wk.forward_frozen(t, h);
            let v = blk.wv.forward_frozen(t, h);
            let a = t.causal_attention(q, k, v, seqs);
            let o = blk.wo.forward_frozen(t, a);
            let o = t.scale(o, self.res_scale);
            x = t.add(x, o);
            let h2 = blk.ln2.forward(t, x);
            let m = blk.mlp.forward_frozen(t, h2);
            let m = t.scale(m, self.res_scale);
            x = t.add(x, m);
        }
        let xf = self.ln_f.forward(t, x);
        let logits = t.matmul_nt(xf, tokv);
        let loss = t.softmax_xent(logits, batch.targets.clone());
        GptFrozenVars { tok_gather: x_tok, pos_gather: x_pos, logits, loss }
    }

    /// Forward-only mean loss over one batch (all tensors as no-grad
    /// inputs; same rounding policy as training forward).
    pub fn eval_loss(&self, batch: &LmBatch, policy: QPolicy) -> f32 {
        let mut t = Tape::new(policy);
        let v = self.frozen_graph_into(&mut t, batch);
        t.value(v.loss).item()
    }

    /// All parameter tensors, in forward registration order.
    pub fn param_tensors(&self) -> Vec<&Tensor> {
        let mut v = self.tok.params();
        v.extend(self.pos.params());
        for b in &self.blocks {
            v.extend(b.params());
        }
        v
    }

    /// Mutable walk in the same order (optimizer updates).
    pub fn param_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v = self.tok.params_mut();
        v.extend(self.pos.params_mut());
        for b in &mut self.blocks {
            v.extend(b.params_mut());
        }
        v
    }
}

/// gpt-nano as a [`Task`]: the config maps onto the model, the Markov
/// corpus and the perplexity eval; the generic [`Trainer`] supplies the
/// loop, the per-tensor optimizer bank (mixed precision placements now
/// work here too, not just on DLRM), the eval fork and checkpointing.
/// Param order: [tok, pos, (wq, wk, wv, wo, fc1_w, fc1_b, fc2_w, fc2_b)
/// × block]; the token/position embeddings are the `Embed` telemetry
/// class, everything else `Dense`.
impl Task for GptConfig {
    type Model = GptModel;
    type Gen = MarkovGen;
    type Batch = LmBatch;

    const NAME: &'static str = "gpt-nano";
    const EVAL_STREAM: u64 = LM_EVAL_STREAM;

    fn seed(&self) -> u64 {
        self.seed
    }

    fn fmt(&self) -> Format {
        self.fmt
    }

    fn backend(&self) -> Backend {
        self.backend
    }

    fn intra_threads(&self) -> usize {
        self.intra_threads
    }

    fn config_fingerprint(&self) -> String {
        format!(
            "seed={} vocab={} seq={} dim={} hidden={} blocks={} batch={}",
            self.seed, self.vocab, self.seq_len, self.dim, self.hidden, self.n_blocks,
            self.batch
        )
    }

    fn num_tensors(&self) -> usize {
        GptModel::num_tensors(self)
    }

    fn tensor_class(&self, i: usize) -> TensorClass {
        if i < 2 {
            TensorClass::Embed
        } else {
            TensorClass::Dense
        }
    }

    fn init_model(&self) -> GptModel {
        GptModel::init(self)
    }

    fn make_gen(&self) -> MarkovGen {
        MarkovGen::new(self)
    }

    fn fork_gen(gen: &MarkovGen, stream: u64) -> MarkovGen {
        gen.fork(stream)
    }

    fn next_batch(gen: &mut MarkovGen) -> LmBatch {
        gen.next_batch()
    }

    fn forward_into(model: &GptModel, t: &mut Tape, batch: &LmBatch) -> (Var, Vec<Var>) {
        model.forward_into(t, batch)
    }

    fn param_tensors(model: &GptModel) -> Vec<&Tensor> {
        model.param_tensors()
    }

    fn param_tensors_mut(model: &mut GptModel) -> Vec<&mut Tensor> {
        model.param_tensors_mut()
    }

    /// Mean eval loss (natural log) and perplexity (`exp(loss)`) over `n`
    /// fresh batches.  `n == 0` is defined as zero loss / unit perplexity.
    ///
    /// Scored through a [`GptPlan`](crate::qsim::infer::GptPlan) compiled
    /// from the first batch and rebound for the rest — the plan replay is
    /// bit-identical to the per-batch tape rebuild it replaced (pinned by
    /// the `qsim-parity` digests), just without paying the tape.
    fn eval(model: &GptModel, gen: &mut MarkovGen, n: usize, policy: QPolicy) -> EvalMetrics {
        if n == 0 {
            return EvalMetrics { loss: 0.0, metric: 1.0, metric_name: "ppl" };
        }
        let mut plan: Option<crate::qsim::infer::GptPlan> = None;
        let mut acc = 0f64;
        for _ in 0..n {
            let batch = gen.next_batch();
            let p = plan
                .get_or_insert_with(|| crate::qsim::infer::GptPlan::compile(model, &batch, policy));
            acc += p.score(&batch) as f64;
        }
        let loss = (acc / n as f64) as f32;
        EvalMetrics { loss, metric: loss.exp(), metric_name: "ppl" }
    }
}

/// The gpt-nano trainer — an instantiation of the generic engine.
pub type GptTrainer = Trainer<GptConfig>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Mode;
    use crate::qsim::train::StepTelemetry;

    #[test]
    fn markov_gen_is_deterministic_and_in_range() {
        let cfg = GptConfig { seed: 5, ..Default::default() };
        let mut a = MarkovGen::new(&cfg);
        let mut b = MarkovGen::new(&cfg);
        let ba = a.next_batch();
        let bb = b.next_batch();
        assert_eq!(ba.tokens, bb.tokens);
        assert_eq!(ba.targets, bb.targets);
        assert_eq!(ba.tokens.len(), cfg.batch * cfg.seq_len);
        assert!(ba.tokens.iter().all(|&t| t < cfg.vocab));
        assert!(ba.targets.iter().all(|&t| t < cfg.vocab));
        // targets are the next-token shift of the underlying walk
        for s in 0..cfg.batch {
            for i in 0..cfg.seq_len - 1 {
                assert_eq!(
                    ba.targets[s * cfg.seq_len + i],
                    ba.tokens[s * cfg.seq_len + i + 1],
                    "seq {s} pos {i}"
                );
            }
        }
        // a forked stream shares the language but draws different samples
        let mut e = a.fork(0x1234);
        let be = e.next_batch();
        assert_ne!(be.tokens, ba.tokens);
    }

    #[test]
    fn fp32_training_reduces_loss() {
        let cfg = GptConfig { seed: 3, ..Default::default() };
        let mut tr = GptTrainer::new(cfg, Mode::Fp32);
        let first: f32 = (0..10).map(|_| tr.step(0.1).loss).sum::<f32>() / 10.0;
        for _ in 0..280 {
            tr.step(0.1);
        }
        let last: f32 = (0..10).map(|_| tr.step(0.1).loss).sum::<f32>() / 10.0;
        assert!(last < first, "first={first} last={last}");
        // and eval agrees (below the uniform-prediction bound ln V)
        let el = tr.eval(4).loss;
        assert!(el < (tr.model.cfg.vocab as f32).ln(), "eval {el}");
    }

    /// Acceptance gate (tentpole): the gpt-nano sr16 trajectory is
    /// bit-identical between the vectorized fast path and the scalar
    /// reference backend over 50 steps.
    #[test]
    fn sr16_fifty_steps_bit_identical_across_backends() {
        let mk = |backend| {
            let cfg = GptConfig { seed: 11, backend, ..Default::default() };
            GptTrainer::new(cfg, Mode::Sr16)
        };
        let mut fast = mk(Backend::Fast);
        let mut reference = mk(Backend::Reference);
        for step in 0..50 {
            let a = fast.step(0.1);
            let b = reference.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
            assert_eq!(a.embed, b.embed, "embed stats diverged at step {step}");
            assert_eq!(a.mlp, b.mlp, "dense stats diverged at step {step}");
        }
        let mut fm = fast.model;
        let mut rm = reference.model;
        for (pi, (wa, wb)) in fm
            .param_tensors_mut()
            .into_iter()
            .zip(rm.param_tensors_mut())
            .enumerate()
        {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            assert_eq!(da.len(), db.len());
            for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "param {pi} elem {ei} after 50 steps");
            }
        }
    }

    /// Acceptance gate (tentpole): bit-identical sr16 training at 1 vs 4
    /// intra-threads, sized so the attention/matmul fan-outs engage.
    #[test]
    fn sr16_training_bit_identical_across_thread_counts() {
        let mk = |intra_threads| {
            let cfg = GptConfig {
                seed: 17,
                vocab: 64,
                seq_len: 16,
                dim: 32,
                hidden: 64,
                batch: 8,
                intra_threads,
                ..Default::default()
            };
            GptTrainer::new(cfg, Mode::Sr16)
        };
        let mut base = mk(1);
        let base_tel: Vec<StepTelemetry> = (0..15).map(|_| base.step(0.1)).collect();
        let base_eval = base.eval(2);
        for threads in [4usize] {
            let mut tr = mk(threads);
            assert_eq!(tr.intra_threads(), threads);
            for (step, want) in base_tel.iter().enumerate() {
                let got = tr.step(0.1);
                assert_eq!(
                    got.loss.to_bits(),
                    want.loss.to_bits(),
                    "loss diverged at step {step} with {threads} threads"
                );
                assert_eq!(got.embed, want.embed, "embed stats, step {step}, t={threads}");
                assert_eq!(got.mlp, want.mlp, "dense stats, step {step}, t={threads}");
            }
            assert_eq!(
                tr.eval(2).loss.to_bits(),
                base_eval.loss.to_bits(),
                "eval, t={threads}"
            );
            for (pi, (wa, wb)) in base
                .model
                .param_tensors_mut()
                .into_iter()
                .zip(tr.model.param_tensors_mut())
                .enumerate()
            {
                let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
                for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "param {pi} elem {ei} diverged with {threads} threads"
                    );
                }
            }
        }
    }

    /// Bugfix gate: eval cadence must not perturb the training trajectory
    /// (the eval generator is a fork, not the training stream).
    #[test]
    fn eval_cadence_does_not_change_training_trajectory() {
        let mk = || {
            let cfg = GptConfig { seed: 23, ..Default::default() };
            GptTrainer::new(cfg, Mode::Sr16)
        };
        let mut with_eval = mk();
        let mut without = mk();
        for step in 0..30 {
            let a = with_eval.step(0.1);
            let b = without.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
            if (step + 1) % 10 == 0 {
                let el = with_eval.eval(2);
                assert!(el.loss.is_finite());
            }
        }
        for (wa, wb) in with_eval
            .model
            .param_tensors_mut()
            .into_iter()
            .zip(without.model.param_tensors_mut())
        {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            for (x, y) in da.iter().zip(db.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
        let empty = with_eval.eval(0);
        assert_eq!((empty.loss, empty.metric), (0.0, 1.0), "empty eval is defined");
    }

    /// Satellite gate: per-tensor mixed-precision modes (previously a
    /// DLRM-only capability) work on gpt through the generic trainer —
    /// Kahan embeddings + SR everywhere else trains sanely, and the mix is
    /// reflected in the generic weight-byte accounting.
    #[test]
    fn mixed_precision_modes_work_on_gpt() {
        let cfg = GptConfig { seed: 29, ..Default::default() };
        let n = GptModel::num_tensors(&cfg);
        // tok + pos embeddings in kahan16, every block tensor in sr16
        let modes: Vec<Mode> =
            (0..n).map(|i| if i < 2 { Mode::Kahan16 } else { Mode::Sr16 }).collect();
        let all_sr = vec![Mode::Sr16; n];
        let mut tr = GptTrainer::new_mixed(cfg, modes.clone());
        let mut loss = 0.0;
        for _ in 0..20 {
            let tel = tr.step(0.1);
            loss = tel.loss;
            assert!(loss.is_finite());
            // embeddings and dense tensors are tracked as separate classes
            assert!(tel.embed.nonzero > 0 || tel.mlp.nonzero > 0);
        }
        assert!(tr.eval(2).loss.is_finite());
        assert!(
            tr.weight_bytes_for(&modes) > tr.weight_bytes_for(&all_sr),
            "kahan embeddings must cost extra compensation bytes"
        );
        assert!(loss < (tr.model.cfg.vocab as f32).ln() * 1.5, "training went nowhere: {loss}");
    }

    #[test]
    fn param_registration_order_matches_param_tensors() {
        let cfg = GptConfig { seed: 1, ..Default::default() };
        let mut model = GptModel::init(&cfg);
        let gen_batch = MarkovGen::new(&cfg).next_batch();
        let mut tape = Tape::new(QPolicy::exact());
        let (_, vars) = model.forward_into(&mut tape, &gen_batch);
        assert_eq!(vars.len(), GptModel::num_tensors(&cfg));
        // every registered var's shape matches the owned tensor walk
        for (var, tensor) in vars.iter().zip(model.param_tensors_mut()) {
            let v = tape.value(*var);
            assert_eq!((v.rows, v.cols), (tensor.rows, tensor.cols));
        }
    }
}
