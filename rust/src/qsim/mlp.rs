//! Native MLP classifier (`mlp`) on the quantised tape — the app that
//! *proves* the generic `qsim::train` engine: the whole implementation is a
//! model, a seeded data generator and a [`Task`] impl (~150 lines); the
//! training loop, per-tensor optimizer bank, eval fork, intra-step pool and
//! checkpoint/resume all come from [`Trainer`] unchanged.
//!
//! The workload is a seeded synthetic **spiral** dataset — `classes`
//! interleaved spiral arms in the plane, the classic non-linearly-separable
//! multi-class task — classified by a three-layer MLP (2 → hidden → hidden
//! → classes, ReLU, softmax cross-entropy).  Like the other native apps it
//! has real structure to learn, an exact ground truth, and the full
//! determinism contract: counter-keyed SR dither, `Fast`/`Reference`
//! backends bit-identical, bit-identical training at every
//! `--intra-threads` setting.

use crate::precision::Format;
use crate::util::rng::Rng;

use super::nn::{Linear, Mlp, Module};
use super::tape::{QPolicy, Tape, Var};
use super::tensor::Tensor;
use super::train::{EvalMetrics, Task, TensorClass, Trainer};
use super::Backend;

/// Stream tag for the spiral training draws.
const SPIRAL_DATA_STREAM: u64 = 0x5350; // "SP"
/// Stream tag for the held-out eval draws (disjoint from training).
const SPIRAL_EVAL_STREAM: u64 = 0xE7A3;
/// Stream tag for parameter initialisation.
const SPIRAL_INIT_STREAM: u64 = 0x6D6C; // "ml"

/// Model + data configuration.
#[derive(Debug, Clone)]
pub struct MlpConfig {
    /// Number of spiral arms / output classes.
    pub classes: usize,
    /// Hidden width of both hidden layers.
    pub hidden: usize,
    /// Samples per batch.
    pub batch: usize,
    /// Spiral revolutions from centre to rim (more turns = harder task).
    pub turns: f32,
    /// Angular jitter (radians, scaled by a normal draw) on each sample.
    pub noise: f32,
    pub fmt: Format,
    pub seed: u64,
    /// Kernel backend (see [`Backend`]); bit-identical results either way.
    pub backend: Backend,
    /// Intra-step worker threads (`Fast` backend only; `1` = sequential,
    /// `0` = auto).  Bit-identical results at every setting.
    pub intra_threads: usize,
}

impl Default for MlpConfig {
    fn default() -> Self {
        Self {
            classes: 3,
            hidden: 32,
            batch: 32,
            // one revolution with mild jitter: hard enough that a linear
            // model fails, easy enough that a 2×32 MLP converges within a
            // few hundred SGD steps (validated against a numpy port)
            turns: 1.0,
            noise: 0.06,
            fmt: crate::precision::BF16,
            seed: 0,
            backend: Backend::Fast,
            intra_threads: 1,
        }
    }
}

/// One batch of classification data: `(batch, 2)` points and their arm ids.
pub struct SpiralBatch {
    pub x: Tensor,
    pub y: Vec<usize>,
}

/// Seeded spiral sampler.  The "ground truth" is the spiral geometry
/// itself — a pure function of the config — so forked generators draw
/// different samples from the *same* task through disjoint RNG streams.
pub struct SpiralGen {
    cfg: MlpConfig,
    rng: Rng,
}

impl SpiralGen {
    pub fn new(cfg: &MlpConfig) -> Self {
        Self { cfg: cfg.clone(), rng: Rng::new(cfg.seed, SPIRAL_DATA_STREAM) }
    }

    /// Fork a generator over an independent (seed, stream) pair.
    pub fn fork(&self, stream: u64) -> SpiralGen {
        SpiralGen { cfg: self.cfg.clone(), rng: Rng::new(self.cfg.seed, stream) }
    }

    pub fn next_batch(&mut self) -> SpiralBatch {
        let b = self.cfg.batch;
        let k_cls = self.cfg.classes;
        let mut x = Tensor::zeros(b, 2);
        let mut y = Vec::with_capacity(b);
        for r in 0..b {
            let k = self.rng.below(k_cls);
            // radial position along the arm, then the arm's angle at that
            // radius plus the class phase offset and angular jitter
            let t = self.rng.uniform();
            let radius = 0.1 + 0.9 * t;
            let angle = std::f32::consts::TAU * (t * self.cfg.turns + k as f32 / k_cls as f32)
                + self.cfg.noise * self.rng.normal();
            *x.at_mut(r, 0) = radius * angle.cos();
            *x.at_mut(r, 1) = radius * angle.sin();
            y.push(k);
        }
        SpiralBatch { x, y }
    }
}

/// Batch-payload and output node ids of one frozen spiral-MLP graph —
/// what `qsim::infer` rebinds per batch (the input leaf, xent targets)
/// and reads back (logits, mean loss).
pub struct MlpFrozenVars {
    pub x: Var,
    pub logits: Var,
    pub loss: Var,
}

/// The model: 2 → hidden → hidden → classes, composed from `qsim::nn`.
pub struct MlpModel {
    pub cfg: MlpConfig,
    pub body: Mlp,
    pub head: Linear,
}

impl MlpModel {
    pub fn init(cfg: &MlpConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, SPIRAL_INIT_STREAM);
        Self {
            cfg: cfg.clone(),
            body: Mlp::init(2, cfg.hidden, cfg.hidden, cfg.fmt, &mut rng),
            head: Linear::init(cfg.hidden, cfg.classes, true, cfg.fmt, &mut rng),
        }
    }

    /// Number of parameter tensors: the body's two weight/bias pairs plus
    /// the head pair.
    pub fn num_tensors(_cfg: &MlpConfig) -> usize {
        6
    }

    /// Build the training graph into a caller-owned tape; returns
    /// (loss, params) with params ordered [fc1_w, fc1_b, fc2_w, fc2_b,
    /// head_w, head_b].
    pub fn forward_into(&self, t: &mut Tape, batch: &SpiralBatch) -> (Var, Vec<Var>) {
        let mut params = Vec::new();
        let xv = t.input_from(&batch.x);
        let h = self.body.forward(t, xv, &mut params);
        let hr = t.relu(h);
        let logits = self.head.forward(t, hr, &mut params);
        let loss = t.softmax_xent(logits, batch.y.clone());
        (loss, params)
    }

    /// Forward-only pass from no-grad leaves; returns (mean loss, logits).
    pub fn eval_scores(&self, batch: &SpiralBatch, policy: QPolicy) -> (f32, Tensor) {
        let mut t = Tape::new(policy);
        let v = self.frozen_graph_into(&mut t, batch);
        let scores = t.value(v.logits).clone();
        (t.value(v.loss).item(), scores)
    }

    /// Build the frozen (no-grad) forward graph into a caller-owned tape
    /// — shared by the per-batch eval path and `qsim::infer` plan
    /// compilation (which needs the batch-payload node ids to rebind per
    /// batch).  Op order matches the historical eval body exactly, so
    /// eval values are bit-identical across the refactor.
    pub fn frozen_graph_into(&self, t: &mut Tape, batch: &SpiralBatch) -> MlpFrozenVars {
        let x = t.input_from(&batch.x);
        let h = self.body.forward_frozen(t, x);
        let hr = t.relu(h);
        let logits = self.head.forward_frozen(t, hr);
        let loss = t.softmax_xent(logits, batch.y.clone());
        MlpFrozenVars { x, logits, loss }
    }

    /// All parameter tensors, in forward registration order.
    pub fn param_tensors(&self) -> Vec<&Tensor> {
        let mut v = self.body.params();
        v.extend(self.head.params());
        v
    }

    /// Mutable walk in the same order (optimizer updates).
    pub fn param_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v = self.body.params_mut();
        v.extend(self.head.params_mut());
        v
    }
}

/// The spiral classifier as a [`Task`] — everything the generic engine
/// needs to train, evaluate and checkpoint it.
impl Task for MlpConfig {
    type Model = MlpModel;
    type Gen = SpiralGen;
    type Batch = SpiralBatch;

    const NAME: &'static str = "mlp";
    const EVAL_STREAM: u64 = SPIRAL_EVAL_STREAM;

    fn seed(&self) -> u64 {
        self.seed
    }

    fn fmt(&self) -> Format {
        self.fmt
    }

    fn backend(&self) -> Backend {
        self.backend
    }

    fn intra_threads(&self) -> usize {
        self.intra_threads
    }

    fn config_fingerprint(&self) -> String {
        format!(
            "seed={} classes={} hidden={} batch={} turns={} noise={}",
            self.seed, self.classes, self.hidden, self.batch, self.turns, self.noise
        )
    }

    fn num_tensors(&self) -> usize {
        MlpModel::num_tensors(self)
    }

    fn tensor_class(&self, _i: usize) -> TensorClass {
        TensorClass::Dense
    }

    fn init_model(&self) -> MlpModel {
        MlpModel::init(self)
    }

    fn make_gen(&self) -> SpiralGen {
        SpiralGen::new(self)
    }

    fn fork_gen(gen: &SpiralGen, stream: u64) -> SpiralGen {
        gen.fork(stream)
    }

    fn next_batch(gen: &mut SpiralGen) -> SpiralBatch {
        gen.next_batch()
    }

    fn forward_into(model: &MlpModel, t: &mut Tape, batch: &SpiralBatch) -> (Var, Vec<Var>) {
        model.forward_into(t, batch)
    }

    fn param_tensors(model: &MlpModel) -> Vec<&Tensor> {
        model.param_tensors()
    }

    fn param_tensors_mut(model: &mut MlpModel) -> Vec<&mut Tensor> {
        model.param_tensors_mut()
    }

    /// Mean loss and top-1 accuracy over `n` fresh batches.  `n == 0` is
    /// defined as zero loss / chance accuracy.
    fn eval(model: &MlpModel, gen: &mut SpiralGen, n: usize, policy: QPolicy) -> EvalMetrics {
        if n == 0 {
            return EvalMetrics {
                loss: 0.0,
                metric: 1.0 / model.cfg.classes.max(1) as f32,
                metric_name: "acc",
            };
        }
        let mut plan: Option<crate::qsim::infer::MlpPlan> = None;
        let mut loss_acc = 0f64;
        let mut correct = 0u64;
        let mut total = 0u64;
        for _ in 0..n {
            let batch = gen.next_batch();
            let p = plan.get_or_insert_with(|| {
                crate::qsim::infer::MlpPlan::compile(model, &batch, policy)
            });
            let (loss, scores) = p.score(&batch);
            loss_acc += loss as f64;
            for (r, &label) in batch.y.iter().enumerate() {
                let mut best = 0usize;
                for c in 1..scores.cols {
                    if scores.at(r, c) > scores.at(r, best) {
                        best = c;
                    }
                }
                if best == label {
                    correct += 1;
                }
                total += 1;
            }
        }
        EvalMetrics {
            loss: (loss_acc / n as f64) as f32,
            metric: correct as f32 / total.max(1) as f32,
            metric_name: "acc",
        }
    }
}

/// The spiral-MLP trainer — an instantiation of the generic engine.
pub type MlpTrainer = Trainer<MlpConfig>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Mode;
    use crate::qsim::train::StepTelemetry;

    #[test]
    fn spiral_gen_is_deterministic_and_forkable() {
        let cfg = MlpConfig { seed: 5, ..Default::default() };
        let mut a = SpiralGen::new(&cfg);
        let mut b = SpiralGen::new(&cfg);
        let ba = a.next_batch();
        let bb = b.next_batch();
        assert_eq!(ba.y, bb.y);
        for (x, y) in ba.x.data.iter().zip(&bb.x.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(ba.y.iter().all(|&k| k < cfg.classes));
        // points live on the unit-ish disc
        for r in 0..cfg.batch {
            let (x, y) = (ba.x.at(r, 0), ba.x.at(r, 1));
            assert!((x * x + y * y).sqrt() < 1.2, "({x}, {y})");
        }
        // a fork shares the task but draws different samples
        let mut e = a.fork(0x1234);
        let be = e.next_batch();
        assert_ne!(
            be.x.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            ba.x.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fp32_training_learns_the_spiral() {
        let cfg = MlpConfig { seed: 3, ..Default::default() };
        let mut tr = MlpTrainer::new(cfg, Mode::Fp32);
        let first: f32 = (0..10).map(|_| tr.step(0.3).loss).sum::<f32>() / 10.0;
        for _ in 0..400 {
            tr.step(0.3);
        }
        let last: f32 = (0..10).map(|_| tr.step(0.3).loss).sum::<f32>() / 10.0;
        assert!(last < first, "first={first} last={last}");
        let m = tr.eval(8);
        assert_eq!(m.metric_name, "acc");
        // clearly better than the 1/3 chance level on held-out draws (a
        // numpy port of this exact task reaches ≈0.98+ under this budget)
        assert!(m.metric > 0.7, "held-out accuracy {} — did not learn", m.metric);
    }

    /// The generic-engine determinism contract extends to the new app:
    /// fast and reference backends bit-identical over a training run.
    #[test]
    fn sr16_forty_steps_bit_identical_across_backends() {
        let mk = |backend| {
            let cfg = MlpConfig { seed: 11, backend, ..Default::default() };
            MlpTrainer::new(cfg, Mode::Sr16)
        };
        let mut fast = mk(Backend::Fast);
        let mut reference = mk(Backend::Reference);
        for step in 0..40 {
            let a = fast.step(0.1);
            let b = reference.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
            assert_eq!(a.mlp, b.mlp, "update stats diverged at step {step}");
        }
        for (pi, (wa, wb)) in fast
            .model
            .param_tensors_mut()
            .into_iter()
            .zip(reference.model.param_tensors_mut())
            .enumerate()
        {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "param {pi} elem {ei}");
            }
        }
    }

    /// Bit-identical sr16 training at 1 vs 4 intra-threads, sized so the
    /// matmul fan-out engages.
    #[test]
    fn sr16_training_bit_identical_across_thread_counts() {
        let mk = |intra_threads| {
            let cfg = MlpConfig {
                seed: 17,
                hidden: 96,
                batch: 64,
                intra_threads,
                ..Default::default()
            };
            MlpTrainer::new(cfg, Mode::Sr16)
        };
        let mut base = mk(1);
        let base_tel: Vec<StepTelemetry> = (0..15).map(|_| base.step(0.1)).collect();
        let mut tr = mk(4);
        assert_eq!(tr.intra_threads(), 4);
        for (step, want) in base_tel.iter().enumerate() {
            let got = tr.step(0.1);
            assert_eq!(got.loss.to_bits(), want.loss.to_bits(), "loss diverged at step {step}");
            assert_eq!(got.mlp, want.mlp, "stats diverged at step {step}");
        }
        for (pi, (wa, wb)) in base
            .model
            .param_tensors_mut()
            .into_iter()
            .zip(tr.model.param_tensors_mut())
            .enumerate()
        {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "param {pi} elem {ei}");
            }
        }
    }

    #[test]
    fn telemetry_is_all_dense() {
        let cfg = MlpConfig { seed: 7, ..Default::default() };
        let mut tr = MlpTrainer::new(cfg, Mode::Standard16);
        let tel = tr.step(0.1);
        assert_eq!(tel.embed.nonzero, 0, "an MLP has no embedding class");
        assert!(tel.mlp.nonzero > 0);
        assert_eq!(tel.total().nonzero, tel.mlp.nonzero);
    }
}
