//! `qsim::fault` — deterministic chaos injection for the sharded trainer.
//!
//! Every fault is decided by a pure function of `(chaos_seed, step, shard)`
//! through the same counter-keyed [`DitherKey`] machinery the SR dither
//! uses, so a chaos schedule is exactly reproducible from its spec string:
//! `repro qsim-parity --shards 4 --chaos heavy` injects the identical
//! crashes, stalls and corruptions on every run and every machine.  A
//! schedule can also pin explicit events (`crash@3.1` = crash shard 1 when
//! it is asked for step 3's gradients).
//!
//! Each `(step, shard)` cell hosts at most one event, and events are
//! **fire-once**: a shard that crashes at step 3 is respawned from the
//! coordinator's snapshot and asked for step 3 again — the retry must
//! compute, not crash forever, so the plan records consumption.  That
//! consumption is the only mutable state; which event a cell hosts never
//! depends on timing.
//!
//! The injected faults (and who injects them):
//! * [`ChaosKind::Crash`] — the worker thread exits on receipt of a step
//!   request (recovery: respawn from snapshot + data-stream fast-forward);
//! * [`ChaosKind::Stall`] — the worker sleeps `stall_ms` before computing
//!   (recovery: bounded wait + straggler accounting, retransmit request);
//! * [`ChaosKind::DropGrad`] — the worker computes but never sends its
//!   gradient message (recovery: timeout + retransmit of the cached frame);
//! * [`ChaosKind::CorruptGrad`] — a bit of the gradient frame is flipped on
//!   the wire *after* the CRC is computed (recovery: receiver CRC reject +
//!   retransmit);
//! * [`ChaosKind::DropUpdate`] — the coordinator's update broadcast to one
//!   shard is dropped, silently desynchronising the replica (recovery: the
//!   param digest carried by the replica's next gradient message exposes
//!   the drift; snapshot re-sync + recompute).

use std::collections::HashSet;
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::util::rng::DitherKey;

/// Stream tag separating chaos draws from every other keyed consumer.
pub const CHAOS_STREAM: u64 = 0xFA_07;

/// The failure injected at one `(step, shard)` cell.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosKind {
    Crash,
    Stall,
    DropGrad,
    CorruptGrad,
    DropUpdate,
}

impl ChaosKind {
    fn parse(s: &str) -> Result<ChaosKind> {
        Ok(match s {
            "crash" => ChaosKind::Crash,
            "stall" => ChaosKind::Stall,
            "drop" => ChaosKind::DropGrad,
            "corrupt" => ChaosKind::CorruptGrad,
            "drop-update" => ChaosKind::DropUpdate,
            other => bail!(
                "unknown chaos kind {other:?} (expected crash, stall, drop, corrupt \
                 or drop-update)"
            ),
        })
    }
}

/// One concrete injected event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChaosEvent {
    pub kind: ChaosKind,
    /// Sleep duration for [`ChaosKind::Stall`] (ignored by other kinds).
    pub stall_ms: u64,
}

/// A chaos schedule: per-kind probabilities (drawn per `(step, shard)`
/// cell) plus explicitly pinned events.  Parsed from the `--chaos` spec.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    pub seed: u64,
    pub crash_p: f64,
    pub stall_p: f64,
    pub drop_grad_p: f64,
    pub corrupt_grad_p: f64,
    pub drop_update_p: f64,
    /// Default stall duration for probabilistic stall events.
    pub stall_ms: u64,
    /// Pinned events: `(step, shard, event)`; these take precedence over
    /// the probabilistic draw for their cell.
    pub events: Vec<(u64, u32, ChaosEvent)>,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0,
            crash_p: 0.0,
            stall_p: 0.0,
            drop_grad_p: 0.0,
            corrupt_grad_p: 0.0,
            drop_update_p: 0.0,
            stall_ms: 40,
            events: Vec::new(),
        }
    }
}

impl ChaosConfig {
    /// Parse a `--chaos` spec.  Grammar (comma-separated, spaces ignored):
    ///
    /// * a preset: `none` | `light` | `heavy` (may appear first, then be
    ///   overridden by later items);
    /// * a rate: `crash=0.05`, `stall=0.1`, `drop=0.05`, `corrupt=0.1`,
    ///   `drop-update=0.05`, plus `seed=N` and `stall-ms=N`;
    /// * a pinned event: `kind@step.shard`, e.g. `crash@3.1`, with an
    ///   optional stall duration `stall@5.0:80` (80 ms).
    pub fn parse(spec: &str) -> Result<ChaosConfig> {
        let mut cfg = ChaosConfig::default();
        for (i, raw) in spec.split(',').enumerate() {
            let item = raw.trim();
            if item.is_empty() {
                continue;
            }
            match item {
                "none" | "off" => {
                    if i != 0 {
                        bail!("chaos preset {item:?} must be the first item in the spec");
                    }
                    continue;
                }
                "light" | "heavy" => {
                    if i != 0 {
                        bail!("chaos preset {item:?} must be the first item in the spec");
                    }
                    let scale = if item == "heavy" { 2.0 } else { 1.0 };
                    cfg.crash_p = 0.025 * scale;
                    cfg.stall_p = 0.04 * scale;
                    cfg.drop_grad_p = 0.025 * scale;
                    cfg.corrupt_grad_p = 0.04 * scale;
                    cfg.drop_update_p = 0.025 * scale;
                    continue;
                }
                _ => {}
            }
            if let Some((kind, at)) = item.split_once('@') {
                let (at, ms) = match at.split_once(':') {
                    Some((at, ms)) => (
                        at,
                        ms.parse::<u64>()
                            .with_context(|| format!("chaos event {item:?}: bad duration"))?,
                    ),
                    None => (at, cfg.stall_ms),
                };
                let (step, shard) = at
                    .split_once('.')
                    .with_context(|| format!("chaos event {item:?}: expected kind@step.shard"))?;
                let step = step
                    .parse::<u64>()
                    .with_context(|| format!("chaos event {item:?}: bad step"))?;
                let shard = shard
                    .parse::<u32>()
                    .with_context(|| format!("chaos event {item:?}: bad shard"))?;
                let kind = ChaosKind::parse(kind)?;
                cfg.events.push((step, shard, ChaosEvent { kind, stall_ms: ms }));
            } else if let Some((key, val)) = item.split_once('=') {
                let num = || {
                    val.parse::<f64>()
                        .with_context(|| format!("chaos rate {item:?}: bad number"))
                };
                match key.trim() {
                    "seed" => {
                        cfg.seed = val
                            .parse()
                            .with_context(|| format!("chaos seed {item:?}: bad integer"))?
                    }
                    "stall-ms" => {
                        cfg.stall_ms = val
                            .parse()
                            .with_context(|| format!("chaos stall-ms {item:?}: bad integer"))?
                    }
                    "crash" => cfg.crash_p = num()?,
                    "stall" => cfg.stall_p = num()?,
                    "drop" => cfg.drop_grad_p = num()?,
                    "corrupt" => cfg.corrupt_grad_p = num()?,
                    "drop-update" => cfg.drop_update_p = num()?,
                    other => bail!("unknown chaos parameter {other:?} in {spec:?}"),
                }
            } else {
                bail!("cannot parse chaos spec item {item:?} (in {spec:?})");
            }
        }
        let total = cfg.crash_p
            + cfg.stall_p
            + cfg.drop_grad_p
            + cfg.corrupt_grad_p
            + cfg.drop_update_p;
        if !(0.0..=1.0).contains(&total) || [
            cfg.crash_p,
            cfg.stall_p,
            cfg.drop_grad_p,
            cfg.corrupt_grad_p,
            cfg.drop_update_p,
        ]
        .iter()
        .any(|p| !(0.0..=1.0).contains(p))
        {
            bail!("chaos rates must be in [0, 1] and sum to at most 1 (got total {total})");
        }
        Ok(cfg)
    }

    /// True when this schedule can never inject anything.
    pub fn is_quiet(&self) -> bool {
        self.events.is_empty()
            && self.crash_p == 0.0
            && self.stall_p == 0.0
            && self.drop_grad_p == 0.0
            && self.corrupt_grad_p == 0.0
            && self.drop_update_p == 0.0
    }
}

/// A live chaos schedule: the pure event function plus the fire-once
/// consumption set.  Shared (`Arc`) between the coordinator and every
/// worker thread.
pub struct ChaosPlan {
    cfg: ChaosConfig,
    fired: Mutex<HashSet<(u64, u32)>>,
}

impl ChaosPlan {
    pub fn new(cfg: ChaosConfig) -> ChaosPlan {
        ChaosPlan { cfg, fired: Mutex::new(HashSet::new()) }
    }

    pub fn config(&self) -> &ChaosConfig {
        &self.cfg
    }

    /// The event hosted by cell `(step, shard)`, independent of whether it
    /// has fired: pinned events first, then the probabilistic draw.  Pure.
    pub fn peek(&self, step: u64, shard: u32) -> Option<ChaosEvent> {
        if let Some((_, _, ev)) =
            self.cfg.events.iter().find(|(s, w, _)| *s == step && *w == shard)
        {
            return Some(*ev);
        }
        let word = DitherKey::new(self.cfg.seed, CHAOS_STREAM, step, shard as u64).word(0);
        let u = word as f64 / (1u64 << 32) as f64;
        let mut acc = 0.0;
        for (p, kind) in [
            (self.cfg.crash_p, ChaosKind::Crash),
            (self.cfg.stall_p, ChaosKind::Stall),
            (self.cfg.drop_grad_p, ChaosKind::DropGrad),
            (self.cfg.corrupt_grad_p, ChaosKind::CorruptGrad),
            (self.cfg.drop_update_p, ChaosKind::DropUpdate),
        ] {
            acc += p;
            if u < acc {
                return Some(ChaosEvent { kind, stall_ms: self.cfg.stall_ms });
            }
        }
        None
    }

    /// Fire-once draw for the given site.  Worker sites consume every kind
    /// except [`ChaosKind::DropUpdate`] (which belongs to the coordinator's
    /// broadcast site); each cell fires at most once globally.
    fn take(&self, step: u64, shard: u32, want_update_site: bool) -> Option<ChaosEvent> {
        let ev = self.peek(step, shard)?;
        if (ev.kind == ChaosKind::DropUpdate) != want_update_site {
            return None;
        }
        let mut fired = self.fired.lock().expect("chaos fired-set poisoned");
        if !fired.insert((step, shard)) {
            return None; // already consumed: retries run clean
        }
        Some(ev)
    }

    /// Worker-side draw at step-request time (crash / stall / drop /
    /// corrupt).
    pub fn take_worker(&self, step: u64, shard: u32) -> Option<ChaosEvent> {
        self.take(step, shard, false)
    }

    /// Coordinator-side draw at update-broadcast time.
    pub fn take_drop_update(&self, step: u64, shard: u32) -> bool {
        self.take(step, shard, true).is_some()
    }

    /// Deterministically flip one payload bit of an encoded frame —
    /// *after* its CRC was computed, so the receiver's CRC check must
    /// reject it.  `header_len` protects the frame header so the flip
    /// always lands in the payload region.
    pub fn corrupt_frame(&self, frame: &mut [u8], header_len: usize, step: u64, shard: u32) {
        debug_assert!(frame.len() > header_len + 4, "frame too small to corrupt");
        let span = frame.len() - header_len - 4; // keep the trailing CRC intact too
        let word = DitherKey::new(self.cfg.seed, CHAOS_STREAM ^ 0xBAD, step, shard as u64).word(1);
        let byte = header_len + (word as usize % span);
        let bit = (word >> 13 & 7) as u8;
        frame[byte] ^= 1 << bit;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_presets_rates_and_events() {
        assert!(ChaosConfig::parse("none").unwrap().is_quiet());
        assert!(ChaosConfig::parse("").unwrap().is_quiet());
        let light = ChaosConfig::parse("light").unwrap();
        let heavy = ChaosConfig::parse("heavy").unwrap();
        assert!(heavy.crash_p > light.crash_p && !heavy.is_quiet());

        let cfg = ChaosConfig::parse("seed=9, crash=0.1, stall-ms=75, drop-update=0.05").unwrap();
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.crash_p, 0.1);
        assert_eq!(cfg.stall_ms, 75);
        assert_eq!(cfg.drop_update_p, 0.05);

        let cfg = ChaosConfig::parse("crash@3.1,stall@5.0:80,corrupt@2.2").unwrap();
        assert_eq!(cfg.events.len(), 3);
        assert_eq!(cfg.events[0], (3, 1, ChaosEvent { kind: ChaosKind::Crash, stall_ms: 40 }));
        assert_eq!(cfg.events[1], (5, 0, ChaosEvent { kind: ChaosKind::Stall, stall_ms: 80 }));

        let cfg = ChaosConfig::parse("heavy,seed=3").unwrap();
        assert_eq!(cfg.seed, 3);
        assert!(cfg.crash_p > 0.0, "preset rates survive the override");
    }

    #[test]
    fn parse_rejects_garbage() {
        for bad in [
            "explode=0.1",
            "crash=oops",
            "crash@x.y",
            "crash@3",
            "sideways",
            "crash=0.9,stall=0.9",
            "drop=1.5",
            "crash=0.1,heavy",
        ] {
            assert!(ChaosConfig::parse(bad).is_err(), "spec {bad:?} should not parse");
        }
    }

    #[test]
    fn schedule_is_deterministic_and_fire_once() {
        let cfg = ChaosConfig::parse("seed=4,crash=0.2,stall=0.2,corrupt=0.2").unwrap();
        let a = ChaosPlan::new(cfg.clone());
        let b = ChaosPlan::new(cfg);
        let mut hosted = 0;
        for step in 0..64u64 {
            for shard in 0..4u32 {
                assert_eq!(a.peek(step, shard), b.peek(step, shard), "cell ({step},{shard})");
                if a.peek(step, shard).is_some() {
                    hosted += 1;
                }
            }
        }
        // 256 cells at total rate 0.6: the draw must actually fire
        assert!(hosted > 64, "only {hosted} cells host events at rate 0.6");

        // fire-once: the first consuming site gets the event, retries don't
        let cfg = ChaosConfig::parse("crash@2.1,drop-update@2.0").unwrap();
        let plan = ChaosPlan::new(cfg);
        assert!(plan.take_worker(2, 1).is_some());
        assert!(plan.take_worker(2, 1).is_none(), "respawned shard must not re-crash");
        // a worker-site draw must not consume an update-site event
        assert!(plan.take_worker(2, 0).is_none());
        assert!(plan.take_drop_update(2, 0));
        assert!(!plan.take_drop_update(2, 0));
    }

    #[test]
    fn corrupt_frame_flips_exactly_one_payload_bit() {
        let plan = ChaosPlan::new(ChaosConfig::default());
        let base = vec![0u8; 64];
        let mut frame = base.clone();
        plan.corrupt_frame(&mut frame, 16, 7, 2);
        let flipped: Vec<usize> = (0..base.len()).filter(|&i| frame[i] != base[i]).collect();
        assert_eq!(flipped.len(), 1);
        assert!(flipped[0] >= 16 && flipped[0] < 60, "flip must land in the payload");
        assert_eq!((frame[flipped[0]] ^ base[flipped[0]]).count_ones(), 1);
    }
}
