//! `qsim::train` — the generic training engine over native quantised apps.
//!
//! The paper's claim is *cross-application*: SR/Kahan weight updates close
//! the 16-bit gap on seven diverse workloads (Zamirai et al. 2020; Kalamkar
//! et al. 2019 make the same point for bf16 generally).  Before this module
//! every native app re-implemented its own trainer loop by hand (and the
//! copies drifted: DLRM returned `StepTelemetry`, gpt a bare tuple; only
//! DLRM had per-tensor mixed modes or weight-byte accounting).  Now an app
//! is a [`Task`] — config → model, a forkable batch generator, a
//! graph-building `forward_into`, per-app eval — and `Trainer<T>` supplies
//! everything else once:
//!
//! * the per-tensor optimizer bank keyed by counter-dither `tensor_id`
//!   (uniform via [`Trainer::new`] or per-tensor via [`Trainer::new_mixed`]
//!   — Figure-5/9-style placements for *every* app, not just DLRM);
//! * the intra-step fork-join [`Pool`] and arena [`Tape`] (bit-identical
//!   results at every `--intra-threads` setting and on
//!   [`Backend::Reference`]);
//! * the dedicated held-out eval generator forked from the seed, so eval
//!   cadence can never perturb a training trajectory;
//! * unified [`StepTelemetry`] / [`EvalMetrics`];
//! * **native checkpoint save/resume** in the `BF16CKP2` format that
//!   previously only the PJRT coordinator path supported.  Because all
//!   native RNG is counter-keyed or stream-seeded, a resumed run is
//!   **bit-identical** to an uninterrupted one (tests pin this at 1 and 4
//!   intra-threads).
//!
//! The construction and step order exactly mirror the former hand-rolled
//! `DlrmTrainer`/`GptTrainer`, so existing trajectories are bit-identical
//! across the refactor (the `repro qsim-parity` digests pin this).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::hwcost;
use crate::precision::{Format, Mode, FP32};
use crate::util::ckpt;

use super::optim::{Sgd, SgdState, UpdateStats};
use super::pool::Pool;
use super::shard::{scale_grads, tree_reduce};
use super::tape::{QPolicy, Tape, Var};
use super::tensor::{Storage, Tensor};
use super::Backend;

/// True when a tensor trained under `mode` can live natively as packed
/// 16-bit words: every optimizer write lands on a bf16-grid format
/// (`exp_bits == 8`, `mant_bits <= 7` — bf16 and its shorter-mantissa
/// truncations), so the top-16-bit representation is lossless.
/// Exact-update modes (fp32 and mixed16 master weights) leave the grid
/// between rounds and must stay f32.
pub fn native16_storage(mode: Mode, fmt: Format) -> bool {
    !mode.exact_update() && fmt.exp_bits == 8 && fmt.mant_bits <= 7
}

/// Telemetry class of one parameter tensor (Figure 9 separates embedding
/// tables from dense/MLP layers; apps without embeddings are all-dense).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorClass {
    /// Embedding tables (sparse row updates; the paper's most
    /// cancellation-prone layer family).
    Embed,
    /// Everything else: dense weights, biases, attention projections.
    Dense,
}

/// Per-step per-layer-class telemetry (Figure 9's series), unified across
/// apps — DLRM used to return this while gpt returned a bare tuple.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTelemetry {
    pub loss: f32,
    /// Update stats over the [`TensorClass::Embed`] tensors.
    pub embed: UpdateStats,
    /// Update stats over the [`TensorClass::Dense`] tensors.
    pub mlp: UpdateStats,
}

impl StepTelemetry {
    /// Merged stats over every parameter tensor.
    pub fn total(&self) -> UpdateStats {
        let mut t = self.embed;
        t.merge(self.mlp);
        t
    }
}

/// Unified eval result: mean loss over the eval batches plus the app's
/// paper-convention metric (AUC for CTR, perplexity for LMs, accuracy for
/// classifiers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EvalMetrics {
    pub loss: f32,
    pub metric: f32,
    pub metric_name: &'static str,
}

/// One native application: the implementing type *is* the app config
/// (`DlrmConfig`, `GptConfig`, `MlpConfig`), and the trait maps it onto a
/// model, a data stream and an eval procedure.  Everything else — optimizer
/// bank, worker pool, tape arena, eval fork, telemetry, checkpointing — is
/// supplied by [`Trainer`].
///
/// ## Contracts
///
/// * `forward_into` must register parameter tensors on the tape **in the
///   same order** `param_tensors`/`param_tensors_mut` walk them: that
///   shared order maps each tensor to its optimizer slot and counter-dither
///   `tensor_id`, so it is part of the reproducibility contract.
/// * `make_gen` must be a pure function of the config (seeded), and
///   `fork_gen` must share the generator's ground-truth model while drawing
///   from an independent `(seed, stream)` pair — the trainer's eval stream
///   and checkpoint fast-forward both rely on it.
pub trait Task {
    type Model;
    type Gen;
    type Batch;

    /// Short app id, recorded in checkpoint headers ("dlrm", "gpt-nano",
    /// "mlp") — resuming a checkpoint into a different app fails loudly.
    const NAME: &'static str;
    /// Stream tag for the held-out eval generator fork (disjoint from the
    /// training stream, unique per app).
    const EVAL_STREAM: u64;

    // -- config accessors (the Task is the app config) ----------------------
    fn seed(&self) -> u64;
    fn fmt(&self) -> Format;
    fn backend(&self) -> Backend;
    fn intra_threads(&self) -> usize;
    /// One-line fingerprint of every config field that shapes the model or
    /// the data stream (seed, sizes, task parameters) — but **not**
    /// execution knobs (backend, intra-threads), which may legitimately
    /// differ across a resume because results are bit-identical across
    /// them.  Recorded in checkpoints and validated on load, so resuming
    /// into a differently-configured trainer (same tensor shapes, different
    /// seed or data distribution) fails loudly instead of silently
    /// producing a trajectory that continues nothing.
    fn config_fingerprint(&self) -> String;
    /// Number of parameter tensors the model registers.
    fn num_tensors(&self) -> usize;
    /// Telemetry class of parameter tensor `i` (registration order).
    fn tensor_class(&self, i: usize) -> TensorClass;

    // -- model + data -------------------------------------------------------
    fn init_model(&self) -> Self::Model;
    fn make_gen(&self) -> Self::Gen;
    fn fork_gen(gen: &Self::Gen, stream: u64) -> Self::Gen;
    fn next_batch(gen: &mut Self::Gen) -> Self::Batch;

    /// Fast-forward the generator past `n` batches (checkpoint resume).
    /// The default draws and discards; override if the app has a cheaper
    /// exact skip.
    fn skip_batches(gen: &mut Self::Gen, n: u64) {
        for _ in 0..n {
            let _ = Self::next_batch(gen);
        }
    }

    // -- graph + parameters -------------------------------------------------
    /// Build the training graph for one batch into the caller's tape;
    /// returns the loss and the registered parameter [`Var`]s in walk order.
    fn forward_into(model: &Self::Model, t: &mut Tape, batch: &Self::Batch) -> (Var, Vec<Var>);
    /// Parameter tensors in registration order (checkpoint save, byte
    /// accounting).
    fn param_tensors(model: &Self::Model) -> Vec<&Tensor>;
    /// Mutable walk in the same order (optimizer updates, checkpoint load).
    fn param_tensors_mut(model: &mut Self::Model) -> Vec<&mut Tensor>;

    // -- eval ---------------------------------------------------------------
    /// Evaluate over `n` fresh batches from `gen` (the trainer hands in its
    /// dedicated eval fork).  `n == 0` must be defined (no data ⇒ zero loss,
    /// chance metric), never 0/0 NaN.
    fn eval(model: &Self::Model, gen: &mut Self::Gen, n: usize, policy: QPolicy) -> EvalMetrics;
}

/// The generic native trainer: one implementation of the training loop,
/// optimizer bank, eval fork, telemetry and checkpointing for every
/// [`Task`].
pub struct Trainer<T: Task> {
    pub task: T,
    pub model: T::Model,
    /// Per-tensor precision modes, in parameter walk order.
    modes: Vec<Mode>,
    opts: Vec<Sgd>,
    states: Vec<SgdState>,
    gen: T::Gen,
    /// Dedicated eval stream forked from the seed (shared ground truth,
    /// disjoint draws): evaluation never touches `gen`, so the training
    /// trajectory is invariant to eval cadence.
    eval_gen: T::Gen,
    policy: QPolicy,
    /// Retained across steps (pooled backends): node + gradient storage is
    /// recycled via `Tape::reset` instead of reallocated per step.
    tape: Tape,
    /// Shared intra-step worker pool (spawned once, here; the tape and
    /// every optimizer hold clones of this handle).
    pool: Arc<Pool>,
    steps_done: u64,
    /// Microbatches per optimizer step (gradient accumulation).  1 keeps
    /// the original single-batch step byte-for-byte; >1 draws this many
    /// batches per step, combines their gradients with the fixed pairwise
    /// reduction tree of [`tree_reduce`], scales by `1/M`, and applies one
    /// keyed-SR update.  Must be a power of two: the fixed tree topology is
    /// what makes an `N`-shard data-parallel run (shard = an aligned block
    /// of microbatches = a complete subtree) bit-identical to this
    /// single-process trainer for every power-of-two `N <= M`.
    grad_accum: usize,
}

impl<T: Task> Trainer<T> {
    /// All parameter tensors share one precision mode.
    pub fn new(task: T, mode: Mode) -> Self {
        let n = task.num_tensors();
        Self::new_mixed(task, vec![mode; n])
    }

    /// Per-tensor precision modes (Figure 5's incremental SR→Kahan sweep,
    /// Figure-9-style placements) — available to every app, not just DLRM.
    /// `modes` ordering matches the parameter registration order of the
    /// task's `forward_into`.
    pub fn new_mixed(task: T, modes: Vec<Mode>) -> Self {
        assert_eq!(modes.len(), task.num_tensors(), "one mode per parameter tensor");
        let backend = task.backend();
        let pool = Arc::new(Pool::new(if backend.pooled() { task.intra_threads() } else { 1 }));
        let mut model = task.init_model();
        let fmt = task.fmt();
        let seed = task.seed();
        let opts: Vec<Sgd> = modes
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                Sgd::new(m, fmt, 0.0, 0.0, seed)
                    .with_tensor_id(i as u64)
                    .with_backend(backend)
                    .with_pool(Arc::clone(&pool))
            })
            .collect();
        let mut states: Vec<SgdState> = T::param_tensors_mut(&mut model)
            .iter()
            .zip(&opts)
            .map(|(t, o)| o.init_state(t))
            .collect();
        // Native 16-bit weight storage (the paper's 2× memory claim,
        // *measured*): when a tensor's mode rounds every write onto a
        // bf16-grid format, its weight and Kahan buffers live as packed
        // 16-bit words.  Lossless — init is quantised onto the format and
        // the optimizer rounds on write — so trajectories, parity digests
        // and checkpoints are bit-identical to f32 storage.
        for ((t, st), &m) in
            T::param_tensors_mut(&mut model).into_iter().zip(states.iter_mut()).zip(&modes)
        {
            if native16_storage(m, fmt) {
                t.narrow_to_bf16();
                if let Some(k) = st.kahan.as_mut() {
                    k.narrow_to_bf16();
                }
            }
        }
        // fwd/bwd compute rounds unless every tensor trains in fp32
        let policy = if modes.iter().all(|&m| m == Mode::Fp32) {
            QPolicy::with_backend(FP32, backend)
        } else {
            QPolicy::with_backend(fmt, backend)
        };
        let gen = task.make_gen();
        let eval_gen = T::fork_gen(&gen, T::EVAL_STREAM);
        let tape = Tape::with_pool(policy, Arc::clone(&pool));
        Self {
            task,
            model,
            modes,
            opts,
            states,
            gen,
            eval_gen,
            policy,
            tape,
            pool,
            steps_done: 0,
            grad_accum: 1,
        }
    }

    /// Train with `m` microbatches per optimizer step (gradient
    /// accumulation over the fixed reduction tree).  Must be called before
    /// any step runs, and `m` must be a power of two — see the field docs.
    pub fn with_grad_accum(mut self, m: usize) -> Self {
        assert!(
            m >= 1 && m.is_power_of_two(),
            "grad_accum must be a power of two (fixed reduction-tree topology), got {m}"
        );
        assert_eq!(self.steps_done, 0, "set grad_accum before training, not mid-run");
        self.grad_accum = m;
        self
    }

    /// Microbatches per optimizer step (1 = plain single-batch training).
    pub fn grad_accum(&self) -> usize {
        self.grad_accum
    }

    /// Effective intra-step worker count (1 unless configured otherwise).
    pub fn intra_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Steps this trainer has executed (including resumed-from steps).
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    /// Per-tensor precision modes, in parameter walk order.
    pub fn modes(&self) -> &[Mode] {
        &self.modes
    }

    /// The forward/backward rounding policy.
    pub fn policy(&self) -> QPolicy {
        self.policy
    }

    /// Every optimizer's `(stream, tensor_id)` dither coordinate, in
    /// parameter walk order — the input to the static collision lint
    /// (`verify::lint_dither_coords`).
    pub fn dither_coords(&self) -> Vec<(u64, u64)> {
        self.opts.iter().map(|o| o.dither_coord()).collect()
    }

    /// One SGD step over a fresh synthetic batch.
    ///
    /// Pooled backends (`Fast`, `Simd`): the retained tape is `reset`
    /// (node and gradient buffers recycled) and gradients are fed to the
    /// optimizer by reference, so steady-state tensor traffic is
    /// allocation-free.  `Reference` backend: a fresh tape per step,
    /// reproducing the pre-optimization allocation pattern.
    pub fn step(&mut self, lr: f32) -> StepTelemetry {
        if self.grad_accum > 1 {
            return self.step_accum(lr);
        }
        let batch = T::next_batch(&mut self.gen);
        if self.policy.backend.pooled() {
            self.tape.reset();
        } else {
            self.tape = Tape::new(self.policy);
        }
        let (loss, param_vars) = T::forward_into(&self.model, &mut self.tape, &batch);
        self.tape.backward(loss);
        let loss_val = self.tape.value(loss).item();
        let mut tel = StepTelemetry { loss: loss_val, ..Default::default() };
        let tape = &self.tape;
        let params = T::param_tensors_mut(&mut self.model);
        for (i, (w, var)) in params.into_iter().zip(&param_vars).enumerate() {
            let zero_g;
            let g = match tape.grad(*var) {
                Some(g) => g,
                // a parameter off the loss path still takes its (no-op)
                // optimizer update, so its step counter — the dither key's
                // step coordinate — stays in lockstep with the others
                None => {
                    zero_g = Tensor::zeros(w.rows, w.cols);
                    &zero_g
                }
            };
            let stats = self.opts[i].step(w, &mut self.states[i], g, lr);
            match self.task.tensor_class(i) {
                TensorClass::Embed => tel.embed.merge(stats),
                TensorClass::Dense => tel.mlp.merge(stats),
            }
        }
        self.steps_done += 1;
        tel
    }

    /// One optimizer step over `grad_accum` microbatches: the reference
    /// semantics that `qsim::shard`'s data-parallel engine must reproduce
    /// bit-for-bit at every shard count.
    fn step_accum(&mut self, lr: f32) -> StepTelemetry {
        let m = self.grad_accum;
        let mut parts = Vec::with_capacity(m);
        for _ in 0..m {
            let batch = T::next_batch(&mut self.gen);
            parts.push(self.grad_batch(&batch));
        }
        let (loss_sum, mut grads) = tree_reduce(parts);
        let inv = 1.0 / m as f32;
        scale_grads(&mut grads, inv);
        self.apply_update(loss_sum * inv, grads, lr)
    }

    /// Forward + backward over one caller-supplied batch, returning the
    /// loss and per-parameter flat gradients (f32 bit patterns, walk
    /// order).  Because compute-path rounding is deterministic
    /// round-to-nearest — only the optimizer update consumes keyed dither —
    /// this is a pure function of (parameters, batch), which is what lets
    /// shards compute gradients independently yet bit-identically.  Does
    /// not advance the step counter.
    pub fn grad_batch(&mut self, batch: &T::Batch) -> (f32, Vec<Vec<f32>>) {
        if self.policy.backend.pooled() {
            self.tape.reset();
        } else {
            self.tape = Tape::new(self.policy);
        }
        let (loss, param_vars) = T::forward_into(&self.model, &mut self.tape, batch);
        self.tape.backward(loss);
        let loss_val = self.tape.value(loss).item();
        let tape = &self.tape;
        let grads = T::param_tensors(&self.model)
            .iter()
            .zip(&param_vars)
            .map(|(w, var)| match tape.grad(*var) {
                Some(g) => g.data.clone(),
                None => vec![0.0; w.len()],
            })
            .collect();
        (loss_val, grads)
    }

    /// Apply one optimizer update from pre-reduced flat gradients (walk
    /// order; already scaled by the caller).  Advances the step counter —
    /// the SR dither step coordinate — exactly once, which is how one
    /// coordinator update and N replica updates stay bit-identical.
    /// `loss` is recorded in the returned telemetry verbatim.
    pub fn apply_update(&mut self, loss: f32, grads: Vec<Vec<f32>>, lr: f32) -> StepTelemetry {
        assert_eq!(grads.len(), self.modes.len(), "one gradient per parameter tensor");
        let mut tel = StepTelemetry { loss, ..Default::default() };
        let params = T::param_tensors_mut(&mut self.model);
        for (i, (w, g)) in params.into_iter().zip(grads).enumerate() {
            assert_eq!(g.len(), w.len(), "gradient {i} length mismatch");
            let gt = Tensor::from_vec(w.rows, w.cols, g);
            let stats = self.opts[i].step(w, &mut self.states[i], &gt, lr);
            match self.task.tensor_class(i) {
                TensorClass::Embed => tel.embed.merge(stats),
                TensorClass::Dense => tel.mlp.merge(stats),
            }
        }
        self.steps_done += 1;
        tel
    }

    /// Draw the next training batch (shard workers pull their microbatch
    /// block through this).
    pub fn draw_batch(&mut self) -> T::Batch {
        T::next_batch(&mut self.gen)
    }

    /// Fast-forward the training stream past `n` batches (shard workers
    /// skip the microbatches other shards own).
    pub fn skip_batches(&mut self, n: u64) {
        T::skip_batches(&mut self.gen, n);
    }

    /// FNV-1a digest over the exact bit patterns of every parameter, in
    /// walk order.  Shard replicas include this in every gradient message;
    /// a mismatch against the coordinator's own digest means the replica
    /// drifted (e.g. a lost update broadcast) and triggers a snapshot
    /// re-sync instead of a silent divergence.
    pub fn param_digest(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for t in T::param_tensors(&self.model) {
            for v in t.to_f32_vec() {
                h = (h ^ v.to_bits() as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }

    /// Evaluate over `n` fresh batches from the dedicated eval stream.
    /// Side-effect-free with respect to training: the training generator is
    /// never advanced.
    pub fn eval(&mut self, n: usize) -> EvalMetrics {
        T::eval(&self.model, &mut self.eval_gen, n, self.policy)
    }

    /// Weight-memory bytes under the trainer's own per-tensor modes
    /// (generic [`hwcost`] accounting from the parameter walk — every app
    /// reports a memory plan, not just DLRM).
    pub fn weight_bytes(&self) -> u64 {
        self.weight_bytes_for(&self.modes)
    }

    /// Weight-memory bytes under hypothetical per-tensor modes (Figure 5's
    /// x-axis sweeps these without rebuilding trainers).
    pub fn weight_bytes_for(&self, modes: &[Mode]) -> u64 {
        T::param_tensors(&self.model)
            .iter()
            .zip(modes)
            .map(|(t, &m)| hwcost::tensor_weight_bytes(t.len() as u64, m))
            .sum()
    }

    /// *Measured* weight-memory bytes: what the trainer's parameter and
    /// optimizer-state buffers actually occupy right now, from
    /// [`Tensor::storage_bytes`] — 2 bytes/element for native 16-bit
    /// storage, 4 for f32.  Matches [`Trainer::weight_bytes`] for every
    /// narrowable mode; diverges for `mixed16`, whose f32 master weights
    /// measure 4 bytes/element while the [`hwcost`] *plan* charges 2 (the
    /// paper's mixed-precision hardware keeps the bf16 copy resident and
    /// materialises masters in the update unit).
    pub fn measured_weight_bytes(&self) -> u64 {
        let mut total = 0u64;
        for (t, st) in T::param_tensors(&self.model).iter().zip(&self.states) {
            total += t.storage_bytes();
            if let Some(m) = &st.momentum {
                total += m.storage_bytes();
            }
            if let Some(k) = &st.kahan {
                total += k.storage_bytes();
            }
        }
        total
    }

    // -- checkpointing -------------------------------------------------------

    /// Header name recorded in (and validated against) checkpoints.
    fn ckpt_name(&self) -> String {
        format!("qsim/{}", T::NAME)
    }

    /// Config fingerprint as recorded in checkpoints: the task fingerprint,
    /// plus the microbatch count when it differs from the default — `M`
    /// changes what a "step" means (M batches, 1/M-scaled tree-reduced
    /// gradients), so resuming across an accumulation mismatch must fail
    /// loudly.  Plain trainers keep the bare task fingerprint, so existing
    /// checkpoints stay loadable.  The shard count is deliberately *not*
    /// recorded: results are bit-identical across shard counts, so resuming
    /// at a different N is legitimate.
    fn ckpt_fingerprint(&self) -> String {
        if self.grad_accum == 1 {
            self.task.config_fingerprint()
        } else {
            format!("{}|accum={}", self.task.config_fingerprint(), self.grad_accum)
        }
    }

    /// Save all training state to a binary checkpoint (`BF16CKP2`, the
    /// same format family as the PJRT coordinator path).
    ///
    /// Layout after the magic: app name, storage format name, config
    /// fingerprint, the per-tensor mode list, the step counter, then per
    /// parameter tensor the weights plus optional momentum/Kahan state
    /// slices.  Everything
    /// needed for a bit-identical resume is either in the file or
    /// reconstructed from the (seeded) task config: the SR dither schedule
    /// is a pure function of `(seed, stream, step, tensor_id, element)`,
    /// and the training stream is fast-forwarded past the consumed batches
    /// on load.
    pub fn save_checkpoint(&self, path: impl AsRef<Path>) -> Result<()> {
        ckpt::write_atomic(path.as_ref(), &self.checkpoint_bytes())
            .with_context(|| format!("writing checkpoint {:?}", path.as_ref()))
    }

    /// The checkpoint image as bytes (CRC-32-footed `BF16CKP2`), without
    /// touching the filesystem — this is also the snapshot the sharded
    /// coordinator streams to a respawned or drifted shard replica.
    pub fn checkpoint_bytes(&self) -> Vec<u8> {
        let mut w = ckpt::Writer::new();
        w.str(&self.ckpt_name());
        w.str(self.task.fmt().name);
        w.str(&self.ckpt_fingerprint());
        w.u64(self.modes.len() as u64);
        for m in &self.modes {
            w.str(m.name());
        }
        w.u64(self.steps_done);
        let params = T::param_tensors(&self.model);
        w.u64(params.len() as u64);
        // Native 16-bit buffers are widened to f32 streams on save, so the
        // file is byte-identical to one written from f32 storage (the
        // values are on the bf16 grid either way) — `BF16CKP2` needs no
        // format bump and old checkpoints resume into narrow trainers.
        for (t, st) in params.iter().zip(&self.states) {
            match &t.store {
                Storage::F32 => w.f32s(&t.data),
                Storage::Bf16(_) => w.f32s(&t.to_f32_vec()),
            }
            let mom = st.momentum.as_ref().map(|m| m.to_f32_vec());
            w.opt_f32s(mom.as_deref());
            let kah = st.kahan.as_ref().map(|k| k.to_f32_vec());
            w.opt_f32s(kah.as_deref());
        }
        w.into_bytes()
    }

    /// Restore training state from a checkpoint written by
    /// [`Trainer::save_checkpoint`].
    ///
    /// Validates the app name, storage format, config fingerprint,
    /// per-tensor mode list and every tensor shape before touching any
    /// state — a checkpoint from a different app (or a
    /// differently-configured trainer, even one with identical tensor
    /// shapes) fails loudly.  Execution knobs (backend, intra-threads)
    /// are deliberately *not* validated: results are bit-identical across
    /// them, so resuming on different hardware settings is legitimate.
    /// After loading, optimizer step counters are repositioned and the
    /// training stream is fast-forwarded, so continuing the run is
    /// bit-identical to never having stopped.
    pub fn load_checkpoint(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let buf = std::fs::read(path.as_ref())
            .with_context(|| format!("reading checkpoint {:?}", path.as_ref()))?;
        self.load_checkpoint_bytes(&buf)
            .with_context(|| format!("checkpoint {:?}", path.as_ref()))
    }

    /// Restore from an in-memory checkpoint image (shard replicas apply
    /// coordinator snapshots through this).
    pub fn load_checkpoint_bytes(&mut self, buf: &[u8]) -> Result<()> {
        let mut r = ckpt::Reader::new(buf)?;
        self.load_checkpoint_reader(&mut r)
    }

    fn load_checkpoint_reader(&mut self, r: &mut ckpt::Reader<'_>) -> Result<()> {
        let name = r.str()?;
        let expected = self.ckpt_name();
        if name != expected {
            bail!(
                "checkpoint was saved from app {name:?} but this trainer runs {expected:?}; \
                 refusing to load mismatched state"
            );
        }
        let fmt = r.str()?;
        if fmt != self.task.fmt().name {
            bail!(
                "checkpoint was saved with storage format {fmt:?} but this trainer uses {:?}",
                self.task.fmt().name
            );
        }
        let fingerprint = r.str()?;
        let expected_fp = self.ckpt_fingerprint();
        if fingerprint != expected_fp {
            bail!(
                "checkpoint was saved from a differently-configured trainer \
                 (config {fingerprint:?}, this trainer {expected_fp:?}); a resume would \
                 silently continue neither run — refusing to load"
            );
        }
        let n_modes = r.u64()? as usize;
        if n_modes != self.modes.len() {
            bail!("checkpoint has {n_modes} tensor modes, this trainer has {}", self.modes.len());
        }
        for (i, m) in self.modes.iter().enumerate() {
            let got = r.str()?;
            if got != m.name() {
                bail!(
                    "checkpoint tensor {i} was trained in mode {got:?} but this trainer \
                     uses {:?}; refusing to load mismatched state",
                    m.name()
                );
            }
        }
        let steps = r.u64()?;
        let n = r.u64()? as usize;
        let expected_lens: Vec<usize> =
            T::param_tensors(&self.model).iter().map(|t| t.len()).collect();
        if n != expected_lens.len() {
            bail!("checkpoint has {n} tensors, model has {}", expected_lens.len());
        }
        // Phase 1: parse and validate the *entire* file before touching any
        // trainer state — a truncated or mismatched checkpoint must leave
        // the trainer exactly as it was, never half-overwritten.
        let mut loaded: Vec<(Vec<f32>, Option<Vec<f32>>, Option<Vec<f32>>)> =
            Vec::with_capacity(n);
        for (i, &len) in expected_lens.iter().enumerate() {
            let w = r.f32s()?;
            if w.len() != len {
                bail!("checkpoint tensor {i} has {} elements, model expects {len}", w.len());
            }
            let mom = r.opt_f32s()?;
            match (&self.states[i].momentum, &mom) {
                (Some(st), Some(v)) if v.len() == st.len() => {}
                (None, None) => {}
                _ => bail!("checkpoint momentum state mismatch for tensor {i}"),
            }
            let kah = r.opt_f32s()?;
            match (&self.states[i].kahan, &kah) {
                (Some(st), Some(v)) if v.len() == st.len() => {}
                (None, None) => {}
                _ => bail!("checkpoint kahan state mismatch for tensor {i}"),
            }
            loaded.push((w, mom, kah));
        }
        // every field consumed: trailing bytes mean corruption (or a newer
        // writer), not something to silently ignore
        r.expect_end()?;
        // Phase 2: apply — nothing below can fail (lengths were validated
        // above, and `set_from_f32` re-narrows native 16-bit buffers).
        for ((t, st), (w, mom, kah)) in T::param_tensors_mut(&mut self.model)
            .into_iter()
            .zip(self.states.iter_mut())
            .zip(loaded)
        {
            t.set_from_f32(&w);
            if let (Some(s), Some(v)) = (st.momentum.as_mut(), mom) {
                s.set_from_f32(&v);
            }
            if let (Some(s), Some(v)) = (st.kahan.as_mut(), kah) {
                s.set_from_f32(&v);
            }
        }
        self.steps_done = steps;
        // the only optimizer RNG state is the counter-keyed step index
        for o in &mut self.opts {
            o.set_step_idx(steps);
        }
        // Reposition the training stream: generators are sequential, so a
        // resumed run must consume the same prefix the original run did to
        // replay the remaining batches exactly (each step consumed
        // `grad_accum` batches).  The eval fork is rebuilt fresh (eval
        // draws never influence training).
        let mut gen = self.task.make_gen();
        T::skip_batches(&mut gen, steps.saturating_mul(self.grad_accum as u64));
        self.eval_gen = T::fork_gen(&gen, T::EVAL_STREAM);
        self.gen = gen;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qsim::dlrm::{DlrmConfig, DlrmTrainer};
    use crate::qsim::gpt::{GptConfig, GptTrainer};
    use crate::qsim::mlp::{MlpConfig, MlpTrainer};

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bf16_qsim_ckpt_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn assert_params_bit_identical<T: Task>(a: &mut Trainer<T>, b: &mut Trainer<T>, what: &str) {
        let pa = T::param_tensors_mut(&mut a.model);
        let pb = T::param_tensors_mut(&mut b.model);
        assert_eq!(pa.len(), pb.len());
        for (pi, (wa, wb)) in pa.into_iter().zip(pb).enumerate() {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            assert_eq!(da.len(), db.len(), "{what}: param {pi} shape");
            for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: param {pi} elem {ei}");
            }
        }
    }

    /// Tentpole acceptance: save at step k, resume into a fresh trainer,
    /// and the continuation is bit-identical to an uninterrupted run — at
    /// 1 and 4 intra-threads (counter-keyed dither makes this exact).
    /// `SrKahan16` exercises both the SR step keys and the Kahan state
    /// buffers through the checkpoint.
    #[test]
    fn dlrm_resume_is_bit_identical_to_uninterrupted_run() {
        for threads in [1usize, 4] {
            let mk = || {
                let cfg = DlrmConfig {
                    seed: 31,
                    // large enough that the parallel kernels engage at t=4
                    table_size: 600,
                    embed_dim: 16,
                    hidden: 64,
                    batch: 48,
                    intra_threads: threads,
                    ..Default::default()
                };
                DlrmTrainer::new(cfg, Mode::SrKahan16)
            };
            let path = tmp(&format!("dlrm_resume_t{threads}.ckpt"));

            let mut full = mk();
            let mut interrupted = mk();
            for _ in 0..10 {
                full.step(0.05);
                interrupted.step(0.05);
            }
            interrupted.save_checkpoint(&path).unwrap();
            // interleave an eval on the interrupted side: cadence must not
            // perturb anything that lands in the checkpoint
            interrupted.eval(2);

            let mut resumed = mk();
            resumed.load_checkpoint(&path).unwrap();
            assert_eq!(resumed.steps_done(), 10);
            for step in 0..15 {
                let a = full.step(0.05);
                let b = resumed.step(0.05);
                assert_eq!(
                    a.loss.to_bits(),
                    b.loss.to_bits(),
                    "loss diverged at post-resume step {step} (t={threads})"
                );
                assert_eq!(a.embed, b.embed, "embed stats, step {step}, t={threads}");
                assert_eq!(a.mlp, b.mlp, "mlp stats, step {step}, t={threads}");
            }
            assert_params_bit_identical(&mut full, &mut resumed, &format!("t={threads}"));
        }
    }

    /// The same resume guarantee for the third app (sr16: SR dither step
    /// keys must re-align after the counter reposition) — and the resume
    /// happens at a *different* intra-thread count, which the config
    /// fingerprint deliberately permits because results are bit-identical
    /// across execution knobs.
    #[test]
    fn mlp_resume_is_bit_identical_to_uninterrupted_run() {
        let mk = |intra_threads| {
            let cfg = MlpConfig { seed: 7, intra_threads, ..Default::default() };
            MlpTrainer::new(cfg, Mode::Sr16)
        };
        let path = tmp("mlp_resume.ckpt");
        let mut full = mk(1);
        let mut interrupted = mk(1);
        for _ in 0..12 {
            full.step(0.1);
            interrupted.step(0.1);
        }
        interrupted.save_checkpoint(&path).unwrap();
        let mut resumed = mk(2);
        resumed.load_checkpoint(&path).unwrap();
        for step in 0..12 {
            let a = full.step(0.1);
            let b = resumed.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
        }
        assert_params_bit_identical(&mut full, &mut resumed, "mlp resume");
        // and the eval fork is live after a resume
        let m = resumed.eval(2);
        assert!(m.loss.is_finite());
        assert_eq!(m.metric_name, "acc");
    }

    /// A checkpoint from one app must not load into another, even when
    /// nothing else would catch it — the header name check fires first.
    #[test]
    fn mismatched_app_checkpoint_fails_loudly() {
        let path = tmp("dlrm_for_gpt.ckpt");
        let mut dlrm = DlrmTrainer::new(DlrmConfig { seed: 1, ..Default::default() }, Mode::Sr16);
        dlrm.step(0.05);
        dlrm.save_checkpoint(&path).unwrap();

        let mut gpt = GptTrainer::new(GptConfig { seed: 1, ..Default::default() }, Mode::Sr16);
        let err = gpt.load_checkpoint(&path).unwrap_err().to_string();
        assert!(
            err.contains("qsim/dlrm") && err.contains("qsim/gpt-nano"),
            "error should name both apps: {err}"
        );
    }

    /// Same app, same tensor shapes, different seed: the config
    /// fingerprint must refuse — a resume would fast-forward a generator
    /// that never produced the checkpointed weights, silently continuing
    /// neither run.  Execution knobs are exempt (tested in the mlp resume
    /// test, which resumes at a different intra-thread count).
    #[test]
    fn mismatched_config_checkpoint_fails_loudly() {
        let path = tmp("mlp_seed1.ckpt");
        let mut a = MlpTrainer::new(MlpConfig { seed: 1, ..Default::default() }, Mode::Sr16);
        a.step(0.1);
        a.save_checkpoint(&path).unwrap();
        let mut b = MlpTrainer::new(MlpConfig { seed: 2, ..Default::default() }, Mode::Sr16);
        let err = b.load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("differently-configured"), "{err}");
    }

    /// Same-app, different per-tensor modes: refuse instead of silently
    /// producing a garbage trajectory.
    #[test]
    fn mismatched_mode_checkpoint_fails_loudly() {
        let path = tmp("mlp_sr16.ckpt");
        let mut a = MlpTrainer::new(MlpConfig { seed: 2, ..Default::default() }, Mode::Sr16);
        a.step(0.1);
        a.save_checkpoint(&path).unwrap();
        let mut b = MlpTrainer::new(MlpConfig { seed: 2, ..Default::default() }, Mode::Kahan16);
        let err = b.load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("mode"), "{err}");
    }

    /// A load that fails mid-file must leave the trainer untouched
    /// (phase-1 validation parses the whole file before phase-2 applies
    /// anything) — a half-overwritten trainer would train from garbage
    /// with no further error.
    #[test]
    fn failed_load_leaves_trainer_state_untouched() {
        let path = tmp("mlp_truncated.ckpt");
        let mut src = MlpTrainer::new(MlpConfig { seed: 4, ..Default::default() }, Mode::Sr16);
        for _ in 0..5 {
            src.step(0.1);
        }
        src.save_checkpoint(&path).unwrap();
        // header stays valid; the tensor section is truncated
        let buf = std::fs::read(&path).unwrap();
        std::fs::write(&path, &buf[..buf.len() - 12]).unwrap();

        let mk = || MlpTrainer::new(MlpConfig { seed: 4, ..Default::default() }, Mode::Sr16);
        let mut damaged = mk();
        let mut pristine = mk();
        assert!(damaged.load_checkpoint(&path).is_err());
        assert_eq!(damaged.steps_done(), 0, "step counter must be untouched");
        // the trainer still trains exactly like one that never saw the load
        for step in 0..5 {
            let a = damaged.step(0.1);
            let b = pristine.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
        }
        assert_params_bit_identical(&mut damaged, &mut pristine, "failed load");
    }

    #[test]
    fn corrupt_and_legacy_checkpoints_are_clear_errors() {
        let path = tmp("garbage.ckpt");
        std::fs::write(&path, b"BF16CKPT-old-v1-payload").unwrap();
        let mut tr = MlpTrainer::new(MlpConfig::default(), Mode::Sr16);
        let err = tr.load_checkpoint(&path).unwrap_err();
        assert!(format!("{err:#}").contains("legacy v1"), "{err:#}");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        let err = tr.load_checkpoint(&path).unwrap_err();
        assert!(format!("{err:#}").contains("not a bf16-train checkpoint"), "{err:#}");
    }

    /// Satellite: the generic weight-byte accounting matches the explicit
    /// per-mode formula the DLRM-only implementation used.
    #[test]
    fn generic_weight_bytes_matches_param_walk() {
        let cfg = DlrmConfig { seed: 3, ..Default::default() };
        let n = cfg.num_tables + 6;
        let modes: Vec<Mode> =
            (0..n).map(|i| if i < 2 { Mode::Kahan16 } else { Mode::Sr16 }).collect();
        let tr = DlrmTrainer::new_mixed(cfg, modes.clone());
        let expected: u64 = tr
            .model
            .param_tensors()
            .iter()
            .zip(&modes)
            .map(|(t, m)| t.len() as u64 * if m.kahan() { 4 } else { 2 })
            .sum();
        assert_eq!(tr.weight_bytes_for(&modes), expected);
        assert_eq!(tr.weight_bytes(), expected, "trainer's own modes");
        // gpt and mlp report memory plans too now: kahan16 stores 2 weight
        // + 2 compensation bytes per element, sr16 stores 2
        let gpt = GptTrainer::new(GptConfig::default(), Mode::Kahan16);
        let gpt_elems: u64 = gpt.model.param_tensors().iter().map(|t| t.len() as u64).sum();
        assert_eq!(gpt.weight_bytes(), 4 * gpt_elems);
        let mlp = MlpTrainer::new(MlpConfig::default(), Mode::Sr16);
        let mlp_elems: u64 = mlp.model.param_tensors().iter().map(|t| t.len() as u64).sum();
        assert_eq!(mlp.weight_bytes(), 2 * mlp_elems);
    }

    /// Eval goes through the dedicated fork: cadence cannot perturb the
    /// training trajectory of *any* task (the generic engine owns the fork).
    #[test]
    fn generic_eval_is_side_effect_free() {
        let mk = || MlpTrainer::new(MlpConfig { seed: 5, ..Default::default() }, Mode::Sr16);
        let mut with_eval = mk();
        let mut without = mk();
        for step in 0..20 {
            let a = with_eval.step(0.1);
            let b = without.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
            if (step + 1) % 5 == 0 {
                let m = with_eval.eval(2);
                assert!(m.loss.is_finite());
            }
        }
        assert_params_bit_identical(&mut with_eval, &mut without, "eval cadence");
    }

    /// Tentpole: native 16-bit weight storage is *transparent*.  A trainer
    /// whose buffers were force-widened back to f32 takes a bit-identical
    /// trajectory, and both sides write byte-identical `BF16CKP2` files
    /// (narrow buffers widen to f32 streams on save), so old checkpoints
    /// resume into narrow trainers and vice versa.
    #[test]
    fn native16_storage_is_transparent_and_checkpoint_byte_compatible() {
        let mk = || MlpTrainer::new(MlpConfig { seed: 11, ..Default::default() }, Mode::SrKahan16);
        let mut narrow = mk();
        for t in narrow.model.param_tensors() {
            assert!(t.is_native16(), "sr-kahan16 + bf16 params should narrow at init");
        }
        let mut wide = mk();
        for t in wide.model.param_tensors_mut() {
            t.widen_to_f32();
        }
        for st in &mut wide.states {
            if let Some(k) = st.kahan.as_mut() {
                k.widen_to_f32();
            }
        }
        assert_eq!(narrow.measured_weight_bytes() * 2, wide.measured_weight_bytes());
        for step in 0..8 {
            let a = narrow.step(0.1);
            let b = wide.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
        }
        assert_params_bit_identical(&mut narrow, &mut wide, "narrow vs wide");

        let pn = tmp("mlp_native16_narrow.ckpt");
        let pw = tmp("mlp_native16_wide.ckpt");
        narrow.save_checkpoint(&pn).unwrap();
        wide.save_checkpoint(&pw).unwrap();
        assert_eq!(
            std::fs::read(&pn).unwrap(),
            std::fs::read(&pw).unwrap(),
            "narrow storage must not change the checkpoint bytes"
        );
        // resume from the wide file: storage stays narrow, run continues
        let mut resumed = mk();
        resumed.load_checkpoint(&pw).unwrap();
        for t in resumed.model.param_tensors() {
            assert!(t.is_native16(), "load must preserve native 16-bit storage");
        }
        for step in 0..6 {
            let a = narrow.step(0.1);
            let b = resumed.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "post-resume step {step}");
        }
    }

    /// Satellite: the hwcost *plan* is now backed by *measured* allocation.
    /// For every narrowable mode the measured bytes equal the plan exactly;
    /// fp32 measures 4 bytes/element; mixed16 is the documented divergence
    /// (f32 masters measure 4 while the plan charges the resident bf16 copy
    /// at 2).  The 16-bit modes measure exactly half (standard/sr) or equal
    /// (kahan: 2+2) the fp32 footprint — the paper's memory thesis, on real
    /// buffers.
    #[test]
    fn measured_weight_bytes_match_hwcost_plan_per_mode() {
        let elems: u64 = MlpTrainer::new(MlpConfig::default(), Mode::Fp32)
            .model
            .param_tensors()
            .iter()
            .map(|t| t.len() as u64)
            .sum();
        for mode in Mode::ALL {
            let tr = MlpTrainer::new(MlpConfig::default(), mode);
            let measured = tr.measured_weight_bytes();
            match mode {
                Mode::Fp32 => assert_eq!(measured, 4 * elems),
                Mode::Mixed16 => {
                    assert_eq!(measured, 4 * elems, "f32 masters");
                    assert_eq!(tr.weight_bytes(), 2 * elems, "plan: resident bf16 copy");
                }
                Mode::Standard16 | Mode::Sr16 => {
                    assert_eq!(measured, 2 * elems, "half of fp32: {mode:?}");
                    assert_eq!(measured, tr.weight_bytes(), "plan == measured: {mode:?}");
                }
                Mode::Kahan16 | Mode::SrKahan16 => {
                    assert_eq!(measured, 4 * elems, "2 weight + 2 compensation: {mode:?}");
                    assert_eq!(measured, tr.weight_bytes(), "plan == measured: {mode:?}");
                }
            }
        }
        // and for the embedding-heavy app, one narrowable mode end-to-end
        let dlrm = DlrmTrainer::new(DlrmConfig { seed: 9, ..Default::default() }, Mode::Sr16);
        assert_eq!(dlrm.measured_weight_bytes(), dlrm.weight_bytes());
        let gpt = GptTrainer::new(GptConfig::default(), Mode::Standard16);
        assert_eq!(gpt.measured_weight_bytes(), gpt.weight_bytes());
    }

    /// Gradient accumulation: a resumed accum-4 run is bit-identical to an
    /// uninterrupted one (the generator fast-forward must account for M
    /// batches per step), and a checkpoint written at a different accum
    /// count refuses to load (a "step" means something else there).
    #[test]
    fn grad_accum_resume_is_bit_identical_and_mismatch_fails() {
        let mk = || {
            MlpTrainer::new(MlpConfig { seed: 13, ..Default::default() }, Mode::Sr16)
                .with_grad_accum(4)
        };
        let path = tmp("mlp_accum4.ckpt");
        let mut full = mk();
        let mut interrupted = mk();
        for _ in 0..6 {
            full.step(0.1);
            interrupted.step(0.1);
        }
        interrupted.save_checkpoint(&path).unwrap();
        let mut resumed = mk();
        resumed.load_checkpoint(&path).unwrap();
        for step in 0..6 {
            let a = full.step(0.1);
            let b = resumed.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "post-resume step {step}");
            assert_eq!(a.embed, b.embed, "embed stats, step {step}");
            assert_eq!(a.mlp, b.mlp, "mlp stats, step {step}");
        }
        assert_params_bit_identical(&mut full, &mut resumed, "accum resume");

        let mut plain = MlpTrainer::new(MlpConfig { seed: 13, ..Default::default() }, Mode::Sr16);
        let err = plain.load_checkpoint(&path).unwrap_err().to_string();
        assert!(err.contains("accum=4"), "accum mismatch must be loud: {err}");
    }

    /// grad_accum=1 must stay byte-for-byte the original single-batch
    /// engine: an explicit `.with_grad_accum(1)` changes nothing, including
    /// the checkpoint bytes (no fingerprint suffix).
    #[test]
    fn grad_accum_one_is_the_identity() {
        let mk = || MlpTrainer::new(MlpConfig { seed: 5, ..Default::default() }, Mode::SrKahan16);
        let mut plain = mk();
        let mut explicit = mk().with_grad_accum(1);
        for step in 0..8 {
            let a = plain.step(0.1);
            let b = explicit.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "step {step}");
        }
        assert_eq!(plain.checkpoint_bytes(), explicit.checkpoint_bytes());
    }

    /// Satellite: flipping a bit anywhere in a saved checkpoint must make
    /// the load fail loudly (CRC-32 footer), and the failed load leaves
    /// the trainer untouched.
    #[test]
    fn corrupted_checkpoint_bytes_fail_loudly_at_any_offset() {
        let mut src = MlpTrainer::new(MlpConfig { seed: 21, ..Default::default() }, Mode::Sr16);
        for _ in 0..3 {
            src.step(0.1);
        }
        let bytes = src.checkpoint_bytes();
        let mut fresh = MlpTrainer::new(MlpConfig { seed: 21, ..Default::default() }, Mode::Sr16);
        fresh.load_checkpoint_bytes(&bytes).unwrap();

        let mut x = 0x2545_F491_4F6C_DD1Du64;
        for trial in 0..48 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let off = (x >> 33) as usize % bytes.len();
            let bit = (x >> 29 & 7) as u8;
            let mut m = bytes.clone();
            m[off] ^= 1 << bit;
            let mut tr = MlpTrainer::new(MlpConfig { seed: 21, ..Default::default() }, Mode::Sr16);
            assert!(
                tr.load_checkpoint_bytes(&m).is_err(),
                "trial {trial}: corruption at byte {off} bit {bit} loaded silently"
            );
            assert_eq!(tr.steps_done(), 0, "failed load must leave the trainer untouched");
        }
    }
}
