//! Native DLRM-lite on the quantised tape: powers the *per-layer*
//! telemetry experiments (Figure 9: % of cancelled updates for an
//! embedding layer vs an MLP layer over training; Figure 10: sub-16-bit
//! format sweep) where the PJRT path only reports aggregates.
//!
//! Architecture: one embedding table per categorical feature, a bottom MLP
//! over dense features, dot-product interaction (via concat + linear here —
//! the rounding behaviour of interest lives in the *updates*, not the
//! interaction flavour), a top MLP to a single logit, BCE loss.

use crate::precision::Format;
use crate::util::rng::{Rng, ZipfTable};

use super::nn::{Embedding, Linear, Module};
use super::tape::{QPolicy, Tape, Var};
use super::tensor::Tensor;
use super::train::{EvalMetrics, Task, TensorClass, Trainer};
use super::Backend;

pub use super::train::StepTelemetry;

/// Stream tag for the held-out eval batches — disjoint from the training
/// stream (0xC7), so evaluation can never perturb the training trajectory.
const CTR_EVAL_STREAM: u64 = 0xE7A1;

/// Model + data configuration.
#[derive(Debug, Clone)]
pub struct DlrmConfig {
    pub num_tables: usize,
    pub table_size: usize,
    pub embed_dim: usize,
    pub dense_dim: usize,
    pub hidden: usize,
    pub batch: usize,
    pub fmt: Format,
    pub seed: u64,
    /// Kernel backend: `Fast` (tape arena + vectorized kernels) or
    /// `Reference` (fresh tape + scalar loops each step, the bench
    /// baseline).  Bit-identical results either way.
    pub backend: Backend,
    /// Worker threads for intra-step parallelism (`Fast` backend only;
    /// `Reference` is always scalar-sequential).  `1` = no worker threads,
    /// `0` = available parallelism.  The SR dither is counter-keyed, so
    /// training results are bit-identical at every setting.
    pub intra_threads: usize,
}

impl Default for DlrmConfig {
    fn default() -> Self {
        Self {
            num_tables: 4,
            table_size: 200,
            embed_dim: 8,
            dense_dim: 8,
            hidden: 32,
            batch: 32,
            fmt: crate::precision::BF16,
            seed: 0,
            backend: Backend::Fast,
            intra_threads: 1,
        }
    }
}

/// Synthetic click-through data: Zipf categorical draws + gaussian dense
/// features; label from a random logistic ground-truth model.
pub struct CtrGen {
    cfg: DlrmConfig,
    zipf: ZipfTable,
    truth_dense: Vec<f32>,
    truth_cat: Vec<f32>, // per (table, bucket) contribution
    rng: Rng,
}

pub struct CtrBatch {
    pub dense: Tensor,           // (B, dense_dim)
    pub cat: Vec<Vec<usize>>,    // per table: B indices
    pub labels: Tensor,          // (1, B)
}

impl CtrGen {
    pub fn new(cfg: &DlrmConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0xC7);
        let truth_dense = (0..cfg.dense_dim).map(|_| rng.normal()).collect();
        let truth_cat = (0..cfg.num_tables * cfg.table_size)
            .map(|_| rng.normal() * 0.5)
            .collect();
        Self {
            zipf: ZipfTable::new(cfg.table_size, 1.1),
            cfg: cfg.clone(),
            truth_dense,
            truth_cat,
            rng,
        }
    }

    pub fn next_batch(&mut self) -> CtrBatch {
        let b = self.cfg.batch;
        let mut dense = Tensor::zeros(b, self.cfg.dense_dim);
        let mut cat = vec![Vec::with_capacity(b); self.cfg.num_tables];
        let mut labels = Tensor::zeros(1, b);
        for r in 0..b {
            let mut logit = 0f32;
            for c in 0..self.cfg.dense_dim {
                let v = self.rng.normal();
                *dense.at_mut(r, c) = v;
                logit += v * self.truth_dense[c];
            }
            for (t, col) in cat.iter_mut().enumerate() {
                let idx = self.rng.zipf(&self.zipf);
                col.push(idx);
                logit += self.truth_cat[t * self.cfg.table_size + idx];
            }
            let p = 1.0 / (1.0 + (-logit).exp());
            labels.data[r] = if self.rng.uniform() < p { 1.0 } else { 0.0 };
        }
        CtrBatch { dense, cat, labels }
    }

    /// Fork a generator sharing this one's ground-truth model but drawing
    /// samples from an independent (seed, stream) pair.  Trainers hand
    /// their eval loop a fork so evaluation draws never advance the
    /// training stream (`eval` used to consume `self.gen`, silently making
    /// the training trajectory a function of `eval_every`).
    pub fn fork(&self, stream: u64) -> CtrGen {
        CtrGen {
            cfg: self.cfg.clone(),
            zipf: self.zipf.clone(),
            truth_dense: self.truth_dense.clone(),
            truth_cat: self.truth_cat.clone(),
            rng: Rng::new(self.cfg.seed, stream),
        }
    }
}

/// Batch-payload and output node ids of one frozen DLRM graph — what
/// `qsim::infer` rebinds per request batch (per-table gathers, the dense
/// leaf, BCE labels) and reads back (per-example logits, mean loss).
pub struct DlrmFrozenVars {
    pub gathers: Vec<Var>,
    pub dense: Var,
    pub logits: Var,
    pub loss: Var,
}

/// The model, composed from `qsim::nn` layers (the layer logic that used to
/// be hand-rolled here).  Parameter tensors live inside the layers, kept
/// in-format by the optimizer; the graph shape and the init draw order are
/// unchanged by the refactor, so trajectories are bit-identical to the
/// pre-`nn` implementation.
pub struct DlrmModel {
    pub cfg: DlrmConfig,
    pub tables: Vec<Embedding>,
    pub bot: Linear,
    pub top: Linear,
    pub head: Linear,
}

impl DlrmModel {
    pub fn init(cfg: &DlrmConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0xD1);
        let inter_dim = cfg.embed_dim * (cfg.num_tables + 1);
        Self {
            cfg: cfg.clone(),
            tables: (0..cfg.num_tables)
                .map(|_| Embedding::init(cfg.table_size, cfg.embed_dim, 0.05, cfg.fmt, &mut rng))
                .collect(),
            bot: Linear::init(cfg.dense_dim, cfg.embed_dim, true, cfg.fmt, &mut rng),
            top: Linear::init(inter_dim, cfg.hidden, true, cfg.fmt, &mut rng),
            head: Linear::init(cfg.hidden, 1, true, cfg.fmt, &mut rng),
        }
    }

    /// Build the forward graph for one batch into a fresh tape.
    ///
    /// Returns (tape, loss var, param vars) with params ordered
    /// [tables..., bot_w, bot_b, top_w, top_b, head_w, head_b].
    pub fn forward(&self, batch: &CtrBatch, policy: QPolicy) -> (Tape, Var, Vec<Var>) {
        let mut t = Tape::new(policy);
        let (loss, params) = self.forward_into(&mut t, batch);
        (t, loss, params)
    }

    /// Build the forward graph into a caller-owned tape — the steady-state
    /// entry point: `t.reset()` between steps recycles every node and
    /// gradient buffer, so graph construction is allocation-free once the
    /// pool has warmed up.  Param values are copied into pooled buffers
    /// (`param_from`), never cloned into fresh allocations.
    pub fn forward_into(&self, t: &mut Tape, batch: &CtrBatch) -> (Var, Vec<Var>) {
        let mut params = Vec::new();
        // embeddings
        let mut feats: Vec<Var> = Vec::new();
        for (ti, table) in self.tables.iter().enumerate() {
            feats.push(table.forward(t, batch.cat[ti].clone(), &mut params));
        }
        // bottom MLP over dense features (fused affine-relu panel)
        let x = t.input_from(&batch.dense);
        let z = self.bot.forward_relu(t, x, &mut params);
        feats.push(z);
        // interaction: concat features, top MLP, scalar head
        let cat = t.concat_cols(feats);
        let h = self.top.forward_relu(t, cat, &mut params);
        let logits2d = self.head.forward(t, h, &mut params); // (B, 1)
        // labels copy into a pooled buffer: a fresh Tensor here would
        // retire one new allocation into the free pool every step
        let loss = t.bce_loss_from(logits2d, &batch.labels);
        (loss, params)
    }

    /// Forward-only pass from no-grad leaves; returns (mean BCE loss,
    /// per-example logits) off one frozen graph — the eval hot path used
    /// to build two identical graphs per batch (one for the loss, one for
    /// the logits).  Frozen and trainable forwards are bit-identical, so
    /// the reported eval loss is unchanged.
    pub fn eval_scores(&self, batch: &CtrBatch, policy: QPolicy) -> (f32, Vec<f32>) {
        let mut t2 = Tape::new(policy);
        let v = self.frozen_graph_into(&mut t2, batch);
        let scores = t2.value(v.logits).data.clone();
        (t2.value(v.loss).item(), scores)
    }

    /// Build the frozen (no-grad) forward graph into a caller-owned tape
    /// — the single source of truth for the inference graph shape, shared
    /// by the per-batch eval path and `qsim::infer` plan compilation
    /// (which needs the batch-payload node ids to rebind per request).
    /// Op order matches the historical eval body exactly, so eval values
    /// are bit-identical across the refactor.
    pub fn frozen_graph_into(&self, t: &mut Tape, batch: &CtrBatch) -> DlrmFrozenVars {
        let mut gathers: Vec<Var> = Vec::with_capacity(self.tables.len());
        let mut feats: Vec<Var> = Vec::new();
        for (ti, table) in self.tables.iter().enumerate() {
            let e = table.forward_frozen(t, batch.cat[ti].clone());
            gathers.push(e);
            feats.push(e);
        }
        let dense = t.input(batch.dense.clone());
        let z = self.bot.forward_relu_frozen(t, dense);
        feats.push(z);
        let cat = t.concat_cols(feats);
        let h = self.top.forward_relu_frozen(t, cat);
        let logits = self.head.forward_frozen(t, h);
        let loss = t.bce_loss_from(logits, &batch.labels);
        DlrmFrozenVars { gathers, dense, logits, loss }
    }

    /// All parameter tensors, in forward registration order.
    pub fn param_tensors(&self) -> Vec<&Tensor> {
        let mut v: Vec<&Tensor> = Vec::new();
        for e in &self.tables {
            v.extend(e.params());
        }
        v.extend(self.bot.params());
        v.extend(self.top.params());
        v.extend(self.head.params());
        v
    }

    /// Mutable walk in the same order (optimizer updates).
    pub fn param_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v: Vec<&mut Tensor> = Vec::new();
        for e in &mut self.tables {
            v.extend(e.params_mut());
        }
        v.extend(self.bot.params_mut());
        v.extend(self.top.params_mut());
        v.extend(self.head.params_mut());
        v
    }
}

/// DLRM as a [`Task`]: the config maps onto the model, the CTR stream and
/// the AUC eval; the generic [`Trainer`] supplies the loop, the optimizer
/// bank (per-tensor modes included — Figure 5's sweep), the eval fork and
/// checkpointing.  Param order: [tables..., bot_w, bot_b, top_w, top_b,
/// head_w, head_b]; tensors are distinguished in the dither schedule by
/// that index (the key's `tensor_id` coordinate), not by per-tensor seeds.
impl Task for DlrmConfig {
    type Model = DlrmModel;
    type Gen = CtrGen;
    type Batch = CtrBatch;

    const NAME: &'static str = "dlrm";
    const EVAL_STREAM: u64 = CTR_EVAL_STREAM;

    fn seed(&self) -> u64 {
        self.seed
    }

    fn fmt(&self) -> Format {
        self.fmt
    }

    fn backend(&self) -> Backend {
        self.backend
    }

    fn intra_threads(&self) -> usize {
        self.intra_threads
    }

    fn config_fingerprint(&self) -> String {
        format!(
            "seed={} tables={} rows={} embed={} dense={} hidden={} batch={}",
            self.seed, self.num_tables, self.table_size, self.embed_dim, self.dense_dim,
            self.hidden, self.batch
        )
    }

    fn num_tensors(&self) -> usize {
        self.num_tables + 6
    }

    fn tensor_class(&self, i: usize) -> TensorClass {
        if i < self.num_tables {
            TensorClass::Embed
        } else {
            TensorClass::Dense
        }
    }

    fn init_model(&self) -> DlrmModel {
        DlrmModel::init(self)
    }

    fn make_gen(&self) -> CtrGen {
        CtrGen::new(self)
    }

    fn fork_gen(gen: &CtrGen, stream: u64) -> CtrGen {
        gen.fork(stream)
    }

    fn next_batch(gen: &mut CtrGen) -> CtrBatch {
        gen.next_batch()
    }

    fn forward_into(model: &DlrmModel, t: &mut Tape, batch: &CtrBatch) -> (Var, Vec<Var>) {
        model.forward_into(t, batch)
    }

    fn param_tensors(model: &DlrmModel) -> Vec<&Tensor> {
        model.param_tensors()
    }

    fn param_tensors_mut(model: &mut DlrmModel) -> Vec<&mut Tensor> {
        model.param_tensors_mut()
    }

    /// Mean loss and AUC over `n` fresh batches.  `n == 0` is defined as
    /// `(0.0, 0.5)` — no data, chance AUC — instead of 0/0 NaN.
    ///
    /// Scored through a [`DlrmPlan`](crate::qsim::infer::DlrmPlan)
    /// compiled from the first batch and rebound for the rest — the plan
    /// replay is bit-identical to the per-batch tape rebuild it replaced
    /// (pinned by the `qsim-parity` digests), just without paying the
    /// tape.
    fn eval(model: &DlrmModel, gen: &mut CtrGen, n: usize, policy: QPolicy) -> EvalMetrics {
        if n == 0 {
            return EvalMetrics { loss: 0.0, metric: 0.5, metric_name: "auc" };
        }
        let mut plan: Option<crate::qsim::infer::DlrmPlan> = None;
        let mut loss_acc = 0f64;
        let mut scored: Vec<(f32, bool)> = Vec::new();
        for _ in 0..n {
            let batch = gen.next_batch();
            let p = plan.get_or_insert_with(|| {
                crate::qsim::infer::DlrmPlan::compile(model, &batch, policy)
            });
            let (loss, logits) = p.score(&batch);
            loss_acc += loss as f64;
            for (z, &y) in logits.iter().zip(&batch.labels.data) {
                scored.push((*z, y > 0.5));
            }
        }
        EvalMetrics {
            loss: (loss_acc / n as f64) as f32,
            metric: crate::metrics::auc(&scored),
            metric_name: "auc",
        }
    }
}

/// The DLRM trainer — an instantiation of the generic engine.
pub type DlrmTrainer = Trainer<DlrmConfig>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Mode;
    use crate::qsim::UpdateStats;

    #[test]
    fn training_reduces_loss_fp32() {
        let cfg = DlrmConfig { seed: 3, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Fp32);
        let first: f32 =
            (0..20).map(|_| tr.step(0.1).loss).sum::<f32>() / 20.0;
        for _ in 0..400 {
            tr.step(0.1);
        }
        let last: f32 = (0..20).map(|_| tr.step(0.1).loss).sum::<f32>() / 20.0;
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn standard16_cancellation_grows_late_in_training(){
        let cfg = DlrmConfig { seed: 5, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
        let mut early = UpdateStats::default();
        let mut late = UpdateStats::default();
        for t in 0..600 {
            let tel = tr.step(0.05);
            if t < 100 {
                early.merge(tel.embed);
                early.merge(tel.mlp);
            } else if t >= 500 {
                late.merge(tel.embed);
                late.merge(tel.mlp);
            }
        }
        // Figure 9's shape: cancellation increases in mid-to-late training.
        assert!(
            late.frac() >= early.frac(),
            "early={} late={}",
            early.frac(),
            late.frac()
        );
    }

    /// Acceptance gate for the kernel vectorization: the fast path (arena
    /// tape, tiled matmul, batched SR) must reproduce the scalar reference
    /// path bit-for-bit over a real training trajectory.
    #[test]
    fn sr16_hundred_steps_bit_identical_across_backends() {
        let mk = |backend| {
            let cfg = DlrmConfig { seed: 11, backend, ..Default::default() };
            DlrmTrainer::new(cfg, Mode::Sr16)
        };
        let mut fast = mk(Backend::Fast);
        let mut reference = mk(Backend::Reference);
        for step in 0..100 {
            let a = fast.step(0.05);
            let b = reference.step(0.05);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
            assert_eq!(a.embed, b.embed, "embed stats diverged at step {step}");
            assert_eq!(a.mlp, b.mlp, "mlp stats diverged at step {step}");
        }
        let mut fm = fast.model;
        let mut rm = reference.model;
        for (pi, (wa, wb)) in fm
            .param_tensors_mut()
            .into_iter()
            .zip(rm.param_tensors_mut())
            .enumerate()
        {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            assert_eq!(da.len(), db.len());
            for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "param {pi} elem {ei} after 100 steps");
            }
        }
    }

    /// Acceptance gate for deterministic intra-step parallelism: the same
    /// seed must produce bit-identical training at every thread count (the
    /// dither schedule is counter-keyed, and every parallel kernel is
    /// row/element-local).
    #[test]
    fn sr16_training_bit_identical_across_thread_counts() {
        let mk = |intra_threads| {
            let cfg = DlrmConfig {
                seed: 17,
                // large enough that matmul and optimizer fan-out engage
                table_size: 600,
                embed_dim: 16,
                hidden: 64,
                batch: 48,
                intra_threads,
                ..Default::default()
            };
            DlrmTrainer::new(cfg, Mode::Sr16)
        };
        let mut base = mk(1);
        let base_tel: Vec<StepTelemetry> = (0..25).map(|_| base.step(0.05)).collect();
        for threads in [2usize, 4] {
            let mut tr = mk(threads);
            assert_eq!(tr.intra_threads(), threads);
            for (step, want) in base_tel.iter().enumerate() {
                let got = tr.step(0.05);
                assert_eq!(
                    got.loss.to_bits(),
                    want.loss.to_bits(),
                    "loss diverged at step {step} with {threads} threads"
                );
                assert_eq!(got.embed, want.embed, "embed stats, step {step}, t={threads}");
                assert_eq!(got.mlp, want.mlp, "mlp stats, step {step}, t={threads}");
            }
            for (pi, (wa, wb)) in base
                .model
                .param_tensors_mut()
                .into_iter()
                .zip(tr.model.param_tensors_mut())
                .enumerate()
            {
                let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
                for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "param {pi} elem {ei} diverged with {threads} threads"
                    );
                }
            }
        }
    }

    /// Same gate for the kahan+SR combination (exercises every optimizer
    /// stage and the kahan state buffers).
    #[test]
    fn srkahan16_thirty_steps_bit_identical_across_backends() {
        let mk = |backend| {
            let cfg = DlrmConfig { seed: 13, backend, ..Default::default() };
            DlrmTrainer::new(cfg, Mode::SrKahan16)
        };
        let mut fast = mk(Backend::Fast);
        let mut reference = mk(Backend::Reference);
        for step in 0..30 {
            let a = fast.step(0.05);
            let b = reference.step(0.05);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
        }
        let mut fm = fast.model;
        let mut rm = reference.model;
        for (wa, wb) in fm.param_tensors_mut().into_iter().zip(rm.param_tensors_mut()) {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            for (x, y) in da.iter().zip(db.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    /// Bugfix gate: the training trajectory must be bit-identical whether
    /// or not (and how often) `eval` runs — evaluation draws from its own
    /// forked stream, never the training generator.
    #[test]
    fn eval_cadence_does_not_change_training_trajectory() {
        let mk = || {
            let cfg = DlrmConfig { seed: 21, ..Default::default() };
            DlrmTrainer::new(cfg, Mode::Sr16)
        };
        let mut with_eval = mk();
        let mut without = mk();
        for step in 0..30 {
            let a = with_eval.step(0.05);
            let b = without.step(0.05);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
            assert_eq!(a.embed, b.embed, "embed stats diverged at step {step}");
            assert_eq!(a.mlp, b.mlp, "mlp stats diverged at step {step}");
            // eval_every = 10, the ISSUE's regression cadence
            if (step + 1) % 10 == 0 {
                let m = with_eval.eval(2);
                assert!(m.loss.is_finite() && (0.0..=1.0).contains(&m.metric));
            }
        }
        for (pi, (wa, wb)) in with_eval
            .model
            .param_tensors_mut()
            .into_iter()
            .zip(without.model.param_tensors_mut())
            .enumerate()
        {
            let (da, db) = (wa.to_f32_vec(), wb.to_f32_vec());
            for (ei, (x, y)) in da.iter().zip(db.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "param {pi} elem {ei}");
            }
        }
    }

    #[test]
    fn empty_eval_is_defined() {
        let cfg = DlrmConfig { seed: 2, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Fp32);
        assert_eq!(tr.eval(0), EvalMetrics { loss: 0.0, metric: 0.5, metric_name: "auc" });
    }

    #[test]
    fn eval_stream_shares_ground_truth_with_training() {
        // a forked generator must describe the same synthetic task: a
        // trained model should score (clearly) better than chance on it
        let cfg = DlrmConfig { seed: 9, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Fp32);
        for _ in 0..400 {
            tr.step(0.1);
        }
        let auc = tr.eval(16).metric;
        assert!(auc > 0.55, "held-out auc {auc} — eval stream looks unrelated to training");
    }

    #[test]
    fn telemetry_separates_embedding_and_mlp() {
        let cfg = DlrmConfig { seed: 7, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
        let tel = tr.step(0.05);
        // embeddings: only touched rows get non-zero updates
        assert!(tel.embed.nonzero > 0);
        assert!(tel.mlp.nonzero > 0);
        let table_elems =
            tr.model.cfg.num_tables * tr.model.cfg.table_size * tr.model.cfg.embed_dim;
        assert!(tel.embed.nonzero < table_elems as u64);
    }
}

