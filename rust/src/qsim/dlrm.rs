//! Native DLRM-lite on the quantised tape: powers the *per-layer*
//! telemetry experiments (Figure 9: % of cancelled updates for an
//! embedding layer vs an MLP layer over training; Figure 10: sub-16-bit
//! format sweep) where the PJRT path only reports aggregates.
//!
//! Architecture: one embedding table per categorical feature, a bottom MLP
//! over dense features, dot-product interaction (via concat + linear here —
//! the rounding behaviour of interest lives in the *updates*, not the
//! interaction flavour), a top MLP to a single logit, BCE loss.

use std::sync::Arc;

use crate::precision::{Format, Mode, FP32};
use crate::util::rng::{Rng, ZipfTable};

use super::optim::{Sgd, SgdState, UpdateStats};
use super::pool::Pool;
use super::tape::{QPolicy, Tape, Var};
use super::tensor::Tensor;
use super::Backend;

/// Model + data configuration.
#[derive(Debug, Clone)]
pub struct DlrmConfig {
    pub num_tables: usize,
    pub table_size: usize,
    pub embed_dim: usize,
    pub dense_dim: usize,
    pub hidden: usize,
    pub batch: usize,
    pub fmt: Format,
    pub seed: u64,
    /// Kernel backend: `Fast` (tape arena + vectorized kernels) or
    /// `Reference` (fresh tape + scalar loops each step, the bench
    /// baseline).  Bit-identical results either way.
    pub backend: Backend,
    /// Worker threads for intra-step parallelism (`Fast` backend only;
    /// `Reference` is always scalar-sequential).  `1` = no worker threads,
    /// `0` = available parallelism.  The SR dither is counter-keyed, so
    /// training results are bit-identical at every setting.
    pub intra_threads: usize,
}

impl Default for DlrmConfig {
    fn default() -> Self {
        Self {
            num_tables: 4,
            table_size: 200,
            embed_dim: 8,
            dense_dim: 8,
            hidden: 32,
            batch: 32,
            fmt: crate::precision::BF16,
            seed: 0,
            backend: Backend::Fast,
            intra_threads: 1,
        }
    }
}

/// Synthetic click-through data: Zipf categorical draws + gaussian dense
/// features; label from a random logistic ground-truth model.
pub struct CtrGen {
    cfg: DlrmConfig,
    zipf: ZipfTable,
    truth_dense: Vec<f32>,
    truth_cat: Vec<f32>, // per (table, bucket) contribution
    rng: Rng,
}

pub struct CtrBatch {
    pub dense: Tensor,           // (B, dense_dim)
    pub cat: Vec<Vec<usize>>,    // per table: B indices
    pub labels: Tensor,          // (1, B)
}

impl CtrGen {
    pub fn new(cfg: &DlrmConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0xC7);
        let truth_dense = (0..cfg.dense_dim).map(|_| rng.normal()).collect();
        let truth_cat = (0..cfg.num_tables * cfg.table_size)
            .map(|_| rng.normal() * 0.5)
            .collect();
        Self {
            zipf: ZipfTable::new(cfg.table_size, 1.1),
            cfg: cfg.clone(),
            truth_dense,
            truth_cat,
            rng,
        }
    }

    pub fn next_batch(&mut self) -> CtrBatch {
        let b = self.cfg.batch;
        let mut dense = Tensor::zeros(b, self.cfg.dense_dim);
        let mut cat = vec![Vec::with_capacity(b); self.cfg.num_tables];
        let mut labels = Tensor::zeros(1, b);
        for r in 0..b {
            let mut logit = 0f32;
            for c in 0..self.cfg.dense_dim {
                let v = self.rng.normal();
                *dense.at_mut(r, c) = v;
                logit += v * self.truth_dense[c];
            }
            for (t, col) in cat.iter_mut().enumerate() {
                let idx = self.rng.zipf(&self.zipf);
                col.push(idx);
                logit += self.truth_cat[t * self.cfg.table_size + idx];
            }
            let p = 1.0 / (1.0 + (-logit).exp());
            labels.data[r] = if self.rng.uniform() < p { 1.0 } else { 0.0 };
        }
        CtrBatch { dense, cat, labels }
    }
}

/// The model parameters (kept in-format by the optimizer).
pub struct DlrmModel {
    pub cfg: DlrmConfig,
    pub tables: Vec<Tensor>,
    pub bot_w: Tensor,
    pub bot_b: Tensor,
    pub top_w: Tensor,
    pub top_b: Tensor,
    pub head_w: Tensor,
    pub head_b: Tensor,
}

impl DlrmModel {
    pub fn init(cfg: &DlrmConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0xD1);
        let inter_dim = cfg.embed_dim * (cfg.num_tables + 1);
        let quant = |mut t: Tensor| {
            for x in &mut t.data {
                *x = crate::precision::round_nearest(*x, cfg.fmt);
            }
            t
        };
        Self {
            cfg: cfg.clone(),
            tables: (0..cfg.num_tables)
                .map(|_| {
                    quant(Tensor::rand_uniform(
                        cfg.table_size,
                        cfg.embed_dim,
                        -0.05,
                        0.05,
                        &mut rng,
                    ))
                })
                .collect(),
            bot_w: quant(Tensor::randn(
                cfg.dense_dim,
                cfg.embed_dim,
                (2.0 / cfg.dense_dim as f32).sqrt(),
                &mut rng,
            )),
            bot_b: Tensor::zeros(1, cfg.embed_dim),
            top_w: quant(Tensor::randn(
                inter_dim,
                cfg.hidden,
                (2.0 / inter_dim as f32).sqrt(),
                &mut rng,
            )),
            top_b: Tensor::zeros(1, cfg.hidden),
            head_w: quant(Tensor::randn(
                cfg.hidden,
                1,
                (2.0 / cfg.hidden as f32).sqrt(),
                &mut rng,
            )),
            head_b: Tensor::zeros(1, 1),
        }
    }

    /// Build the forward graph for one batch into a fresh tape.
    ///
    /// Returns (tape, loss var, param vars) with params ordered
    /// [tables..., bot_w, bot_b, top_w, top_b, head_w, head_b].
    pub fn forward(&self, batch: &CtrBatch, policy: QPolicy) -> (Tape, Var, Vec<Var>) {
        let mut t = Tape::new(policy);
        let (loss, params) = self.forward_into(&mut t, batch);
        (t, loss, params)
    }

    /// Build the forward graph into a caller-owned tape — the steady-state
    /// entry point: `t.reset()` between steps recycles every node and
    /// gradient buffer, so graph construction is allocation-free once the
    /// pool has warmed up.  Param values are copied into pooled buffers
    /// (`param_from`), never cloned into fresh allocations.
    pub fn forward_into(&self, t: &mut Tape, batch: &CtrBatch) -> (Var, Vec<Var>) {
        let mut params = Vec::new();
        // embeddings
        let mut feats: Vec<Var> = Vec::new();
        for (ti, table) in self.tables.iter().enumerate() {
            let tv = t.param_from(table);
            params.push(tv);
            feats.push(t.embed(tv, batch.cat[ti].clone()));
        }
        // bottom MLP over dense features
        let x = t.input_from(&batch.dense);
        let bw = t.param_from(&self.bot_w);
        let bb = t.param_from(&self.bot_b);
        params.extend([bw, bb]);
        let z0 = t.matmul(x, bw);
        let z1 = t.add_row(z0, bb);
        let z = t.relu(z1);
        feats.push(z);
        // interaction: concat features, top MLP, scalar head
        let cat = t.concat_cols(feats);
        let tw = t.param_from(&self.top_w);
        let tb = t.param_from(&self.top_b);
        params.extend([tw, tb]);
        let h0 = t.matmul(cat, tw);
        let h1 = t.add_row(h0, tb);
        let h = t.relu(h1);
        let hw = t.param_from(&self.head_w);
        let hb = t.param_from(&self.head_b);
        params.extend([hw, hb]);
        let l0 = t.matmul(h, hw);
        let logits2d = t.add_row(l0, hb); // (B, 1)
        let loss = t.bce_loss(
            logits2d,
            Tensor::from_vec(batch.labels.len(), 1, batch.labels.data.clone()),
        );
        (loss, params)
    }

    /// Forward pass only; returns per-example logits.
    pub fn logits(&self, batch: &CtrBatch, policy: QPolicy) -> Vec<f32> {
        let mut t2 = Tape::new(policy);
        let mut feats: Vec<Var> = Vec::new();
        for (ti, table) in self.tables.iter().enumerate() {
            let tv = t2.input(table.clone());
            feats.push(t2.embed(tv, batch.cat[ti].clone()));
        }
        let x = t2.input(batch.dense.clone());
        let bw = t2.input(self.bot_w.clone());
        let bb = t2.input(self.bot_b.clone());
        let z0 = t2.matmul(x, bw);
        let z1 = t2.add_row(z0, bb);
        let z = t2.relu(z1);
        feats.push(z);
        let cat = t2.concat_cols(feats);
        let tw = t2.input(self.top_w.clone());
        let tb = t2.input(self.top_b.clone());
        let h0 = t2.matmul(cat, tw);
        let h1 = t2.add_row(h0, tb);
        let h = t2.relu(h1);
        let hw = t2.input(self.head_w.clone());
        let hb = t2.input(self.head_b.clone());
        let l0 = t2.matmul(h, hw);
        let logits2d = t2.add_row(l0, hb);
        t2.value(logits2d).data.clone()
    }

    fn param_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v: Vec<&mut Tensor> = self.tables.iter_mut().collect();
        v.push(&mut self.bot_w);
        v.push(&mut self.bot_b);
        v.push(&mut self.top_w);
        v.push(&mut self.top_b);
        v.push(&mut self.head_w);
        v.push(&mut self.head_b);
        v
    }
}

/// Per-step per-layer-class telemetry (Figure 9's series).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTelemetry {
    pub loss: f32,
    pub embed: UpdateStats,
    pub mlp: UpdateStats,
}

/// Trainer combining the model, optimizer and data generator.
pub struct DlrmTrainer {
    pub model: DlrmModel,
    opts: Vec<Sgd>,
    states: Vec<SgdState>,
    gen: CtrGen,
    policy: QPolicy,
    /// Retained across steps (`Fast` backend): node + gradient storage is
    /// recycled via `Tape::reset` instead of reallocated per step.
    tape: Tape,
    /// Shared intra-step worker pool (spawned once, here; the tape and
    /// every optimizer hold clones of this handle).
    pool: Arc<Pool>,
}

impl DlrmTrainer {
    /// All parameter tensors share one precision mode.
    pub fn new(cfg: DlrmConfig, mode: Mode) -> Self {
        let n = cfg.num_tables + 6;
        Self::new_mixed(cfg, vec![mode; n])
    }

    /// Per-tensor precision modes (Figure 5's incremental SR→Kahan sweep).
    /// `modes` ordering matches the param order of `DlrmModel::forward`:
    /// [tables..., bot_w, bot_b, top_w, top_b, head_w, head_b].
    ///
    /// The worker pool is spawned here, once per trainer, sized by
    /// `cfg.intra_threads`; tensors are distinguished in the dither
    /// schedule by their param index (the key's `tensor_id` coordinate),
    /// not by per-tensor seeds.
    pub fn new_mixed(cfg: DlrmConfig, modes: Vec<Mode>) -> Self {
        assert_eq!(modes.len(), cfg.num_tables + 6, "one mode per tensor");
        let pool = Arc::new(Pool::new(if cfg.backend == Backend::Fast {
            cfg.intra_threads
        } else {
            1
        }));
        let model = DlrmModel::init(&cfg);
        let opts: Vec<Sgd> = modes
            .iter()
            .enumerate()
            .map(|(i, &m)| {
                Sgd::new(m, cfg.fmt, 0.0, 0.0, cfg.seed)
                    .with_tensor_id(i as u64)
                    .with_backend(cfg.backend)
                    .with_pool(Arc::clone(&pool))
            })
            .collect();
        let mut probe = DlrmModel::init(&cfg);
        let states = probe
            .param_tensors_mut()
            .iter()
            .zip(&opts)
            .map(|(t, o)| o.init_state(t))
            .collect();
        // fwd/bwd compute rounds unless every tensor trains in fp32
        let policy = if modes.iter().all(|&m| m == Mode::Fp32) {
            QPolicy::with_backend(FP32, cfg.backend)
        } else {
            QPolicy::with_backend(cfg.fmt, cfg.backend)
        };
        let gen = CtrGen::new(&cfg);
        let tape = Tape::with_pool(policy, Arc::clone(&pool));
        Self { model, opts, states, gen, policy, tape, pool }
    }

    /// Effective intra-step worker count (1 unless configured otherwise).
    pub fn intra_threads(&self) -> usize {
        self.pool.threads()
    }

    /// Weight-memory bytes under the per-tensor modes (Figure 5's x-axis).
    pub fn weight_bytes(&self, modes: &[Mode]) -> u64 {
        let mut probe = DlrmModel::init(&self.model.cfg);
        probe
            .param_tensors_mut()
            .iter()
            .zip(modes)
            .map(|(t, m)| t.data.len() as u64 * if m.kahan() { 4 } else { 2 })
            .sum()
    }

    /// One SGD step over a fresh synthetic batch.
    ///
    /// `Fast` backend: the retained tape is `reset` (node and gradient
    /// buffers recycled) and gradients are fed to the optimizer by
    /// reference, so steady-state tensor traffic is allocation-free; only
    /// the small per-batch index/label buffers stored in graph ops are
    /// still allocated each step.  `Reference` backend: a fresh tape per
    /// step, reproducing the pre-optimization allocation pattern.
    pub fn step(&mut self, lr: f32) -> StepTelemetry {
        let batch = self.gen.next_batch();
        if self.policy.backend == Backend::Fast {
            self.tape.reset();
        } else {
            self.tape = Tape::new(self.policy);
        }
        let (loss, param_vars) = self.model.forward_into(&mut self.tape, &batch);
        self.tape.backward(loss);
        let loss_val = self.tape.value(loss).item();
        let n_tables = self.model.cfg.num_tables;
        let mut tel = StepTelemetry { loss: loss_val, ..Default::default() };
        let tape = &self.tape;
        let params = self.model.param_tensors_mut();
        for (i, (w, var)) in params.into_iter().zip(&param_vars).enumerate() {
            let zero_g;
            let g = match tape.grad(*var) {
                Some(g) => g,
                // a parameter off the loss path still takes its (no-op)
                // optimizer update, so its step counter — the dither key's
                // step coordinate — stays in lockstep with the others
                None => {
                    zero_g = Tensor::zeros(w.rows, w.cols);
                    &zero_g
                }
            };
            let stats = self.opts[i].step(w, &mut self.states[i], g, lr);
            if i < n_tables {
                tel.embed.merge(stats);
            } else {
                tel.mlp.merge(stats);
            }
        }
        tel
    }

    /// Evaluate mean loss and AUC over `n` fresh batches.
    pub fn eval(&mut self, n: usize) -> (f32, f32) {
        let mut loss_acc = 0f64;
        let mut scored: Vec<(f32, bool)> = Vec::new();
        for _ in 0..n {
            let batch = self.gen.next_batch();
            let (tape, loss, _) = self.model.forward(&batch, self.policy);
            loss_acc += tape.value(loss).item() as f64;
            let logits = self.model.logits(&batch, self.policy);
            for (z, &y) in logits.iter().zip(&batch.labels.data) {
                scored.push((*z, y > 0.5));
            }
        }
        ((loss_acc / n as f64) as f32, crate::metrics::auc(&scored))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_reduces_loss_fp32() {
        let cfg = DlrmConfig { seed: 3, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Fp32);
        let first: f32 =
            (0..20).map(|_| tr.step(0.1).loss).sum::<f32>() / 20.0;
        for _ in 0..400 {
            tr.step(0.1);
        }
        let last: f32 = (0..20).map(|_| tr.step(0.1).loss).sum::<f32>() / 20.0;
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn standard16_cancellation_grows_late_in_training(){
        let cfg = DlrmConfig { seed: 5, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
        let mut early = UpdateStats::default();
        let mut late = UpdateStats::default();
        for t in 0..600 {
            let tel = tr.step(0.05);
            if t < 100 {
                early.merge(tel.embed);
                early.merge(tel.mlp);
            } else if t >= 500 {
                late.merge(tel.embed);
                late.merge(tel.mlp);
            }
        }
        // Figure 9's shape: cancellation increases in mid-to-late training.
        assert!(
            late.frac() >= early.frac(),
            "early={} late={}",
            early.frac(),
            late.frac()
        );
    }

    /// Acceptance gate for the kernel vectorization: the fast path (arena
    /// tape, tiled matmul, batched SR) must reproduce the scalar reference
    /// path bit-for-bit over a real training trajectory.
    #[test]
    fn sr16_hundred_steps_bit_identical_across_backends() {
        let mk = |backend| {
            let cfg = DlrmConfig { seed: 11, backend, ..Default::default() };
            DlrmTrainer::new(cfg, Mode::Sr16)
        };
        let mut fast = mk(Backend::Fast);
        let mut reference = mk(Backend::Reference);
        for step in 0..100 {
            let a = fast.step(0.05);
            let b = reference.step(0.05);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
            assert_eq!(a.embed, b.embed, "embed stats diverged at step {step}");
            assert_eq!(a.mlp, b.mlp, "mlp stats diverged at step {step}");
        }
        let mut fm = fast.model;
        let mut rm = reference.model;
        for (pi, (wa, wb)) in fm
            .param_tensors_mut()
            .into_iter()
            .zip(rm.param_tensors_mut())
            .enumerate()
        {
            assert_eq!(wa.data.len(), wb.data.len());
            for (ei, (x, y)) in wa.data.iter().zip(wb.data.iter()).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "param {pi} elem {ei} after 100 steps");
            }
        }
    }

    /// Acceptance gate for deterministic intra-step parallelism: the same
    /// seed must produce bit-identical training at every thread count (the
    /// dither schedule is counter-keyed, and every parallel kernel is
    /// row/element-local).
    #[test]
    fn sr16_training_bit_identical_across_thread_counts() {
        let mk = |intra_threads| {
            let cfg = DlrmConfig {
                seed: 17,
                // large enough that matmul and optimizer fan-out engage
                table_size: 600,
                embed_dim: 16,
                hidden: 64,
                batch: 48,
                intra_threads,
                ..Default::default()
            };
            DlrmTrainer::new(cfg, Mode::Sr16)
        };
        let mut base = mk(1);
        let base_tel: Vec<StepTelemetry> = (0..25).map(|_| base.step(0.05)).collect();
        for threads in [2usize, 4] {
            let mut tr = mk(threads);
            assert_eq!(tr.intra_threads(), threads);
            for (step, want) in base_tel.iter().enumerate() {
                let got = tr.step(0.05);
                assert_eq!(
                    got.loss.to_bits(),
                    want.loss.to_bits(),
                    "loss diverged at step {step} with {threads} threads"
                );
                assert_eq!(got.embed, want.embed, "embed stats, step {step}, t={threads}");
                assert_eq!(got.mlp, want.mlp, "mlp stats, step {step}, t={threads}");
            }
            for (pi, (wa, wb)) in base
                .model
                .param_tensors_mut()
                .into_iter()
                .zip(tr.model.param_tensors_mut())
                .enumerate()
            {
                for (ei, (x, y)) in wa.data.iter().zip(wb.data.iter()).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "param {pi} elem {ei} diverged with {threads} threads"
                    );
                }
            }
        }
    }

    /// Same gate for the kahan+SR combination (exercises every optimizer
    /// stage and the kahan state buffers).
    #[test]
    fn srkahan16_thirty_steps_bit_identical_across_backends() {
        let mk = |backend| {
            let cfg = DlrmConfig { seed: 13, backend, ..Default::default() };
            DlrmTrainer::new(cfg, Mode::SrKahan16)
        };
        let mut fast = mk(Backend::Fast);
        let mut reference = mk(Backend::Reference);
        for step in 0..30 {
            let a = fast.step(0.05);
            let b = reference.step(0.05);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
        }
        let mut fm = fast.model;
        let mut rm = reference.model;
        for (wa, wb) in fm.param_tensors_mut().into_iter().zip(rm.param_tensors_mut()) {
            for (x, y) in wa.data.iter().zip(wb.data.iter()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    #[test]
    fn telemetry_separates_embedding_and_mlp() {
        let cfg = DlrmConfig { seed: 7, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
        let tel = tr.step(0.05);
        // embeddings: only touched rows get non-zero updates
        assert!(tel.embed.nonzero > 0);
        assert!(tel.mlp.nonzero > 0);
        let table_elems =
            tr.model.cfg.num_tables * tr.model.cfg.table_size * tr.model.cfg.embed_dim;
        assert!(tel.embed.nonzero < table_elems as u64);
    }
}

