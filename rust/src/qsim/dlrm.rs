//! Native DLRM-lite on the quantised tape: powers the *per-layer*
//! telemetry experiments (Figure 9: % of cancelled updates for an
//! embedding layer vs an MLP layer over training; Figure 10: sub-16-bit
//! format sweep) where the PJRT path only reports aggregates.
//!
//! Architecture: one embedding table per categorical feature, a bottom MLP
//! over dense features, dot-product interaction (via concat + linear here —
//! the rounding behaviour of interest lives in the *updates*, not the
//! interaction flavour), a top MLP to a single logit, BCE loss.

use crate::precision::{Format, Mode};
use crate::util::rng::{Rng, ZipfTable};

use super::optim::{Sgd, SgdState, UpdateStats};
use super::tape::{QPolicy, Tape, Var};
use super::tensor::Tensor;

/// Model + data configuration.
#[derive(Debug, Clone)]
pub struct DlrmConfig {
    pub num_tables: usize,
    pub table_size: usize,
    pub embed_dim: usize,
    pub dense_dim: usize,
    pub hidden: usize,
    pub batch: usize,
    pub fmt: Format,
    pub seed: u64,
}

impl Default for DlrmConfig {
    fn default() -> Self {
        Self {
            num_tables: 4,
            table_size: 200,
            embed_dim: 8,
            dense_dim: 8,
            hidden: 32,
            batch: 32,
            fmt: crate::precision::BF16,
            seed: 0,
        }
    }
}

/// Synthetic click-through data: Zipf categorical draws + gaussian dense
/// features; label from a random logistic ground-truth model.
pub struct CtrGen {
    cfg: DlrmConfig,
    zipf: ZipfTable,
    truth_dense: Vec<f32>,
    truth_cat: Vec<f32>, // per (table, bucket) contribution
    rng: Rng,
}

pub struct CtrBatch {
    pub dense: Tensor,           // (B, dense_dim)
    pub cat: Vec<Vec<usize>>,    // per table: B indices
    pub labels: Tensor,          // (1, B)
}

impl CtrGen {
    pub fn new(cfg: &DlrmConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0xC7);
        let truth_dense = (0..cfg.dense_dim).map(|_| rng.normal()).collect();
        let truth_cat = (0..cfg.num_tables * cfg.table_size)
            .map(|_| rng.normal() * 0.5)
            .collect();
        Self {
            zipf: ZipfTable::new(cfg.table_size, 1.1),
            cfg: cfg.clone(),
            truth_dense,
            truth_cat,
            rng,
        }
    }

    pub fn next_batch(&mut self) -> CtrBatch {
        let b = self.cfg.batch;
        let mut dense = Tensor::zeros(b, self.cfg.dense_dim);
        let mut cat = vec![Vec::with_capacity(b); self.cfg.num_tables];
        let mut labels = Tensor::zeros(1, b);
        for r in 0..b {
            let mut logit = 0f32;
            for c in 0..self.cfg.dense_dim {
                let v = self.rng.normal();
                *dense.at_mut(r, c) = v;
                logit += v * self.truth_dense[c];
            }
            for (t, col) in cat.iter_mut().enumerate() {
                let idx = self.rng.zipf(&self.zipf);
                col.push(idx);
                logit += self.truth_cat[t * self.cfg.table_size + idx];
            }
            let p = 1.0 / (1.0 + (-logit).exp());
            labels.data[r] = if self.rng.uniform() < p { 1.0 } else { 0.0 };
        }
        CtrBatch { dense, cat, labels }
    }
}

/// The model parameters (kept in-format by the optimizer).
pub struct DlrmModel {
    pub cfg: DlrmConfig,
    pub tables: Vec<Tensor>,
    pub bot_w: Tensor,
    pub bot_b: Tensor,
    pub top_w: Tensor,
    pub top_b: Tensor,
    pub head_w: Tensor,
    pub head_b: Tensor,
}

impl DlrmModel {
    pub fn init(cfg: &DlrmConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0xD1);
        let inter_dim = cfg.embed_dim * (cfg.num_tables + 1);
        let quant = |mut t: Tensor| {
            for x in &mut t.data {
                *x = crate::precision::round_nearest(*x, cfg.fmt);
            }
            t
        };
        Self {
            cfg: cfg.clone(),
            tables: (0..cfg.num_tables)
                .map(|_| {
                    quant(Tensor::rand_uniform(
                        cfg.table_size,
                        cfg.embed_dim,
                        -0.05,
                        0.05,
                        &mut rng,
                    ))
                })
                .collect(),
            bot_w: quant(Tensor::randn(
                cfg.dense_dim,
                cfg.embed_dim,
                (2.0 / cfg.dense_dim as f32).sqrt(),
                &mut rng,
            )),
            bot_b: Tensor::zeros(1, cfg.embed_dim),
            top_w: quant(Tensor::randn(
                inter_dim,
                cfg.hidden,
                (2.0 / inter_dim as f32).sqrt(),
                &mut rng,
            )),
            top_b: Tensor::zeros(1, cfg.hidden),
            head_w: quant(Tensor::randn(
                cfg.hidden,
                1,
                (2.0 / cfg.hidden as f32).sqrt(),
                &mut rng,
            )),
            head_b: Tensor::zeros(1, 1),
        }
    }

    /// Build the forward graph for one batch.
    ///
    /// Returns (tape, loss var, param vars) with params ordered
    /// [tables..., bot_w, bot_b, top_w, top_b, head_w, head_b].
    pub fn forward(&self, batch: &CtrBatch, policy: QPolicy) -> (Tape, Var, Vec<Var>) {
        let mut t = Tape::new(policy);
        let mut params = Vec::new();
        // embeddings
        let mut feats: Vec<Var> = Vec::new();
        for (ti, table) in self.tables.iter().enumerate() {
            let tv = t.param(table.clone());
            params.push(tv);
            feats.push(t.embed(tv, batch.cat[ti].clone()));
        }
        // bottom MLP over dense features
        let x = t.input(batch.dense.clone());
        let bw = t.param(self.bot_w.clone());
        let bb = t.param(self.bot_b.clone());
        params.extend([bw, bb]);
        let z0 = t.matmul(x, bw);
        let z1 = t.add_row(z0, bb);
        let z = t.relu(z1);
        feats.push(z);
        // interaction: concat features, top MLP, scalar head
        let cat = t.concat_cols(feats);
        let tw = t.param(self.top_w.clone());
        let tb = t.param(self.top_b.clone());
        params.extend([tw, tb]);
        let h0 = t.matmul(cat, tw);
        let h1 = t.add_row(h0, tb);
        let h = t.relu(h1);
        let hw = t.param(self.head_w.clone());
        let hb = t.param(self.head_b.clone());
        params.extend([hw, hb]);
        let l0 = t.matmul(h, hw);
        let logits2d = t.add_row(l0, hb); // (B, 1)
        let loss = t.bce_loss(
            logits2d,
            Tensor::from_vec(batch.labels.len(), 1, batch.labels.data.clone()),
        );
        (t, loss, params)
    }

    /// Forward pass only; returns per-example logits.
    pub fn logits(&self, batch: &CtrBatch, policy: QPolicy) -> Vec<f32> {
        let mut t2 = Tape::new(policy);
        let mut feats: Vec<Var> = Vec::new();
        for (ti, table) in self.tables.iter().enumerate() {
            let tv = t2.input(table.clone());
            feats.push(t2.embed(tv, batch.cat[ti].clone()));
        }
        let x = t2.input(batch.dense.clone());
        let bw = t2.input(self.bot_w.clone());
        let bb = t2.input(self.bot_b.clone());
        let z0 = t2.matmul(x, bw);
        let z1 = t2.add_row(z0, bb);
        let z = t2.relu(z1);
        feats.push(z);
        let cat = t2.concat_cols(feats);
        let tw = t2.input(self.top_w.clone());
        let tb = t2.input(self.top_b.clone());
        let h0 = t2.matmul(cat, tw);
        let h1 = t2.add_row(h0, tb);
        let h = t2.relu(h1);
        let hw = t2.input(self.head_w.clone());
        let hb = t2.input(self.head_b.clone());
        let l0 = t2.matmul(h, hw);
        let logits2d = t2.add_row(l0, hb);
        t2.value(logits2d).data.clone()
    }

    fn param_tensors_mut(&mut self) -> Vec<&mut Tensor> {
        let mut v: Vec<&mut Tensor> = self.tables.iter_mut().collect();
        v.push(&mut self.bot_w);
        v.push(&mut self.bot_b);
        v.push(&mut self.top_w);
        v.push(&mut self.top_b);
        v.push(&mut self.head_w);
        v.push(&mut self.head_b);
        v
    }
}

/// Per-step per-layer-class telemetry (Figure 9's series).
#[derive(Debug, Clone, Copy, Default)]
pub struct StepTelemetry {
    pub loss: f32,
    pub embed: UpdateStats,
    pub mlp: UpdateStats,
}

/// Trainer combining the model, optimizer and data generator.
pub struct DlrmTrainer {
    pub model: DlrmModel,
    opts: Vec<Sgd>,
    states: Vec<SgdState>,
    gen: CtrGen,
    policy: QPolicy,
}

impl DlrmTrainer {
    /// All parameter tensors share one precision mode.
    pub fn new(cfg: DlrmConfig, mode: Mode) -> Self {
        let n = cfg.num_tables + 6;
        Self::new_mixed(cfg, vec![mode; n])
    }

    /// Per-tensor precision modes (Figure 5's incremental SR→Kahan sweep).
    /// `modes` ordering matches the param order of `DlrmModel::forward`:
    /// [tables..., bot_w, bot_b, top_w, top_b, head_w, head_b].
    pub fn new_mixed(cfg: DlrmConfig, modes: Vec<Mode>) -> Self {
        assert_eq!(modes.len(), cfg.num_tables + 6, "one mode per tensor");
        let model = DlrmModel::init(&cfg);
        let opts: Vec<Sgd> = modes
            .iter()
            .enumerate()
            .map(|(i, &m)| Sgd::new(m, cfg.fmt, 0.0, 0.0, cfg.seed ^ 0x0B ^ i as u64))
            .collect();
        let mut probe = DlrmModel::init(&cfg);
        let states = probe
            .param_tensors_mut()
            .iter()
            .zip(&opts)
            .map(|(t, o)| o.init_state(t))
            .collect();
        // fwd/bwd compute rounds unless every tensor trains in fp32
        let policy = if modes.iter().all(|&m| m == Mode::Fp32) {
            QPolicy::exact()
        } else {
            QPolicy::new(cfg.fmt)
        };
        let gen = CtrGen::new(&cfg);
        Self { model, opts, states, gen, policy }
    }

    /// Weight-memory bytes under the per-tensor modes (Figure 5's x-axis).
    pub fn weight_bytes(&self, modes: &[Mode]) -> u64 {
        let mut probe = DlrmModel::init(&self.model.cfg);
        probe
            .param_tensors_mut()
            .iter()
            .zip(modes)
            .map(|(t, m)| t.data.len() as u64 * if m.kahan() { 4 } else { 2 })
            .sum()
    }

    /// One SGD step over a fresh synthetic batch.
    pub fn step(&mut self, lr: f32) -> StepTelemetry {
        let batch = self.gen.next_batch();
        let (mut tape, loss, param_vars) = self.model.forward(&batch, self.policy);
        tape.backward(loss);
        let loss_val = tape.value(loss).item();
        let grads: Vec<Tensor> = param_vars
            .iter()
            .map(|&v| tape.grad(v).cloned().unwrap_or_else(|| {
                let t = tape.value(v);
                Tensor::zeros(t.rows, t.cols)
            }))
            .collect();
        let n_tables = self.model.cfg.num_tables;
        let mut tel = StepTelemetry { loss: loss_val, ..Default::default() };
        let params = self.model.param_tensors_mut();
        for (i, (w, g)) in params.into_iter().zip(&grads).enumerate() {
            let stats = self.opts[i].step(w, &mut self.states[i], g, lr);
            if i < n_tables {
                tel.embed.merge(stats);
            } else {
                tel.mlp.merge(stats);
            }
        }
        tel
    }

    /// Evaluate mean loss and AUC over `n` fresh batches.
    pub fn eval(&mut self, n: usize) -> (f32, f32) {
        let mut loss_acc = 0f64;
        let mut scored: Vec<(f32, bool)> = Vec::new();
        for _ in 0..n {
            let batch = self.gen.next_batch();
            let (tape, loss, _) = self.model.forward(&batch, self.policy);
            loss_acc += tape.value(loss).item() as f64;
            let logits = self.model.logits(&batch, self.policy);
            for (z, &y) in logits.iter().zip(&batch.labels.data) {
                scored.push((*z, y > 0.5));
            }
        }
        ((loss_acc / n as f64) as f32, crate::metrics::auc(&scored))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_reduces_loss_fp32() {
        let cfg = DlrmConfig { seed: 3, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Fp32);
        let first: f32 =
            (0..20).map(|_| tr.step(0.1).loss).sum::<f32>() / 20.0;
        for _ in 0..400 {
            tr.step(0.1);
        }
        let last: f32 = (0..20).map(|_| tr.step(0.1).loss).sum::<f32>() / 20.0;
        assert!(last < first, "first={first} last={last}");
    }

    #[test]
    fn standard16_cancellation_grows_late_in_training(){
        let cfg = DlrmConfig { seed: 5, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
        let mut early = UpdateStats::default();
        let mut late = UpdateStats::default();
        for t in 0..600 {
            let tel = tr.step(0.05);
            if t < 100 {
                early.merge(tel.embed);
                early.merge(tel.mlp);
            } else if t >= 500 {
                late.merge(tel.embed);
                late.merge(tel.mlp);
            }
        }
        // Figure 9's shape: cancellation increases in mid-to-late training.
        assert!(
            late.frac() >= early.frac(),
            "early={} late={}",
            early.frac(),
            late.frac()
        );
    }

    #[test]
    fn telemetry_separates_embedding_and_mlp() {
        let cfg = DlrmConfig { seed: 7, ..Default::default() };
        let mut tr = DlrmTrainer::new(cfg, Mode::Standard16);
        let tel = tr.step(0.05);
        // embeddings: only touched rows get non-zero updates
        assert!(tel.embed.nonzero > 0);
        assert!(tel.mlp.nonzero > 0);
        let table_elems =
            tr.model.cfg.num_tables * tr.model.cfg.table_size * tr.model.cfg.embed_dim;
        assert!(tel.embed.nonzero < table_elems as u64);
    }
}

