//! Scoped fork-join worker pool for intra-step parallelism.
//!
//! One [`Pool`] is spawned per trainer (sized by the `--intra-threads`
//! knob) and reused for every parallel region of every step: row panels of
//! the matmul kernels, elementwise tape ops, and the staged `Sgd::step`
//! passes.  Dispatch is a single mutex/condvar handshake per region, cheap
//! enough for the qsim kernel granularity; worker threads live for the
//! pool's lifetime, so steady-state training never spawns.
//!
//! ## Determinism contract
//!
//! The pool only ever *partitions* work — callers hand it element-local or
//! row-local computations over disjoint chunks, each chunk carrying its
//! global offset.  Combined with the counter-keyed SR dither
//! ([`crate::util::rng::DitherKey`], where every dither word is a pure
//! function of element position), results are bit-identical at every thread
//! count, including `threads == 1` and the scalar `Reference` backend.
//! Nothing in this module may introduce an accumulation order that depends
//! on scheduling.

use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Hard ceiling on worker slots per pool (a safety cap, far above any
/// sensible intra-step parallelism for these kernels).
pub const MAX_THREADS: usize = 256;

/// Type-erased pointer to the current region's task closure.  Only
/// dereferenced between the epoch bump in [`Pool::run`] and the
/// `active == 0` handshake that `run` blocks on before returning, so the
/// underlying closure is always alive at every dereference.
#[derive(Clone, Copy)]
struct TaskPtr(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared calls from many threads are fine)
// and `Pool::run` guarantees it outlives every dereference (see above).
unsafe impl Send for TaskPtr {}

struct JobState {
    /// Bumped once per `run`; workers run each epoch exactly once.
    epoch: u64,
    task: Option<TaskPtr>,
    /// Workers still executing the current epoch.
    active: usize,
    shutdown: bool,
}

struct Shared {
    job: Mutex<JobState>,
    start: Condvar,
    done: Condvar,
}

/// A fixed-size fork-join pool.  `threads == 1` is a true no-op wrapper
/// (no worker threads, no synchronization) so single-threaded configs pay
/// nothing.
pub struct Pool {
    shared: Option<Arc<Shared>>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes concurrent `run` calls (the job slot holds one region).
    run_lock: Mutex<()>,
    threads: usize,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

fn worker_loop(shared: Arc<Shared>, id: usize) {
    let mut seen_epoch = 0u64;
    loop {
        let ptr = {
            let mut job = shared.job.lock().unwrap();
            loop {
                if job.shutdown {
                    return;
                }
                if job.epoch != seen_epoch {
                    seen_epoch = job.epoch;
                    break job.task.expect("task set for epoch");
                }
                job = shared.start.wait(job).unwrap();
            }
        };
        // SAFETY: `Pool::run` keeps the closure alive until every worker
        // has decremented `active` for this epoch, which happens below,
        // strictly after this call returns.
        let task: &(dyn Fn(usize) + Sync) = unsafe { &*ptr.0 };
        // A panicking kernel must not unwind past the handshake: silently
        // skipping a chunk would corrupt results, and never decrementing
        // `active` would deadlock `run`.  Kernels are plain slice loops
        // that should never panic — treat it as fatal, loudly.
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(id))).is_err() {
            eprintln!("qsim worker {id}: kernel panicked; aborting");
            std::process::abort();
        }
        let mut job = shared.job.lock().unwrap();
        job.active -= 1;
        if job.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// Blocks until every worker has finished the current epoch, then clears
/// the task slot.  Used via `Drop` so [`Pool::run`] waits even when the
/// calling thread's own share of the task panics — workers must never
/// outlive the region borrow.
struct WaitGuard<'a> {
    shared: &'a Shared,
}

impl Drop for WaitGuard<'_> {
    fn drop(&mut self) {
        let mut job = self.shared.job.lock().unwrap();
        while job.active > 0 {
            job = self.shared.done.wait(job).unwrap();
        }
        job.task = None;
    }
}

impl Pool {
    /// Build a pool.  `threads == 0` means "auto" (available parallelism);
    /// `threads == 1` spawns nothing.  Requests are capped at
    /// [`MAX_THREADS`] — oversubscription beyond that is never useful here,
    /// and an unchecked count (e.g. a config value gone through integer
    /// conversion) must not exhaust OS threads.
    pub fn new(threads: usize) -> Pool {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
        } else {
            threads.min(MAX_THREADS)
        };
        if threads <= 1 {
            return Pool {
                shared: None,
                handles: Vec::new(),
                run_lock: Mutex::new(()),
                threads: 1,
            };
        }
        let shared = Arc::new(Shared {
            job: Mutex::new(JobState { epoch: 0, task: None, active: 0, shutdown: false }),
            start: Condvar::new(),
            done: Condvar::new(),
        });
        let handles = (1..threads)
            .map(|id| {
                let s = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("qsim-worker-{id}"))
                    .spawn(move || worker_loop(s, id))
                    .expect("spawning qsim worker thread")
            })
            .collect();
        Pool { shared: Some(shared), handles, run_lock: Mutex::new(()), threads }
    }

    /// A single-threaded pool behind an `Arc` (the default for tapes and
    /// optimizers constructed without explicit parallelism).
    pub fn single() -> Arc<Pool> {
        Arc::new(Pool::new(1))
    }

    /// Worker-slot count (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `task(worker_id)` once per worker slot `0..threads()`, in
    /// parallel; the calling thread takes slot 0.  Returns only after every
    /// slot has finished.  Concurrent `run` calls serialize.
    pub fn run(&self, task: &(dyn Fn(usize) + Sync)) {
        let Some(shared) = &self.shared else {
            task(0);
            return;
        };
        let _region = self.run_lock.lock().unwrap();
        // Erase the caller's lifetime: workers only dereference between the
        // epoch bump and the active == 0 handshake below, while `task` is
        // still borrowed by this frame.
        let task_static: &'static (dyn Fn(usize) + Sync + 'static) =
            unsafe { std::mem::transmute(task) };
        let ptr = TaskPtr(task_static as *const _);
        {
            let mut job = shared.job.lock().unwrap();
            job.task = Some(ptr);
            job.epoch = job.epoch.wrapping_add(1);
            job.active = self.threads - 1;
            shared.start.notify_all();
        }
        // The guard waits for every worker even if `task(0)` unwinds, so
        // the erased borrow can never dangle.
        let _wait = WaitGuard { shared };
        task(0);
    }

    /// Run `f` once per element of `parts` — part `i` on worker slot `i` —
    /// and return the parts once every call has finished.  This is the one
    /// fork-join entry point the kernel call sites share: they build their
    /// disjoint views (row bands, element spans), and the pool owns the
    /// dispatch.  At most [`Pool::threads`] parts are supported per call.
    pub fn run_parts<T, F>(&self, mut parts: Vec<T>, f: F) -> Vec<T>
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        assert!(parts.len() <= self.threads, "more parts than worker slots");
        if parts.len() <= 1 {
            if let Some(p) = parts.first_mut() {
                f(p);
            }
            return parts;
        }
        let slots: Vec<Mutex<&mut T>> = parts.iter_mut().map(Mutex::new).collect();
        self.run(&|wid| {
            if let Some(slot) = slots.get(wid) {
                let mut guard = slot.lock().unwrap();
                f(&mut **guard);
            }
        });
        drop(slots);
        parts
    }

    /// Parallel in-place transform over contiguous chunks of `data`.
    ///
    /// `f(offset, chunk)` receives each chunk together with its global
    /// element offset, so counter-keyed consumers can address per-element
    /// state (dither words) position-wise.  Chunks are disjoint and cover
    /// `data` exactly once; `f` must be element-local (no cross-chunk
    /// dependence) for results to be schedule-independent.  Slices shorter
    /// than `min_chunk` per thread degrade gracefully toward fewer chunks
    /// (down to a plain sequential call).
    pub fn for_chunks_mut<T, F>(&self, data: &mut [T], min_chunk: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let n = data.len();
        let max_chunks = if min_chunk <= 1 { n } else { n / min_chunk };
        let t = self.threads.min(max_chunks).max(1);
        if t <= 1 {
            f(0, data);
            return;
        }
        let per = n.div_ceil(t);
        let mut parts: Vec<(usize, &mut [T])> = Vec::with_capacity(t);
        let mut rest = data;
        let mut off = 0usize;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(take);
            parts.push((off, head));
            off += take;
            rest = tail;
        }
        self.run_parts(parts, |(off, chunk)| f(*off, &mut **chunk));
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        if let Some(shared) = &self.shared {
            {
                let mut job = shared.job.lock().unwrap();
                job.shutdown = true;
                shared.start.notify_all();
            }
            for h in self.handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn single_threaded_pool_runs_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let hits = AtomicUsize::new(0);
        pool.run(&|wid| {
            assert_eq!(wid, 0);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn every_worker_slot_runs_exactly_once_per_region() {
        let pool = Pool::new(4);
        assert_eq!(pool.threads(), 4);
        for _ in 0..50 {
            let hits: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
            pool.run(&|wid| {
                hits[wid].fetch_add(1, Ordering::SeqCst);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "slot {i}");
            }
        }
    }

    #[test]
    fn for_chunks_mut_covers_disjointly_with_offsets() {
        for threads in [1usize, 2, 3, 4] {
            let pool = Pool::new(threads);
            for len in [0usize, 1, 7, 100, 1001] {
                let mut data = vec![0u32; len];
                pool.for_chunks_mut(&mut data, 1, |off, chunk| {
                    for (j, x) in chunk.iter_mut().enumerate() {
                        // each element written exactly once with its index
                        *x = (off + j) as u32 + 1;
                    }
                });
                for (i, &x) in data.iter().enumerate() {
                    assert_eq!(x, i as u32 + 1, "threads={threads} len={len} i={i}");
                }
            }
        }
    }

    #[test]
    fn for_chunks_mut_respects_min_chunk() {
        let pool = Pool::new(4);
        let mut data = vec![0u8; 100];
        // min_chunk larger than the slice → one sequential chunk at offset 0
        let regions = AtomicUsize::new(0);
        pool.for_chunks_mut(&mut data, 1000, |off, chunk| {
            assert_eq!(off, 0);
            assert_eq!(chunk.len(), 100);
            regions.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(regions.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn auto_sizing_uses_available_parallelism() {
        let pool = Pool::new(0);
        assert!(pool.threads() >= 1);
    }
}
