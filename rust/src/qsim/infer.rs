//! `qsim::infer` — tape-free inference executor and the `repro serve` stack.
//!
//! Training pays for its tape: every forward op records a node, reserves
//! gradient storage, and rebuilds the graph object per batch.  A frozen
//! forward needs none of that — the graph is static, the weights are
//! constant, and only the batch payloads (dense rows, gather indices,
//! targets, labels) change between requests.  This module compiles a
//! frozen graph **once** into an [`InferPlan`]:
//!
//! * [`Tape::export_program`] lifts the recorded graph to the
//!   [`verify`](super::verify) IR — the same IR the linter and fuzzer
//!   already pin against the tape, so the plan replays a *validated*
//!   program, not a re-derivation of the model;
//! * [`Tape::export_values`] seeds the arena: one buffer per node, leaf
//!   buffers holding the weights (native-16 tensors widen exactly once
//!   here, on tape entry — read-only serving never re-widens), interior
//!   buffers pre-sized to their activation shapes;
//! * [`InferPlan::run`] replays the program through the same Fast/Simd
//!   kernels (fused affine / attention / losses included) writing into the
//!   arena in place — zero tape nodes, zero grad buffers, and no per-batch
//!   allocation in steady state (the `Reference` backend's matmul
//!   allocates fresh outputs, exactly as it does under the tape).
//!
//! **Bit-identity contract**: for every op the plan executes the same
//! kernel the tape's forward executes, with the same one-rounding-per-op
//! policy, over the same fp32 buffers.  The unit tests pin plan-vs-tape
//! equality for every `OpIr` variant on every backend, and the serve
//! golden tests extend that to checkpointed models end-to-end.
//!
//! On top of the executor sits `repro serve`: a line-oriented TCP scoring
//! server with **dynamic micro-batching**.  Connections enqueue requests;
//! a single batcher thread coalesces the queue for at most
//! `batch_window_us` (or until `max_batch` requests are waiting), binds
//! the whole group into the plan as one padded batch, runs it, and fans
//! the per-row results back to the waiting connections.  Because every
//! scored row is row-local (DLRM) or sequence-local (gpt-nano causal
//! attention), padding a partial batch to plan capacity cannot change any
//! real row's bits — batching is a latency/throughput knob, never a
//! numerics knob.  [`tape_oracle_replies`] recomputes each request
//! one-at-a-time on a fresh tape and must agree bit-for-bit; CI diffs the
//! two digests on a pinned corpus.
//!
//! Wire protocol (UTF-8 lines, one request per line, one reply per line):
//!
//! ```text
//! dlrm <f0> .. <f{D-1}> | <i0> .. <i{T-1}>   ->  ctr <logit-bits:08x> <logit>
//! gpt <t0> <t1> ..                           ->  lm <next-token> <logit-bits:08x>
//! shutdown                                   ->  ok shutting down
//! anything else                              ->  err <reason>
//! ```

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::dlrm::{CtrBatch, DlrmModel};
use super::gpt::{GptModel, LmBatch};
use super::mlp::{MlpModel, SpiralBatch};
use super::pool::Pool;
use super::tape::{attn_forward_seqs, layernorm_rows, xent_row, QPolicy, Tape};
use super::tensor::Tensor;
use super::verify::{OpIr, Program};
use super::Backend;

// ---------------------------------------------------------------------------
// The compiled plan
// ---------------------------------------------------------------------------

/// A frozen graph compiled to an arena of per-node buffers plus the IR
/// program that fills them.  Weights live in leaf buffers (widened from
/// native-16 storage exactly once, at compile); batch payloads are rebound
/// through [`InferPlan::set_leaf`] / [`InferPlan::set_gather_idx`] /
/// [`InferPlan::set_xent_targets`] / [`InferPlan::set_bce_labels`]; and
/// [`InferPlan::run`] replays every interior node in place.
pub struct InferPlan {
    prog: Program,
    bufs: Vec<Tensor>,
    /// Attention probability scratch, per node (empty for non-attention
    /// nodes) — the tape keeps these for backward; the plan only needs
    /// them as kernel workspace, but pre-sizes them all the same so `run`
    /// never allocates.
    probs: Vec<Vec<f32>>,
    policy: QPolicy,
    pool: Arc<Pool>,
}

impl InferPlan {
    /// Snapshot a recorded frozen graph into a replayable plan.  The tape
    /// is only read; callers typically drop it immediately after.
    pub fn compile(tape: &Tape, policy: QPolicy) -> Self {
        let prog = tape.export_program();
        let bufs = tape.export_values();
        let mut probs = vec![Vec::new(); prog.nodes.len()];
        for (i, node) in prog.nodes.iter().enumerate() {
            if let OpIr::CausalAttn { seqs, .. } = &node.op {
                let t_len = node.rows / (*seqs).max(1);
                probs[i] = vec![0.0; node.rows * t_len];
            }
        }
        Self { prog, bufs, probs, policy, pool: Pool::single() }
    }

    pub fn policy(&self) -> QPolicy {
        self.policy
    }

    pub fn node_count(&self) -> usize {
        self.prog.nodes.len()
    }

    /// The current value buffer of a node (by tape [`Var`](super::Var)
    /// index).  Valid after [`InferPlan::run`]; before the first run it
    /// holds the compile-time snapshot.
    pub fn value(&self, node: usize) -> &Tensor {
        &self.bufs[node]
    }

    /// Rebind a leaf's payload (e.g. the dense feature block).  Shapes are
    /// frozen at compile: the payload must match the leaf's length.
    pub fn set_leaf(&mut self, node: usize, data: &[f32]) {
        assert!(
            matches!(self.prog.nodes[node].op, OpIr::Leaf),
            "set_leaf on a non-leaf node"
        );
        let buf = &mut self.bufs[node];
        assert_eq!(buf.data.len(), data.len(), "leaf payload length changed");
        buf.data.copy_from_slice(data);
    }

    /// Rebind a gather's row indices (token ids, embedding lookups).
    pub fn set_gather_idx(&mut self, node: usize, idx: &[usize]) {
        match &mut self.prog.nodes[node].op {
            OpIr::GatherRows { idx: slot, .. } => {
                assert_eq!(slot.len(), idx.len(), "gather index count changed");
                slot.copy_from_slice(idx);
            }
            _ => panic!("set_gather_idx on a non-gather node"),
        }
    }

    /// Rebind a softmax-xent node's per-row target classes.
    pub fn set_xent_targets(&mut self, node: usize, targets: &[usize]) {
        match &mut self.prog.nodes[node].op {
            OpIr::SoftmaxXent { targets: slot, .. } => {
                assert_eq!(slot.len(), targets.len(), "target count changed");
                slot.copy_from_slice(targets);
            }
            _ => panic!("set_xent_targets on a non-xent node"),
        }
    }

    /// Rebind a BCE node's labels.
    pub fn set_bce_labels(&mut self, node: usize, labels: &[f32]) {
        match &mut self.prog.nodes[node].op {
            OpIr::BceLoss { labels: slot, .. } => {
                assert_eq!(slot.len(), labels.len(), "label count changed");
                slot.copy_from_slice(labels);
            }
            _ => panic!("set_bce_labels on a non-bce node"),
        }
    }

    /// Replay every interior node into the arena.  Each arm mirrors the
    /// corresponding `Tape` forward op exactly — same kernel, same
    /// rounding placement — so the filled buffers are bit-identical to
    /// what a fresh tape would record for the same leaf payloads.
    pub fn run(&mut self) {
        let policy = self.policy;
        for i in 0..self.prog.nodes.len() {
            let (prev, rest) = self.bufs.split_at_mut(i);
            let out = &mut rest[0];
            match &self.prog.nodes[i].op {
                OpIr::Leaf => {}
                OpIr::MatMul(a, b) => {
                    matmul_into(&prev[*a], &prev[*b], out, policy, &self.pool);
                }
                OpIr::Add(a, b) => binary_into(&prev[*a], &prev[*b], out, policy, |x, y| x + y),
                OpIr::Sub(a, b) => binary_into(&prev[*a], &prev[*b], out, policy, |x, y| x - y),
                OpIr::Mul(a, b) => binary_into(&prev[*a], &prev[*b], out, policy, |x, y| x * y),
                OpIr::Relu(a) => unary_into(&prev[*a], out, policy, |x| x.max(0.0)),
                OpIr::Sigmoid(a) => {
                    unary_into(&prev[*a], out, policy, |x| 1.0 / (1.0 + (-x).exp()));
                }
                OpIr::Tanh(a) => unary_into(&prev[*a], out, policy, f32::tanh),
                OpIr::Scale(a, c) => {
                    let c = *c;
                    unary_into(&prev[*a], out, policy, move |x| c * x);
                }
                OpIr::GatherRows { x, idx } => {
                    let tv = &prev[*x];
                    let cols = tv.cols;
                    out.rows = idx.len();
                    out.cols = cols;
                    out.data.clear();
                    out.data.reserve(idx.len() * cols);
                    for &r in idx {
                        out.data.extend_from_slice(&tv.data[r * cols..(r + 1) * cols]);
                    }
                    // gather is a memory op: values already in-format
                }
                OpIr::AddRow(a, b) => {
                    let (av, bv) = (&prev[*a], &prev[*b]);
                    out.rows = av.rows;
                    out.cols = av.cols;
                    out.data.clear();
                    out.data.reserve(av.data.len());
                    if av.cols > 0 {
                        for arow in av.data.chunks_exact(av.cols) {
                            out.data.extend(arow.iter().zip(&bv.data).map(|(&x, &b)| x + b));
                        }
                    }
                    policy.q_slice(&mut out.data);
                }
                OpIr::Affine { x, w, b, relu } => {
                    matmul_into(&prev[*x], &prev[*w], out, policy, &self.pool);
                    let bv = &prev[*b];
                    if out.cols > 0 {
                        for orow in out.data.chunks_exact_mut(out.cols) {
                            for (o, &bx) in orow.iter_mut().zip(&bv.data) {
                                *o += bx;
                            }
                        }
                    }
                    policy.q_slice(&mut out.data);
                    if *relu {
                        for o in &mut out.data {
                            *o = o.max(0.0);
                        }
                        policy.q_slice(&mut out.data);
                    }
                }
                OpIr::ConcatCols(parts) => {
                    let rows = prev[parts[0]].rows;
                    let total: usize = parts.iter().map(|&p| prev[p].cols).sum();
                    out.rows = rows;
                    out.cols = total;
                    out.data.clear();
                    out.data.resize(rows * total, 0.0);
                    let mut off = 0;
                    for &p in parts {
                        let pv = &prev[p];
                        debug_assert_eq!(pv.rows, rows, "concat row mismatch");
                        for r in 0..rows {
                            out.data[r * total + off..r * total + off + pv.cols]
                                .copy_from_slice(&pv.data[r * pv.cols..(r + 1) * pv.cols]);
                        }
                        off += pv.cols;
                    }
                }
                OpIr::MatMulNT(a, b) => {
                    match policy.backend {
                        Backend::Fast | Backend::Simd => {
                            prev[*a].matmul_nt_into_pooled(&prev[*b], out, &self.pool);
                        }
                        Backend::Reference => prev[*a].matmul_nt_into(&prev[*b], out),
                    }
                    policy.q_slice(&mut out.data);
                }
                OpIr::LayerNorm { x, eps } => {
                    let av = &prev[*x];
                    out.rows = av.rows;
                    out.cols = av.cols;
                    out.data.clear();
                    out.data.resize(av.data.len(), 0.0);
                    layernorm_rows(&av.data, av.cols, *eps, &mut out.data, policy);
                }
                OpIr::CausalAttn { q, k, v, seqs } => {
                    let (qv, kv, vv) = (&prev[*q], &prev[*k], &prev[*v]);
                    let (rows, d) = (qv.rows, qv.cols);
                    let t_len = rows / (*seqs).max(1);
                    let alpha = 1.0 / (d.max(1) as f32).sqrt();
                    out.rows = rows;
                    out.cols = d;
                    out.data.clear();
                    out.data.resize(rows * d, 0.0);
                    let probs = &mut self.probs[i];
                    probs.clear();
                    probs.resize(rows * t_len, 0.0);
                    attn_forward_seqs(
                        &qv.data, &kv.data, &vv.data, t_len, d, alpha, 0, &mut out.data, probs,
                        policy,
                    );
                }
                OpIr::SoftmaxXent { logits, targets } => {
                    let lv = &prev[*logits];
                    let cols = lv.cols;
                    let mut acc = 0f64;
                    for (r, &tg) in targets.iter().enumerate() {
                        acc += xent_row(&lv.data[r * cols..(r + 1) * cols], tg) as f64;
                    }
                    scalar_into(out, (acc / lv.rows.max(1) as f64) as f32, policy);
                }
                OpIr::MeanAll(a) => {
                    let v = &prev[*a];
                    let m = v.data.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
                    scalar_into(out, m as f32, policy);
                }
                OpIr::MseLoss { diff } => {
                    let dv = &prev[*diff];
                    let m = dv.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                        / dv.len() as f64;
                    scalar_into(out, 0.5 * m as f32, policy);
                }
                OpIr::BceLoss { logits, labels } => {
                    let lv = &prev[*logits];
                    let mut acc = 0f64;
                    for (&z, &y) in lv.data.iter().zip(labels) {
                        let l = z.max(0.0) - z * y + (1.0 + (-z.abs()).exp()).ln();
                        acc += l as f64;
                    }
                    scalar_into(out, (acc / lv.len() as f64) as f32, policy);
                }
            }
        }
    }
}

/// Backend-dispatched matmul into an arena buffer — the exact dispatch
/// `Tape::matmul` / the matmul half of `Tape::affine` performs: Fast/Simd
/// round inside the producing kernel (`fuse_fmt`), Reference rounds in a
/// post-pass (fuzzer-pinned bit-identical).
fn matmul_into(a: &Tensor, b: &Tensor, out: &mut Tensor, policy: QPolicy, pool: &Pool) {
    match policy.backend {
        Backend::Fast => a.matmul_into_pooled(b, out, policy.fuse_fmt(), pool),
        Backend::Simd => a.matmul_into_pooled_simd(b, out, policy.fuse_fmt(), pool),
        Backend::Reference => {
            *out = a.matmul_reference(b);
            policy.q_slice(&mut out.data);
        }
    }
}

fn unary_into(a: &Tensor, out: &mut Tensor, policy: QPolicy, f: impl Fn(f32) -> f32) {
    out.rows = a.rows;
    out.cols = a.cols;
    out.data.clear();
    out.data.extend(a.data.iter().map(|&x| f(x)));
    policy.q_slice(&mut out.data);
}

fn binary_into(
    a: &Tensor,
    b: &Tensor,
    out: &mut Tensor,
    policy: QPolicy,
    f: impl Fn(f32, f32) -> f32,
) {
    out.rows = a.rows;
    out.cols = a.cols;
    out.data.clear();
    out.data.extend(a.data.iter().zip(&b.data).map(|(&x, &y)| f(x, y)));
    policy.q_slice(&mut out.data);
}

fn scalar_into(out: &mut Tensor, v: f32, policy: QPolicy) {
    out.rows = 1;
    out.cols = 1;
    out.data.clear();
    out.data.push(v);
    policy.q_slice(&mut out.data);
}

// ---------------------------------------------------------------------------
// Per-app plans: the frozen graph + the node ids that change per batch
// ---------------------------------------------------------------------------

/// Compiled DLRM CTR scorer.  Capacity (batch rows) is fixed at compile;
/// partial batches are padded by the caller (row-local graph: padding
/// never changes a real row's bits).
pub struct DlrmPlan {
    plan: InferPlan,
    gathers: Vec<usize>,
    dense: usize,
    logits: usize,
    loss: usize,
    capacity: usize,
    dense_dim: usize,
}

impl DlrmPlan {
    /// Compile from any representative batch — only its shape matters.
    pub fn compile(model: &DlrmModel, batch: &CtrBatch, policy: QPolicy) -> Self {
        let mut t = Tape::new(policy);
        let v = model.frozen_graph_into(&mut t, batch);
        Self {
            plan: InferPlan::compile(&t, policy),
            gathers: v.gathers.iter().map(|g| g.0).collect(),
            dense: v.dense.0,
            logits: v.logits.0,
            loss: v.loss.0,
            capacity: batch.dense.rows,
            dense_dim: batch.dense.cols,
        }
    }

    /// Batch rows the plan was compiled for.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Rebind one batch's payloads without running.
    pub fn bind(&mut self, dense: &[f32], cat: &[Vec<usize>], labels: &[f32]) {
        assert_eq!(dense.len(), self.capacity * self.dense_dim, "dense payload shape changed");
        assert_eq!(cat.len(), self.gathers.len(), "categorical table count changed");
        self.plan.set_leaf(self.dense, dense);
        for (&g, idx) in self.gathers.iter().zip(cat) {
            self.plan.set_gather_idx(g, idx);
        }
        self.plan.set_bce_labels(self.loss, labels);
    }

    pub fn run(&mut self) {
        self.plan.run();
    }

    pub fn loss(&self) -> f32 {
        self.plan.value(self.loss).item()
    }

    /// Per-example logits, shape (capacity, 1).
    pub fn logits(&self) -> &Tensor {
        self.plan.value(self.logits)
    }

    /// One-call eval replacement for [`DlrmModel::eval_scores`] —
    /// bit-identical output, no tape.
    pub fn score(&mut self, batch: &CtrBatch) -> (f32, Vec<f32>) {
        self.bind(&batch.dense.data, &batch.cat, &batch.labels.data);
        self.run();
        (self.loss(), self.logits().data.clone())
    }
}

/// Compiled gpt-nano scorer over `seqs` packed sequences of the model's
/// full context length.
pub struct GptPlan {
    plan: InferPlan,
    tok_gather: usize,
    logits: usize,
    loss: usize,
    seqs: usize,
    t_len: usize,
}

impl GptPlan {
    pub fn compile(model: &GptModel, batch: &LmBatch, policy: QPolicy) -> Self {
        let mut t = Tape::new(policy);
        let v = model.frozen_graph_into(&mut t, batch);
        let t_len = model.cfg.seq_len;
        Self {
            plan: InferPlan::compile(&t, policy),
            tok_gather: v.tok_gather.0,
            logits: v.logits.0,
            loss: v.loss.0,
            seqs: batch.tokens.len() / t_len.max(1),
            t_len,
        }
    }

    pub fn capacity_seqs(&self) -> usize {
        self.seqs
    }

    pub fn seq_len(&self) -> usize {
        self.t_len
    }

    /// Rebind tokens only (serving: targets stay at their compile-time
    /// zeros — the loss node is computed but unused).
    pub fn bind_tokens(&mut self, tokens: &[usize]) {
        self.plan.set_gather_idx(self.tok_gather, tokens);
    }

    pub fn bind(&mut self, tokens: &[usize], targets: &[usize]) {
        self.plan.set_gather_idx(self.tok_gather, tokens);
        self.plan.set_xent_targets(self.loss, targets);
    }

    pub fn run(&mut self) {
        self.plan.run();
    }

    pub fn loss(&self) -> f32 {
        self.plan.value(self.loss).item()
    }

    /// Next-token logits, shape (seqs·T, vocab): row `s·T + p` scores
    /// position `p+1` of sequence `s`.
    pub fn logits(&self) -> &Tensor {
        self.plan.value(self.logits)
    }

    /// One-call eval replacement for [`GptModel::eval_loss`] —
    /// bit-identical loss, no tape.
    pub fn score(&mut self, batch: &LmBatch) -> f32 {
        self.bind(&batch.tokens, &batch.targets);
        self.run();
        self.loss()
    }
}

/// Compiled spiral-MLP scorer.
pub struct MlpPlan {
    plan: InferPlan,
    x: usize,
    logits: usize,
    loss: usize,
}

impl MlpPlan {
    pub fn compile(model: &MlpModel, batch: &SpiralBatch, policy: QPolicy) -> Self {
        let mut t = Tape::new(policy);
        let v = model.frozen_graph_into(&mut t, batch);
        Self {
            plan: InferPlan::compile(&t, policy),
            x: v.x.0,
            logits: v.logits.0,
            loss: v.loss.0,
        }
    }

    /// One-call eval replacement for [`MlpModel::eval_scores`] —
    /// bit-identical output, no tape.
    pub fn score(&mut self, batch: &SpiralBatch) -> (f32, Tensor) {
        self.plan.set_leaf(self.x, &batch.x.data);
        self.plan.set_xent_targets(self.loss, &batch.y);
        self.plan.run();
        (self.plan.value(self.loss).item(), self.plan.value(self.logits).clone())
    }
}

// ---------------------------------------------------------------------------
// The serving loop: TCP line protocol + dynamic micro-batching
// ---------------------------------------------------------------------------

/// Serving knobs — the `serve.*` TOML table and the `repro serve` flags.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    /// Listen address; port 0 picks a free port (tests).
    pub addr: String,
    /// Micro-batch coalescing window in microseconds: after the first
    /// request of a batch arrives, the batcher waits at most this long
    /// for more before scoring.  0 scores each queue drain immediately.
    pub batch_window_us: u64,
    /// Hard batch-size cap (also the compiled plan's capacity).
    pub max_batch: usize,
    /// Kernel backend requests are scored on.
    pub backend: Backend,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            addr: "127.0.0.1:7878".to_string(),
            batch_window_us: 200,
            max_batch: 32,
            backend: Backend::Fast,
        }
    }
}

/// A frozen model behind the server — the two serving workloads.
pub enum ServeApp {
    Dlrm(Box<DlrmModel>),
    Gpt(Box<GptModel>),
}

impl ServeApp {
    pub fn name(&self) -> &'static str {
        match self {
            ServeApp::Dlrm(_) => "dlrm",
            ServeApp::Gpt(_) => "gpt-nano",
        }
    }

    fn spec(&self) -> AppSpec {
        match self {
            ServeApp::Dlrm(m) => AppSpec::Ctr {
                dense_dim: m.cfg.dense_dim,
                tables: m.cfg.num_tables,
                table_size: m.cfg.table_size,
            },
            ServeApp::Gpt(m) => AppSpec::Lm { vocab: m.cfg.vocab, t_len: m.cfg.seq_len },
        }
    }
}

/// Request-shape metadata shared by the parser, the batcher and the
/// oracle — everything needed to validate a line without the model.
#[derive(Clone, Copy)]
enum AppSpec {
    Ctr { dense_dim: usize, tables: usize, table_size: usize },
    Lm { vocab: usize, t_len: usize },
}

/// One parsed request line.
enum ParsedLine {
    Ctr { dense: Vec<f32>, cat: Vec<usize> },
    Lm { tokens: Vec<usize> },
    Shutdown,
}

/// Parse + validate one request line against the served app's shape.
/// Errors are returned as client-facing strings (the `err ` reply body).
fn parse_line(line: &str, spec: AppSpec) -> std::result::Result<ParsedLine, String> {
    if line == "shutdown" {
        return Ok(ParsedLine::Shutdown);
    }
    let (tag, rest) = line.split_once(' ').ok_or_else(|| format!("bare request {line:?}"))?;
    match (tag, spec) {
        ("dlrm", AppSpec::Ctr { dense_dim, tables, table_size }) => {
            let (d, c) = rest
                .split_once('|')
                .ok_or_else(|| "dlrm request needs `<dense..> | <cat..>`".to_string())?;
            let dense = d
                .split_whitespace()
                .map(|s| s.parse::<f32>().map_err(|_| format!("bad dense value {s:?}")))
                .collect::<std::result::Result<Vec<f32>, String>>()?;
            let cat = c
                .split_whitespace()
                .map(|s| s.parse::<usize>().map_err(|_| format!("bad cat index {s:?}")))
                .collect::<std::result::Result<Vec<usize>, String>>()?;
            if dense.len() != dense_dim {
                return Err(format!("want {dense_dim} dense features, got {}", dense.len()));
            }
            if cat.len() != tables {
                return Err(format!("want {tables} cat indices, got {}", cat.len()));
            }
            if let Some(&ix) = cat.iter().find(|&&ix| ix >= table_size) {
                return Err(format!("cat index {ix} out of range ({table_size} rows)"));
            }
            Ok(ParsedLine::Ctr { dense, cat })
        }
        ("gpt", AppSpec::Lm { vocab, t_len }) => {
            let tokens = rest
                .split_whitespace()
                .map(|s| s.parse::<usize>().map_err(|_| format!("bad token {s:?}")))
                .collect::<std::result::Result<Vec<usize>, String>>()?;
            if tokens.is_empty() || tokens.len() > t_len {
                return Err(format!("want 1..={t_len} tokens, got {}", tokens.len()));
            }
            if let Some(&tk) = tokens.iter().find(|&&tk| tk >= vocab) {
                return Err(format!("token {tk} out of range (vocab {vocab})"));
            }
            Ok(ParsedLine::Lm { tokens })
        }
        (other, _) => Err(format!("request tag {other:?} does not match the served app")),
    }
}

/// Reply line for one scored CTR row: the logit as exact bits + decimal.
fn ctr_reply(z: f32) -> String {
    format!("ctr {:08x} {z}", z.to_bits())
}

/// Reply line for one scored LM request: greedy next token + its logit
/// bits (the argmax of the last real position's next-token row).
fn lm_reply(best: usize, z: f32) -> String {
    format!("lm {best} {:08x}", z.to_bits())
}

/// First-max argmax over row `row` of `t` — ties resolve to the lowest
/// column, matching the mlp eval's accuracy rule.
fn argmax_row(t: &Tensor, row: usize) -> (usize, f32) {
    let r = &t.data[row * t.cols..(row + 1) * t.cols];
    let mut best = 0usize;
    for (c, &v) in r.iter().enumerate() {
        if v > r[best] {
            best = c;
        }
    }
    (best, r[best])
}

/// The batcher's compiled plan plus its padded staging buffers (reused
/// every round — no per-batch allocation).
enum AppPlan {
    Ctr {
        plan: DlrmPlan,
        dense: Vec<f32>,
        cat: Vec<Vec<usize>>,
        labels: Vec<f32>,
        dense_dim: usize,
    },
    Lm {
        plan: GptPlan,
        tokens: Vec<usize>,
        t_len: usize,
    },
}

impl AppPlan {
    fn compile(app: ServeApp, policy: QPolicy, max_batch: usize) -> AppPlan {
        match app {
            ServeApp::Dlrm(model) => {
                let cfg = &model.cfg;
                let shape = CtrBatch {
                    dense: Tensor::zeros(max_batch, cfg.dense_dim),
                    cat: vec![vec![0; max_batch]; cfg.num_tables],
                    labels: Tensor::zeros(1, max_batch),
                };
                AppPlan::Ctr {
                    plan: DlrmPlan::compile(&model, &shape, policy),
                    dense: vec![0.0; max_batch * cfg.dense_dim],
                    cat: vec![vec![0; max_batch]; cfg.num_tables],
                    labels: vec![0.0; max_batch],
                    dense_dim: cfg.dense_dim,
                }
            }
            ServeApp::Gpt(model) => {
                let t_len = model.cfg.seq_len;
                let shape = LmBatch {
                    tokens: vec![0; max_batch * t_len],
                    targets: vec![0; max_batch * t_len],
                };
                AppPlan::Lm {
                    plan: GptPlan::compile(&model, &shape, policy),
                    tokens: vec![0; max_batch * t_len],
                    t_len,
                }
            }
        }
    }

    /// Score every parsed request as one padded batch and write each
    /// reply into its job's slot.  Padding rows/sequences are zeros;
    /// row/sequence locality makes them invisible to the real slots.
    fn score_into(&mut self, rows: &[(usize, ParsedLine)], replies: &mut [Option<String>]) {
        match self {
            AppPlan::Ctr { plan, dense, cat, labels, dense_dim } => {
                for d in dense.iter_mut() {
                    *d = 0.0;
                }
                for col in cat.iter_mut() {
                    col.iter_mut().for_each(|ix| *ix = 0);
                }
                for (slot, (_, p)) in rows.iter().enumerate() {
                    let ParsedLine::Ctr { dense: rd, cat: rc } = p else { continue };
                    dense[slot * *dense_dim..(slot + 1) * *dense_dim].copy_from_slice(rd);
                    for (t, &ix) in rc.iter().enumerate() {
                        cat[t][slot] = ix;
                    }
                }
                plan.bind(dense, cat, labels);
                plan.run();
                let lg = plan.logits();
                for (slot, (ji, _)) in rows.iter().enumerate() {
                    replies[*ji] = Some(ctr_reply(lg.data[slot]));
                }
            }
            AppPlan::Lm { plan, tokens, t_len } => {
                for tk in tokens.iter_mut() {
                    *tk = 0;
                }
                let mut lens = Vec::with_capacity(rows.len());
                for (slot, (_, p)) in rows.iter().enumerate() {
                    let ParsedLine::Lm { tokens: rt } = p else { continue };
                    tokens[slot * *t_len..slot * *t_len + rt.len()].copy_from_slice(rt);
                    lens.push(rt.len());
                }
                plan.bind_tokens(tokens);
                plan.run();
                let lg = plan.logits();
                for ((slot, (ji, _)), len) in rows.iter().enumerate().zip(lens) {
                    let (best, z) = argmax_row(lg, slot * *t_len + (len - 1));
                    replies[*ji] = Some(lm_reply(best, z));
                }
            }
        }
    }
}

/// One queued request: the raw line and where to send the reply.
struct Job {
    line: String,
    reply: mpsc::Sender<String>,
}

/// Handle to a running server: the bound address (useful with port 0)
/// and the accept thread.
pub struct ServerHandle {
    addr: SocketAddr,
    accept: thread::JoinHandle<()>,
}

impl ServerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the server exits (a client sent `shutdown`).
    pub fn join(self) {
        let _ = self.accept.join();
    }

    /// Send `shutdown` ourselves and wait for a clean exit.
    pub fn shutdown(self) -> Result<()> {
        let stream = connect_retry(&self.addr.to_string())?;
        let mut writer = BufWriter::new(stream.try_clone().context("cloning shutdown stream")?);
        writeln!(writer, "shutdown").context("sending shutdown")?;
        writer.flush().context("flushing shutdown")?;
        let mut reply = String::new();
        let _ = BufReader::new(stream).read_line(&mut reply);
        self.join();
        Ok(())
    }
}

/// Start the scoring server: bind, compile the plan once, then accept
/// connections forever (until a `shutdown` request).  One thread per
/// connection feeds a single batcher thread over a channel; the batcher
/// owns the plan, so scoring is strictly serialized — batching, not
/// locking, is the concurrency story.
pub fn spawn_server(app: ServeApp, policy: QPolicy, cfg: &ServeConfig) -> Result<ServerHandle> {
    let listener =
        TcpListener::bind(&cfg.addr).with_context(|| format!("binding {}", cfg.addr))?;
    let addr = listener.local_addr().context("server local addr")?;
    let spec = app.spec();
    let window = Duration::from_micros(cfg.batch_window_us);
    let max_batch = cfg.max_batch.max(1);
    let plan = AppPlan::compile(app, policy, max_batch);
    let stop = Arc::new(AtomicBool::new(false));
    let (tx, rx) = mpsc::channel::<Job>();
    {
        let stop = Arc::clone(&stop);
        thread::spawn(move || batcher_loop(plan, spec, rx, window, max_batch, stop, addr));
    }
    let accept = {
        let stop = Arc::clone(&stop);
        thread::spawn(move || {
            for stream in listener.incoming() {
                if stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = stream else { continue };
                let tx = tx.clone();
                thread::spawn(move || conn_loop(stream, tx));
            }
        })
    };
    Ok(ServerHandle { addr, accept })
}

/// Per-connection pump: read request lines, enqueue them, write replies
/// back in request order.  Exits when the client hangs up or the server
/// stops.
fn conn_loop(stream: TcpStream, tx: mpsc::Sender<Job>) {
    stream.set_nodelay(true).ok();
    let Ok(rd) = stream.try_clone() else { return };
    let mut reader = BufReader::new(rd);
    let mut writer = BufWriter::new(stream);
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => return,
            Ok(_) => {}
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let (rtx, rrx) = mpsc::channel();
        if tx.send(Job { line: trimmed.to_string(), reply: rtx }).is_err() {
            return;
        }
        let Ok(reply) = rrx.recv() else { return };
        if writeln!(writer, "{reply}").and_then(|()| writer.flush()).is_err() {
            return;
        }
    }
}

/// The micro-batching core: block for the first request, then coalesce
/// the queue for at most `window` (or until `max_batch`), score the
/// group as one padded batch, fan replies back.
fn batcher_loop(
    mut plan: AppPlan,
    spec: AppSpec,
    rx: mpsc::Receiver<Job>,
    window: Duration,
    max_batch: usize,
    stop: Arc<AtomicBool>,
    addr: SocketAddr,
) {
    loop {
        let Ok(first) = rx.recv() else { return };
        let mut jobs = vec![first];
        let deadline = Instant::now() + window;
        while jobs.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(j) => jobs.push(j),
                Err(_) => break,
            }
        }
        let mut rows: Vec<(usize, ParsedLine)> = Vec::new();
        let mut replies: Vec<Option<String>> = vec![None; jobs.len()];
        let mut shutdown = false;
        for (ji, job) in jobs.iter().enumerate() {
            match parse_line(&job.line, spec) {
                Ok(ParsedLine::Shutdown) => {
                    replies[ji] = Some("ok shutting down".to_string());
                    shutdown = true;
                }
                Ok(p) => rows.push((ji, p)),
                Err(e) => replies[ji] = Some(format!("err {e}")),
            }
        }
        if !rows.is_empty() {
            plan.score_into(&rows, &mut replies);
        }
        for (job, reply) in jobs.iter().zip(replies) {
            let _ = job.reply.send(reply.unwrap_or_else(|| "err internal".to_string()));
        }
        if shutdown {
            stop.store(true, Ordering::SeqCst);
            // unblock the accept loop so it observes the stop flag
            let _ = TcpStream::connect(addr);
            return;
        }
    }
}

// ---------------------------------------------------------------------------
// Load generation + the single-request tape oracle
// ---------------------------------------------------------------------------

/// What one load run measured: replies in corpus order (for digesting),
/// per-request round-trip latencies, and the wall time of the whole run.
pub struct LoadReport {
    pub replies: Vec<String>,
    pub latencies_ns: Vec<u64>,
    pub wall_ns: u64,
}

impl LoadReport {
    /// FNV-1a over the reply lines — the scoring digest CI pins.
    pub fn digest(&self) -> u64 {
        reply_digest(&self.replies)
    }

    /// Latency percentile in ns (q in 0..=1; nearest-rank on the sorted
    /// sample).
    pub fn percentile_ns(&self, q: f64) -> u64 {
        if self.latencies_ns.is_empty() {
            return 0;
        }
        let mut s = self.latencies_ns.clone();
        s.sort_unstable();
        let pos = (s.len() - 1) as f64 * q.clamp(0.0, 1.0);
        s[pos.round() as usize]
    }

    /// Completed requests per second over the run's wall time.
    pub fn qps(&self) -> f64 {
        if self.wall_ns == 0 {
            return 0.0;
        }
        self.replies.len() as f64 * 1e9 / self.wall_ns as f64
    }
}

/// FNV-1a (64-bit) over reply lines, newline-terminated — the same digest
/// whether replies came off the wire or out of the oracle.
pub fn reply_digest(lines: &[String]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for line in lines {
        for &b in line.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h = (h ^ b'\n' as u64).wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Connect with retry — the server may still be binding when the load
/// generator starts (CI races the two processes).
pub fn connect_retry(addr: &str) -> Result<TcpStream> {
    let mut last: Option<std::io::Error> = None;
    for _ in 0..100 {
        match TcpStream::connect(addr) {
            Ok(s) => {
                s.set_nodelay(true).ok();
                return Ok(s);
            }
            Err(e) => {
                last = Some(e);
                thread::sleep(Duration::from_millis(50));
            }
        }
    }
    bail!("connecting to {addr}: {last:?}")
}

/// Drive `requests` against a running server from `clients` concurrent
/// connections (requests dealt round-robin), collecting replies back
/// into corpus order.
pub fn run_load(addr: &str, requests: &[String], clients: usize) -> Result<LoadReport> {
    if requests.is_empty() {
        return Ok(LoadReport { replies: Vec::new(), latencies_ns: Vec::new(), wall_ns: 0 });
    }
    let clients = clients.clamp(1, requests.len());
    let mut lanes: Vec<Vec<(usize, &str)>> = vec![Vec::new(); clients];
    for (i, line) in requests.iter().enumerate() {
        lanes[i % clients].push((i, line.as_str()));
    }
    let t0 = Instant::now();
    let lane_results: Vec<Result<Vec<(usize, String, u64)>>> = thread::scope(|s| {
        let handles: Vec<_> =
            lanes.iter().map(|lane| s.spawn(move || drive_client(addr, lane))).collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("load client panicked")).and_then(|r| r))
            .collect()
    });
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let mut rows: Vec<(usize, String, u64)> = Vec::with_capacity(requests.len());
    for lane in lane_results {
        rows.extend(lane?);
    }
    rows.sort_by_key(|r| r.0);
    Ok(LoadReport {
        replies: rows.iter().map(|r| r.1.clone()).collect(),
        latencies_ns: rows.iter().map(|r| r.2).collect(),
        wall_ns,
    })
}

/// One load-generator connection: send each assigned request, wait for
/// its reply, record the round trip.
fn drive_client(addr: &str, lane: &[(usize, &str)]) -> Result<Vec<(usize, String, u64)>> {
    let stream = connect_retry(addr)?;
    let mut reader = BufReader::new(stream.try_clone().context("cloning load stream")?);
    let mut writer = BufWriter::new(stream);
    let mut out = Vec::with_capacity(lane.len());
    for &(idx, line) in lane {
        let t0 = Instant::now();
        writeln!(writer, "{line}").context("sending request")?;
        writer.flush().context("flushing request")?;
        let mut reply = String::new();
        let n = reader.read_line(&mut reply).context("reading reply")?;
        if n == 0 {
            bail!("server closed the connection mid-load");
        }
        out.push((idx, reply.trim_end().to_string(), t0.elapsed().as_nanos() as u64));
    }
    Ok(out)
}

/// Score a request corpus one line at a time on a fresh tape per request
/// — the slow, unbatched, autograd-era path.  The serve golden tests and
/// CI pin that the batched plan's replies match these bit-for-bit: DLRM
/// rows are row-local and gpt sequences are sequence-local, so neither
/// batching nor padding may change a single scored bit.
pub fn tape_oracle_replies(app: &ServeApp, policy: QPolicy, lines: &[String]) -> Vec<String> {
    let spec = app.spec();
    let mut out = Vec::with_capacity(lines.len());
    for line in lines {
        match parse_line(line.trim(), spec) {
            Err(e) => out.push(format!("err {e}")),
            Ok(ParsedLine::Shutdown) => out.push("ok shutting down".to_string()),
            Ok(ParsedLine::Ctr { dense, cat }) => {
                let ServeApp::Dlrm(model) = app else { unreachable!("spec gates the app") };
                let n = dense.len();
                let batch = CtrBatch {
                    dense: Tensor::from_vec(1, n, dense),
                    cat: cat.iter().map(|&ix| vec![ix]).collect(),
                    labels: Tensor::zeros(1, 1),
                };
                let (_, scores) = model.eval_scores(&batch, policy);
                out.push(ctr_reply(scores[0]));
            }
            Ok(ParsedLine::Lm { tokens }) => {
                let ServeApp::Gpt(model) = app else { unreachable!("spec gates the app") };
                let t_len = model.cfg.seq_len;
                let len = tokens.len();
                let mut toks = tokens;
                toks.resize(t_len, 0);
                let batch = LmBatch { tokens: toks, targets: vec![0; t_len] };
                let mut t = Tape::new(policy);
                let v = model.frozen_graph_into(&mut t, &batch);
                let (best, z) = argmax_row(t.value(v.logits), len - 1);
                out.push(lm_reply(best, z));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeSet;

    use super::*;
    use crate::precision::BF16;
    use crate::qsim::dlrm::{CtrGen, DlrmConfig};
    use crate::qsim::gpt::{GptConfig, MarkovGen};
    use crate::qsim::mlp::{MlpConfig, SpiralGen};
    use crate::qsim::Var;
    use crate::util::rng::Rng;

    /// Per-variant payloads of the op-soup graph: gather indices, xent
    /// targets, BCE labels (the mutable, non-leaf request payloads).
    fn soup_payloads(variant: usize) -> (Vec<usize>, Vec<usize>, Vec<f32>) {
        let idx = if variant == 0 { vec![2, 0, 3, 1] } else { vec![1, 1, 0, 2] };
        let targets = if variant == 0 { vec![0, 3, 1, 2] } else { vec![3, 0, 0, 1] };
        let labels = (0..16).map(|i| ((i * 7 + variant) % 2) as f32).collect();
        (idx, targets, labels)
    }

    /// A graph touching every `OpIr` variant once; returns the payload-
    /// carrying vars (gather, softmax-xent, bce).
    fn build_soup(t: &mut Tape, seed: u64, variant: usize) -> (Var, Var, Var) {
        let mut rng = Rng::new(seed, 0x50);
        let mut mk = |r: usize, c: usize| -> Tensor {
            let data = (0..r * c).map(|_| rng.normal()).collect();
            Tensor::from_vec(r, c, data)
        };
        let (idx, targets, labels) = soup_payloads(variant);
        let a = t.input(mk(4, 6));
        let b = t.input(mk(6, 5));
        let mm = t.matmul(a, b); // (4,5)
        let w = t.input(mk(5, 3));
        let bias = t.input(mk(1, 3));
        let af = t.affine(mm, w, bias, false); // (4,3)
        let afr = t.affine(mm, w, bias, true);
        let ar = t.add_row(af, bias);
        let sg = t.sigmoid(ar);
        let th = t.tanh(sg);
        let sc = t.scale(th, 1.25);
        let g = t.gather_rows(sc, idx);
        let ad = t.add(g, afr);
        let sb = t.sub(ad, g);
        let ml = t.mul(sb, ad);
        let rl = t.relu(ml);
        let cc = t.concat_cols(vec![rl, g]); // (4,6)
        let ln = t.layernorm(cc, 1e-5);
        let at = t.causal_attention(ln, ln, ln, 2); // 2 seqs of T=2
        let nt = t.matmul_nt(at, cc); // (4,4)
        let xe = t.softmax_xent(nt, targets);
        let _ = t.mean_all(cc);
        let _ = t.mse_loss(ad, g);
        let labels_t = Tensor::from_vec(1, 16, labels);
        let bc = t.bce_loss_from(nt, &labels_t);
        (g, xe, bc)
    }

    fn assert_all_nodes_match(plan: &InferPlan, want: &[Tensor], ctx: &str) {
        assert_eq!(plan.node_count(), want.len(), "{ctx}: node count");
        for (i, w) in want.iter().enumerate() {
            let got = plan.value(i);
            assert_eq!(got.rows, w.rows, "{ctx}: node {i} rows");
            assert_eq!(got.cols, w.cols, "{ctx}: node {i} cols");
            assert_eq!(got.data.len(), w.data.len(), "{ctx}: node {i} len");
            for (x, y) in got.data.iter().zip(&w.data) {
                assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: node {i} bits");
            }
        }
    }

    fn assert_plan_matches_tape(policy: QPolicy) {
        let mut t = Tape::new(policy);
        build_soup(&mut t, 7, 0);
        let want = t.export_values();
        let mut plan = InferPlan::compile(&t, policy);
        // the soup must exercise the full executor: every OpIr variant
        let kinds: BTreeSet<&str> = plan.prog.nodes.iter().map(|n| n.op.name()).collect();
        assert_eq!(kinds.len(), 20, "op soup should cover every OpIr variant: {kinds:?}");
        plan.run();
        assert_all_nodes_match(&plan, &want, policy.backend.name());
    }

    #[test]
    fn plan_matches_tape_exact() {
        assert_plan_matches_tape(QPolicy::exact());
    }

    #[test]
    fn plan_matches_tape_bf16_fast() {
        assert_plan_matches_tape(QPolicy::with_backend(BF16, Backend::Fast));
    }

    #[test]
    fn plan_matches_tape_bf16_simd() {
        assert_plan_matches_tape(QPolicy::with_backend(BF16, Backend::Simd));
    }

    #[test]
    fn plan_matches_tape_bf16_reference() {
        assert_plan_matches_tape(QPolicy::with_backend(BF16, Backend::Reference));
    }

    /// Rebinding every request payload (leaves, gather indices, targets,
    /// labels) and re-running must reproduce a fresh tape on the new
    /// batch bit-for-bit — twice, so no stale arena state can leak
    /// between runs.
    #[test]
    fn rebound_plan_matches_fresh_tape() {
        for backend in [Backend::Fast, Backend::Simd] {
            let policy = QPolicy::with_backend(BF16, backend);
            let mut t1 = Tape::new(policy);
            build_soup(&mut t1, 7, 0);
            let mut plan = InferPlan::compile(&t1, policy);

            let mut t2 = Tape::new(policy);
            let (g, xe, bc) = build_soup(&mut t2, 11, 1);
            let want = t2.export_values();
            let (idx, targets, labels) = soup_payloads(1);
            for (i, w) in want.iter().enumerate() {
                if matches!(plan.prog.nodes[i].op, OpIr::Leaf) {
                    plan.set_leaf(i, &w.data);
                }
            }
            plan.set_gather_idx(g.0, &idx);
            plan.set_xent_targets(xe.0, &targets);
            plan.set_bce_labels(bc.0, &labels);
            for pass in 0..2 {
                plan.run();
                assert_all_nodes_match(&plan, &want, &format!("{backend:?} pass {pass}"));
            }
        }
    }

    #[test]
    fn dlrm_plan_matches_tape_eval_scores() {
        let cfg = DlrmConfig { seed: 5, ..Default::default() };
        let model = DlrmModel::init(&cfg);
        let gen = CtrGen::new(&cfg);
        for backend in [Backend::Fast, Backend::Simd, Backend::Reference] {
            let policy = QPolicy::with_backend(cfg.fmt, backend);
            let mut g = gen.fork(0x11);
            let mut plan: Option<DlrmPlan> = None;
            for _ in 0..3 {
                let batch = g.next_batch();
                let (want_loss, want_scores) = model.eval_scores(&batch, policy);
                let p = plan.get_or_insert_with(|| DlrmPlan::compile(&model, &batch, policy));
                let (loss, scores) = p.score(&batch);
                assert_eq!(loss.to_bits(), want_loss.to_bits(), "{backend:?} loss");
                assert_eq!(scores.len(), want_scores.len());
                for (x, y) in scores.iter().zip(&want_scores) {
                    assert_eq!(x.to_bits(), y.to_bits(), "{backend:?} score");
                }
            }
        }
    }

    #[test]
    fn gpt_plan_matches_tape_eval_loss() {
        let cfg = GptConfig { seed: 4, ..Default::default() };
        let model = GptModel::init(&cfg);
        let gen = MarkovGen::new(&cfg);
        for backend in [Backend::Fast, Backend::Simd] {
            let policy = QPolicy::with_backend(cfg.fmt, backend);
            let mut g = gen.fork(0x22);
            let mut plan: Option<GptPlan> = None;
            for _ in 0..2 {
                let batch = g.next_batch();
                let want = model.eval_loss(&batch, policy);
                let p = plan.get_or_insert_with(|| GptPlan::compile(&model, &batch, policy));
                assert_eq!(p.score(&batch).to_bits(), want.to_bits(), "{backend:?}");
            }
        }
    }

    #[test]
    fn mlp_plan_matches_tape_eval_scores() {
        let cfg = MlpConfig::default();
        let model = MlpModel::init(&cfg);
        let mut gen = SpiralGen::new(&cfg);
        let policy = QPolicy::with_backend(cfg.fmt, Backend::Fast);
        let mut plan: Option<MlpPlan> = None;
        for _ in 0..2 {
            let batch = gen.next_batch();
            let (want_loss, want_scores) = model.eval_scores(&batch, policy);
            let p = plan.get_or_insert_with(|| MlpPlan::compile(&model, &batch, policy));
            let (loss, scores) = p.score(&batch);
            assert_eq!(loss.to_bits(), want_loss.to_bits());
            for (x, y) in scores.data.iter().zip(&want_scores.data) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
        }
    }

    /// A batch padded out to plan capacity must score its real rows
    /// bit-identically to each row evaluated alone on a tape — the
    /// property that makes dynamic micro-batching numerics-free.
    #[test]
    fn dlrm_padding_never_changes_scored_bits() {
        let cfg = DlrmConfig { seed: 9, ..Default::default() };
        let model = DlrmModel::init(&cfg);
        let mut gen = CtrGen::new(&cfg);
        let batch = gen.next_batch();
        let policy = QPolicy::with_backend(cfg.fmt, Backend::Fast);
        let (cap, real, dd) = (8usize, 3usize, cfg.dense_dim);
        let shape = CtrBatch {
            dense: Tensor::zeros(cap, dd),
            cat: vec![vec![0; cap]; cfg.num_tables],
            labels: Tensor::zeros(1, cap),
        };
        let mut plan = DlrmPlan::compile(&model, &shape, policy);
        let mut dense = vec![0.0; cap * dd];
        dense[..real * dd].copy_from_slice(&batch.dense.data[..real * dd]);
        let mut cat = vec![vec![0usize; cap]; cfg.num_tables];
        for (t, col) in cat.iter_mut().enumerate() {
            col[..real].copy_from_slice(&batch.cat[t][..real]);
        }
        let labels = vec![0.0; cap];
        plan.bind(&dense, &cat, &labels);
        plan.run();
        let padded = plan.logits().data.clone();
        for r in 0..real {
            let one = CtrBatch {
                dense: Tensor::from_vec(1, dd, batch.dense.data[r * dd..(r + 1) * dd].to_vec()),
                cat: (0..cfg.num_tables).map(|t| vec![batch.cat[t][r]]).collect(),
                labels: Tensor::zeros(1, 1),
            };
            let (_, scores) = model.eval_scores(&one, policy);
            assert_eq!(padded[r].to_bits(), scores[0].to_bits(), "row {r}");
        }
    }

    fn ctr_request(batch: &CtrBatch, r: usize, dd: usize) -> String {
        let dense: Vec<String> =
            batch.dense.data[r * dd..(r + 1) * dd].iter().map(|v| v.to_string()).collect();
        let cat: Vec<String> = batch.cat.iter().map(|col| col[r].to_string()).collect();
        format!("dlrm {} | {}", dense.join(" "), cat.join(" "))
    }

    /// End to end: spawn the server, drive a mixed corpus (valid rows,
    /// malformed lines, a wrong-app tag) through concurrent clients at
    /// two batch windows, and require byte-identical replies to the
    /// single-request tape oracle.
    #[test]
    fn serve_replies_match_the_tape_oracle() {
        let cfg = DlrmConfig { seed: 3, ..Default::default() };
        let policy = QPolicy::with_backend(cfg.fmt, Backend::Fast);
        let mut gen = CtrGen::new(&cfg);
        let batch = gen.next_batch();
        let mut corpus: Vec<String> =
            (0..6).map(|r| ctr_request(&batch, r, cfg.dense_dim)).collect();
        corpus.push("dlrm 1 2 3".to_string()); // no `|` separator
        corpus.push("gpt 1 2 3".to_string()); // wrong app tag
        let oracle =
            tape_oracle_replies(&ServeApp::Dlrm(Box::new(DlrmModel::init(&cfg))), policy, &corpus);
        assert_eq!(oracle.iter().filter(|l| l.starts_with("ctr ")).count(), 6);
        assert_eq!(oracle.iter().filter(|l| l.starts_with("err ")).count(), 2);
        for (window, clients) in [(0u64, 1usize), (2000, 4)] {
            let serve_cfg = ServeConfig {
                addr: "127.0.0.1:0".to_string(),
                batch_window_us: window,
                max_batch: 4,
                backend: Backend::Fast,
            };
            let app = ServeApp::Dlrm(Box::new(DlrmModel::init(&cfg)));
            let handle = spawn_server(app, policy, &serve_cfg).unwrap();
            let report = run_load(&handle.addr().to_string(), &corpus, clients).unwrap();
            assert_eq!(report.replies, oracle, "window {window}");
            assert_eq!(report.digest(), reply_digest(&oracle));
            assert!(report.percentile_ns(0.99) >= report.percentile_ns(0.5));
            handle.shutdown().unwrap();
        }
    }

    /// Same end-to-end property for gpt-nano: variable-length prompts
    /// coalesced into padded sequence batches must reply bit-identically
    /// to the one-sequence tape oracle.
    #[test]
    fn gpt_serve_batching_never_changes_bits() {
        let cfg = GptConfig { seed: 2, ..Default::default() };
        let policy = QPolicy::with_backend(cfg.fmt, Backend::Fast);
        let mut gen = MarkovGen::new(&cfg);
        let batch = gen.next_batch();
        let t_len = cfg.seq_len;
        let mut corpus = Vec::new();
        for s in 0..4 {
            let len = 1 + (s * 5) % t_len;
            let toks: Vec<String> =
                batch.tokens[s * t_len..s * t_len + len].iter().map(|t| t.to_string()).collect();
            corpus.push(format!("gpt {}", toks.join(" ")));
        }
        corpus.push(format!("gpt {}", cfg.vocab)); // out-of-range token
        let app = ServeApp::Gpt(Box::new(GptModel::init(&cfg)));
        let oracle = tape_oracle_replies(&app, policy, &corpus);
        assert_eq!(oracle.iter().filter(|l| l.starts_with("lm ")).count(), 4);
        assert!(oracle.last().unwrap().starts_with("err "));
        let serve_cfg = ServeConfig {
            addr: "127.0.0.1:0".to_string(),
            batch_window_us: 1500,
            max_batch: 3,
            backend: Backend::Fast,
        };
        let handle = spawn_server(app, policy, &serve_cfg).unwrap();
        let report = run_load(&handle.addr().to_string(), &corpus, 2).unwrap();
        assert_eq!(report.replies, oracle);
        handle.shutdown().unwrap();
    }
}
