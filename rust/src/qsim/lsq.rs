//! The paper's Section-3.1 theory experiment: least-squares regression with
//! selectively-placed rounding (Figure 2) and the Theorem-1 halting radius.
//!
//! Setup (paper, "Theory Validation"): 10-dimensional least squares; inputs
//! x ~ N(0, I); true weights w* ~ U[0, 100); labels y = x·w* + N(0, 0.5²);
//! SGD with batch size 1, lr 0.01.  Four rounding placements:
//!
//!   * `Exact`          — no rounding anywhere (the fp32 curve),
//!   * `WeightUpdate`   — nearest rounding ONLY on the weight-update
//!                        subtraction (the provably-halting case, Thm 1),
//!   * `ForwardBackward`— nearest rounding only on activations/gradients
//!                        (the benign case, Thm 2),
//!   * `Everywhere`     — both (the standard 16-bit-FPU algorithm).
//!
//! Plus `WeightUpdateSr` / `WeightUpdateKahan` for the Section-3.2 fixes.
//!
//! LSQ runs its scalar SGD loop directly — no tape, no [`Task`]
//! (`Task::eval`) impl — so the `qsim::infer` compiled-plan eval routing
//! that serves dlrm / gpt-nano / mlp has nothing to replace here; this is
//! the one native app outside the serving stack.
//!
//! [`Task`]: super::train::Task

use crate::precision::{round_nearest, round_stochastic, Format};
use crate::util::rng::{DitherKey, Rng};

/// Stream tag for the LSQ experiment's SR dither keys.  The dither is
/// counter-keyed by `(seed, step, coordinate)`, not drawn from the sample-
/// selection stream — so every placement sees the *same* sample sequence
/// (previously `WeightUpdateSr` perturbed the shared stream with its extra
/// draws) and chunked/parallel evaluation would be bit-identical.
const LSQ_DITHER_STREAM: u64 = 0x5352;

/// The LSQ sweep's one `(stream, tensor_id)` dither coordinate, for the
/// static collision lint (`verify::lint_dither_coords`) — it must never
/// collide with the SGD optimizers' per-tensor coordinates.
pub fn dither_coord() -> (u64, u64) {
    (LSQ_DITHER_STREAM, 0)
}

/// Where rounding is applied in the SGD loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Exact,
    WeightUpdate,
    ForwardBackward,
    Everywhere,
    WeightUpdateSr,
    WeightUpdateKahan,
}

impl Placement {
    pub const ALL: [Placement; 6] = [
        Placement::Exact,
        Placement::WeightUpdate,
        Placement::ForwardBackward,
        Placement::Everywhere,
        Placement::WeightUpdateSr,
        Placement::WeightUpdateKahan,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Placement::Exact => "exact",
            Placement::WeightUpdate => "weight-update",
            Placement::ForwardBackward => "fwd-bwd",
            Placement::Everywhere => "everywhere",
            Placement::WeightUpdateSr => "weight-update-sr",
            Placement::WeightUpdateKahan => "weight-update-kahan",
        }
    }

    fn rounds_fwd_bwd(&self) -> bool {
        matches!(self, Placement::ForwardBackward | Placement::Everywhere)
    }

    fn rounds_update(&self) -> bool {
        !matches!(self, Placement::Exact | Placement::ForwardBackward)
    }
}

/// Experiment configuration (defaults = the paper's).
#[derive(Debug, Clone)]
pub struct LsqConfig {
    pub dim: usize,
    pub n_samples: usize,
    pub lr: f32,
    pub steps: usize,
    pub noise_std: f32,
    pub w_star_hi: f32,
    pub fmt: Format,
    pub seed: u64,
}

impl Default for LsqConfig {
    fn default() -> Self {
        Self {
            dim: 10,
            n_samples: 1024,
            lr: 0.01,
            steps: 20_000,
            noise_std: 0.5,
            w_star_hi: 100.0,
            fmt: crate::precision::BF16,
            seed: 0,
        }
    }
}

/// Result series of one run.
#[derive(Debug, Clone)]
pub struct LsqRun {
    pub placement: Placement,
    /// training loss sampled every `sample_every` steps
    pub losses: Vec<f32>,
    pub sample_every: usize,
    /// final ||w - w*||
    pub final_dist: f32,
    /// fraction of steps whose update was entirely cancelled
    pub halt_frac: f32,
}

/// The synthetic least-squares dataset.
pub struct LsqData {
    pub xs: Vec<f32>, // n × d, row-major
    pub ys: Vec<f32>,
    pub w_star: Vec<f32>,
    pub dim: usize,
}

impl LsqData {
    pub fn generate(cfg: &LsqConfig) -> Self {
        let mut rng = Rng::new(cfg.seed, 0x15);
        let w_star: Vec<f32> =
            (0..cfg.dim).map(|_| rng.uniform_in(0.0, cfg.w_star_hi)).collect();
        let mut xs = Vec::with_capacity(cfg.n_samples * cfg.dim);
        let mut ys = Vec::with_capacity(cfg.n_samples);
        for _ in 0..cfg.n_samples {
            let mut dot = 0f32;
            for &w in &w_star {
                let x = rng.normal();
                xs.push(x);
                dot += x * w;
            }
            ys.push(dot + rng.normal() * cfg.noise_std);
        }
        Self { xs, ys, w_star, dim: cfg.dim }
    }

    fn sample(&self, i: usize) -> (&[f32], f32) {
        (&self.xs[i * self.dim..(i + 1) * self.dim], self.ys[i])
    }

    /// Mean squared loss of `w` over the dataset (exact arithmetic).
    pub fn full_loss(&self, w: &[f32]) -> f32 {
        let n = self.ys.len();
        let mut acc = 0f64;
        for i in 0..n {
            let (x, y) = self.sample(i);
            let r = x.iter().zip(w).map(|(a, b)| a * b).sum::<f32>() - y;
            acc += (r as f64) * (r as f64);
        }
        (acc / (2.0 * n as f64)) as f32
    }
}

/// Run SGD with the given rounding placement.
pub fn run(cfg: &LsqConfig, data: &LsqData, placement: Placement) -> LsqRun {
    let fmt = cfg.fmt;
    let rf = |x: f32| {
        if placement.rounds_fwd_bwd() {
            round_nearest(x, fmt)
        } else {
            x
        }
    };
    let mut rng = Rng::new(cfg.seed, 0x51D);
    let mut w = vec![0f32; cfg.dim];
    let mut kahan = vec![0f32; cfg.dim];
    let sample_every = (cfg.steps / 200).max(1);
    let mut losses = Vec::new();
    let mut halted_steps = 0usize;
    let n = data.ys.len();
    for t in 0..cfg.steps {
        let (x, y) = data.sample(rng.below(n));
        // forward: activation a = Q(x·w - y) (dot product in the FMAC's
        // wide accumulator — no intra-dot rounding, paper §3.1)
        let mut dot = 0f32;
        for (xi, wi) in x.iter().zip(&w) {
            dot += xi * wi;
        }
        let a = rf(dot - y);
        // backward: activation grad Q(a), weight grad Q(g_a * x_j)
        let ga = rf(a);
        let mut any_moved = false;
        let mut any_update = false;
        // update magnitude u = lr·gj is an exact scalar mult; rounding of
        // the subtraction output is what Theorem 1 is about.  The placement
        // dispatch is hoisted out of the per-coordinate loop so each variant
        // runs a straight-line slice pass.
        let mut track = |u: f32, wj: f32, new: f32| {
            if u != 0.0 {
                any_update = true;
                if new != wj {
                    any_moved = true;
                }
            }
        };
        match placement {
            Placement::Exact | Placement::ForwardBackward => {
                for j in 0..cfg.dim {
                    let gj = rf(ga * x[j]);
                    let u = cfg.lr * gj;
                    let wj = w[j];
                    let new = wj - u;
                    track(u, wj, new);
                    w[j] = new;
                }
            }
            Placement::WeightUpdate | Placement::Everywhere => {
                for j in 0..cfg.dim {
                    let gj = rf(ga * x[j]);
                    let u = cfg.lr * gj;
                    let wj = w[j];
                    let new = round_nearest(wj - u, fmt);
                    track(u, wj, new);
                    w[j] = new;
                }
            }
            Placement::WeightUpdateSr => {
                let key = DitherKey::new(cfg.seed, LSQ_DITHER_STREAM, t as u64, 0);
                for j in 0..cfg.dim {
                    let gj = rf(ga * x[j]);
                    let u = cfg.lr * gj;
                    let wj = w[j];
                    let new = round_stochastic(wj - u, fmt, key.word(j as u64));
                    track(u, wj, new);
                    w[j] = new;
                }
            }
            Placement::WeightUpdateKahan => {
                for j in 0..cfg.dim {
                    let gj = rf(ga * x[j]);
                    let u = cfg.lr * gj;
                    let wj = w[j];
                    let yv = round_nearest(-u - kahan[j], fmt);
                    let new = round_nearest(wj + yv, fmt);
                    kahan[j] = round_nearest(round_nearest(new - wj, fmt) - yv, fmt);
                    track(u, wj, new);
                    w[j] = new;
                }
            }
        }
        if any_update && !any_moved {
            halted_steps += 1;
        }
        if t % sample_every == 0 {
            losses.push(data.full_loss(&w));
        }
    }
    let final_dist = w
        .iter()
        .zip(&data.w_star)
        .map(|(a, b)| {
            let d = (a - b) as f64;
            d * d
        })
        .sum::<f64>()
        .sqrt() as f32;
    LsqRun {
        placement,
        losses,
        sample_every,
        final_dist,
        halt_frac: halted_steps as f32 / cfg.steps as f32,
    }
}

/// Theorem 1's halting radius:  eps/(alpha L + eps) * min_j |w*_j|.
///
/// For least squares with batch size 1, L = max_i ||x_i||².
pub fn halting_radius(cfg: &LsqConfig, data: &LsqData) -> f32 {
    let eps = cfg.fmt.machine_eps() as f32;
    let n = data.ys.len();
    let mut l_max = 0f32;
    for i in 0..n {
        let (x, _) = data.sample(i);
        let norm2 = x.iter().map(|v| v * v).sum::<f32>();
        l_max = l_max.max(norm2);
    }
    let min_w = data.w_star.iter().fold(f32::INFINITY, |m, &v| m.min(v.abs()));
    eps / (cfg.lr * l_max + eps) * min_w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> LsqConfig {
        LsqConfig { steps: 4000, n_samples: 256, ..LsqConfig::default() }
    }

    #[test]
    fn exact_converges_weight_update_halts() {
        let cfg = small_cfg();
        let data = LsqData::generate(&cfg);
        let exact = run(&cfg, &data, Placement::Exact);
        let halted = run(&cfg, &data, Placement::WeightUpdate);
        // Figure 2's shape: weight-update rounding saturates orders of
        // magnitude above exact training.
        let e = *exact.losses.last().unwrap();
        let h = *halted.losses.last().unwrap();
        assert!(h > 10.0 * e.max(1e-6), "exact={e} halted={h}");
        assert!(halted.halt_frac > 0.2, "halt_frac={}", halted.halt_frac);
    }

    #[test]
    fn fwd_bwd_rounding_is_benign() {
        let cfg = small_cfg();
        let data = LsqData::generate(&cfg);
        let exact = run(&cfg, &data, Placement::Exact);
        let fb = run(&cfg, &data, Placement::ForwardBackward);
        let halted = run(&cfg, &data, Placement::WeightUpdate);
        let e = *exact.losses.last().unwrap();
        let f = *fb.losses.last().unwrap();
        let h = *halted.losses.last().unwrap();
        // fwd/bwd rounding lands within a small factor of exact, far below
        // the weight-update-rounded plateau (Thm 2 vs Thm 1).
        assert!(f < h / 3.0, "fb={f} halted={h}");
        assert!(f < 100.0 * e.max(1e-6), "fb={f} exact={e}");
    }

    #[test]
    fn sr_and_kahan_restore_convergence() {
        let cfg = small_cfg();
        let data = LsqData::generate(&cfg);
        let halted = run(&cfg, &data, Placement::WeightUpdate);
        let sr = run(&cfg, &data, Placement::WeightUpdateSr);
        let kahan = run(&cfg, &data, Placement::WeightUpdateKahan);
        let h = *halted.losses.last().unwrap();
        assert!(*sr.losses.last().unwrap() < h / 2.0);
        assert!(*kahan.losses.last().unwrap() < h / 2.0);
    }

    #[test]
    fn final_distance_respects_thm1_lower_bound_region() {
        let cfg = small_cfg();
        let data = LsqData::generate(&cfg);
        let halted = run(&cfg, &data, Placement::WeightUpdate);
        let radius = halting_radius(&cfg, &data);
        // the iterate cannot end *inside* a shrunk version of the ball;
        // allow slack for the (1 - αL) factor in the theorem.
        assert!(
            halted.final_dist >= radius * 0.1,
            "dist={} radius={radius}",
            halted.final_dist
        );
    }

    #[test]
    fn sr_run_is_deterministic() {
        // counter-keyed dither: same seed → bit-identical trajectory, and
        // the dither draws never touch the sample-selection stream
        let cfg = LsqConfig { steps: 500, n_samples: 64, ..LsqConfig::default() };
        let data = LsqData::generate(&cfg);
        let a = run(&cfg, &data, Placement::WeightUpdateSr);
        let b = run(&cfg, &data, Placement::WeightUpdateSr);
        assert_eq!(a.losses, b.losses);
        assert_eq!(a.final_dist.to_bits(), b.final_dist.to_bits());
    }

    #[test]
    fn dataset_is_deterministic() {
        let cfg = small_cfg();
        let a = LsqData::generate(&cfg);
        let b = LsqData::generate(&cfg);
        assert_eq!(a.xs, b.xs);
        assert_eq!(a.w_star, b.w_star);
    }
}
