//! Rust-native optimizer with the paper's weight-update policies
//! (mirror of `python/compile/optim.py` over `qsim` tensors).
//!
//! Used by the native theory experiments (Figure 2, Theorem 1, Figure 9/10
//! fast paths) and by the property-test suite; the PJRT path runs the same
//! algorithms inside lowered HLO instead.

use crate::precision::{
    round_nearest, round_nearest_slice, round_stochastic, Format, Mode, Policy, BF16,
};
use crate::util::rng::Rng;

use super::tensor::Tensor;
use super::Backend;

/// Per-step statistics (Figure 9's cancellation telemetry).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UpdateStats {
    /// Non-zero updates cancelled by rounding.
    pub cancelled: u64,
    /// Non-zero updates total.
    pub nonzero: u64,
}

impl UpdateStats {
    pub fn frac(&self) -> f64 {
        if self.nonzero == 0 {
            0.0
        } else {
            self.cancelled as f64 / self.nonzero as f64
        }
    }

    pub fn merge(&mut self, other: UpdateStats) {
        self.cancelled += other.cancelled;
        self.nonzero += other.nonzero;
    }
}

/// SGD(-momentum) optimizer state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct SgdState {
    pub momentum: Option<Tensor>,
    pub kahan: Option<Tensor>,
}

/// SGD with the paper's weight-update policies.
#[derive(Debug)]
pub struct Sgd {
    pub mode: Mode,
    pub fmt: Format,
    pub momentum: f32,
    pub weight_decay: f32,
    pub backend: Backend,
    rng: Rng,
    /// Per-step update-magnitude scratch (stage buffer, reused across steps).
    u_buf: Vec<f32>,
    /// Pre-drawn SR dither words (one per element, reused across steps).
    bits_buf: Vec<u32>,
}

impl Sgd {
    pub fn new(mode: Mode, fmt: Format, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self {
            mode,
            fmt,
            momentum,
            weight_decay,
            backend: Backend::Fast,
            rng: Rng::new(seed, 0x0907),
            u_buf: Vec::new(),
            bits_buf: Vec::new(),
        }
    }

    /// Builder-style backend override (the scalar reference path).
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    pub fn bf16(mode: Mode, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(mode, BF16, momentum, weight_decay, seed)
    }

    /// Build from a typed precision policy.
    pub fn from_policy(policy: Policy, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(policy.mode, policy.fmt, momentum, weight_decay, seed)
    }

    pub fn init_state(&self, w: &Tensor) -> SgdState {
        SgdState {
            momentum: (self.momentum != 0.0).then(|| Tensor::zeros(w.rows, w.cols)),
            kahan: self.mode.kahan().then(|| Tensor::zeros(w.rows, w.cols)),
        }
    }

    /// One update of `w` from gradient `g`.  All optimizer-internal ops are
    /// nearest-rounded in the 16-bit modes (Algorithms 2 & 3).
    ///
    /// The fast path runs as per-stage slice passes with batched dither
    /// draws; the reference path is the original interleaved per-element
    /// loop.  Both are bit-identical, including RNG consumption (one dither
    /// word per element, in element order, for the stochastic modes).
    pub fn step(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
    ) -> UpdateStats {
        match self.backend {
            Backend::Fast => self.step_fast(w, state, g, lr),
            Backend::Reference => self.step_reference(w, state, g, lr),
        }
    }

    /// Vectorized update: per-stage slice passes over `w` / `momentum` /
    /// `kahan` with the format constants hoisted and SR dither pre-drawn in
    /// bulk, instead of one interleaved branchy loop per element.
    fn step_fast(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
    ) -> UpdateStats {
        let n = w.data.len();
        debug_assert_eq!(g.data.len(), n);
        let exact = self.mode.exact_update();
        let stochastic = self.mode.stochastic();
        let fmt = self.fmt;

        // stage 1: effective gradient (+ optional decoupled weight decay)
        let u = &mut self.u_buf;
        u.clear();
        u.extend_from_slice(&g.data);
        if self.weight_decay != 0.0 {
            let wd = self.weight_decay;
            if exact {
                for (ui, &wi) in u.iter_mut().zip(&w.data) {
                    *ui += wd * wi;
                }
            } else {
                for (ui, &wi) in u.iter_mut().zip(&w.data) {
                    *ui = round_nearest(*ui + round_nearest(wd * wi, fmt), fmt);
                }
            }
        }

        // stage 2: momentum accumulation (slice pass over the state tensor)
        if let Some(mom) = &mut state.momentum {
            let mu = self.momentum;
            if exact {
                for (ui, mi) in u.iter_mut().zip(mom.data.iter_mut()) {
                    let m_new = mu * *mi + *ui;
                    *mi = m_new;
                    *ui = m_new;
                }
            } else {
                for (ui, mi) in u.iter_mut().zip(mom.data.iter_mut()) {
                    let m_new = round_nearest(round_nearest(mu * *mi, fmt) + *ui, fmt);
                    *mi = m_new;
                    *ui = m_new;
                }
            }
        }

        // stage 3: update magnitude u = r(lr · m)
        for ui in u.iter_mut() {
            *ui *= lr;
        }
        if !exact {
            round_nearest_slice(u, fmt);
        }

        // stage 4: bulk dither draws (same words the scalar loop would draw)
        if stochastic {
            if self.bits_buf.len() != n {
                self.bits_buf.resize(n, 0);
            }
            self.rng.fill_u32(&mut self.bits_buf);
        }

        // stage 5: weight accumulate + cancellation stats, one pass
        let mut stats = UpdateStats::default();
        if self.mode.kahan() {
            // srkahan16 (Fig 11): the accumulate output is SR'd
            let c = state.kahan.as_mut().expect("kahan mode without kahan state");
            for i in 0..n {
                let ui = u[i];
                let wi = w.data[i];
                let y = round_nearest(-ui - c.data[i], fmt);
                let s = if stochastic {
                    round_stochastic(wi + y, fmt, self.bits_buf[i])
                } else {
                    round_nearest(wi + y, fmt)
                };
                c.data[i] = round_nearest(round_nearest(s - wi, fmt) - y, fmt);
                if ui != 0.0 {
                    stats.nonzero += 1;
                    if s == wi {
                        stats.cancelled += 1;
                    }
                }
                w.data[i] = s;
            }
        } else if exact {
            for (wi, &ui) in w.data.iter_mut().zip(u.iter()) {
                let w_new = *wi - ui;
                if ui != 0.0 {
                    stats.nonzero += 1;
                    if w_new == *wi {
                        stats.cancelled += 1;
                    }
                }
                *wi = w_new;
            }
        } else if stochastic {
            for i in 0..n {
                let ui = u[i];
                let wi = w.data[i];
                let w_new = round_stochastic(wi - ui, fmt, self.bits_buf[i]);
                if ui != 0.0 {
                    stats.nonzero += 1;
                    if w_new == wi {
                        stats.cancelled += 1;
                    }
                }
                w.data[i] = w_new;
            }
        } else {
            for (wi, &ui) in w.data.iter_mut().zip(u.iter()) {
                let w_new = round_nearest(*wi - ui, fmt);
                if ui != 0.0 {
                    stats.nonzero += 1;
                    if w_new == *wi {
                        stats.cancelled += 1;
                    }
                }
                *wi = w_new;
            }
        }
        stats
    }

    /// The original interleaved per-element loop (pre-vectorization code),
    /// kept as the bit-exactness oracle and bench baseline.
    fn step_reference(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
    ) -> UpdateStats {
        let exact = self.mode.exact_update();
        let fmt = self.fmt;
        let r = |x: f32| if exact { x } else { round_nearest(x, fmt) };
        let mut stats = UpdateStats::default();
        for i in 0..w.data.len() {
            let mut gi = g.data[i];
            if self.weight_decay != 0.0 {
                gi = r(gi + r(self.weight_decay * w.data[i]));
            }
            let m = if let Some(mom) = &mut state.momentum {
                let m_new = r(r(self.momentum * mom.data[i]) + gi);
                mom.data[i] = m_new;
                m_new
            } else {
                gi
            };
            let u = r(lr * m);
            let wi = w.data[i];
            let w_new = if self.mode.kahan() {
                // srkahan16 (Fig 11): the accumulate output is SR'd
                let c = state.kahan.as_mut().unwrap();
                let y = r(-u - c.data[i]);
                let s = if self.mode.stochastic() {
                    round_stochastic(wi + y, fmt, self.rng.next_u32())
                } else {
                    r(wi + y)
                };
                c.data[i] = r(r(s - wi) - y);
                s
            } else if exact {
                wi - u
            } else if self.mode.stochastic() {
                round_stochastic(wi - u, fmt, self.rng.next_u32())
            } else {
                r(wi - u)
            };
            if u != 0.0 {
                stats.nonzero += 1;
                if w_new == wi {
                    stats.cancelled += 1;
                }
            }
            w.data[i] = w_new;
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(mode: Mode, grad: f32, lr: f32, steps: usize) -> (f32, f64) {
        let mut opt = Sgd::bf16(mode, 0.0, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(grad);
        let mut total = UpdateStats::default();
        for _ in 0..steps {
            total.merge(opt.step(&mut w, &mut st, &g, lr));
        }
        (w.item(), total.frac())
    }

    #[test]
    fn nearest_halts_small_updates() {
        let (w, frac) = run(Mode::Standard16, 2f32.powi(-11), 1.0, 50);
        assert_eq!(w, 1.0);
        assert_eq!(frac, 1.0);
    }

    #[test]
    fn kahan_lands_small_updates() {
        let (w, _) = run(Mode::Kahan16, 2f32.powi(-11), 1.0, 64);
        let exact = 1.0 - 64.0 * 2f32.powi(-11);
        assert!((w - exact).abs() <= 2f32.powi(-8), "{w}");
    }

    #[test]
    fn sr_progresses_in_expectation() {
        let mut acc = 0f64;
        let n = 50;
        for seed in 0..n {
            let mut opt = Sgd::bf16(Mode::Sr16, 0.0, 0.0, seed);
            let mut w = Tensor::scalar(1.0);
            let mut st = opt.init_state(&w);
            let g = Tensor::scalar(2f32.powi(-11));
            for _ in 0..64 {
                opt.step(&mut w, &mut st, &g, 1.0);
            }
            acc += w.item() as f64;
        }
        let mean = acc / n as f64;
        let target = 1.0 - 64.0 * 2f64.powi(-11);
        assert!((mean - target).abs() < 0.01, "{mean} vs {target}");
    }

    #[test]
    fn exact_modes_track_exact_descent() {
        for mode in [Mode::Fp32, Mode::Mixed16] {
            let (w, frac) = run(mode, 2f32.powi(-11), 1.0, 10);
            assert!((w - (1.0 - 10.0 * 2f32.powi(-11))).abs() < 1e-6);
            assert_eq!(frac, 0.0, "{mode:?}");
        }
    }

    #[test]
    fn momentum_accelerates() {
        let mut opt = Sgd::bf16(Mode::Fp32, 0.9, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(0.01);
        for _ in 0..10 {
            opt.step(&mut w, &mut st, &g, 0.1);
        }
        // with momentum the total displacement exceeds 10 * lr * g
        assert!(1.0 - w.item() > 10.0 * 0.1 * 0.01);
    }

    #[test]
    fn fast_step_bit_identical_to_reference_all_modes() {
        use crate::precision::{E8M5, FP16};
        let mut rng = Rng::new(0x51, 0);
        for mode in Mode::ALL {
            for fmt in [BF16, FP16, E8M5] {
                for (momentum, wd) in [(0.0, 0.0), (0.9, 0.0), (0.9, 1e-4)] {
                    let mut fast = Sgd::new(mode, fmt, momentum, wd, 42);
                    let mut reference =
                        Sgd::new(mode, fmt, momentum, wd, 42).with_backend(Backend::Reference);
                    // odd length exercises ragged dither chunks
                    let len = 515;
                    let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
                    let mut wf = Tensor::vector(init.clone());
                    let mut wr = Tensor::vector(init);
                    let mut sf = fast.init_state(&wf);
                    let mut sr = reference.init_state(&wr);
                    for step in 0..20 {
                        // occasionally-zero gradients hit the stats guard
                        let g = Tensor::vector(
                            (0..len)
                                .map(|i| {
                                    if (i + step) % 13 == 0 {
                                        0.0
                                    } else {
                                        rng.normal() * 2f32.powi(-(step as i32) - 2)
                                    }
                                })
                                .collect(),
                        );
                        let stf = fast.step(&mut wf, &mut sf, &g, 0.05);
                        let str_ = reference.step(&mut wr, &mut sr, &g, 0.05);
                        assert_eq!(stf, str_, "{mode:?}/{}/mu={momentum} step {step}", fmt.name);
                        for (i, (a, b)) in wf.data.iter().zip(&wr.data).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{mode:?}/{}/mu={momentum} step {step} w[{i}]",
                                fmt.name
                            );
                        }
                        if let (Some(mf), Some(mr)) = (&sf.momentum, &sr.momentum) {
                            assert_eq!(mf.data, mr.data, "{mode:?} momentum state");
                        }
                        if let (Some(kf), Some(kr)) = (&sf.kahan, &sr.kahan) {
                            assert_eq!(kf.data, kr.data, "{mode:?} kahan state");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn from_policy_binds_mode_and_fmt() {
        let p = Policy::parse("sr16-e8m5").unwrap();
        let opt = Sgd::from_policy(p, 0.9, 0.0, 1);
        assert_eq!(opt.mode, Mode::Sr16);
        assert_eq!(opt.fmt, crate::precision::E8M5);
    }
}
