//! Rust-native optimizer with the paper's weight-update policies
//! (mirror of `python/compile/optim.py` over `qsim` tensors).
//!
//! Used by the native theory experiments (Figure 2, Theorem 1, Figure 9/10
//! fast paths) and by the property-test suite; the PJRT path runs the same
//! algorithms inside lowered HLO instead.
//!
//! ## Dither schedule
//!
//! Stochastic-rounding dither is **counter-keyed**: the word for element
//! `i` of step `t` is `DitherKey::new(seed, STREAM, t, tensor_id).word(i)` —
//! a pure function of position, not a draw from a sequential stream.  All
//! backends consume the same schedule by construction, so the `Fast` path
//! can split the update into chunks across a worker [`Pool`] and the `Simd`
//! path can round eight elements per lane block without changing a single
//! bit of the result.
//!
//! ## Native 16-bit storage
//!
//! Weight and Kahan tensors may live in [`Storage::Bf16`] under the 16-bit
//! modes (`qsim::tensor`).  `step` widens narrow buffers into optimizer-held
//! f32 scratch for the duration of the update and narrows them back after —
//! lossless both ways, because every value the update writes was rounded
//! onto the format grid (a subset of the bf16 grid for every `exp=8,
//! mant<=7` format), so results are bit-identical to f32 storage.
//!
//! [`Storage::Bf16`]: super::tensor::Storage

use std::sync::Arc;

use crate::precision::{
    round_nearest, round_nearest_slice, round_nearest_slice_simd, round_stochastic, Format, Mode,
    Policy, SimdRound, BF16, LANES,
};
use crate::util::rng::DitherKey;

use super::pool::Pool;
use super::tensor::Tensor;
use super::Backend;

/// Stream tag separating optimizer dither keys from every other RNG use.
const SGD_DITHER_STREAM: u64 = 0x0907;

/// Minimum elements per chunk before `Sgd::step` fans out across the pool.
const SGD_PAR_MIN: usize = 4096;

/// Per-step statistics (Figure 9's cancellation telemetry).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UpdateStats {
    /// Non-zero updates cancelled by rounding.
    pub cancelled: u64,
    /// Non-zero updates total.
    pub nonzero: u64,
}

impl UpdateStats {
    pub fn frac(&self) -> f64 {
        if self.nonzero == 0 {
            0.0
        } else {
            self.cancelled as f64 / self.nonzero as f64
        }
    }

    pub fn merge(&mut self, other: UpdateStats) {
        self.cancelled += other.cancelled;
        self.nonzero += other.nonzero;
    }
}

/// SGD(-momentum) optimizer state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct SgdState {
    pub momentum: Option<Tensor>,
    pub kahan: Option<Tensor>,
}

/// SGD with the paper's weight-update policies.
#[derive(Debug)]
pub struct Sgd {
    pub mode: Mode,
    pub fmt: Format,
    pub momentum: f32,
    pub weight_decay: f32,
    pub backend: Backend,
    /// Seed coordinate of the dither key (run-level randomness).
    seed: u64,
    /// Tensor coordinate of the dither key — set one id per parameter
    /// tensor ([`Sgd::with_tensor_id`]) so tensors sharing a seed still
    /// draw independent dither.
    tensor_id: u64,
    /// Steps taken so far — the step coordinate of the dither key.
    step_idx: u64,
    /// Worker pool for the chunked `Fast`/`Simd` update (single-threaded
    /// default).
    pool: Arc<Pool>,
    /// Per-step update-magnitude scratch (stage buffer, reused across steps).
    u_buf: Vec<f32>,
    /// Widened views of native-16-bit weight / momentum / Kahan buffers,
    /// reused across steps.
    w_scratch: Vec<f32>,
    m_scratch: Vec<f32>,
    k_scratch: Vec<f32>,
}

/// Scalar parameters of one update, copied per step so chunk workers share
/// them without touching `&self`.
#[derive(Clone, Copy)]
struct StepParams {
    fmt: Format,
    exact: bool,
    stochastic: bool,
    kahan: bool,
    momentum: f32,
    weight_decay: f32,
    lr: f32,
    key: DitherKey,
    /// Route span updates through the 8-wide lane kernels.
    simd: bool,
}

impl Sgd {
    pub fn new(mode: Mode, fmt: Format, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self {
            mode,
            fmt,
            momentum,
            weight_decay,
            backend: Backend::Fast,
            seed,
            tensor_id: 0,
            step_idx: 0,
            pool: Pool::single(),
            u_buf: Vec::new(),
            w_scratch: Vec::new(),
            m_scratch: Vec::new(),
            k_scratch: Vec::new(),
        }
    }

    /// Builder-style backend override (scalar reference / tiled fast /
    /// vector-wide simd).
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Builder-style tensor id for the dither key (one id per parameter
    /// tensor of a model).
    pub fn with_tensor_id(mut self, tensor_id: u64) -> Self {
        self.tensor_id = tensor_id;
        self
    }

    /// This optimizer's `(stream, tensor_id)` dither coordinate, for the
    /// static collision lint (`verify::lint_dither_coords`): two live
    /// optimizers sharing a coordinate draw correlated rounding noise.
    pub fn dither_coord(&self) -> (u64, u64) {
        (SGD_DITHER_STREAM, self.tensor_id)
    }

    /// Builder-style worker pool for the chunked `Fast`/`Simd` update.
    /// Results are bit-identical at every pool size (and to `Reference`).
    pub fn with_pool(mut self, pool: Arc<Pool>) -> Self {
        self.pool = pool;
        self
    }

    /// Steps taken so far — the step coordinate of the dither key.
    pub fn step_idx(&self) -> u64 {
        self.step_idx
    }

    /// Reposition the step counter (checkpoint resume): the next update
    /// draws dither for step `idx`, exactly as if the optimizer had already
    /// executed `idx` steps.  Because the dither schedule is counter-keyed,
    /// this is all the RNG state an optimizer has.
    pub fn set_step_idx(&mut self, idx: u64) {
        self.step_idx = idx;
    }

    pub fn bf16(mode: Mode, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(mode, BF16, momentum, weight_decay, seed)
    }

    /// Build from a typed precision policy.
    pub fn from_policy(policy: Policy, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(policy.mode, policy.fmt, momentum, weight_decay, seed)
    }

    pub fn init_state(&self, w: &Tensor) -> SgdState {
        SgdState {
            momentum: (self.momentum != 0.0).then(|| Tensor::zeros(w.rows, w.cols)),
            kahan: self.mode.kahan().then(|| Tensor::zeros(w.rows, w.cols)),
        }
    }

    /// One update of `w` from gradient `g`.  All optimizer-internal ops are
    /// nearest-rounded in the 16-bit modes (Algorithms 2 & 3).
    ///
    /// The fast and simd paths run as per-stage slice passes, chunked
    /// across the worker pool when the tensor is large enough; the
    /// reference path is the original interleaved per-element loop.  All
    /// three consume the same counter-keyed dither schedule (word `i` of
    /// the step's key for element `i`), so they are bit-identical — to
    /// each other and across every thread count.
    pub fn step(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
    ) -> UpdateStats {
        let key = DitherKey::new(self.seed, SGD_DITHER_STREAM, self.step_idx, self.tensor_id);
        self.step_idx = self.step_idx.wrapping_add(1);
        let p = StepParams {
            fmt: self.fmt,
            exact: self.mode.exact_update(),
            stochastic: self.mode.stochastic(),
            kahan: self.mode.kahan(),
            momentum: self.momentum,
            weight_decay: self.weight_decay,
            lr,
            key,
            simd: self.backend.simd(),
        };
        debug_assert!(!g.is_native16(), "gradients are always f32-stored");

        // Native 16-bit storage: widen narrow buffers into optimizer-held
        // f32 scratch for the update, narrow back after.  Lossless both
        // ways — stored values sit on the format grid — so the result is
        // bit-identical to f32 storage.
        let mut w_host = std::mem::take(&mut self.w_scratch);
        let w_narrow = widen_if_native16(Some(&*w), &mut w_host);
        let mut m_host = std::mem::take(&mut self.m_scratch);
        let m_narrow = widen_if_native16(state.momentum.as_ref(), &mut m_host);
        let mut k_host = std::mem::take(&mut self.k_scratch);
        let k_narrow = widen_if_native16(state.kahan.as_ref(), &mut k_host);
        let stats = {
            let ws: &mut [f32] = if w_narrow { &mut w_host } else { &mut w.data };
            let ms: Option<&mut [f32]> = if m_narrow {
                Some(&mut m_host)
            } else {
                state.momentum.as_mut().map(|t| t.data.as_mut_slice())
            };
            let ks: Option<&mut [f32]> = if k_narrow {
                Some(&mut k_host)
            } else {
                state.kahan.as_mut().map(|t| t.data.as_mut_slice())
            };
            match self.backend {
                Backend::Fast | Backend::Simd => self.step_slices(p, ws, &g.data, ms, ks),
                Backend::Reference => step_reference_slices(p, ws, &g.data, ms, ks),
            }
        };
        if w_narrow {
            w.set_from_f32(&w_host);
        }
        if m_narrow {
            state.momentum.as_mut().unwrap().set_from_f32(&m_host);
        }
        if k_narrow {
            state.kahan.as_mut().unwrap().set_from_f32(&k_host);
        }
        self.w_scratch = w_host;
        self.m_scratch = m_host;
        self.k_scratch = k_host;
        stats
    }

    /// Vectorized update: per-stage slice passes over `w` / `momentum` /
    /// `kahan` with the format constants hoisted, run whole (small tensors)
    /// or as disjoint chunks fanned out over the pool (large tensors).
    fn step_slices(
        &mut self,
        p: StepParams,
        w: &mut [f32],
        g: &[f32],
        mom: Option<&mut [f32]>,
        kahan: Option<&mut [f32]>,
    ) -> UpdateStats {
        let n = w.len();
        debug_assert_eq!(g.len(), n);
        if self.u_buf.len() != n {
            self.u_buf.resize(n, 0.0);
        }
        let threads = self.pool.threads().min(n / SGD_PAR_MIN.max(1)).max(1);
        if threads <= 1 {
            return step_span(p, 0, w, g, mom, kahan, &mut self.u_buf);
        }

        /// One worker's disjoint view of every per-element array.
        struct Span<'a> {
            base: usize,
            w: &'a mut [f32],
            g: &'a [f32],
            mom: Option<&'a mut [f32]>,
            kahan: Option<&'a mut [f32]>,
            u: &'a mut [f32],
            stats: UpdateStats,
        }

        let per = n.div_ceil(threads);
        let mut parts: Vec<Span> = Vec::with_capacity(threads);
        let mut w_rest = w;
        let mut u_rest = self.u_buf.as_mut_slice();
        let mut g_rest = g;
        let mut m_rest = mom;
        let mut k_rest = kahan;
        let mut base = 0usize;
        while base < n {
            let take = per.min(n - base);
            let (wc, wr) = std::mem::take(&mut w_rest).split_at_mut(take);
            let (uc, ur) = std::mem::take(&mut u_rest).split_at_mut(take);
            let (gc, gr) = g_rest.split_at(take);
            g_rest = gr;
            let mc = match m_rest.take() {
                Some(s) => {
                    let (a, b) = s.split_at_mut(take);
                    m_rest = Some(b);
                    Some(a)
                }
                None => None,
            };
            let kc = match k_rest.take() {
                Some(s) => {
                    let (a, b) = s.split_at_mut(take);
                    k_rest = Some(b);
                    Some(a)
                }
                None => None,
            };
            parts.push(Span {
                base,
                w: wc,
                g: gc,
                mom: mc,
                kahan: kc,
                u: uc,
                stats: UpdateStats::default(),
            });
            w_rest = wr;
            u_rest = ur;
            base += take;
        }
        let parts = self.pool.run_parts(parts, |s| {
            s.stats = step_span(
                p,
                s.base as u64,
                &mut *s.w,
                s.g,
                s.mom.as_deref_mut(),
                s.kahan.as_deref_mut(),
                &mut *s.u,
            );
        });
        let mut stats = UpdateStats::default();
        for s in parts {
            stats.merge(s.stats);
        }
        stats
    }
}

/// Widen a possibly-narrow tensor into `buf`; returns whether it was narrow.
fn widen_if_native16(t: Option<&Tensor>, buf: &mut Vec<f32>) -> bool {
    match t {
        Some(t) if t.is_native16() => {
            buf.resize(t.len(), 0.0);
            t.widen_into(buf);
            true
        }
        _ => false,
    }
}

/// The original interleaved per-element loop (pre-vectorization code),
/// kept as the bit-exactness oracle and bench baseline.  Always scalar
/// and sequential, but addressing the same counter-keyed dither.
fn step_reference_slices(
    p: StepParams,
    w: &mut [f32],
    g: &[f32],
    mut mom: Option<&mut [f32]>,
    mut kahan: Option<&mut [f32]>,
) -> UpdateStats {
    let fmt = p.fmt;
    let r = |x: f32| if p.exact { x } else { round_nearest(x, fmt) };
    let mut stats = UpdateStats::default();
    for i in 0..w.len() {
        let mut gi = g[i];
        if p.weight_decay != 0.0 {
            gi = r(gi + r(p.weight_decay * w[i]));
        }
        let m = if let Some(mom) = mom.as_deref_mut() {
            let m_new = r(r(p.momentum * mom[i]) + gi);
            mom[i] = m_new;
            m_new
        } else {
            gi
        };
        let u = r(p.lr * m);
        let wi = w[i];
        let w_new = if p.kahan {
            // srkahan16 (Fig 11): the accumulate output is SR'd
            let c = kahan.as_deref_mut().expect("kahan mode without kahan state");
            let y = r(-u - c[i]);
            let s = if p.stochastic {
                round_stochastic(wi + y, fmt, p.key.word(i as u64))
            } else {
                r(wi + y)
            };
            c[i] = r(r(s - wi) - y);
            s
        } else if p.exact {
            wi - u
        } else if p.stochastic {
            round_stochastic(wi - u, fmt, p.key.word(i as u64))
        } else {
            r(wi - u)
        };
        if u != 0.0 {
            stats.nonzero += 1;
            if w_new == wi {
                stats.cancelled += 1;
            }
        }
        w[i] = w_new;
    }
    stats
}

/// The staged update over one contiguous element span starting at global
/// offset `base`.  Every stage is element-local and the dither word for
/// element `base + i` is `p.key.word(base + i)`, so running the spans of a
/// partition in any order (or in parallel) reproduces the whole-tensor pass
/// bit-for-bit.  Dispatches to the scalar or 8-wide lane body per
/// `p.simd`; the two are bit-identical (enforced by the parity tests).
fn step_span(
    p: StepParams,
    base: u64,
    w: &mut [f32],
    g: &[f32],
    mom: Option<&mut [f32]>,
    kahan: Option<&mut [f32]>,
    u: &mut [f32],
) -> UpdateStats {
    if p.simd {
        step_span_simd(p, base, w, g, mom, kahan, u)
    } else {
        step_span_scalar(p, base, w, g, mom, kahan, u)
    }
}

fn step_span_scalar(
    p: StepParams,
    base: u64,
    w: &mut [f32],
    g: &[f32],
    mom: Option<&mut [f32]>,
    kahan: Option<&mut [f32]>,
    u: &mut [f32],
) -> UpdateStats {
    let n = w.len();
    debug_assert_eq!(g.len(), n);
    debug_assert_eq!(u.len(), n);
    let fmt = p.fmt;

    // stage 1: effective gradient (+ optional decoupled weight decay)
    u.copy_from_slice(g);
    if p.weight_decay != 0.0 {
        let wd = p.weight_decay;
        if p.exact {
            for (ui, &wi) in u.iter_mut().zip(w.iter()) {
                *ui += wd * wi;
            }
        } else {
            for (ui, &wi) in u.iter_mut().zip(w.iter()) {
                *ui = round_nearest(*ui + round_nearest(wd * wi, fmt), fmt);
            }
        }
    }

    // stage 2: momentum accumulation (slice pass over the state span)
    if let Some(mom) = mom {
        let mu = p.momentum;
        if p.exact {
            for (ui, mi) in u.iter_mut().zip(mom.iter_mut()) {
                let m_new = mu * *mi + *ui;
                *mi = m_new;
                *ui = m_new;
            }
        } else {
            for (ui, mi) in u.iter_mut().zip(mom.iter_mut()) {
                let m_new = round_nearest(round_nearest(mu * *mi, fmt) + *ui, fmt);
                *mi = m_new;
                *ui = m_new;
            }
        }
    }

    // stage 3: update magnitude u = r(lr · m)
    for ui in u.iter_mut() {
        *ui *= p.lr;
    }
    if !p.exact {
        round_nearest_slice(u, fmt);
    }

    // stage 4: weight accumulate + cancellation stats, one pass, dither
    // addressed by global element position
    let mut stats = UpdateStats::default();
    if p.kahan {
        // srkahan16 (Fig 11): the accumulate output is SR'd
        let c = kahan.expect("kahan mode without kahan state");
        for i in 0..n {
            let ui = u[i];
            let wi = w[i];
            let y = round_nearest(-ui - c[i], fmt);
            let s = if p.stochastic {
                round_stochastic(wi + y, fmt, p.key.word(base.wrapping_add(i as u64)))
            } else {
                round_nearest(wi + y, fmt)
            };
            c[i] = round_nearest(round_nearest(s - wi, fmt) - y, fmt);
            if ui != 0.0 {
                stats.nonzero += 1;
                if s == wi {
                    stats.cancelled += 1;
                }
            }
            w[i] = s;
        }
    } else if p.exact {
        for (wi, &ui) in w.iter_mut().zip(u.iter()) {
            let w_new = *wi - ui;
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == *wi {
                    stats.cancelled += 1;
                }
            }
            *wi = w_new;
        }
    } else if p.stochastic {
        // scalar keyed draws: the cancellation stats need each update
        // magnitude `u[i]` *and* its rounded result side by side, so the
        // slice kernel (which would overwrite one of them) doesn't fit here
        for i in 0..n {
            let ui = u[i];
            let wi = w[i];
            let w_new =
                round_stochastic(wi - ui, fmt, p.key.word(base.wrapping_add(i as u64)));
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == wi {
                    stats.cancelled += 1;
                }
            }
            w[i] = w_new;
        }
    } else {
        for (wi, &ui) in w.iter_mut().zip(u.iter()) {
            let w_new = round_nearest(*wi - ui, fmt);
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == *wi {
                    stats.cancelled += 1;
                }
            }
            *wi = w_new;
        }
    }
    stats
}

/// The `Simd`-tier span body: the same four stages as
/// [`step_span_scalar`], with every per-element rounding routed through
/// the 8-wide integer lane kernels ([`SimdRound`]).  Each lane computes
/// exactly the scalar arithmetic — IEEE f32 mul/add are deterministic per
/// element and the lane rounders are bit-identical to the scalar kernels —
/// so the span result is bit-for-bit the scalar span's.
fn step_span_simd(
    p: StepParams,
    base: u64,
    w: &mut [f32],
    g: &[f32],
    mom: Option<&mut [f32]>,
    kahan: Option<&mut [f32]>,
    u: &mut [f32],
) -> UpdateStats {
    let n = w.len();
    debug_assert_eq!(g.len(), n);
    debug_assert_eq!(u.len(), n);
    let fmt = p.fmt;
    let lane = SimdRound::new(fmt);
    let n8 = n & !(LANES - 1);

    // stage 1: effective gradient (+ optional decoupled weight decay)
    u.copy_from_slice(g);
    if p.weight_decay != 0.0 {
        let wd = p.weight_decay;
        if p.exact {
            for (ui, &wi) in u.iter_mut().zip(w.iter()) {
                *ui += wd * wi;
            }
        } else {
            let mut i = 0;
            while i < n8 {
                let mut t = [0f32; LANES];
                for (tl, &wl) in t.iter_mut().zip(&w[i..i + LANES]) {
                    *tl = wd * wl;
                }
                lane.nearest8(&mut t);
                for (tl, &ul) in t.iter_mut().zip(&u[i..i + LANES]) {
                    *tl += ul;
                }
                lane.nearest8(&mut t);
                u[i..i + LANES].copy_from_slice(&t);
                i += LANES;
            }
            for i in n8..n {
                u[i] = round_nearest(u[i] + round_nearest(wd * w[i], fmt), fmt);
            }
        }
    }

    // stage 2: momentum accumulation
    if let Some(mom) = mom {
        let mu = p.momentum;
        if p.exact {
            for (ui, mi) in u.iter_mut().zip(mom.iter_mut()) {
                let m_new = mu * *mi + *ui;
                *mi = m_new;
                *ui = m_new;
            }
        } else {
            let mut i = 0;
            while i < n8 {
                let mut t = [0f32; LANES];
                for (tl, &ml) in t.iter_mut().zip(&mom[i..i + LANES]) {
                    *tl = mu * ml;
                }
                lane.nearest8(&mut t);
                for (tl, &ul) in t.iter_mut().zip(&u[i..i + LANES]) {
                    *tl += ul;
                }
                lane.nearest8(&mut t);
                mom[i..i + LANES].copy_from_slice(&t);
                u[i..i + LANES].copy_from_slice(&t);
                i += LANES;
            }
            for i in n8..n {
                let m_new = round_nearest(round_nearest(mu * mom[i], fmt) + u[i], fmt);
                mom[i] = m_new;
                u[i] = m_new;
            }
        }
    }

    // stage 3: update magnitude u = r(lr · m) via the slice kernel
    for ui in u.iter_mut() {
        *ui *= p.lr;
    }
    if !p.exact {
        round_nearest_slice_simd(u, fmt);
    }

    // stage 4: weight accumulate + cancellation stats, lane blocks with a
    // scalar ragged tail; dither addressed by global element position
    let mut stats = UpdateStats::default();
    if p.kahan {
        let c = kahan.expect("kahan mode without kahan state");
        let mut i = 0;
        while i < n8 {
            // y = r(-u - c)
            let mut y = [0f32; LANES];
            for (l, yl) in y.iter_mut().enumerate() {
                *yl = -u[i + l] - c[i + l];
            }
            lane.nearest8(&mut y);
            // s = SR/RN(w + y)
            let mut s = [0f32; LANES];
            for (l, sl) in s.iter_mut().enumerate() {
                *sl = w[i + l] + y[l];
            }
            if p.stochastic {
                let mut rb = [0u32; LANES];
                for (l, rbl) in rb.iter_mut().enumerate() {
                    *rbl = p.key.word(base.wrapping_add((i + l) as u64));
                }
                lane.stochastic8(&mut s, &rb);
            } else {
                lane.nearest8(&mut s);
            }
            // c = r(r(s - w) - y)
            let mut t = [0f32; LANES];
            for (l, tl) in t.iter_mut().enumerate() {
                *tl = s[l] - w[i + l];
            }
            lane.nearest8(&mut t);
            for (tl, &yl) in t.iter_mut().zip(y.iter()) {
                *tl -= yl;
            }
            lane.nearest8(&mut t);
            c[i..i + LANES].copy_from_slice(&t);
            for (l, &sl) in s.iter().enumerate() {
                let ui = u[i + l];
                if ui != 0.0 {
                    stats.nonzero += 1;
                    if sl == w[i + l] {
                        stats.cancelled += 1;
                    }
                }
                w[i + l] = sl;
            }
            i += LANES;
        }
        for i in n8..n {
            let ui = u[i];
            let wi = w[i];
            let y = round_nearest(-ui - c[i], fmt);
            let s = if p.stochastic {
                round_stochastic(wi + y, fmt, p.key.word(base.wrapping_add(i as u64)))
            } else {
                round_nearest(wi + y, fmt)
            };
            c[i] = round_nearest(round_nearest(s - wi, fmt) - y, fmt);
            if ui != 0.0 {
                stats.nonzero += 1;
                if s == wi {
                    stats.cancelled += 1;
                }
            }
            w[i] = s;
        }
    } else if p.exact {
        for (wi, &ui) in w.iter_mut().zip(u.iter()) {
            let w_new = *wi - ui;
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == *wi {
                    stats.cancelled += 1;
                }
            }
            *wi = w_new;
        }
    } else if p.stochastic {
        let mut i = 0;
        while i < n8 {
            let mut x = [0f32; LANES];
            let mut rb = [0u32; LANES];
            for (l, xl) in x.iter_mut().enumerate() {
                *xl = w[i + l] - u[i + l];
            }
            for (l, rbl) in rb.iter_mut().enumerate() {
                *rbl = p.key.word(base.wrapping_add((i + l) as u64));
            }
            lane.stochastic8(&mut x, &rb);
            for (l, &xl) in x.iter().enumerate() {
                let ui = u[i + l];
                if ui != 0.0 {
                    stats.nonzero += 1;
                    if xl == w[i + l] {
                        stats.cancelled += 1;
                    }
                }
                w[i + l] = xl;
            }
            i += LANES;
        }
        for i in n8..n {
            let ui = u[i];
            let wi = w[i];
            let w_new =
                round_stochastic(wi - ui, fmt, p.key.word(base.wrapping_add(i as u64)));
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == wi {
                    stats.cancelled += 1;
                }
            }
            w[i] = w_new;
        }
    } else {
        let mut i = 0;
        while i < n8 {
            let mut x = [0f32; LANES];
            for (l, xl) in x.iter_mut().enumerate() {
                *xl = w[i + l] - u[i + l];
            }
            lane.nearest8(&mut x);
            for (l, &xl) in x.iter().enumerate() {
                let ui = u[i + l];
                if ui != 0.0 {
                    stats.nonzero += 1;
                    if xl == w[i + l] {
                        stats.cancelled += 1;
                    }
                }
                w[i + l] = xl;
            }
            i += LANES;
        }
        for i in n8..n {
            let ui = u[i];
            let wi = w[i];
            let w_new = round_nearest(wi - ui, fmt);
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == wi {
                    stats.cancelled += 1;
                }
            }
            w[i] = w_new;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn run(mode: Mode, grad: f32, lr: f32, steps: usize) -> (f32, f64) {
        let mut opt = Sgd::bf16(mode, 0.0, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(grad);
        let mut total = UpdateStats::default();
        for _ in 0..steps {
            total.merge(opt.step(&mut w, &mut st, &g, lr));
        }
        (w.item(), total.frac())
    }

    #[test]
    fn nearest_halts_small_updates() {
        let (w, frac) = run(Mode::Standard16, 2f32.powi(-11), 1.0, 50);
        assert_eq!(w, 1.0);
        assert_eq!(frac, 1.0);
    }

    #[test]
    fn kahan_lands_small_updates() {
        let (w, _) = run(Mode::Kahan16, 2f32.powi(-11), 1.0, 64);
        let exact = 1.0 - 64.0 * 2f32.powi(-11);
        assert!((w - exact).abs() <= 2f32.powi(-8), "{w}");
    }

    #[test]
    fn sr_progresses_in_expectation() {
        let mut acc = 0f64;
        let n = 50;
        for seed in 0..n {
            let mut opt = Sgd::bf16(Mode::Sr16, 0.0, 0.0, seed);
            let mut w = Tensor::scalar(1.0);
            let mut st = opt.init_state(&w);
            let g = Tensor::scalar(2f32.powi(-11));
            for _ in 0..64 {
                opt.step(&mut w, &mut st, &g, 1.0);
            }
            acc += w.item() as f64;
        }
        let mean = acc / n as f64;
        let target = 1.0 - 64.0 * 2f64.powi(-11);
        assert!((mean - target).abs() < 0.01, "{mean} vs {target}");
    }

    #[test]
    fn exact_modes_track_exact_descent() {
        for mode in [Mode::Fp32, Mode::Mixed16] {
            let (w, frac) = run(mode, 2f32.powi(-11), 1.0, 10);
            assert!((w - (1.0 - 10.0 * 2f32.powi(-11))).abs() < 1e-6);
            assert_eq!(frac, 0.0, "{mode:?}");
        }
    }

    #[test]
    fn momentum_accelerates() {
        let mut opt = Sgd::bf16(Mode::Fp32, 0.9, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(0.01);
        for _ in 0..10 {
            opt.step(&mut w, &mut st, &g, 0.1);
        }
        // with momentum the total displacement exceeds 10 * lr * g
        assert!(1.0 - w.item() > 10.0 * 0.1 * 0.01);
    }

    #[test]
    fn fast_and_simd_steps_bit_identical_to_reference_all_modes() {
        use crate::precision::{E8M5, FP16};
        let mut rng = Rng::new(0x51, 0);
        for backend in [Backend::Fast, Backend::Simd] {
            for mode in Mode::ALL {
                for fmt in [BF16, FP16, E8M5] {
                    for (momentum, wd) in [(0.0, 0.0), (0.9, 0.0), (0.9, 1e-4)] {
                        let mut vec = Sgd::new(mode, fmt, momentum, wd, 42)
                            .with_tensor_id(7)
                            .with_backend(backend);
                        let mut reference = Sgd::new(mode, fmt, momentum, wd, 42)
                            .with_tensor_id(7)
                            .with_backend(Backend::Reference);
                        // odd length exercises ragged dither chunks + lane tails
                        let len = 515;
                        let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
                        let mut wf = Tensor::vector(init.clone());
                        let mut wr = Tensor::vector(init);
                        let mut sf = vec.init_state(&wf);
                        let mut sr = reference.init_state(&wr);
                        for step in 0..20 {
                            // occasionally-zero gradients hit the stats guard
                            let g = Tensor::vector(
                                (0..len)
                                    .map(|i| {
                                        if (i + step) % 13 == 0 {
                                            0.0
                                        } else {
                                            rng.normal() * 2f32.powi(-(step as i32) - 2)
                                        }
                                    })
                                    .collect(),
                            );
                            let stf = vec.step(&mut wf, &mut sf, &g, 0.05);
                            let str_ = reference.step(&mut wr, &mut sr, &g, 0.05);
                            assert_eq!(
                                stf, str_,
                                "{backend:?}/{mode:?}/{}/mu={momentum} step {step}",
                                fmt.name
                            );
                            for (i, (a, b)) in wf.data.iter().zip(&wr.data).enumerate() {
                                assert_eq!(
                                    a.to_bits(),
                                    b.to_bits(),
                                    "{backend:?}/{mode:?}/{}/mu={momentum} step {step} w[{i}]",
                                    fmt.name
                                );
                            }
                            if let (Some(mf), Some(mr)) = (&sf.momentum, &sr.momentum) {
                                assert_eq!(mf.data, mr.data, "{backend:?}/{mode:?} momentum");
                            }
                            if let (Some(kf), Some(kr)) = (&sf.kahan, &sr.kahan) {
                                assert_eq!(kf.data, kr.data, "{backend:?}/{mode:?} kahan");
                            }
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_step_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(0x52, 0);
        // big enough to split into several SGD_PAR_MIN chunks, ragged tail
        let len = 3 * SGD_PAR_MIN + 517;
        let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let grads: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..len).map(|_| rng.normal() * 2f32.powi(-6)).collect())
            .collect();
        for backend in [Backend::Fast, Backend::Simd] {
            for mode in [Mode::Sr16, Mode::SrKahan16, Mode::Kahan16, Mode::Standard16] {
                let run_with = |threads: usize| {
                    let mut opt = Sgd::bf16(mode, 0.9, 1e-4, 9)
                        .with_tensor_id(3)
                        .with_backend(backend)
                        .with_pool(Arc::new(Pool::new(threads)));
                    let mut w = Tensor::vector(init.clone());
                    let mut st = opt.init_state(&w);
                    let mut stats = UpdateStats::default();
                    for g in &grads {
                        stats.merge(opt.step(&mut w, &mut st, &Tensor::vector(g.clone()), 0.05));
                    }
                    (w, st, stats)
                };
                let (w1, s1, st1) = run_with(1);
                for threads in [2usize, 3, 4] {
                    let (wt, stt, stats_t) = run_with(threads);
                    assert_eq!(st1, stats_t, "{backend:?}/{mode:?} stats threads={threads}");
                    for (i, (a, b)) in w1.data.iter().zip(&wt.data).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "{backend:?}/{mode:?} threads={threads} w[{i}]"
                        );
                    }
                    if let (Some(ma), Some(mb)) = (&s1.momentum, &stt.momentum) {
                        assert_eq!(ma.data, mb.data, "{backend:?}/{mode:?} momentum");
                    }
                    if let (Some(ka), Some(kb)) = (&s1.kahan, &stt.kahan) {
                        assert_eq!(ka.data, kb.data, "{backend:?}/{mode:?} kahan");
                    }
                }
            }
        }
    }

    #[test]
    fn native16_storage_step_bit_identical_to_f32_storage() {
        let mut rng = Rng::new(0x53, 0);
        let len = 515;
        for mode in [Mode::Standard16, Mode::Sr16, Mode::Kahan16, Mode::SrKahan16] {
            for backend in [Backend::Reference, Backend::Fast, Backend::Simd] {
                // init must sit on the bf16 grid before narrowing (the
                // trainer rounds inits onto the format via `nn::quant`)
                let init: Vec<f32> = (0..len)
                    .map(|_| round_nearest(rng.normal(), BF16))
                    .collect();
                let mut w_f32 = Tensor::vector(init.clone());
                let mut w_n = Tensor::vector(init);
                w_n.narrow_to_bf16();
                let mut opt_a = Sgd::bf16(mode, 0.0, 0.0, 5)
                    .with_tensor_id(2)
                    .with_backend(backend);
                let mut opt_b = Sgd::bf16(mode, 0.0, 0.0, 5)
                    .with_tensor_id(2)
                    .with_backend(backend);
                let mut sa = opt_a.init_state(&w_f32);
                let mut sb = opt_b.init_state(&w_n);
                if let Some(k) = sb.kahan.as_mut() {
                    k.narrow_to_bf16();
                }
                for step in 0..8 {
                    let g = Tensor::vector(
                        (0..len)
                            .map(|i| {
                                ((i * 31 + step * 7) % 17) as f32 * 2f32.powi(-9) - 0.03
                            })
                            .collect(),
                    );
                    let sta = opt_a.step(&mut w_f32, &mut sa, &g, 0.05);
                    let stb = opt_b.step(&mut w_n, &mut sb, &g, 0.05);
                    assert_eq!(sta, stb, "{mode:?}/{backend:?} stats step {step}");
                }
                assert!(w_n.is_native16(), "storage class must persist");
                assert_eq!(w_n.storage_bytes() * 2, w_f32.storage_bytes());
                let wa = w_f32.to_f32_vec();
                let wb = w_n.to_f32_vec();
                for (i, (a, b)) in wa.iter().zip(&wb).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{mode:?}/{backend:?} w[{i}]");
                }
                if let (Some(ka), Some(kb)) = (&sa.kahan, &sb.kahan) {
                    assert_eq!(ka.to_f32_vec(), kb.to_f32_vec(), "{mode:?} kahan");
                }
            }
        }
    }

    #[test]
    fn from_policy_binds_mode_and_fmt() {
        let p = Policy::parse("sr16-e8m5").unwrap();
        let opt = Sgd::from_policy(p, 0.9, 0.0, 1);
        assert_eq!(opt.mode, Mode::Sr16);
        assert_eq!(opt.fmt, crate::precision::E8M5);
    }
}
