//! Rust-native optimizer with the paper's weight-update policies
//! (mirror of `python/compile/optim.py` over `qsim` tensors).
//!
//! Used by the native theory experiments (Figure 2, Theorem 1, Figure 9/10
//! fast paths) and by the property-test suite; the PJRT path runs the same
//! algorithms inside lowered HLO instead.
//!
//! ## Dither schedule
//!
//! Stochastic-rounding dither is **counter-keyed**: the word for element
//! `i` of step `t` is `DitherKey::new(seed, STREAM, t, tensor_id).word(i)` —
//! a pure function of position, not a draw from a sequential stream.  Both
//! backends consume the same schedule by construction, and the `Fast` path
//! can split the update into chunks across a worker [`Pool`] without
//! changing a single bit of the result.

use std::sync::Arc;

use crate::precision::{
    round_nearest, round_nearest_slice, round_stochastic, Format, Mode, Policy, BF16,
};
use crate::util::rng::DitherKey;

use super::pool::Pool;
use super::tensor::Tensor;
use super::Backend;

/// Stream tag separating optimizer dither keys from every other RNG use.
const SGD_DITHER_STREAM: u64 = 0x0907;

/// Minimum elements per chunk before `Sgd::step` fans out across the pool.
const SGD_PAR_MIN: usize = 4096;

/// Per-step statistics (Figure 9's cancellation telemetry).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UpdateStats {
    /// Non-zero updates cancelled by rounding.
    pub cancelled: u64,
    /// Non-zero updates total.
    pub nonzero: u64,
}

impl UpdateStats {
    pub fn frac(&self) -> f64 {
        if self.nonzero == 0 {
            0.0
        } else {
            self.cancelled as f64 / self.nonzero as f64
        }
    }

    pub fn merge(&mut self, other: UpdateStats) {
        self.cancelled += other.cancelled;
        self.nonzero += other.nonzero;
    }
}

/// SGD(-momentum) optimizer state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct SgdState {
    pub momentum: Option<Tensor>,
    pub kahan: Option<Tensor>,
}

/// SGD with the paper's weight-update policies.
#[derive(Debug)]
pub struct Sgd {
    pub mode: Mode,
    pub fmt: Format,
    pub momentum: f32,
    pub weight_decay: f32,
    pub backend: Backend,
    /// Seed coordinate of the dither key (run-level randomness).
    seed: u64,
    /// Tensor coordinate of the dither key — set one id per parameter
    /// tensor ([`Sgd::with_tensor_id`]) so tensors sharing a seed still
    /// draw independent dither.
    tensor_id: u64,
    /// Steps taken so far — the step coordinate of the dither key.
    step_idx: u64,
    /// Worker pool for the chunked `Fast` update (single-threaded default).
    pool: Arc<Pool>,
    /// Per-step update-magnitude scratch (stage buffer, reused across steps).
    u_buf: Vec<f32>,
}

/// Scalar parameters of one update, copied per step so chunk workers share
/// them without touching `&self`.
#[derive(Clone, Copy)]
struct StepParams {
    fmt: Format,
    exact: bool,
    stochastic: bool,
    kahan: bool,
    momentum: f32,
    weight_decay: f32,
    lr: f32,
    key: DitherKey,
}

impl Sgd {
    pub fn new(mode: Mode, fmt: Format, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self {
            mode,
            fmt,
            momentum,
            weight_decay,
            backend: Backend::Fast,
            seed,
            tensor_id: 0,
            step_idx: 0,
            pool: Pool::single(),
            u_buf: Vec::new(),
        }
    }

    /// Builder-style backend override (the scalar reference path).
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Builder-style tensor id for the dither key (one id per parameter
    /// tensor of a model).
    pub fn with_tensor_id(mut self, tensor_id: u64) -> Self {
        self.tensor_id = tensor_id;
        self
    }

    /// Builder-style worker pool for the chunked `Fast` update.  Results
    /// are bit-identical at every pool size (and to `Reference`).
    pub fn with_pool(mut self, pool: Arc<Pool>) -> Self {
        self.pool = pool;
        self
    }

    /// Steps taken so far — the step coordinate of the dither key.
    pub fn step_idx(&self) -> u64 {
        self.step_idx
    }

    /// Reposition the step counter (checkpoint resume): the next update
    /// draws dither for step `idx`, exactly as if the optimizer had already
    /// executed `idx` steps.  Because the dither schedule is counter-keyed,
    /// this is all the RNG state an optimizer has.
    pub fn set_step_idx(&mut self, idx: u64) {
        self.step_idx = idx;
    }

    pub fn bf16(mode: Mode, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(mode, BF16, momentum, weight_decay, seed)
    }

    /// Build from a typed precision policy.
    pub fn from_policy(policy: Policy, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(policy.mode, policy.fmt, momentum, weight_decay, seed)
    }

    pub fn init_state(&self, w: &Tensor) -> SgdState {
        SgdState {
            momentum: (self.momentum != 0.0).then(|| Tensor::zeros(w.rows, w.cols)),
            kahan: self.mode.kahan().then(|| Tensor::zeros(w.rows, w.cols)),
        }
    }

    /// One update of `w` from gradient `g`.  All optimizer-internal ops are
    /// nearest-rounded in the 16-bit modes (Algorithms 2 & 3).
    ///
    /// The fast path runs as per-stage slice passes, chunked across the
    /// worker pool when the tensor is large enough; the reference path is
    /// the original interleaved per-element loop.  Both consume the same
    /// counter-keyed dither schedule (word `i` of the step's key for
    /// element `i`), so they are bit-identical — to each other and across
    /// every thread count.
    pub fn step(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
    ) -> UpdateStats {
        let key = DitherKey::new(self.seed, SGD_DITHER_STREAM, self.step_idx, self.tensor_id);
        self.step_idx = self.step_idx.wrapping_add(1);
        match self.backend {
            Backend::Fast => self.step_fast(w, state, g, lr, key),
            Backend::Reference => self.step_reference(w, state, g, lr, key),
        }
    }

    /// Vectorized update: per-stage slice passes over `w` / `momentum` /
    /// `kahan` with the format constants hoisted, run whole (small tensors)
    /// or as disjoint chunks fanned out over the pool (large tensors).
    fn step_fast(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
        key: DitherKey,
    ) -> UpdateStats {
        let n = w.data.len();
        debug_assert_eq!(g.data.len(), n);
        let p = StepParams {
            fmt: self.fmt,
            exact: self.mode.exact_update(),
            stochastic: self.mode.stochastic(),
            kahan: self.mode.kahan(),
            momentum: self.momentum,
            weight_decay: self.weight_decay,
            lr,
            key,
        };
        if self.u_buf.len() != n {
            self.u_buf.resize(n, 0.0);
        }
        let threads = self.pool.threads().min(n / SGD_PAR_MIN.max(1)).max(1);
        if threads <= 1 {
            return step_span(
                p,
                0,
                &mut w.data,
                &g.data,
                state.momentum.as_mut().map(|t| t.data.as_mut_slice()),
                state.kahan.as_mut().map(|t| t.data.as_mut_slice()),
                &mut self.u_buf,
            );
        }

        /// One worker's disjoint view of every per-element array.
        struct Span<'a> {
            base: usize,
            w: &'a mut [f32],
            g: &'a [f32],
            mom: Option<&'a mut [f32]>,
            kahan: Option<&'a mut [f32]>,
            u: &'a mut [f32],
            stats: UpdateStats,
        }

        let per = n.div_ceil(threads);
        let mut parts: Vec<Span> = Vec::with_capacity(threads);
        let mut w_rest = w.data.as_mut_slice();
        let mut u_rest = self.u_buf.as_mut_slice();
        let mut g_rest: &[f32] = &g.data;
        let mut m_rest = state.momentum.as_mut().map(|t| t.data.as_mut_slice());
        let mut k_rest = state.kahan.as_mut().map(|t| t.data.as_mut_slice());
        let mut base = 0usize;
        while base < n {
            let take = per.min(n - base);
            let (wc, wr) = std::mem::take(&mut w_rest).split_at_mut(take);
            let (uc, ur) = std::mem::take(&mut u_rest).split_at_mut(take);
            let (gc, gr) = g_rest.split_at(take);
            g_rest = gr;
            let mc = match m_rest.take() {
                Some(s) => {
                    let (a, b) = s.split_at_mut(take);
                    m_rest = Some(b);
                    Some(a)
                }
                None => None,
            };
            let kc = match k_rest.take() {
                Some(s) => {
                    let (a, b) = s.split_at_mut(take);
                    k_rest = Some(b);
                    Some(a)
                }
                None => None,
            };
            parts.push(Span {
                base,
                w: wc,
                g: gc,
                mom: mc,
                kahan: kc,
                u: uc,
                stats: UpdateStats::default(),
            });
            w_rest = wr;
            u_rest = ur;
            base += take;
        }
        let parts = self.pool.run_parts(parts, |s| {
            s.stats = step_span(
                p,
                s.base as u64,
                &mut *s.w,
                s.g,
                s.mom.as_deref_mut(),
                s.kahan.as_deref_mut(),
                &mut *s.u,
            );
        });
        let mut stats = UpdateStats::default();
        for s in parts {
            stats.merge(s.stats);
        }
        stats
    }

    /// The original interleaved per-element loop (pre-vectorization code),
    /// kept as the bit-exactness oracle and bench baseline.  Always scalar
    /// and sequential, but addressing the same counter-keyed dither.
    fn step_reference(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
        key: DitherKey,
    ) -> UpdateStats {
        let exact = self.mode.exact_update();
        let fmt = self.fmt;
        let r = |x: f32| if exact { x } else { round_nearest(x, fmt) };
        let mut stats = UpdateStats::default();
        for i in 0..w.data.len() {
            let mut gi = g.data[i];
            if self.weight_decay != 0.0 {
                gi = r(gi + r(self.weight_decay * w.data[i]));
            }
            let m = if let Some(mom) = &mut state.momentum {
                let m_new = r(r(self.momentum * mom.data[i]) + gi);
                mom.data[i] = m_new;
                m_new
            } else {
                gi
            };
            let u = r(lr * m);
            let wi = w.data[i];
            let w_new = if self.mode.kahan() {
                // srkahan16 (Fig 11): the accumulate output is SR'd
                let c = state.kahan.as_mut().unwrap();
                let y = r(-u - c.data[i]);
                let s = if self.mode.stochastic() {
                    round_stochastic(wi + y, fmt, key.word(i as u64))
                } else {
                    r(wi + y)
                };
                c.data[i] = r(r(s - wi) - y);
                s
            } else if exact {
                wi - u
            } else if self.mode.stochastic() {
                round_stochastic(wi - u, fmt, key.word(i as u64))
            } else {
                r(wi - u)
            };
            if u != 0.0 {
                stats.nonzero += 1;
                if w_new == wi {
                    stats.cancelled += 1;
                }
            }
            w.data[i] = w_new;
        }
        stats
    }
}

/// The staged update over one contiguous element span starting at global
/// offset `base`.  Every stage is element-local and the dither word for
/// element `base + i` is `p.key.word(base + i)`, so running the spans of a
/// partition in any order (or in parallel) reproduces the whole-tensor pass
/// bit-for-bit.
fn step_span(
    p: StepParams,
    base: u64,
    w: &mut [f32],
    g: &[f32],
    mom: Option<&mut [f32]>,
    kahan: Option<&mut [f32]>,
    u: &mut [f32],
) -> UpdateStats {
    let n = w.len();
    debug_assert_eq!(g.len(), n);
    debug_assert_eq!(u.len(), n);
    let fmt = p.fmt;

    // stage 1: effective gradient (+ optional decoupled weight decay)
    u.copy_from_slice(g);
    if p.weight_decay != 0.0 {
        let wd = p.weight_decay;
        if p.exact {
            for (ui, &wi) in u.iter_mut().zip(w.iter()) {
                *ui += wd * wi;
            }
        } else {
            for (ui, &wi) in u.iter_mut().zip(w.iter()) {
                *ui = round_nearest(*ui + round_nearest(wd * wi, fmt), fmt);
            }
        }
    }

    // stage 2: momentum accumulation (slice pass over the state span)
    if let Some(mom) = mom {
        let mu = p.momentum;
        if p.exact {
            for (ui, mi) in u.iter_mut().zip(mom.iter_mut()) {
                let m_new = mu * *mi + *ui;
                *mi = m_new;
                *ui = m_new;
            }
        } else {
            for (ui, mi) in u.iter_mut().zip(mom.iter_mut()) {
                let m_new = round_nearest(round_nearest(mu * *mi, fmt) + *ui, fmt);
                *mi = m_new;
                *ui = m_new;
            }
        }
    }

    // stage 3: update magnitude u = r(lr · m)
    for ui in u.iter_mut() {
        *ui *= p.lr;
    }
    if !p.exact {
        round_nearest_slice(u, fmt);
    }

    // stage 4: weight accumulate + cancellation stats, one pass, dither
    // addressed by global element position
    let mut stats = UpdateStats::default();
    if p.kahan {
        // srkahan16 (Fig 11): the accumulate output is SR'd
        let c = kahan.expect("kahan mode without kahan state");
        for i in 0..n {
            let ui = u[i];
            let wi = w[i];
            let y = round_nearest(-ui - c[i], fmt);
            let s = if p.stochastic {
                round_stochastic(wi + y, fmt, p.key.word(base.wrapping_add(i as u64)))
            } else {
                round_nearest(wi + y, fmt)
            };
            c[i] = round_nearest(round_nearest(s - wi, fmt) - y, fmt);
            if ui != 0.0 {
                stats.nonzero += 1;
                if s == wi {
                    stats.cancelled += 1;
                }
            }
            w[i] = s;
        }
    } else if p.exact {
        for (wi, &ui) in w.iter_mut().zip(u.iter()) {
            let w_new = *wi - ui;
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == *wi {
                    stats.cancelled += 1;
                }
            }
            *wi = w_new;
        }
    } else if p.stochastic {
        // scalar keyed draws: the cancellation stats need each update
        // magnitude `u[i]` *and* its rounded result side by side, so the
        // slice kernel (which would overwrite one of them) doesn't fit here
        for i in 0..n {
            let ui = u[i];
            let wi = w[i];
            let w_new =
                round_stochastic(wi - ui, fmt, p.key.word(base.wrapping_add(i as u64)));
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == wi {
                    stats.cancelled += 1;
                }
            }
            w[i] = w_new;
        }
    } else {
        for (wi, &ui) in w.iter_mut().zip(u.iter()) {
            let w_new = round_nearest(*wi - ui, fmt);
            if ui != 0.0 {
                stats.nonzero += 1;
                if w_new == *wi {
                    stats.cancelled += 1;
                }
            }
            *wi = w_new;
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn run(mode: Mode, grad: f32, lr: f32, steps: usize) -> (f32, f64) {
        let mut opt = Sgd::bf16(mode, 0.0, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(grad);
        let mut total = UpdateStats::default();
        for _ in 0..steps {
            total.merge(opt.step(&mut w, &mut st, &g, lr));
        }
        (w.item(), total.frac())
    }

    #[test]
    fn nearest_halts_small_updates() {
        let (w, frac) = run(Mode::Standard16, 2f32.powi(-11), 1.0, 50);
        assert_eq!(w, 1.0);
        assert_eq!(frac, 1.0);
    }

    #[test]
    fn kahan_lands_small_updates() {
        let (w, _) = run(Mode::Kahan16, 2f32.powi(-11), 1.0, 64);
        let exact = 1.0 - 64.0 * 2f32.powi(-11);
        assert!((w - exact).abs() <= 2f32.powi(-8), "{w}");
    }

    #[test]
    fn sr_progresses_in_expectation() {
        let mut acc = 0f64;
        let n = 50;
        for seed in 0..n {
            let mut opt = Sgd::bf16(Mode::Sr16, 0.0, 0.0, seed);
            let mut w = Tensor::scalar(1.0);
            let mut st = opt.init_state(&w);
            let g = Tensor::scalar(2f32.powi(-11));
            for _ in 0..64 {
                opt.step(&mut w, &mut st, &g, 1.0);
            }
            acc += w.item() as f64;
        }
        let mean = acc / n as f64;
        let target = 1.0 - 64.0 * 2f64.powi(-11);
        assert!((mean - target).abs() < 0.01, "{mean} vs {target}");
    }

    #[test]
    fn exact_modes_track_exact_descent() {
        for mode in [Mode::Fp32, Mode::Mixed16] {
            let (w, frac) = run(mode, 2f32.powi(-11), 1.0, 10);
            assert!((w - (1.0 - 10.0 * 2f32.powi(-11))).abs() < 1e-6);
            assert_eq!(frac, 0.0, "{mode:?}");
        }
    }

    #[test]
    fn momentum_accelerates() {
        let mut opt = Sgd::bf16(Mode::Fp32, 0.9, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(0.01);
        for _ in 0..10 {
            opt.step(&mut w, &mut st, &g, 0.1);
        }
        // with momentum the total displacement exceeds 10 * lr * g
        assert!(1.0 - w.item() > 10.0 * 0.1 * 0.01);
    }

    #[test]
    fn fast_step_bit_identical_to_reference_all_modes() {
        use crate::precision::{E8M5, FP16};
        let mut rng = Rng::new(0x51, 0);
        for mode in Mode::ALL {
            for fmt in [BF16, FP16, E8M5] {
                for (momentum, wd) in [(0.0, 0.0), (0.9, 0.0), (0.9, 1e-4)] {
                    let mut fast = Sgd::new(mode, fmt, momentum, wd, 42).with_tensor_id(7);
                    let mut reference = Sgd::new(mode, fmt, momentum, wd, 42)
                        .with_tensor_id(7)
                        .with_backend(Backend::Reference);
                    // odd length exercises ragged dither chunks
                    let len = 515;
                    let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
                    let mut wf = Tensor::vector(init.clone());
                    let mut wr = Tensor::vector(init);
                    let mut sf = fast.init_state(&wf);
                    let mut sr = reference.init_state(&wr);
                    for step in 0..20 {
                        // occasionally-zero gradients hit the stats guard
                        let g = Tensor::vector(
                            (0..len)
                                .map(|i| {
                                    if (i + step) % 13 == 0 {
                                        0.0
                                    } else {
                                        rng.normal() * 2f32.powi(-(step as i32) - 2)
                                    }
                                })
                                .collect(),
                        );
                        let stf = fast.step(&mut wf, &mut sf, &g, 0.05);
                        let str_ = reference.step(&mut wr, &mut sr, &g, 0.05);
                        assert_eq!(stf, str_, "{mode:?}/{}/mu={momentum} step {step}", fmt.name);
                        for (i, (a, b)) in wf.data.iter().zip(&wr.data).enumerate() {
                            assert_eq!(
                                a.to_bits(),
                                b.to_bits(),
                                "{mode:?}/{}/mu={momentum} step {step} w[{i}]",
                                fmt.name
                            );
                        }
                        if let (Some(mf), Some(mr)) = (&sf.momentum, &sr.momentum) {
                            assert_eq!(mf.data, mr.data, "{mode:?} momentum state");
                        }
                        if let (Some(kf), Some(kr)) = (&sf.kahan, &sr.kahan) {
                            assert_eq!(kf.data, kr.data, "{mode:?} kahan state");
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn pooled_step_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(0x52, 0);
        // big enough to split into several SGD_PAR_MIN chunks, ragged tail
        let len = 3 * SGD_PAR_MIN + 517;
        let init: Vec<f32> = (0..len).map(|_| rng.normal()).collect();
        let grads: Vec<Vec<f32>> = (0..8)
            .map(|_| (0..len).map(|_| rng.normal() * 2f32.powi(-6)).collect())
            .collect();
        for mode in [Mode::Sr16, Mode::SrKahan16, Mode::Kahan16, Mode::Standard16] {
            let run_with = |threads: usize| {
                let mut opt = Sgd::bf16(mode, 0.9, 1e-4, 9)
                    .with_tensor_id(3)
                    .with_pool(Arc::new(Pool::new(threads)));
                let mut w = Tensor::vector(init.clone());
                let mut st = opt.init_state(&w);
                let mut stats = UpdateStats::default();
                for g in &grads {
                    stats.merge(opt.step(&mut w, &mut st, &Tensor::vector(g.clone()), 0.05));
                }
                (w, st, stats)
            };
            let (w1, s1, st1) = run_with(1);
            for threads in [2usize, 3, 4] {
                let (wt, stt, stats_t) = run_with(threads);
                assert_eq!(st1, stats_t, "{mode:?} stats threads={threads}");
                for (i, (a, b)) in w1.data.iter().zip(&wt.data).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "{mode:?} threads={threads} w[{i}]"
                    );
                }
                if let (Some(ma), Some(mb)) = (&s1.momentum, &stt.momentum) {
                    assert_eq!(ma.data, mb.data, "{mode:?} momentum threads={threads}");
                }
                if let (Some(ka), Some(kb)) = (&s1.kahan, &stt.kahan) {
                    assert_eq!(ka.data, kb.data, "{mode:?} kahan threads={threads}");
                }
            }
        }
    }

    #[test]
    fn from_policy_binds_mode_and_fmt() {
        let p = Policy::parse("sr16-e8m5").unwrap();
        let opt = Sgd::from_policy(p, 0.9, 0.0, 1);
        assert_eq!(opt.mode, Mode::Sr16);
        assert_eq!(opt.fmt, crate::precision::E8M5);
    }
}
