//! Rust-native optimizer with the paper's weight-update policies
//! (mirror of `python/compile/optim.py` over `qsim` tensors).
//!
//! Used by the native theory experiments (Figure 2, Theorem 1, Figure 9/10
//! fast paths) and by the property-test suite; the PJRT path runs the same
//! algorithms inside lowered HLO instead.

use crate::precision::{round_nearest, round_stochastic, Format, Mode, Policy, BF16};
use crate::util::rng::Rng;

use super::tensor::Tensor;

/// Per-step statistics (Figure 9's cancellation telemetry).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct UpdateStats {
    /// Non-zero updates cancelled by rounding.
    pub cancelled: u64,
    /// Non-zero updates total.
    pub nonzero: u64,
}

impl UpdateStats {
    pub fn frac(&self) -> f64 {
        if self.nonzero == 0 {
            0.0
        } else {
            self.cancelled as f64 / self.nonzero as f64
        }
    }

    pub fn merge(&mut self, other: UpdateStats) {
        self.cancelled += other.cancelled;
        self.nonzero += other.nonzero;
    }
}

/// SGD(-momentum) optimizer state for one parameter tensor.
#[derive(Debug, Clone)]
pub struct SgdState {
    pub momentum: Option<Tensor>,
    pub kahan: Option<Tensor>,
}

/// SGD with the paper's weight-update policies.
#[derive(Debug)]
pub struct Sgd {
    pub mode: Mode,
    pub fmt: Format,
    pub momentum: f32,
    pub weight_decay: f32,
    rng: Rng,
}

impl Sgd {
    pub fn new(mode: Mode, fmt: Format, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self { mode, fmt, momentum, weight_decay, rng: Rng::new(seed, 0x0907) }
    }

    pub fn bf16(mode: Mode, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(mode, BF16, momentum, weight_decay, seed)
    }

    /// Build from a typed precision policy.
    pub fn from_policy(policy: Policy, momentum: f32, weight_decay: f32, seed: u64) -> Self {
        Self::new(policy.mode, policy.fmt, momentum, weight_decay, seed)
    }

    pub fn init_state(&self, w: &Tensor) -> SgdState {
        SgdState {
            momentum: (self.momentum != 0.0).then(|| Tensor::zeros(w.rows, w.cols)),
            kahan: self.mode.kahan().then(|| Tensor::zeros(w.rows, w.cols)),
        }
    }

    /// One update of `w` from gradient `g`.  All optimizer-internal ops are
    /// nearest-rounded in the 16-bit modes (Algorithms 2 & 3).
    pub fn step(
        &mut self,
        w: &mut Tensor,
        state: &mut SgdState,
        g: &Tensor,
        lr: f32,
    ) -> UpdateStats {
        let exact = self.mode.exact_update();
        let fmt = self.fmt;
        let r = |x: f32| if exact { x } else { round_nearest(x, fmt) };
        let mut stats = UpdateStats::default();
        for i in 0..w.data.len() {
            let mut gi = g.data[i];
            if self.weight_decay != 0.0 {
                gi = r(gi + r(self.weight_decay * w.data[i]));
            }
            let m = if let Some(mom) = &mut state.momentum {
                let m_new = r(r(self.momentum * mom.data[i]) + gi);
                mom.data[i] = m_new;
                m_new
            } else {
                gi
            };
            let u = r(lr * m);
            let wi = w.data[i];
            let w_new = if self.mode.kahan() {
                // srkahan16 (Fig 11): the accumulate output is SR'd
                let c = state.kahan.as_mut().unwrap();
                let y = r(-u - c.data[i]);
                let s = if self.mode.stochastic() {
                    round_stochastic(wi + y, fmt, self.rng.next_u32())
                } else {
                    r(wi + y)
                };
                c.data[i] = r(r(s - wi) - y);
                s
            } else if exact {
                wi - u
            } else if self.mode.stochastic() {
                round_stochastic(wi - u, fmt, self.rng.next_u32())
            } else {
                r(wi - u)
            };
            if u != 0.0 {
                stats.nonzero += 1;
                if w_new == wi {
                    stats.cancelled += 1;
                }
            }
            w.data[i] = w_new;
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(mode: Mode, grad: f32, lr: f32, steps: usize) -> (f32, f64) {
        let mut opt = Sgd::bf16(mode, 0.0, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(grad);
        let mut total = UpdateStats::default();
        for _ in 0..steps {
            total.merge(opt.step(&mut w, &mut st, &g, lr));
        }
        (w.item(), total.frac())
    }

    #[test]
    fn nearest_halts_small_updates() {
        let (w, frac) = run(Mode::Standard16, 2f32.powi(-11), 1.0, 50);
        assert_eq!(w, 1.0);
        assert_eq!(frac, 1.0);
    }

    #[test]
    fn kahan_lands_small_updates() {
        let (w, _) = run(Mode::Kahan16, 2f32.powi(-11), 1.0, 64);
        let exact = 1.0 - 64.0 * 2f32.powi(-11);
        assert!((w - exact).abs() <= 2f32.powi(-8), "{w}");
    }

    #[test]
    fn sr_progresses_in_expectation() {
        let mut acc = 0f64;
        let n = 50;
        for seed in 0..n {
            let mut opt = Sgd::bf16(Mode::Sr16, 0.0, 0.0, seed);
            let mut w = Tensor::scalar(1.0);
            let mut st = opt.init_state(&w);
            let g = Tensor::scalar(2f32.powi(-11));
            for _ in 0..64 {
                opt.step(&mut w, &mut st, &g, 1.0);
            }
            acc += w.item() as f64;
        }
        let mean = acc / n as f64;
        let target = 1.0 - 64.0 * 2f64.powi(-11);
        assert!((mean - target).abs() < 0.01, "{mean} vs {target}");
    }

    #[test]
    fn exact_modes_track_exact_descent() {
        for mode in [Mode::Fp32, Mode::Mixed16] {
            let (w, frac) = run(mode, 2f32.powi(-11), 1.0, 10);
            assert!((w - (1.0 - 10.0 * 2f32.powi(-11))).abs() < 1e-6);
            assert_eq!(frac, 0.0, "{mode:?}");
        }
    }

    #[test]
    fn momentum_accelerates() {
        let mut opt = Sgd::bf16(Mode::Fp32, 0.9, 0.0, 1);
        let mut w = Tensor::scalar(1.0);
        let mut st = opt.init_state(&w);
        let g = Tensor::scalar(0.01);
        for _ in 0..10 {
            opt.step(&mut w, &mut st, &g, 0.1);
        }
        // with momentum the total displacement exceeds 10 * lr * g
        assert!(1.0 - w.item() > 10.0 * 0.1 * 0.01);
    }

    #[test]
    fn from_policy_binds_mode_and_fmt() {
        let p = Policy::parse("sr16-e8m5").unwrap();
        let opt = Sgd::from_policy(p, 0.9, 0.0, 1);
        assert_eq!(opt.mode, Mode::Sr16);
        assert_eq!(opt.fmt, crate::precision::E8M5);
    }
}
