//! `qsim::shard` — deterministic data-parallel sharded training with
//! bit-identical fault recovery.
//!
//! ## Why this can be exact
//!
//! Two properties of the engine make data parallelism *bit-exact* instead
//! of merely statistically equivalent:
//!
//! 1. Forward/backward rounding is deterministic round-to-nearest — only
//!    the optimizer update consumes keyed SR dither — so a microbatch
//!    gradient is a pure function of (parameters, batch).
//! 2. The SR dither is counter-keyed by `(seed, stream, step, tensor_id,
//!    element)`, so *who* applies an update doesn't matter, only *which*
//!    update it is.
//!
//! The remaining hazard is f32 addition's non-associativity: summing shard
//! partials naively would change bits with the shard count.  So a step is
//! defined over a fixed grid of `M` microbatches (`M` a power of two,
//! constant across shard counts) reduced by a **fixed pairwise tree**
//! ([`tree_reduce`]).  Shard `i` of `N` owns the aligned contiguous block
//! of `M/N` microbatches — a complete subtree — computes the block's
//! partial with the same tree, and the coordinator combines the `N` block
//! roots with the tree's upper levels.  The result is bit-identical for
//! every power-of-two `N <= M`, including `N = 1`.
//!
//! ## Topology and recovery
//!
//! [`ShardedTrainer`] owns the authoritative [`Trainer`] (one keyed-SR
//! update per step, checkpointing, eval) and `N` worker threads, each
//! holding a deterministic replica trainer and its own slice of the data
//! stream (skip `lo`, draw `M/N`, skip the rest — exactly `M` draws per
//! step, so a respawned worker fast-forwards by `steps × M`).  Transport
//! is an in-process channel carrying *encoded byte frames* (magic, source,
//! epoch, sequence number, payload, CRC-32), so the message layer is
//! process/socket-ready and every fault a real wire could inject is
//! detectable here.
//!
//! Recovery machinery, exercised by `qsim::fault`:
//! * CRC + sequence + epoch validation on every frame; stale or replayed
//!   frames are discarded (epochs fence out zombie incarnations);
//! * timeout with exponential backoff and bounded retries; a retry is a
//!   duplicate step request, which a live worker answers from its cached
//!   gradient frame without recomputing (and without re-drawing data);
//! * crash detection (send failure or retry exhaustion) → respawn from the
//!   coordinator's in-memory `BF16CKP2` snapshot + stream fast-forward;
//! * replica drift detection: every gradient message carries an FNV-1a
//!   digest of the replica's parameters; a mismatch (e.g. after a dropped
//!   update broadcast) triggers snapshot re-sync and recompute;
//! * straggler accounting with bounded wait (latency beyond
//!   `straggler_factor ×` the step median is recorded, never trusted
//!   less — values are validated by construction, not by timing).
//!
//! None of the recovery paths can change a single bit of the trajectory:
//! accepted gradients are validated against the coordinator's parameter
//! digest, the reduction topology is fixed, and the one keyed update per
//! step is applied by the coordinator alone.  Timing changes only the
//! [`ShardStats`] — which is why parity digests never include them.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::precision::Mode;
use crate::util::ckpt;
use crate::util::crc::crc32;

use super::fault::{ChaosKind, ChaosPlan};
use super::train::{EvalMetrics, StepTelemetry, Task, Trainer};

// ---------------------------------------------------------------------------
// fixed-topology reduction
// ---------------------------------------------------------------------------

/// Flat per-parameter gradients plus the (tree-summed) loss.
pub type GradPartial = (f32, Vec<Vec<f32>>);

/// Pairwise reduction over a power-of-two number of partials with a fixed
/// tree topology: round 1 combines (0,1), (2,3), …; round 2 combines the
/// round-1 roots pairwise; and so on.  Because the tree shape depends only
/// on the leaf count, reducing `M` leaves directly equals reducing `N`
/// aligned block-partials of `M/N` leaves each — the associativity
/// schedule that makes shard counts interchangeable at the bit level.
pub fn tree_reduce(mut parts: Vec<GradPartial>) -> GradPartial {
    assert!(
        !parts.is_empty() && parts.len().is_power_of_two(),
        "tree_reduce needs a power-of-two leaf count, got {}",
        parts.len()
    );
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len() / 2);
        let mut it = parts.into_iter();
        while let (Some((la, mut ga)), Some((lb, gb))) = (it.next(), it.next()) {
            debug_assert_eq!(ga.len(), gb.len(), "partials disagree on tensor count");
            for (a, b) in ga.iter_mut().zip(&gb) {
                debug_assert_eq!(a.len(), b.len(), "partials disagree on tensor shape");
                for (x, y) in a.iter_mut().zip(b) {
                    *x += *y;
                }
            }
            next.push((la + lb, ga));
        }
        parts = next;
    }
    parts.pop().expect("non-empty by the assert above")
}

/// Scale every gradient element by `s` (the `1/M` mean normalisation,
/// applied once after the reduction).
pub fn scale_grads(grads: &mut [Vec<f32>], s: f32) {
    for g in grads {
        for x in g.iter_mut() {
            *x *= s;
        }
    }
}

// ---------------------------------------------------------------------------
// wire format
// ---------------------------------------------------------------------------

/// Frame magic: "QSF1".
pub const FRAME_MAGIC: u32 = 0x3146_5351;
/// `src` value identifying the coordinator.
pub const COORD_SRC: u32 = u32::MAX;
/// Bytes before the payload: magic, src, epoch, kind, seq, payload length.
pub const FRAME_HEADER_LEN: usize = 4 + 4 + 4 + 1 + 8 + 8;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MsgKind {
    StepReq = 0,
    Grad = 1,
    Update = 2,
    Snapshot = 3,
    Nack = 4,
    Shutdown = 5,
}

impl MsgKind {
    fn parse(v: u8) -> Result<MsgKind> {
        Ok(match v {
            0 => MsgKind::StepReq,
            1 => MsgKind::Grad,
            2 => MsgKind::Update,
            3 => MsgKind::Snapshot,
            4 => MsgKind::Nack,
            5 => MsgKind::Shutdown,
            other => bail!("unknown frame kind {other}"),
        })
    }
}

/// One decoded wire frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub src: u32,
    pub epoch: u32,
    pub seq: u64,
    pub kind: MsgKind,
    pub payload: Vec<u8>,
}

/// Encode a frame: header, payload, trailing CRC-32 over everything
/// before it.
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut b = Vec::with_capacity(FRAME_HEADER_LEN + f.payload.len() + 4);
    b.extend_from_slice(&FRAME_MAGIC.to_le_bytes());
    b.extend_from_slice(&f.src.to_le_bytes());
    b.extend_from_slice(&f.epoch.to_le_bytes());
    b.push(f.kind as u8);
    b.extend_from_slice(&f.seq.to_le_bytes());
    b.extend_from_slice(&(f.payload.len() as u64).to_le_bytes());
    b.extend_from_slice(&f.payload);
    let crc = crc32(&b);
    b.extend_from_slice(&crc.to_le_bytes());
    b
}

/// Decode and validate a frame (CRC first — a flipped bit anywhere is
/// rejected here, which is what turns `fault`'s corrupt-message chaos into
/// a retransmit instead of silent garbage).
pub fn decode_frame(b: &[u8]) -> Result<Frame> {
    if b.len() < FRAME_HEADER_LEN + 4 {
        bail!("frame truncated: {} bytes", b.len());
    }
    let (body, tail) = b.split_at(b.len() - 4);
    let stored = u32::from_le_bytes(tail.try_into().unwrap());
    let actual = crc32(body);
    if stored != actual {
        bail!("frame failed CRC-32 validation (stored {stored:08x}, computed {actual:08x})");
    }
    let magic = u32::from_le_bytes(body[0..4].try_into().unwrap());
    if magic != FRAME_MAGIC {
        bail!("bad frame magic {magic:08x}");
    }
    let src = u32::from_le_bytes(body[4..8].try_into().unwrap());
    let epoch = u32::from_le_bytes(body[8..12].try_into().unwrap());
    let kind = MsgKind::parse(body[12])?;
    let seq = u64::from_le_bytes(body[13..21].try_into().unwrap());
    let payload_len = u64::from_le_bytes(body[21..29].try_into().unwrap()) as usize;
    if payload_len != body.len() - FRAME_HEADER_LEN {
        bail!(
            "frame payload length mismatch: header says {payload_len}, got {}",
            body.len() - FRAME_HEADER_LEN
        );
    }
    Ok(Frame { src, epoch, seq, kind, payload: body[FRAME_HEADER_LEN..].to_vec() })
}

/// Decoded message payloads.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    /// Coordinator → worker: compute gradients for `step`.
    StepReq { step: u64 },
    /// Worker → coordinator: block partial for `step`, with the replica's
    /// parameter digest for drift detection.
    Grad { step: u64, loss_sum: f32, digest: u64, grads: Vec<Vec<f32>> },
    /// Coordinator → worker: reduced, 1/M-scaled gradients to apply as
    /// step `step`'s single keyed update.
    Update { step: u64, lr: f32, grads: Vec<Vec<f32>> },
    /// Coordinator → worker: full state image (`BF16CKP2` bytes) to load.
    Snapshot { ckpt: Vec<u8> },
    /// Worker → coordinator: out of sync (`have_steps` applied), needs a
    /// snapshot.
    Nack { have_steps: u64 },
    /// Coordinator → worker: exit cleanly.
    Shutdown,
}

impl Msg {
    pub fn kind(&self) -> MsgKind {
        match self {
            Msg::StepReq { .. } => MsgKind::StepReq,
            Msg::Grad { .. } => MsgKind::Grad,
            Msg::Update { .. } => MsgKind::Update,
            Msg::Snapshot { .. } => MsgKind::Snapshot,
            Msg::Nack { .. } => MsgKind::Nack,
            Msg::Shutdown => MsgKind::Shutdown,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = ckpt::Writer::bare();
        match self {
            Msg::StepReq { step } => w.u64(*step),
            Msg::Grad { step, loss_sum, digest, grads } => {
                w.u64(*step);
                w.f32(*loss_sum);
                w.u64(*digest);
                encode_grads(&mut w, grads);
            }
            Msg::Update { step, lr, grads } => {
                w.u64(*step);
                w.f32(*lr);
                encode_grads(&mut w, grads);
            }
            Msg::Snapshot { ckpt } => w.blob(ckpt),
            Msg::Nack { have_steps } => w.u64(*have_steps),
            Msg::Shutdown => {}
        }
        w.into_bytes()
    }

    pub fn decode(kind: MsgKind, payload: &[u8]) -> Result<Msg> {
        let mut r = ckpt::Reader::bare(payload);
        let msg = match kind {
            MsgKind::StepReq => Msg::StepReq { step: r.u64()? },
            MsgKind::Grad => Msg::Grad {
                step: r.u64()?,
                loss_sum: r.f32()?,
                digest: r.u64()?,
                grads: decode_grads(&mut r)?,
            },
            MsgKind::Update => {
                Msg::Update { step: r.u64()?, lr: r.f32()?, grads: decode_grads(&mut r)? }
            }
            MsgKind::Snapshot => Msg::Snapshot { ckpt: r.blob()? },
            MsgKind::Nack => Msg::Nack { have_steps: r.u64()? },
            MsgKind::Shutdown => Msg::Shutdown,
        };
        r.expect_end().context("trailing bytes after message payload")?;
        Ok(msg)
    }
}

fn encode_grads(w: &mut ckpt::Writer, grads: &[Vec<f32>]) {
    w.u64(grads.len() as u64);
    for g in grads {
        w.f32s(g);
    }
}

fn decode_grads(r: &mut ckpt::Reader<'_>) -> Result<Vec<Vec<f32>>> {
    let n = r.u64()? as usize;
    // bound by the payload that actually arrived, so a corrupt count can't
    // balloon the allocation
    let mut grads = Vec::with_capacity(n.min(1024));
    for _ in 0..n {
        grads.push(r.f32s()?);
    }
    Ok(grads)
}

// ---------------------------------------------------------------------------
// configuration + stats
// ---------------------------------------------------------------------------

/// Knobs for [`ShardedTrainer`].
#[derive(Clone)]
pub struct ShardOptions {
    /// Worker shard count; power of two, `<= microbatches`.
    pub shards: usize,
    /// Microbatches per optimizer step (`M`); power of two.  Must be held
    /// constant to compare digests across shard counts.
    pub microbatches: usize,
    /// Deterministic fault schedule (None = clean run).
    pub chaos: Option<Arc<ChaosPlan>>,
    /// First wait window for shard gradient responses; doubles per retry.
    pub timeout: Duration,
    /// Retransmit attempts per step before a shard is declared dead and
    /// respawned from snapshot.
    pub max_retries: u32,
    /// A shard slower than `factor × median` step latency (and above
    /// `straggler_floor`) is counted in [`ShardStats::stragglers`].
    pub straggler_factor: f64,
    /// Absolute latency floor below which nothing is a straggler.
    pub straggler_floor: Duration,
}

impl Default for ShardOptions {
    fn default() -> Self {
        ShardOptions {
            shards: 1,
            microbatches: 4,
            chaos: None,
            timeout: Duration::from_millis(300),
            max_retries: 3,
            straggler_factor: 4.0,
            straggler_floor: Duration::from_millis(25),
        }
    }
}

impl ShardOptions {
    fn validate(&self) -> Result<()> {
        if self.shards == 0 || !self.shards.is_power_of_two() {
            bail!("--shards must be a power of two >= 1, got {}", self.shards);
        }
        if !self.microbatches.is_power_of_two() {
            bail!("microbatches (--grad-accum) must be a power of two, got {}", self.microbatches);
        }
        if self.shards > self.microbatches {
            bail!(
                "{} shards need at least {} microbatches (one aligned block each); \
                 got --grad-accum {}",
                self.shards,
                self.shards,
                self.microbatches
            );
        }
        if self.max_retries == 0 {
            bail!("max_retries must be >= 1");
        }
        Ok(())
    }
}

/// Fault/recovery counters.  Timing-dependent by design, which is exactly
/// why they are *not* part of any parity digest.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct ShardStats {
    /// Retransmit requests sent after a wait window expired.
    pub retries: u64,
    /// Workers declared dead and respawned from snapshot.
    pub respawns: u64,
    /// Frames rejected by CRC/format validation.
    pub crc_rejects: u64,
    /// Frames discarded as stale (old epoch, replayed seq, wrong step, or
    /// duplicate gradients).
    pub stale_frames: u64,
    /// Out-of-sync notices from workers (each triggers a snapshot).
    pub nacks: u64,
    /// Replica param-digest mismatches healed by snapshot re-sync.
    pub drift_resyncs: u64,
    /// Update broadcasts dropped by chaos injection.
    pub updates_dropped: u64,
    /// Step responses that arrived but beyond the straggler threshold.
    pub stragglers: u64,
}

impl ShardStats {
    /// Total injected-or-detected fault events (for "the schedule actually
    /// fired" assertions).
    pub fn total_events(&self) -> u64 {
        self.retries
            + self.respawns
            + self.crc_rejects
            + self.nacks
            + self.drift_resyncs
            + self.updates_dropped
            + self.stragglers
    }
}

// ---------------------------------------------------------------------------
// worker
// ---------------------------------------------------------------------------

struct WorkerSpec<T: Task> {
    task: T,
    modes: Vec<Mode>,
    id: u32,
    epoch: u32,
    shards: usize,
    microbatches: usize,
    rx: Receiver<Vec<u8>>,
    tx: Sender<Vec<u8>>,
    chaos: Option<Arc<ChaosPlan>>,
}

/// Worker main loop: a replica trainer answering step requests with block
/// partials and applying broadcast updates.  Exits on `Shutdown`, channel
/// disconnect, injected crash, or an unloadable snapshot.
fn worker_loop<T: Task>(spec: WorkerSpec<T>) {
    let WorkerSpec { task, modes, id, epoch, shards, microbatches, rx, tx, chaos } = spec;
    let mut tr = Trainer::new_mixed(task, modes).with_grad_accum(microbatches);
    let per = microbatches / shards;
    let lo = id as usize * per;
    let mut seq = 0u64;
    // the last computed gradient frame: duplicate step requests (the
    // coordinator's retransmit mechanism) are answered from here, never by
    // recomputing — the data stream has already advanced past this step
    let mut cached: Option<(u64, Vec<u8>)> = None;
    let mut send = |seq: &mut u64, kind: MsgKind, payload: Vec<u8>| -> bool {
        *seq += 1;
        let frame = Frame { src: id, epoch, seq: *seq, kind, payload };
        tx.send(encode_frame(&frame)).is_ok()
    };
    for buf in rx.iter() {
        let Ok(frame) = decode_frame(&buf) else {
            continue; // corrupt inbound frame: the coordinator will retry
        };
        let Ok(msg) = Msg::decode(frame.kind, &frame.payload) else {
            continue;
        };
        match msg {
            Msg::StepReq { step } => {
                if let Some((s, payload)) = &cached {
                    if *s == step {
                        if !send(&mut seq, MsgKind::Grad, payload.clone()) {
                            return;
                        }
                        continue;
                    }
                }
                if step != tr.steps_done() {
                    // missed an update (or got a request from the future):
                    // ask for a snapshot instead of computing from stale
                    // parameters
                    if !send(&mut seq, MsgKind::Nack, Msg::Nack { have_steps: tr.steps_done() }
                        .encode())
                    {
                        return;
                    }
                    continue;
                }
                let mut drop_grad = false;
                let mut corrupt_grad = false;
                if let Some(plan) = &chaos {
                    if let Some(ev) = plan.take_worker(step, id) {
                        match ev.kind {
                            ChaosKind::Crash => return,
                            ChaosKind::Stall => {
                                std::thread::sleep(Duration::from_millis(ev.stall_ms))
                            }
                            ChaosKind::DropGrad => drop_grad = true,
                            ChaosKind::CorruptGrad => corrupt_grad = true,
                            ChaosKind::DropUpdate => unreachable!("coordinator-site event"),
                        }
                    }
                }
                // exactly M draws per step: skip the blocks other shards
                // own, draw our aligned block
                tr.skip_batches(lo as u64);
                let mut parts = Vec::with_capacity(per);
                for _ in 0..per {
                    let batch = tr.draw_batch();
                    parts.push(tr.grad_batch(&batch));
                }
                tr.skip_batches((microbatches - lo - per) as u64);
                let (loss_sum, grads) = tree_reduce(parts);
                let digest = tr.param_digest();
                let payload = Msg::Grad { step, loss_sum, digest, grads }.encode();
                cached = Some((step, payload.clone()));
                if drop_grad {
                    continue; // computed and cached, never sent: retransmit will deliver
                }
                seq += 1;
                let mut bytes =
                    encode_frame(&Frame { src: id, epoch, seq, kind: MsgKind::Grad, payload });
                if corrupt_grad {
                    if let Some(plan) = &chaos {
                        plan.corrupt_frame(&mut bytes, FRAME_HEADER_LEN, step, id);
                    }
                }
                if tx.send(bytes).is_err() {
                    return;
                }
            }
            Msg::Update { step, lr, grads } => {
                if step != tr.steps_done() {
                    continue; // stale broadcast for a step we already applied
                }
                tr.apply_update(0.0, grads, lr);
                cached = None;
            }
            Msg::Snapshot { ckpt } => {
                if tr.load_checkpoint_bytes(&ckpt).is_err() {
                    return; // unloadable state: die, the coordinator respawns us
                }
                cached = None;
            }
            Msg::Shutdown => return,
            Msg::Grad { .. } | Msg::Nack { .. } => {}
        }
    }
}

// ---------------------------------------------------------------------------
// coordinator
// ---------------------------------------------------------------------------

struct WorkerHandle {
    epoch: u32,
    tx: Sender<Vec<u8>>,
    last_seq: u64,
    join: Option<JoinHandle<()>>,
}

/// Data-parallel trainer: `N` worker shards over the checksummed frame
/// transport, one authoritative keyed-SR update per step.  Bit-identical
/// to [`Trainer`] with `grad_accum = microbatches` at every power-of-two
/// shard count, under any `qsim::fault` schedule.
pub struct ShardedTrainer<T: Task + Clone + Send + 'static> {
    inner: Trainer<T>,
    task: T,
    modes: Vec<Mode>,
    opts: ShardOptions,
    workers: Vec<WorkerHandle>,
    rx: Receiver<Vec<u8>>,
    tx: Sender<Vec<u8>>,
    send_seq: u64,
    stats: ShardStats,
    /// Monotone epoch source for respawns (shared with nothing; atomic so
    /// `&mut self` borrows stay simple).
    next_epoch: AtomicU64,
}

impl<T: Task + Clone + Send + 'static> ShardedTrainer<T> {
    /// All parameter tensors share one precision mode.
    pub fn new(task: T, mode: Mode, opts: ShardOptions) -> Result<Self> {
        let n = task.num_tensors();
        Self::new_mixed(task, vec![mode; n], opts)
    }

    /// Per-tensor precision modes, as [`Trainer::new_mixed`].
    pub fn new_mixed(task: T, modes: Vec<Mode>, opts: ShardOptions) -> Result<Self> {
        opts.validate()?;
        let inner =
            Trainer::new_mixed(task.clone(), modes.clone()).with_grad_accum(opts.microbatches);
        let (tx, rx) = mpsc::channel();
        let mut st = ShardedTrainer {
            inner,
            task,
            modes,
            opts,
            workers: Vec::new(),
            rx,
            tx,
            send_seq: 0,
            stats: ShardStats::default(),
            next_epoch: AtomicU64::new(1),
        };
        for id in 0..st.opts.shards {
            let w = st.spawn_worker(id as u32)?;
            st.workers.push(w);
        }
        Ok(st)
    }

    fn spawn_worker(&self, id: u32) -> Result<WorkerHandle> {
        let epoch = self.next_epoch.fetch_add(1, Ordering::Relaxed) as u32;
        let (tx, rx) = mpsc::channel();
        let spec = WorkerSpec {
            task: self.task.clone(),
            modes: self.modes.clone(),
            id,
            epoch,
            shards: self.opts.shards,
            microbatches: self.opts.microbatches,
            rx,
            tx: self.tx.clone(),
            chaos: self.opts.chaos.clone(),
        };
        let join = std::thread::Builder::new()
            .name(format!("qsim-shard-{id}"))
            .spawn(move || worker_loop(spec))
            .context("spawning shard worker thread")?;
        Ok(WorkerHandle { epoch, tx, last_seq: 0, join: Some(join) })
    }

    fn send_to(&mut self, id: usize, msg: &Msg) -> bool {
        self.send_seq += 1;
        let frame = Frame {
            src: COORD_SRC,
            epoch: self.workers[id].epoch,
            seq: self.send_seq,
            kind: msg.kind(),
            payload: msg.encode(),
        };
        self.workers[id].tx.send(encode_frame(&frame)).is_ok()
    }

    /// Replace worker `id` with a fresh incarnation (new epoch — frames
    /// from the old thread are fenced out) and stream it the last good
    /// checkpoint.  The replica loads it and fast-forwards its data stream
    /// by `steps × M` batches.
    fn respawn(&mut self, id: usize) {
        self.stats.respawns += 1;
        let fresh = self.spawn_worker(id as u32).expect("respawning shard worker");
        // old thread: drop its sender; it exits on channel disconnect (or
        // already has).  Detach the old join handle.
        self.workers[id] = fresh;
        let snap = Msg::Snapshot { ckpt: self.inner.checkpoint_bytes() };
        let _ = self.send_to(id, &snap);
    }

    /// Send the current snapshot to a live-but-drifted worker; respawn it
    /// if even that send fails.
    fn resync(&mut self, id: usize) {
        let snap = Msg::Snapshot { ckpt: self.inner.checkpoint_bytes() };
        if !self.send_to(id, &snap) {
            self.respawn(id);
        }
    }

    fn send_step_req(&mut self, id: usize, step: u64) {
        if !self.send_to(id, &Msg::StepReq { step }) {
            // dead channel: the worker crashed since its last reply
            self.respawn(id);
            let _ = self.send_to(id, &Msg::StepReq { step });
        }
    }

    /// One data-parallel optimizer step.  Survives any `qsim::fault`
    /// schedule with the exact bits of the clean single-shard run; panics
    /// only if shards stay unresponsive long past the retry budget (a bug,
    /// not an injected fault — every injected fault is recoverable).
    pub fn step(&mut self, lr: f32) -> StepTelemetry {
        let step = self.inner.steps_done();
        let n = self.opts.shards;
        let m = self.opts.microbatches;
        let expected_digest = self.inner.param_digest();
        for id in 0..n {
            self.send_step_req(id, step);
        }
        let mut partials: Vec<Option<GradPartial>> = (0..n).map(|_| None).collect();
        let mut latency: Vec<Duration> = vec![Duration::ZERO; n];
        let t0 = Instant::now();
        let mut window = self.opts.timeout;
        let mut timeouts = 0u32;
        // a respawn resets the budget once; beyond that, something is wrong
        let budget = self.opts.max_retries * 2 + 2;
        while partials.iter().any(Option::is_none) {
            match self.rx.recv_timeout(window) {
                Err(RecvTimeoutError::Disconnected) => {
                    unreachable!("coordinator holds a sender clone; channel cannot disconnect")
                }
                Err(RecvTimeoutError::Timeout) => {
                    timeouts += 1;
                    assert!(
                        timeouts <= budget,
                        "step {step}: shards unresponsive after {timeouts} wait windows \
                         (respawns {}, retries {}) — transport bug, not an injected fault",
                        self.stats.respawns,
                        self.stats.retries
                    );
                    for id in 0..n {
                        if partials[id].is_some() {
                            continue;
                        }
                        if timeouts > self.opts.max_retries {
                            self.respawn(id);
                            self.send_step_req(id, step);
                        } else {
                            self.stats.retries += 1;
                            self.send_step_req(id, step);
                        }
                    }
                    // exponential backoff, bounded
                    window = (window * 2).min(self.opts.timeout * 16);
                }
                Ok(buf) => {
                    let frame = match decode_frame(&buf) {
                        Ok(f) => f,
                        Err(_) => {
                            // CRC/format reject: the source is unreadable,
                            // so re-request from every shard still missing
                            self.stats.crc_rejects += 1;
                            for id in 0..n {
                                if partials[id].is_none() {
                                    self.stats.retries += 1;
                                    self.send_step_req(id, step);
                                }
                            }
                            continue;
                        }
                    };
                    let id = frame.src as usize;
                    if id >= n
                        || frame.epoch != self.workers[id].epoch
                        || frame.seq <= self.workers[id].last_seq
                    {
                        // zombie incarnation or replayed frame
                        self.stats.stale_frames += 1;
                        continue;
                    }
                    self.workers[id].last_seq = frame.seq;
                    let msg = match Msg::decode(frame.kind, &frame.payload) {
                        Ok(m) => m,
                        Err(_) => {
                            self.stats.crc_rejects += 1;
                            self.stats.retries += 1;
                            self.send_step_req(id, step);
                            continue;
                        }
                    };
                    match msg {
                        Msg::Grad { step: s, loss_sum, digest, grads } => {
                            if s != step || partials[id].is_some() {
                                self.stats.stale_frames += 1;
                                continue;
                            }
                            if digest != expected_digest {
                                // replica drift (e.g. lost update): heal
                                // and recompute; never accept the values
                                self.stats.drift_resyncs += 1;
                                self.resync(id);
                                self.send_step_req(id, step);
                                continue;
                            }
                            latency[id] = t0.elapsed();
                            partials[id] = Some((loss_sum, grads));
                        }
                        Msg::Nack { .. } => {
                            self.stats.nacks += 1;
                            self.resync(id);
                            self.send_step_req(id, step);
                        }
                        _ => {
                            self.stats.stale_frames += 1;
                        }
                    }
                }
            }
        }
        // straggler accounting: responders far beyond the step median
        if n > 1 {
            let mut sorted = latency.clone();
            sorted.sort();
            let median = sorted[n / 2];
            let threshold = self
                .opts
                .straggler_floor
                .max(median.mul_f64(self.opts.straggler_factor));
            self.stats.stragglers += latency.iter().filter(|&&l| l > threshold).count() as u64;
        }
        // combine the N block roots with the tree's upper levels, scale by
        // 1/M, apply the single keyed update — identical arithmetic to
        // Trainer::step_accum
        let (loss_sum, mut grads) =
            tree_reduce(partials.into_iter().map(|p| p.expect("all present")).collect());
        let inv = 1.0 / m as f32;
        scale_grads(&mut grads, inv);
        let update = Msg::Update { step, lr, grads: grads.clone() };
        let tel = self.inner.apply_update(loss_sum * inv, grads, lr);
        for id in 0..n {
            let dropped = self
                .opts
                .chaos
                .as_ref()
                .map(|p| p.take_drop_update(step, id as u32))
                .unwrap_or(false);
            if dropped {
                self.stats.updates_dropped += 1;
                continue; // the replica drifts; its next digest exposes it
            }
            let _ = self.send_to(id, &update); // send failure ⇒ next step respawns
        }
        tel
    }

    /// Evaluate on the coordinator's dedicated eval fork (identical to the
    /// single-process trainer's).
    pub fn eval(&mut self, n: usize) -> EvalMetrics {
        self.inner.eval(n)
    }

    pub fn steps_done(&self) -> u64 {
        self.inner.steps_done()
    }

    pub fn stats(&self) -> ShardStats {
        self.stats
    }

    pub fn shards(&self) -> usize {
        self.opts.shards
    }

    pub fn microbatches(&self) -> usize {
        self.opts.microbatches
    }

    /// The authoritative trainer (parameters, telemetry accounting, byte
    /// measurement).
    pub fn trainer(&self) -> &Trainer<T> {
        &self.inner
    }

    pub fn param_digest(&self) -> u64 {
        self.inner.param_digest()
    }

    /// Save the authoritative state (atomic, CRC-footed `BF16CKP2`).
    pub fn save_checkpoint(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.inner.save_checkpoint(path)
    }

    /// Load a checkpoint (any shard count may resume it — the fingerprint
    /// records `M`, not `N`) and re-sync every worker replica to it.
    pub fn load_checkpoint(&mut self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.inner.load_checkpoint(path)?;
        for id in 0..self.opts.shards {
            self.resync(id);
        }
        Ok(())
    }
}

impl<T: Task + Clone + Send + 'static> Drop for ShardedTrainer<T> {
    fn drop(&mut self) {
        for id in 0..self.workers.len() {
            let _ = self.send_to(id, &Msg::Shutdown);
        }
        for w in &mut self.workers {
            // dropping the sender guarantees the worker's recv loop ends
            // even if the shutdown frame raced a full queue
            let (dead_tx, _) = mpsc::channel();
            w.tx = dead_tx;
            if let Some(join) = w.join.take() {
                let _ = join.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::precision::Mode;
    use crate::qsim::dlrm::DlrmConfig;
    use crate::qsim::fault::ChaosConfig;
    use crate::qsim::mlp::MlpConfig;

    fn opts(shards: usize, microbatches: usize) -> ShardOptions {
        ShardOptions { shards, microbatches, ..Default::default() }
    }

    fn chaos(spec: &str) -> Option<Arc<ChaosPlan>> {
        Some(Arc::new(ChaosPlan::new(ChaosConfig::parse(spec).unwrap())))
    }

    #[test]
    fn frame_roundtrip_and_corruption_detection() {
        let f = Frame {
            src: 3,
            epoch: 7,
            seq: 42,
            kind: MsgKind::Grad,
            payload: vec![1, 2, 3, 4, 5],
        };
        let bytes = encode_frame(&f);
        assert_eq!(decode_frame(&bytes).unwrap(), f);
        // a flipped bit anywhere in the frame — header, payload or CRC —
        // must be rejected (CRC-32 catches every single-bit error)
        for byte in 0..bytes.len() {
            let mut m = bytes.clone();
            m[byte] ^= 1;
            assert!(decode_frame(&m).is_err(), "flip at byte {byte} went undetected");
        }
        // message payloads round-trip through the bare framing
        let msg = Msg::Grad {
            step: 9,
            loss_sum: 1.25,
            digest: 0xdead_beef,
            grads: vec![vec![1.0, -2.0], vec![0.5]],
        };
        assert_eq!(Msg::decode(MsgKind::Grad, &msg.encode()).unwrap(), msg);
        let upd = Msg::Update { step: 3, lr: 0.1, grads: vec![vec![0.25; 4]] };
        assert_eq!(Msg::decode(MsgKind::Update, &upd.encode()).unwrap(), upd);
    }

    /// The associativity schedule behind everything: reducing M leaves
    /// directly equals reducing N aligned block-partials of M/N leaves,
    /// for every power-of-two N — at the bit level.
    #[test]
    fn tree_reduce_is_block_composable() {
        let m = 8usize;
        let leaves: Vec<GradPartial> = (0..m)
            .map(|i| {
                let x = i as f32 * 0.37 + 1.0;
                (x * 0.25, vec![vec![x, -x, x * 0.513], vec![1.0 / x]])
            })
            .collect();
        let direct = tree_reduce(leaves.clone());
        for n in [1usize, 2, 4, 8] {
            let per = m / n;
            let blocks: Vec<GradPartial> = (0..n)
                .map(|b| tree_reduce(leaves[b * per..(b + 1) * per].to_vec()))
                .collect();
            let combined = tree_reduce(blocks);
            assert_eq!(combined.0.to_bits(), direct.0.to_bits(), "loss bits at n={n}");
            for (a, b) in combined.1.iter().zip(&direct.1) {
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.to_bits(), y.to_bits(), "grad bits at n={n}");
                }
            }
        }
    }

    /// Tentpole: the sharded engine IS the single-process accumulation
    /// trainer, bit for bit — losses, telemetry and final parameters.
    #[test]
    fn sharded_matches_single_process_accum_bit_for_bit() {
        let task = MlpConfig { seed: 13, ..Default::default() };
        let mut solo = Trainer::new(task.clone(), Mode::Sr16).with_grad_accum(4);
        let mut sharded = ShardedTrainer::new(task, Mode::Sr16, opts(2, 4)).unwrap();
        for step in 0..8 {
            let a = solo.step(0.1);
            let b = sharded.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
            assert_eq!(a.embed, b.embed, "embed stats, step {step}");
            assert_eq!(a.mlp, b.mlp, "mlp stats, step {step}");
        }
        assert_eq!(solo.param_digest(), sharded.param_digest());
        assert_eq!(sharded.stats().total_events(), 0, "clean run must record no fault events");
    }

    /// Same contract on the embedding-heavy app (sparse rows + dense MLP,
    /// Kahan state in flight).
    #[test]
    fn dlrm_sharded_matches_single_process() {
        let task = DlrmConfig { seed: 3, ..Default::default() };
        let mut solo = Trainer::new(task.clone(), Mode::SrKahan16).with_grad_accum(4);
        let mut sharded = ShardedTrainer::new(task, Mode::SrKahan16, opts(4, 4)).unwrap();
        for step in 0..4 {
            let a = solo.step(0.05);
            let b = sharded.step(0.05);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss diverged at step {step}");
        }
        assert_eq!(solo.param_digest(), sharded.param_digest());
    }

    /// The shard count is a pure deployment knob: 1, 2 and 4 shards over
    /// the same microbatch grid produce identical bits.
    #[test]
    fn shard_counts_are_interchangeable() {
        let run = |n: usize| {
            let task = MlpConfig { seed: 29, ..Default::default() };
            let mut tr = ShardedTrainer::new(task, Mode::Sr16, opts(n, 4)).unwrap();
            let mut losses = Vec::new();
            for _ in 0..6 {
                losses.push(tr.step(0.1).loss.to_bits());
            }
            (losses, tr.param_digest())
        };
        let base = run(1);
        assert_eq!(run(2), base, "2 shards diverged from 1");
        assert_eq!(run(4), base, "4 shards diverged from 1");
    }

    /// Every injected fault kind recovers to the exact clean-run bits, and
    /// the matching recovery counter proves the fault actually fired.
    #[test]
    fn every_chaos_kind_recovers_bit_identically() {
        let clean = {
            let task = MlpConfig { seed: 5, ..Default::default() };
            let mut tr = ShardedTrainer::new(task, Mode::Sr16, opts(4, 4)).unwrap();
            for _ in 0..6 {
                tr.step(0.1);
            }
            tr.param_digest()
        };
        for spec in
            ["crash@2.1", "drop@1.3", "corrupt@3.0", "drop-update@2.2", "stall@4.3:150"]
        {
            let task = MlpConfig { seed: 5, ..Default::default() };
            let mut o = opts(4, 4);
            o.chaos = chaos(spec);
            if spec.starts_with("stall") {
                // make the stalled shard an unambiguous straggler
                o.straggler_floor = Duration::from_millis(50);
                o.straggler_factor = 1.5;
            }
            let mut tr = ShardedTrainer::new(task, Mode::Sr16, o).unwrap();
            for _ in 0..6 {
                tr.step(0.1);
            }
            assert_eq!(tr.param_digest(), clean, "chaos {spec} changed the trajectory");
            let st = tr.stats();
            match spec.split('@').next().unwrap() {
                "crash" => assert!(st.respawns >= 1, "{spec}: {st:?}"),
                "drop" => assert!(st.retries >= 1, "{spec}: {st:?}"),
                "corrupt" => assert!(st.crc_rejects >= 1, "{spec}: {st:?}"),
                "drop-update" => assert!(
                    st.updates_dropped >= 1 && st.nacks + st.drift_resyncs >= 1,
                    "{spec}: {st:?}"
                ),
                "stall" => assert!(st.stragglers >= 1, "{spec}: {st:?}"),
                other => unreachable!("unknown spec prefix {other}"),
            }
        }
    }

    /// Checkpoints are shard-count-portable: save from a 2-shard run,
    /// resume into a 4-shard run, continue bit-identically (the
    /// fingerprint records the microbatch grid M, never N).
    #[test]
    fn sharded_checkpoint_resumes_at_any_shard_count() {
        let dir = std::env::temp_dir().join("bf16_qsim_shard_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("shard_resume.ckpt");
        let task = MlpConfig { seed: 17, ..Default::default() };
        let mut full = ShardedTrainer::new(task.clone(), Mode::Sr16, opts(2, 4)).unwrap();
        let mut interrupted =
            ShardedTrainer::new(task.clone(), Mode::Sr16, opts(2, 4)).unwrap();
        for _ in 0..4 {
            full.step(0.1);
            interrupted.step(0.1);
        }
        interrupted.save_checkpoint(&path).unwrap();
        drop(interrupted);
        let mut resumed = ShardedTrainer::new(task, Mode::Sr16, opts(4, 4)).unwrap();
        resumed.load_checkpoint(&path).unwrap();
        assert_eq!(resumed.steps_done(), 4);
        for step in 0..4 {
            let a = full.step(0.1);
            let b = resumed.step(0.1);
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "post-resume step {step}");
        }
        assert_eq!(full.param_digest(), resumed.param_digest());
    }

    #[test]
    fn invalid_shard_geometry_is_rejected() {
        let mk = |n, m| ShardedTrainer::new(MlpConfig::default(), Mode::Sr16, opts(n, m));
        assert!(mk(0, 4).is_err(), "zero shards");
        assert!(mk(3, 4).is_err(), "non-power-of-two shards");
        assert!(mk(1, 3).is_err(), "non-power-of-two microbatches");
        assert!(mk(8, 4).is_err(), "more shards than microbatches");
    }
}
