//! Dense f32 tensor (rank ≤ 2) for the quantised-autograd simulator.
//!
//! Deliberately minimal: the simulator exists to reproduce the paper's
//! numerical behaviour (per-operator output rounding with fp32 FMAC
//! accumulation), not to be a general array library.  Row-major storage.

use crate::util::rng::Rng;

/// Dense row-major tensor, rank 1 or 2 (a rank-1 tensor has rows == 1).
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    pub fn vector(data: Vec<f32>) -> Self {
        Self { rows: 1, cols: data.len(), data }
    }

    pub fn scalar(v: f32) -> Self {
        Self { rows: 1, cols: 1, data: vec![v] }
    }

    /// Standard-normal init scaled by `scale`.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
        Self { rows, cols, data }
    }

    /// Uniform init in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform_in(lo, hi)).collect();
        Self { rows, cols, data }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.len(), 1, "item() on non-scalar");
        self.data[0]
    }

    /// `self @ other` with f32 FMAC accumulation.
    ///
    /// The paper's 16-bit FMAC unit multiplies 16-bit operands and
    /// accumulates in 32 bits; operands here are 16-bit *values* stored in
    /// f32, so plain f32 accumulation models the unit exactly.  The caller
    /// rounds the output (one rounding per operator).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor::zeros(m, n);
        // i-k-j loop order: streams `other` rows, vectorizes over j.
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (acc, &b) in orow.iter_mut().zip(brow) {
                    *acc += a * b;
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                *out.at_mut(c, r) = self.at(r, c);
            }
        }
        out
    }

    /// Element-wise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Element-wise binary op (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(5, 0);
        let a = Tensor::randn(3, 4, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn zip_and_map() {
        let a = Tensor::vector(vec![1.0, -2.0]);
        let b = Tensor::vector(vec![0.5, 0.5]);
        assert_eq!(a.zip(&b, |x, y| x * y).data, vec![0.5, -1.0]);
        assert_eq!(a.map(f32::abs).data, vec![1.0, 2.0]);
    }
}
