//! Dense f32 tensor (rank ≤ 2) for the quantised-autograd simulator.
//!
//! Deliberately minimal: the simulator exists to reproduce the paper's
//! numerical behaviour (per-operator output rounding with fp32 FMAC
//! accumulation), not to be a general array library.  Row-major storage.
//!
//! ## Native 16-bit storage
//!
//! Persistent training state (weights, momentum, Kahan compensation) under
//! the 16-bit modes holds values that are *exactly representable* on the
//! bf16 grid — the optimizer rounds every write onto the storage format and
//! init is quantised.  [`Storage::Bf16`] stores those buffers as the top 16
//! bits of their f32 patterns (`Vec<u16>`, half the bytes), so the paper's
//! 2×-memory claim is measured, not modeled.  Narrowing is lossless by
//! construction (widen-on-read reproduces the identical f32 bits), which is
//! what keeps every backend digest unchanged when storage narrows.  Compute
//! tensors (activations, gradients, tape arena buffers) stay [`Storage::F32`].

use crate::precision::{round_nearest_slice, Format};
use crate::util::rng::Rng;

use super::pool::Pool;

/// j-register-block width of the SIMD matmul microkernel: eight f32
/// accumulators held in registers across the whole k loop (one 256-bit
/// vector).
const MM_SIMD_JW: usize = 8;

/// k-panel height: rows of `other` streamed per tile (64 rows × ≤256 cols of
/// f32 fits L1 alongside the output panel).
const MM_KB: usize = 64;
/// j-panel width: output columns accumulated per tile.
const MM_NB: usize = 256;
/// Minimum multiply-accumulate count before a matmul is worth fanning out
/// across the worker pool (below this, one dispatch handshake costs more
/// than the whole product).
const MM_PAR_MIN: usize = 16_384;

/// Physical representation of a tensor's element buffer.
///
/// `F32` is the default for everything the tape computes with.  `Bf16`
/// holds bf16 bit patterns (top 16 bits of the f32 pattern) and is used for
/// persistent training state whose values are in-format by construction —
/// see the module docs.  Conversion helpers: [`Tensor::narrow_to_bf16`],
/// [`Tensor::widen_to_f32`], [`Tensor::to_f32_vec`],
/// [`Tensor::set_from_f32`].
#[derive(Debug, Clone, PartialEq, Default)]
pub enum Storage {
    /// Full-precision buffer — lives in [`Tensor::data`].
    #[default]
    F32,
    /// Native 16-bit buffer (bf16 bit patterns); [`Tensor::data`] is empty.
    Bf16(Vec<u16>),
}

/// Widening read: bf16 bits → the f32 whose top half they are.
#[inline]
pub fn bf16_bits_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// Narrowing write by truncation — lossless iff `x` is on the bf16 grid
/// (which persistent 16-bit training state is, by construction).
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    (x.to_bits() >> 16) as u16
}

/// Dense row-major tensor, rank 1 or 2 (a rank-1 tensor has rows == 1).
///
/// `data` holds the elements when `store` is [`Storage::F32`] (the default
/// everywhere except narrowed training state); direct `data` access on a
/// narrowed tensor sees an empty buffer — go through [`Tensor::to_f32_vec`]
/// / [`Tensor::set_from_f32`] or widen first.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
    pub store: Storage,
}

impl Tensor {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols], store: Storage::F32 }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data, store: Storage::F32 }
    }

    pub fn vector(data: Vec<f32>) -> Self {
        Self { rows: 1, cols: data.len(), data, store: Storage::F32 }
    }

    pub fn scalar(v: f32) -> Self {
        Self { rows: 1, cols: 1, data: vec![v], store: Storage::F32 }
    }

    /// Standard-normal init scaled by `scale`.
    pub fn randn(rows: usize, cols: usize, scale: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.normal() * scale).collect();
        Self { rows, cols, data, store: Storage::F32 }
    }

    /// Uniform init in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.uniform_in(lo, hi)).collect();
        Self { rows, cols, data, store: Storage::F32 }
    }

    #[inline]
    pub fn len(&self) -> usize {
        match &self.store {
            Storage::F32 => self.data.len(),
            Storage::Bf16(h) => h.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether this tensor stores its elements in a native 16-bit buffer.
    #[inline]
    pub fn is_native16(&self) -> bool {
        matches!(self.store, Storage::Bf16(_))
    }

    /// Measured bytes of the element buffer as allocated — 4 per element
    /// for [`Storage::F32`], 2 for [`Storage::Bf16`].  This is the
    /// *measured* side of the hwcost memory model.
    pub fn storage_bytes(&self) -> u64 {
        match &self.store {
            Storage::F32 => self.data.len() as u64 * 4,
            Storage::Bf16(h) => h.len() as u64 * 2,
        }
    }

    /// Narrow the element buffer to native bf16 storage.  Every value must
    /// already be on the bf16 grid (debug-asserted): narrowing is a
    /// representation change, never a rounding step — digests are invariant
    /// under it.  No-op if already narrow.
    pub fn narrow_to_bf16(&mut self) {
        if self.is_native16() {
            return;
        }
        let h: Vec<u16> = self
            .data
            .iter()
            .map(|&x| {
                let h = f32_to_bf16_bits(x);
                debug_assert_eq!(
                    bf16_bits_to_f32(h).to_bits(),
                    x.to_bits(),
                    "narrowing a value not on the bf16 grid: {x}"
                );
                h
            })
            .collect();
        self.data = Vec::new();
        self.store = Storage::Bf16(h);
    }

    /// Widen a narrow buffer back to f32 storage in place.  No-op for f32
    /// tensors.  Lossless (bf16 is a value subset of f32).
    pub fn widen_to_f32(&mut self) {
        if let Storage::Bf16(h) = std::mem::take(&mut self.store) {
            self.data = h.iter().map(|&b| bf16_bits_to_f32(b)).collect();
        }
    }

    /// Widened copy of the element buffer regardless of storage.
    pub fn to_f32_vec(&self) -> Vec<f32> {
        match &self.store {
            Storage::F32 => self.data.clone(),
            Storage::Bf16(h) => h.iter().map(|&b| bf16_bits_to_f32(b)).collect(),
        }
    }

    /// Widen the element buffer into a caller-owned scratch slice
    /// (`dst.len()` must equal [`Tensor::len`]); allocation-free on the
    /// steady-state optimizer path.
    pub fn widen_into(&self, dst: &mut [f32]) {
        match &self.store {
            Storage::F32 => dst.copy_from_slice(&self.data),
            Storage::Bf16(h) => {
                for (d, &b) in dst.iter_mut().zip(h.iter()) {
                    *d = bf16_bits_to_f32(b);
                }
            }
        }
    }

    /// Storage-aware element write: copies `src` into the buffer, narrowing
    /// by truncation when the tensor is native-16.  `src.len()` must equal
    /// [`Tensor::len`]; values must be in-format for narrow tensors (same
    /// losslessness contract as [`Tensor::narrow_to_bf16`]).
    pub fn set_from_f32(&mut self, src: &[f32]) {
        match &mut self.store {
            Storage::F32 => self.data.copy_from_slice(src),
            Storage::Bf16(h) => {
                assert_eq!(h.len(), src.len(), "set_from_f32 length mismatch");
                for (d, &x) in h.iter_mut().zip(src.iter()) {
                    debug_assert_eq!(
                        bf16_bits_to_f32(f32_to_bf16_bits(x)).to_bits(),
                        x.to_bits(),
                        "writing a value not on the bf16 grid: {x}"
                    );
                    *d = f32_to_bf16_bits(x);
                }
            }
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.len(), 1, "item() on non-scalar");
        self.data[0]
    }

    /// `self @ other` with f32 FMAC accumulation.
    ///
    /// The paper's 16-bit FMAC unit multiplies 16-bit operands and
    /// accumulates in 32 bits; operands here are 16-bit *values* stored in
    /// f32, so plain f32 accumulation models the unit exactly.  The caller
    /// rounds the output (one rounding per operator).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let mut out = Tensor::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out, None);
        out
    }

    /// Cache-blocked `self @ other` into a caller-owned output tensor.
    ///
    /// Tiles the k and j loops into panels so `other`'s rows and the output
    /// panel stay L1-resident while the inner multiply-accumulate loop
    /// vectorizes over j.  Each output element accumulates its k terms in
    /// strictly increasing k order with the same zero-skip, so the result is
    /// bit-identical to [`Tensor::matmul_reference`].
    ///
    /// With `round: Some(fmt)`, each finished output row is nearest-rounded
    /// onto `fmt` while still cache-hot — the operator's output rounding
    /// fused into the producing kernel instead of a second memory pass.
    pub fn matmul_into(&self, other: &Tensor, out: &mut Tensor, round: Option<Format>) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        out.rows = m;
        out.cols = n;
        out.data.clear();
        out.data.resize(m * n, 0.0);
        self.mm_rows(other, 0, &mut out.data, round);
    }

    /// Tiled multiply for one contiguous band of output rows starting at
    /// `row0` (`band.len()` must be a multiple of `other.cols`).  Each row
    /// is produced entirely by one call with the k accumulation order of
    /// the scalar reference, so any row partition of the output — including
    /// a parallel one — yields bit-identical results.
    fn mm_rows(&self, other: &Tensor, row0: usize, band: &mut [f32], round: Option<Format>) {
        let (k, n) = (self.cols, other.cols);
        if n == 0 {
            return;
        }
        debug_assert_eq!(band.len() % n, 0);
        for (bi, orow) in band.chunks_exact_mut(n).enumerate() {
            let i = row0 + bi;
            let arow = &self.data[i * k..(i + 1) * k];
            for j0 in (0..n).step_by(MM_NB) {
                let j1 = (j0 + MM_NB).min(n);
                let opanel = &mut orow[j0..j1];
                for k0 in (0..k).step_by(MM_KB) {
                    let k1 = (k0 + MM_KB).min(k);
                    for (kk, &a) in arow[k0..k1].iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let brow = &other.data[(k0 + kk) * n + j0..(k0 + kk) * n + j1];
                        for (acc, &b) in opanel.iter_mut().zip(brow) {
                            *acc += a * b;
                        }
                    }
                }
            }
            if let Some(fmt) = round {
                round_nearest_slice(orow, fmt);
            }
        }
    }

    /// [`Tensor::matmul_into`] with the output rows fanned out across a
    /// worker [`Pool`] in contiguous bands.
    ///
    /// Every output element still accumulates its k terms sequentially in
    /// one band pass, so the result is bit-identical to the sequential and
    /// scalar-reference kernels at any thread count.  Small products (fewer
    /// than [`MM_PAR_MIN`] multiply-accumulates) stay sequential — the
    /// dispatch handshake would dominate.
    pub fn matmul_into_pooled(
        &self,
        other: &Tensor,
        out: &mut Tensor,
        round: Option<Format>,
        pool: &Pool,
    ) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        if pool.threads() <= 1 || m < 2 || m * k * n < MM_PAR_MIN {
            self.matmul_into(other, out, round);
            return;
        }
        out.rows = m;
        out.cols = n;
        out.data.clear();
        out.data.resize(m * n, 0.0);
        let t = pool.threads().min(m);
        let rows_per = m.div_ceil(t);
        let mut bands: Vec<(usize, &mut [f32])> = Vec::with_capacity(t);
        let mut rest = out.data.as_mut_slice();
        let mut row0 = 0usize;
        while row0 < m {
            let take = rows_per.min(m - row0);
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            bands.push((row0, band));
            rest = tail;
            row0 += take;
        }
        pool.run_parts(bands, |(row0, band)| {
            self.mm_rows(other, *row0, &mut **band, round);
        });
    }

    /// [`Tensor::matmul_into`] through the SIMD microkernel
    /// ([`Tensor::mm_rows_simd`]): 8-wide register-blocked j panels with the
    /// same per-element ascending-k accumulation and zero-skip, so the
    /// result is bit-identical to both other kernels.
    pub fn matmul_into_simd(&self, other: &Tensor, out: &mut Tensor, round: Option<Format>) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, n) = (self.rows, other.cols);
        out.rows = m;
        out.cols = n;
        out.data.clear();
        out.data.resize(m * n, 0.0);
        self.mm_rows_simd(other, 0, &mut out.data, round);
    }

    /// SIMD microkernel for one contiguous band of output rows: the j loop
    /// is register-blocked [`MM_SIMD_JW`] columns wide, with the eight f32
    /// accumulators living in one vector register across the entire k loop
    /// (the tiled kernel re-reads its output panel from cache every k
    /// iteration instead).  Each output element still accumulates its k
    /// terms in strictly ascending order with the same `a == 0` skip, and
    /// fused output rounding goes through the 8-lane rounding kernel — so
    /// the band is bit-identical to [`Tensor::mm_rows`] and to
    /// [`Tensor::matmul_reference`].
    fn mm_rows_simd(&self, other: &Tensor, row0: usize, band: &mut [f32], round: Option<Format>) {
        use crate::precision::round_nearest_slice_simd;
        let (k, n) = (self.cols, other.cols);
        if n == 0 {
            return;
        }
        debug_assert_eq!(band.len() % n, 0);
        for (bi, orow) in band.chunks_exact_mut(n).enumerate() {
            let i = row0 + bi;
            let arow = &self.data[i * k..(i + 1) * k];
            let mut j0 = 0usize;
            while j0 < n {
                let jw = (n - j0).min(MM_SIMD_JW);
                let mut acc = [0f32; MM_SIMD_JW];
                for (kk, &a) in arow.iter().enumerate() {
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &other.data[kk * n + j0..kk * n + j0 + jw];
                    for (l, &b) in brow.iter().enumerate() {
                        acc[l] += a * b;
                    }
                }
                orow[j0..j0 + jw].copy_from_slice(&acc[..jw]);
                j0 += jw;
            }
            if let Some(fmt) = round {
                round_nearest_slice_simd(orow, fmt);
            }
        }
    }

    /// [`Tensor::matmul_into_simd`] with the output rows fanned out across
    /// a worker [`Pool`] in contiguous bands (same banding and threshold as
    /// [`Tensor::matmul_into_pooled`]); bit-identical at every thread count.
    pub fn matmul_into_pooled_simd(
        &self,
        other: &Tensor,
        out: &mut Tensor,
        round: Option<Format>,
        pool: &Pool,
    ) {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        if pool.threads() <= 1 || m < 2 || m * k * n < MM_PAR_MIN {
            self.matmul_into_simd(other, out, round);
            return;
        }
        out.rows = m;
        out.cols = n;
        out.data.clear();
        out.data.resize(m * n, 0.0);
        let t = pool.threads().min(m);
        let rows_per = m.div_ceil(t);
        let mut bands: Vec<(usize, &mut [f32])> = Vec::with_capacity(t);
        let mut rest = out.data.as_mut_slice();
        let mut row0 = 0usize;
        while row0 < m {
            let take = rows_per.min(m - row0);
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            bands.push((row0, band));
            rest = tail;
            row0 += take;
        }
        pool.run_parts(bands, |(row0, band)| {
            self.mm_rows_simd(other, *row0, &mut **band, round);
        });
    }

    /// `self @ otherᵀ` with f32 FMAC accumulation (no transposed copy):
    /// `out[i][j] = Σ_k self[i,k] · other[j,k]`.  The tied-softmax output
    /// projection (`logits = x @ embedᵀ`) runs through this so weight tying
    /// never materializes a transposed table.
    ///
    /// One kernel serves both backends: every output element is a row-local
    /// dot product accumulated in ascending k, so the pooled row fan-out
    /// ([`Tensor::matmul_nt_into_pooled`]) and the sequential call are
    /// bit-identical by construction.
    pub fn matmul_nt_into(&self, other: &Tensor, out: &mut Tensor) {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, n) = (self.rows, other.rows);
        out.rows = m;
        out.cols = n;
        out.data.clear();
        out.data.resize(m * n, 0.0);
        self.nt_rows(other, 0, &mut out.data);
    }

    /// `self @ otherᵀ` for one contiguous band of output rows starting at
    /// `row0` (`band.len()` must be a multiple of `other.rows`).
    fn nt_rows(&self, other: &Tensor, row0: usize, band: &mut [f32]) {
        let (k, n) = (self.cols, other.rows);
        if n == 0 {
            return;
        }
        debug_assert_eq!(band.len() % n, 0);
        for (bi, orow) in band.chunks_exact_mut(n).enumerate() {
            let i = row0 + bi;
            let arow = &self.data[i * k..(i + 1) * k];
            for (j, acc) in orow.iter_mut().enumerate() {
                let brow = &other.data[j * k..(j + 1) * k];
                let mut s = 0f32;
                for (&a, &b) in arow.iter().zip(brow) {
                    s += a * b;
                }
                *acc = s;
            }
        }
    }

    /// [`Tensor::matmul_nt_into`] with the output rows fanned out across a
    /// worker [`Pool`] in contiguous bands; small products stay sequential.
    /// Bit-identical at every thread count (row-local dot products).
    pub fn matmul_nt_into_pooled(&self, other: &Tensor, out: &mut Tensor, pool: &Pool) {
        assert_eq!(self.cols, other.cols, "matmul_nt shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.rows);
        if pool.threads() <= 1 || m < 2 || m * k * n < MM_PAR_MIN {
            self.matmul_nt_into(other, out);
            return;
        }
        out.rows = m;
        out.cols = n;
        out.data.clear();
        out.data.resize(m * n, 0.0);
        let t = pool.threads().min(m);
        let rows_per = m.div_ceil(t);
        let mut bands: Vec<(usize, &mut [f32])> = Vec::with_capacity(t);
        let mut rest = out.data.as_mut_slice();
        let mut row0 = 0usize;
        while row0 < m {
            let take = rows_per.min(m - row0);
            let (band, tail) = std::mem::take(&mut rest).split_at_mut(take * n);
            bands.push((row0, band));
            rest = tail;
            row0 += take;
        }
        pool.run_parts(bands, |(row0, band)| {
            self.nt_rows(other, *row0, &mut **band);
        });
    }

    /// The original scalar i-k-j matmul, kept as the bit-exactness oracle
    /// for the tiled kernel (and as the `Backend::Reference` bench baseline).
    pub fn matmul_reference(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Tensor::zeros(m, n);
        // i-k-j loop order: streams `other` rows, vectorizes over j.
        for i in 0..m {
            let orow = &mut out.data[i * n..(i + 1) * n];
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                for (acc, &b) in orow.iter_mut().zip(brow) {
                    *acc += a * b;
                }
            }
        }
        out
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Tensor {
        let mut out = Tensor::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a caller-owned tensor (backward-pass scratch reuse).
    pub fn transpose_into(&self, out: &mut Tensor) {
        out.rows = self.cols;
        out.cols = self.rows;
        out.data.clear();
        out.data.resize(self.rows * self.cols, 0.0);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
    }

    /// Element-wise map into a new tensor.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
            store: Storage::F32,
        }
    }

    /// Element-wise binary op (shapes must match).
    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.rows, other.rows);
        assert_eq!(self.cols, other.cols);
        Tensor {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
            store: Storage::F32,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(5, 0);
        let a = Tensor::randn(3, 4, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    #[should_panic]
    fn matmul_shape_mismatch_panics() {
        let a = Tensor::zeros(2, 3);
        let b = Tensor::zeros(2, 3);
        let _ = a.matmul(&b);
    }

    #[test]
    fn tiled_matmul_bit_identical_to_reference() {
        let mut rng = Rng::new(0x77, 0);
        // odd/unaligned shapes straddling the MM_KB/MM_NB panel boundaries
        for (m, k, n) in [
            (1, 1, 1),
            (3, 5, 7),
            (2, 63, 65),
            (4, 64, 256),
            (5, 65, 257),
            (2, 200, 300),
        ] {
            let mut a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            // sprinkle zeros to exercise the zero-skip path
            for i in 0..a.data.len() {
                if i % 7 == 0 {
                    a.data[i] = 0.0;
                }
            }
            let fast = a.matmul(&b);
            let reference = a.matmul_reference(&b);
            assert_eq!(fast.rows, reference.rows);
            assert_eq!(fast.cols, reference.cols);
            for (i, (x, y)) in fast.data.iter().zip(&reference.data).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n}) elem {i}");
            }
        }
    }

    #[test]
    fn pooled_matmul_bit_identical_at_every_thread_count() {
        use crate::precision::BF16;
        let mut rng = Rng::new(0x7A7, 0);
        // shapes below and above the MM_PAR_MIN fan-out threshold, ragged
        // row counts that don't divide evenly across workers
        for (m, k, n) in [(1, 8, 8), (3, 5, 7), (7, 64, 64), (33, 96, 50), (128, 64, 40)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            for round in [None, Some(BF16)] {
                let mut seq = Tensor::zeros(0, 0);
                a.matmul_into(&b, &mut seq, round);
                for threads in [1usize, 2, 3, 4] {
                    let pool = Pool::new(threads);
                    let mut par = Tensor::zeros(0, 0);
                    a.matmul_into_pooled(&b, &mut par, round, &pool);
                    assert_eq!(par.rows, seq.rows);
                    assert_eq!(par.cols, seq.cols);
                    for (i, (x, y)) in par.data.iter().zip(&seq.data).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "({m},{k},{n}) threads={threads} round={round:?} elem {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn simd_matmul_bit_identical_to_reference_with_and_without_rounding() {
        use crate::precision::BF16;
        let mut rng = Rng::new(0x7A9, 0);
        // odd/unaligned shapes straddling the 8-wide register block
        for (m, k, n) in [
            (1, 1, 1),
            (3, 5, 7),
            (2, 63, 65),
            (4, 64, 256),
            (5, 65, 257),
            (2, 200, 300),
            (7, 9, 8),
        ] {
            let mut a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            // sprinkle zeros to exercise the zero-skip path
            for i in 0..a.data.len() {
                if i % 7 == 0 {
                    a.data[i] = 0.0;
                }
            }
            for round in [None, Some(BF16)] {
                let mut simd = Tensor::zeros(0, 0);
                a.matmul_into_simd(&b, &mut simd, round);
                let mut reference = a.matmul_reference(&b);
                if let Some(fmt) = round {
                    round_nearest_slice(&mut reference.data, fmt);
                }
                assert_eq!(simd.rows, reference.rows);
                assert_eq!(simd.cols, reference.cols);
                for (i, (x, y)) in simd.data.iter().zip(&reference.data).enumerate() {
                    assert_eq!(x.to_bits(), y.to_bits(), "({m},{k},{n}) round={round:?} elem {i}");
                }
            }
        }
    }

    #[test]
    fn pooled_simd_matmul_bit_identical_at_every_thread_count() {
        use crate::precision::BF16;
        let mut rng = Rng::new(0x7AA, 0);
        for (m, k, n) in [(1, 8, 8), (3, 5, 7), (7, 64, 64), (33, 96, 50), (128, 64, 40)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(k, n, 1.0, &mut rng);
            for round in [None, Some(BF16)] {
                let mut seq = Tensor::zeros(0, 0);
                a.matmul_into_simd(&b, &mut seq, round);
                for threads in [1usize, 2, 3, 4] {
                    let pool = Pool::new(threads);
                    let mut par = Tensor::zeros(0, 0);
                    a.matmul_into_pooled_simd(&b, &mut par, round, &pool);
                    assert_eq!(par.rows, seq.rows);
                    assert_eq!(par.cols, seq.cols);
                    for (i, (x, y)) in par.data.iter().zip(&seq.data).enumerate() {
                        assert_eq!(
                            x.to_bits(),
                            y.to_bits(),
                            "({m},{k},{n}) threads={threads} round={round:?} elem {i}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn bf16_storage_round_trip_is_lossless_for_in_format_values() {
        use crate::precision::{round_nearest, BF16, E8M1, E8M5};
        let mut rng = Rng::new(0x7AB, 0);
        for fmt in [BF16, E8M5, E8M1] {
            let mut t = Tensor::randn(7, 9, 1.0, &mut rng);
            for x in &mut t.data {
                *x = round_nearest(*x, fmt);
            }
            let want = t.data.clone();
            assert_eq!(t.storage_bytes(), 7 * 9 * 4);
            t.narrow_to_bf16();
            assert!(t.is_native16());
            assert_eq!(t.len(), 63);
            assert_eq!(t.storage_bytes(), 7 * 9 * 2, "{}: half the bytes", fmt.name);
            // widened reads reproduce the identical bits
            for (i, (a, b)) in t.to_f32_vec().iter().zip(&want).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} elem {i}", fmt.name);
            }
            // storage-aware writes round-trip too
            let updated: Vec<f32> =
                want.iter().map(|&x| round_nearest(x * 0.5, fmt)).collect();
            t.set_from_f32(&updated);
            for (i, (a, b)) in t.to_f32_vec().iter().zip(&updated).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{} write elem {i}", fmt.name);
            }
            t.widen_to_f32();
            assert!(!t.is_native16());
            assert_eq!(t.data, updated);
        }
    }

    #[test]
    fn widen_into_matches_to_f32_vec() {
        let mut t = Tensor::vector(vec![1.0, -2.0, 0.5, 0.0]);
        let mut dst = vec![0.0f32; 4];
        t.widen_into(&mut dst);
        assert_eq!(dst, t.to_f32_vec());
        t.narrow_to_bf16();
        t.widen_into(&mut dst);
        assert_eq!(dst, t.to_f32_vec());
    }

    #[test]
    fn matmul_nt_matches_explicit_transpose() {
        let mut rng = Rng::new(0x7D1, 0);
        for (m, k, n) in [(1, 1, 1), (3, 5, 7), (5, 33, 17), (33, 64, 50)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(n, k, 1.0, &mut rng);
            let mut nt = Tensor::zeros(0, 0);
            a.matmul_nt_into(&b, &mut nt);
            let via_t = a.matmul_reference(&b.transpose());
            assert_eq!(nt.rows, via_t.rows);
            assert_eq!(nt.cols, via_t.cols);
            for (i, (x, y)) in nt.data.iter().zip(&via_t.data).enumerate() {
                assert!((x - y).abs() <= 1e-5 * (1.0 + y.abs()), "({m},{k},{n}) elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn pooled_matmul_nt_bit_identical_at_every_thread_count() {
        let mut rng = Rng::new(0x7D2, 0);
        // shapes below and above the fan-out threshold, ragged row counts
        for (m, k, n) in [(1, 8, 8), (3, 5, 7), (33, 96, 50), (128, 64, 40)] {
            let a = Tensor::randn(m, k, 1.0, &mut rng);
            let b = Tensor::randn(n, k, 1.0, &mut rng);
            let mut seq = Tensor::zeros(0, 0);
            a.matmul_nt_into(&b, &mut seq);
            for threads in [1usize, 2, 3, 4] {
                let pool = Pool::new(threads);
                let mut par = Tensor::zeros(0, 0);
                a.matmul_nt_into_pooled(&b, &mut par, &pool);
                assert_eq!(par.rows, seq.rows);
                assert_eq!(par.cols, seq.cols);
                for (i, (x, y)) in par.data.iter().zip(&seq.data).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "({m},{k},{n}) threads={threads} elem {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn matmul_into_fused_rounding_matches_post_pass() {
        use crate::precision::{round_nearest, BF16};
        let mut rng = Rng::new(0x78, 0);
        let a = Tensor::randn(5, 33, 1.0, &mut rng);
        let b = Tensor::randn(33, 17, 1.0, &mut rng);
        let mut fused = Tensor::zeros(0, 0);
        a.matmul_into(&b, &mut fused, Some(BF16));
        let mut post = a.matmul_reference(&b);
        for x in &mut post.data {
            *x = round_nearest(*x, BF16);
        }
        assert_eq!(fused.data, post.data);
    }

    #[test]
    fn transpose_into_reuses_buffer() {
        let mut rng = Rng::new(0x79, 0);
        let a = Tensor::randn(3, 4, 1.0, &mut rng);
        let mut out = Tensor::zeros(9, 9); // wrong shape on purpose
        a.transpose_into(&mut out);
        assert_eq!(out, a.transpose());
    }

    #[test]
    fn zip_and_map() {
        let a = Tensor::vector(vec![1.0, -2.0]);
        let b = Tensor::vector(vec![0.5, 0.5]);
        assert_eq!(a.zip(&b, |x, y| x * y).data, vec![0.5, -1.0]);
        assert_eq!(a.map(f32::abs).data, vec![1.0, 2.0]);
    }
}
